//! PJRT CPU client and artifact loading.
//!
//! Wraps the `xla` crate: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile`. HLO *text* is
//! the interchange format (see python/compile/aot.py and
//! /opt/xla-example/README.md for the proto-id rationale).

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use super::executable::Executable;
use super::meta::Meta;

/// A PJRT CPU client bound to an artifact directory.
pub struct Runtime {
    client: xla::PjRtClient,
    artifact_dir: PathBuf,
    meta: Meta,
}

impl Runtime {
    /// Create a CPU client and read the shape contract from
    /// `artifact_dir/meta.json`.
    pub fn new(artifact_dir: impl Into<PathBuf>) -> Result<Runtime> {
        let artifact_dir = artifact_dir.into();
        let meta = Meta::load(&artifact_dir)?;
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        Ok(Runtime { client, artifact_dir, meta })
    }

    /// The artifact shape contract.
    pub fn meta(&self) -> Meta {
        self.meta
    }

    /// PJRT platform string (e.g. `"cpu"`), for logs.
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load and compile `<artifact_dir>/<name>.hlo.txt`.
    pub fn load(&self, name: &str) -> Result<Executable> {
        let path = self.artifact_dir.join(format!("{name}.hlo.txt"));
        self.load_path(&path)
    }

    /// Load and compile an HLO text file at an explicit path.
    pub fn load_path(&self, path: &Path) -> Result<Executable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path is not UTF-8")?,
        )
        .with_context(|| format!("parse HLO text {} (run `make artifacts`)", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("PJRT compile {}", path.display()))?;
        Ok(Executable::new(exe, path.display().to_string()))
    }
}

impl std::fmt::Debug for Runtime {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Runtime")
            .field("artifact_dir", &self.artifact_dir)
            .field("meta", &self.meta)
            .finish_non_exhaustive()
    }
}
