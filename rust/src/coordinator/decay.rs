//! Exponential-decay AUC — the future-work line the paper names (§5).
//!
//! “The other option is to gradually forget the data points, for example
//! using an exponential decay […] There are currently no methodology for
//! efficiently estimating AUC under exponential decay, and this is a
//! promising future line of work.”
//!
//! This estimator combines two observations:
//!
//! 1. AUC is **scale-invariant in the weights** (numerator and the
//!    normalizer `WP·WN` both scale quadratically), so instead of
//!    decaying every stored weight by `γ` per event — `O(k)` — new
//!    events are inserted with *growing* weight `γ^{−t}` and nothing
//!    already stored ever changes.
//! 2. With weighted points the incremental `C`-list machinery of §4
//!    does not apply (Lemma 1 needs unit updates), but the §7
//!    from-scratch `(1+ε)`-list construction does — giving an
//!    `ε·auc/2`-approximate query in `O((log² k)/ε)`.
//!
//! Two maintenance chores keep the structure bounded:
//! * events whose relative weight has decayed below `horizon_tol` are
//!   evicted (FIFO order = ascending weight, so a deque suffices) —
//!   the live set is `O(log(1/tol)/log(1/γ))` events;
//! * before `γ^{−t}` overflows `f64`, the structure is rebuilt with
//!   weights rescaled by the current maximum (AUC is unchanged by
//!   scale invariance; a rebuild is `O(k log k)` amortized over the
//!   ~10⁵ events between rebuilds).

use std::collections::VecDeque;

use super::scratch::WeightedAuc;

/// Exponentially decayed AUC estimator (`insert`-only streaming; old
/// events fade at rate `γ` per event and are evicted beyond the
/// horizon).
#[derive(Clone, Debug)]
pub struct DecayedAuc {
    inner: WeightedAuc,
    /// Per-event decay factor `γ ∈ (0, 1)`.
    gamma: f64,
    /// Relative weight below which events are evicted.
    horizon_tol: f64,
    /// Weight assigned to the *next* event (`γ^{−t}`, grows).
    next_weight: f64,
    /// Live events, oldest first: `(score, pos, stored_weight)`.
    live: VecDeque<(f64, bool, f64)>,
}

impl DecayedAuc {
    /// New estimator. Typical: `gamma = 0.999` (half-life ≈ 693
    /// events), `horizon_tol = 1e-4` (events keep influencing AUC until
    /// they carry < 0.01% of a fresh event's weight).
    pub fn new(gamma: f64, horizon_tol: f64) -> Self {
        assert!(gamma > 0.0 && gamma < 1.0, "gamma must be in (0, 1)");
        assert!(
            horizon_tol > 0.0 && horizon_tol < 1.0,
            "horizon_tol must be in (0, 1)"
        );
        DecayedAuc {
            inner: WeightedAuc::new(),
            gamma,
            horizon_tol,
            next_weight: 1.0,
            live: VecDeque::new(),
        }
    }

    /// Number of events currently contributing (inside the horizon).
    pub fn len(&self) -> usize {
        self.live.len()
    }

    /// True before the first insert.
    pub fn is_empty(&self) -> bool {
        self.live.is_empty()
    }

    /// The effective horizon in events for the configured `γ`/tolerance.
    pub fn horizon(&self) -> usize {
        (self.horizon_tol.ln() / self.gamma.ln()).ceil() as usize
    }

    /// Insert the next stream event. Amortized `O(log k)` plus the
    /// occasional rescale rebuild.
    pub fn insert(&mut self, score: f64, pos: bool) {
        let w = self.next_weight;
        self.inner.insert(score, pos, w);
        self.live.push_back((score, pos, w));
        self.next_weight /= self.gamma;
        // Evict events that fell beyond the horizon (oldest = smallest
        // stored weight; eviction order is FIFO).
        let cutoff = self.next_weight * self.horizon_tol;
        while let Some(&(s, p, ew)) = self.live.front() {
            if ew >= cutoff {
                break;
            }
            self.inner.remove(s, p, ew);
            self.live.pop_front();
        }
        // Rescale long before f64 overflows. The binding constraint is
        // the normalizer `WP·WN`, which SQUARES the magnitude: keep
        // total weights below ~1e120 so products stay ≪ 1e308.
        if self.next_weight > 1e120 {
            self.rescale();
        }
    }

    /// Rebuild with all weights divided by the current scale; AUC is
    /// invariant under the rescaling.
    fn rescale(&mut self) {
        let scale = self.next_weight;
        let mut rebuilt = WeightedAuc::new();
        for (s, p, w) in self.live.iter_mut() {
            *w /= scale;
            rebuilt.insert(*s, *p, *w);
        }
        self.inner = rebuilt;
        self.next_weight = 1.0;
    }

    /// Exact decayed AUC (`O(k)` over distinct scores in the horizon).
    pub fn exact_auc(&self) -> f64 {
        self.inner.exact_auc()
    }

    /// `ε·auc/2`-approximate decayed AUC via the §7 from-scratch
    /// `(1+ε)`-list (`O((log² k)/ε)`).
    pub fn approx_auc(&self, epsilon: f64) -> f64 {
        self.inner.approx_auc(epsilon)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::NaiveAuc;
    use crate::testing::Pcg;

    #[test]
    fn matches_naive_exponential_weighting() {
        // Brute force: AUC with explicit weights γ^age over all events.
        let mut rng = Pcg::seed(1);
        let gamma: f64 = 0.99;
        let mut est = DecayedAuc::new(gamma, 1e-9); // huge horizon
        let mut events: Vec<(f64, bool)> = Vec::new();
        for _ in 0..500 {
            let pos = rng.chance(0.4);
            let s = if pos { rng.normal_with(0.4, 0.2) } else { rng.normal_with(0.6, 0.2) };
            est.insert(s, pos);
            events.push((s, pos));
        }
        // Brute-force weighted AUC.
        let n = events.len();
        let mut num = 0.0;
        let mut wp = 0.0;
        let mut wn = 0.0;
        for (i, &(si, pi)) in events.iter().enumerate() {
            let wi = gamma.powi((n - 1 - i) as i32);
            if pi {
                wp += wi;
            } else {
                wn += wi;
            }
            for (j, &(sj, pj)) in events.iter().enumerate() {
                if pi && !pj {
                    let wj = gamma.powi((n - 1 - j) as i32);
                    num += wi
                        * wj
                        * if si < sj {
                            1.0
                        } else if si == sj {
                            0.5
                        } else {
                            0.0
                        };
                }
            }
        }
        let want = num / (wp * wn);
        let got = est.exact_auc();
        assert!((got - want).abs() < 1e-9, "decayed {got} vs brute {want}");
    }

    #[test]
    fn horizon_bounds_live_set() {
        let mut est = DecayedAuc::new(0.99, 1e-3);
        let expected_horizon = est.horizon(); // ln(1e-3)/ln(0.99) ≈ 688
        let mut rng = Pcg::seed(2);
        for _ in 0..10_000 {
            est.insert(rng.uniform(), rng.chance(0.5));
        }
        assert!(est.len() <= expected_horizon + 1, "{} live", est.len());
        assert!(est.len() > expected_horizon / 2, "{} live", est.len());
    }

    #[test]
    fn tracks_regime_change_faster_than_long_window() {
        let mut rng = Pcg::seed(3);
        let mut est = DecayedAuc::new(0.995, 1e-4);
        let mut recent: Vec<(f64, bool)> = Vec::new();
        // Regime A: AUC high.
        for _ in 0..4000 {
            let pos = rng.chance(0.5);
            let s = if pos { rng.normal_with(0.3, 0.1) } else { rng.normal_with(0.7, 0.1) };
            est.insert(s, pos);
        }
        assert!(est.exact_auc() > 0.95);
        // Regime B: labels flip — AUC inverts.
        for _ in 0..1500 {
            let pos = rng.chance(0.5);
            let s = if pos { rng.normal_with(0.7, 0.1) } else { rng.normal_with(0.3, 0.1) };
            est.insert(s, pos);
            recent.push((s, pos));
        }
        let decayed = est.exact_auc();
        let recent_truth = NaiveAuc::of(&recent);
        // After 1500 events (≈1.1 half-lives × 693... γ=0.995 → half-life
        // 138), the decayed estimate must be dominated by regime B.
        assert!(
            (decayed - recent_truth).abs() < 0.1,
            "decayed {decayed} should track recent {recent_truth}"
        );
    }

    #[test]
    fn approx_query_keeps_guarantee() {
        let mut rng = Pcg::seed(4);
        let mut est = DecayedAuc::new(0.999, 1e-4);
        for _ in 0..5000 {
            let pos = rng.chance(0.3);
            let s = if pos { rng.normal_with(0.45, 0.15) } else { rng.normal_with(0.55, 0.15) };
            est.insert(s, pos);
        }
        let exact = est.exact_auc();
        for eps in [0.01, 0.1, 0.5] {
            let approx = est.approx_auc(eps);
            assert!(
                (approx - exact).abs() <= eps * exact / 2.0 + 1e-9,
                "ε={eps}: {approx} vs {exact}"
            );
        }
    }

    #[test]
    fn rescale_is_transparent() {
        // Force many rescales with a tiny overflow threshold? The
        // threshold is fixed; instead use a strong decay so weights grow
        // fast: γ = 0.5 doubles next_weight per event → rescale every
        // ~830 events.
        let mut rng = Pcg::seed(5);
        let mut est = DecayedAuc::new(0.5, 1e-6);
        let mut prev: Option<f64> = None;
        for i in 0..5000 {
            let pos = i % 2 == 0;
            let s = if pos { 0.3 + 0.01 * rng.uniform() } else { 0.7 + 0.01 * rng.uniform() };
            est.insert(s, pos);
            let auc = est.exact_auc();
            if let Some(p) = prev {
                // Perfectly separated stream: AUC stays 1 across every
                // rescale boundary (up to float summation order).
                assert!((auc - p).abs() < 1e-9, "AUC jumped at event {i}: {auc} vs {p}");
            }
            if i > 10 {
                prev = Some(auc);
            }
        }
        assert!((est.exact_auc() - 1.0).abs() < 1e-9);
        // ~20 live events at γ=0.5, tol=1e-6.
        assert!(est.len() < 30);
    }

    #[test]
    #[should_panic(expected = "gamma")]
    fn rejects_bad_gamma() {
        DecayedAuc::new(1.0, 1e-4);
    }
}
