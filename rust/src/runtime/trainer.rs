//! Minibatch SGD training loop over the `train_step` artifact.
//!
//! The entire loop runs in rust: parameters live as host vectors, each
//! step executes the fused AOT `train_step` (forward + Pallas gradient
//! kernel + SGD update in one HLO module) and reads back the updated
//! parameters and the pre-update loss.

use anyhow::{ensure, Context, Result};

use super::executable::{features_literal, labels_literal, Executable};
use super::Runtime;
use crate::stream::synth::Example;

/// Model parameters on the host.
#[derive(Clone, Debug)]
pub struct Params {
    /// Weight vector (length = `meta.dims`).
    pub w: Vec<f32>,
    /// Bias.
    pub b: f32,
}

/// Outcome of a training run.
#[derive(Clone, Debug)]
pub struct TrainReport {
    /// Final parameters.
    pub params: Params,
    /// Loss recorded at every step (pre-update).
    pub losses: Vec<f32>,
    /// Steps executed.
    pub steps: usize,
}

impl TrainReport {
    /// Mean loss over the first `n` steps.
    pub fn early_loss(&self, n: usize) -> f32 {
        mean(&self.losses[..n.min(self.losses.len())])
    }

    /// Mean loss over the final `n` steps.
    pub fn late_loss(&self, n: usize) -> f32 {
        let len = self.losses.len();
        mean(&self.losses[len.saturating_sub(n)..])
    }
}

fn mean(xs: &[f32]) -> f32 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f32>() / xs.len() as f32
}

/// SGD trainer bound to the `train_step` artifact.
pub struct Trainer {
    exec: Executable,
    dims: usize,
    batch: usize,
    lr: f32,
}

impl Trainer {
    /// Load the `train_step` artifact from a runtime.
    pub fn new(rt: &Runtime, lr: f32) -> Result<Trainer> {
        ensure!(lr > 0.0 && lr.is_finite(), "learning rate must be positive");
        let meta = rt.meta();
        let exec = rt.load("train_step").context("load train_step artifact")?;
        Ok(Trainer { exec, dims: meta.dims, batch: meta.train_batch, lr })
    }

    /// Training batch size frozen into the artifact.
    pub fn batch_size(&self) -> usize {
        self.batch
    }

    /// Run `steps` minibatch SGD steps over `data` (cycled in order;
    /// shuffle beforehand if desired). Starts from zero parameters.
    pub fn train(&self, data: &[Example], steps: usize) -> Result<TrainReport> {
        self.train_from(Params { w: vec![0.0; self.dims], b: 0.0 }, data, steps)
    }

    /// Run `steps` SGD steps starting from explicit parameters.
    pub fn train_from(
        &self,
        mut params: Params,
        data: &[Example],
        steps: usize,
    ) -> Result<TrainReport> {
        ensure!(!data.is_empty(), "no training data");
        ensure!(params.w.len() == self.dims, "params width != model dims");
        let mut losses = Vec::with_capacity(steps);
        let mut cursor = 0usize;
        // Reusable row buffers to avoid re-allocating per step.
        let mut rows: Vec<Vec<f32>> = Vec::with_capacity(self.batch);
        let mut labels: Vec<bool> = Vec::with_capacity(self.batch);
        for _ in 0..steps {
            rows.clear();
            labels.clear();
            for _ in 0..self.batch {
                let ex = &data[cursor];
                rows.push(ex.features.clone());
                labels.push(ex.label);
                cursor = (cursor + 1) % data.len();
            }
            let x = features_literal(&rows, self.batch, self.dims)?;
            let y = labels_literal(&labels, self.batch)?;
            let w = xla::Literal::vec1(&params.w);
            let b = xla::Literal::scalar(params.b);
            let lr = xla::Literal::scalar(self.lr);
            let out = self.exec.run_f32(&[w, b, x, y, lr])?;
            ensure!(out.len() == 3, "train_step must return (w, b, loss)");
            params.w = out[0].clone();
            params.b = out[1][0];
            losses.push(out[2][0]);
        }
        Ok(TrainReport { params, losses, steps })
    }
}

impl std::fmt::Debug for Trainer {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Trainer")
            .field("dims", &self.dims)
            .field("batch", &self.batch)
            .field("lr", &self.lr)
            .finish_non_exhaustive()
    }
}
