//! Weighted linked list with gap counters (paper §3.1).
//!
//! A weighted linked list `L` maintains a subset `U` of tree nodes sorted
//! by score, with two gap counters per element: for `u ∈ L` with successor
//! `v`, `gp(u; L)` / `gn(u; L)` are the total positive / negative label
//! counts over the half-open score range `[s(u), s(v))` — i.e. `u` itself
//! plus every tree node strictly between `u` and `v`.
//!
//! The two paper-critical operations are `O(1)`:
//! * [`ListCore::remove`] — delete an element, folding its gap into
//!   the predecessor (`Remove(L, v)`);
//! * [`ListCore::insert_after`] — insert `v` after `u` given the label
//!   sums over `[s(u), s(v))` (`Add(L, u, v, p, n)`).
//!
//! Cells live in a [`CellArena`] — an [`Arena`] slab plus a dense
//! `tree-node → cell` map giving the `O(1)` membership test `w ∉ L`
//! needed by `AddNext` (Algorithm 5). Like the rbtree, the list comes
//! in two forms: the storage-free [`ListCore`] (head/tail/len, arena
//! passed into every call — many per-stream lists share one
//! shard-owned arena) and the self-contained [`WeightedList`] bundling
//! core and arena for standalone use (`rust/DESIGN.md` §Memory).
//!
//! A shared [`CellArena`] serves one *role* (the fleet keeps one for
//! every stream's `P` list and another for every `C` list): the
//! `by_node` map is keyed by tree-node slot, and a tree node belongs to
//! exactly one stream, so per-role sharing keeps the map collision-free.

use super::arena::Arena;
use super::rbtree::NodeId;

/// Handle to a list cell.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CellId(u32);

const NIL: u32 = u32::MAX;

#[derive(Clone, Debug)]
pub(crate) struct Cell {
    node: NodeId,
    next: u32,
    prev: u32,
    gp: u64,
    gn: u64,
    /// Cached copy of the tree node's score. Scores are immutable for a
    /// node's lifetime, so this never goes stale; it keeps the hot
    /// `c_floor` scan free of tree dereferences (see §Perf).
    key: f64,
    /// Cached copies of the node's own label counters `p(v)` / `n(v)`,
    /// maintained by the list owner alongside the tree counters (the
    /// invariant checkers in coordinator verify cache coherence).
    p: u64,
    n: u64,
}

/// Cell storage for weighted lists: slab plus the dense
/// `tree-node slot → cell` membership map.
#[derive(Clone, Debug, Default)]
pub(crate) struct CellArena {
    pub(crate) cells: Arena<Cell>,
    /// Dense map: tree-node slot → cell id (NIL when absent).
    by_node: Vec<u32>,
}

impl CellArena {
    fn alloc(&mut self, cell: Cell) -> u32 {
        self.cells.alloc(cell)
    }

    fn map(&mut self, node: NodeId, cell: u32) {
        let i = node.0 as usize;
        if i >= self.by_node.len() {
            self.by_node.resize(i + 1, NIL);
        }
        debug_assert_eq!(self.by_node[i], NIL, "node already mapped");
        self.by_node[i] = cell;
    }

    fn unmap(&mut self, node: NodeId) {
        self.by_node[node.0 as usize] = NIL;
    }

    /// Drop all storage (callers must have removed every cell — see
    /// [`Arena::reset`]).
    pub(crate) fn reset(&mut self) {
        self.cells.reset();
        debug_assert!(self.by_node.iter().all(|&c| c == NIL), "reset with mapped cells");
        self.by_node = Vec::new();
    }

    /// Release retained capacity without disturbing live cells: freed
    /// tail slots truncate away, and the membership map drops its
    /// trailing unmapped region.
    pub(crate) fn shrink_to_fit(&mut self) {
        self.cells.shrink_to_fit();
        let mut keep = self.by_node.len();
        while keep > 0 && self.by_node[keep - 1] == NIL {
            keep -= 1;
        }
        self.by_node.truncate(keep);
        self.by_node.shrink_to_fit();
    }

    /// Logical bytes of live cells plus the mapped region of `by_node`
    /// (logical, not capacity — see [`Arena::live_bytes`]).
    pub(crate) fn live_bytes(&self) -> usize {
        self.cells.live_bytes()
    }
}

/// Storage-free weighted linked list: head/tail indices and a length,
/// with the backing [`CellArena`] passed into every operation. The
/// same-arena rule of [`super::rbtree::RbTreeCore`] applies.
#[derive(Clone, Copy, Debug)]
pub(crate) struct ListCore {
    head: u32,
    tail: u32,
    len: usize,
}

impl Default for ListCore {
    fn default() -> Self {
        Self::new()
    }
}

impl ListCore {
    /// Empty list (no sentinels yet).
    pub(crate) fn new() -> Self {
        ListCore { head: NIL, tail: NIL, len: 0 }
    }

    /// Number of elements, including any sentinel cells the coordinator
    /// pushed.
    #[inline]
    pub(crate) fn len(&self) -> usize {
        self.len
    }

    /// True when no cells are present.
    #[inline]
    pub(crate) fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// First cell.
    #[inline]
    pub(crate) fn head(&self) -> Option<CellId> {
        wrap(self.head)
    }

    /// Last cell.
    #[inline]
    pub(crate) fn tail(&self) -> Option<CellId> {
        wrap(self.tail)
    }

    /// `next(u; L)`.
    #[inline]
    pub(crate) fn next(&self, ar: &CellArena, c: CellId) -> Option<CellId> {
        wrap(ar.cells.slots[c.0 as usize].next)
    }

    /// `prev(u; L)`.
    #[inline]
    pub(crate) fn prev(&self, ar: &CellArena, c: CellId) -> Option<CellId> {
        wrap(ar.cells.slots[c.0 as usize].prev)
    }

    /// Tree node this cell references.
    #[inline]
    pub(crate) fn node(&self, ar: &CellArena, c: CellId) -> NodeId {
        ar.cells.slots[c.0 as usize].node
    }

    /// Gap positive count `gp(u; L)`.
    #[inline]
    pub(crate) fn gp(&self, ar: &CellArena, c: CellId) -> u64 {
        ar.cells.slots[c.0 as usize].gp
    }

    /// Gap negative count `gn(u; L)`.
    #[inline]
    pub(crate) fn gn(&self, ar: &CellArena, c: CellId) -> u64 {
        ar.cells.slots[c.0 as usize].gn
    }

    /// Add `delta` to `gp(u; L)` (counter maintenance on label arrival /
    /// departure).
    #[inline]
    pub(crate) fn add_gp(&self, ar: &mut CellArena, c: CellId, delta: i64) {
        let g = &mut ar.cells.slots[c.0 as usize].gp;
        *g = g.checked_add_signed(delta).expect("gp underflow");
    }

    /// Add `delta` to `gn(u; L)`.
    #[inline]
    pub(crate) fn add_gn(&self, ar: &mut CellArena, c: CellId, delta: i64) {
        let g = &mut ar.cells.slots[c.0 as usize].gn;
        *g = g.checked_add_signed(delta).expect("gn underflow");
    }

    /// Cell holding `node`, if `node ∈ L`.
    #[inline]
    pub(crate) fn cell_of(&self, ar: &CellArena, node: NodeId) -> Option<CellId> {
        let i = node.0 as usize;
        if i < ar.by_node.len() {
            wrap(ar.by_node[i])
        } else {
            None
        }
    }

    /// `O(1)` membership test.
    #[inline]
    pub(crate) fn contains(&self, ar: &CellArena, node: NodeId) -> bool {
        self.cell_of(ar, node).is_some()
    }

    /// Cached score of the cell's node.
    #[inline]
    pub(crate) fn key(&self, ar: &CellArena, c: CellId) -> f64 {
        ar.cells.slots[c.0 as usize].key
    }

    /// Cached `p(v)` of the cell's node.
    #[inline]
    pub(crate) fn cp(&self, ar: &CellArena, c: CellId) -> u64 {
        ar.cells.slots[c.0 as usize].p
    }

    /// Cached `n(v)` of the cell's node.
    #[inline]
    pub(crate) fn cn(&self, ar: &CellArena, c: CellId) -> u64 {
        ar.cells.slots[c.0 as usize].n
    }

    /// Adjust the cached `p(v)` (call alongside the tree counter).
    #[inline]
    pub(crate) fn add_cp(&self, ar: &mut CellArena, c: CellId, delta: i64) {
        let p = &mut ar.cells.slots[c.0 as usize].p;
        *p = p.checked_add_signed(delta).expect("cached p underflow");
    }

    /// Adjust the cached `n(v)` (call alongside the tree counter).
    #[inline]
    pub(crate) fn add_cn(&self, ar: &mut CellArena, c: CellId, delta: i64) {
        let n = &mut ar.cells.slots[c.0 as usize].n;
        *n = n.checked_add_signed(delta).expect("cached n underflow");
    }

    /// Append a cell at the back with explicit gap counters. Used only to
    /// seed the sentinel cells; ordinary insertion goes through
    /// [`ListCore::insert_after`].
    pub(crate) fn push_back(
        &mut self,
        ar: &mut CellArena,
        node: NodeId,
        key: f64,
        gp: u64,
        gn: u64,
    ) -> CellId {
        let id = ar.alloc(Cell { node, next: NIL, prev: self.tail, gp, gn, key, p: 0, n: 0 });
        if self.tail != NIL {
            ar.cells.slots[self.tail as usize].next = id;
        } else {
            self.head = id;
        }
        self.tail = id;
        ar.map(node, id);
        self.len += 1;
        CellId(id)
    }

    /// `Add(L, u, v, p, n)` — insert `v` immediately after `u`, where `p`
    /// and `n` are the label sums over `[s(u), s(v))` *at the time of the
    /// call*. Splits `u`'s gap: `gp(u)′ = p`, `gp(v)′ = gp(u) − p` (same
    /// for `gn`). `key`/`vp`/`vn` seed the new cell's caches. `O(1)`.
    #[allow(clippy::too_many_arguments)] // mirrors the paper's Add(L, u, v, p, n) plus caches
    pub(crate) fn insert_after(
        &mut self,
        ar: &mut CellArena,
        u: CellId,
        v: NodeId,
        key: f64,
        vp: u64,
        vn: u64,
        p: u64,
        n: u64,
    ) -> CellId {
        debug_assert!(!self.contains(ar, v), "insert_after of node already in list");
        let (u_next, u_gp, u_gn) = {
            let cu = &ar.cells.slots[u.0 as usize];
            (cu.next, cu.gp, cu.gn)
        };
        debug_assert!(u_gp >= p, "gap split underflow (gp={u_gp}, p={p})");
        debug_assert!(u_gn >= n, "gap split underflow (gn={u_gn}, n={n})");
        let id = ar.alloc(Cell {
            node: v,
            next: u_next,
            prev: u.0,
            gp: u_gp - p,
            gn: u_gn - n,
            key,
            p: vp,
            n: vn,
        });
        {
            let cu = &mut ar.cells.slots[u.0 as usize];
            cu.next = id;
            cu.gp = p;
            cu.gn = n;
        }
        if u_next != NIL {
            ar.cells.slots[u_next as usize].prev = id;
        } else {
            self.tail = id;
        }
        ar.map(v, id);
        self.len += 1;
        CellId(id)
    }

    /// `Remove(L, v)` — delete a cell, folding its gap counters into the
    /// predecessor so coverage is preserved. `O(1)`. The head cell (the
    /// `−∞` sentinel, which has no predecessor to absorb its gap) must not
    /// be removed.
    pub(crate) fn remove(&mut self, ar: &mut CellArena, c: CellId) {
        let Cell { node, next, prev, gp, gn, .. } = ar.cells.slots[c.0 as usize].clone();
        assert_ne!(prev, NIL, "cannot remove the head cell of a weighted list");
        {
            let cp = &mut ar.cells.slots[prev as usize];
            cp.next = next;
            cp.gp += gp;
            cp.gn += gn;
        }
        if next != NIL {
            ar.cells.slots[next as usize].prev = prev;
        } else {
            self.tail = prev;
        }
        ar.unmap(node);
        ar.cells.release(c.0);
        self.len -= 1;
    }

    /// Iterate cells front to back.
    pub(crate) fn iter_in<'a>(&self, ar: &'a CellArena) -> Cells<'a> {
        Cells { ar, cur: self.head }
    }

    /// Snapshot of one cell's hot fields (scan-friendly: one slab lookup
    /// per cell instead of one per accessor; see §Perf).
    #[inline]
    pub(crate) fn view(&self, ar: &CellArena, c: CellId) -> CellView {
        let cell = &ar.cells.slots[c.0 as usize];
        CellView { key: cell.key, p: cell.p, n: cell.n, gp: cell.gp, gn: cell.gn }
    }

    /// Iterate cell snapshots front to back (the `ApproxAUC` read path).
    pub(crate) fn views_in<'a>(&self, ar: &'a CellArena) -> Views<'a> {
        Views { ar, cur: self.head }
    }

    /// Largest cell with cached `key ≤ s`, plus the prefix `gp` *and*
    /// `gn` sums of the cells before it (the `c_floor` hot scan).
    /// Assumes the head cell's key is `−∞`. The `gn` prefix rides the
    /// same hops for free; it is what lets the estimator's incremental
    /// doubled-area accumulator compute its suffix-negative term in
    /// `O(1)` instead of an extra tree query (approx.rs, DESIGN.md
    /// §Incremental-reads).
    pub(crate) fn floor_scan(&self, ar: &CellArena, s: f64) -> (CellId, u64, u64) {
        let mut cur = self.head;
        let mut hp = 0u64;
        let mut hn = 0u64;
        loop {
            let cell = &ar.cells.slots[cur as usize];
            let next = cell.next;
            if next == NIL || ar.cells.slots[next as usize].key > s {
                return (CellId(cur), hp, hn);
            }
            hp += cell.gp;
            hn += cell.gn;
            cur = next;
        }
    }

    /// Release every cell (sentinels included) back to the arena in one
    /// `O(len)` pass, unmapping each node. The bulk-free hook for
    /// dropping a pooled stream (freeze / evict); afterwards the core
    /// is empty.
    pub(crate) fn drain(&mut self, ar: &mut CellArena) {
        let mut cur = self.head;
        while cur != NIL {
            let (node, next) = {
                let cell = &ar.cells.slots[cur as usize];
                (cell.node, cell.next)
            };
            ar.unmap(node);
            ar.cells.release(cur);
            cur = next;
        }
        self.head = NIL;
        self.tail = NIL;
        self.len = 0;
    }

    /// Total `gp` over all cells (= positive labels covered; test helper).
    pub(crate) fn total_gp(&self, ar: &CellArena) -> u64 {
        self.iter_in(ar).map(|c| self.gp(ar, c)).sum()
    }

    /// Total `gn` over all cells.
    pub(crate) fn total_gn(&self, ar: &CellArena) -> u64 {
        self.iter_in(ar).map(|c| self.gn(ar, c)).sum()
    }
}

/// Weighted linked list bundling its own cell arena — the
/// self-contained form for standalone estimators and tests. Delegates
/// to a [`ListCore`] over a private [`CellArena`]; the fleet uses cores
/// against shard-owned arenas.
#[derive(Clone, Debug, Default)]
pub struct WeightedList {
    ar: CellArena,
    core: ListCore,
}

impl WeightedList {
    /// Empty list (no sentinels yet).
    pub fn new() -> Self {
        WeightedList::default()
    }

    /// Number of elements, including any sentinel cells the coordinator
    /// pushed.
    #[inline]
    pub fn len(&self) -> usize {
        self.core.len()
    }

    /// True when no cells are present.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.core.is_empty()
    }

    /// First cell.
    #[inline]
    pub fn head(&self) -> Option<CellId> {
        self.core.head()
    }

    /// Last cell.
    #[inline]
    pub fn tail(&self) -> Option<CellId> {
        self.core.tail()
    }

    /// `next(u; L)`.
    #[inline]
    pub fn next(&self, c: CellId) -> Option<CellId> {
        self.core.next(&self.ar, c)
    }

    /// `prev(u; L)`.
    #[inline]
    pub fn prev(&self, c: CellId) -> Option<CellId> {
        self.core.prev(&self.ar, c)
    }

    /// Tree node this cell references.
    #[inline]
    pub fn node(&self, c: CellId) -> NodeId {
        self.core.node(&self.ar, c)
    }

    /// Gap positive count `gp(u; L)`.
    #[inline]
    pub fn gp(&self, c: CellId) -> u64 {
        self.core.gp(&self.ar, c)
    }

    /// Gap negative count `gn(u; L)`.
    #[inline]
    pub fn gn(&self, c: CellId) -> u64 {
        self.core.gn(&self.ar, c)
    }

    /// Add `delta` to `gp(u; L)`.
    #[inline]
    pub fn add_gp(&mut self, c: CellId, delta: i64) {
        self.core.add_gp(&mut self.ar, c, delta);
    }

    /// Add `delta` to `gn(u; L)`.
    #[inline]
    pub fn add_gn(&mut self, c: CellId, delta: i64) {
        self.core.add_gn(&mut self.ar, c, delta);
    }

    /// Cell holding `node`, if `node ∈ L`.
    #[inline]
    pub fn cell_of(&self, node: NodeId) -> Option<CellId> {
        self.core.cell_of(&self.ar, node)
    }

    /// `O(1)` membership test.
    #[inline]
    pub fn contains(&self, node: NodeId) -> bool {
        self.core.contains(&self.ar, node)
    }

    /// Cached score of the cell's node.
    #[inline]
    pub fn key(&self, c: CellId) -> f64 {
        self.core.key(&self.ar, c)
    }

    /// Cached `p(v)` of the cell's node.
    #[inline]
    pub fn cp(&self, c: CellId) -> u64 {
        self.core.cp(&self.ar, c)
    }

    /// Cached `n(v)` of the cell's node.
    #[inline]
    pub fn cn(&self, c: CellId) -> u64 {
        self.core.cn(&self.ar, c)
    }

    /// Adjust the cached `p(v)` (call alongside the tree counter).
    #[inline]
    pub fn add_cp(&mut self, c: CellId, delta: i64) {
        self.core.add_cp(&mut self.ar, c, delta);
    }

    /// Adjust the cached `n(v)` (call alongside the tree counter).
    #[inline]
    pub fn add_cn(&mut self, c: CellId, delta: i64) {
        self.core.add_cn(&mut self.ar, c, delta);
    }

    /// Append a cell at the back with explicit gap counters (sentinel
    /// seeding; ordinary insertion goes through
    /// [`WeightedList::insert_after`]).
    pub fn push_back(&mut self, node: NodeId, key: f64, gp: u64, gn: u64) -> CellId {
        self.core.push_back(&mut self.ar, node, key, gp, gn)
    }

    /// `Add(L, u, v, p, n)` — insert `v` immediately after `u`; see
    /// [`ListCore::insert_after`].
    #[allow(clippy::too_many_arguments)] // mirrors the paper's Add(L, u, v, p, n) plus caches
    pub fn insert_after(
        &mut self,
        u: CellId,
        v: NodeId,
        key: f64,
        vp: u64,
        vn: u64,
        p: u64,
        n: u64,
    ) -> CellId {
        self.core.insert_after(&mut self.ar, u, v, key, vp, vn, p, n)
    }

    /// `Remove(L, v)` — delete a cell, folding its gap counters into the
    /// predecessor. The head cell must not be removed.
    pub fn remove(&mut self, c: CellId) {
        self.core.remove(&mut self.ar, c);
    }

    /// Iterate cells front to back.
    pub fn iter(&self) -> Cells<'_> {
        self.core.iter_in(&self.ar)
    }

    /// Snapshot of one cell's hot fields.
    #[inline]
    pub fn view(&self, c: CellId) -> CellView {
        self.core.view(&self.ar, c)
    }

    /// Iterate cell snapshots front to back (the `ApproxAUC` read path).
    pub fn views(&self) -> Views<'_> {
        self.core.views_in(&self.ar)
    }

    /// Largest cell with cached `key ≤ s`, plus the prefix `gp` and `gn`
    /// sums of the cells before it (the `c_floor` hot scan).
    pub fn floor_scan(&self, s: f64) -> (CellId, u64, u64) {
        self.core.floor_scan(&self.ar, s)
    }

    /// Total `gp` over all cells (= positive labels covered; test helper).
    pub fn total_gp(&self) -> u64 {
        self.core.total_gp(&self.ar)
    }

    /// Total `gn` over all cells.
    pub fn total_gn(&self) -> u64 {
        self.core.total_gn(&self.ar)
    }

    /// Release retained slab capacity (freed tail slots, membership-map
    /// tail, vector slack) without disturbing live cells — the
    /// churn-shrink hook for standalone lists.
    pub fn shrink_to_fit(&mut self) {
        self.ar.shrink_to_fit();
    }

    /// Slots the backing arena currently retains (live + freed) — the
    /// measure the capacity-regression tests bound after churn.
    pub fn capacity(&self) -> usize {
        self.ar.cells.slot_count()
    }
}

#[inline]
fn wrap(i: u32) -> Option<CellId> {
    if i == NIL {
        None
    } else {
        Some(CellId(i))
    }
}

/// Copy of a cell's hot fields for scan loops.
#[derive(Clone, Copy, Debug)]
pub struct CellView {
    /// Cached node score.
    pub key: f64,
    /// Cached `p(v)`.
    pub p: u64,
    /// Cached `n(v)`.
    pub n: u64,
    /// Gap positive count.
    pub gp: u64,
    /// Gap negative count.
    pub gn: u64,
}

/// Front-to-back snapshot iterator.
pub struct Views<'a> {
    ar: &'a CellArena,
    cur: u32,
}

impl Iterator for Views<'_> {
    type Item = CellView;

    #[inline]
    fn next(&mut self) -> Option<CellView> {
        if self.cur == NIL {
            return None;
        }
        let cell = &self.ar.cells.slots[self.cur as usize];
        self.cur = cell.next;
        Some(CellView { key: cell.key, p: cell.p, n: cell.n, gp: cell.gp, gn: cell.gn })
    }
}

/// Front-to-back cell iterator.
pub struct Cells<'a> {
    ar: &'a CellArena,
    cur: u32,
}

impl Iterator for Cells<'_> {
    type Item = CellId;

    fn next(&mut self) -> Option<CellId> {
        if self.cur == NIL {
            return None;
        }
        let c = CellId(self.cur);
        self.cur = self.ar.cells.slots[self.cur as usize].next;
        Some(c)
    }
}

// Cells live in a plain `Vec` slab addressed by index — no `Rc`, no
// interior mutability — so the list moves freely across the fleet's
// pool worker threads. Enforced at compile time.
const _: () = {
    const fn assert_send<T: Send>() {}
    assert_send::<WeightedList>();
    assert_send::<CellArena>();
};

#[cfg(test)]
mod tests {
    use super::*;

    fn nid(i: u32) -> NodeId {
        NodeId(i)
    }

    /// Builds [sentinel, tail-sentinel] with the head gap holding (gp, gn).
    fn seeded(gp: u64, gn: u64) -> (WeightedList, CellId, CellId) {
        let mut l = WeightedList::new();
        let h = l.push_back(nid(1000), f64::NEG_INFINITY, gp, gn);
        let t = l.push_back(nid(1001), f64::INFINITY, 0, 0);
        (l, h, t)
    }

    #[test]
    fn sentinels_only() {
        let (l, h, t) = seeded(5, 7);
        assert_eq!(l.len(), 2);
        assert_eq!(l.head(), Some(h));
        assert_eq!(l.tail(), Some(t));
        assert_eq!(l.next(h), Some(t));
        assert_eq!(l.prev(t), Some(h));
        assert_eq!(l.next(t), None);
        assert_eq!(l.prev(h), None);
        assert_eq!((l.total_gp(), l.total_gn()), (5, 7));
    }

    #[test]
    fn insert_splits_gap() {
        let (mut l, h, t) = seeded(10, 20);
        // 4 positives and 6 negatives lie in [head, v)
        let v = l.insert_after(h, nid(5), 5.0, 1, 0, 4, 6);
        assert_eq!(l.gp(h), 4);
        assert_eq!(l.gn(h), 6);
        assert_eq!(l.gp(v), 6);
        assert_eq!(l.gn(v), 14);
        assert_eq!(l.next(h), Some(v));
        assert_eq!(l.next(v), Some(t));
        assert_eq!(l.prev(t), Some(v));
        assert_eq!((l.total_gp(), l.total_gn()), (10, 20));
        assert!(l.contains(nid(5)));
        assert_eq!(l.cell_of(nid(5)), Some(v));
    }

    #[test]
    fn remove_folds_gap_into_prev() {
        let (mut l, h, _t) = seeded(10, 20);
        let v = l.insert_after(h, nid(5), 5.0, 1, 0, 4, 6);
        l.remove(v);
        assert_eq!(l.gp(h), 10);
        assert_eq!(l.gn(h), 20);
        assert_eq!(l.len(), 2);
        assert!(!l.contains(nid(5)));
    }

    #[test]
    fn remove_middle_of_three() {
        let (mut l, h, t) = seeded(12, 0);
        let a = l.insert_after(h, nid(2), 2.0, 1, 0, 3, 0);
        let b = l.insert_after(a, nid(3), 3.0, 1, 0, 4, 0);
        // gaps now: h=3, a=4, b=5
        assert_eq!(l.gp(b), 5);
        l.remove(a);
        assert_eq!(l.gp(h), 7); // 3 + 4
        assert_eq!(l.next(h), Some(b));
        assert_eq!(l.prev(b), Some(h));
        assert_eq!(l.next(b), Some(t));
        assert_eq!(l.total_gp(), 12);
    }

    #[test]
    fn counter_deltas() {
        let (mut l, h, _t) = seeded(1, 1);
        l.add_gp(h, 3);
        l.add_gn(h, -1);
        assert_eq!(l.gp(h), 4);
        assert_eq!(l.gn(h), 0);
    }

    #[test]
    #[should_panic(expected = "underflow")]
    fn gn_underflow_panics() {
        let (mut l, h, _t) = seeded(0, 0);
        l.add_gn(h, -1);
    }

    #[test]
    #[should_panic(expected = "head cell")]
    fn removing_head_panics() {
        let (mut l, h, _t) = seeded(0, 0);
        l.remove(h);
    }

    #[test]
    fn slot_reuse_keeps_mapping_clean() {
        let (mut l, h, _t) = seeded(6, 0);
        let a = l.insert_after(h, nid(2), 2.0, 1, 0, 3, 0);
        l.remove(a);
        assert!(!l.contains(nid(2)));
        let b = l.insert_after(h, nid(4), 4.0, 1, 0, 2, 0);
        assert!(l.contains(nid(4)));
        assert!(!l.contains(nid(2)));
        assert_eq!(l.node(b), nid(4));
        assert_eq!(l.len(), 3);
    }

    #[test]
    fn floor_scan_accumulates_both_prefixes() {
        let (mut l, h, _t) = seeded(10, 20);
        let a = l.insert_after(h, nid(2), 2.0, 1, 0, 4, 6);
        let b = l.insert_after(a, nid(5), 5.0, 1, 0, 3, 5);
        // gaps now: h = (4, 6), a = (3, 5), b = (3, 9).
        assert_eq!(l.floor_scan(1.0), (h, 0, 0));
        assert_eq!(l.floor_scan(2.0), (a, 4, 6));
        assert_eq!(l.floor_scan(4.9), (a, 4, 6));
        assert_eq!(l.floor_scan(99.0), (b, 7, 11));
    }

    #[test]
    fn iteration_order() {
        let (mut l, h, _t) = seeded(10, 0);
        let a = l.insert_after(h, nid(2), 2.0, 1, 0, 2, 0);
        let b = l.insert_after(a, nid(3), 3.0, 1, 0, 3, 0);
        let nodes: Vec<u32> = l.iter().map(|c| l.node(c).0).collect();
        assert_eq!(nodes, vec![1000, 2, 3, 1001]);
        let _ = b;
    }

    #[test]
    fn shrink_releases_churn_capacity() {
        let (mut l, h, _t) = seeded(1000, 0);
        // Grow a long list, then remove everything but the sentinels.
        let mut cells = Vec::new();
        let mut prev = h;
        for i in 0..200u32 {
            let gap = 999 - u64::from(i);
            prev = l.insert_after(prev, nid(i), f64::from(i), 1, 0, gap.min(l.gp(prev)), 0);
            cells.push(prev);
        }
        for c in cells {
            l.remove(c);
        }
        assert!(l.capacity() >= 200);
        l.shrink_to_fit();
        assert!(l.capacity() <= 2, "churned-out list must release its slab");
        assert_eq!(l.len(), 2);
        assert_eq!((l.total_gp(), l.total_gn()), (1000, 0));
    }
}
