//! Error and latency accounting for the experiment drivers, plus the
//! exact H-measure read shared by the maintained-exact estimator.
//!
//! The paper's evaluation (§6) reports the *relative* approximation error
//! `|ãuc − auc| / auc` averaged and maximised over all sliding windows,
//! plus per-update running time. These accumulators are shared by the
//! Figure 1–3 drivers and the examples. [`h_measure`] implements the
//! coherent alternative to AUC from Hand (2009) that Tatti's follow-up
//! paper (arXiv 2112.06160) maintains over time next to the exact AUC;
//! `MaintainedExactAuc::h_measure` feeds it the window's score groups.

use std::time::Duration;

/// Exact H-measure (Hand 2009) under the Beta(2,2) cost prior
/// `u(c) = 6c(1 − c)`, from score groups in ascending order.
///
/// `groups` yields `(positives, negatives)` per distinct score. The
/// crate's AUC convention has positives scoring *low* (AUC 1 means
/// every positive is below every negative), so the implied classifier
/// predicts positive at scores `≤` a threshold; sweeping the threshold
/// over the groups traces ROC points `(FPR, TPR)` from `(0, 0)` to
/// `(1, 1)`.
///
/// The expected minimum misclassification loss at cost `c ∈ (0, 1)`
/// (cost `c` for a missed positive, `1 − c` for a false positive, class
/// priors `π1 = P/(P+N)`, `π0 = N/(P+N)`) is attained on the upper
/// convex hull of the ROC points; vertex `(x, y)` is optimal for `c`
/// between the breakpoints of its adjacent hull segments,
/// `c* = π0·Δx / (π1·Δy + π0·Δx)`. Integrating the per-vertex loss
/// `c·π1·(1 − y) + (1 − c)·π0·x` against `u(c)` over each vertex's
/// interval gives `L`; normalising by the trivial classifier's loss
/// `L_max` (assign everything to the better class per `c`) gives
/// `H = 1 − L / L_max ∈ [0, 1]`.
///
/// Hull decisions are made on the *integer* cumulative counts with
/// `i128` cross-products, so the vertex set — and therefore the result
/// — is deterministic, independent of score magnitudes. Returns 0 when
/// either class is empty (no separation is measurable).
pub fn h_measure(groups: impl IntoIterator<Item = (u64, u64)>) -> f64 {
    // Cumulative integer ROC points (cum_neg, cum_pos), origin included.
    let mut pts: Vec<(u64, u64)> = vec![(0, 0)];
    let (mut cp, mut cn) = (0u64, 0u64);
    for (p, n) in groups {
        cp += p;
        cn += n;
        pts.push((cn, cp));
    }
    let (total_neg, total_pos) = (cn, cp);
    if total_pos == 0 || total_neg == 0 {
        return 0.0;
    }
    // Upper convex hull (slopes non-increasing): convexity is invariant
    // under the per-axis 1/N, 1/P normalisation, so the hull of the
    // integer points is the hull of the ROC points. Collinear middle
    // vertices are dropped (they only split an interval in two without
    // changing the envelope).
    let mut hull: Vec<(u64, u64)> = Vec::with_capacity(pts.len());
    for pt in pts {
        while hull.len() >= 2 {
            let o = hull[hull.len() - 2];
            let a = hull[hull.len() - 1];
            let cross = (a.0 as i128 - o.0 as i128) * (pt.1 as i128 - o.1 as i128)
                - (a.1 as i128 - o.1 as i128) * (pt.0 as i128 - o.0 as i128);
            if cross >= 0 {
                hull.pop(); // `a` is on or below the chord o→pt
            } else {
                break;
            }
        }
        hull.push(pt);
    }

    let total = (total_pos + total_neg) as f64;
    let pi1 = total_pos as f64 / total;
    let pi0 = total_neg as f64 / total;
    // ∫ c·u(c) dc and ∫ (1−c)·u(c) dc for u(c) = 6c(1−c).
    let int1 = |c: f64| 2.0 * c.powi(3) - 1.5 * c.powi(4);
    let int0 = |c: f64| 3.0 * c.powi(2) - 4.0 * c.powi(3) + 1.5 * c.powi(4);

    // Vertex i is optimal on [c_{i-1}, c_i]; the breakpoint between
    // consecutive hull vertices solves c·π1·Δy = (1−c)·π0·Δx.
    let mut loss = 0.0;
    let mut c_lo = 0.0;
    for (i, &(xn, yp)) in hull.iter().enumerate() {
        let c_hi = if i + 1 < hull.len() {
            let (nx, ny) = hull[i + 1];
            let dx = pi0 * (nx - xn) as f64 / total_neg as f64;
            let dy = pi1 * (ny - yp) as f64 / total_pos as f64;
            dx / (dy + dx)
        } else {
            1.0
        };
        let x = xn as f64 / total_neg as f64;
        let y = yp as f64 / total_pos as f64;
        loss += pi1 * (1.0 - y) * (int1(c_hi) - int1(c_lo))
            + pi0 * x * (int0(c_hi) - int0(c_lo));
        c_lo = c_hi;
    }
    // Trivial classifier: all-positive costs (1−c)·π0, all-negative
    // costs c·π1; the better of the two switches at c = π0.
    let loss_max = pi1 * int1(pi0) + pi0 * (int0(1.0) - int0(pi0));
    (1.0 - loss / loss_max).clamp(0.0, 1.0)
}

/// Streaming summary of a scalar series: count / mean / max / min.
#[derive(Clone, Copy, Debug, Default)]
pub struct Summary {
    count: u64,
    sum: f64,
    max: f64,
    min: f64,
}

impl Summary {
    /// Empty summary.
    pub fn new() -> Self {
        Summary { count: 0, sum: 0.0, max: f64::NEG_INFINITY, min: f64::INFINITY }
    }

    /// Fold one observation.
    pub fn push(&mut self, x: f64) {
        self.count += 1;
        self.sum += x;
        self.max = self.max.max(x);
        self.min = self.min.min(x);
    }

    /// Number of observations.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Arithmetic mean (0 when empty).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    /// Maximum (0 when empty).
    pub fn max(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.max
        }
    }

    /// Minimum (0 when empty).
    pub fn min(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.min
        }
    }
}

/// Relative-error tracker: feeds Figure 1 (average and maximum relative
/// error over all sliding windows).
#[derive(Clone, Copy, Debug, Default)]
pub struct RelErr {
    summary: Summary,
    skipped: u64,
}

impl RelErr {
    /// Empty tracker.
    pub fn new() -> Self {
        RelErr { summary: Summary::new(), skipped: 0 }
    }

    /// Record one window: the estimate against the exact value. Windows
    /// with `auc = 0` are skipped (relative error undefined), counted in
    /// [`RelErr::skipped`].
    pub fn record(&mut self, estimate: f64, exact: f64) {
        if exact == 0.0 {
            self.skipped += 1;
            return;
        }
        self.summary.push((estimate - exact).abs() / exact);
    }

    /// Average relative error over recorded windows.
    pub fn avg(&self) -> f64 {
        self.summary.mean()
    }

    /// Maximum relative error over recorded windows.
    pub fn max(&self) -> f64 {
        self.summary.max()
    }

    /// Number of recorded windows.
    pub fn count(&self) -> u64 {
        self.summary.count()
    }

    /// Windows skipped because the exact AUC was zero.
    pub fn skipped(&self) -> u64 {
        self.skipped
    }
}

/// Latency tracker with mean and high percentiles, for per-update cost.
///
/// Keeps raw nanosecond samples (the experiment streams are bounded, and
/// exact percentiles beat a histogram's bucketing error at this scale).
#[derive(Clone, Debug, Default)]
pub struct Latency {
    nanos: Vec<u64>,
}

impl Latency {
    /// Empty tracker.
    pub fn new() -> Self {
        Self::default()
    }

    /// Pre-sized tracker.
    pub fn with_capacity(n: usize) -> Self {
        Latency { nanos: Vec::with_capacity(n) }
    }

    /// Record one duration.
    pub fn push(&mut self, d: Duration) {
        self.nanos.push(d.as_nanos() as u64);
    }

    /// Number of samples.
    pub fn count(&self) -> usize {
        self.nanos.len()
    }

    /// Total recorded time.
    pub fn total(&self) -> Duration {
        Duration::from_nanos(self.nanos.iter().sum())
    }

    /// Mean per-sample time.
    pub fn mean(&self) -> Duration {
        if self.nanos.is_empty() {
            return Duration::ZERO;
        }
        Duration::from_nanos(self.nanos.iter().sum::<u64>() / self.nanos.len() as u64)
    }

    /// Exact percentile (`q ∈ [0, 1]`) by nearest-rank.
    pub fn percentile(&self, q: f64) -> Duration {
        if self.nanos.is_empty() {
            return Duration::ZERO;
        }
        let mut sorted = self.nanos.clone();
        sorted.sort_unstable();
        let rank = ((q * sorted.len() as f64).ceil() as usize).clamp(1, sorted.len());
        Duration::from_nanos(sorted[rank - 1])
    }

    /// Median.
    pub fn median(&self) -> Duration {
        self.percentile(0.5)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_moments() {
        let mut s = Summary::new();
        for x in [1.0, 2.0, 3.0, 10.0] {
            s.push(x);
        }
        assert_eq!(s.count(), 4);
        assert_eq!(s.mean(), 4.0);
        assert_eq!(s.max(), 10.0);
        assert_eq!(s.min(), 1.0);
    }

    #[test]
    fn empty_summary_is_zero() {
        let s = Summary::new();
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.max(), 0.0);
        assert_eq!(s.min(), 0.0);
    }

    #[test]
    fn rel_err_tracks_avg_and_max() {
        let mut r = RelErr::new();
        r.record(0.99, 1.0); // 1%
        r.record(0.90, 1.0); // 10%
        r.record(0.5, 0.0); // skipped
        assert_eq!(r.count(), 2);
        assert_eq!(r.skipped(), 1);
        assert!((r.avg() - 0.055).abs() < 1e-12);
        assert!((r.max() - 0.10).abs() < 1e-12);
    }

    #[test]
    fn latency_percentiles() {
        let mut l = Latency::new();
        for i in 1..=100u64 {
            l.push(Duration::from_nanos(i));
        }
        assert_eq!(l.median(), Duration::from_nanos(50));
        assert_eq!(l.percentile(0.95), Duration::from_nanos(95));
        assert_eq!(l.percentile(1.0), Duration::from_nanos(100));
        assert_eq!(l.mean(), Duration::from_nanos(50));
        assert_eq!(l.count(), 100);
    }

    #[test]
    fn empty_latency_is_zero() {
        let l = Latency::new();
        assert_eq!(l.median(), Duration::ZERO);
        assert_eq!(l.mean(), Duration::ZERO);
        assert_eq!(l.total(), Duration::ZERO);
    }

    /// Reference H-measure by brute force: numeric integration of the
    /// pointwise-minimum loss over *all* ROC points (the minimum picks
    /// the hull vertices by itself, so no hull code is shared with the
    /// implementation under test).
    fn h_measure_brute(groups: &[(u64, u64)]) -> f64 {
        let mut pts = vec![(0u64, 0u64)];
        let (mut cp, mut cn) = (0u64, 0u64);
        for &(p, n) in groups {
            cp += p;
            cn += n;
            pts.push((cn, cp));
        }
        let (total_pos, total_neg) = (cp, cn);
        if total_pos == 0 || total_neg == 0 {
            return 0.0;
        }
        let total = (total_pos + total_neg) as f64;
        let (pi1, pi0) = (total_pos as f64 / total, total_neg as f64 / total);
        let u = |c: f64| 6.0 * c * (1.0 - c);
        let steps = 200_000;
        let (mut loss, mut loss_max) = (0.0, 0.0);
        for i in 0..steps {
            let c = (i as f64 + 0.5) / steps as f64;
            let min = pts
                .iter()
                .map(|&(xn, yp)| {
                    let x = xn as f64 / total_neg as f64;
                    let y = yp as f64 / total_pos as f64;
                    c * pi1 * (1.0 - y) + (1.0 - c) * pi0 * x
                })
                .fold(f64::INFINITY, f64::min);
            loss += min * u(c) / steps as f64;
            loss_max += (c * pi1).min((1.0 - c) * pi0) * u(c) / steps as f64;
        }
        1.0 - loss / loss_max
    }

    #[test]
    fn h_measure_extremes() {
        // Perfect separation (positives all below negatives) → 1.
        assert!((h_measure([(10, 0), (0, 10)]) - 1.0).abs() < 1e-12);
        // One indistinguishable group → 0.
        assert!(h_measure([(10, 10)]).abs() < 1e-12);
        // Reversed separation is no better than trivial → 0.
        assert!(h_measure([(0, 10), (10, 0)]).abs() < 1e-12);
        // Empty classes are the 0 convention.
        assert_eq!(h_measure([]), 0.0);
        assert_eq!(h_measure([(5, 0)]), 0.0);
        assert_eq!(h_measure([(0, 5)]), 0.0);
    }

    #[test]
    fn h_measure_matches_numeric_integration() {
        let cases: [&[(u64, u64)]; 5] = [
            &[(3, 1), (2, 2), (1, 4)],
            &[(1, 0), (0, 1), (1, 0), (0, 1)],
            &[(5, 1), (0, 3), (2, 2), (1, 7), (4, 0)],
            &[(1, 2), (3, 3), (2, 1)],
            &[(10, 1), (1, 10)],
        ];
        for groups in cases {
            let fast = h_measure(groups.iter().copied());
            let brute = h_measure_brute(groups);
            assert!(
                (fast - brute).abs() < 1e-4,
                "H mismatch on {groups:?}: {fast} vs {brute}"
            );
        }
    }

    #[test]
    fn h_measure_is_within_unit_interval_and_orders_separability() {
        // More separable groupings must not score lower.
        let weak = h_measure([(3, 2), (2, 3)]);
        let strong = h_measure([(4, 1), (1, 4)]);
        assert!((0.0..=1.0).contains(&weak));
        assert!((0.0..=1.0).contains(&strong));
        assert!(strong > weak, "H not ordering separability: {strong} vs {weak}");
    }
}
