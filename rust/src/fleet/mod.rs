//! Multi-stream AUC fleet engine — the service layer over the paper's
//! estimator.
//!
//! The §4 machinery maintains *one* `ε/2`-approximate window in
//! `O((log k)/ε)` per update. A production monitoring system maintains
//! one such window **per user / model / segment** — thousands to
//! millions of concurrent streams under bursty traffic. [`AucFleet`]
//! owns that multiplexing as a layered engine:
//!
//! * **Shard-owned state** (`fleet/shard.rs`) — streams live in `2^s`
//!   shards selected by a mixed hash of the stream id. Each shard owns
//!   its dense stream slab, id index, ingestion bucket and a
//!   shard-local alarm log, so shards never share mutable state and a
//!   shard is the unit of parallelism.
//! * **Parallel execution** (`fleet/executor.rs`) — [`AucFleet::push_batch`]
//!   partitions a batch by shard, then a [`FleetExecutor`] drains the
//!   shards either inline (serial, the default) or on
//!   [`std::thread::scope`] workers (`workers ≥ 2`). Events carry
//!   precomputed fleet-wide ticks and alarms merge in shard-index
//!   order, so **parallel and serial ingestion produce bit-identical
//!   snapshots, aggregates and alarm logs** — property-tested in
//!   `rust/tests/fleet.rs`.
//! * **Batched ingestion** — within a shard, the bucket is drained in
//!   arrival order with the stream-id → slot lookup resolved once per
//!   *run* of same-stream events; bursty traffic produces long runs, so
//!   per-event dispatch cost amortizes away (`benches/fleet.rs`).
//! * **Per-stream configuration** — window size `k`, accuracy `ε` and
//!   drift-monitor parameters default from
//!   [`FleetConfig::stream_defaults`] and can be overridden per stream
//!   ([`AucFleet::configure_stream`]).
//! * **Fleet-wide observability** — monitor alarms accumulate in a
//!   deterministic fleet-level log ([`AucFleet::alarms`]);
//!   [`AucFleet::snapshot`] materializes every stream,
//!   [`AucFleet::snapshot_iter`] streams the same records without
//!   materializing them, and [`AucFleet::aggregate`] computes fleet
//!   quantiles (min/p10/median/p90/max AUC, alarmed-stream count)
//!   shard-parallel.
//! * **Eviction** — [`AucFleet::evict_idle`] drops streams that have
//!   seen no traffic for a configurable number of fleet-wide events,
//!   compacting the shard slabs.
//!
//! ```
//! use streamauc::fleet::AucFleet;
//!
//! let mut fleet = AucFleet::with_defaults();
//! fleet.push_batch(&[(7, 0.2, true), (7, 0.8, false), (9, 0.4, true)]);
//! assert_eq!(fleet.stream_count(), 2);
//! assert_eq!(fleet.auc(7), Some(1.0)); // positives score low: perfect
//! assert_eq!(fleet.auc(9), Some(0.5)); // single class: undefined ⇒ ½
//! ```

mod config;
mod executor;
mod shard;
mod snapshot;

pub use config::{FleetConfig, MonitorConfig, StreamConfig};
pub use executor::FleetExecutor;
pub use snapshot::{FleetAggregate, FleetAlarm, FleetSnapshot, StreamSnapshot};

use std::collections::HashMap;

use shard::{Shard, StreamState};

use crate::coordinator::AucMonitor;

/// A fleet of independent sliding-window AUC estimators keyed by
/// stream id. See the module docs for the design.
#[derive(Clone, Debug)]
pub struct AucFleet {
    shards: Vec<Shard>,
    /// `shards.len() - 1`; shard count is a power of two.
    mask: u64,
    defaults: StreamConfig,
    overrides: HashMap<u64, StreamConfig>,
    executor: FleetExecutor,
    /// Fleet-wide tick: total events ingested since construction.
    total_events: u64,
    alarm_log: Vec<FleetAlarm>,
}

/// splitmix64 finalizer: decorrelates sequential / structured stream
/// ids before the power-of-two shard mask.
#[inline]
fn mix64(mut x: u64) -> u64 {
    x ^= x >> 30;
    x = x.wrapping_mul(0xbf58476d1ce4e5b9);
    x ^= x >> 27;
    x = x.wrapping_mul(0x94d049bb133111eb);
    x ^ (x >> 31)
}

impl AucFleet {
    /// New fleet from a configuration.
    pub fn new(cfg: FleetConfig) -> AucFleet {
        let shards = cfg.shards.max(1).next_power_of_two();
        AucFleet {
            shards: (0..shards).map(|_| Shard::default()).collect(),
            mask: shards as u64 - 1,
            defaults: cfg.stream_defaults,
            overrides: HashMap::new(),
            executor: FleetExecutor::new(cfg.workers),
            total_events: 0,
            alarm_log: Vec::new(),
        }
    }

    /// New fleet with [`FleetConfig::default`].
    pub fn with_defaults() -> AucFleet {
        AucFleet::new(FleetConfig::default())
    }

    /// Ingestion worker threads (1 = serial).
    pub fn workers(&self) -> usize {
        self.executor.workers()
    }

    /// Reconfigure the ingestion worker count at runtime. Worker count
    /// never affects results (only wall-clock), so this is always safe.
    pub fn set_workers(&mut self, workers: usize) {
        self.executor = FleetExecutor::new(workers);
    }

    #[inline]
    fn shard_of(&self, id: u64) -> usize {
        (mix64(id) & self.mask) as usize
    }

    /// Register a per-stream configuration override. If the stream is
    /// already live its state is **reset** under the new configuration
    /// (window contents, monitor state and alarm counters start fresh);
    /// otherwise the override applies on the stream's first event.
    /// Overrides survive [`AucFleet::evict_idle`]: a re-appearing stream
    /// is recreated under its override.
    pub fn configure_stream(&mut self, id: u64, cfg: StreamConfig) {
        let s = self.shard_of(id);
        self.shards[s].reset_stream(id, &cfg, self.total_events);
        self.overrides.insert(id, cfg);
    }

    /// Effective configuration for a stream (override or defaults).
    pub fn stream_config(&self, id: u64) -> StreamConfig {
        self.overrides.get(&id).copied().unwrap_or(self.defaults)
    }

    /// Ingest one `(stream, score, label)` event. The one-at-a-time
    /// path: full dispatch (hash + index probe) on every call. Prefer
    /// [`AucFleet::push_batch`] under load.
    pub fn push(&mut self, stream: u64, score: f64, label: bool) {
        let s = self.shard_of(stream);
        let tick = self.total_events + 1;
        let shard = &mut self.shards[s];
        let slot = shard.ensure_slot(stream, &self.defaults, &self.overrides);
        shard.push_at(slot, score, label, tick);
        shard.take_alarms_into(&mut self.alarm_log);
        self.total_events = tick;
    }

    /// Ingest a batch of `(stream, score, label)` events.
    ///
    /// Events are bucketed per shard, then every shard drains its bucket
    /// in arrival order — inline when `workers ≤ 1`, on scoped worker
    /// threads otherwise. Per-stream event order is always preserved.
    /// The fleet-wide alarm log orders a batch's alarms by shard index
    /// (then arrival order within the shard); this order is identical
    /// for serial and parallel ingestion, so the two modes produce
    /// bit-identical fleets.
    pub fn push_batch(&mut self, batch: &[(u64, f64, bool)]) {
        if batch.is_empty() {
            return;
        }
        // Buckets are normally left empty by `drain`; clear defensively
        // so events stranded by a caught mid-batch panic can never be
        // re-ingested with stale ticks on the next call.
        for shard in &mut self.shards {
            shard.bucket.clear();
        }
        for &(id, score, label) in batch {
            let s = self.shard_of(id);
            self.shards[s].bucket.push((id, score, label));
        }
        // Bucket sizes are known before draining starts, so every shard
        // can stamp its events with the exact fleet-wide ticks the
        // serial shard-by-shard drain would assign — the key to
        // scheduling-independent results.
        let mut start_ticks = Vec::with_capacity(self.shards.len());
        let mut tick = self.total_events;
        for shard in &self.shards {
            start_ticks.push(tick);
            tick += shard.bucket.len() as u64;
        }
        let defaults = &self.defaults;
        let overrides = &self.overrides;
        let ticks = &start_ticks;
        self.executor.for_each_shard(&mut self.shards, |i: usize, shard: &mut Shard| {
            shard.drain(defaults, overrides, ticks[i]);
        });
        self.total_events = tick;
        // Deterministic merge of the shard-local alarm logs.
        for s in 0..self.shards.len() {
            self.shards[s].take_alarms_into(&mut self.alarm_log);
        }
    }

    /// Drop every stream that has seen no events for at least
    /// `max_idle_events` fleet-wide events (the fleet tick advances by
    /// one per ingested event, across all streams). Shard slabs are
    /// compacted in place; per-stream overrides are kept, so a stream
    /// that re-appears is recreated fresh under its configured override.
    /// Returns the number of evicted streams.
    ///
    /// `max_idle_events = 0` evicts every stream.
    pub fn evict_idle(&mut self, max_idle_events: u64) -> usize {
        let now = self.total_events;
        self.shards.iter_mut().map(|sh| sh.evict_idle(now, max_idle_events)).sum()
    }

    fn find(&self, id: u64) -> Option<&StreamState> {
        self.shards[self.shard_of(id)].get(id)
    }

    /// Current windowed AUC estimate of a stream (`None` if unseen).
    pub fn auc(&self, id: u64) -> Option<f64> {
        self.find(id).map(|st| st.win.auc())
    }

    /// Pairs currently in a stream's window (`None` if unseen).
    pub fn stream_len(&self, id: u64) -> Option<usize> {
        self.find(id).map(|st| st.win.len())
    }

    /// A stream's window contents, oldest first (`None` if unseen).
    /// Test / audit helper: lets callers recompute the exact AUC over
    /// the identical window.
    pub fn entries(&self, id: u64) -> Option<impl Iterator<Item = (f64, bool)> + '_> {
        self.find(id).map(|st| st.win.entries())
    }

    /// True while a stream's monitor is inside an alarmed excursion.
    pub fn is_alarmed(&self, id: u64) -> bool {
        self.find(id)
            .and_then(|st| st.monitor.as_ref())
            .map_or(false, AucMonitor::is_alarmed)
    }

    /// True once a stream has been seen (and not evicted).
    pub fn contains(&self, id: u64) -> bool {
        self.find(id).is_some()
    }

    /// Number of live streams across all shards.
    pub fn stream_count(&self) -> usize {
        self.shards.iter().map(Shard::len).sum()
    }

    /// Total events ingested across the fleet (the fleet tick).
    pub fn total_events(&self) -> u64 {
        self.total_events
    }

    /// Shard count (power of two).
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// Streams per shard (balance diagnostics).
    pub fn shard_sizes(&self) -> Vec<usize> {
        self.shards.iter().map(Shard::len).collect()
    }

    /// Alarms accumulated since construction (or the last
    /// [`AucFleet::take_alarms`]), in deterministic firing order.
    pub fn alarms(&self) -> &[FleetAlarm] {
        &self.alarm_log
    }

    /// Drain the alarm log.
    pub fn take_alarms(&mut self) -> Vec<FleetAlarm> {
        std::mem::take(&mut self.alarm_log)
    }

    /// Stream every live stream's snapshot without materializing the
    /// whole fleet, in shard-major slab order (**not** id-sorted — sort
    /// requires materialization; use [`AucFleet::snapshot`] for the
    /// sorted view). `O(|C|)` per yielded stream, `O(1)` extra memory.
    pub fn snapshot_iter(&self) -> impl Iterator<Item = StreamSnapshot> + '_ {
        self.shards.iter().flat_map(|sh| sh.streams().iter().map(StreamState::snapshot))
    }

    /// Point-in-time snapshot of every stream: AUC, window fill, `|C|`,
    /// alarm state. Streams are sorted by id. `O(total |C|)`.
    pub fn snapshot(&self) -> FleetSnapshot {
        let mut streams: Vec<StreamSnapshot> = self.snapshot_iter().collect();
        streams.sort_by_key(|s| s.stream);
        let alarmed_streams = streams.iter().filter(|s| s.alarmed).map(|s| s.stream).collect();
        FleetSnapshot { streams, alarmed_streams, total_events: self.total_events }
    }

    /// Fleet-level aggregate metrics — stream counts plus the
    /// min/p10/median/p90/max/mean of the per-stream windowed AUCs and
    /// the currently-alarmed stream count. Per-shard collection runs on
    /// the executor's workers; the merge is in shard order, so the
    /// result is identical under any worker count.
    pub fn aggregate(&self) -> FleetAggregate {
        let per_shard = self.executor.map_shards(&self.shards, |_: usize, shard: &Shard| {
            let mut aucs = Vec::with_capacity(shard.len());
            let mut alarmed = 0usize;
            for st in shard.streams() {
                if !st.win.is_empty() {
                    aucs.push(st.win.auc());
                }
                if st.monitor.as_ref().map_or(false, AucMonitor::is_alarmed) {
                    alarmed += 1;
                }
            }
            (aucs, alarmed)
        });
        let mut aucs = Vec::new();
        let mut alarmed = 0;
        for (a, al) in per_shard {
            aucs.extend(a);
            alarmed += al;
        }
        FleetAggregate::compute(aucs, self.stream_count(), alarmed, self.total_events)
    }
}

// The whole fleet is `Send`: it can be owned by a service thread, moved
// into spawned workers, or sharded further by an embedding application.
const _: () = {
    const fn assert_send<T: Send>() {}
    assert_send::<AucFleet>();
};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::NaiveAuc;
    use crate::testing::Pcg;

    fn small_fleet(window: usize, epsilon: f64) -> AucFleet {
        AucFleet::new(FleetConfig {
            shards: 8,
            workers: 1,
            stream_defaults: StreamConfig::new(window, epsilon),
        })
    }

    /// Deterministic event soup over `n_streams` streams.
    fn soup(n_streams: u64, events: usize, seed: u64) -> Vec<(u64, f64, bool)> {
        let mut rng = Pcg::seed(seed);
        (0..events)
            .map(|_| {
                let id = rng.below(n_streams);
                let pos = rng.chance(0.5);
                // Separable per-stream scores so AUCs are interesting.
                let s = if pos { rng.normal_with(0.35, 0.15) } else { rng.normal_with(0.65, 0.15) };
                (id, s, pos)
            })
            .collect()
    }

    #[test]
    fn batched_equals_one_at_a_time() {
        let events = soup(17, 4000, 0xBA7C);
        let mut one = small_fleet(100, 0.1);
        let mut bat = small_fleet(100, 0.1);
        for &(id, s, l) in &events {
            one.push(id, s, l);
        }
        for chunk in events.chunks(257) {
            bat.push_batch(chunk);
        }
        assert_eq!(one.stream_count(), bat.stream_count());
        assert_eq!(one.total_events(), bat.total_events());
        // The fleet-wide log may interleave streams differently across
        // a batch; per-stream alarm sequences must match exactly.
        let by_stream = |alarms: &[FleetAlarm]| {
            let mut v = alarms.to_vec();
            v.sort_by_key(|a| (a.stream, a.stream_event));
            v
        };
        assert_eq!(by_stream(one.alarms()), by_stream(bat.alarms()));
        for id in 0..17 {
            assert_eq!(one.auc(id), bat.auc(id), "stream {id} AUC diverged");
            assert_eq!(one.stream_len(id), bat.stream_len(id));
            let a: Vec<_> = one.entries(id).unwrap().collect();
            let b: Vec<_> = bat.entries(id).unwrap().collect();
            assert_eq!(a, b, "stream {id} window contents diverged");
        }
    }

    #[test]
    fn workers_do_not_change_results() {
        let events = soup(31, 6000, 0x9A11);
        let mut serial = small_fleet(100, 0.1);
        let mut parallel = small_fleet(100, 0.1);
        parallel.set_workers(4);
        assert_eq!(parallel.workers(), 4);
        for chunk in events.chunks(513) {
            serial.push_batch(chunk);
            parallel.push_batch(chunk);
        }
        assert_eq!(serial.snapshot(), parallel.snapshot());
        assert_eq!(serial.aggregate(), parallel.aggregate());
        assert_eq!(serial.alarms(), parallel.alarms());
    }

    #[test]
    fn streams_are_isolated() {
        let mut fleet = small_fleet(50, 0.05);
        // Stream 1: perfectly separated. Stream 2: adversarial noise.
        let mut rng = Pcg::seed(3);
        for _ in 0..200 {
            fleet.push(1, 0.2, true);
            fleet.push(1, 0.8, false);
            fleet.push(2, rng.uniform(), rng.chance(0.5));
        }
        assert_eq!(fleet.auc(1), Some(1.0), "noise in stream 2 leaked into stream 1");
        assert_eq!(fleet.stream_len(1), Some(50));
    }

    #[test]
    fn windows_evict_fifo_per_stream() {
        let mut fleet = small_fleet(3, 0.1);
        for (i, id) in [(1, 7u64), (2, 9), (3, 7), (4, 7), (5, 7)] {
            fleet.push(id, f64::from(i), true);
        }
        // Stream 7 saw scores 1, 3, 4, 5 with capacity 3 → {3, 4, 5}.
        let got: Vec<f64> = fleet.entries(7).unwrap().map(|(s, _)| s).collect();
        assert_eq!(got, vec![3.0, 4.0, 5.0]);
        assert_eq!(fleet.stream_len(9), Some(1));
    }

    #[test]
    fn per_stream_config_overrides_apply() {
        let mut fleet = small_fleet(100, 0.0);
        fleet.configure_stream(5, StreamConfig::new(10, 0.0).without_monitor());
        let events = soup(1, 300, 9); // all events on stream 0…
        for &(_, s, l) in &events {
            fleet.push(0, s, l); // …default config
            fleet.push(5, s, l); // …override
        }
        assert_eq!(fleet.stream_len(0), Some(100));
        assert_eq!(fleet.stream_len(5), Some(10), "override window ignored");
        assert_eq!(fleet.stream_config(5).window, 10);
        assert_eq!(fleet.stream_config(0).window, 100);
    }

    #[test]
    fn configure_resets_live_stream() {
        let mut fleet = small_fleet(50, 0.1);
        for i in 0..40 {
            fleet.push(3, f64::from(i) / 40.0, i % 2 == 0);
        }
        assert_eq!(fleet.stream_len(3), Some(40));
        fleet.configure_stream(3, StreamConfig::new(20, 0.1));
        assert_eq!(fleet.stream_len(3), Some(0), "reconfigure must reset the window");
        fleet.push(3, 0.5, true);
        assert_eq!(fleet.stream_len(3), Some(1));
    }

    #[test]
    fn estimates_track_naive_oracle_per_stream() {
        let eps = 0.1;
        let events = soup(11, 6000, 0x0A7E);
        let mut fleet = small_fleet(120, eps);
        for chunk in events.chunks(512) {
            fleet.push_batch(chunk);
        }
        for id in 0..11 {
            let window: Vec<(f64, bool)> = fleet.entries(id).unwrap().collect();
            let truth = NaiveAuc::of(&window);
            let est = fleet.auc(id).unwrap();
            assert!(
                (est - truth).abs() <= eps * truth / 2.0 + 1e-12,
                "stream {id}: est {est} vs naive {truth}"
            );
        }
    }

    #[test]
    fn monitor_alarms_surface_in_log_and_snapshot() {
        let mut fleet = AucFleet::new(FleetConfig {
            shards: 4,
            workers: 1,
            stream_defaults: StreamConfig {
                window: 100,
                epsilon: 0.1,
                monitor: Some(MonitorConfig {
                    lambda: 0.001,
                    margin: 0.08,
                    patience: 20,
                    warmup: 100,
                }),
            },
        });
        let mut rng = Pcg::seed(0xA1A);
        // Healthy phase on both streams.
        for _ in 0..1500 {
            for id in [1u64, 2] {
                let pos = rng.chance(0.5);
                let s = if pos { rng.normal_with(0.3, 0.1) } else { rng.normal_with(0.7, 0.1) };
                fleet.push(id, s, pos);
            }
        }
        assert!(fleet.alarms().is_empty(), "healthy phase must not alarm");
        // Stream 2 breaks: labels decouple from scores.
        for _ in 0..1500 {
            let pos = rng.chance(0.5);
            let s = if pos { rng.normal_with(0.3, 0.1) } else { rng.normal_with(0.7, 0.1) };
            fleet.push(1, s, pos);
            fleet.push(2, rng.uniform(), rng.chance(0.5));
        }
        let alarmed: Vec<u64> = fleet.alarms().iter().map(|a| a.stream).collect();
        assert!(alarmed.contains(&2), "broken stream must alarm");
        assert!(!alarmed.contains(&1), "healthy stream must stay quiet");
        assert!(fleet.is_alarmed(2));
        assert!(!fleet.is_alarmed(1));
        let snap = fleet.snapshot();
        assert_eq!(snap.alarmed_streams, vec![2]);
        let agg = fleet.aggregate();
        assert_eq!(agg.alarmed_streams, 1);
        assert_eq!(agg.streams, 2);
        let drained = fleet.take_alarms();
        assert!(!drained.is_empty());
        assert!(fleet.alarms().is_empty());
    }

    #[test]
    fn snapshot_is_sorted_and_complete() {
        let mut fleet = small_fleet(30, 0.2);
        let events = soup(23, 2000, 0x51AB);
        fleet.push_batch(&events);
        let snap = fleet.snapshot();
        assert_eq!(snap.streams.len(), fleet.stream_count());
        assert_eq!(snap.total_events, 2000);
        let ids: Vec<u64> = snap.streams.iter().map(|s| s.stream).collect();
        let mut sorted = ids.clone();
        sorted.sort_unstable();
        assert_eq!(ids, sorted, "snapshot must be id-sorted");
        for s in &snap.streams {
            assert!(s.len <= 30);
            assert!(s.compressed_len >= 2);
            assert!((0.0..=1.0).contains(&s.auc));
        }
        assert!(snap.mean_auc() > 0.5, "separable soup should score above chance");
    }

    #[test]
    fn snapshot_iter_matches_snapshot() {
        let mut fleet = small_fleet(30, 0.2);
        fleet.push_batch(&soup(19, 1500, 0x17E8));
        let mut streamed: Vec<StreamSnapshot> = fleet.snapshot_iter().collect();
        assert_eq!(streamed.len(), fleet.stream_count());
        streamed.sort_by_key(|s| s.stream);
        assert_eq!(streamed, fleet.snapshot().streams);
    }

    #[test]
    fn aggregate_quantiles_over_known_aucs() {
        let mut fleet = AucFleet::new(FleetConfig {
            shards: 4,
            workers: 2,
            stream_defaults: StreamConfig::new(10, 0.0).without_monitor(),
        });
        // Stream 1: AUC 1.0; stream 2: AUC 0.0; stream 3: single class ⇒ ½.
        for _ in 0..5 {
            fleet.push(1, 0.2, true);
            fleet.push(1, 0.8, false);
            fleet.push(2, 0.8, true);
            fleet.push(2, 0.2, false);
            fleet.push(3, 0.5, true);
        }
        let agg = fleet.aggregate();
        assert_eq!(agg.streams, 3);
        assert_eq!(agg.live_streams, 3);
        assert_eq!(agg.alarmed_streams, 0);
        assert_eq!(agg.total_events, 25);
        assert_eq!(agg.min_auc, 0.0);
        assert_eq!(agg.max_auc, 1.0);
        assert_eq!(agg.median_auc, 0.5);
        assert_eq!(agg.p10_auc, 0.0); // round(0.1 · 2) = 0
        assert_eq!(agg.p90_auc, 1.0); // round(0.9 · 2) = 2
        assert_eq!(agg.mean_auc, 0.5);
    }

    #[test]
    fn aggregate_of_empty_fleet_is_the_convention() {
        let agg = AucFleet::with_defaults().aggregate();
        assert_eq!(agg.streams, 0);
        assert_eq!(agg.live_streams, 0);
        assert_eq!(agg.median_auc, 0.5);
        assert_eq!(agg.min_auc, 0.5);
        assert_eq!(agg.max_auc, 0.5);
        assert_eq!(agg.mean_auc, 0.5);
    }

    #[test]
    fn evict_idle_compacts_and_preserves_survivors() {
        let mut fleet = small_fleet(20, 0.1);
        // Phase 1: streams 0..6 all take traffic.
        for round in 0..30 {
            for id in 0..6u64 {
                fleet.push(id, 0.1 * f64::from(round % 10), round % 2 == 0);
            }
        }
        // Phase 2: only streams 3..6 stay active.
        for round in 0..100 {
            for id in 3..6u64 {
                fleet.push(id, 0.1 * f64::from(round % 10), round % 2 == 0);
            }
        }
        let survivors_before: Vec<Vec<(f64, bool)>> =
            (3..6).map(|id| fleet.entries(id).unwrap().collect()).collect();
        // Streams 0..3 idle ≥ 300 ticks; 3..6 idle < 10.
        let evicted = fleet.evict_idle(200);
        assert_eq!(evicted, 3);
        assert_eq!(fleet.stream_count(), 3);
        for id in 0..3u64 {
            assert!(!fleet.contains(id), "stream {id} should be evicted");
            assert_eq!(fleet.auc(id), None);
        }
        for (i, id) in (3..6u64).enumerate() {
            let after: Vec<(f64, bool)> = fleet.entries(id).unwrap().collect();
            assert_eq!(after, survivors_before[i], "stream {id} disturbed by compaction");
        }
        // Evicted streams come back fresh on their next event.
        fleet.push(1, 0.5, true);
        assert_eq!(fleet.stream_len(1), Some(1));
        // max_idle 0 clears the fleet.
        assert_eq!(fleet.evict_idle(0), 4);
        assert_eq!(fleet.stream_count(), 0);
    }

    #[test]
    fn evict_idle_keeps_overrides() {
        let mut fleet = small_fleet(100, 0.1);
        fleet.configure_stream(9, StreamConfig::new(7, 0.1).without_monitor());
        for i in 0..50 {
            fleet.push(9, f64::from(i), i % 2 == 0);
        }
        fleet.push(1, 0.5, true); // keep the tick moving
        assert_eq!(fleet.stream_len(9), Some(7));
        assert_eq!(fleet.evict_idle(1), 1); // stream 9 idle exactly 1 tick
        assert!(!fleet.contains(9));
        fleet.push(9, 0.5, true);
        assert_eq!(fleet.stream_config(9).window, 7, "override lost across eviction");
        assert_eq!(fleet.stream_len(9), Some(1));
    }

    #[test]
    fn sharding_spreads_streams() {
        let mut fleet = AucFleet::new(FleetConfig {
            shards: 16,
            workers: 1,
            stream_defaults: StreamConfig::new(10, 0.5).without_monitor(),
        });
        // Sequential ids — the adversarial pattern for naive modulo.
        for id in 0..1600u64 {
            fleet.push(id, 0.5, true);
        }
        assert_eq!(fleet.shard_count(), 16);
        assert_eq!(fleet.stream_count(), 1600);
        let sizes = fleet.shard_sizes();
        let (min, max) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
        assert!(*min > 50 && *max < 200, "unbalanced shards: {sizes:?}");
    }

    #[test]
    fn shard_count_rounds_to_power_of_two() {
        let fleet = AucFleet::new(FleetConfig { shards: 5, ..FleetConfig::default() });
        assert_eq!(fleet.shard_count(), 8);
        let fleet = AucFleet::new(FleetConfig { shards: 0, ..FleetConfig::default() });
        assert_eq!(fleet.shard_count(), 1);
    }

    #[test]
    fn empty_batch_and_unseen_queries() {
        let mut fleet = AucFleet::with_defaults();
        fleet.push_batch(&[]);
        assert_eq!(fleet.stream_count(), 0);
        assert_eq!(fleet.total_events(), 0);
        assert_eq!(fleet.auc(42), None);
        assert_eq!(fleet.stream_len(42), None);
        assert!(!fleet.contains(42));
        assert!(!fleet.is_alarmed(42));
        assert!(fleet.entries(42).is_none());
        assert!(fleet.snapshot().streams.is_empty());
        assert_eq!(fleet.snapshot_iter().count(), 0);
    }
}
