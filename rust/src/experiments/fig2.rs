//! Figure 2: computational cost and `|C|` versus the achieved error.
//!
//! Paper setup: window k = 1000; top row plots total running time as a
//! function of the *average relative error* achieved by each ε, bottom
//! row the compressed-list size |C|. Expected shape: time falls as the
//! error grows, then plateaus (the ε-independent `O(log k)` tree
//! maintenance dominates); |C| shrinks like `(log k)/ε`.
//!
//! Timing protocol (paper §6: “running times measure only the
//! computation of AUC”): a separate pass per ε measures
//! `push + ApproxAUC query` per event, without the exact-AUC
//! enumeration; the error comes from the same pass as Fig. 1.

use std::time::Instant;

use super::report::{fmt_duration, fmt_sci, Table};
use super::{ExpConfig, EPSILONS};
use crate::coordinator::metrics::{RelErr, Summary};
use crate::coordinator::window::Window;
use crate::coordinator::{ApproxAuc, AucEstimator};
use crate::stream::synth::{paper_datasets, Dataset};

/// One sweep point.
#[derive(Clone, Debug)]
pub struct Point {
    /// Dataset name.
    pub dataset: &'static str,
    /// Approximation parameter.
    pub epsilon: f64,
    /// Average relative error (x-axis of both plots).
    pub avg_err: f64,
    /// Total time for the timed pass (maintenance + query per event).
    pub total: std::time::Duration,
    /// Mean per-event time.
    pub per_event: std::time::Duration,
    /// Mean / max compressed-list size (sentinels included).
    pub avg_c: f64,
    /// Maximum |C| observed.
    pub max_c: usize,
}

/// Run the sweep: an error pass (exact comparison) plus a timed pass.
pub fn sweep(cfg: ExpConfig, epsilons: &[f64]) -> Vec<Point> {
    let mut points = Vec::new();
    for spec in paper_datasets() {
        let name = spec.name;
        let mut data = Dataset::new(spec, cfg.seed);
        let stream = data.score_stream(cfg.events);
        for &eps in epsilons {
            // Pass 1: error + |C| statistics.
            let mut win = Window::with_estimator(cfg.window, ApproxAuc::new(eps));
            let mut err = RelErr::new();
            let mut csize = Summary::new();
            for &(s, l) in &stream {
                win.push(s, l);
                if win.is_full() {
                    err.record(win.auc(), win.estimator().exact_auc());
                    csize.push(win.estimator().compressed_len() as f64);
                }
            }
            // Pass 2: timed (no exact enumeration in the loop).
            let mut est = ApproxAuc::new(eps);
            let mut fifo = std::collections::VecDeque::with_capacity(cfg.window + 1);
            let start = Instant::now();
            let mut sink = 0.0;
            for &(s, l) in &stream {
                est.insert(s, l);
                fifo.push_back((s, l));
                if fifo.len() > cfg.window {
                    let (os, ol) = fifo.pop_front().unwrap();
                    est.remove(os, ol);
                }
                sink += est.auc();
            }
            let total = start.elapsed();
            std::hint::black_box(sink);
            points.push(Point {
                dataset: name,
                epsilon: eps,
                avg_err: err.avg(),
                total,
                per_event: total / cfg.events.max(1) as u32,
                avg_c: csize.mean(),
                max_c: csize.max() as usize,
            });
        }
    }
    points
}

/// Build the Figure 2 table (top: time vs error; bottom: |C| vs error).
pub fn run(cfg: ExpConfig) -> Table {
    let mut table = Table::new(
        format!(
            "fig2: runtime and |C| vs avg error (k={}, {} events/dataset)",
            cfg.window, cfg.events
        ),
        &["dataset", "epsilon", "avg_rel_err", "total_time", "per_event", "avg_|C|", "max_|C|"],
    );
    for p in sweep(cfg, &EPSILONS) {
        table.push(vec![
            p.dataset.to_string(),
            fmt_sci(p.epsilon),
            fmt_sci(p.avg_err),
            fmt_duration(p.total),
            fmt_duration(p.per_event),
            format!("{:.1}", p.avg_c),
            p.max_c.to_string(),
        ]);
    }
    table
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn c_shrinks_and_time_improves_with_epsilon() {
        let cfg = ExpConfig { events: 6000, window: 500, seed: 3 };
        let points = sweep(cfg, &[1e-3, 1.0]);
        for chunk in points.chunks(2) {
            let (tight, loose) = (&chunk[0], &chunk[1]);
            assert!(
                loose.avg_c < tight.avg_c,
                "{}: |C| must shrink with ε ({} vs {})",
                tight.dataset,
                loose.avg_c,
                tight.avg_c
            );
            // Large ε must not be slower than tight ε by more than noise.
            assert!(
                loose.total.as_secs_f64() < tight.total.as_secs_f64() * 1.5,
                "{}: ε=1 pass slower than ε=1e-3",
                tight.dataset
            );
        }
    }

    #[test]
    fn c_matches_log_over_epsilon_shape() {
        let cfg = ExpConfig { events: 5000, window: 1000, seed: 4 };
        let points = sweep(cfg, &[0.01, 0.1]);
        for chunk in points.chunks(2) {
            let ratio = chunk[0].avg_c / chunk[1].avg_c;
            // |C| ~ log(k)/ε ⇒ tenfold ε should shrink |C| severalfold.
            assert!(
                ratio > 2.0,
                "{}: |C| ratio {ratio} too flat for 10× ε",
                chunk[0].dataset
            );
        }
    }
}
