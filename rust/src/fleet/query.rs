//! Shard-parallel fleet query layer: the monitoring questions a fleet
//! operator actually asks, answered on the typed job engine.
//!
//! The paper makes *maintaining* a windowed AUC cheap, which shifts
//! fleet cost onto *reading* the maintained estimates: triage ("which
//! streams are worst right now?"), SLO accounting ("how many streams
//! sit below 0.8?"), and distribution shape ("is the fleet bimodal?").
//! Each query here runs as a [`ShardWork`] job on the fleet's
//! executor — inline, scoped, or on the persistent worker pool
//! ([`FleetConfig::pool`](super::FleetConfig::pool)), exactly like
//! ingestion drains — and merges per-shard partials in shard-index
//! order, so results are **bit-identical under every execution
//! strategy** (adversarially tested in `rust/tests/executor.rs`).
//!
//! Since the shards maintain running sketches (`fleet/shard.rs`
//! `ShardSketch`), the queries no longer rescan streams:
//! `count_below` reads whole bins from the merged sketch and refines
//! only the bin containing the threshold; `auc_histogram` is a pure
//! sketch merge whenever the requested bin count divides
//! `SKETCH_BINS` (a cached-stat rebin otherwise); `top_k_worst` cuts
//! the candidate set to the smallest bin prefix holding `k` live
//! streams before ranking. Exactness survives because the bin
//! partition is monotone in AUC with *exact* f64 boundaries
//! (`auc · 64` never rounds) — see `DESIGN.md` §Incremental-reads.
//!
//! All queries synchronize transparently with an in-flight pipelined
//! batch before reading, like every other read path.

use super::pool::{FleetCore, ShardWork};
use super::shard::{threshold_bin, worst_first, SKETCH_BINS};
use super::snapshot::StreamSnapshot;
use super::AucFleet;

/// Distribution of the per-stream windowed AUC estimates over `[0, 1]`
/// in equal-width bins ([`AucFleet::auc_histogram`]). Streams with an
/// empty window carry no estimate and are not counted.
#[derive(Clone, Debug, PartialEq)]
pub struct AucHistogram {
    /// Per-bin stream counts; bin `i` covers
    /// `[i · w, (i+1) · w)` with `w = 1 / counts.len()` (the last bin
    /// is closed at 1.0).
    pub counts: Vec<usize>,
    /// Streams counted (= sum of `counts`).
    pub live_streams: usize,
}

impl AucHistogram {
    /// Number of bins.
    pub fn bins(&self) -> usize {
        self.counts.len()
    }

    /// Width of one bin.
    pub fn bin_width(&self) -> f64 {
        1.0 / self.counts.len() as f64
    }

    /// Inclusive-exclusive AUC range of bin `i` (the last bin closes
    /// at 1.0).
    pub fn bin_range(&self, i: usize) -> (f64, f64) {
        let w = self.bin_width();
        (i as f64 * w, (i as f64 + 1.0) * w)
    }

    /// Fraction of counted streams in bin `i` (0 when the fleet has no
    /// live streams).
    pub fn fraction(&self, i: usize) -> f64 {
        if self.live_streams == 0 {
            0.0
        } else {
            self.counts[i] as f64 / self.live_streams as f64
        }
    }
}

/// Distribution of the raw window-entry *scores* over `[0, 1]` in
/// equal-width cells ([`AucFleet::score_histogram`]) — the input-side
/// companion to [`AucHistogram`]'s estimate-side view. Out-of-range
/// scores clamp into the edge cells.
#[derive(Clone, Debug, PartialEq)]
pub struct ScoreHistogram {
    /// Per-cell window-entry counts; cell `i` covers
    /// `[i · w, (i+1) · w)` with `w = 1 / counts.len()` (edge cells
    /// absorb out-of-range scores).
    pub counts: Vec<u64>,
    /// Window entries counted (= sum of `counts`).
    pub entries: u64,
}

impl ScoreHistogram {
    /// Number of cells.
    pub fn bins(&self) -> usize {
        self.counts.len()
    }

    /// Fraction of counted entries in cell `i` (0 when all windows are
    /// empty).
    pub fn fraction(&self, i: usize) -> f64 {
        if self.entries == 0 {
            0.0
        } else {
            self.counts[i] as f64 / self.entries as f64
        }
    }
}

/// Per-shard score-distribution partials for
/// [`AucFleet::score_histogram`]. The shard visitor takes the binned
/// fast path (count-array group-sum) per eligible stream and rescans
/// window FIFOs otherwise.
struct ScoreHistogramWork {
    bins: usize,
}

impl ShardWork for ScoreHistogramWork {
    type Output = (Vec<u64>, u64);
    fn visit(&self, s: usize, core: &FleetCore) -> Self::Output {
        core.lock_shard(s).score_histogram(self.bins)
    }
}

/// Per-shard top-k candidates for [`AucFleet::top_k_worst`], cut to
/// the sketch-derived candidate bins. Any global top-k member is
/// necessarily in its own shard's top-k of the candidates, so
/// per-shard truncation loses nothing.
struct TopKWork {
    k: usize,
    /// Candidate sketch bins (`MergedSketch::worst_prefix_mask`).
    mask: u64,
}

impl ShardWork for TopKWork {
    type Output = Vec<StreamSnapshot>;
    fn visit(&self, s: usize, core: &FleetCore) -> Self::Output {
        core.lock_shard(s).top_k_worst(self.k, self.mask)
    }
}

/// Boundary-bin refinement for [`AucFleet::count_below`]: bins fully
/// below the threshold are counted from the merged sketch alone; only
/// the bin containing the threshold compares actual values.
struct CountBelowBinWork {
    bin: u8,
    threshold: f64,
}

impl ShardWork for CountBelowBinWork {
    type Output = usize;
    fn visit(&self, s: usize, core: &FleetCore) -> usize {
        core.lock_shard(s).count_below_in_bin(self.bin, self.threshold)
    }
}

/// Per-shard histogram partials for [`AucFleet::auc_histogram`] —
/// the cached-stat rebin fallback for bin counts that do not divide
/// `SKETCH_BINS`.
struct HistogramWork {
    bins: usize,
}

impl ShardWork for HistogramWork {
    type Output = (Vec<usize>, usize);
    fn visit(&self, s: usize, core: &FleetCore) -> Self::Output {
        core.lock_shard(s).histogram(self.bins)
    }
}

/// Per-shard predicate filtering for [`AucFleet::select_streams`]. The
/// predicate is owned by the work value (the owned-state rule), so it
/// can ride the persistent pool's threads; hence the `'static` bound
/// on the public API.
struct SelectWork<P> {
    pred: P,
}

impl<P> ShardWork for SelectWork<P>
where
    P: Fn(&StreamSnapshot) -> bool + Send + Sync + 'static,
{
    type Output = Vec<StreamSnapshot>;
    fn visit(&self, s: usize, core: &FleetCore) -> Self::Output {
        let mut hits = core.lock_shard(s).snapshots();
        hits.retain(|snap| (self.pred)(snap));
        hits
    }
}

impl AucFleet {
    /// The `k` live streams with the lowest windowed AUC — the triage
    /// view — sorted worst first (ties broken by stream id; the shared
    /// `worst_first` order, which is also what makes the per-shard
    /// truncation in `Shard::top_k_worst` lossless). Streams with an
    /// empty window carry no estimate and are not ranked.
    ///
    /// Two-phase: the merged sketch yields the smallest bin prefix
    /// holding `k` live streams, then only those candidate bins are
    /// ranked and snapshotted shard-parallel (equal estimates share a
    /// bin, so id tie-breaks never straddle the cut). Per-shard
    /// candidates merge in shard order and re-sort on a total order,
    /// so the result is identical under every strategy.
    pub fn top_k_worst(&self, k: usize) -> Vec<StreamSnapshot> {
        if k == 0 {
            return Vec::new();
        }
        let mask = self.merged_sketch().worst_prefix_mask(k);
        if mask == 0 {
            return Vec::new();
        }
        let mut all: Vec<StreamSnapshot> = self
            .executor
            .map_shards(&self.core, TopKWork { k, mask })
            .into_iter()
            .flatten()
            .collect();
        all.sort_by(|a, b| worst_first((a.auc, a.stream), (b.auc, b.stream)));
        all.truncate(k);
        all
    }

    /// Number of live streams whose windowed AUC is strictly below
    /// `threshold` — the SLO accounting query.
    ///
    /// Edge semantics are explicit at this surface (thresholds arrive
    /// from the network through `crate::serve`, so "whatever the cast
    /// does" is not a contract): estimates live in `[0, 1]`, hence
    /// `t ≤ 0` (including `-∞`) and NaN count nothing, and `t > 1`
    /// (including `+∞`) counts every live stream — each resolved
    /// before any bin arithmetic, instead of a bare `as usize` cast
    /// silently truncating negative or NaN thresholds to bin 0.
    ///
    /// Thresholds in `(0, 1]` are sketch-backed: every bin strictly
    /// below the threshold's bin is counted from the merged histogram;
    /// only the boundary bin compares actual cached estimates. Exact —
    /// `⌊64·t⌋` and the bin partition use the same exact f64 products
    /// (`shard::threshold_bin`), so a value `v < t` can never sit in a
    /// bin above the boundary bin, nor `v ≥ t` below it.
    pub fn count_below(&self, threshold: f64) -> usize {
        if threshold.is_nan() || threshold <= 0.0 {
            // Strictly-below-t is empty for t ≤ 0 (estimates are
            // ≥ 0) and for NaN (no value compares below it); skip the
            // sketch merge entirely.
            return 0;
        }
        let sketch = self.merged_sketch();
        if sketch.live == 0 {
            return 0;
        }
        if threshold > 1.0 {
            // Every estimate is ≤ 1 < t (covers +∞).
            return sketch.live;
        }
        let boundary = threshold_bin(threshold);
        let whole_bins = sketch.count_before(boundary) as usize;
        if sketch.bins[boundary] == 0 {
            // Empty boundary bin: the refinement is provably 0, skip
            // the per-shard dispatch entirely (the common case for a
            // round SLO threshold on a healthy fleet).
            return whole_bins;
        }
        let refined: usize = self
            .executor
            .map_shards(&self.core, CountBelowBinWork { bin: boundary as u8, threshold })
            .into_iter()
            .sum();
        whole_bins + refined
    }

    /// Histogram of the per-stream windowed AUCs over `[0, 1]` in
    /// `bins` equal-width buckets (AUC 1.0 lands in the last).
    ///
    /// # Panics
    ///
    /// Panics if `bins == 0` — a zero-bin histogram has no shape, and
    /// silently clamping it to one catch-all bucket gave a malformed
    /// request a shape-surprising answer. Matches
    /// [`AucFleet::score_histogram`]; the CLI and the serving layer
    /// validate at their own boundaries and return an error instead.
    ///
    /// When `bins` divides the sketch resolution (1, 2, 4, …, 64 —
    /// all powers of two, so both partitions use exact products and
    /// group-summing sketch bins is bit-identical to direct binning)
    /// the answer is a pure `O(shards·bins)` sketch merge with no
    /// stream visit at all. Other bin counts fall back to a
    /// cached-stat rebin (`O(streams)`, no estimator work). Either
    /// way, partials are summed bin-wise, so the result is
    /// strategy-independent.
    pub fn auc_histogram(&self, bins: usize) -> AucHistogram {
        assert!(bins >= 1, "auc_histogram: bins must be >= 1");
        if bins <= SKETCH_BINS && SKETCH_BINS % bins == 0 {
            let sketch = self.merged_sketch();
            let group = SKETCH_BINS / bins;
            let mut counts = vec![0usize; bins];
            for (b, &c) in sketch.bins.iter().enumerate() {
                counts[b / group] += c as usize;
            }
            return AucHistogram { counts, live_streams: sketch.live };
        }
        self.wait_inflight();
        let mut counts = vec![0usize; bins];
        let mut live_streams = 0usize;
        for (partial, live) in self.executor.map_shards(&self.core, HistogramWork { bins }) {
            for (bin, c) in counts.iter_mut().zip(partial) {
                *bin += c;
            }
            live_streams += live;
        }
        AucHistogram { counts, live_streams }
    }

    /// Histogram of the raw window-entry scores over `[0, 1]` in
    /// `bins` equal-width cells (out-of-range scores clamp into the
    /// edge cells) — the input-distribution view that pairs with
    /// [`AucFleet::auc_histogram`]'s estimate distribution, e.g. for
    /// spotting score drift before it moves the AUC.
    ///
    /// # Panics
    ///
    /// Panics if `bins == 0`, unified with [`AucFleet::auc_histogram`]
    /// (this query used to clamp to a single catch-all cell while the
    /// CLI validated — a malformed request must error, not surprise).
    ///
    /// Binned streams declared over exactly `[0, 1]` whose cell count
    /// is a multiple of `bins` are answered straight from their count
    /// arrays (`Shard::score_histogram` fast path) — `O(stream_bins)`
    /// per stream instead of `O(k)`; every other stream pays one pass
    /// over its window FIFO. Partials are summed cell-wise, so the
    /// result is strategy-independent.
    pub fn score_histogram(&self, bins: usize) -> ScoreHistogram {
        assert!(bins >= 1, "score_histogram: bins must be >= 1");
        self.wait_inflight();
        let mut counts = vec![0u64; bins];
        let mut entries = 0u64;
        for (partial, n) in self.executor.map_shards(&self.core, ScoreHistogramWork { bins }) {
            for (cell, c) in counts.iter_mut().zip(partial) {
                *cell += c;
            }
            entries += n;
        }
        ScoreHistogram { counts, entries }
    }

    /// Snapshots of every stream matching `pred`, sorted by stream id.
    /// The predicate sees the same [`StreamSnapshot`] that
    /// [`AucFleet::snapshot`] reports and must be pure (it may run
    /// concurrently on several shards and its per-shard evaluation
    /// order is unspecified). `'static` because the predicate is moved
    /// into the job that rides the persistent pool's threads.
    pub fn select_streams<P>(&self, pred: P) -> Vec<StreamSnapshot>
    where
        P: Fn(&StreamSnapshot) -> bool + Send + Sync + 'static,
    {
        self.wait_inflight();
        let mut hits: Vec<StreamSnapshot> = self
            .executor
            .map_shards(&self.core, SelectWork { pred })
            .into_iter()
            .flatten()
            .collect();
        hits.sort_by_key(|s| s.stream);
        hits
    }
}

#[cfg(test)]
mod tests {
    use super::super::{FleetConfig, StreamConfig};
    use super::*;

    fn demo_fleet(workers: usize) -> AucFleet {
        let mut fleet = AucFleet::new(FleetConfig {
            shards: 8,
            workers,
            stream_defaults: StreamConfig::new(10, 0.0).without_monitor(),
            ..FleetConfig::default()
        });
        // AUCs: stream 1 → 1.0, stream 2 → 0.0, stream 3 → 0.5
        // (single class), stream 4 → 1.0.
        for _ in 0..5 {
            fleet.push(1, 0.2, true);
            fleet.push(1, 0.8, false);
            fleet.push(2, 0.8, true);
            fleet.push(2, 0.2, false);
            fleet.push(3, 0.5, true);
            fleet.push(4, 0.1, true);
            fleet.push(4, 0.9, false);
        }
        fleet
    }

    #[test]
    fn top_k_worst_ranks_and_breaks_ties_by_id() {
        for workers in [1usize, 4] {
            let fleet = demo_fleet(workers);
            let worst: Vec<(u64, f64)> =
                fleet.top_k_worst(3).into_iter().map(|s| (s.stream, s.auc)).collect();
            assert_eq!(worst, vec![(2, 0.0), (3, 0.5), (1, 1.0)], "workers = {workers}");
            // Tie at AUC 1.0 between streams 1 and 4: id breaks it.
            let all: Vec<u64> = fleet.top_k_worst(10).into_iter().map(|s| s.stream).collect();
            assert_eq!(all, vec![2, 3, 1, 4]);
            assert!(fleet.top_k_worst(0).is_empty());
        }
    }

    #[test]
    fn count_below_is_strict() {
        let fleet = demo_fleet(2);
        assert_eq!(fleet.count_below(0.0), 0);
        assert_eq!(fleet.count_below(0.25), 1); // stream 2
        assert_eq!(fleet.count_below(0.75), 2); // + stream 3
        assert_eq!(fleet.count_below(2.0), 4);
    }

    #[test]
    fn count_below_edge_thresholds_have_explicit_semantics() {
        let fleet = demo_fleet(2);
        // t ≤ 0 (estimates are ≥ 0) and NaN count nothing.
        assert_eq!(fleet.count_below(-1.0), 0);
        assert_eq!(fleet.count_below(f64::NEG_INFINITY), 0);
        assert_eq!(fleet.count_below(f64::NAN), 0);
        // t = 1 is strict: the two AUC-1.0 streams are not below it.
        assert_eq!(fleet.count_below(1.0), 2);
        // t > 1 (including +∞) counts every live stream.
        assert_eq!(fleet.count_below(1.0 + f64::EPSILON), 4);
        assert_eq!(fleet.count_below(f64::INFINITY), 4);
        // An empty fleet answers 0 for every threshold.
        let empty = AucFleet::with_defaults();
        for t in [f64::NAN, -1.0, 0.0, 0.5, 1.0, 2.0, f64::INFINITY] {
            assert_eq!(empty.count_below(t), 0, "threshold {t}");
        }
    }

    #[test]
    fn count_below_matches_the_snapshot_rescan_for_every_threshold() {
        use crate::testing::Pcg;
        // Regression for the boundary-bin cast: sweep thresholds across
        // and beyond [0, 1] — including exact bin edges, which is where
        // `as usize` truncation and the strict comparison can disagree
        // — over a seeded mixed-estimator fleet, against the
        // O(streams) rescan answer derived from the same snapshot the
        // rescan aggregate uses.
        for workers in [1usize, 4] {
            let mut fleet = AucFleet::new(FleetConfig {
                shards: 8,
                workers,
                stream_defaults: StreamConfig::new(32, 0.1).without_monitor(),
                ..FleetConfig::default()
            });
            fleet.configure_stream(3, StreamConfig::exact(32).without_monitor());
            fleet.configure_stream(5, StreamConfig::binned(32, 64, 0.0, 1.0).without_monitor());
            let mut rng = Pcg::seed(0xC0B3);
            for _ in 0..900 {
                let id = rng.below(24);
                fleet.push(id, rng.uniform(), rng.chance(0.5));
            }
            let snap = fleet.snapshot();
            let rescan =
                |t: f64| snap.streams.iter().filter(|s| s.len > 0 && s.auc < t).count();
            let mut thresholds = vec![
                f64::NEG_INFINITY,
                -0.5,
                0.0,
                1.0,
                1.5,
                f64::INFINITY,
            ];
            for i in 0..=64 {
                thresholds.push(i as f64 / 64.0); // every sketch-bin edge
            }
            for i in 0..50 {
                thresholds.push(0.02 * i as f64 + 0.013);
            }
            for t in thresholds {
                assert_eq!(fleet.count_below(t), rescan(t), "workers {workers}, t = {t}");
            }
            assert_eq!(fleet.count_below(f64::NAN), 0);
        }
    }

    #[test]
    fn histogram_bins_cover_the_unit_interval() {
        let fleet = demo_fleet(4);
        let hist = fleet.auc_histogram(4);
        assert_eq!(hist.bins(), 4);
        assert_eq!(hist.live_streams, 4);
        // 0.0 → bin 0; 0.5 → bin 2; two 1.0s → last bin.
        assert_eq!(hist.counts, vec![1, 0, 1, 2]);
        assert_eq!(hist.counts.iter().sum::<usize>(), hist.live_streams);
        assert_eq!(hist.bin_range(0), (0.0, 0.25));
        assert!((hist.fraction(3) - 0.5).abs() < 1e-12);
    }

    #[test]
    #[should_panic(expected = "auc_histogram: bins must be >= 1")]
    fn auc_histogram_rejects_zero_bins() {
        demo_fleet(1).auc_histogram(0);
    }

    #[test]
    #[should_panic(expected = "score_histogram: bins must be >= 1")]
    fn score_histogram_rejects_zero_bins() {
        demo_fleet(1).score_histogram(0);
    }

    #[test]
    fn histogram_of_empty_fleet_is_zero() {
        let fleet = AucFleet::with_defaults();
        let hist = fleet.auc_histogram(5);
        assert_eq!(hist.counts, vec![0; 5]);
        assert_eq!(hist.live_streams, 0);
        assert_eq!(hist.fraction(0), 0.0);
    }

    #[test]
    fn score_histogram_counts_window_entries() {
        let fleet = demo_fleet(2);
        let h = fleet.score_histogram(4);
        // Entries: 0.2/0.8 ×5 (stream 1), 0.8/0.2 ×5 (2), 0.5 ×5 (3),
        // 0.1/0.9 ×5 (4) — 35 total.
        assert_eq!(h.entries, 35);
        assert_eq!(h.counts, vec![15, 0, 5, 15]);
        assert_eq!(h.bins(), 4);
        assert!((h.fraction(2) - 5.0 / 35.0).abs() < 1e-12);
        let empty = AucFleet::with_defaults();
        assert_eq!(empty.score_histogram(3).counts, vec![0; 3]);
        assert_eq!(empty.score_histogram(3).fraction(0), 0.0);
    }

    #[test]
    fn score_histogram_binned_fast_path_matches_the_rescan() {
        use crate::testing::Pcg;
        // Binned defaults (32 cells over [0,1]) take the count-array
        // group-sum; two overridden streams (approx, exact) take the
        // FIFO rescan. Query cells 8 divide 32 and everything is a
        // power of two, so the fast path must equal the raw rescan
        // bit-for-bit — computed here independently from `entries()`.
        for workers in [1usize, 4] {
            let mut fleet = AucFleet::new(FleetConfig {
                shards: 8,
                workers,
                stream_defaults: StreamConfig::binned(16, 32, 0.0, 1.0).without_monitor(),
                ..FleetConfig::default()
            });
            fleet.configure_stream(3, StreamConfig::new(16, 0.1).without_monitor());
            fleet.configure_stream(4, StreamConfig::exact(16).without_monitor());
            let mut rng = Pcg::seed(0x5C0E);
            for _ in 0..400 {
                let id = rng.below(8);
                fleet.push(id, rng.uniform(), rng.chance(0.5));
            }
            let bins = 8;
            let h = fleet.score_histogram(bins);
            let mut expect = vec![0u64; bins];
            let mut entries = 0u64;
            for id in 0..8 {
                for (score, _) in fleet.entries(id).into_iter().flatten() {
                    expect[((score * bins as f64) as usize).min(bins - 1)] += 1;
                    entries += 1;
                }
            }
            assert!(entries > 0);
            assert_eq!(h.counts, expect, "workers = {workers}");
            assert_eq!(h.entries, entries);
            // A cell count not dividing 32 forces the rescan for every
            // stream; totals must still reconcile.
            let h5 = fleet.score_histogram(5);
            assert_eq!(h5.counts.iter().sum::<u64>(), entries);
        }
    }

    #[test]
    fn select_streams_filters_and_sorts_by_id() {
        let fleet = demo_fleet(4);
        let perfect: Vec<u64> =
            fleet.select_streams(|s| s.auc >= 1.0).into_iter().map(|s| s.stream).collect();
        assert_eq!(perfect, vec![1, 4]);
        assert!(fleet.select_streams(|_| false).is_empty());
        assert_eq!(fleet.select_streams(|_| true).len(), 4);
    }
}
