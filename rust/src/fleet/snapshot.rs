//! Fleet observability types: per-stream snapshots and alarm records.

/// One monitor alarm raised during ingestion (drained or read via
/// [`AucFleet::alarms`](super::AucFleet::alarms)).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FleetAlarm {
    /// Stream that degraded.
    pub stream: u64,
    /// Stream-local event count at which the alarm fired (1-based).
    pub stream_event: u64,
    /// Windowed AUC estimate at the alarm.
    pub auc: f64,
    /// Monitor baseline at the alarm.
    pub baseline: f64,
}

/// Point-in-time state of one stream.
#[derive(Clone, Debug)]
pub struct StreamSnapshot {
    /// Stream id.
    pub stream: u64,
    /// Current windowed AUC estimate.
    pub auc: f64,
    /// Pairs currently in the window (≤ configured capacity).
    pub len: usize,
    /// Compressed-list size `|C|` (sentinels included).
    pub compressed_len: usize,
    /// Stream-local events ingested so far.
    pub events: u64,
    /// Alarms raised over the stream's lifetime.
    pub alarms: u32,
    /// True while the stream's monitor is inside an alarmed excursion.
    pub alarmed: bool,
    /// Monitor baseline (`None` when monitoring is disabled).
    pub baseline: Option<f64>,
}

/// Point-in-time state of the whole fleet
/// ([`AucFleet::snapshot`](super::AucFleet::snapshot)).
#[derive(Clone, Debug, Default)]
pub struct FleetSnapshot {
    /// All streams, sorted by stream id.
    pub streams: Vec<StreamSnapshot>,
    /// Ids of streams currently inside an alarmed excursion (same order
    /// as [`FleetSnapshot::streams`]).
    pub alarmed_streams: Vec<u64>,
    /// Total events ingested across the fleet.
    pub total_events: u64,
}

impl FleetSnapshot {
    /// Streams sorted by ascending AUC (worst first) — the triage view.
    pub fn worst_streams(&self, n: usize) -> Vec<&StreamSnapshot> {
        let mut refs: Vec<&StreamSnapshot> = self.streams.iter().collect();
        refs.sort_by(|a, b| a.auc.total_cmp(&b.auc));
        refs.truncate(n);
        refs
    }

    /// Mean AUC across streams with a non-empty window (0.5 if none).
    pub fn mean_auc(&self) -> f64 {
        let live: Vec<f64> =
            self.streams.iter().filter(|s| s.len > 0).map(|s| s.auc).collect();
        if live.is_empty() {
            0.5
        } else {
            live.iter().sum::<f64>() / live.len() as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn snap(stream: u64, auc: f64, len: usize) -> StreamSnapshot {
        StreamSnapshot {
            stream,
            auc,
            len,
            compressed_len: 2,
            events: len as u64,
            alarms: 0,
            alarmed: false,
            baseline: None,
        }
    }

    #[test]
    fn worst_streams_sorts_ascending() {
        let s = FleetSnapshot {
            streams: vec![snap(1, 0.9, 5), snap(2, 0.4, 5), snap(3, 0.7, 5)],
            alarmed_streams: Vec::new(),
            total_events: 15,
        };
        let worst: Vec<u64> = s.worst_streams(2).iter().map(|x| x.stream).collect();
        assert_eq!(worst, vec![2, 3]);
    }

    #[test]
    fn mean_auc_skips_empty_windows() {
        let s = FleetSnapshot {
            streams: vec![snap(1, 1.0, 4), snap(2, 0.5, 0)],
            alarmed_streams: Vec::new(),
            total_events: 4,
        };
        assert_eq!(s.mean_auc(), 1.0);
        assert_eq!(FleetSnapshot::default().mean_auc(), 0.5);
    }
}
