//! Structural property suite: data-structure invariants checked after
//! **every** operation of random op sequences.
//!
//! * [`RbTree`]: red-black shape (root black, no red-red edge, equal
//!   black heights), BST order, parent pointers, and the augmented
//!   subtree sums — all via `RbTree::check_invariants`, which
//!   recomputes every node's augmentation from its children and
//!   panics on mismatch. Cross-checked against a `BTreeMap` model.
//! * [`SupportTree`] (§3): `T`/`TP`/`P` coherence, sentinel placement,
//!   gap counters vs brute-force `HeadStats` differences.
//! * [`ApproxAuc`] (§4): the compressed-list invariants — coverage,
//!   score order, cell-cache coherence, and the Eq. 3 / Eq. 4
//!   group-size bounds (`hp(w) ≤ α·(hp(v) + p(v))` for consecutive
//!   cells; strict violation for cell *pairs*, which is what keeps
//!   `|C| ∈ O((log k)/ε)`).
//! * [`MaintainedExactAuc`]: tree shape, stored class totals vs a
//!   recount, and the delta-maintained doubled-area accumulator vs the
//!   Eq. 1 scan — all via `MaintainedExactAuc::check_invariants`.
//!
//! * Arena capacity regression: the pooled free lists must not grow
//!   monotonically — `shrink_to_fit` (and the automatic drain-to-empty
//!   hook) returns a churn spike's slot capacity instead of pinning
//!   the peak forever.
//!
//! All sequences come from the seeded harness; failures print a replay
//! seed.

use std::collections::BTreeMap;

use streamauc::collections::{Augment, RbTree, Score};
use streamauc::coordinator::support::SupportTree;
use streamauc::coordinator::{ApproxAuc, AucEstimator, MaintainedExactAuc};
use streamauc::testing::{check, gen_ops, Op};

/// Subtree (count, value-sum) augmentation — the same shape as the
/// estimator's `accpos`/`accneg`, verifiable against a flat model.
#[derive(Clone, Copy, Debug, PartialEq)]
struct CountSum {
    count: u64,
    sum: u64,
}

impl Augment<u64> for CountSum {
    fn recompute(val: &u64, left: Option<&Self>, right: Option<&Self>) -> Self {
        let l = left.copied().unwrap_or(CountSum { count: 0, sum: 0 });
        let r = right.copied().unwrap_or(CountSum { count: 0, sum: 0 });
        CountSum { count: 1 + l.count + r.count, sum: val + l.sum + r.sum }
    }
}

#[test]
fn rbtree_invariants_hold_after_every_op() {
    check(0x4B7EE, 60, |rng| {
        let mut tree: RbTree<u64, CountSum> = RbTree::new();
        let mut model: BTreeMap<i64, u64> = BTreeMap::new();
        let key_space = 4 + rng.below(60);
        let ops = 150 + rng.below(100);
        for step in 0..ops {
            let key = rng.below(key_space) as i64 - (key_space / 2) as i64;
            let ks = Score(key as f64);
            match rng.below(3) {
                0 | 1 => {
                    let v = rng.below(100);
                    let (id, fresh) = tree.insert(ks, || v);
                    if !fresh {
                        tree.with_val_mut(id, |old| *old = v);
                    }
                    model.insert(key, v);
                }
                _ => {
                    if let Some(id) = tree.find(ks) {
                        tree.remove(id);
                        model.remove(&key);
                    }
                }
            }
            // Every red-black + BST + augmentation invariant, every op.
            tree.check_invariants();
            assert_eq!(tree.len(), model.len(), "len diverged at step {step}");
            // Augmented subtree counts and sums against the model.
            let (count, sum) = tree
                .root()
                .map_or((0, 0), |r| (tree.aug(r).count, tree.aug(r).sum));
            assert_eq!(count as usize, model.len(), "aug count at step {step}");
            assert_eq!(sum, model.values().sum::<u64>(), "aug sum at step {step}");
            // Order queries agree with the model.
            let probe = Score((rng.below(key_space) as i64 - (key_space / 2) as i64) as f64);
            let got = tree.floor(probe).map(|id| tree.key(id).0 as i64);
            let want = model.range(..=(probe.0 as i64)).next_back().map(|(k, _)| *k);
            assert_eq!(got, want, "floor({}) diverged at step {step}", probe.0);
        }
        // Drain in model order; invariants must survive every removal.
        let keys: Vec<i64> = model.keys().copied().collect();
        for key in keys {
            let id = tree.find(Score(key as f64)).expect("model key present");
            tree.remove(id);
            tree.check_invariants();
        }
        assert!(tree.is_empty());
    });
}

#[test]
fn support_tree_invariants_hold_after_every_op() {
    for grid in [Some(6), Some(24), None] {
        check(0x5077 ^ grid.unwrap_or(99), 30, |rng| {
            let mut t = SupportTree::new();
            let ops = gen_ops(rng, 180, 45, grid);
            for op in ops {
                match op {
                    Op::Insert { score, pos: true } => {
                        t.add_pos(Score(score));
                    }
                    Op::Insert { score, pos: false } => {
                        t.add_neg(Score(score));
                    }
                    Op::Remove { score, pos: true } => t.remove_pos(Score(score)),
                    Op::Remove { score, pos: false } => t.remove_neg(Score(score)),
                }
                t.check_invariants();
            }
        });
    }
}

#[test]
fn compressed_list_eq3_eq4_hold_after_every_op() {
    // `ApproxAuc::check_invariants` asserts, besides cache coherence
    // and coverage, exactly the paper's Eqs. 3–4 on C; ε = 0 pins the
    // degenerate exact mode, large ε the aggressive-merging mode.
    for eps in [0.0, 0.05, 0.3, 1.0] {
        for grid in [Some(5), Some(32), None] {
            check(
                0xC3_0000 ^ (eps * 1e3) as u64 ^ grid.unwrap_or(7),
                25,
                |rng| {
                    let mut approx = ApproxAuc::new(eps);
                    let ops = gen_ops(rng, 160, 40, grid);
                    for op in ops {
                        match op {
                            Op::Insert { score, pos } => approx.insert(score, pos),
                            Op::Remove { score, pos } => approx.remove(score, pos),
                        }
                        approx.check_invariants();
                    }
                    approx.check_invariants();
                },
            );
        }
    }
}

#[test]
fn maintained_exact_invariants_hold_after_every_op() {
    // `check_invariants` re-verifies the rbtree shape, recounts the
    // class totals from the tree and recomputes the doubled-area
    // accumulator with the Eq. 1 scan — so a single delta formula
    // applied with the wrong pre-mutation ordering trips here at the
    // exact op that broke it.
    for grid in [Some(6), Some(24), None] {
        check(0x3A17_5077 ^ grid.unwrap_or(99), 30, |rng| {
            let mut m = MaintainedExactAuc::new();
            for op in gen_ops(rng, 180, 45, grid) {
                match op {
                    Op::Insert { score, pos } => m.insert(score, pos),
                    Op::Remove { score, pos } => m.remove(score, pos),
                }
                m.check_invariants();
            }
        });
    }
}

#[test]
fn arena_capacity_sheds_after_a_churn_spike() {
    // The estimators' arenas recycle freed slots but never release
    // them on their own; `shrink_to_fit` is the explicit trim, and
    // draining to empty trims automatically. A spike of 2000 entries
    // followed by a LIFO drain to a small residue frees the slab tails
    // (tree nodes never move slots, so last-inserted sits last), which
    // is exactly what the trim must give back.
    check(0x5EED_CA9, 10, |rng| {
        let mut approx = ApproxAuc::new(0.1);
        let mut maintained = MaintainedExactAuc::new();
        let mut window: Vec<(f64, bool)> = Vec::new();
        for _ in 0..2000 {
            let (s, l) = (rng.uniform(), rng.chance(0.5));
            approx.insert(s, l);
            maintained.insert(s, l);
            window.push((s, l));
        }
        let (peak_a, peak_m) = (approx.capacity(), maintained.capacity());
        while window.len() > 16 {
            let (s, l) = window.pop().unwrap();
            approx.remove(s, l);
            maintained.remove(s, l);
        }
        // Freed slots are retained for reuse until explicitly trimmed…
        approx.shrink_to_fit();
        maintained.shrink_to_fit();
        assert!(
            approx.capacity() < peak_a / 4,
            "approx capacity {} did not shed from peak {peak_a}",
            approx.capacity()
        );
        assert!(
            maintained.capacity() < peak_m / 4,
            "maintained capacity {} did not shed from peak {peak_m}",
            maintained.capacity()
        );
        approx.check_invariants();
        maintained.check_invariants();
        // …and draining to empty trims to nothing without being asked.
        while let Some((s, l)) = window.pop() {
            approx.remove(s, l);
            maintained.remove(s, l);
        }
        assert_eq!(approx.capacity(), 0, "drained approx must release all slots");
        assert_eq!(maintained.capacity(), 0, "drained maintained must release all slots");
    });
}

#[test]
fn compressed_list_stays_logarithmic_under_churn() {
    // Eq. 4's purpose (Proposition 2): |C| ∈ O((log k)/ε). FIFO churn
    // at k = 2000 must keep |C| far below the positive count.
    check(0x10C7, 8, |rng| {
        let eps = 0.1;
        let mut approx = ApproxAuc::new(eps);
        let mut fifo: std::collections::VecDeque<(f64, bool)> = Default::default();
        let k = 2000;
        for _ in 0..3 * k {
            let s = rng.uniform();
            let l = rng.chance(0.5);
            approx.insert(s, l);
            fifo.push_back((s, l));
            if fifo.len() > k {
                let (os, ol) = fifo.pop_front().unwrap();
                approx.remove(os, ol);
            }
        }
        let bound = ((k as f64).log2() / eps) as usize; // ≈ 110
        assert!(
            approx.compressed_len() < bound,
            "|C| = {} exceeds the O(log k/ε) ballpark {bound}",
            approx.compressed_len()
        );
        approx.check_invariants();
    });
}
