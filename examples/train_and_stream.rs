//! End-to-end driver over all three layers (the EXPERIMENTS.md §E2E run).
//!
//! ```sh
//! make artifacts && cargo run --release --example train_and_stream
//! ```
//!
//! 1. **L1/L2 (build time, already done by `make artifacts`)**: the
//!    JAX logistic-regression model with its Pallas scoring/gradient
//!    kernels was AOT-lowered to HLO text.
//! 2. **Runtime**: rust loads `train_step.hlo.txt` into PJRT and runs
//!    the full SGD loop — Python is not involved.
//! 3. **Scoring**: the trained parameters drive `score_batch.hlo.txt`
//!    over a held-out miniboone-like stream.
//! 4. **L3**: the scored stream feeds the paper's estimator; approximate
//!    and exact sliding-window AUC run side by side, reporting the
//!    relative error and the per-update speed-up.

use std::time::Instant;

use streamauc::coordinator::window::Window;
use streamauc::coordinator::{ApproxAuc, ExactAuc, NaiveAuc};
use streamauc::runtime::{Runtime, Scorer, Trainer};
use streamauc::stream::synth::{miniboone_like, Dataset};

const TRAIN_EXAMPLES: usize = 20_000;
const TRAIN_STEPS: usize = 300;
const TEST_EVENTS: usize = 100_000;
const WINDOW: usize = 1000;
const EPSILON: f64 = 0.01;

fn main() -> anyhow::Result<()> {
    // ---- Layer 2/1 artifacts into the PJRT runtime -------------------
    let rt = Runtime::new(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"))?;
    println!("PJRT platform: {}; contract {:?}", rt.platform(), rt.meta());

    // ---- Train through the AOT train_step ----------------------------
    let mut data = Dataset::new(miniboone_like(), 0xE2E);
    let train = data.examples(TRAIN_EXAMPLES);
    let trainer = Trainer::new(&rt, 0.5)?;
    let t0 = Instant::now();
    let report = trainer.train(&train, TRAIN_STEPS)?;
    println!(
        "trained {TRAIN_STEPS} steps × {} batch in {:.2?}: loss {:.4} → {:.4}",
        trainer.batch_size(),
        t0.elapsed(),
        report.early_loss(10),
        report.late_loss(10),
    );
    assert!(report.late_loss(10) < report.early_loss(10) * 0.8, "training failed to converge");

    // ---- Score the held-out stream ------------------------------------
    let test = data.examples(TEST_EVENTS);
    let scorer = Scorer::new(&rt, report.params)?;
    let rows: Vec<Vec<f32>> = test.iter().map(|e| e.features.clone()).collect();
    let t1 = Instant::now();
    let scores = scorer.score(&rows)?;
    let score_elapsed = t1.elapsed();
    let pairs: Vec<(f64, bool)> = scores.iter().zip(&test).map(|(&s, e)| (s, e.label)).collect();
    println!(
        "scored {TEST_EVENTS} events in {:.2?} ({:.0} events/s); stream AUC {:.4}",
        score_elapsed,
        TEST_EVENTS as f64 / score_elapsed.as_secs_f64(),
        NaiveAuc::of(&pairs)
    );

    // ---- Sliding-window estimation: approx vs exact -------------------
    let run = |label: &str, timed: &mut dyn FnMut() -> f64| {
        let t = Instant::now();
        let auc = timed();
        let d = t.elapsed();
        println!(
            "{label:<22} {:.2?} total, {:>7.0} ns/event, final auc {auc:.4}",
            d,
            d.as_nanos() as f64 / TEST_EVENTS as f64
        );
        d
    };

    let mut approx = Window::with_estimator(WINDOW, ApproxAuc::new(EPSILON));
    let approx_time = run(&format!("approx (ε={EPSILON})"), &mut || {
        let mut sink = 0.0;
        for &(s, l) in &pairs {
            approx.push(s, l);
            sink = approx.auc();
        }
        sink
    });

    let mut exact = Window::with_estimator(WINDOW, ExactAuc::new());
    let exact_time = run("exact baseline", &mut || {
        let mut sink = 0.0;
        for &(s, l) in &pairs {
            exact.push(s, l);
            sink = exact.auc();
        }
        sink
    });

    // ---- Verify the paper's claims on this run ------------------------
    let (a, e) = (approx.auc(), exact.auc());
    let rel = (a - e).abs() / e;
    let speedup = exact_time.as_secs_f64() / approx_time.as_secs_f64();
    println!("\nrelative error {rel:.2e} (guarantee {:.2e})", EPSILON / 2.0);
    println!("speed-up over exact recomputation at k={WINDOW}: {speedup:.1}×");
    println!("compressed list |C| = {}", approx.estimator().compressed_len());
    assert!(rel <= EPSILON / 2.0, "guarantee violated");
    assert!(speedup > 2.0, "speed-up {speedup:.1} too small at k={WINDOW}");
    println!("\nE2E OK: three layers composed, guarantee held, speed-up realized.");
    Ok(())
}
