//! Typed execution helpers over a compiled PJRT executable.
//!
//! The AOT entry points are lowered with `return_tuple=True`, so every
//! run returns one tuple literal; [`Executable::run`] unpacks it into
//! its member literals and [`Executable::run_f32`] further converts to
//! host `Vec<f32>`s — the only dtype the shape contract uses.

use anyhow::{Context, Result};

/// A compiled artifact plus its origin (for error messages).
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    origin: String,
}

impl Executable {
    pub(crate) fn new(exe: xla::PjRtLoadedExecutable, origin: String) -> Self {
        Executable { exe, origin }
    }

    /// Execute with literal inputs; returns the members of the result
    /// tuple.
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let result = self
            .exe
            .execute::<xla::Literal>(inputs)
            .with_context(|| format!("execute {}", self.origin))?;
        let literal = result[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetch result of {}", self.origin))?;
        let parts = literal
            .to_tuple()
            .with_context(|| format!("untuple result of {}", self.origin))?;
        Ok(parts)
    }

    /// Execute and convert every result-tuple member to a host
    /// `Vec<f32>` (scalars become length-1 vectors).
    pub fn run_f32(&self, inputs: &[xla::Literal]) -> Result<Vec<Vec<f32>>> {
        self.run(inputs)?
            .into_iter()
            .enumerate()
            .map(|(i, lit)| {
                lit.to_vec::<f32>()
                    .with_context(|| format!("result {i} of {} as f32", self.origin))
            })
            .collect()
    }
}

impl std::fmt::Debug for Executable {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Executable").field("origin", &self.origin).finish_non_exhaustive()
    }
}

/// Build the `(batch, dims)` feature literal, zero-padding each row to
/// `dims` and the batch to `batch` rows.
///
/// The model is lowered at a fixed feature width (`meta.dims`); dataset
/// rows may be narrower (hepmass 28, miniboone 50, tvads 124). Zero
/// padding is exact for a linear model: padded coordinates contribute
/// nothing to `x·w` and their trained weights stay 0.
pub fn features_literal(rows: &[Vec<f32>], batch: usize, dims: usize) -> Result<xla::Literal> {
    anyhow::ensure!(rows.len() <= batch, "batch overflow: {} > {batch}", rows.len());
    let mut flat = vec![0f32; batch * dims];
    for (i, row) in rows.iter().enumerate() {
        anyhow::ensure!(row.len() <= dims, "feature row wider than model: {} > {dims}", row.len());
        flat[i * dims..i * dims + row.len()].copy_from_slice(row);
    }
    Ok(xla::Literal::vec1(&flat).reshape(&[batch as i64, dims as i64])?)
}

/// Build the `(batch,)` label literal (0/1 as f32), zero-padded.
pub fn labels_literal(labels: &[bool], batch: usize) -> Result<xla::Literal> {
    anyhow::ensure!(labels.len() <= batch, "batch overflow");
    let mut flat = vec![0f32; batch];
    for (i, &l) in labels.iter().enumerate() {
        flat[i] = f32::from(u8::from(l));
    }
    Ok(xla::Literal::vec1(&flat))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn features_pad_rows_and_batch() {
        let rows = vec![vec![1.0, 2.0], vec![3.0]];
        let lit = features_literal(&rows, 3, 4).unwrap();
        let v = lit.to_vec::<f32>().unwrap();
        assert_eq!(
            v,
            vec![1.0, 2.0, 0.0, 0.0, 3.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0]
        );
    }

    #[test]
    fn features_reject_overflow() {
        assert!(features_literal(&[vec![0.0; 5]], 1, 4).is_err());
        assert!(features_literal(&vec![Vec::new(); 3], 2, 4).is_err());
    }

    #[test]
    fn labels_encode_and_pad() {
        let lit = labels_literal(&[true, false, true], 5).unwrap();
        assert_eq!(lit.to_vec::<f32>().unwrap(), vec![1.0, 0.0, 1.0, 0.0, 0.0]);
    }
}
