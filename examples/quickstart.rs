//! Quickstart: maintain an approximate AUC over a sliding window.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```
//!
//! Streams 100 000 synthetic scored events through a window of k = 1000
//! with ε = 0.01, printing the estimate, the exact value and the
//! compressed-list size every 10 000 events — the paper's headline
//! behaviour in a dozen lines of user code.

use streamauc::coordinator::SlidingAuc;
use streamauc::stream::synth::{miniboone_like, Dataset};

fn main() {
    let mut window = SlidingAuc::new(1000, 0.01);
    let mut data = Dataset::new(miniboone_like(), 42);

    println!("{:>8}  {:>9}  {:>9}  {:>9}  {:>5}", "event", "approx", "exact", "rel_err", "|C|");
    for i in 1..=100_000 {
        let (score, label) = {
            let ex = data.example();
            (data.analytic_score(&ex), ex.label)
        };
        window.push(score, label);
        if i % 10_000 == 0 {
            let approx = window.auc();
            let exact = window.exact_auc();
            println!(
                "{i:>8}  {approx:>9.5}  {exact:>9.5}  {:>9.2e}  {:>5}",
                (approx - exact).abs() / exact,
                window.compressed_len()
            );
        }
    }
    println!(
        "\nwindow k = {}, ε = 0.01 ⇒ guaranteed |ãuc − auc| ≤ 0.005·auc",
        window.capacity()
    );
}
