//! Scoped-thread parallel executor over fleet shards.
//!
//! The paper makes one window cheap (`O((log k)/ε)` per update); this
//! module makes *many* windows scale across cores. A [`FleetExecutor`]
//! runs a closure once per shard, either inline (serial path, `workers
//! ≤ 1` — zero thread overhead, the default) or on [`std::thread::scope`]
//! workers, each owning a contiguous chunk of the shard slice. No
//! threadpool crate is available offline (`rust/DESIGN.md`
//! §Offline-deps), and scoped threads need no `'static` bounds or
//! channels: disjoint `&mut Shard` borrows move into the workers and the
//! scope joins them before returning.
//!
//! Determinism: workers never share state, each shard's work depends
//! only on its own inputs, and result collection ([`map_shards`]) is
//! reassembled in shard-index order — so the executor's output is
//! independent of thread scheduling, and parallel ingestion is
//! bit-identical to serial (property-tested in `rust/tests/fleet.rs`).
//!
//! [`map_shards`]: FleetExecutor::map_shards

use super::shard::Shard;

/// Runs per-shard work serially or on scoped worker threads.
#[derive(Clone, Debug)]
pub struct FleetExecutor {
    workers: usize,
}

impl FleetExecutor {
    /// Executor with `workers` threads; `0` and `1` both mean the serial
    /// inline path.
    pub fn new(workers: usize) -> FleetExecutor {
        FleetExecutor { workers: workers.max(1) }
    }

    /// Configured worker count (≥ 1; 1 = serial).
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Run `f(shard_index, &mut shard)` for every shard. With more than
    /// one worker, shards are split into contiguous chunks, one scoped
    /// thread per chunk; the scope joins all workers before returning.
    pub(super) fn for_each_shard<F>(&self, shards: &mut [Shard], f: F)
    where
        F: Fn(usize, &mut Shard) + Sync,
    {
        let workers = self.workers.min(shards.len()).max(1);
        if workers <= 1 {
            for (i, shard) in shards.iter_mut().enumerate() {
                f(i, shard);
            }
            return;
        }
        let chunk = shards.len() / workers + usize::from(shards.len() % workers != 0);
        std::thread::scope(|scope| {
            for (c, slice) in shards.chunks_mut(chunk).enumerate() {
                let f = &f;
                scope.spawn(move || {
                    for (off, shard) in slice.iter_mut().enumerate() {
                        f(c * chunk + off, shard);
                    }
                });
            }
        });
    }

    /// Map `f(shard_index, &shard)` over every shard, returning the
    /// results in shard-index order regardless of which worker computed
    /// them (per-chunk result vectors are concatenated in chunk order).
    pub(super) fn map_shards<T, F>(&self, shards: &[Shard], f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize, &Shard) -> T + Sync,
    {
        let workers = self.workers.min(shards.len()).max(1);
        if workers <= 1 {
            return shards.iter().enumerate().map(|(i, s)| f(i, s)).collect();
        }
        let chunk = shards.len() / workers + usize::from(shards.len() % workers != 0);
        std::thread::scope(|scope| {
            let handles: Vec<_> = shards
                .chunks(chunk)
                .enumerate()
                .map(|(c, slice)| {
                    let f = &f;
                    scope.spawn(move || {
                        slice
                            .iter()
                            .enumerate()
                            .map(|(off, shard)| f(c * chunk + off, shard))
                            .collect::<Vec<T>>()
                    })
                })
                .collect();
            let mut out = Vec::with_capacity(shards.len());
            for h in handles {
                out.extend(h.join().expect("fleet worker panicked"));
            }
            out
        })
    }
}
