//! Binned AUC over a declared bounded score range — no tree, no list.
//!
//! When scores are known to live in a fixed interval `[lo, hi]` (bounded
//! probabilities in `[0, 1]` — the overwhelmingly common production
//! case), the whole §3 supporting structure is overkill: snap each score
//! to one of `bins` equal cells and the window state is just two
//! contiguous `u32` count arrays. A window slide touches two array
//! cells; the Eq. 1 doubled-area total over the cells is maintained
//! delta-wise exactly as in [`super::MaintainedExactAuc`], so the AUC
//! read stays `O(1)`.
//!
//! [`BinnedAuc`] computes the **exact** AUC of the *quantized* multiset:
//! scores are mapped through the monotone cell index
//!
//! ```text
//! bin(s) = min(⌊(s − lo)/(hi − lo) · bins⌋, bins − 1)
//! ```
//!
//! and [`super::auc_terms_doubled`] over the cells counts same-cell
//! cross-class pairs at half weight — the trapezoidal (ties-at-half)
//! treatment within a cell. The delta formulas are the maintained-exact
//! ones (`DESIGN.md` §Estimators), with the `O(log k)` tree descent for
//! the head counts `hp`/`hn` replaced by a prefix pass over the two
//! count arrays: `O(bins)` worst-case, but `bins` is a small constant
//! independent of the window size `k`, the arrays are contiguous `u32`s
//! the compiler auto-vectorizes, and there is no allocation or pointer
//! chasing anywhere — which is what lets the update beat the ε-sketch's
//! `O((log k)/ε)` node walk at production ε (see `benches/core.rs`).
//!
//! **Discretization error.** `bin` is monotone, so a cross-class pair in
//! *different* cells keeps its order and contributes identically to the
//! true AUC; only pairs sharing a cell can differ, and a pair's
//! contribution moves by at most `1/2`. Hence
//!
//! ```text
//! |auc_binned − auc| ≤ Σ_b p_b·n_b / (2·P·N)
//! ```
//!
//! with `p_b`/`n_b` the per-cell class counts — computable from the live
//! state ([`BinnedAuc::error_bound`]) and asserted against the naive
//! oracle by `tests/differential.rs`. Choosing `bins = ⌈2/ε⌉` makes the
//! cell width `(hi − lo)·ε/2`, the resolution matched against the
//! paper's `ε/2` guarantee by the fleet's per-stream auto-selection
//! ([`crate::fleet::StreamConfig::auto`]). When every realized score
//! sits on its own cell boundary (a duplicate grid with `bins` a
//! multiple of the grid), quantization is injective on the realized
//! scores and the estimate is **bit-identical** to the exact oracle.
//!
//! Determinism under the fleet pool is free: the cell index is one fixed
//! monotone float map, counts and the doubled-area accumulator are
//! integers, and per-stream op order is fixed by the shard — no worker
//! interleaving can change a single bit.

use super::{auc_terms_doubled, finish_auc, AucEstimator};

/// Fixed-bin AUC estimator over a declared bounded score range:
/// `O(bins)`-bounded update with `bins` a small `k`-independent
/// constant, `O(1)` read, footprint `2·bins` cells regardless of `k`.
#[derive(Clone, Debug)]
pub struct BinnedAuc {
    lo: f64,
    hi: f64,
    pos: Vec<u32>,
    neg: Vec<u32>,
    /// Running doubled area over the cells: at every op boundary
    /// bit-equal to the retained scan ([`BinnedAuc::doubled_area_scan`]).
    a2: u128,
    total_pos: u64,
    total_neg: u64,
}

impl BinnedAuc {
    /// Empty estimator with `bins` equal cells over `[lo, hi]`.
    ///
    /// # Panics
    ///
    /// On `bins == 0`, non-finite bounds, or `lo >= hi` — the same
    /// validation the fleet config and CLI apply at their boundaries;
    /// kept here too so a hand-built estimator cannot exist in an
    /// unusable state.
    pub fn new(bins: usize, lo: f64, hi: f64) -> Self {
        assert!(bins > 0, "binned estimator: bins must be ≥ 1");
        assert!(
            lo.is_finite() && hi.is_finite(),
            "binned estimator: score range bounds must be finite, got [{lo}, {hi}]"
        );
        assert!(lo < hi, "binned estimator: score range must satisfy lo < hi, got [{lo}, {hi}]");
        BinnedAuc {
            lo,
            hi,
            pos: vec![0; bins],
            neg: vec![0; bins],
            a2: 0,
            total_pos: 0,
            total_neg: 0,
        }
    }

    /// Number of cells.
    pub fn bins(&self) -> usize {
        self.pos.len()
    }

    /// Bytes held by the two count arrays: `2·bins·4`, independent of
    /// the window size `k` and of allocation history — the figure the
    /// fleet's per-stream footprint accounting reports for this
    /// estimator.
    pub fn footprint_bytes(&self) -> usize {
        (self.pos.len() + self.neg.len()) * std::mem::size_of::<u32>()
    }

    /// The declared score range `(lo, hi)`.
    pub fn range(&self) -> (f64, f64) {
        (self.lo, self.hi)
    }

    /// Positive / negative totals (exposed for experiment drivers).
    pub fn class_totals(&self) -> (u64, u64) {
        (self.total_pos, self.total_neg)
    }

    /// Per-cell `(positive, negative)` counts, ascending score order.
    /// The fleet's score-histogram fast path group-sums these directly
    /// instead of rescanning window entries (`fleet/query.rs`).
    pub fn cells(&self) -> impl Iterator<Item = (u32, u32)> + '_ {
        self.pos.iter().zip(&self.neg).map(|(&p, &n)| (p, n))
    }

    /// The running doubled-area accumulator behind the O(1) read.
    /// Exposed for the bit-equality property tests.
    #[inline]
    pub fn doubled_area(&self) -> u128 {
        self.a2
    }

    /// The cell index of `score`: monotone, deterministic, the same map
    /// for insert and remove (the window FIFO retains the raw score, so
    /// eviction re-derives the identical cell).
    #[inline]
    fn bin_of(&self, score: f64) -> usize {
        let t = (score - self.lo) / (self.hi - self.lo);
        ((t * self.pos.len() as f64) as usize).min(self.pos.len() - 1)
    }

    /// The doubled area recomputed by the full Eq. 1 pass over the
    /// cells — `O(bins)`, one run over contiguous memory. Retained as
    /// the reference the running accumulator must equal bit-for-bit
    /// after every operation.
    pub fn doubled_area_scan(&self) -> u128 {
        let groups = self.pos.iter().zip(&self.neg).map(|(&p, &n)| (u64::from(p), u64::from(n)));
        let (a2, pos, neg) = auc_terms_doubled(groups);
        assert_eq!(pos, self.total_pos, "binned: positive total drifted");
        assert_eq!(neg, self.total_neg, "binned: negative total drifted");
        a2
    }

    /// The estimate read via the full cell pass instead of the
    /// accumulator. Bit-identical to [`AucEstimator::auc`]; kept as the
    /// reference/benchmark read path.
    pub fn auc_full_scan(&self) -> f64 {
        finish_auc(self.doubled_area_scan(), self.total_pos, self.total_neg)
    }

    /// The discretization bound derived in the module docs, computed
    /// from the live cell counts: `Σ_b p_b·n_b / (2·P·N)`. Zero when a
    /// class is empty (both the binned and the true estimate are then
    /// pinned at the 0.5 convention). `O(bins)`.
    pub fn error_bound(&self) -> f64 {
        let area = u128::from(self.total_pos) * u128::from(self.total_neg);
        if area == 0 {
            return 0.0;
        }
        let same: u128 =
            self.pos.iter().zip(&self.neg).map(|(&p, &n)| u128::from(p) * u128::from(n)).sum();
        (same as f64) / (2.0 * area as f64)
    }

    fn update(&mut self, score: f64, pos: bool, add: bool) {
        // Reject before any state is touched (NaN fails the comparison
        // too), mirroring the finite-score check in `Window::push`: a
        // caught panic leaves the estimator exactly as it was.
        assert!(
            score >= self.lo && score <= self.hi,
            "binned estimator: score {score} outside declared range [{}, {}]",
            self.lo,
            self.hi
        );
        let b = self.bin_of(score);
        // Everything the delta needs is read before the counts mutate:
        // one prefix pass per class over contiguous u32 cells.
        let hp: u64 = self.pos[..b].iter().copied().map(u64::from).sum();
        let hn: u64 = self.neg[..b].iter().copied().map(u64::from).sum();
        let (at_p, at_n) = (u64::from(self.pos[b]), u64::from(self.neg[b]));
        let delta = if pos {
            // Same derivation as maintained.rs: 2·(N − hn) − n(s).
            u128::from(2 * (self.total_neg - hn) - at_n)
        } else {
            // 2·hp + p(s).
            u128::from(2 * hp + at_p)
        };
        if add {
            if pos {
                self.pos[b] += 1;
                self.total_pos += 1;
            } else {
                self.neg[b] += 1;
                self.total_neg += 1;
            }
            self.a2 =
                self.a2.checked_add(delta).expect("binned: doubled-area accumulator overflow");
        } else {
            if pos {
                assert!(at_p > 0, "binned remove: no positive in bin {b} (score {score})");
                self.pos[b] -= 1;
                self.total_pos -= 1;
            } else {
                assert!(at_n > 0, "binned remove: no negative in bin {b} (score {score})");
                self.neg[b] -= 1;
                self.total_neg -= 1;
            }
            self.a2 =
                self.a2.checked_sub(delta).expect("binned: doubled-area accumulator underflow");
        }
    }

    /// Validate the stored class totals and the accumulator's
    /// bit-equality with the Eq. 1 cell pass. Panics on violation
    /// (tests / property harness).
    pub fn check_invariants(&self) {
        let pos: u64 = self.pos.iter().copied().map(u64::from).sum();
        let neg: u64 = self.neg.iter().copied().map(u64::from).sum();
        assert_eq!(pos, self.total_pos, "binned: positive total drifted");
        assert_eq!(neg, self.total_neg, "binned: negative total drifted");
        assert_eq!(
            self.a2,
            self.doubled_area_scan(),
            "binned: incremental a2 drifted from the full scan"
        );
    }
}

impl AucEstimator for BinnedAuc {
    fn insert(&mut self, score: f64, pos: bool) {
        self.update(score, pos, true);
    }

    fn remove(&mut self, score: f64, pos: bool) {
        self.update(score, pos, false);
    }

    /// O(1): the running accumulator over the stored totals — the same
    /// `finish_auc` division every estimator in this crate ends with.
    fn auc(&self) -> f64 {
        finish_auc(self.a2, self.total_pos, self.total_neg)
    }

    fn len(&self) -> usize {
        (self.total_pos + self.total_neg) as usize
    }
}

// Two flat Vec<u32>s and integers — per-stream windows over this
// estimator drain on the fleet executor's worker threads.
const _: () = {
    const fn assert_send<T: Send>() {}
    assert_send::<BinnedAuc>();
};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::NaiveAuc;
    use crate::testing::{check, gen_ops, Op};

    #[test]
    fn matches_naive_bitwise_on_bin_aligned_grids() {
        // Power-of-two grids with bins a multiple of the grid: every
        // realized score i/g is exactly representable, lands exactly on
        // a cell boundary, and distinct scores land in distinct cells —
        // quantization is order- and tie-preserving, so the binned
        // estimate must equal the exact oracle bit-for-bit.
        for (grid, bins) in [(4u64, 4usize), (4, 32), (32, 32), (32, 64)] {
            check(0xB1A5 ^ grid ^ bins as u64, 20, |rng| {
                let mut binned = BinnedAuc::new(bins, 0.0, 1.0);
                let mut naive = NaiveAuc::new();
                for (i, op) in gen_ops(rng, 300, 60, Some(grid)).into_iter().enumerate() {
                    match op {
                        Op::Insert { score, pos } => {
                            binned.insert(score, pos);
                            naive.insert(score, pos);
                        }
                        Op::Remove { score, pos } => {
                            binned.remove(score, pos);
                            naive.remove(score, pos);
                        }
                    }
                    assert_eq!(binned.len(), naive.len());
                    assert_eq!(
                        binned.doubled_area(),
                        binned.doubled_area_scan(),
                        "a2 drifted at op {i}"
                    );
                    let (b, n) = (binned.auc(), naive.auc());
                    assert_eq!(b.to_bits(), n.to_bits(), "op {i}: binned {b} != naive {n}");
                }
                binned.check_invariants();
            });
        }
    }

    #[test]
    fn continuum_error_stays_within_the_derived_bound() {
        check(0xC0117, 20, |rng| {
            let mut binned = BinnedAuc::new(64, 0.0, 1.0);
            let mut naive = NaiveAuc::new();
            for (i, op) in gen_ops(rng, 300, 60, None).into_iter().enumerate() {
                match op {
                    Op::Insert { score, pos } => {
                        binned.insert(score, pos);
                        naive.insert(score, pos);
                    }
                    Op::Remove { score, pos } => {
                        binned.remove(score, pos);
                        naive.remove(score, pos);
                    }
                }
                let (b, n) = (binned.auc(), naive.auc());
                let bound = binned.error_bound();
                assert!(
                    (b - n).abs() <= bound + 1e-12,
                    "op {i}: |{b} − {n}| exceeds derived bound {bound}"
                );
            }
        });
    }

    #[test]
    fn bin_lifecycle() {
        let mut e = BinnedAuc::new(8, 0.0, 1.0);
        e.insert(0.5, true);
        e.insert(0.5, false);
        assert_eq!(e.len(), 2);
        assert_eq!(e.auc(), 0.5);
        e.remove(0.5, true);
        e.remove(0.5, false);
        assert!(e.is_empty());
        assert_eq!(e.auc(), 0.5);
        assert_eq!(e.doubled_area(), 0);
        assert_eq!(e.error_bound(), 0.0);
        e.check_invariants();
    }

    #[test]
    fn perfect_and_reversed_separation_are_exact() {
        let mut e = BinnedAuc::new(16, 0.0, 1.0);
        for _ in 0..50 {
            e.insert(0.1, true);
            e.insert(0.9, false);
        }
        assert_eq!(e.auc(), 1.0);
        assert_eq!(e.error_bound(), 0.0);
        let mut e = BinnedAuc::new(16, 0.0, 1.0);
        for _ in 0..50 {
            e.insert(0.1, false);
            e.insert(0.9, true);
        }
        assert_eq!(e.auc(), 0.0);
        e.check_invariants();
    }

    #[test]
    fn all_ties_is_chance_level() {
        let mut e = BinnedAuc::new(4, 0.0, 1.0);
        for _ in 0..40 {
            e.insert(0.3, true);
            e.insert(0.3, false);
        }
        assert_eq!(e.auc(), 0.5);
        // Everything shares one cell: the bound degenerates to 1/2.
        assert_eq!(e.error_bound(), 0.5);
        e.check_invariants();
    }

    #[test]
    fn range_endpoints_land_in_edge_bins() {
        let mut e = BinnedAuc::new(10, -2.0, 2.0);
        e.insert(-2.0, true); // lo → first cell
        e.insert(2.0, false); // hi → clamped into the last cell
        assert_eq!(e.auc(), 1.0);
        assert_eq!(e.len(), 2);
        e.remove(-2.0, true);
        e.remove(2.0, false);
        e.check_invariants();
    }

    #[test]
    fn rejected_score_leaves_the_estimator_untouched() {
        let mut e = BinnedAuc::new(8, 0.0, 1.0);
        e.insert(0.2, true);
        e.insert(0.8, false);
        let (a2, auc) = (e.doubled_area(), e.auc());
        for bad in [1.5, -0.1, f64::NAN, f64::INFINITY] {
            let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                e.insert(bad, true);
            }));
            assert!(err.is_err(), "score {bad} must be rejected");
        }
        assert_eq!(e.doubled_area(), a2);
        assert_eq!(e.auc().to_bits(), auc.to_bits());
        assert_eq!(e.len(), 2);
        e.insert(0.5, true); // still fully usable
        e.check_invariants();
    }

    #[test]
    #[should_panic(expected = "outside declared range")]
    fn out_of_range_score_panics_with_the_range() {
        let mut e = BinnedAuc::new(8, 0.0, 1.0);
        e.insert(1.5, true);
    }

    #[test]
    #[should_panic(expected = "no positive in bin")]
    fn remove_wrong_label_panics() {
        let mut e = BinnedAuc::new(8, 0.0, 1.0);
        e.insert(0.5, false);
        e.remove(0.5, true);
    }

    #[test]
    #[should_panic(expected = "bins must be ≥ 1")]
    fn zero_bins_rejected() {
        BinnedAuc::new(0, 0.0, 1.0);
    }

    #[test]
    #[should_panic(expected = "lo < hi")]
    fn inverted_range_rejected() {
        BinnedAuc::new(8, 1.0, 0.0);
    }

    #[test]
    #[should_panic(expected = "must be finite")]
    fn non_finite_range_rejected() {
        BinnedAuc::new(8, 0.0, f64::INFINITY);
    }
}
