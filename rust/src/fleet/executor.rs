//! Fleet execution strategies: serial, scoped threads, or the
//! persistent work-stealing pool — one dispatcher for every fleet job.
//!
//! The paper makes one window cheap (`O((log k)/ε)` per update); this
//! module makes *many* windows scale across cores. A [`FleetExecutor`]
//! runs typed fleet jobs (`fleet/pool.rs` `ShardWork`) one of three
//! ways:
//!
//! * **serial** (`workers ≤ 1`, the default) — inline on the caller,
//!   zero thread overhead;
//! * **scoped** (`workers ≥ 2`, pooling off) — a `std::thread::scope`
//!   per call, retained as the spawn-per-batch baseline the benches
//!   compare against;
//! * **pooled** (`workers ≥ 2`, pooling on) — jobs go to the
//!   persistent `WorkerPool` (threads spawned once, parked between
//!   jobs). Drains submitted through [`FleetExecutor::run_job`] return
//!   immediately (enabling pipelining); reads go through
//!   [`FleetExecutor::map_shards`], which waits the job out and hands
//!   back per-shard outputs in shard-index order.
//!
//! Since PR 4 every fleet operation — ingestion drains *and* the read
//! paths (aggregate, snapshot prefetch, queries, eviction) — routes
//! through this one dispatcher, so `FleetConfig::pool` governs them
//! uniformly and reads stop paying a thread spawn per call. The
//! sketch-backed reads (PR 5, `DESIGN.md` §Incremental-reads) are the
//! cheapest jobs it runs: an `O(bins)` sketch copy per shard, plus —
//! for quantiles / top-k / threshold counts — one masked
//! candidate-bin refinement pass over cached per-stream stats.
//!
//! Every parallel path uses **work stealing**, not chunking: workers
//! claim the next item from a shared atomic cursor until the queue is
//! empty. PR-2's ceil-sized chunking could build fewer chunks than
//! workers (9 shards / 4 workers → ceil(9/4) = 3 chunks of 3), silently
//! idling a worker; with a claim cursor every worker participates
//! whenever at least `workers` items exist (regression-tested in
//! `rust/tests/executor.rs`), and a skewed queue no longer serializes
//! behind its largest chunk.
//!
//! Determinism: scheduling decides only *who* computes, never *what* —
//! per-item work touches disjoint state, and result collection
//! ([`map_shards`], [`map_indexed`]) is reassembled in index order.
//! Every strategy stays bit-identical to serial (adversarially tested
//! in `rust/tests/executor.rs`).
//!
//! [`map_shards`]: FleetExecutor::map_shards
//! [`map_indexed`]: FleetExecutor::map_indexed

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, PoisonError};

use super::pool::{lock, FleetCore, FleetJob, ShardWork, WorkerPool};

/// Runs fleet work serially, on scoped threads, or on the persistent
/// worker pool. See the module docs for the strategy split.
#[derive(Debug)]
pub struct FleetExecutor {
    workers: usize,
    use_pool: bool,
    pool: Option<WorkerPool>,
}

impl FleetExecutor {
    /// Executor with `workers` threads; `0` and `1` both mean the
    /// serial inline path. With `use_pool` (and ≥ 2 workers) the
    /// persistent pool is spawned immediately and reused for every
    /// job until the executor is dropped or reconfigured.
    pub fn new(workers: usize, use_pool: bool) -> FleetExecutor {
        let workers = workers.max(1);
        let pool = (use_pool && workers > 1).then(|| WorkerPool::spawn(workers));
        FleetExecutor { workers, use_pool, pool }
    }

    /// Configured worker count (≥ 1; 1 = serial).
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// True when this executor was configured to use the persistent
    /// pool (even if the current worker count keeps it serial).
    pub fn uses_pool(&self) -> bool {
        self.use_pool
    }

    /// True when a persistent pool is actually live (pooling on and
    /// `workers ≥ 2`).
    pub fn is_pooled(&self) -> bool {
        self.pool.is_some()
    }

    /// Workers a job over `items` claimable units will engage:
    /// `min(workers, items)`, at least 1. This is the participation
    /// guarantee the old ceil-chunked dispatch violated (9 items on 4
    /// workers built only 3 chunks).
    pub fn planned_workers(&self, items: usize) -> usize {
        self.workers.min(items).max(1)
    }

    /// Launch a fleet job on `workers` threads (as computed by
    /// [`FleetExecutor::planned_workers`] — the job's latch is armed
    /// for exactly that many arrivals). Serial runs inline; the pool
    /// returns immediately after submission (enabling pipelining);
    /// scoped joins before returning.
    pub(super) fn run_job<W: ShardWork>(&self, job: &Arc<FleetJob<W>>, workers: usize) {
        if workers <= 1 {
            job.run_worker();
        } else if let Some(pool) = &self.pool {
            // planned_workers caps at self.workers == pool.size(), so
            // exactly `workers` run_worker calls reach the job — the
            // count its completion latch is armed for.
            debug_assert!(workers <= pool.size());
            for w in 0..workers {
                let j = Arc::clone(job);
                pool.submit(w, Box::new(move || j.run_worker()));
            }
        } else {
            std::thread::scope(|scope| {
                for _ in 0..workers {
                    let j: &FleetJob<W> = job;
                    scope.spawn(move || j.run_worker());
                }
            });
        }
    }

    /// Run `work` over every shard of `core` on the configured
    /// strategy and return the per-shard outputs in **shard-index
    /// order** — the uniform engine behind `aggregate`, snapshot
    /// prefetching, the `fleet/query.rs` queries and both eviction
    /// flavours. Serial visits inline (no job allocation); scoped and
    /// pooled build a [`FleetJob`], wait out its latch, and re-raise a
    /// visit panic on the caller (unless the caller is already
    /// unwinding — reads stay panic-free mid-drop).
    pub(super) fn map_shards<W: ShardWork>(&self, core: &Arc<FleetCore>, work: W) -> Vec<W::Output> {
        let n = core.shard_count();
        let workers = self.planned_workers(n);
        if workers <= 1 {
            let out = (0..n).map(|s| work.visit(s, core)).collect();
            work.finish(core);
            return out;
        }
        let job = Arc::new(FleetJob::new(Arc::clone(core), work, (0..n).collect(), workers));
        self.run_job(&job, workers);
        job.wait();
        if !std::thread::panicking() && job.poisoned.swap(false, Ordering::Relaxed) {
            panic!("a fleet worker panicked while executing a shard job");
        }
        job.take_outputs().into_iter().map(|(_, out)| out).collect()
    }

    /// Run `f(i)` once for every `i in 0..n`, work-stealing indices off
    /// a shared cursor. Serial inline for `workers ≤ 1`; otherwise
    /// `min(workers, n)` scoped threads. Borrowed-closure utility for
    /// callers outside the fleet core (tests, ad-hoc tools): closures
    /// cannot move onto the persistent pool without `'static`
    /// ownership — fleet-internal work rides the typed-job engine
    /// (`map_shards`) instead.
    pub fn for_each_index<F>(&self, n: usize, f: F)
    where
        F: Fn(usize) + Sync,
    {
        let threads = self.planned_workers(n);
        if threads <= 1 {
            for i in 0..n {
                f(i);
            }
            return;
        }
        let cursor = AtomicUsize::new(0);
        std::thread::scope(|scope| {
            for _ in 0..threads {
                scope.spawn(|| loop {
                    let i = cursor.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    f(i);
                });
            }
        });
    }

    /// Map `f(i)` over `0..n` with work stealing, returning results in
    /// index order regardless of which worker computed them. Same
    /// borrowed-closure scope as [`FleetExecutor::for_each_index`].
    pub fn map_indexed<T, F>(&self, n: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize) -> T + Sync,
    {
        let threads = self.planned_workers(n);
        if threads <= 1 {
            return (0..n).map(f).collect();
        }
        let results = Mutex::new(Vec::with_capacity(n));
        let cursor = AtomicUsize::new(0);
        std::thread::scope(|scope| {
            for _ in 0..threads {
                scope.spawn(|| loop {
                    let i = cursor.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    let value = f(i);
                    lock(&results).push((i, value));
                });
            }
        });
        let mut pairs = results.into_inner().unwrap_or_else(PoisonError::into_inner);
        pairs.sort_unstable_by_key(|&(i, _)| i);
        pairs.into_iter().map(|(_, value)| value).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;

    #[test]
    fn serial_executor_runs_inline() {
        let ex = FleetExecutor::new(1, true);
        assert_eq!(ex.workers(), 1);
        assert!(!ex.is_pooled(), "one worker must not spawn pool threads");
        let main = std::thread::current().id();
        ex.for_each_index(5, |_| assert_eq!(std::thread::current().id(), main));
    }

    #[test]
    fn planned_workers_never_exceeds_items() {
        let ex = FleetExecutor::new(4, false);
        assert_eq!(ex.planned_workers(0), 1);
        assert_eq!(ex.planned_workers(1), 1);
        assert_eq!(ex.planned_workers(3), 3);
        // The ceil-chunking regression: 9 items on 4 workers must plan
        // 4 participants, not ceil-chunk down to 3.
        assert_eq!(ex.planned_workers(9), 4);
        assert_eq!(ex.planned_workers(100), 4);
    }

    #[test]
    fn map_indexed_preserves_index_order() {
        for (workers, pool) in [(1, false), (4, false), (4, true), (16, false)] {
            let ex = FleetExecutor::new(workers, pool);
            let out = ex.map_indexed(97, |i| i * 3);
            assert_eq!(out, (0..97).map(|i| i * 3).collect::<Vec<_>>());
        }
    }

    #[test]
    fn for_each_index_visits_every_index_exactly_once() {
        let ex = FleetExecutor::new(8, false);
        let seen = Mutex::new(HashSet::new());
        ex.for_each_index(1000, |i| {
            assert!(seen.lock().unwrap().insert(i), "index {i} visited twice");
        });
        assert_eq!(seen.lock().unwrap().len(), 1000);
    }

    /// Typed shard work used to exercise `map_shards` across all three
    /// strategies without a full fleet.
    struct ShardIndexWork;
    impl ShardWork for ShardIndexWork {
        type Output = usize;
        fn visit(&self, s: usize, _core: &FleetCore) -> usize {
            s + 100
        }
    }

    #[test]
    fn map_shards_is_identical_across_strategies() {
        let core = Arc::new(FleetCore::new(16));
        let expect: Vec<usize> = (0..16).map(|s| s + 100).collect();
        for (workers, pool) in [(1, false), (1, true), (3, false), (3, true), (16, true)] {
            let ex = FleetExecutor::new(workers, pool);
            assert_eq!(
                ex.map_shards(&core, ShardIndexWork),
                expect,
                "map_shards diverged at workers {workers}, pool {pool}"
            );
        }
    }

    #[test]
    fn pooled_executor_spawns_and_drops_cleanly() {
        let ex = FleetExecutor::new(4, true);
        assert!(ex.is_pooled());
        assert!(ex.uses_pool());
        drop(ex); // joins the parked workers without hanging
    }
}
