//! Persistent worker pool and the typed fleet-job engine it executes.
//!
//! PR-3 introduced the persistent [`WorkerPool`] but hardwired it to
//! one job shape — the batch drain — so every *read* path (aggregates,
//! snapshots, queries, eviction) fell back to scoped threads spawned
//! per call, exactly the per-batch spawn cost the pool eliminated for
//! writes. This module generalizes the engine:
//!
//! * [`ShardWork`] — the typed unit of fleet work: what to do to one
//!   shard ([`ShardWork::visit`]) plus an optional completion hook run
//!   once by the job's last worker ([`ShardWork::finish`]). Work is
//!   `Send + Sync + 'static` and owns everything it needs (the
//!   **owned-state rule**), so the same value can ride pool threads,
//!   scoped threads or run inline.
//! * [`FleetJob`] — one work value plus the claim machinery shared by
//!   every worker executing it: the shard claim queue, the stealing
//!   cursor, per-shard **output slots**, a participant/poison record
//!   and a completion latch. Workers claim shards off the queue until
//!   it is empty; outputs land in slots indexed by claim position and
//!   are reassembled in shard-index order by [`FleetJob::take_outputs`]
//!   — which is why out-of-order claiming never changes results.
//! * [`DrainWork`] — batched ingestion, now just one `ShardWork`
//!   implementation among several: per-shard event buckets, precomputed
//!   fleet ticks, and a finish hook that merges shard-local alarm logs
//!   in shard-index order (the serial order).
//! * [`WorkerPool`] — unchanged substrate: threads spawned **once** per
//!   fleet (lazily, when the executor is built with pooling and ≥ 2
//!   workers) and parked on their job channels between batches.
//!   Submitting any job costs one boxed closure per worker instead of a
//!   thread spawn.
//!
//! Determinism: claiming order affects only wall-clock. Each shard's
//! visit depends solely on that shard's state and the work value's own
//! fields (precomputed ticks, batch timestamp, thresholds …), outputs
//! are merged in shard-index order, and any cross-shard completion work
//! runs in the finish hook — also in shard-index order. See
//! `rust/DESIGN.md` §Jobs.
//!
//! Panic safety: a panic inside one shard's visit is caught per shard,
//! recorded on the job, and re-raised as a clean panic at the fleet's
//! next synchronization point. The pool threads never unwind, so the
//! same `AucFleet` keeps working afterwards — no poisoned, parked or
//! deadlocked workers (property-tested in `rust/tests/executor.rs`).

use std::collections::HashMap;
use std::fmt;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex, MutexGuard, PoisonError};
use std::thread;

use super::config::StreamConfig;
use super::shard::Shard;

/// One ingestion event: `(stream id, score, label)`.
pub(super) type Event = (u64, f64, bool);

/// A unit of work shipped to a pool thread.
pub(super) type Task = Box<dyn FnOnce() + Send + 'static>;

/// Lock a mutex, ignoring poisoning: fleet invariants are maintained at
/// a coarser level (a shard-visit panic marks the whole job poisoned
/// and the fleet re-raises it at the next sync), so an unwound worker
/// must not brick every later lock of the same shard.
pub(super) fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// The shard state shared between the fleet handle and the pool
/// workers. Everything a fleet job touches lives here, behind one
/// mutex per shard (always uncontended: the claim cursor hands each
/// shard to exactly one worker, and the fleet only locks after the
/// job's completion latch).
#[derive(Debug)]
pub(super) struct FleetCore {
    /// One mutex per shard; the shard is the unit of parallelism.
    pub(super) shards: Vec<Mutex<Shard>>,
    /// Alarms of the in-flight (or just-finished) batch, merged here in
    /// shard-index order by the drain job's finish hook; the fleet
    /// moves them into its public log at the next sync.
    pub(super) pending_alarms: Mutex<Vec<super::snapshot::FleetAlarm>>,
    /// Drained bucket allocations handed back for reuse by later
    /// batches (capacity recycling across the pipeline).
    pub(super) spare_buckets: Mutex<Vec<Vec<Event>>>,
}

impl FleetCore {
    pub(super) fn new(shards: usize) -> FleetCore {
        FleetCore {
            shards: (0..shards).map(|_| Mutex::new(Shard::default())).collect(),
            pending_alarms: Mutex::new(Vec::new()),
            spare_buckets: Mutex::new(Vec::new()),
        }
    }

    /// Shard count (power of two).
    pub(super) fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// Lock one shard (unpoisoning — see [`lock`]).
    pub(super) fn lock_shard(&self, s: usize) -> MutexGuard<'_, Shard> {
        lock(&self.shards[s])
    }
}

/// The typed unit of fleet work: what one job does to each shard it
/// claims. Implementations own all their inputs (buckets, thresholds,
/// predicates — the **owned-state rule**), so a job can outlive the
/// call that launched it and ride the persistent pool's threads.
///
/// Determinism contract: `visit(s, …)` must depend only on shard `s`'s
/// state and `self`'s owned fields — never on claim order, thread
/// identity, or shared mutable scratch. `finish` runs exactly once, by
/// the job's last worker, *before* the completion latch opens; any
/// cross-shard merge it performs must iterate shards in index order.
pub(super) trait ShardWork: Send + Sync + 'static {
    /// Per-shard result, reassembled in shard-index order by
    /// [`FleetJob::take_outputs`].
    type Output: Send + 'static;

    /// Visit one claimed shard. Lock it through `core` (uncontended —
    /// the claim cursor hands each shard to exactly one worker).
    fn visit(&self, s: usize, core: &FleetCore) -> Self::Output;

    /// Completion hook: run once by the last worker before the latch
    /// opens, so waiters always observe its effects.
    fn finish(&self, _core: &FleetCore) {}
}

/// One fleet job: a [`ShardWork`] value plus the claim machinery shared
/// by every worker executing it.
///
/// The fleet (or executor) constructs the job with the shard claim
/// queue, hands an `Arc` of it to the execution strategy, and calls
/// [`FleetJob::wait`] at its next synchronization point (immediately
/// for reads and unpipelined drains). Workers call
/// [`FleetJob::run_worker`].
pub(super) struct FleetJob<W: ShardWork> {
    core: Arc<FleetCore>,
    work: W,
    /// Claim queue: shard indices, in whatever priority order the
    /// caller chose (drains: largest bucket first; reads: shard order).
    /// The queue is deterministic even though claiming is not, and
    /// neither affects results.
    order: Vec<usize>,
    /// Next claim-queue position to steal.
    cursor: AtomicUsize,
    /// Workers that have not yet finished their claim loop.
    remaining: AtomicUsize,
    /// Workers that visited at least one shard (scheduling diagnostics).
    pub(super) participants: AtomicUsize,
    /// Set when any shard visit panicked; the fleet re-raises once at
    /// the next sync.
    pub(super) poisoned: AtomicBool,
    /// Output slot per claim-queue position (`outputs[i]` belongs to
    /// shard `order[i]`); filled by whichever worker claimed it.
    outputs: Vec<Mutex<Option<W::Output>>>,
    /// Completion latch: flipped by the last worker *after* the finish
    /// hook, so waiters always observe merged state.
    done: Mutex<bool>,
    cv: Condvar,
}

impl<W: ShardWork> fmt::Debug for FleetJob<W> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("FleetJob")
            .field("shards", &self.order.len())
            .field("claimed", &self.cursor.load(Ordering::Relaxed))
            .field("poisoned", &self.poisoned.load(Ordering::Relaxed))
            .finish_non_exhaustive()
    }
}

impl<W: ShardWork> FleetJob<W> {
    /// Job over the shards in `order`, to be executed by exactly
    /// `workers` [`FleetJob::run_worker`] calls (the latch is armed for
    /// that many arrivals).
    pub(super) fn new(core: Arc<FleetCore>, work: W, order: Vec<usize>, workers: usize) -> Self {
        let outputs = order.iter().map(|_| Mutex::new(None)).collect();
        FleetJob {
            core,
            work,
            order,
            cursor: AtomicUsize::new(0),
            remaining: AtomicUsize::new(workers.max(1)),
            participants: AtomicUsize::new(0),
            poisoned: AtomicBool::new(false),
            outputs,
            done: Mutex::new(false),
            cv: Condvar::new(),
        }
    }

    /// Worker entry point: steal shards off the claim queue until it is
    /// empty, then arrive at the latch. Called exactly `workers` times
    /// per job (inline for the serial path).
    pub(super) fn run_worker(&self) {
        let mut claimed = false;
        loop {
            let i = self.cursor.fetch_add(1, Ordering::Relaxed);
            let Some(&s) = self.order.get(i) else { break };
            claimed = true;
            // Catch per shard: one poisoned shard must not stop this
            // worker from visiting the shards it would steal next, and
            // must never unwind into the pool's run loop.
            match catch_unwind(AssertUnwindSafe(|| self.work.visit(s, &self.core))) {
                Ok(out) => *lock(&self.outputs[i]) = Some(out),
                Err(_) => self.poisoned.store(true, Ordering::Relaxed),
            }
        }
        if claimed {
            self.participants.fetch_add(1, Ordering::Relaxed);
        }
        self.finish();
    }

    /// Arrive at the latch; the last worker runs the work's completion
    /// hook (e.g. the drain's shard-order alarm merge) before releasing
    /// waiters.
    fn finish(&self) {
        if self.remaining.fetch_sub(1, Ordering::AcqRel) == 1 {
            if catch_unwind(AssertUnwindSafe(|| self.work.finish(&self.core))).is_err() {
                self.poisoned.store(true, Ordering::Relaxed);
            }
            *lock(&self.done) = true;
            self.cv.notify_all();
        }
    }

    /// Block until every worker has finished and the finish hook is
    /// visible. Cheap (one uncontended lock) once the job is done.
    pub(super) fn wait(&self) {
        let mut done = lock(&self.done);
        while !*done {
            done = self.cv.wait(done).unwrap_or_else(PoisonError::into_inner);
        }
    }

    /// Collect the per-shard outputs in **shard-index order**,
    /// regardless of claim-queue priority or which worker computed
    /// them. Call after [`FleetJob::wait`]. Slots a panicked visit
    /// never filled are skipped (the fleet re-raises the panic at its
    /// sync point instead).
    pub(super) fn take_outputs(&self) -> Vec<(usize, W::Output)> {
        let mut out = Vec::with_capacity(self.order.len());
        for (i, &s) in self.order.iter().enumerate() {
            if let Some(v) = lock(&self.outputs[i]).take() {
                out.push((s, v));
            }
        }
        out.sort_unstable_by_key(|&(s, _)| s);
        out
    }
}

/// Batched ingestion as a [`ShardWork`]: drain each claimed shard's
/// event bucket with its precomputed start tick and the batch
/// timestamp, then merge the batch's alarms in shard-index order (the
/// serial order) in the finish hook.
pub(super) struct DrainWork {
    /// Per-shard event buckets (full shard indexing; untouched shards
    /// hold empty vectors). Mutexed so any worker can take one.
    buckets: Vec<Mutex<Vec<Event>>>,
    /// Fleet tick immediately before each shard's first event — the
    /// exact ticks the serial shard-by-shard drain would assign.
    start_ticks: Vec<u64>,
    /// Caller timestamp of the whole batch (see `AucFleet::push_batch_at`).
    at: u64,
    defaults: StreamConfig,
    /// Shared with the fleet (copy-on-write there), so a job costs one
    /// `Arc` bump instead of a map clone per batch.
    overrides: Arc<HashMap<u64, StreamConfig>>,
}

impl DrainWork {
    pub(super) fn new(
        buckets: Vec<Mutex<Vec<Event>>>,
        start_ticks: Vec<u64>,
        at: u64,
        defaults: StreamConfig,
        overrides: Arc<HashMap<u64, StreamConfig>>,
    ) -> DrainWork {
        DrainWork { buckets, start_ticks, at, defaults, overrides }
    }
}

impl ShardWork for DrainWork {
    type Output = ();

    /// Drain one claimed shard, then recycle its bucket allocation.
    fn visit(&self, s: usize, core: &FleetCore) {
        let mut bucket = std::mem::take(&mut *lock(&self.buckets[s]));
        {
            let mut shard = core.lock_shard(s);
            shard.drain_events(&bucket, &self.defaults, &self.overrides, self.start_ticks[s], self.at);
        }
        bucket.clear();
        lock(&core.spare_buckets).push(bucket);
    }

    /// Merge the batch's alarms into the fleet's pending log in
    /// shard-index order — exactly the order the serial drain produces.
    fn finish(&self, core: &FleetCore) {
        let mut out = lock(&core.pending_alarms);
        for shard in &core.shards {
            lock(shard).take_alarms_into(&mut out);
        }
    }
}

/// The drain job the fleet keeps in flight while pipelining.
pub(super) type DrainJob = FleetJob<DrainWork>;

/// Persistent ingestion threads, spawned once per fleet and parked on
/// their job channels between batches.
#[derive(Debug)]
pub(super) struct WorkerPool {
    senders: Vec<mpsc::Sender<Task>>,
    handles: Vec<thread::JoinHandle<()>>,
}

impl WorkerPool {
    /// Spawn `workers` named threads, each parked on its own channel.
    pub(super) fn spawn(workers: usize) -> WorkerPool {
        let mut senders = Vec::with_capacity(workers);
        let mut handles = Vec::with_capacity(workers);
        for w in 0..workers {
            let (tx, rx) = mpsc::channel::<Task>();
            let handle = thread::Builder::new()
                .name(format!("fleet-worker-{w}"))
                .spawn(move || {
                    // Parked in `recv` between jobs; exits when the
                    // pool drops its sender. Tasks are already
                    // panic-proofed by `FleetJob::run_worker`; the
                    // catch here is defense in depth so no panic can
                    // ever take a pool thread down.
                    while let Ok(task) = rx.recv() {
                        let _ = catch_unwind(AssertUnwindSafe(task));
                    }
                })
                .expect("failed to spawn fleet worker thread");
            senders.push(tx);
            handles.push(handle);
        }
        WorkerPool { senders, handles }
    }

    /// Number of pool threads.
    pub(super) fn size(&self) -> usize {
        self.senders.len()
    }

    /// Hand a task to worker `w`. If that thread is somehow gone the
    /// task runs inline so the job's completion latch still resolves.
    pub(super) fn submit(&self, w: usize, task: Task) {
        if let Err(mpsc::SendError(task)) = self.senders[w].send(task) {
            task();
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        // Disconnect the channels; each worker finishes its in-flight
        // task (if any) and exits its recv loop, then we join.
        self.senders.clear();
        for handle in self.handles.drain(..) {
            let _ = handle.join();
        }
    }
}

// Jobs are shared across worker threads behind an `Arc`, and the pool
// (inside the executor, inside the fleet) must move with the fleet.
const _: () = {
    const fn assert_send_sync<T: Send + Sync>() {}
    const fn assert_send<T: Send>() {}
    assert_send_sync::<DrainJob>();
    assert_send_sync::<FleetCore>();
    assert_send::<WorkerPool>();
};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pool_runs_tasks_and_survives_panics() {
        let pool = WorkerPool::spawn(2);
        assert_eq!(pool.size(), 2);
        let hits = Arc::new(AtomicUsize::new(0));
        // A panicking task must not kill the worker...
        pool.submit(0, Box::new(|| panic!("boom")));
        for w in 0..2 {
            let hits = Arc::clone(&hits);
            pool.submit(
                w,
                Box::new(move || {
                    hits.fetch_add(1, Ordering::SeqCst);
                }),
            );
        }
        // ...so both workers still drain their queues before the drop
        // below joins them.
        drop(pool);
        assert_eq!(hits.load(Ordering::SeqCst), 2);
    }

    #[test]
    fn latch_waits_for_all_workers_and_finish_hook() {
        let core = Arc::new(FleetCore::new(4));
        let buckets: Vec<Mutex<Vec<Event>>> = (0..4).map(|_| Mutex::new(Vec::new())).collect();
        let work = DrainWork::new(
            buckets,
            vec![0; 4],
            0,
            StreamConfig::default(),
            Arc::new(HashMap::new()),
        );
        let job = Arc::new(FleetJob::new(
            Arc::clone(&core),
            work,
            Vec::new(), // nothing to claim: workers arrive immediately
            3,
        ));
        let pool = WorkerPool::spawn(3);
        for w in 0..3 {
            let j = Arc::clone(&job);
            pool.submit(w, Box::new(move || j.run_worker()));
        }
        job.wait();
        assert!(!job.poisoned.load(Ordering::Relaxed));
        assert_eq!(job.participants.load(Ordering::Relaxed), 0);
    }

    /// A read-shaped work: outputs must come back in shard-index order
    /// no matter the claim-queue priority or which worker computed
    /// each slot.
    struct IndexWork;
    impl ShardWork for IndexWork {
        type Output = usize;
        fn visit(&self, s: usize, _core: &FleetCore) -> usize {
            s * 10
        }
    }

    #[test]
    fn outputs_reassemble_in_shard_order_despite_reversed_claim_queue() {
        let core = Arc::new(FleetCore::new(8));
        // Claim queue deliberately reversed — like a size-sorted drain.
        let order: Vec<usize> = (0..8).rev().collect();
        let job = Arc::new(FleetJob::new(Arc::clone(&core), IndexWork, order, 3));
        let pool = WorkerPool::spawn(3);
        for w in 0..3 {
            let j = Arc::clone(&job);
            pool.submit(w, Box::new(move || j.run_worker()));
        }
        job.wait();
        let outputs = job.take_outputs();
        let expect: Vec<(usize, usize)> = (0..8).map(|s| (s, s * 10)).collect();
        assert_eq!(outputs, expect);
        assert!(job.participants.load(Ordering::Relaxed) >= 1);
    }

    /// A panicking visit poisons the job but leaves the other slots
    /// filled and the latch resolving.
    struct PanicOn(usize);
    impl ShardWork for PanicOn {
        type Output = usize;
        fn visit(&self, s: usize, _core: &FleetCore) -> usize {
            assert_ne!(s, self.0, "injected shard panic");
            s
        }
    }

    #[test]
    fn poisoned_visit_skips_its_slot_and_releases_the_latch() {
        let core = Arc::new(FleetCore::new(4));
        let job = Arc::new(FleetJob::new(Arc::clone(&core), PanicOn(2), (0..4).collect(), 2));
        let pool = WorkerPool::spawn(2);
        for w in 0..2 {
            let j = Arc::clone(&job);
            pool.submit(w, Box::new(move || j.run_worker()));
        }
        job.wait();
        assert!(job.poisoned.load(Ordering::Relaxed));
        let shards: Vec<usize> = job.take_outputs().into_iter().map(|(s, _)| s).collect();
        assert_eq!(shards, vec![0, 1, 3], "panicked slot must be skipped, not fabricated");
    }
}
