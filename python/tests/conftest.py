"""Put the ``python/`` layer root on sys.path so ``from compile import …``
works when pytest is invoked from the repository root (as CI does)."""

import sys
from pathlib import Path

LAYER_ROOT = Path(__file__).resolve().parent.parent
if str(LAYER_ROOT) not in sys.path:
    sys.path.insert(0, str(LAYER_ROOT))
