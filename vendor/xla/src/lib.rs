//! Offline stand-in for the `xla` crate (PJRT bindings).
//!
//! The real crate links libxla and drives a PJRT CPU client; it cannot
//! be built in this offline environment. This stub keeps the workspace
//! compiling and the pure-rust test suite green with two tiers of
//! fidelity:
//!
//! * [`Literal`] is a **real host-side implementation** (f32 buffer +
//!   shape): `vec1` / `scalar` / `reshape` / `to_vec` behave exactly
//!   like the originals, so the literal-marshalling helpers in
//!   `streamauc::runtime::executable` and their unit tests work
//!   unchanged.
//! * The PJRT surface ([`PjRtClient`], [`HloModuleProto`],
//!   [`XlaComputation`], [`PjRtLoadedExecutable`], [`PjRtBuffer`])
//!   type-checks against the call sites but returns
//!   [`Error::Unavailable`] at runtime. The runtime integration tests
//!   gate on `artifacts/meta.json` and skip before ever reaching these
//!   entry points; the `streamauc train` CLI surfaces the error with
//!   context.
//!
//! Swapping in the real `xla` crate (edit `[dependencies]` in the root
//! `Cargo.toml`) re-enables the PJRT runtime without touching
//! `src/runtime/`.

use std::fmt;

/// Error type mirroring the real crate's (std-error, Send + Sync).
#[derive(Debug)]
pub enum Error {
    /// PJRT is not available in this build (vendored stub).
    Unavailable(&'static str),
    /// Host-literal shape/usage error.
    Shape(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Unavailable(what) => write!(
                f,
                "{what}: PJRT runtime unavailable (offline xla stub; \
                 vendor the real `xla` crate to enable it)"
            ),
            Error::Shape(msg) => write!(f, "literal shape error: {msg}"),
        }
    }
}

impl std::error::Error for Error {}

/// Result alias matching the real crate.
pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &'static str) -> Result<T> {
    Err(Error::Unavailable(what))
}

// ---------------------------------------------------------------------
// Host literals (fully functional)
// ---------------------------------------------------------------------

/// Element types a [`Literal`] can be read back as. The workspace's
/// shape contract is f32-only.
pub trait NativeElem: Sized + Copy {
    /// Convert one stored f32 element.
    fn from_f32(v: f32) -> Self;
}

impl NativeElem for f32 {
    #[inline]
    fn from_f32(v: f32) -> f32 {
        v
    }
}

impl NativeElem for f64 {
    #[inline]
    fn from_f32(v: f32) -> f64 {
        f64::from(v)
    }
}

/// A host tensor: flat f32 buffer plus dimensions.
#[derive(Clone, Debug, PartialEq)]
pub struct Literal {
    data: Vec<f32>,
    dims: Vec<i64>,
}

impl Literal {
    /// Rank-1 literal from a slice.
    pub fn vec1(values: &[f32]) -> Literal {
        Literal { data: values.to_vec(), dims: vec![values.len() as i64] }
    }

    /// Rank-0 (scalar) literal.
    pub fn scalar(value: f32) -> Literal {
        Literal { data: vec![value], dims: Vec::new() }
    }

    /// Dimensions of this literal.
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    /// Number of elements.
    pub fn element_count(&self) -> usize {
        self.data.len()
    }

    /// Reinterpret with new dimensions (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        if want < 0 || want as usize != self.data.len() {
            return Err(Error::Shape(format!(
                "cannot reshape {} elements to {dims:?}",
                self.data.len()
            )));
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    /// Copy the elements to a host vector (row-major order).
    pub fn to_vec<T: NativeElem>(&self) -> Result<Vec<T>> {
        Ok(self.data.iter().map(|&v| T::from_f32(v)).collect())
    }

    /// Decompose a tuple literal into its members. Host literals built
    /// by this stub are never tuples; only PJRT results are, and those
    /// are unreachable here.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        unavailable("untuple result literal")
    }
}

// ---------------------------------------------------------------------
// PJRT surface (type-checks, errors at runtime)
// ---------------------------------------------------------------------

/// PJRT client handle (stub: construction always fails).
#[derive(Debug)]
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    /// Create a CPU client. Always fails in the stub.
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("create PJRT CPU client")
    }

    /// Platform name of the client.
    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    /// Compile a computation. Unreachable (no client can exist).
    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PJRT compile")
    }
}

/// Parsed HLO module (stub: parsing always fails).
#[derive(Debug)]
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    /// Parse HLO text from a file. Always fails in the stub.
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable("parse HLO text")
    }
}

/// An XLA computation wrapping an HLO module.
#[derive(Debug)]
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    /// Wrap a parsed HLO module.
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// A compiled, loaded executable (stub: cannot be constructed).
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    /// Execute with borrowed literal inputs. Unreachable in the stub.
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _inputs: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PJRT execute")
    }
}

/// A device buffer produced by execution (stub: cannot be constructed).
#[derive(Debug)]
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    /// Fetch the buffer to a host literal. Unreachable in the stub.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("fetch result buffer")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vec1_scalar_roundtrip() {
        let v = Literal::vec1(&[1.0, 2.0, 3.0]);
        assert_eq!(v.dims(), &[3]);
        assert_eq!(v.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0]);
        let s = Literal::scalar(0.5);
        assert_eq!(s.dims(), &[] as &[i64]);
        assert_eq!(s.to_vec::<f64>().unwrap(), vec![0.5]);
    }

    #[test]
    fn reshape_checks_element_count() {
        let v = Literal::vec1(&[0.0; 12]);
        let m = v.reshape(&[3, 4]).unwrap();
        assert_eq!(m.dims(), &[3, 4]);
        assert_eq!(m.element_count(), 12);
        assert!(v.reshape(&[5, 3]).is_err());
    }

    #[test]
    fn pjrt_surface_reports_unavailable() {
        let err = PjRtClient::cpu().unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("unavailable"), "{msg}");
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
    }
}
