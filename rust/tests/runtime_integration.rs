//! End-to-end runtime integration: PJRT loads the AOT artifacts, the
//! rust training loop reaches a discriminative model, and the scorer
//! feeds the sliding-window estimator.
//!
//! Requires `artifacts/` (run `make artifacts`); every test is skipped
//! with a notice when the artifacts are absent so `cargo test` stays
//! green in a fresh checkout.

use streamauc::coordinator::{NaiveAuc, SlidingAuc};
use streamauc::runtime::{Runtime, Scorer, Trainer};
use streamauc::runtime::trainer::Params;
use streamauc::stream::synth::{hepmass_like, miniboone_like, Dataset};

fn runtime() -> Option<Runtime> {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    if !std::path::Path::new(dir).join("meta.json").exists() {
        eprintln!("skipping: no artifacts (run `make artifacts`)");
        return None;
    }
    Some(Runtime::new(dir).expect("create runtime"))
}

#[test]
fn meta_contract_loaded() {
    let Some(rt) = runtime() else { return };
    let meta = rt.meta();
    assert_eq!(meta.dims, 128);
    assert_eq!(meta.score_batch, 1024);
    assert_eq!(meta.train_batch, 256);
    assert_eq!(rt.platform(), "cpu");
}

#[test]
fn zero_params_score_half() {
    let Some(rt) = runtime() else { return };
    let params = Params { w: vec![0.0; rt.meta().dims], b: 0.0 };
    let scorer = Scorer::new(&rt, params).unwrap();
    let rows = vec![vec![1.0f32; 28]; 10];
    let scores = scorer.score(&rows).unwrap();
    assert_eq!(scores.len(), 10);
    for s in scores {
        assert!((s - 0.5).abs() < 1e-6, "zero model must score 0.5, got {s}");
    }
}

#[test]
fn scorer_handles_partial_and_multi_batches() {
    let Some(rt) = runtime() else { return };
    let meta = rt.meta();
    let params = Params { w: vec![0.01; meta.dims], b: -0.1 };
    let scorer = Scorer::new(&rt, params).unwrap();
    // 1 element, one full batch, and one-and-a-half batches.
    for n in [1, meta.score_batch, meta.score_batch + meta.score_batch / 2] {
        let rows: Vec<Vec<f32>> = (0..n).map(|i| vec![(i % 7) as f32 * 0.1; 50]).collect();
        let scores = scorer.score(&rows).unwrap();
        assert_eq!(scores.len(), n);
        assert!(scores.iter().all(|s| (0.0..=1.0).contains(s)));
        // Identical rows must score identically (padding is consistent).
        let s0 = scorer.score(&rows[..1]).unwrap()[0];
        assert!((scores[0] - s0).abs() < 1e-6);
    }
}

#[test]
fn training_reduces_loss_and_discriminates() {
    let Some(rt) = runtime() else { return };
    let mut data = Dataset::new(miniboone_like().scaled(20), 42);
    let train = data.examples(4000);
    let trainer = Trainer::new(&rt, 0.5).unwrap();
    let report = trainer.train(&train, 120).unwrap();
    let early = report.early_loss(10);
    let late = report.late_loss(10);
    assert!(
        late < early * 0.8,
        "loss must drop substantially: {early} -> {late}"
    );

    // Score a held-out stream and check AUC through the estimator stack.
    let test = data.examples(4000);
    let scorer = Scorer::new(&rt, report.params).unwrap();
    let rows: Vec<Vec<f32>> = test.iter().map(|e| e.features.clone()).collect();
    let scores = scorer.score(&rows).unwrap();
    let pairs: Vec<(f64, bool)> = scores
        .iter()
        .zip(&test)
        .map(|(&s, e)| (s, e.label))
        .collect();
    let auc = NaiveAuc::of(&pairs);
    assert!(auc > 0.85, "trained model AUC {auc} too low");

    // The paper's full pipeline: feed the scored stream into the
    // approximate sliding window and compare against exact.
    let mut window = SlidingAuc::new(1000, 0.05);
    for &(s, l) in &pairs {
        window.push(s, l);
    }
    let est = window.auc();
    let exact = window.exact_auc();
    assert!(
        (est - exact).abs() <= 0.05 * exact / 2.0 + 1e-12,
        "windowed estimate {est} vs exact {exact}"
    );
}

#[test]
fn training_is_deterministic() {
    let Some(rt) = runtime() else { return };
    let mut data = Dataset::new(hepmass_like().scaled(1000), 7);
    let train = data.examples(1024);
    let trainer = Trainer::new(&rt, 0.2).unwrap();
    let a = trainer.train(&train, 10).unwrap();
    let b = trainer.train(&train, 10).unwrap();
    assert_eq!(a.params.w, b.params.w);
    assert_eq!(a.params.b, b.params.b);
    assert_eq!(a.losses, b.losses);
}

#[test]
fn trainer_rejects_bad_inputs() {
    let Some(rt) = runtime() else { return };
    assert!(Trainer::new(&rt, 0.0).is_err());
    assert!(Trainer::new(&rt, f32::NAN).is_err());
    let trainer = Trainer::new(&rt, 0.1).unwrap();
    assert!(trainer.train(&[], 5).is_err());
}
