//! Integration suite for the serving layer (`src/serve/`).
//!
//! The load-bearing property is **wire ≡ in-process**: every endpoint
//! response, on both protocols, must decode to a value equal to the
//! in-process query *at the publication seq the response echoes* — and
//! *byte-derived* equal: re-encoding the decoded value reproduces the
//! exact response bytes, so nothing was lost or reformatted in flight.
//! The suite drives seeded mixed-estimator fleets (approx +
//! maintained-exact + binned in one fleet), the empty- and one-stream
//! edges that used to underflow before the quantile-rank fix, the
//! malformed requests that must be rejected at the surface instead of
//! panicking the fleet, and the delta-subscription stream on both
//! protocols.
//!
//! The robustness half attacks the bounded front-end: hostile clients
//! (garbage preambles, mid-frame hangups, half-open connects,
//! oversized frame lengths, slow-loris heads, connect floods past the
//! connection limit) must be answered or shed — never panic or wedge
//! the server — and a deliberately unread subscriber must not stall
//! `ingest_batch` (the fan-out is queue-only; a lagging subscriber is
//! resynced with a `lagged` notice plus a fresh baseline).

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

use streamauc::fleet::{AucFleet, FleetConfig, StreamConfig};
use streamauc::serve::{
    http_get, http_subscribe, json, wire, BinClient, FleetServer, HttpClient, MAX_HEAD_BYTES,
    ServeLimits, SubEvent,
};
use streamauc::stream::Pcg;

// ---------------------------------------------------------------------
// Fixtures
// ---------------------------------------------------------------------

fn fleet_with(workers: usize, pipeline: bool, defaults: StreamConfig) -> AucFleet {
    AucFleet::new(FleetConfig {
        shards: 8,
        workers,
        pool: true,
        pipeline,
        adaptive: false,
        stream_defaults: defaults,
    })
}

/// A seeded fleet mixing all three estimator kinds, fed enough traffic
/// to spread streams across sketch bins.
fn mixed_fleet(workers: usize, pipeline: bool) -> AucFleet {
    let mut fleet = fleet_with(workers, pipeline, StreamConfig::new(32, 0.1).without_monitor());
    fleet.configure_stream(3, StreamConfig::exact(32).without_monitor());
    fleet.configure_stream(5, StreamConfig::binned(32, 64, 0.0, 1.0).without_monitor());
    let mut rng = Pcg::seed(0x5EAF);
    let mut batch = Vec::new();
    for _ in 0..30 {
        batch.clear();
        for _ in 0..40 {
            let id = rng.below(24);
            let pos = rng.chance(0.5);
            let score = if pos { rng.range(0.05, 0.7) } else { rng.range(0.3, 0.95) };
            batch.push((id, score, pos));
        }
        fleet.push_batch(&batch);
    }
    fleet
}

/// One deterministic batch for post-subscription ingestion.
fn delta_batch(seed: u64) -> Vec<(u64, f64, bool)> {
    let mut rng = Pcg::seed(seed);
    (0..64)
        .map(|_| {
            let pos = rng.chance(0.5);
            let score = if pos { rng.range(0.05, 0.6) } else { rng.range(0.4, 0.95) };
            (rng.below(30), score, pos)
        })
        .collect()
}

/// One event per stream with fresh random scores — maximal sketch-bin
/// churn per publish at minimal ingestion cost. Sized for the lag
/// test, which needs many kilobytes of delta traffic to overflow a
/// subscriber's bounded queue plus its unread socket buffers.
fn churn_batch(round: u64) -> Vec<(u64, f64, bool)> {
    let mut rng = Pcg::seed(0xC0FE ^ round);
    (0..24u64).map(|id| (id, rng.range(0.02, 0.98), rng.chance(0.5))).collect()
}

/// Send a raw request (must carry `Connection: close`) and return
/// `(status, body)`.
fn raw_http(addr: SocketAddr, request: &str) -> (u16, String) {
    let mut s = TcpStream::connect(addr).expect("connect");
    s.write_all(request.as_bytes()).expect("send");
    let mut buf = String::new();
    s.read_to_string(&mut buf).expect("read");
    let status = buf
        .split_whitespace()
        .nth(1)
        .and_then(|v| v.parse().ok())
        .unwrap_or_else(|| panic!("no status in {buf:?}"));
    let body = buf.split_once("\r\n\r\n").map(|(_, b)| b.to_string()).unwrap_or_default();
    (status, body)
}

fn get_ok(addr: SocketAddr, target: &str) -> String {
    let (status, body) = http_get(addr, target).expect("http round-trip");
    assert_eq!(status, 200, "GET {target} → {body}");
    body
}

fn bad_request(addr: SocketAddr, target: &str) {
    let (status, body) = http_get(addr, target).expect("http round-trip");
    assert_eq!(status, 400, "GET {target} must be rejected, got {status}: {body}");
    let err = json::Json::parse(&body).expect("error body is JSON");
    let msg = err.get("error").expect("error key");
    assert!(matches!(msg, json::Json::Str(s) if !s.is_empty()), "{body}");
}

// ---------------------------------------------------------------------
// Wire ≡ in-process
// ---------------------------------------------------------------------

#[test]
fn http_endpoints_are_byte_derived_equal_to_in_process_queries() {
    for (workers, pipeline) in [(1, false), (4, true)] {
        let server =
            FleetServer::start(mixed_fleet(workers, pipeline), "127.0.0.1:0").expect("bind");
        let addr = server.local_addr();
        let label = format!("workers={workers} pipeline={pipeline}");

        let body = get_ok(addr, "/snapshot");
        let snap = json::snapshot_from_json(&body).expect("decode snapshot");
        assert_eq!(snap, server.with_fleet(|f| f.snapshot()), "{label}");
        assert_eq!(json::snapshot_to_json(&snap), body, "{label}");

        let body = get_ok(addr, "/aggregate");
        let agg = json::aggregate_from_json(&body).expect("decode aggregate");
        assert_eq!(agg, server.with_fleet(|f| f.aggregate()), "{label}");
        assert_eq!(json::aggregate_to_json(&agg), body, "{label}");

        let body = get_ok(addr, "/top_k_worst?k=5");
        let top = json::top_k_from_json(&body).expect("decode top-k");
        assert_eq!(top, server.with_fleet(|f| f.top_k_worst(5)), "{label}");
        assert_eq!(json::top_k_to_json(&top), body, "{label}");

        for t in ["0.5", "0.015625", "1", "-2", "3.5"] {
            let body = get_ok(addr, &format!("/count_below?t={t}"));
            let (threshold, count) = json::count_below_from_json(&body).expect("decode count");
            assert_eq!(threshold, t.parse::<f64>().unwrap(), "{label}");
            assert_eq!(count, server.with_fleet(|f| f.count_below(threshold)), "{label} t={t}");
            assert_eq!(json::count_below_to_json(threshold, count), body, "{label}");
        }

        let body = get_ok(addr, "/auc_histogram?bins=7");
        let hist = json::auc_histogram_from_json(&body).expect("decode histogram");
        assert_eq!(hist, server.with_fleet(|f| f.auc_histogram(7)), "{label}");
        assert_eq!(json::auc_histogram_to_json(&hist), body, "{label}");

        let body = get_ok(addr, "/score_histogram?bins=9");
        let hist = json::score_histogram_from_json(&body).expect("decode histogram");
        assert_eq!(hist, server.with_fleet(|f| f.score_histogram(9)), "{label}");
        assert_eq!(json::score_histogram_to_json(&hist), body, "{label}");
    }
}

#[test]
fn binary_endpoints_are_byte_derived_equal_to_in_process_queries() {
    let server = FleetServer::start(mixed_fleet(4, true), "127.0.0.1:0").expect("bind");
    let mut bin = BinClient::connect(server.local_addr()).expect("binary session");

    let mut ask = |op: u8, payload: &[u8]| -> Vec<u8> {
        let (status, body) = bin.request(op, payload).expect("binary round-trip");
        assert_eq!(status, wire::STATUS_OK, "{}", String::from_utf8_lossy(&body));
        body
    };

    let body = ask(wire::OP_SNAPSHOT, &[]);
    let snap = wire::decode_snapshot(&body).expect("decode snapshot");
    assert_eq!(snap, server.with_fleet(|f| f.snapshot()));
    assert_eq!(wire::encode_snapshot(&snap), body);

    let body = ask(wire::OP_AGGREGATE, &[]);
    let agg = wire::decode_aggregate(&body).expect("decode aggregate");
    assert_eq!(agg, server.with_fleet(|f| f.aggregate()));
    assert_eq!(wire::encode_aggregate(&agg), body);

    let body = ask(wire::OP_TOP_K, &4u32.to_le_bytes());
    let top = wire::decode_top_k(&body).expect("decode top-k");
    assert_eq!(top, server.with_fleet(|f| f.top_k_worst(4)));
    assert_eq!(wire::encode_top_k(&top), body);

    let body = ask(wire::OP_COUNT_BELOW, &0.62_f64.to_bits().to_le_bytes());
    let (threshold, count) = wire::decode_count_below(&body).expect("decode count");
    assert_eq!(threshold.to_bits(), 0.62_f64.to_bits());
    assert_eq!(count, server.with_fleet(|f| f.count_below(0.62)));
    assert_eq!(wire::encode_count_below(threshold, count), body);

    let body = ask(wire::OP_AUC_HISTOGRAM, &11u32.to_le_bytes());
    let hist = wire::decode_auc_histogram(&body).expect("decode histogram");
    assert_eq!(hist, server.with_fleet(|f| f.auc_histogram(11)));
    assert_eq!(wire::encode_auc_histogram(&hist), body);

    let body = ask(wire::OP_SCORE_HISTOGRAM, &6u32.to_le_bytes());
    let hist = wire::decode_score_histogram(&body).expect("decode histogram");
    assert_eq!(hist, server.with_fleet(|f| f.score_histogram(6)));
    assert_eq!(wire::encode_score_histogram(&hist), body);
}

#[test]
fn http_and_binary_answers_decode_to_the_same_value() {
    let server = FleetServer::start(mixed_fleet(2, false), "127.0.0.1:0").expect("bind");
    let addr = server.local_addr();
    let via_http = json::aggregate_from_json(&get_ok(addr, "/aggregate")).expect("decode http");
    let mut bin = BinClient::connect(addr).expect("binary session");
    let (status, payload) = bin.request(wire::OP_AGGREGATE, &[]).expect("binary round-trip");
    assert_eq!(status, wire::STATUS_OK);
    let via_bin = wire::decode_aggregate(&payload).expect("decode binary");
    assert_eq!(via_http, via_bin);
    for (a, b) in [
        (via_http.min_auc, via_bin.min_auc),
        (via_http.median_auc, via_bin.median_auc),
        (via_http.mean_auc, via_bin.mean_auc),
    ] {
        assert_eq!(a.to_bits(), b.to_bits());
    }
}

// ---------------------------------------------------------------------
// Memory accounting over the wire
// ---------------------------------------------------------------------

/// `footprint_bytes` — per stream and in the aggregate — must survive
/// both protocols byte-derived, sum to the fleet-wide total, and track
/// hibernation: freezing every stream shrinks each served figure to
/// the compact form's cost while AUC bits and lengths stay pinned.
#[test]
fn footprint_bytes_track_hibernation_on_both_protocols() {
    let server = FleetServer::start(mixed_fleet(2, false), "127.0.0.1:0").expect("bind");
    let addr = server.local_addr();

    let live_total = server.with_fleet(|f| f.footprint_bytes());
    assert!(live_total > 0);
    let live = json::snapshot_from_json(&get_ok(addr, "/snapshot")).expect("decode");
    assert!(live.streams.iter().all(|s| s.footprint_bytes > 0));
    assert_eq!(live.streams.iter().map(|s| s.footprint_bytes).sum::<u64>(), live_total);
    let agg = json::aggregate_from_json(&get_ok(addr, "/aggregate")).expect("decode");
    assert_eq!(agg.footprint_bytes, live_total);

    let frozen = server.with_fleet_mut(|f| f.hibernate_idle(0));
    assert_eq!(frozen, live.streams.len(), "every stream must freeze");

    // HTTP: byte-derived, shrunk per stream, estimates pinned.
    let body = get_ok(addr, "/snapshot");
    let hib = json::snapshot_from_json(&body).expect("decode");
    assert_eq!(json::snapshot_to_json(&hib), body);
    let hib_total = server.with_fleet(|f| f.footprint_bytes());
    assert!(
        hib_total * 3 <= live_total,
        "hibernated total {hib_total} not ≤ ⅓ of live {live_total}"
    );
    assert_eq!(hib.streams.iter().map(|s| s.footprint_bytes).sum::<u64>(), hib_total);
    for (l, h) in live.streams.iter().zip(&hib.streams) {
        assert_eq!(l.stream, h.stream);
        assert_eq!(l.auc.to_bits(), h.auc.to_bits(), "frozen estimate must stay pinned");
        assert_eq!(l.len, h.len);
        assert!(h.footprint_bytes < l.footprint_bytes, "stream {} did not shrink", l.stream);
    }

    // The binary protocol serves the same figures, byte-derived.
    let mut bin = BinClient::connect(addr).expect("binary session");
    let (status, payload) = bin.request(wire::OP_SNAPSHOT, &[]).expect("binary round-trip");
    assert_eq!(status, wire::STATUS_OK);
    let via_bin = wire::decode_snapshot(&payload).expect("decode snapshot");
    assert_eq!(via_bin, hib);
    assert_eq!(wire::encode_snapshot(&via_bin), payload);
    let (status, payload) = bin.request(wire::OP_AGGREGATE, &[]).expect("binary round-trip");
    assert_eq!(status, wire::STATUS_OK);
    let agg = wire::decode_aggregate(&payload).expect("decode aggregate");
    assert_eq!(agg.footprint_bytes, hib_total);
    assert_eq!(wire::encode_aggregate(&agg), payload);
}

// ---------------------------------------------------------------------
// Empty-fleet and one-stream edges (network-reachable since the
// quantile-rank underflow fix)
// ---------------------------------------------------------------------

#[test]
fn empty_fleet_endpoints_answer_totally() {
    let empty = fleet_with(2, false, StreamConfig::new(16, 0.0).without_monitor());
    let server = FleetServer::start(empty, "127.0.0.1:0").expect("bind");
    let addr = server.local_addr();

    let agg = json::aggregate_from_json(&get_ok(addr, "/aggregate")).expect("decode");
    assert_eq!(agg, server.with_fleet(|f| f.aggregate()));
    assert_eq!(agg.live_streams, 0);

    let snap = json::snapshot_from_json(&get_ok(addr, "/snapshot")).expect("decode");
    assert!(snap.streams.is_empty());

    let top = json::top_k_from_json(&get_ok(addr, "/top_k_worst?k=3")).expect("decode");
    assert!(top.is_empty());

    let (_, count) =
        json::count_below_from_json(&get_ok(addr, "/count_below?t=0.5")).expect("decode");
    assert_eq!(count, 0);

    let hist = json::auc_histogram_from_json(&get_ok(addr, "/auc_histogram?bins=4")).expect("ok");
    assert_eq!(hist.counts, vec![0; 4]);
    let hist =
        json::score_histogram_from_json(&get_ok(addr, "/score_histogram?bins=4")).expect("ok");
    assert_eq!(hist.counts, vec![0; 4]);
}

#[test]
fn one_stream_fleet_serves_degenerate_quantiles() {
    let mut fleet = fleet_with(2, false, StreamConfig::new(16, 0.0).without_monitor());
    fleet.push_batch(&[(42, 0.2, true), (42, 0.8, false), (42, 0.5, true)]);
    let server = FleetServer::start(fleet, "127.0.0.1:0").expect("bind");
    let addr = server.local_addr();

    let agg = json::aggregate_from_json(&get_ok(addr, "/aggregate")).expect("decode");
    assert_eq!(agg, server.with_fleet(|f| f.aggregate()));
    assert_eq!(agg.live_streams, 1);
    // Every quantile of a one-stream fleet is that stream's AUC.
    for q in [agg.min_auc, agg.p10_auc, agg.median_auc, agg.p90_auc, agg.max_auc] {
        assert_eq!(q.to_bits(), agg.mean_auc.to_bits());
    }
    let top = json::top_k_from_json(&get_ok(addr, "/top_k_worst?k=8")).expect("decode");
    assert_eq!(top.len(), 1);
    assert_eq!(top[0].stream, 42);
}

// ---------------------------------------------------------------------
// Malformed requests error cleanly on both protocols
// ---------------------------------------------------------------------

#[test]
fn malformed_http_requests_get_client_errors_not_panics() {
    let server = FleetServer::start(mixed_fleet(2, false), "127.0.0.1:0").expect("bind");
    let addr = server.local_addr();

    // Zero-bin histograms: the in-process methods assert, the wire
    // surface must reject instead.
    bad_request(addr, "/auc_histogram?bins=0");
    bad_request(addr, "/score_histogram?bins=0");
    // Non-finite and unparseable thresholds.
    bad_request(addr, "/count_below?t=nan");
    bad_request(addr, "/count_below?t=inf");
    bad_request(addr, "/count_below?t=half");
    // Missing parameters.
    bad_request(addr, "/top_k_worst");
    bad_request(addr, "/count_below");
    bad_request(addr, "/auc_histogram");
    bad_request(addr, "/auc_histogram?bins=-1");

    let (status, body) = http_get(addr, "/nope").expect("http round-trip");
    assert_eq!(status, 404, "{body}");
    json::Json::parse(&body).expect("404 body is JSON");

    let (status, _) =
        raw_http(addr, "POST /aggregate HTTP/1.1\r\nConnection: close\r\n\r\n");
    assert_eq!(status, 400, "non-GET must be rejected");

    // The server survives all of the above.
    let agg = json::aggregate_from_json(&get_ok(addr, "/aggregate")).expect("decode");
    assert_eq!(agg, server.with_fleet(|f| f.aggregate()));
}

#[test]
fn malformed_binary_requests_get_error_frames() {
    let server = FleetServer::start(mixed_fleet(2, false), "127.0.0.1:0").expect("bind");
    let mut bin = BinClient::connect(server.local_addr()).expect("binary session");

    let mut expect_err = |op: u8, payload: &[u8]| {
        let (status, body) = bin.request(op, payload).expect("binary round-trip");
        assert_eq!(status, wire::STATUS_ERR, "opcode {op} must error");
        assert!(!body.is_empty(), "error frame carries a message");
        String::from_utf8(body).expect("error message is UTF-8");
    };

    expect_err(99, &[]); // unknown opcode
    expect_err(wire::OP_AUC_HISTOGRAM, &0u32.to_le_bytes());
    expect_err(wire::OP_SCORE_HISTOGRAM, &0u32.to_le_bytes());
    expect_err(wire::OP_COUNT_BELOW, &f64::NAN.to_bits().to_le_bytes());
    expect_err(wire::OP_COUNT_BELOW, &f64::INFINITY.to_bits().to_le_bytes());
    expect_err(wire::OP_TOP_K, &[1, 2]); // truncated k
    expect_err(wire::OP_SNAPSHOT, &[0]); // trailing payload

    // The session keeps working after rejected requests.
    let (status, payload) = bin.request(wire::OP_TOP_K, &2u32.to_le_bytes()).expect("ok");
    assert_eq!(status, wire::STATUS_OK);
    let top = wire::decode_top_k(&payload).expect("decode");
    assert_eq!(top, server.with_fleet(|f| f.top_k_worst(2)));
}

// ---------------------------------------------------------------------
// Keep-alive and concurrency
// ---------------------------------------------------------------------

#[test]
fn http_keep_alive_serves_many_requests_on_one_connection() {
    let server = FleetServer::start(mixed_fleet(2, false), "127.0.0.1:0").expect("bind");
    let mut client = HttpClient::connect(server.local_addr()).expect("connect");
    let reference = server.with_fleet(|f| f.aggregate());
    for _ in 0..25 {
        let (status, body) = client.get("/aggregate").expect("keep-alive get");
        assert_eq!(status, 200);
        assert_eq!(json::aggregate_from_json(&body).expect("decode"), reference);
    }
}

#[test]
fn queries_stay_well_formed_under_concurrent_pooled_ingestion() {
    let fleet = fleet_with(4, true, StreamConfig::new(32, 0.1).without_monitor());
    let server = std::sync::Arc::new(FleetServer::start(fleet, "127.0.0.1:0").expect("bind"));
    let addr = server.local_addr();

    let ingest = {
        let server = std::sync::Arc::clone(&server);
        std::thread::spawn(move || {
            for round in 0..40u64 {
                server.ingest_batch(&delta_batch(0xFEED ^ round));
            }
        })
    };
    let mut client = HttpClient::connect(addr).expect("connect");
    for i in 0..60 {
        let target = match i % 4 {
            0 => "/aggregate",
            1 => "/snapshot",
            2 => "/top_k_worst?k=3",
            _ => "/auc_histogram?bins=5",
        };
        let (status, body) = client.get(target).expect("get under ingestion");
        assert_eq!(status, 200);
        // Under live mutation the *value* changes between requests,
        // but every response must still be a complete, decodable
        // document.
        match i % 4 {
            0 => {
                json::aggregate_from_json(&body).expect("decode");
            }
            1 => {
                json::snapshot_from_json(&body).expect("decode");
            }
            2 => {
                json::top_k_from_json(&body).expect("decode");
            }
            _ => {
                json::auc_histogram_from_json(&body).expect("decode");
            }
        }
    }
    ingest.join().expect("ingest thread");
    // Quiesced: wire and in-process agree again, byte-derived.
    let body = get_ok(addr, "/aggregate");
    let agg = json::aggregate_from_json(&body).expect("decode");
    assert_eq!(agg, server.with_fleet(|f| f.aggregate()));
    assert_eq!(json::aggregate_to_json(&agg), body);
}

// ---------------------------------------------------------------------
// Subscriptions
// ---------------------------------------------------------------------

#[test]
fn http_subscription_baseline_plus_deltas_reconstruct_the_sketch() {
    let server = FleetServer::start(mixed_fleet(2, false), "127.0.0.1:0").expect("bind");
    let mut lines = http_subscribe(server.local_addr()).expect("subscribe");

    let baseline_line = lines.next().expect("baseline line").expect("read");
    let (base_seq, mut sketch) = json::sketch_from_json(&baseline_line).expect("decode baseline");
    assert_eq!(sketch, server.with_fleet(|f| f.sketch_state()));

    for round in 0..3u64 {
        server.ingest_batch(&delta_batch(0xD17A ^ round));
        let delta_line = lines.next().expect("delta line").expect("read");
        let seq = json::apply_subscription_json(&delta_line, &mut sketch).expect("apply");
        // Gapless: one delta per publishing drain, in order.
        assert_eq!(seq, base_seq + round + 1);
        let (want_seq, want) = server.last_published();
        assert_eq!((seq, &sketch), (want_seq, &want));
    }
    assert_eq!(sketch, server.with_fleet(|f| f.sketch_state()));
}

#[test]
fn binary_subscription_baseline_plus_deltas_reconstruct_the_sketch() {
    let server = FleetServer::start(mixed_fleet(4, true), "127.0.0.1:0").expect("bind");
    let mut bin = BinClient::connect(server.local_addr()).expect("binary session");

    let baseline = bin.subscribe().expect("subscribe");
    let (base_seq, mut sketch) = wire::decode_sketch(&baseline).expect("decode baseline");
    assert_eq!(sketch, server.with_fleet(|f| f.sketch_state()));
    assert_eq!(server.subscriber_count(), 1);

    // A quiet drain publishes nothing.
    server.ingest_batch(&[]);
    assert_eq!(server.last_published().0, base_seq);

    for round in 0..3u64 {
        server.ingest_batch(&delta_batch(0xB1A5 ^ round));
        let payload = bin.next_delta().expect("delta frame");
        let seq = wire::apply_delta(&payload, &mut sketch).expect("apply");
        assert_eq!(seq, base_seq + round + 1);
        let (want_seq, want) = server.last_published();
        assert_eq!((seq, &sketch), (want_seq, &want));
    }
    assert_eq!(sketch, server.with_fleet(|f| f.sketch_state()));
}

#[test]
fn dropped_subscribers_are_pruned_on_the_next_publish() {
    let server = FleetServer::start(mixed_fleet(2, false), "127.0.0.1:0").expect("bind");
    {
        let mut bin = BinClient::connect(server.local_addr()).expect("binary session");
        bin.subscribe().expect("subscribe");
        assert_eq!(server.subscriber_count(), 1);
    } // client dropped — socket closed
    // Publishing notices the dead socket and prunes it. Early writes
    // can still land in the closed socket's buffer until the kernel
    // processes the reset, so publish until the prune shows up.
    for round in 0..50u64 {
        server.ingest_batch(&delta_batch(0xDEAD ^ round));
        if server.subscriber_count() == 0 {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(10));
    }
    assert_eq!(server.subscriber_count(), 0);
}

// ---------------------------------------------------------------------
// Seq echo: every response names the publication epoch it answers at,
// and the answer is bit-identical to the in-process query at that seq
// ---------------------------------------------------------------------

#[test]
fn every_response_echoes_the_seq_it_answers_at() {
    let server = FleetServer::start(mixed_fleet(2, false), "127.0.0.1:0").expect("bind");
    let addr = server.local_addr();
    let mut http = HttpClient::connect(addr).expect("connect");
    let mut bin = BinClient::connect(addr).expect("binary session");

    let (status, body) = http.get("/aggregate").expect("get");
    assert_eq!(status, 200);
    let seq = http.last_seq().expect("200 responses echo a seq");
    let view = server.published_view();
    assert_eq!(view.seq(), seq, "the echo names the current published epoch");
    // Bit-identity at the echoed seq: re-encoding that epoch's view
    // reproduces the exact response bytes.
    assert_eq!(json::aggregate_to_json(view.aggregate()), body);

    // Errors answer at an epoch too.
    let (status, _) = http.get("/nope").expect("get");
    assert_eq!(status, 404);
    assert_eq!(http.last_seq(), Some(seq));

    let (bstatus, payload) = bin.request(wire::OP_SNAPSHOT, &[]).expect("round-trip");
    assert_eq!(bstatus, wire::STATUS_OK);
    assert_eq!(bin.last_seq(), Some(seq));
    assert_eq!(wire::encode_snapshot(view.snapshot()), payload);

    let (bstatus, _) = bin.request(99, &[]).expect("round-trip");
    assert_eq!(bstatus, wire::STATUS_ERR);
    assert_eq!(bin.last_seq(), Some(seq), "error frames echo the epoch");

    // Ingestion that changes the sketch bumps the epoch by exactly
    // one; fresh responses echo the new seq and answer at it.
    server.ingest_batch(&delta_batch(0x5EC0));
    let (status, body) = http.get("/top_k_worst?k=6").expect("get");
    assert_eq!(status, 200);
    assert_eq!(http.last_seq(), Some(seq + 1));
    let view = server.published_view();
    assert_eq!(view.seq(), seq + 1);
    assert_eq!(json::top_k_to_json(&view.top_k_worst(6)), body);

    let (bstatus, payload) =
        bin.request(wire::OP_AUC_HISTOGRAM, &8u32.to_le_bytes()).expect("round-trip");
    assert_eq!(bstatus, wire::STATUS_OK);
    assert_eq!(bin.last_seq(), Some(seq + 1));
    assert_eq!(wire::encode_auc_histogram(&view.auc_histogram(8)), payload);
}

#[test]
fn seq_echoes_are_monotonic_under_concurrent_ingestion() {
    let fleet = fleet_with(4, true, StreamConfig::new(32, 0.1).without_monitor());
    let server = Arc::new(FleetServer::start(fleet, "127.0.0.1:0").expect("bind"));
    let ingest = {
        let server = Arc::clone(&server);
        thread::spawn(move || {
            for round in 0..40u64 {
                server.ingest_batch(&delta_batch(0xC0DE ^ round));
            }
        })
    };
    let mut client = HttpClient::connect(server.local_addr()).expect("connect");
    let mut last = 0u64;
    for _ in 0..60 {
        let (status, _) = client.get("/aggregate").expect("get under ingestion");
        assert_eq!(status, 200);
        let seq = client.last_seq().expect("echo");
        assert!(seq >= last, "seq echo went backwards: {seq} < {last}");
        last = seq;
    }
    ingest.join().expect("ingest thread");
    // Quiesced, the echo is exactly the last published epoch.
    let (status, _) = client.get("/aggregate").expect("get");
    assert_eq!(status, 200);
    assert_eq!(client.last_seq(), Some(server.last_published().0));
}

/// The published view's query methods — what the wire serves without
/// the fleet lock — must match the fleet's own answers exactly,
/// including the non-divisor bin counts that exercise the direct
/// rebin formula rather than the sketch group-sum.
#[test]
fn published_view_queries_match_the_fleet_exactly() {
    let server = FleetServer::start(mixed_fleet(2, false), "127.0.0.1:0").expect("bind");
    let view = server.published_view();
    server.with_fleet(|f| {
        assert_eq!(view.snapshot(), &f.snapshot());
        assert_eq!(view.aggregate(), &f.aggregate());
        for k in [0, 1, 3, 24, 100] {
            assert_eq!(view.top_k_worst(k), f.top_k_worst(k), "k={k}");
        }
        for t in [-1.0, 0.0, 0.015625, 0.25, 0.5, 0.9999, 1.0, 3.5, f64::NAN] {
            assert_eq!(view.count_below(t), f.count_below(t), "t={t}");
        }
        for bins in [1, 2, 7, 10, 13, 64] {
            assert_eq!(view.auc_histogram(bins), f.auc_histogram(bins), "bins={bins}");
        }
    });

    // Epoch isolation: a retained view keeps answering its own epoch
    // after the fleet moves on; the server's current view advances.
    let before = json::aggregate_to_json(view.aggregate());
    server.ingest_batch(&delta_batch(0xE90C));
    assert_eq!(json::aggregate_to_json(view.aggregate()), before);
    let after = server.published_view();
    assert_eq!(after.seq(), view.seq() + 1);
    assert_eq!(after.aggregate(), &server.with_fleet(|f| f.aggregate()));
}

// ---------------------------------------------------------------------
// Subscriber lag: fan-out is queue-only, so ingestion never waits on
// a socket, and a lagging subscriber is coalesced onto a fresh
// baseline instead of being fed an unbounded backlog
// ---------------------------------------------------------------------

#[test]
fn unread_subscriber_cannot_stall_ingestion() {
    let server = FleetServer::start_with(
        mixed_fleet(2, false),
        "127.0.0.1:0",
        ServeLimits { workers: 2, max_conns: 8, timeout: Duration::from_secs(30) },
    )
    .expect("bind");
    let addr = server.local_addr();

    // A subscriber that never reads a byte.
    let mut sock = TcpStream::connect(addr).expect("connect");
    sock.write_all(b"GET /subscribe HTTP/1.1\r\nHost: fleet\r\n\r\n").expect("send");
    let t0 = Instant::now();
    while server.subscriber_count() == 0 {
        assert!(t0.elapsed() < Duration::from_secs(10), "subscriber never attached");
        thread::sleep(Duration::from_millis(5));
    }

    // 400 drains publish far more than the subscriber's bounded queue
    // plus its unread socket can absorb. The publisher only ever
    // try_sends, so this completes at ingestion speed — with the old
    // blocking fan-out it would wedge on the first full socket buffer.
    let t0 = Instant::now();
    for round in 0..400u64 {
        server.ingest_batch(&delta_batch(0x57A1 ^ round));
    }
    assert!(
        t0.elapsed() < Duration::from_secs(30),
        "ingestion stalled behind an unread subscriber: {:?}",
        t0.elapsed()
    );

    // And reads still answer, exactly.
    let agg = json::aggregate_from_json(&get_ok(addr, "/aggregate")).expect("decode");
    assert_eq!(agg, server.with_fleet(|f| f.aggregate()));
    drop(sock);
}

#[test]
fn lagged_subscriber_resyncs_with_a_notice_and_fresh_baseline() {
    let server = FleetServer::start_with(
        mixed_fleet(1, false),
        "127.0.0.1:0",
        ServeLimits { workers: 2, max_conns: 8, timeout: Duration::from_secs(120) },
    )
    .expect("bind");
    let mut bin = BinClient::connect(server.local_addr()).expect("binary session");
    let baseline = bin.subscribe().expect("subscribe");
    let (base_seq, mut sketch) = wire::decode_sketch(&baseline).expect("decode baseline");

    // Publish far more delta bytes than the subscriber's bounded queue
    // plus its unread socket buffers can hold: the writer blocks on
    // the full socket, the queue fills, and the publisher marks the
    // subscriber lagged instead of waiting.
    let t0 = Instant::now();
    for round in 0..4000u64 {
        server.ingest_batch(&churn_batch(round));
    }
    assert!(
        t0.elapsed() < Duration::from_secs(60),
        "publishing stalled behind a lagging subscriber: {:?}",
        t0.elapsed()
    );
    let (final_seq, final_sketch) = server.last_published();

    // Drain the stream: pre-lag deltas apply gaplessly, then one
    // lagged notice announces the jump, and the very next frame is a
    // fresh baseline replacing everything missed.
    let mut seq = base_seq;
    let mut saw_lag = false;
    while seq < final_seq {
        match bin.next_event().expect("subscription event") {
            SubEvent::Delta(payload) => {
                let got = wire::apply_delta(&payload, &mut sketch).expect("apply");
                assert_eq!(got, seq + 1, "delta stream must stay gapless");
                seq = got;
            }
            SubEvent::Lagged(at) => {
                let payload = match bin.next_event().expect("frame after lag notice") {
                    SubEvent::Baseline(payload) => payload,
                    _ => panic!("a lagged notice must be followed by a baseline"),
                };
                let (bseq, fresh) = wire::decode_sketch(&payload).expect("decode baseline");
                assert_eq!(bseq, at, "the baseline answers at the notice's seq");
                assert!(at > seq, "a resync must move the subscriber forward");
                sketch = fresh;
                seq = at;
                saw_lag = true;
            }
            SubEvent::Baseline(_) => panic!("baseline without a lagged notice"),
        }
    }
    assert!(saw_lag, "the subscriber never lagged — raise the round count");
    assert_eq!((seq, sketch), (final_seq, final_sketch));
}

// ---------------------------------------------------------------------
// Hostile clients: answer or shed, never panic or wedge
// ---------------------------------------------------------------------

#[test]
fn oversized_http_heads_get_431_and_a_close() {
    let server = FleetServer::start(mixed_fleet(2, false), "127.0.0.1:0").expect("bind");
    let addr = server.local_addr();

    // One endless request line. Sized so the server consumes exactly
    // what we send (its cap probe reads MAX_HEAD_BYTES + 1 bytes) —
    // no unread bytes, so the close is a clean FIN, not an RST race.
    let mut s = TcpStream::connect(addr).expect("connect");
    s.write_all(&vec![b'A'; MAX_HEAD_BYTES + 1]).expect("send");
    let mut buf = String::new();
    s.read_to_string(&mut buf).expect("read");
    assert!(buf.starts_with("HTTP/1.1 431 "), "{buf:?}");

    // A legal request line followed by endless headers; 4-byte filler
    // lines land the cap exactly on a line boundary (the request line
    // counts toward the cap), again leaving nothing unread.
    let mut s = TcpStream::connect(addr).expect("connect");
    let first = b"GET / HTTP/1.1\r\n";
    s.write_all(first).expect("send");
    assert_eq!((MAX_HEAD_BYTES - first.len()) % 4, 0);
    for _ in 0..(MAX_HEAD_BYTES - first.len()) / 4 {
        s.write_all(b"A:\r\n").expect("send filler header");
    }
    let mut buf = String::new();
    s.read_to_string(&mut buf).expect("read");
    assert!(buf.starts_with("HTTP/1.1 431 "), "{buf:?}");

    // The server shrugged both off.
    get_ok(addr, "/aggregate");
}

#[test]
fn slow_heads_time_out_with_408_and_half_open_connects_close_quietly() {
    let server = FleetServer::start_with(
        mixed_fleet(1, false),
        "127.0.0.1:0",
        ServeLimits { workers: 1, max_conns: 4, timeout: Duration::from_millis(300) },
    )
    .expect("bind");
    let addr = server.local_addr();

    // Half-open: connect and send nothing. The worker's first-byte
    // wait expires and the connection is dropped without a response.
    let mut idle = TcpStream::connect(addr).expect("connect");
    let mut buf = String::new();
    idle.read_to_string(&mut buf).expect("read");
    assert!(buf.is_empty(), "half-open connections get no response, got {buf:?}");

    // Slow-loris: a complete request line, then silence. The head
    // deadline expires and the server answers 408 before closing —
    // and with workers=1 this also proves the worker was released by
    // the half-open connection above.
    let mut slow = TcpStream::connect(addr).expect("connect");
    slow.write_all(b"GET /aggregate HTTP/1.1\r\n").expect("send");
    let mut buf = String::new();
    slow.read_to_string(&mut buf).expect("read");
    assert!(buf.starts_with("HTTP/1.1 408 "), "{buf:?}");

    // The lone worker survived both and still answers.
    get_ok(addr, "/aggregate");
}

#[test]
fn hostile_preambles_and_broken_frames_never_wedge_the_server() {
    let server = FleetServer::start_with(
        mixed_fleet(2, false),
        "127.0.0.1:0",
        ServeLimits { workers: 2, max_conns: 8, timeout: Duration::from_millis(500) },
    )
    .expect("bind");
    let addr = server.local_addr();

    // Printable garbage preamble: routed as HTTP, rejected politely.
    let mut s = TcpStream::connect(addr).expect("connect");
    s.write_all(b"garbage preamble\r\n\r\n").expect("send");
    let mut buf = String::new();
    s.read_to_string(&mut buf).expect("read");
    assert!(buf.starts_with("HTTP/1.1 400 "), "{buf:?}");

    // Non-UTF-8 garbage that is not the protocol magic: closed
    // quietly — there is no dialect to answer in.
    let mut s = TcpStream::connect(addr).expect("connect");
    s.write_all(&[0xFF, 0xFE, 0xFD, b'\n']).expect("send");
    let mut junk = Vec::new();
    s.read_to_end(&mut junk).expect("read");
    assert!(junk.is_empty(), "binary garbage gets no response");

    // A magic-like preamble that is not the magic: one error frame.
    let mut s = TcpStream::connect(addr).expect("connect");
    s.write_all(&[wire::MAGIC[0], b'X', b'Y', b'Z']).expect("send");
    let (op, payload) = wire::read_frame(&mut s).expect("error frame");
    assert_eq!(op, wire::STATUS_ERR);
    assert_eq!(&payload[8..], b"bad magic");

    // Mid-frame hangup: magic, an opcode, half a length header, gone.
    let mut s = TcpStream::connect(addr).expect("connect");
    s.write_all(&wire::MAGIC).expect("send");
    s.write_all(&[wire::OP_SNAPSHOT, 0x10]).expect("send");
    drop(s);

    // Oversized frame length: rejected before any allocation, with an
    // error frame naming the cap, then closed.
    let mut s = TcpStream::connect(addr).expect("connect");
    s.write_all(&wire::MAGIC).expect("send");
    s.write_all(&[wire::OP_SNAPSHOT]).expect("send");
    s.write_all(&(8u32 << 20).to_le_bytes()).expect("send");
    let (op, payload) = wire::read_frame(&mut s).expect("error frame");
    assert_eq!(op, wire::STATUS_ERR);
    let msg = String::from_utf8(payload[8..].to_vec()).expect("utf8 message");
    assert!(msg.contains("exceeds"), "{msg}");
    let mut rest = Vec::new();
    s.read_to_end(&mut rest).expect("read");
    assert!(rest.is_empty(), "connection must close after an oversized frame");

    // A clean client still gets exact answers after all of the above.
    let body = get_ok(addr, "/aggregate");
    let agg = json::aggregate_from_json(&body).expect("decode");
    assert_eq!(agg, server.with_fleet(|f| f.aggregate()));
    let mut bin = BinClient::connect(addr).expect("binary session");
    let (status, payload) = bin.request(wire::OP_AGGREGATE, &[]).expect("round-trip");
    assert_eq!(status, wire::STATUS_OK);
    assert_eq!(wire::decode_aggregate(&payload).expect("decode"), agg);
}

#[test]
fn connect_floods_past_max_conns_are_shed_with_busy_answers() {
    let server = FleetServer::start_with(
        mixed_fleet(1, false),
        "127.0.0.1:0",
        ServeLimits { workers: 1, max_conns: 2, timeout: Duration::from_secs(2) },
    )
    .expect("bind");
    let addr = server.local_addr();

    // Pin the lone worker: a connection that starts a binary frame
    // and stalls holds it for one deadline budget.
    let mut pin = TcpStream::connect(addr).expect("connect");
    pin.write_all(&wire::MAGIC).expect("send");
    pin.write_all(&[wire::OP_SNAPSHOT]).expect("send");
    thread::sleep(Duration::from_millis(100)); // let the worker claim it

    // Fill the accept queue behind it.
    let q1 = TcpStream::connect(addr).expect("connect");
    let q2 = TcpStream::connect(addr).expect("connect");
    thread::sleep(Duration::from_millis(100)); // let the acceptor queue both

    // Overflow is shed with the dialect-appropriate busy answer.
    let mut flood_http = TcpStream::connect(addr).expect("connect");
    flood_http.write_all(b"GET /aggregate HTTP/1.1\r\nHost: x\r\n\r\n").expect("send");
    let mut buf = String::new();
    flood_http.read_to_string(&mut buf).expect("read");
    assert!(buf.starts_with("HTTP/1.1 503 "), "{buf:?}");

    let mut flood_bin = TcpStream::connect(addr).expect("connect");
    flood_bin.write_all(&wire::MAGIC).expect("send");
    let (op, payload) = wire::read_frame(&mut flood_bin).expect("busy frame");
    assert_eq!(op, wire::STATUS_BUSY);
    assert!(String::from_utf8_lossy(&payload[8..]).contains("busy"));

    // Release everything; the server drains and recovers.
    drop(pin);
    drop(q1);
    drop(q2);
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        match http_get(addr, "/aggregate") {
            Ok((200, body)) => {
                json::aggregate_from_json(&body).expect("decode");
                break;
            }
            _ => {
                assert!(Instant::now() < deadline, "server did not recover from the flood");
                thread::sleep(Duration::from_millis(50));
            }
        }
    }
}

#[test]
fn subscriber_overflow_is_shed_with_busy_not_queued() {
    let server = FleetServer::start_with(
        mixed_fleet(2, false),
        "127.0.0.1:0",
        ServeLimits { workers: 2, max_conns: 2, timeout: Duration::from_secs(5) },
    )
    .expect("bind");
    let addr = server.local_addr();

    let mut a = BinClient::connect(addr).expect("first subscriber");
    a.subscribe().expect("subscribe");
    let mut b = BinClient::connect(addr).expect("second subscriber");
    b.subscribe().expect("subscribe");
    assert_eq!(server.subscriber_count(), 2);

    let mut c = BinClient::connect(addr).expect("third connection");
    let err = c.subscribe().expect_err("subscriber cap reached must answer busy");
    assert!(err.to_string().contains("busy"), "{err}");
}

// ---------------------------------------------------------------------
// Shutdown drains: no connection outlives it, no new answers after it
// ---------------------------------------------------------------------

#[test]
fn shutdown_drains_connections_and_refuses_new_answers() {
    let mut server = FleetServer::start(mixed_fleet(2, false), "127.0.0.1:0").expect("bind");
    let addr = server.local_addr();

    // Live traffic: a keep-alive reader and an attached subscriber.
    let mut client = HttpClient::connect(addr).expect("connect");
    let (status, _) = client.get("/aggregate").expect("get");
    assert_eq!(status, 200);
    let mut sub = BinClient::connect(addr).expect("binary session");
    sub.subscribe().expect("subscribe");

    let t0 = Instant::now();
    server.shutdown();
    assert!(
        t0.elapsed() < Duration::from_secs(10),
        "shutdown did not drain promptly: {:?}",
        t0.elapsed()
    );

    // The drain half-closed every live socket...
    assert!(client.get("/aggregate").is_err(), "keep-alive connection must be gone");
    assert!(sub.next_event().is_err(), "subscriber stream must be gone");
    // ...and the port no longer answers at all.
    assert!(http_get(addr, "/aggregate").is_err(), "no new answers after shutdown");
}
