//! Fleet and per-stream configuration.
//!
//! Every stream in an [`AucFleet`](super::AucFleet) owns an independent
//! sliding window; the fleet applies [`FleetConfig::stream_defaults`]
//! to streams it has never seen and per-stream overrides registered
//! with [`AucFleet::configure_stream`](super::AucFleet::configure_stream)
//! otherwise. All configs are plain `Copy` data so the hot ingestion
//! path never clones heap state.

use crate::coordinator::approx::ApproxCore;
use crate::coordinator::maintained::MaintainedCore;
use crate::coordinator::support::EstimatorArenas;
use crate::coordinator::window::Window;
use crate::coordinator::{ApproxAuc, AucEstimator, AucMonitor, BinnedAuc, MaintainedExactAuc};

/// Bin-count ceiling for [`StreamConfig::auto`]: a requested ε whose
/// `⌈2/ε⌉` cells would exceed this stays on the `(1+ε)`-compressed
/// sketch instead (beyond this the flat arrays stop being the obvious
/// cache win, and `ε = 0` — exactness — is never binnable).
pub const MAX_AUTO_BINS: usize = 4096;

/// Which estimator a stream runs behind its sliding window.
///
/// All kinds satisfy the same O(1)-read contract (`DESIGN.md`
/// §Estimators), so exactness-critical, approximate and bounded-score
/// streams coexist in one fleet — sketches, snapshots, aggregates and
/// the digest determinism contract are estimator-agnostic.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum EstimatorKind {
    /// The paper's `(1+ε)`-compressed estimator:
    /// `|ãuc − auc| ≤ ε·auc/2`, `O((log k)/ε)` update, smallest
    /// footprint (`|C| ∈ O((log k)/ε)` cells).
    Approx {
        /// Approximation parameter `ε ≥ 0`.
        epsilon: f64,
    },
    /// Tree-maintained exact AUC (Tatti 2021): no ε at all, `O(log k)`
    /// update, one tree node per distinct score. Pick it for streams
    /// where the estimate feeds decisions that cannot tolerate even the
    /// ε/2 slack; pay ~`O(k)` memory per window in exchange.
    ExactMaintained,
    /// Fixed-bin fast path over a declared bounded score range
    /// (`coordinator/binned.rs`): two flat count arrays, no tree or
    /// list, update bounded by the small `k`-independent bin count,
    /// `O(1)` read, discretization error
    /// `≤ Σ_b p_b·n_b / (2·P·N)` — cell width `(hi−lo)/bins` plays the
    /// role of ε/2. Scores outside `[lo, hi]` are rejected at the shard
    /// boundary with a panic naming the stream.
    Binned {
        /// Number of equal cells over `[lo, hi]`; must be ≥ 1.
        bins: usize,
        /// Inclusive lower score bound; must be finite and `< hi`.
        lo: f64,
        /// Inclusive upper score bound; must be finite and `> lo`.
        hi: f64,
    },
}

impl EstimatorKind {
    /// Instantiate the per-stream estimator.
    ///
    /// # Panics
    ///
    /// For [`EstimatorKind::Binned`], on `bins == 0`, non-finite
    /// bounds, or `lo >= hi` ([`BinnedAuc::new`] validates) — the
    /// backstop behind the CLI / [`StreamConfig::binned`] checks for
    /// hand-built kinds.
    pub(crate) fn build(self) -> FleetEstimator {
        match self {
            EstimatorKind::Approx { epsilon } => {
                FleetEstimator::Approx(ApproxAuc::new(epsilon))
            }
            EstimatorKind::ExactMaintained => {
                FleetEstimator::Exact(MaintainedExactAuc::new())
            }
            EstimatorKind::Binned { bins, lo, hi } => {
                FleetEstimator::Binned(BinnedAuc::new(bins, lo, hi))
            }
        }
    }
}

/// The estimator actually held by a fleet stream: either kind behind
/// one enum so `StreamState` stays a single concrete type (no dyn
/// dispatch on the ingest hot path — one match, both arms inlinable).
#[derive(Clone, Debug)]
pub enum FleetEstimator {
    /// `(1+ε)`-compressed approximate estimator.
    Approx(ApproxAuc),
    /// Tree-maintained exact estimator.
    Exact(MaintainedExactAuc),
    /// Fixed-bin bounded-score estimator.
    Binned(BinnedAuc),
}

impl FleetEstimator {
    /// Size of the structure the estimator maintains beyond the window
    /// itself: compressed-list cells for [`ApproxAuc`], distinct-score
    /// tree nodes for [`MaintainedExactAuc`], `2·bins` count cells for
    /// [`BinnedAuc`]. Feeds `StreamSnapshot::compressed_len`.
    pub fn footprint(&self) -> usize {
        match self {
            FleetEstimator::Approx(e) => e.compressed_len(),
            FleetEstimator::Exact(e) => e.distinct_scores(),
            FleetEstimator::Binned(e) => 2 * e.bins(),
        }
    }

    /// The declared bounded score range of a binned stream; `None` for
    /// the estimators that accept any finite score. The shard ingest
    /// boundary uses this to reject out-of-range scores *before* any
    /// state mutates, with a panic naming the stream.
    pub fn declared_range(&self) -> Option<(f64, f64)> {
        match self {
            FleetEstimator::Binned(e) => Some(e.range()),
            FleetEstimator::Approx(_) | FleetEstimator::Exact(_) => None,
        }
    }
}

impl AucEstimator for FleetEstimator {
    fn insert(&mut self, score: f64, pos: bool) {
        match self {
            FleetEstimator::Approx(e) => e.insert(score, pos),
            FleetEstimator::Exact(e) => e.insert(score, pos),
            FleetEstimator::Binned(e) => e.insert(score, pos),
        }
    }

    fn remove(&mut self, score: f64, pos: bool) {
        match self {
            FleetEstimator::Approx(e) => e.remove(score, pos),
            FleetEstimator::Exact(e) => e.remove(score, pos),
            FleetEstimator::Binned(e) => e.remove(score, pos),
        }
    }

    fn auc(&self) -> f64 {
        match self {
            FleetEstimator::Approx(e) => e.auc(),
            FleetEstimator::Exact(e) => e.auc(),
            FleetEstimator::Binned(e) => e.auc(),
        }
    }

    fn len(&self) -> usize {
        match self {
            FleetEstimator::Approx(e) => e.len(),
            FleetEstimator::Exact(e) => e.len(),
            FleetEstimator::Binned(e) => e.len(),
        }
    }
}

// Stream windows over this enum drain on the fleet's worker threads.
const _: () = {
    const fn assert_send<T: Send>() {}
    assert_send::<FleetEstimator>();
    assert_send::<Window<FleetEstimator>>();
};

impl EstimatorKind {
    /// Instantiate the pooled (arena-backed) per-stream estimator, with
    /// its node/cell storage in the shard's shared `ars`. The fleet's
    /// stream states hold this form; [`EstimatorKind::build`] remains
    /// for standalone (self-owning) use.
    ///
    /// # Panics
    ///
    /// Same validation as [`EstimatorKind::build`].
    pub(crate) fn build_in(self, ars: &mut EstimatorArenas) -> PooledEstimator {
        match self {
            EstimatorKind::Approx { epsilon } => {
                PooledEstimator::Approx(ApproxCore::new_in(ars, epsilon))
            }
            EstimatorKind::ExactMaintained => PooledEstimator::Exact(MaintainedCore::new()),
            EstimatorKind::Binned { bins, lo, hi } => {
                PooledEstimator::Binned(BinnedAuc::new(bins, lo, hi))
            }
        }
    }
}

/// The arena-backed counterpart of [`FleetEstimator`]: the handle form
/// the fleet's stream states actually hold. Tree nodes and list cells
/// live in the owning shard's [`EstimatorArenas`]; this enum is just
/// roots, counters and accumulators (the binned arm keeps its two flat
/// count arrays — they are contiguous and `k`-independent, so pooling
/// them buys nothing). Every operation that touches node/cell storage
/// takes the shard's arenas explicitly.
#[derive(Clone, Debug)]
pub(crate) enum PooledEstimator {
    /// `(1+ε)`-compressed approximate estimator (arena-backed core).
    Approx(ApproxCore),
    /// Tree-maintained exact estimator (arena-backed core).
    Exact(MaintainedCore),
    /// Fixed-bin bounded-score estimator (self-contained; no arena use).
    Binned(BinnedAuc),
}

impl PooledEstimator {
    pub(crate) fn insert_in(&mut self, ars: &mut EstimatorArenas, score: f64, pos: bool) {
        match self {
            PooledEstimator::Approx(e) => e.insert_in(ars, score, pos),
            PooledEstimator::Exact(e) => e.insert_in(ars, score, pos),
            PooledEstimator::Binned(e) => e.insert(score, pos),
        }
    }

    pub(crate) fn remove_in(&mut self, ars: &mut EstimatorArenas, score: f64, pos: bool) {
        match self {
            PooledEstimator::Approx(e) => e.remove_in(ars, score, pos),
            PooledEstimator::Exact(e) => e.remove_in(ars, score, pos),
            PooledEstimator::Binned(e) => e.remove(score, pos),
        }
    }

    /// O(1) read — all three arms maintain their doubled-area
    /// accumulator incrementally.
    pub(crate) fn auc(&self) -> f64 {
        match self {
            PooledEstimator::Approx(e) => e.auc(),
            PooledEstimator::Exact(e) => e.auc(),
            PooledEstimator::Binned(e) => e.auc(),
        }
    }

    pub(crate) fn len(&self) -> usize {
        match self {
            PooledEstimator::Approx(e) => e.len(),
            PooledEstimator::Exact(e) => e.len(),
            PooledEstimator::Binned(e) => e.len(),
        }
    }

    /// Structure size in cells/nodes — same semantics as
    /// [`FleetEstimator::footprint`] (feeds `StreamSnapshot::compressed_len`).
    pub(crate) fn footprint(&self) -> usize {
        match self {
            PooledEstimator::Approx(e) => e.compressed_len(),
            PooledEstimator::Exact(e) => e.distinct_scores(),
            PooledEstimator::Binned(e) => 2 * e.bins(),
        }
    }

    /// Logical bytes of backing storage (arena slots or flat arrays)
    /// this stream's estimator occupies. Content-determined — live
    /// counts times slot sizes, never allocation capacity — so served
    /// footprints cannot depend on pool scheduling.
    pub(crate) fn footprint_bytes(&self) -> usize {
        match self {
            PooledEstimator::Approx(e) => e.live_bytes(),
            PooledEstimator::Exact(e) => e.live_bytes(),
            PooledEstimator::Binned(e) => e.footprint_bytes(),
        }
    }

    /// Declared bounded score range of a binned stream; `None`
    /// otherwise. Same contract as [`FleetEstimator::declared_range`].
    pub(crate) fn declared_range(&self) -> Option<(f64, f64)> {
        match self {
            PooledEstimator::Binned(e) => Some(e.range()),
            PooledEstimator::Approx(_) | PooledEstimator::Exact(_) => None,
        }
    }

    /// Return every arena slot this estimator holds to the shard's free
    /// lists (eviction / hibernation). The estimator is unusable
    /// afterwards and must be dropped.
    pub(crate) fn free_in(&mut self, ars: &mut EstimatorArenas) {
        match self {
            PooledEstimator::Approx(e) => e.free_in(ars),
            PooledEstimator::Exact(e) => e.free_in(ars),
            PooledEstimator::Binned(_) => {}
        }
    }
}

const _: () = {
    const fn assert_send<T: Send>() {}
    assert_send::<PooledEstimator>();
    assert_send::<EstimatorArenas>();
};

/// Drift-monitor parameters for one stream (see [`AucMonitor::new`] for
/// the λ-vs-window guidance).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct MonitorConfig {
    /// EWMA decay factor for the baseline (weight of the new sample).
    pub lambda: f64,
    /// Absolute AUC margin below baseline that counts as degradation.
    pub margin: f64,
    /// Consecutive degraded observations before the alarm fires.
    pub patience: u32,
    /// Observations before the baseline is trusted.
    pub warmup: u32,
}

impl Default for MonitorConfig {
    fn default() -> Self {
        // Tuned for the default stream window of 500: baseline time
        // constant ≫ window, margin above windowed-estimate noise.
        MonitorConfig { lambda: 0.001, margin: 0.08, patience: 100, warmup: 500 }
    }
}

impl MonitorConfig {
    /// Instantiate the monitor.
    pub fn build(&self) -> AucMonitor {
        AucMonitor::new(self.lambda, self.margin, self.patience, self.warmup)
    }
}

/// Per-stream estimator configuration.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct StreamConfig {
    /// Sliding-window capacity `k`.
    pub window: usize,
    /// Which estimator backs the window (approximate with its ε,
    /// tree-maintained exact, or binned over a declared score range).
    pub estimator: EstimatorKind,
    /// Drift monitor; `None` disables monitoring for the stream (saves
    /// one `O(1)` AUC read per update).
    pub monitor: Option<MonitorConfig>,
}

impl Default for StreamConfig {
    fn default() -> Self {
        StreamConfig {
            window: 500,
            estimator: EstimatorKind::Approx { epsilon: 0.05 },
            monitor: Some(MonitorConfig::default()),
        }
    }
}

impl StreamConfig {
    /// Window/ε constructor with default monitoring (the approximate
    /// estimator — the fleet-scale default).
    pub fn new(window: usize, epsilon: f64) -> Self {
        StreamConfig { window, estimator: EstimatorKind::Approx { epsilon }, ..Default::default() }
    }

    /// Exact-maintained constructor with default monitoring, for
    /// exactness-critical streams.
    pub fn exact(window: usize) -> Self {
        StreamConfig { window, estimator: EstimatorKind::ExactMaintained, ..Default::default() }
    }

    /// Binned constructor with default monitoring, for streams whose
    /// scores are declared bounded to `[lo, hi]`.
    ///
    /// # Panics
    ///
    /// On `bins == 0`, non-finite bounds, or `lo >= hi` — invalid
    /// declarations are rejected at this boundary rather than at first
    /// ingest.
    pub fn binned(window: usize, bins: usize, lo: f64, hi: f64) -> Self {
        // Build (and drop) the estimator once so BinnedAuc::new runs
        // its validation here, where the declaration is made.
        let kind = EstimatorKind::Binned { bins, lo, hi };
        let _ = kind.build();
        StreamConfig { window, estimator: kind, ..Default::default() }
    }

    /// Auto-selection: the config the fleet recommends for a stream
    /// requesting accuracy `ε`, given an optionally declared bounded
    /// score range.
    ///
    /// With a declared range and `ε > 0`, `bins = ⌈2/ε⌉` cells make the
    /// cell width `(hi−lo)·ε/2` — resolution matching the paper's
    /// `ε/2` guarantee — and the binned fast path wins on update cost;
    /// it is chosen unless the requested ε demands more than
    /// [`MAX_AUTO_BINS`] cells (or exactness, `ε == 0`), in which case
    /// the `(1+ε)`-compressed sketch keeps the guarantee at any
    /// resolution.
    ///
    /// # Panics
    ///
    /// On an invalid declared range (non-finite bounds or `lo >= hi`),
    /// like [`StreamConfig::binned`].
    pub fn auto(window: usize, epsilon: f64, range: Option<(f64, f64)>) -> Self {
        if let Some((lo, hi)) = range {
            assert!(
                lo.is_finite() && hi.is_finite() && lo < hi,
                "auto-selection: invalid declared score range [{lo}, {hi}]"
            );
            if epsilon > 0.0 {
                let bins = (2.0 / epsilon).ceil() as usize;
                if bins <= MAX_AUTO_BINS {
                    return StreamConfig::binned(window, bins, lo, hi);
                }
            }
        }
        StreamConfig::new(window, epsilon)
    }

    /// The ε of an approximate stream; `None` for exact-maintained and
    /// binned streams (the binned resolution is declared in cells, not
    /// ε — see [`StreamConfig::auto`] for the correspondence).
    pub fn epsilon(&self) -> Option<f64> {
        match self.estimator {
            EstimatorKind::Approx { epsilon } => Some(epsilon),
            EstimatorKind::ExactMaintained | EstimatorKind::Binned { .. } => None,
        }
    }

    /// Replace the estimator choice.
    pub fn with_estimator(mut self, estimator: EstimatorKind) -> Self {
        self.estimator = estimator;
        self
    }

    /// Disable the drift monitor.
    pub fn without_monitor(mut self) -> Self {
        self.monitor = None;
        self
    }

    /// Replace the drift monitor parameters.
    pub fn with_monitor(mut self, monitor: MonitorConfig) -> Self {
        self.monitor = Some(monitor);
        self
    }
}

/// Fleet-wide configuration.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FleetConfig {
    /// Shard count; rounded up to the next power of two, minimum 1.
    /// Streams are distributed by a mixed hash of their id, so shard
    /// occupancy stays balanced regardless of id patterns.
    pub shards: usize,
    /// Ingestion worker threads for batched ingestion and aggregate
    /// queries; `0` and `1` both mean the serial inline path. Worker
    /// count never changes results, only wall-clock (the executor's
    /// determinism contract), so it is safe to tune freely. More workers
    /// than busy shards is wasteful — the executor caps participation at
    /// one worker per claimable shard.
    pub workers: usize,
    /// Use the persistent worker pool (threads spawned once per fleet,
    /// parked between batches) for batch drains. With `false`, parallel
    /// drains fall back to a `std::thread::scope` per batch — the PR-2
    /// baseline, kept for comparison benchmarks. Irrelevant when
    /// `workers ≤ 1`. Execution strategy never changes results.
    pub pool: bool,
    /// Pipeline batches: `push_batch` returns as soon as the drain is
    /// handed to the pool, so the caller buckets/generates the next
    /// batch while workers drain the previous one. Results stay
    /// bit-identical — every read synchronizes on the in-flight batch
    /// first. Effective only with `pool` and `workers ≥ 2`.
    pub pipeline: bool,
    /// Scale the active worker count to the observed batch size: a
    /// batch engages roughly one worker per
    /// [`ADAPTIVE_EVENTS_PER_WORKER`](super::ADAPTIVE_EVENTS_PER_WORKER)
    /// events (capped at `workers`), and a batch small enough for one
    /// worker skips the pool dispatch entirely and drains inline — so
    /// trickle traffic stops paying the full parallel submission cost.
    /// Worker count never changes results, so this only moves
    /// wall-clock. Off by default (fixed worker count).
    pub adaptive: bool,
    /// Configuration applied to streams without an explicit override.
    pub stream_defaults: StreamConfig,
}

impl Default for FleetConfig {
    fn default() -> Self {
        FleetConfig {
            shards: 64,
            workers: 1,
            pool: true,
            pipeline: false,
            adaptive: false,
            stream_defaults: StreamConfig::default(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builders_compose() {
        let c = StreamConfig::new(200, 0.1);
        assert_eq!(c.window, 200);
        assert_eq!(c.estimator, EstimatorKind::Approx { epsilon: 0.1 });
        assert_eq!(c.epsilon(), Some(0.1));
        assert!(c.monitor.is_some());
        assert!(c.without_monitor().monitor.is_none());
        let m = MonitorConfig { lambda: 0.01, margin: 0.1, patience: 5, warmup: 10 };
        assert_eq!(StreamConfig::new(10, 0.5).with_monitor(m).monitor, Some(m));
        let e = StreamConfig::exact(64);
        assert_eq!(e.estimator, EstimatorKind::ExactMaintained);
        assert_eq!(e.epsilon(), None);
        assert!(e.monitor.is_some());
        let b = StreamConfig::binned(64, 32, 0.0, 1.0);
        assert_eq!(b.estimator, EstimatorKind::Binned { bins: 32, lo: 0.0, hi: 1.0 });
        assert_eq!(b.epsilon(), None);
        assert!(b.monitor.is_some());
        let swapped = c.with_estimator(EstimatorKind::ExactMaintained);
        assert_eq!(swapped.estimator, EstimatorKind::ExactMaintained);
        assert_eq!(swapped.window, 200);
    }

    #[test]
    fn estimator_kinds_build_their_estimators() {
        match (EstimatorKind::Approx { epsilon: 0.25 }).build() {
            FleetEstimator::Approx(e) => assert_eq!(e.epsilon(), 0.25),
            other => panic!("expected approx, built {other:?}"),
        }
        let mut exact = EstimatorKind::ExactMaintained.build();
        assert!(matches!(exact, FleetEstimator::Exact(_)));
        exact.insert(0.2, true);
        exact.insert(0.8, false);
        assert_eq!(exact.auc(), 1.0);
        assert_eq!(exact.footprint(), 2);
        assert_eq!(exact.declared_range(), None);
        let mut binned = (EstimatorKind::Binned { bins: 16, lo: 0.0, hi: 1.0 }).build();
        assert!(matches!(binned, FleetEstimator::Binned(_)));
        binned.insert(0.2, true);
        binned.insert(0.8, false);
        assert_eq!(binned.auc(), 1.0);
        assert_eq!(binned.footprint(), 32, "binned footprint is 2·bins, k-independent");
        assert_eq!(binned.declared_range(), Some((0.0, 1.0)));
    }

    #[test]
    fn auto_selection_prefers_binned_when_the_range_is_bounded() {
        // Bounded range + moderate ε → binned with ⌈2/ε⌉ cells.
        let c = StreamConfig::auto(100, 0.01, Some((0.0, 1.0)));
        assert_eq!(c.estimator, EstimatorKind::Binned { bins: 200, lo: 0.0, hi: 1.0 });
        // No declared range → the sketch, whatever the ε.
        let c = StreamConfig::auto(100, 0.01, None);
        assert_eq!(c.estimator, EstimatorKind::Approx { epsilon: 0.01 });
        // ε finer than MAX_AUTO_BINS cells can deliver → the sketch.
        let c = StreamConfig::auto(100, 1e-6, Some((0.0, 1.0)));
        assert_eq!(c.estimator, EstimatorKind::Approx { epsilon: 1e-6 });
        // ε = 0 means exactness — never binnable.
        let c = StreamConfig::auto(100, 0.0, Some((0.0, 1.0)));
        assert_eq!(c.estimator, EstimatorKind::Approx { epsilon: 0.0 });
        // Boundary: ⌈2/ε⌉ exactly at the cap still bins.
        let eps = 2.0 / MAX_AUTO_BINS as f64;
        let c = StreamConfig::auto(100, eps, Some((-1.0, 2.0)));
        assert_eq!(
            c.estimator,
            EstimatorKind::Binned { bins: MAX_AUTO_BINS, lo: -1.0, hi: 2.0 }
        );
    }

    #[test]
    #[should_panic(expected = "bins must be ≥ 1")]
    fn binned_config_rejects_zero_bins() {
        StreamConfig::binned(100, 0, 0.0, 1.0);
    }

    #[test]
    #[should_panic(expected = "lo < hi")]
    fn binned_config_rejects_inverted_range() {
        StreamConfig::binned(100, 8, 1.0, 1.0);
    }

    #[test]
    #[should_panic(expected = "must be finite")]
    fn binned_config_rejects_non_finite_bounds() {
        StreamConfig::binned(100, 8, f64::NEG_INFINITY, 1.0);
    }

    #[test]
    #[should_panic(expected = "invalid declared score range")]
    fn auto_rejects_invalid_declared_range() {
        StreamConfig::auto(100, 0.1, Some((2.0, f64::NAN)));
    }

    #[test]
    fn fleet_defaults_prefer_the_pool_without_pipelining() {
        let c = FleetConfig::default();
        assert_eq!(c.workers, 1);
        assert!(c.pool, "pooled execution is the default strategy");
        assert!(!c.pipeline, "pipelining is opt-in");
        assert!(!c.adaptive, "adaptive worker scaling is opt-in");
    }

    #[test]
    fn monitor_config_builds() {
        let m = MonitorConfig::default().build();
        assert!(!m.is_alarmed());
        assert_eq!(m.baseline(), 0.0);
    }
}
