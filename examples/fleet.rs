//! Fleet-scale monitoring: thousands of per-stream sliding AUC windows
//! under bursty traffic, with drift alarms on the streams that break.
//!
//! ```sh
//! cargo run --release --example fleet
//! ```
//!
//! 2 000 streams (each its own classifier stand-in), 5% of which
//! suffer an abrupt label-flip failure halfway through. Events arrive
//! in bursty, head-skewed batches; the [`AucFleet`] maintains one
//! sliding AUC window plus a drift monitor per stream — most on the
//! `ε/2`-approximate sketch, a few on the tree-maintained exact
//! accumulator and the binned bounded-score fast path — draining
//! its shards on a persistent pool of 4 work-stealing workers with
//! cross-batch pipelining — the next batch is generated and bucketed
//! while the previous one drains (results are bit-identical to
//! serial). The same pool then answers the monitoring queries: the
//! `top_k_worst` triage view, the fleet AUC histogram, the
//! `count_below` SLO count and a `select_streams` predicate scan —
//! all shard-parallel, all bit-identical to their serial versions.
//! The example prints ingestion throughput, fleet aggregate quantiles
//! and the query results, and checks the alarms landed exactly on the
//! broken streams.

use std::collections::HashSet;
use std::time::Instant;

use streamauc::fleet::{AucFleet, EstimatorKind, FleetConfig, MonitorConfig, StreamConfig};
use streamauc::stream::{DriftSchedule, MultiStream, StreamProfile};

const STREAMS: u64 = 2_000;
const DRIFTED: u64 = 100; // 5%
const EVENTS: usize = 1_500_000;
const BATCH: usize = 2_048;

fn main() {
    let per_stream = EVENTS as u64 / STREAMS;
    let profiles: Vec<StreamProfile> = (0..STREAMS)
        .map(|id| {
            let p = StreamProfile::healthy(id);
            if id < DRIFTED {
                p.with_drift(DriftSchedule::Abrupt { at: per_stream / 2, rate: 0.6 })
            } else {
                p
            }
        })
        .collect();
    let mut gen = MultiStream::with_profiles(profiles, 0xF1EE7).with_mean_burst(8.0);

    let monitor = MonitorConfig { lambda: 0.001, margin: 0.08, patience: 50, warmup: 250 };
    let defaults = StreamConfig {
        window: 200,
        estimator: EstimatorKind::Approx { epsilon: 0.1 },
        monitor: Some(monitor),
    };
    let mut fleet = AucFleet::new(FleetConfig {
        shards: 64,
        workers: 4,
        pool: true,
        pipeline: true,
        adaptive: false,
        stream_defaults: defaults,
    });
    // Mixed fleet: a handful of exactness-critical streams run the
    // tree-maintained exact estimator, another handful the binned
    // bounded-score fast path (sigmoid scores are guaranteed inside
    // the unit interval, so the declaration is safe); the rest keep
    // the ε-sketch. All kinds share shards, pool, monitors and
    // queries unchanged.
    for id in 0..8 {
        fleet.configure_stream(id, defaults.with_estimator(EstimatorKind::ExactMaintained));
    }
    for id in 8..16 {
        let kind = EstimatorKind::Binned { bins: 128, lo: 0.0, hi: 1.0 };
        fleet.configure_stream(id, defaults.with_estimator(kind));
    }

    let drift_at = per_stream / 2;
    println!("{STREAMS} streams ({DRIFTED} will break at ~their event {drift_at}); {EVENTS} events\n");
    let started = Instant::now();
    let mut pushed = 0;
    while pushed < EVENTS {
        let n = BATCH.min(EVENTS - pushed);
        fleet.push_batch(&gen.next_batch(n));
        pushed += n;
    }
    // `stream_count` synchronizes with the pipelined final batch, so
    // the clock below includes the full drain.
    let live = fleet.stream_count();
    let elapsed = started.elapsed();
    println!(
        "ingested {EVENTS} events across {live} streams in {:.2?} ({:.0} events/s, \
         {} workers on the last batch)",
        elapsed,
        EVENTS as f64 / elapsed.as_secs_f64(),
        fleet.last_batch_workers()
    );

    let agg = fleet.aggregate();
    println!(
        "AUC quantiles: min {:.4}  p10 {:.4}  median {:.4}  p90 {:.4}  max {:.4}",
        agg.min_auc, agg.p10_auc, agg.median_auc, agg.p90_auc, agg.max_auc
    );
    let snap = fleet.snapshot();
    println!(
        "fleet mean AUC {:.4}; {} streams currently alarmed\n",
        snap.mean_auc(),
        snap.alarmed_streams.len()
    );

    // Shard-parallel queries, answered on the same persistent pool the
    // drains use (fleet/query.rs).
    let hist = fleet.auc_histogram(10);
    println!("AUC histogram ({} live streams):", hist.live_streams);
    let peak = hist.counts.iter().copied().max().unwrap_or(0).max(1);
    for (i, &count) in hist.counts.iter().enumerate() {
        let (lo, hi) = hist.bin_range(i);
        println!("  [{lo:.1}, {hi:.1})  {count:>5}  {}", "#".repeat(count * 40 / peak));
    }
    let below = fleet.count_below(0.7);
    println!("{below} streams below AUC 0.7\n");

    // Raw score distribution over the unit interval; binned streams
    // answer straight from their count arrays, everything else rescans.
    let scores = fleet.score_histogram(10);
    println!("score histogram ({} window entries):", scores.entries);
    let speak = scores.counts.iter().copied().max().unwrap_or(0).max(1);
    for (i, &count) in scores.counts.iter().enumerate() {
        let lo = i as f64 / 10.0;
        let bar = "#".repeat((count * 40 / speak) as usize);
        println!("  [{lo:.1}, {:.1})  {count:>6}  {bar}", lo + 0.1);
    }
    println!();

    println!("worst streams (top_k_worst triage view):");
    println!("{:>8}  {:>8}  {:>6}  {:>6}  alarmed", "stream", "auc~", "fill", "|C|");
    let worst = fleet.top_k_worst(8);
    for s in &worst {
        println!("{:>8}  {:>8.4}  {:>6}  {:>6}  {}", s.stream, s.auc, s.len, s.compressed_len, s.alarmed);
    }
    // The query layer and the materialized snapshot agree on triage.
    let via_snapshot: Vec<u64> = snap.worst_streams(8).iter().map(|s| s.stream).collect();
    let via_query: Vec<u64> = worst.iter().map(|s| s.stream).collect();
    assert_eq!(via_query, via_snapshot, "query triage diverged from snapshot triage");
    // A predicate scan sees exactly the streams the snapshot calls alarmed.
    let alarmed_now = fleet.select_streams(|s| s.alarmed);
    assert_eq!(
        alarmed_now.iter().map(|s| s.stream).collect::<Vec<_>>(),
        snap.alarmed_streams,
        "select_streams(alarmed) diverged from the snapshot's alarm list"
    );

    // Alarms must cover (essentially all of) the drifted streams and
    // none of the healthy ones.
    let alarmed: HashSet<u64> = fleet.alarms().iter().map(|a| a.stream).collect();
    let false_alarms = alarmed.iter().filter(|&&id| id >= DRIFTED).count();
    let caught = alarmed.iter().filter(|&&id| id < DRIFTED).count();
    println!(
        "\nalarms: {} streams flagged; {caught}/{DRIFTED} drifted caught, {false_alarms} false",
        alarmed.len()
    );
    assert_eq!(false_alarms, 0, "healthy streams must stay quiet");
    assert!(
        caught as u64 >= DRIFTED * 9 / 10,
        "monitoring missed too many broken streams ({caught}/{DRIFTED})"
    );
    println!("fleet scenario reproduced: drifted streams alarmed, healthy fleet quiet.");
}
