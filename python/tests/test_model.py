"""L2 correctness: the logistic-regression model over the kernels.

Checks the score convention (paper §2: larger score ⇒ more negative),
that training reduces loss and reaches a discriminative model, and the
shape contract the AOT artifacts freeze.
"""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model


def synthetic(seed, n, dims, sep=2.0):
    """Two-Gaussian data along a random direction; returns (x, y01)."""
    k = jax.random.PRNGKey(seed)
    kd, kl, kn = jax.random.split(k, 3)
    direction = jax.random.normal(kd, (dims,))
    direction = direction / jnp.linalg.norm(direction)
    y = jax.random.bernoulli(kl, 0.5, (n,)).astype(jnp.float32)
    # positives shifted toward negative margin (low scores).
    shift = (-sep) * y[:, None] * direction[None, :]
    x = shift + jax.random.normal(kn, (n, dims))
    return x.astype(jnp.float32), y


def auc_of(scores, y):
    """Plain numpy AUC under the paper's convention (positives low)."""
    s = np.asarray(scores, dtype=np.float64)
    yy = np.asarray(y, dtype=bool)
    pos, neg = s[yy], s[~yy]
    if len(pos) == 0 or len(neg) == 0:
        return 0.5
    correct = (pos[:, None] < neg[None, :]).sum()
    ties = (pos[:, None] == neg[None, :]).sum()
    return (correct + 0.5 * ties) / (len(pos) * len(neg))


def train(x, y, steps=200, lr=0.5, batch=model.TRAIN_BATCH):
    w, b = model.init_params(x.shape[1])
    lr = jnp.asarray(lr, jnp.float32)
    losses = []
    n = x.shape[0]
    for i in range(steps):
        lo = (i * batch) % max(n - batch, 1)
        xb, yb = x[lo : lo + batch], y[lo : lo + batch]
        w, b, loss = model.train_step(w, b, xb, yb, lr)
        losses.append(float(loss))
    return w, b, losses


def test_zero_params_score_half():
    w, b = model.init_params(8)
    x = jnp.ones((4, 8), jnp.float32)
    np.testing.assert_allclose(model.score_batch(w, b, x), 0.5, atol=1e-6)


def test_training_reduces_loss():
    x, y = synthetic(0, 2048, 32)
    _, _, losses = train(x, y, steps=100)
    first = np.mean(losses[:10])
    last = np.mean(losses[-10:])
    assert last < first * 0.7, f"loss did not drop: {first} -> {last}"


def test_trained_model_is_discriminative_with_paper_convention():
    x, y = synthetic(1, 4096, 32)
    w, b, _ = train(x, y, steps=200)
    scores = model.score_batch(w, b, x[:1024])
    auc = auc_of(scores, y[:1024])
    # Positives must receive LOW scores (larger score ⇒ more negative).
    assert auc > 0.9, f"AUC {auc} too low — convention or training broken"


def test_loss_at_init_is_log2():
    x, y = synthetic(2, 256, 16)
    w, b = model.init_params(16)
    loss = model.loss(w, b, x, y)
    np.testing.assert_allclose(float(loss), np.log(2.0), rtol=1e-5)


def test_train_step_is_pure_and_jittable():
    x, y = synthetic(3, model.TRAIN_BATCH, model.DIMS)
    w, b = model.init_params()
    lr = jnp.asarray(0.1, jnp.float32)
    step = jax.jit(model.train_step)
    w1, b1, l1 = step(w, b, x, y, lr)
    w2, b2, l2 = step(w, b, x, y, lr)
    np.testing.assert_allclose(w1, w2)
    np.testing.assert_allclose(b1, b2)
    assert float(l1) == float(l2)
    assert w1.shape == (model.DIMS,)
    assert b1.shape == ()


def test_lowering_specs_match_constants():
    score, trainsp = model.lowering_specs()
    assert score[0].shape == (model.DIMS,)
    assert score[2].shape == (model.SCORE_BATCH, model.DIMS)
    assert trainsp[2].shape == (model.TRAIN_BATCH, model.DIMS)
    assert trainsp[3].shape == (model.TRAIN_BATCH,)
    assert all(s.dtype == jnp.float32 for s in score + trainsp)
