"""AOT path: lowering to HLO text and the artifact contract.

The rust runtime consumes exactly what these tests pin down: HLO *text*
modules (parseable, with the expected parameter/result shapes baked in)
plus ``meta.json``.
"""

import json

import pytest

from compile import aot, model


@pytest.fixture(scope="module")
def lowered():
    return aot.lower_all()


def test_lowers_both_entry_points(lowered):
    assert set(lowered) == {"score_batch", "train_step"}
    for name, text in lowered.items():
        assert text.startswith("HloModule"), f"{name} is not HLO text"
        assert "ENTRY" in text


def test_score_batch_shapes_in_hlo(lowered):
    text = lowered["score_batch"]
    # Parameters: w (128), b scalar, x (1024, 128); result tuple of (1024).
    assert f"f32[{model.DIMS}]" in text
    assert f"f32[{model.SCORE_BATCH},{model.DIMS}]" in text
    # Result: a 1-tuple of (SCORE_BATCH,) scores (layout suffix varies).
    assert f"->(f32[{model.SCORE_BATCH}]" in text


def test_train_step_shapes_in_hlo(lowered):
    text = lowered["train_step"]
    assert f"f32[{model.TRAIN_BATCH},{model.DIMS}]" in text
    # Result tuple: (w, b, loss) = (f32[128], f32[], f32[]); tolerate
    # layout suffixes on the array member.
    assert f"->(f32[{model.DIMS}]" in text
    assert "f32[], f32[])" in text


def test_no_custom_calls_in_hlo(lowered):
    """interpret=True must lower Pallas to plain HLO ops — a Mosaic
    custom-call would be unloadable by the CPU PJRT client."""
    for name, text in lowered.items():
        assert "custom-call" not in text, f"{name} contains a custom call"


def test_artifact_writing(tmp_path, monkeypatch):
    monkeypatch.setattr(
        "sys.argv", ["aot", "--out", str(tmp_path)]
    )
    aot.main()
    for name in ["score_batch.hlo.txt", "train_step.hlo.txt", "meta.json"]:
        assert (tmp_path / name).exists(), name
    meta = json.loads((tmp_path / "meta.json").read_text())
    assert meta["dims"] == model.DIMS
    assert meta["score_batch"]["batch"] == model.SCORE_BATCH
    assert meta["train_step"]["batch"] == model.TRAIN_BATCH
    assert meta["train_step"]["inputs"] == ["w", "b", "x", "y", "lr"]


def test_hlo_text_round_trips_through_parser(lowered):
    """The text must be parseable back into an XlaComputation — the same
    code path the rust loader uses (HloModuleProto::from_text)."""
    from jax._src.lib import xla_client as xc

    for name, text in lowered.items():
        # Will raise on malformed text.
        mod = xc._xla.hlo_module_from_text(text)
        assert mod is not None, name
