//! Cross-module integration over the pure-rust pipeline (no artifacts
//! needed): synthetic streams → window driver → estimators → monitor,
//! checked against the naive oracle throughout.

use streamauc::coordinator::window::Window;
use streamauc::coordinator::{
    ApproxAuc, AucEstimator, AucMonitor, ExactAuc, MonitorEvent, NaiveAuc,
};
use streamauc::stream::synth::{paper_datasets, Dataset};
use streamauc::stream::Drift;

#[test]
fn approx_and_exact_agree_on_every_paper_dataset() {
    for spec in paper_datasets() {
        let name = spec.name;
        let mut data = Dataset::new(spec.scaled(200), 1);
        let stream = data.score_stream(3000);
        for eps in [0.01, 0.1] {
            let mut approx = Window::with_estimator(500, ApproxAuc::new(eps));
            let mut exact = Window::with_estimator(500, ExactAuc::new());
            let mut max_rel = 0.0f64;
            for &(s, l) in &stream {
                approx.push(s, l);
                exact.push(s, l);
                let (a, e) = (approx.auc(), exact.auc());
                if e > 0.0 {
                    max_rel = max_rel.max((a - e).abs() / e);
                }
                assert!(
                    (a - e).abs() <= eps * e / 2.0 + 1e-12,
                    "{name} ε={eps}: {a} vs {e}"
                );
            }
            // Paper §6: the observed error is well below the guarantee.
            assert!(
                max_rel <= eps / 2.0,
                "{name} ε={eps}: max rel err {max_rel} exceeds ε/2"
            );
        }
    }
}

#[test]
fn windowed_estimates_match_naive_recompute_exactly_with_eps0() {
    let mut data = Dataset::new(paper_datasets().swap_remove(2).scaled(500), 3); // tvads: duplicates
    let stream = data.score_stream(1200);
    let mut approx = Window::with_estimator(300, ApproxAuc::new(0.0));
    let mut raw: std::collections::VecDeque<(f64, bool)> = Default::default();
    for &(s, l) in &stream {
        approx.push(s, l);
        raw.push_back((s, l));
        if raw.len() > 300 {
            raw.pop_front();
        }
        let window: Vec<_> = raw.iter().copied().collect();
        let want = NaiveAuc::of(&window);
        let got = approx.auc();
        assert!((got - want).abs() < 1e-12, "{got} vs {want}");
    }
}

#[test]
fn monitor_catches_injected_abrupt_drift() {
    let mut data = Dataset::new(paper_datasets().swap_remove(0).scaled(500), 5);
    let mut stream = data.score_stream(8000);
    Drift::Abrupt { at: 5000, rate: 0.6 }.apply(&mut stream, 99);

    let mut window = Window::with_estimator(500, ApproxAuc::new(0.05));
    let mut monitor = AucMonitor::new(0.001, 0.08, 100, 500);
    let mut alarm_at = None;
    for (i, &(s, l)) in stream.iter().enumerate() {
        window.push(s, l);
        if window.is_full() {
            if monitor.observe(window.auc()) == MonitorEvent::Alarm {
                alarm_at = alarm_at.or(Some(i));
            }
        }
    }
    let at = alarm_at.expect("monitor must alarm on 60% label-flip drift");
    assert!(at > 5000, "alarm before the drift (false positive) at {at}");
    assert!(
        at < 7000,
        "alarm too late ({at}); window 500 + patience 100 should catch it quickly"
    );
}

#[test]
fn monitor_is_quiet_on_clean_streams() {
    let mut data = Dataset::new(paper_datasets().swap_remove(1).scaled(200), 8);
    let stream = data.score_stream(6000);
    let mut window = Window::with_estimator(500, ApproxAuc::new(0.05));
    let mut monitor = AucMonitor::new(0.001, 0.08, 100, 500);
    for &(s, l) in &stream {
        window.push(s, l);
        if window.is_full() {
            assert_ne!(
                monitor.observe(window.auc()),
                MonitorEvent::Alarm,
                "false alarm on a clean stream"
            );
        }
    }
}

#[test]
fn csv_roundtrip_preserves_estimates() {
    let dir = std::env::temp_dir().join("streamauc-pipeline");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("stream.csv");
    let mut data = Dataset::new(paper_datasets().swap_remove(1).scaled(500), 13);
    let stream = data.score_stream(2000);
    streamauc::stream::source::write_csv(&path, &stream).unwrap();
    let loaded = streamauc::stream::source::read_csv(&path).unwrap();
    assert_eq!(stream, loaded);
    let mut a = ApproxAuc::new(0.1);
    let mut b = ApproxAuc::new(0.1);
    for (&(s1, l1), &(s2, l2)) in stream.iter().zip(&loaded) {
        a.insert(s1, l1);
        b.insert(s2, l2);
    }
    assert_eq!(a.auc(), b.auc());
}
