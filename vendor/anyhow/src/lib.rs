//! Offline stand-in for the `anyhow` crate.
//!
//! crates.io is unreachable in this environment, so the subset of the
//! anyhow API the workspace uses is reimplemented here with the same
//! semantics: a context-chained error type, the `anyhow!` / `bail!` /
//! `ensure!` macros, and the [`Context`] extension trait for `Result`
//! and `Option`.
//!
//! Mirrored behaviour that callers rely on:
//! * `{}` displays the outermost message only; `{:#}` joins the whole
//!   chain with `": "` (used by `main.rs` error reporting and asserted
//!   by the runtime meta tests);
//! * `Debug` prints the outermost message plus a `Caused by:` list, so
//!   `unwrap()` failures stay readable;
//! * any `std::error::Error + Send + Sync + 'static` converts via `?`,
//!   capturing its source chain — exactly anyhow's blanket `From`.
//!
//! Deliberately omitted (unused in this workspace): downcasting,
//! backtraces, `Error::new`, `Chain` iteration.

use std::fmt;

/// A context-chained error. The first entry is the outermost message;
/// the rest are causes, outermost first.
pub struct Error {
    head: String,
    causes: Vec<String>,
}

impl Error {
    /// Error from a printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { head: message.to_string(), causes: Vec::new() }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        let mut causes = Vec::with_capacity(self.causes.len() + 1);
        causes.push(self.head);
        causes.extend(self.causes);
        Error { head: context.to_string(), causes }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.head)?;
        if f.alternate() {
            for cause in &self.causes {
                write!(f, ": {cause}")?;
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.head)?;
        if !self.causes.is_empty() {
            write!(f, "\n\nCaused by:")?;
            for (i, cause) in self.causes.iter().enumerate() {
                write!(f, "\n    {i}: {cause}")?;
            }
        }
        Ok(())
    }
}

// The blanket conversion anyhow ships: any std error (with its source
// chain) becomes an `Error`. Sound because `Error` itself does not
// implement `std::error::Error`, so this cannot overlap the reflexive
// `From<T> for T`.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let head = e.to_string();
        let mut causes = Vec::new();
        let mut source = e.source();
        while let Some(s) = source {
            causes.push(s.to_string());
            source = s.source();
        }
        Error { head, causes }
    }
}

/// `anyhow::Result<T>`: a `Result` defaulting to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(...)` / `.with_context(...)` to
/// `Result` and `Option`.
pub trait Context<T, E> {
    /// Attach a context message, converting the error to [`Error`].
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;

    /// Attach a lazily evaluated context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: Into<Error>> Context<T, E> for Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T, Error> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Build an [`Error`] from a format string (implicit captures work).
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::anyhow!(concat!("condition failed: ", stringify!($cond))));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn display_outermost_alternate_chain() {
        let e: Error = Error::from(io_err()).context("reading config");
        assert_eq!(format!("{e}"), "reading config");
        assert_eq!(format!("{e:#}"), "reading config: missing file");
    }

    #[test]
    fn debug_lists_causes() {
        let e = Error::msg("inner").context("mid").context("outer");
        let s = format!("{e:?}");
        assert!(s.contains("outer"));
        assert!(s.contains("Caused by:"));
        assert!(s.contains("0: mid"));
        assert!(s.contains("1: inner"));
    }

    #[test]
    fn macros_and_question_mark() {
        fn inner(fail: bool, n: u64) -> Result<u64> {
            ensure!(n < 10, "n too large: {n}");
            if fail {
                bail!("failed with {n}");
            }
            let parsed: u64 = "42".parse()?;
            Ok(parsed)
        }
        assert_eq!(inner(false, 1).unwrap(), 42);
        assert_eq!(inner(true, 1).unwrap_err().to_string(), "failed with 1");
        assert_eq!(inner(false, 11).unwrap_err().to_string(), "n too large: 11");
    }

    #[test]
    fn context_on_result_and_option() {
        let r: Result<(), std::io::Error> = Err(io_err());
        let e = r.context("outer").unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: missing file");
        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing {}", "thing")).unwrap_err();
        assert_eq!(e.to_string(), "missing thing");
        let some: Option<u32> = Some(7);
        assert_eq!(some.context("unused").unwrap(), 7);
    }
}
