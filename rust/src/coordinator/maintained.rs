//! Tree-maintained exact AUC — O(log k) update, **O(1)** read, no ε.
//!
//! Tatti, *Maintaining AUC and H-measure over time* (arXiv 2112.06160),
//! observes that the exact sliding-window AUC does not need the
//! compressed list at all: the Eq. 1 doubled-area sum can be maintained
//! delta-wise because one insert or remove at score `s` changes the sum
//! by a quantity derivable from a single prefix query — exactly what
//! the augmented rbtree answers in `O(log k)`.
//!
//! With `hp(v)` / `hn(v)` the positive / negative counts strictly below
//! node `v` and `p(v)` / `n(v)` the counts at `v`, the scan total is
//!
//! ```text
//! a2 = Σ_v (2·hp(v) + p(v)) · n(v)
//! ```
//!
//! and the four mutations move it by (derivation in `DESIGN.md`
//! §Estimators):
//!
//! * insert positive at `s`:  `Δa2 = +(2·(N − hn(s)) − n(s))`
//! * remove positive at `s`:  `Δa2 = −(2·(N − hn(s)) − n(s))`
//! * insert negative at `s`:  `Δa2 = +(2·hp(s) + p(s))`
//! * remove negative at `s`:  `Δa2 = −(2·hp(s) + p(s))`
//!
//! where `N` is the pre-update negative total and `hp`/`hn`/`p`/`n` are
//! read *before* the tree is touched. Every quantity is an integer, so
//! the running `u128` accumulator telescopes to precisely the retained
//! Eq. 1 scan — **bit-identical**, asserted after every op by the
//! differential suite and [`MaintainedExactAuc::check_invariants`].
//!
//! Like the other estimators, this one comes as a storage-free
//! [`MaintainedCore`] (nodes in a caller-supplied [`EstimatorArenas`];
//! only the `t` slab is used) and a self-contained
//! [`MaintainedExactAuc`] wrapper. Because `a2` always equals the
//! content-determined Eq. 1 scan, rehydrating a hibernated stream is
//! just replaying its window content — no extra frozen state is needed
//! (contrast [`super::approx::ApproxCore::rebuild_in`]).
//!
//! The same tree yields the exact H-measure (Hand 2009; maintained
//! exactly over time in the same paper) via
//! [`MaintainedExactAuc::h_measure`] — an `O(k)` read over the score
//! groups (see `coordinator/metrics.rs`; incremental hull maintenance
//! is future work, `DESIGN.md` §Estimators).

use super::metrics::h_measure;
use super::support::{Acc, Counts, EstimatorArenas};
use super::{auc_terms_doubled, finish_auc, AucEstimator};
use crate::collections::rbtree::RbTreeCore;
use crate::collections::Score;

/// Storage-free form of the maintained exact estimator: a tree root
/// plus three scalars, nodes in the bundle's `t` arena.
#[derive(Clone, Copy, Debug)]
pub(crate) struct MaintainedCore {
    t: RbTreeCore,
    /// Running doubled area: at every op boundary bit-equal to the
    /// retained scan ([`MaintainedCore::doubled_area_scan`]).
    a2: u128,
    total_pos: u64,
    total_neg: u64,
}

impl Default for MaintainedCore {
    fn default() -> Self {
        Self::new()
    }
}

impl MaintainedCore {
    /// Empty estimator (allocates nothing — no sentinels in this tree).
    pub(crate) fn new() -> Self {
        MaintainedCore { t: RbTreeCore::new(), a2: 0, total_pos: 0, total_neg: 0 }
    }

    /// Release every node back to the arena (`O(k)`). The core must not
    /// be used afterwards.
    pub(crate) fn free_in(&mut self, ars: &mut EstimatorArenas) {
        self.t.drain(&mut ars.t);
        self.a2 = 0;
        self.total_pos = 0;
        self.total_neg = 0;
    }

    /// Number of distinct scores currently held (tree nodes).
    #[inline]
    pub(crate) fn distinct_scores(&self) -> usize {
        self.t.len()
    }

    /// Logical bytes of arena storage the score tree occupies (live
    /// node count × slot size; never arena capacity).
    pub(crate) fn live_bytes(&self) -> usize {
        use crate::collections::rbtree::Node;
        self.t.len() * std::mem::size_of::<Node<Counts, Acc>>()
    }

    /// Positive / negative totals.
    #[inline]
    pub(crate) fn class_totals(&self) -> (u64, u64) {
        (self.total_pos, self.total_neg)
    }

    /// The running doubled-area accumulator behind the O(1) read.
    #[inline]
    pub(crate) fn doubled_area(&self) -> u128 {
        self.a2
    }

    /// Window size (all entries).
    #[inline]
    pub(crate) fn len(&self) -> usize {
        (self.total_pos + self.total_neg) as usize
    }

    /// O(1) read: the running accumulator over the stored totals.
    #[inline]
    pub(crate) fn auc(&self) -> f64 {
        finish_auc(self.a2, self.total_pos, self.total_neg)
    }

    /// The doubled area recomputed by the full Eq. 1 tree scan — `O(k)`.
    pub(crate) fn doubled_area_scan(&self, ars: &EstimatorArenas) -> u128 {
        let groups = self.t.iter_in(&ars.t).map(|id| {
            let c = self.t.val(&ars.t, id);
            (c.p, c.n)
        });
        let (a2, pos, neg) = auc_terms_doubled(groups);
        assert_eq!(pos, self.total_pos, "maintained exact: positive total drifted");
        assert_eq!(neg, self.total_neg, "maintained exact: negative total drifted");
        a2
    }

    /// The estimate read via the full scan instead of the accumulator.
    pub(crate) fn auc_full_scan(&self, ars: &EstimatorArenas) -> f64 {
        finish_auc(self.doubled_area_scan(ars), self.total_pos, self.total_neg)
    }

    /// `(hp, hn)`: positives / negatives strictly below `s`, from one
    /// O(log k) descent over the augmented subtree sums.
    fn head_stats(&self, ars: &EstimatorArenas, s: Score) -> (u64, u64) {
        let mut hp = 0;
        let mut hn = 0;
        let mut cur = self.t.root();
        while let Some(v) = cur {
            if self.t.key(&ars.t, v) < s {
                let c = self.t.val(&ars.t, v);
                hp += c.p;
                hn += c.n;
                if let Some(l) = self.t.left(&ars.t, v) {
                    let a = self.t.aug(&ars.t, l);
                    hp += a.pos;
                    hn += a.neg;
                }
                cur = self.t.right(&ars.t, v);
            } else {
                cur = self.t.left(&ars.t, v);
            }
        }
        (hp, hn)
    }

    fn update(&mut self, ars: &mut EstimatorArenas, score: f64, pos: bool, add: bool) {
        let s = Score(super::canon(score));
        assert!(s.is_valid_entry(), "scores must be finite");
        // Everything the delta needs is read before the tree mutates.
        let (hp, hn) = self.head_stats(ars, s);
        let at_s = self.t.find(&ars.t, s).map_or(Counts { p: 0, n: 0 }, |v| *self.t.val(&ars.t, v));
        let delta = if pos {
            // The moved positive gains/loses 2 per negative strictly
            // above s and 1 per negative tied at s:
            // 2·(N − hn − n(s)) + n(s) = 2·(N − hn) − n(s).
            u128::from(2 * (self.total_neg - hn) - at_s.n)
        } else {
            // The moved negative is worth its positive prefix, ties at
            // half weight: 2·hp + p(s).
            u128::from(2 * hp + at_s.p)
        };
        if add {
            let init = if pos { Counts { p: 1, n: 0 } } else { Counts { p: 0, n: 1 } };
            let (v, fresh) = self.t.insert(&mut ars.t, s, || init);
            if !fresh {
                self.t.with_val_mut(&mut ars.t, v, |c| if pos { c.p += 1 } else { c.n += 1 });
            }
            self.a2 = self
                .a2
                .checked_add(delta)
                .expect("maintained exact: doubled-area accumulator overflow");
            if pos {
                self.total_pos += 1;
            } else {
                self.total_neg += 1;
            }
        } else {
            let v = self.t.find(&ars.t, s).expect("maintained exact remove: score not present");
            if pos {
                assert!(at_s.p > 0, "maintained exact remove: no positive at this score");
            } else {
                assert!(at_s.n > 0, "maintained exact remove: no negative at this score");
            }
            self.t.with_val_mut(&mut ars.t, v, |c| if pos { c.p -= 1 } else { c.n -= 1 });
            if at_s.p + at_s.n == 1 {
                self.t.remove(&mut ars.t, v);
            }
            self.a2 = self
                .a2
                .checked_sub(delta)
                .expect("maintained exact: doubled-area accumulator underflow");
            if pos {
                self.total_pos = self
                    .total_pos
                    .checked_sub(1)
                    .expect("maintained exact: positive total underflow");
            } else {
                self.total_neg = self
                    .total_neg
                    .checked_sub(1)
                    .expect("maintained exact: negative total underflow");
            }
        }
    }

    /// Insert one labelled entry ([`AucEstimator::insert`] semantics).
    #[inline]
    pub(crate) fn insert_in(&mut self, ars: &mut EstimatorArenas, score: f64, pos: bool) {
        self.update(ars, score, pos, true);
    }

    /// Remove one labelled entry ([`AucEstimator::remove`] semantics).
    #[inline]
    pub(crate) fn remove_in(&mut self, ars: &mut EstimatorArenas, score: f64, pos: bool) {
        self.update(ars, score, pos, false);
    }

    /// Exact H-measure (Hand 2009) of the current window under the
    /// Beta(2,2) cost prior — an `O(k)` read over the tree's score
    /// groups ([`h_measure`]). Returns 0 when either class is empty.
    pub(crate) fn h_measure(&self, ars: &EstimatorArenas) -> f64 {
        h_measure(self.t.iter_in(&ars.t).map(|id| {
            let c = self.t.val(&ars.t, id);
            (c.p, c.n)
        }))
    }

    /// Validate the tree invariants, the stored class totals and the
    /// accumulator's bit-equality with the Eq. 1 scan. Panics on
    /// violation (tests / property harness).
    pub(crate) fn check_invariants(&self, ars: &EstimatorArenas) {
        self.t.check_invariants(&ars.t);
        let mut pos = 0;
        let mut neg = 0;
        for id in self.t.iter_in(&ars.t) {
            let c = self.t.val(&ars.t, id);
            assert!(c.p + c.n > 0, "maintained exact: empty node survived");
            pos += c.p;
            neg += c.n;
        }
        assert_eq!(pos, self.total_pos, "maintained exact: positive total drifted");
        assert_eq!(neg, self.total_neg, "maintained exact: negative total drifted");
        // doubled_area_scan re-checks the totals; the assert here is
        // the headline invariant — the O(1) read never drifts.
        assert_eq!(
            self.a2,
            self.doubled_area_scan(ars),
            "maintained exact: incremental a2 drifted from the full scan"
        );
    }
}

/// Exact estimator with an O(log k) update and an O(1) AUC read.
///
/// Same augmented tree as [`super::ExactAuc`] (so the `benches/core.rs`
/// three-way row isolates the read-path difference), plus the running
/// doubled-area accumulator that replaces the per-read Eq. 1 scan.
/// Self-contained form with private arenas; the fleet uses
/// [`MaintainedCore`] against shard-owned arenas.
#[derive(Clone, Debug, Default)]
pub struct MaintainedExactAuc {
    ars: EstimatorArenas,
    core: MaintainedCore,
}

impl MaintainedExactAuc {
    /// Empty estimator.
    pub fn new() -> Self {
        Self::default()
    }

    /// Number of distinct scores currently held (tree nodes) — the
    /// exact-path analogue of `ApproxAuc::compressed_len` for footprint
    /// reporting.
    pub fn distinct_scores(&self) -> usize {
        self.core.distinct_scores()
    }

    /// Positive / negative totals (exposed for experiment drivers).
    pub fn class_totals(&self) -> (u64, u64) {
        self.core.class_totals()
    }

    /// The running doubled-area accumulator behind the O(1) read.
    /// Exposed for the bit-equality property tests.
    #[inline]
    pub fn doubled_area(&self) -> u128 {
        self.core.doubled_area()
    }

    /// The doubled area recomputed by the full Eq. 1 tree scan — `O(k)`.
    /// This is the read path `ExactAuc` pays on every query, retained
    /// here as the reference the running accumulator must equal
    /// bit-for-bit after every operation.
    pub fn doubled_area_scan(&self) -> u128 {
        self.core.doubled_area_scan(&self.ars)
    }

    /// The estimate read via the full scan instead of the accumulator.
    /// Bit-identical to [`AucEstimator::auc`]; kept as the
    /// reference/benchmark read path.
    pub fn auc_full_scan(&self) -> f64 {
        self.core.auc_full_scan(&self.ars)
    }

    /// Exact H-measure (Hand 2009) of the current window under the
    /// Beta(2,2) cost prior — an `O(k)` read over the tree's score
    /// groups ([`h_measure`]). Returns 0 when either class is empty.
    pub fn h_measure(&self) -> f64 {
        self.core.h_measure(&self.ars)
    }

    /// Release retained arena capacity. Called automatically when the
    /// window drains to empty; exposed for explicit trimming.
    pub fn shrink_to_fit(&mut self) {
        self.ars.shrink_to_fit();
    }

    /// Total slots retained by the backing arena (live + reusable).
    pub fn capacity(&self) -> usize {
        self.ars.t.slot_count()
    }

    /// Validate the tree invariants, the stored class totals and the
    /// accumulator's bit-equality with the Eq. 1 scan. Panics on
    /// violation (tests / property harness).
    pub fn check_invariants(&self) {
        self.core.check_invariants(&self.ars);
    }
}

impl AucEstimator for MaintainedExactAuc {
    fn insert(&mut self, score: f64, pos: bool) {
        self.core.insert_in(&mut self.ars, score, pos);
    }

    fn remove(&mut self, score: f64, pos: bool) {
        self.core.remove_in(&mut self.ars, score, pos);
        if self.core.len() == 0 {
            // Drained windows shed their churn slack (`DESIGN.md`
            // §Memory).
            self.ars.shrink_to_fit();
        }
    }

    /// O(1): the running accumulator over the stored totals — the same
    /// `finish_auc` division the Eq. 1 scan ends with, so the result is
    /// bit-identical to [`super::ExactAuc`]'s O(k) read.
    fn auc(&self) -> f64 {
        self.core.auc()
    }

    fn len(&self) -> usize {
        self.core.len()
    }
}

// Arena indices only — per-stream windows over this estimator drain on
// the fleet executor's worker threads.
const _: () = {
    const fn assert_send<T: Send>() {}
    assert_send::<MaintainedExactAuc>();
};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{ExactAuc, NaiveAuc};
    use crate::testing::{check, gen_ops, Op};

    #[test]
    fn matches_exact_bitwise_on_random_streams() {
        for grid in [Some(4), Some(32), None] {
            check(0x3A17 ^ grid.unwrap_or(7), 20, |rng| {
                let mut maintained = MaintainedExactAuc::new();
                let mut exact = ExactAuc::new();
                let mut naive = NaiveAuc::new();
                for (i, op) in gen_ops(rng, 300, 60, grid).into_iter().enumerate() {
                    match op {
                        Op::Insert { score, pos } => {
                            maintained.insert(score, pos);
                            exact.insert(score, pos);
                            naive.insert(score, pos);
                        }
                        Op::Remove { score, pos } => {
                            maintained.remove(score, pos);
                            exact.remove(score, pos);
                            naive.remove(score, pos);
                        }
                    }
                    assert_eq!(maintained.len(), naive.len());
                    assert_eq!(
                        maintained.doubled_area(),
                        maintained.doubled_area_scan(),
                        "a2 drifted at op {i}"
                    );
                    let (m, e) = (maintained.auc(), exact.auc());
                    assert_eq!(
                        m.to_bits(),
                        e.to_bits(),
                        "op {i}: maintained {m} != exact {e}"
                    );
                }
            });
        }
    }

    #[test]
    fn node_lifecycle() {
        let mut e = MaintainedExactAuc::new();
        e.insert(1.0, true);
        e.insert(1.0, false);
        assert_eq!(e.distinct_scores(), 1);
        e.remove(1.0, true);
        assert_eq!(e.distinct_scores(), 1);
        e.remove(1.0, false);
        assert_eq!(e.distinct_scores(), 0);
        assert!(e.is_empty());
        assert_eq!(e.auc(), 0.5);
        assert_eq!(e.doubled_area(), 0);
        e.check_invariants();
    }

    #[test]
    fn perfect_and_reversed_separation_are_exact() {
        let mut e = MaintainedExactAuc::new();
        for i in 0..50 {
            e.insert(f64::from(i), true);
            e.insert(f64::from(i) + 1000.0, false);
        }
        assert_eq!(e.auc(), 1.0);
        assert!((e.h_measure() - 1.0).abs() < 1e-12, "h = {}", e.h_measure());
        let mut e = MaintainedExactAuc::new();
        for i in 0..50 {
            e.insert(f64::from(i), false);
            e.insert(f64::from(i) + 1000.0, true);
        }
        assert_eq!(e.auc(), 0.0);
        assert_eq!(e.h_measure(), 0.0);
    }

    #[test]
    fn all_ties_is_chance_level() {
        let mut e = MaintainedExactAuc::new();
        for _ in 0..40 {
            e.insert(0.5, true);
            e.insert(0.5, false);
        }
        assert_eq!(e.auc(), 0.5);
        assert!(e.h_measure().abs() < 1e-12, "h = {}", e.h_measure());
        e.check_invariants();
    }

    #[test]
    fn drained_estimator_sheds_capacity() {
        let mut e = MaintainedExactAuc::new();
        for i in 0..500 {
            e.insert(f64::from(i), i % 2 == 0);
        }
        assert!(e.capacity() >= 500);
        for i in 0..500 {
            e.remove(f64::from(i), i % 2 == 0);
        }
        assert_eq!(e.capacity(), 0, "drained estimator retains slots");
        e.check_invariants();
        e.insert(0.5, true);
        assert_eq!(e.len(), 1);
    }

    #[test]
    #[should_panic(expected = "not present")]
    fn remove_unknown_score_panics() {
        let mut e = MaintainedExactAuc::new();
        e.remove(3.0, true);
    }

    #[test]
    #[should_panic(expected = "no positive at this score")]
    fn remove_wrong_label_panics() {
        let mut e = MaintainedExactAuc::new();
        e.insert(1.0, false);
        e.remove(1.0, true);
    }
}
