//! Synthetic dataset generators standing in for the paper's UCI datasets.
//!
//! The paper evaluates on three UCI datasets scored by a scikit-learn
//! logistic regression (Table 1). Those datasets are not redistributable
//! inside this environment, so each is replaced by a parametric generator
//! that reproduces the *regime* the dataset exercises (DESIGN.md
//! §Substitutions):
//!
//! | paper       | stand-in           | regime preserved                    |
//! |-------------|--------------------|-------------------------------------|
//! | Hepmass     | [`hepmass_like`]   | large test stream, balanced classes, well-separated scores (high AUC) |
//! | Miniboone   | [`miniboone_like`] | class imbalance (28% positive), moderate overlap |
//! | Tvads       | [`tvads_like`]     | low separability **and quantized scores** — many duplicate-score nodes |
//!
//! Generators produce *feature vectors + labels*; the classifier layers
//! (L1/L2 via the PJRT runtime) turn features into scores on the real
//! pipeline. For algorithm-only experiments, [`Dataset::score_stream`]
//! shortcuts with the generator's analytic margin + noise, which follows
//! the same sigmoid-margin family a trained logistic regression emits.

use super::rng::Pcg;

/// One labelled example: dense features + binary label.
#[derive(Clone, Debug)]
pub struct Example {
    /// Dense feature vector (length = [`DatasetSpec::dims`]).
    pub features: Vec<f32>,
    /// True label (`ℓ = 1` is the positive / anomalous class).
    pub label: bool,
}

/// Parameters of a two-class Gaussian-mixture dataset with an analytic
/// margin, mimicking one of the paper's benchmark datasets.
#[derive(Clone, Debug)]
pub struct DatasetSpec {
    /// Dataset name used in reports (matches the paper's tables).
    pub name: &'static str,
    /// Feature dimensionality.
    pub dims: usize,
    /// Training-set size (Table 1).
    pub train_size: usize,
    /// Test-set (stream) size (Table 1).
    pub test_size: usize,
    /// P(label = 1).
    pub pos_rate: f64,
    /// Distance between class means along the discriminative direction;
    /// controls achievable AUC.
    pub separation: f64,
    /// Per-class feature noise.
    pub noise: f64,
    /// If set, scores are quantized to this many distinct levels —
    /// reproducing Tvads' duplicate-heavy score distribution.
    pub quantize: Option<u32>,
}

impl DatasetSpec {
    /// Scaled-down sizes for tests and quick runs (`scale` divides both
    /// train and test sizes, minimum 100).
    pub fn scaled(mut self, scale: usize) -> Self {
        self.train_size = (self.train_size / scale).max(100);
        self.test_size = (self.test_size / scale).max(100);
        self
    }
}

/// Hepmass-like: 28 features, 50/50 classes, strong separation. The
/// paper's largest stream (500k train / 3.5M test).
pub fn hepmass_like() -> DatasetSpec {
    DatasetSpec {
        name: "hepmass",
        dims: 28,
        train_size: 500_000,
        test_size: 3_500_000,
        pos_rate: 0.5,
        separation: 2.4,
        noise: 1.0,
        quantize: None,
    }
}

/// Miniboone-like: 50 features, 28% positives, moderate overlap
/// (30k train / 100k test).
pub fn miniboone_like() -> DatasetSpec {
    DatasetSpec {
        name: "miniboone",
        dims: 50,
        train_size: 30_064,
        test_size: 100_000,
        pos_rate: 0.28,
        separation: 1.6,
        noise: 1.0,
        quantize: None,
    }
}

/// Tvads-like: wide features, near-balanced, weak separation and
/// *quantized* scores (40k train / 89k test). The quantization forces
/// duplicate-score tree nodes, the structurally distinct regime.
pub fn tvads_like() -> DatasetSpec {
    DatasetSpec {
        name: "tvads",
        dims: 124,
        train_size: 40_265,
        test_size: 89_420,
        pos_rate: 0.45,
        separation: 1.0,
        noise: 1.3,
        quantize: Some(256),
    }
}

/// The paper's three benchmark datasets (Table 1 order).
pub fn paper_datasets() -> Vec<DatasetSpec> {
    vec![hepmass_like(), miniboone_like(), tvads_like()]
}

/// Instantiated generator: draws examples and analytic score streams.
#[derive(Clone, Debug)]
pub struct Dataset {
    spec: DatasetSpec,
    /// Unit discriminative direction (class mean offset).
    direction: Vec<f64>,
    rng: Pcg,
}

impl Dataset {
    /// Instantiate a spec with a seed (direction and draws deterministic).
    pub fn new(spec: DatasetSpec, seed: u64) -> Self {
        let mut rng = Pcg::seed_stream(seed, 0xD5);
        let mut direction: Vec<f64> = (0..spec.dims).map(|_| rng.normal()).collect();
        let norm = direction.iter().map(|x| x * x).sum::<f64>().sqrt().max(1e-12);
        for d in &mut direction {
            *d /= norm;
        }
        Dataset { spec, direction, rng }
    }

    /// The spec this dataset was built from.
    pub fn spec(&self) -> &DatasetSpec {
        &self.spec
    }

    /// Draw one labelled example. Positives are shifted by `−separation`
    /// along the discriminative direction (lower margin ⇒ lower score,
    /// matching the paper's convention: larger score ⇒ more negative).
    pub fn example(&mut self) -> Example {
        let label = self.rng.chance(self.spec.pos_rate);
        let shift = if label { -self.spec.separation } else { 0.0 };
        let features: Vec<f32> = self
            .direction
            .iter()
            .map(|&d| (d * shift + self.rng.normal() * self.spec.noise) as f32)
            .collect();
        Example { features, label }
    }

    /// Draw a batch of examples.
    pub fn examples(&mut self, n: usize) -> Vec<Example> {
        (0..n).map(|_| self.example()).collect()
    }

    /// Analytic score for an example: the logistic of its margin along
    /// the discriminative direction — the Bayes-optimal family the
    /// trained logistic regression converges to. Quantized per spec.
    pub fn analytic_score(&self, ex: &Example) -> f64 {
        let margin: f64 = ex
            .features
            .iter()
            .zip(&self.direction)
            .map(|(&f, &d)| f64::from(f) * d)
            .sum::<f64>()
            + 0.5 * self.spec.separation;
        let score = 1.0 / (1.0 + (-margin).exp());
        self.quantize(score)
    }

    /// Apply the spec's score quantization.
    pub fn quantize(&self, score: f64) -> f64 {
        match self.spec.quantize {
            Some(levels) => (score * f64::from(levels)).floor() / f64::from(levels),
            None => score,
        }
    }

    /// Draw `n` scored pairs `(score, label)` from the analytic-score
    /// shortcut (no classifier in the loop).
    pub fn score_stream(&mut self, n: usize) -> Vec<(f64, bool)> {
        (0..n)
            .map(|_| {
                let ex = self.example();
                (self.analytic_score(&ex), ex.label)
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::NaiveAuc;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Dataset::new(miniboone_like().scaled(100), 7);
        let mut b = Dataset::new(miniboone_like().scaled(100), 7);
        for _ in 0..50 {
            let (ea, eb) = (a.example(), b.example());
            assert_eq!(ea.features, eb.features);
            assert_eq!(ea.label, eb.label);
        }
    }

    #[test]
    fn pos_rate_respected() {
        for spec in paper_datasets() {
            let rate = spec.pos_rate;
            let mut d = Dataset::new(spec, 1);
            let n = 20_000;
            let pos = (0..n).filter(|_| d.example().label).count();
            let got = pos as f64 / n as f64;
            assert!((got - rate).abs() < 0.02, "{}: {got} vs {rate}", d.spec().name);
        }
    }

    #[test]
    fn analytic_scores_discriminate_as_specified() {
        // Separation ordering must translate into AUC ordering, with
        // hepmass clearly high and tvads clearly lower.
        let mut aucs = std::collections::HashMap::new();
        for spec in paper_datasets() {
            let name = spec.name;
            let mut d = Dataset::new(spec, 3);
            let pairs = d.score_stream(8000);
            aucs.insert(name, NaiveAuc::of(&pairs));
        }
        let (h, m, t) = (aucs["hepmass"], aucs["miniboone"], aucs["tvads"]);
        assert!(h > 0.90, "hepmass AUC {h}");
        assert!(m > 0.75 && m < h, "miniboone AUC {m}");
        assert!(t > 0.60 && t < m, "tvads AUC {t}");
    }

    #[test]
    fn quantization_produces_duplicates() {
        let mut d = Dataset::new(tvads_like().scaled(100), 5);
        let pairs = d.score_stream(2000);
        let mut scores: Vec<f64> = pairs.iter().map(|p| p.0).collect();
        scores.sort_by(f64::total_cmp);
        scores.dedup();
        assert!(
            scores.len() <= 256,
            "tvads must quantize to ≤256 levels, got {}",
            scores.len()
        );
        let mut d = Dataset::new(hepmass_like().scaled(1000), 5);
        let pairs = d.score_stream(2000);
        let mut scores: Vec<f64> = pairs.iter().map(|p| p.0).collect();
        scores.sort_by(f64::total_cmp);
        scores.dedup();
        assert!(scores.len() > 1900, "hepmass scores continuous");
    }

    #[test]
    fn scores_are_valid_probabilities() {
        for spec in paper_datasets() {
            let mut d = Dataset::new(spec.scaled(100), 9);
            for (s, _) in d.score_stream(1000) {
                assert!((0.0..=1.0).contains(&s), "score {s} out of range");
            }
        }
    }

    #[test]
    fn scaled_reduces_sizes() {
        let s = hepmass_like().scaled(1000);
        assert_eq!(s.train_size, 500);
        assert_eq!(s.test_size, 3500);
        let tiny = hepmass_like().scaled(usize::MAX);
        assert_eq!(tiny.train_size, 100);
    }

    #[test]
    fn table1_sizes_match_paper() {
        let specs = paper_datasets();
        assert_eq!(specs[0].train_size, 500_000);
        assert_eq!(specs[0].test_size, 3_500_000);
        assert_eq!(specs[1].train_size, 30_064);
        assert_eq!(specs[1].test_size, 100_000);
        assert_eq!(specs[2].train_size, 40_265);
        assert_eq!(specs[2].test_size, 89_420);
    }
}
