//! Stream sources, synthetic datasets and drift injection.
//!
//! * [`rng`] — deterministic PCG random numbers (no external crates);
//! * [`synth`] — parametric generators standing in for the paper's UCI
//!   datasets (DESIGN.md §Substitutions), plus the multi-stream fleet
//!   generator ([`MultiStream`]) with per-stream drift schedules;
//! * [`drift`] — concept-drift injectors for the monitoring scenario;
//! * [`source`] — CSV stream I/O.

pub mod drift;
pub mod rng;
pub mod source;
pub mod synth;

pub use drift::Drift;
pub use rng::Pcg;
pub use synth::{
    hepmass_like, miniboone_like, paper_datasets, tvads_like, Dataset, DatasetSpec,
    DriftSchedule, MultiStream, StreamProfile,
};
