//! Label-flipped estimator (§4.1 remark).
//!
//! Proposition 1 bounds the error *relative to AUC*: `|ãuc − auc| ≤
//! ε·auc/2`. When AUC is close to 1 the guarantee is loose in the regime
//! that matters. The paper's remedy: flip the labels (turning AUC into
//! `1 − auc`) and report `1 − ApproxAUC(C)`, which yields
//! `|ãuc − auc| ≤ (1 − auc)·ε/2` — tight exactly when the monitored
//! system is healthy.

use super::{ApproxAuc, AucEstimator};

/// Approximate estimator with the guarantee anchored at `1 − auc`
/// (preferable when AUC ≈ 1, e.g. a healthy anomaly detector).
#[derive(Clone, Debug)]
pub struct FlippedAuc {
    inner: ApproxAuc,
}

impl FlippedAuc {
    /// New estimator with parameter `ε ≥ 0`; guarantee
    /// `|ãuc − auc| ≤ (1 − auc)·ε/2`.
    pub fn new(epsilon: f64) -> Self {
        FlippedAuc { inner: ApproxAuc::new(epsilon) }
    }

    /// The `ε` this estimator was built with.
    pub fn epsilon(&self) -> f64 {
        self.inner.epsilon()
    }

    /// Size of the inner compressed list.
    pub fn compressed_len(&self) -> usize {
        self.inner.compressed_len()
    }

    /// Exact AUC (O(k), for error measurement).
    pub fn exact_auc(&self) -> f64 {
        1.0 - self.inner.exact_auc()
    }

    /// Inner-invariant check for tests.
    pub fn check_invariants(&self) {
        self.inner.check_invariants();
    }
}

impl AucEstimator for FlippedAuc {
    fn insert(&mut self, score: f64, pos: bool) {
        self.inner.insert(score, !pos);
    }

    fn remove(&mut self, score: f64, pos: bool) {
        self.inner.remove(score, !pos);
    }

    fn auc(&self) -> f64 {
        1.0 - self.inner.auc()
    }

    fn len(&self) -> usize {
        self.inner.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::NaiveAuc;
    use crate::testing::{check, Pcg};

    /// Flipping labels on the naive oracle mirrors AUC around 0.5.
    #[test]
    fn flip_identity_on_oracle() {
        let pairs = [(0.1, true), (0.2, false), (0.6, true), (0.9, false)];
        let flipped: Vec<(f64, bool)> = pairs.iter().map(|&(s, p)| (s, !p)).collect();
        assert!((NaiveAuc::of(&pairs) + NaiveAuc::of(&flipped) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn flipped_guarantee_near_one() {
        // A high-AUC stream (positives low, negatives high, slight
        // overlap): the flipped estimator must satisfy the (1−auc)·ε/2
        // bound, which is far stronger than ε·auc/2 here.
        let eps = 0.4;
        check(0xF11, 10, |rng| {
            let mut est = FlippedAuc::new(eps);
            let mut naive = NaiveAuc::new();
            for _ in 0..400 {
                let pos = rng.chance(0.5);
                let score = if pos {
                    rng.normal_with(0.2, 0.08)
                } else {
                    rng.normal_with(0.8, 0.08)
                };
                est.insert(score, pos);
                naive.insert(score, pos);
            }
            est.check_invariants();
            let truth = naive.auc();
            assert!(truth > 0.95, "stream should be high-AUC, got {truth}");
            let got = est.auc();
            let tol = (1.0 - truth) * eps / 2.0 + 1e-12;
            assert!(
                (got - truth).abs() <= tol,
                "flipped guarantee: got {got}, truth {truth}, tol {tol}"
            );
        });
    }

    #[test]
    fn insert_remove_roundtrip() {
        let mut est = FlippedAuc::new(0.1);
        let mut rng = Pcg::seed(3);
        let mut live = Vec::new();
        for _ in 0..300 {
            let pair = (rng.uniform(), rng.chance(0.3));
            est.insert(pair.0, pair.1);
            live.push(pair);
        }
        assert_eq!(est.len(), 300);
        for (s, p) in live {
            est.remove(s, p);
        }
        assert!(est.is_empty());
        assert_eq!(est.auc(), 0.5);
    }
}
