//! Exact sliding-window AUC — the §5 baseline.
//!
//! Brzezinski & Stefanowski maintain the window in a red-black tree and
//! recompute AUC from scratch on every update, giving `O(log k)` updates
//! and `O(k)` queries. This estimator reproduces that baseline with the
//! same augmented tree as the approximate estimator (minus `TP`/`P`/`C`,
//! which the baseline does not need), so the Figure 3 speed-up comparison
//! measures the algorithmic difference, not incidental constant factors.

use super::support::{Acc, Counts};
use super::{auc_terms_doubled, finish_auc, AucEstimator};
use crate::collections::{RbTree, Score};

/// Exact estimator: `O(log k)` update, `O(k)` AUC query.
#[derive(Clone, Debug, Default)]
pub struct ExactAuc {
    t: RbTree<Counts, Acc>,
    total_pos: u64,
    total_neg: u64,
}

impl ExactAuc {
    /// Empty estimator.
    pub fn new() -> Self {
        Self::default()
    }

    /// Number of distinct scores currently held.
    pub fn distinct_scores(&self) -> usize {
        self.t.len()
    }

    fn update(&mut self, score: f64, pos: bool, delta: i64) {
        let s = Score(super::canon(score));
        assert!(s.is_valid_entry(), "scores must be finite");
        if delta > 0 {
            let init = if pos { Counts { p: 1, n: 0 } } else { Counts { p: 0, n: 1 } };
            let (v, fresh) = self.t.insert(s, || init);
            if !fresh {
                self.t.with_val_mut(v, |c| if pos { c.p += 1 } else { c.n += 1 });
            }
        } else {
            let v = self.t.find(s).expect("exact remove: score not present");
            let c = *self.t.val(v);
            if pos {
                assert!(c.p > 0, "exact remove: no positive at this score");
            } else {
                assert!(c.n > 0, "exact remove: no negative at this score");
            }
            self.t.with_val_mut(v, |c| if pos { c.p -= 1 } else { c.n -= 1 });
            let c = *self.t.val(v);
            if c.p == 0 && c.n == 0 {
                self.t.remove(v);
            }
        }
        // Checked total maintenance: a silent wrap here would corrupt
        // every subsequent read, so mismatched insert/remove traffic
        // must fail loudly at the faulty call.
        let total = if pos { &mut self.total_pos } else { &mut self.total_neg };
        let class = if pos { "positive" } else { "negative" };
        *total = if delta >= 0 {
            total
                .checked_add(delta as u64)
                .unwrap_or_else(|| panic!("exact: {class} total overflow"))
        } else {
            total.checked_sub(delta.unsigned_abs()).unwrap_or_else(|| {
                panic!("exact: {class} total underflow — removed more {class}s than inserted")
            })
        };
    }
}

impl AucEstimator for ExactAuc {
    fn insert(&mut self, score: f64, pos: bool) {
        self.update(score, pos, 1);
    }

    fn remove(&mut self, score: f64, pos: bool) {
        self.update(score, pos, -1);
    }

    /// Full Eq. 1 enumeration over the tree: `O(k)`.
    ///
    /// The stored class totals are asserted against the scan's own
    /// counts in release builds too — the scan already pays `O(k)`, so
    /// the check is free, and a drift here means the tree and the
    /// totals disagree about what the window holds.
    fn auc(&self) -> f64 {
        let groups = self.t.iter().map(|id| {
            let c = self.t.val(id);
            (c.p, c.n)
        });
        let (a2, pos, neg) = auc_terms_doubled(groups);
        assert_eq!(pos, self.total_pos, "exact: positive total drifted from the tree");
        assert_eq!(neg, self.total_neg, "exact: negative total drifted from the tree");
        finish_auc(a2, pos, neg)
    }

    fn len(&self) -> usize {
        (self.total_pos + self.total_neg) as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::NaiveAuc;
    use crate::testing::{check, gen_ops, Op};

    #[test]
    fn agrees_with_naive_on_random_streams() {
        for grid in [Some(4), Some(32), None] {
            check(0xE4AC ^ grid.unwrap_or(7), 20, |rng| {
                let mut exact = ExactAuc::new();
                let mut naive = NaiveAuc::new();
                for op in gen_ops(rng, 300, 60, grid) {
                    match op {
                        Op::Insert { score, pos } => {
                            exact.insert(score, pos);
                            naive.insert(score, pos);
                        }
                        Op::Remove { score, pos } => {
                            exact.remove(score, pos);
                            naive.remove(score, pos);
                        }
                    }
                    assert_eq!(exact.len(), naive.len());
                    let (a, b) = (exact.auc(), naive.auc());
                    assert!((a - b).abs() < 1e-12, "exact {a} vs naive {b}");
                }
            });
        }
    }

    #[test]
    fn node_lifecycle() {
        let mut e = ExactAuc::new();
        e.insert(1.0, true);
        e.insert(1.0, false);
        assert_eq!(e.distinct_scores(), 1);
        e.remove(1.0, true);
        assert_eq!(e.distinct_scores(), 1);
        e.remove(1.0, false);
        assert_eq!(e.distinct_scores(), 0);
        assert!(e.is_empty());
        assert_eq!(e.auc(), 0.5);
    }

    #[test]
    #[should_panic(expected = "not present")]
    fn remove_unknown_score_panics() {
        let mut e = ExactAuc::new();
        e.remove(3.0, true);
    }

    #[test]
    #[should_panic(expected = "no positive at this score")]
    fn remove_wrong_label_panics_descriptively() {
        // The score exists but only as a negative: the per-node guard
        // must fire before any count or total is touched.
        let mut e = ExactAuc::new();
        e.insert(1.0, false);
        e.remove(1.0, true);
    }

    #[test]
    fn totals_stay_coherent_with_the_tree() {
        // The `auc()` totals check is a release-build invariant now; a
        // read after every op exercises it across both score regimes.
        check(0x7074, 10, |rng| {
            let grid = if rng.chance(0.5) { Some(3 + rng.below(13)) } else { None };
            let mut e = ExactAuc::new();
            for op in gen_ops(rng, 200, 40, grid) {
                match op {
                    Op::Insert { score, pos } => e.insert(score, pos),
                    Op::Remove { score, pos } => e.remove(score, pos),
                }
                let _ = e.auc();
            }
        });
    }
}
