//! Shard-owned fleet state: the unit of parallelism.
//!
//! A [`Shard`] owns everything needed to ingest its slice of the fleet's
//! traffic without touching any other shard: the dense stream slab, the
//! stream-id → slot index, and a shard-local alarm log. Because the
//! state is fully shard-owned (no `Rc`, no interior mutability — see
//! the compile-time `Send` assertion at the bottom), each shard sits
//! behind its own mutex in the fleet core and is claimed by exactly one
//! worker of the work-stealing drain (`fleet/pool.rs`), so the locks
//! never contend. Batch buckets live fleet-side (`AucFleet` stages
//! them while the previous batch drains — the pipelining overlap) and
//! arrive here as plain slices; their *sizes* drive both the
//! precomputed tick stamps and the size-aware claim queue.
//!
//! Determinism contract: a shard's observable state after
//! [`Shard::drain_events`] depends only on the events it is given, the
//! `start_tick` and the batch timestamp — never on which thread ran it
//! or when. Alarms accumulate in the shard-local log and are merged
//! into the fleet-wide log in shard-index order, which is exactly the
//! order the serial path produces, so parallel and serial ingestion
//! are bit-identical (`rust/DESIGN.md` §Parallelism).
//!
//! Besides ingestion, the shard exposes the **read-only visitor
//! methods** the typed job layer (`fleet/pool.rs` `ShardWork`) runs
//! shard-parallel: per-shard snapshots, aggregate partials and the
//! query primitives behind `fleet/query.rs`. Each returns plain owned
//! data so per-shard results can be reassembled in shard-index order
//! without further locking (`rust/DESIGN.md` §Jobs).

use std::collections::HashMap;

use crate::coordinator::window::Window;
use crate::coordinator::{ApproxAuc, AucMonitor, MonitorEvent};

use super::config::StreamConfig;
use super::snapshot::{FleetAlarm, StreamSnapshot};

/// The "worst stream first" total order on `(windowed AUC, stream id)`
/// keys: ascending AUC, ties broken by id. Shared by
/// [`Shard::top_k_worst`] and the global merge in `fleet/query.rs` —
/// the per-shard truncation argument ("any global top-k member is in
/// its own shard's top-k") is sound **only** while both sorts use this
/// exact order, so neither site may diverge from it.
pub(super) fn worst_first(a: (f64, u64), b: (f64, u64)) -> std::cmp::Ordering {
    a.0.total_cmp(&b.0).then(a.1.cmp(&b.1))
}

/// One stream's state: sliding estimator window plus optional drift
/// monitor. Factored out of the shard so future per-stream features
/// (decay, flipped estimators) have one place to live.
#[derive(Clone, Debug)]
pub(super) struct StreamState {
    /// Stream id (also the key in the owning shard's index).
    pub(super) id: u64,
    /// The ε/2-approximate sliding window.
    pub(super) win: Window<ApproxAuc>,
    /// Drift monitor; `None` when monitoring is disabled for the stream.
    pub(super) monitor: Option<AucMonitor>,
    /// Stream-local events ingested over the stream's lifetime.
    pub(super) events: u64,
    /// Alarms raised over the stream's lifetime.
    pub(super) alarms: u32,
    /// Fleet-wide tick (total fleet event count) at this stream's most
    /// recent event; drives [`Shard::evict_idle`].
    pub(super) last_seen: u64,
    /// Caller-supplied timestamp (wall clock, epoch seconds, … — any
    /// monotone unit) at this stream's most recent event; drives
    /// [`Shard::evict_older_than`]. `0` until the fleet is ever fed a
    /// timestamp, in which case only tick-based eviction is meaningful.
    pub(super) last_seen_at: u64,
}

impl StreamState {
    pub(super) fn new(id: u64, cfg: &StreamConfig) -> StreamState {
        StreamState {
            id,
            win: Window::with_estimator(cfg.window, ApproxAuc::new(cfg.epsilon)),
            monitor: cfg.monitor.map(|m| m.build()),
            events: 0,
            alarms: 0,
            last_seen: 0,
            last_seen_at: 0,
        }
    }

    /// Point-in-time snapshot of this stream.
    pub(super) fn snapshot(&self) -> StreamSnapshot {
        StreamSnapshot {
            stream: self.id,
            auc: self.win.auc(),
            len: self.win.len(),
            compressed_len: self.win.estimator().compressed_len(),
            events: self.events,
            alarms: self.alarms,
            alarmed: self.monitor.as_ref().map_or(false, AucMonitor::is_alarmed),
            baseline: self.monitor.as_ref().map(AucMonitor::baseline),
        }
    }
}

/// One shard: dense stream slab, id index and local alarm log. See the
/// module docs for the ownership/determinism rules.
#[derive(Clone, Debug, Default)]
pub(super) struct Shard {
    /// Dense slab of stream states (hot streams stay contiguous).
    streams: Vec<StreamState>,
    /// Stream id → slot in `streams`.
    index: HashMap<u64, u32>,
    /// Shard-local alarm log, merged into the fleet log in shard order.
    alarms: Vec<FleetAlarm>,
}

impl Shard {
    /// Number of live streams in this shard.
    pub(super) fn len(&self) -> usize {
        self.streams.len()
    }

    /// The stream slab (slot order: insertion order, perturbed only by
    /// [`Shard::evict_idle`] compaction).
    pub(super) fn streams(&self) -> &[StreamState] {
        &self.streams
    }

    /// Look up a stream by id.
    pub(super) fn get(&self, id: u64) -> Option<&StreamState> {
        self.index.get(&id).map(|&slot| &self.streams[slot as usize])
    }

    /// Slot of `id`, creating the stream on first contact with the
    /// override config if one is registered, the defaults otherwise.
    pub(super) fn ensure_slot(
        &mut self,
        id: u64,
        defaults: &StreamConfig,
        overrides: &HashMap<u64, StreamConfig>,
    ) -> usize {
        if let Some(&slot) = self.index.get(&id) {
            return slot as usize;
        }
        let cfg = overrides.get(&id).copied().unwrap_or(*defaults);
        let slot = self.streams.len();
        self.streams.push(StreamState::new(id, &cfg));
        self.index.insert(id, slot as u32);
        slot
    }

    /// Reset a live stream under a new configuration (window contents,
    /// monitor state and counters start fresh). Returns false when the
    /// stream is not live. `now` is the current fleet tick and `at` the
    /// current fleet timestamp, recorded as the reset stream's
    /// `last_seen`/`last_seen_at` so a reconfigure does not make it
    /// instantly eligible for either eviction flavour.
    pub(super) fn reset_stream(&mut self, id: u64, cfg: &StreamConfig, now: u64, at: u64) -> bool {
        match self.index.get(&id) {
            Some(&slot) => {
                let mut st = StreamState::new(id, cfg);
                st.last_seen = now;
                st.last_seen_at = at;
                self.streams[slot as usize] = st;
                true
            }
            None => false,
        }
    }

    /// Ingest one event into a resolved slot: window update plus monitor
    /// observation (only on full windows, so partially filled streams
    /// never alarm on warm-up noise). `tick` is the fleet-wide event
    /// number of this event (1-based); `at` is the caller's timestamp
    /// for the batch the event arrived in.
    pub(super) fn push_slot(&mut self, slot: usize, score: f64, label: bool, tick: u64, at: u64) {
        let st = &mut self.streams[slot];
        st.win.push(score, label);
        st.events += 1;
        st.last_seen = tick;
        st.last_seen_at = at;
        if st.win.is_full() {
            if let Some(m) = st.monitor.as_mut() {
                let auc = st.win.auc();
                if m.observe(auc) == MonitorEvent::Alarm {
                    st.alarms += 1;
                    self.alarms.push(FleetAlarm {
                        stream: st.id,
                        stream_event: st.events,
                        auc,
                        baseline: m.baseline(),
                    });
                }
            }
        }
    }

    /// Ingest one batch bucket in arrival order, resolving the
    /// stream-id → slot lookup once per run of same-stream events.
    /// Events are stamped with fleet ticks `start_tick + 1, + 2, …` —
    /// the exact ticks the serial shard-by-shard drain would assign,
    /// which is what makes out-of-order parallel draining deterministic
    /// — and with the batch-constant timestamp `at`, which is equally
    /// scheduling-independent.
    pub(super) fn drain_events(
        &mut self,
        events: &[(u64, f64, bool)],
        defaults: &StreamConfig,
        overrides: &HashMap<u64, StreamConfig>,
        start_tick: u64,
        at: u64,
    ) {
        let mut tick = start_tick;
        let mut i = 0;
        while i < events.len() {
            let id = events[i].0;
            let mut j = i + 1;
            while j < events.len() && events[j].0 == id {
                j += 1;
            }
            let slot = self.ensure_slot(id, defaults, overrides);
            for &(_, score, label) in &events[i..j] {
                tick += 1;
                self.push_slot(slot, score, label, tick, at);
            }
            i = j;
        }
    }

    /// Append this shard's pending alarms to `out` (emptying the local
    /// log). Called in shard-index order by the fleet after every
    /// ingestion step, which fixes the fleet-wide alarm order.
    pub(super) fn take_alarms_into(&mut self, out: &mut Vec<FleetAlarm>) {
        out.append(&mut self.alarms);
    }

    /// Drop every stream matching `dead`, compacting the slab via
    /// swap-remove and repairing the index. Returns the number of
    /// evicted streams. Shared engine behind both eviction flavours.
    fn evict_where(&mut self, dead: impl Fn(&StreamState) -> bool) -> usize {
        let mut evicted = 0;
        let mut slot = 0;
        while slot < self.streams.len() {
            if dead(&self.streams[slot]) {
                let gone = self.streams.swap_remove(slot);
                self.index.remove(&gone.id);
                if let Some(moved) = self.streams.get(slot) {
                    self.index.insert(moved.id, slot as u32);
                }
                evicted += 1;
            } else {
                slot += 1;
            }
        }
        evicted
    }

    /// Drop streams idle for at least `max_idle` fleet ticks (`now` is
    /// the current fleet tick). Returns the number of evicted streams.
    pub(super) fn evict_idle(&mut self, now: u64, max_idle: u64) -> usize {
        self.evict_where(|st| now.saturating_sub(st.last_seen) >= max_idle)
    }

    /// Drop streams whose last event's timestamp is at least `max_age`
    /// behind `now` (both in the caller's clock units — see
    /// [`StreamState::last_seen_at`]). Returns the number of evicted
    /// streams.
    pub(super) fn evict_older_than(&mut self, now: u64, max_age: u64) -> usize {
        self.evict_where(|st| now.saturating_sub(st.last_seen_at) >= max_age)
    }

    // ---- read-only visitor methods (run shard-parallel by the typed
    // job layer; each returns owned data merged in shard-index order) --

    /// Snapshot every stream in slab order.
    pub(super) fn snapshots(&self) -> Vec<StreamSnapshot> {
        self.streams.iter().map(StreamState::snapshot).collect()
    }

    /// Aggregate partial: the windowed AUC of every live (non-empty)
    /// stream in slab order, the currently-alarmed count, and the
    /// total stream count.
    pub(super) fn aggregate_partial(&self) -> (Vec<f64>, usize, usize) {
        let mut aucs = Vec::with_capacity(self.streams.len());
        let mut alarmed = 0usize;
        for st in &self.streams {
            if !st.win.is_empty() {
                aucs.push(st.win.auc());
            }
            if st.monitor.as_ref().map_or(false, AucMonitor::is_alarmed) {
                alarmed += 1;
            }
        }
        (aucs, alarmed, self.streams.len())
    }

    /// This shard's `k` worst live streams by [`worst_first`] order,
    /// snapshotted. Streams with an empty window carry no estimate and
    /// are not ranked. Ranks lightweight `(auc, id, slot)` triples and
    /// snapshots only the `k` winners — the full-snapshot
    /// materialization is the expensive part on large shards.
    pub(super) fn top_k_worst(&self, k: usize) -> Vec<StreamSnapshot> {
        let mut ranked: Vec<(f64, u64, usize)> = self
            .streams
            .iter()
            .enumerate()
            .filter(|(_, st)| !st.win.is_empty())
            .map(|(slot, st)| (st.win.auc(), st.id, slot))
            .collect();
        ranked.sort_by(|a, b| worst_first((a.0, a.1), (b.0, b.1)));
        ranked.truncate(k);
        ranked.into_iter().map(|(_, _, slot)| self.streams[slot].snapshot()).collect()
    }

    /// Live streams whose windowed AUC is strictly below `threshold`.
    pub(super) fn count_below(&self, threshold: f64) -> usize {
        self.streams
            .iter()
            .filter(|st| !st.win.is_empty() && st.win.auc() < threshold)
            .count()
    }

    /// Histogram partial over `[0, 1]` split into `bins` equal-width
    /// buckets (AUC 1.0 lands in the last). Returns the per-bin counts
    /// and the number of live streams counted.
    pub(super) fn histogram(&self, bins: usize) -> (Vec<usize>, usize) {
        let mut counts = vec![0usize; bins];
        let mut live = 0usize;
        for st in &self.streams {
            if st.win.is_empty() {
                continue;
            }
            let bin = ((st.win.auc() * bins as f64) as usize).min(bins - 1);
            counts[bin] += 1;
            live += 1;
        }
        (counts, live)
    }
}

// Shards cross thread boundaries (pool workers lock and drain them);
// this compiles only while every constituent (rbtree arena, weighted
// lists, window FIFO, monitor) stays free of `Rc`/interior mutability.
const _: () = {
    const fn assert_send<T: Send>() {}
    assert_send::<StreamState>();
    assert_send::<Shard>();
};
