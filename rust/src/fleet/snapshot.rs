//! Fleet observability types: per-stream snapshots, alarm records and
//! fleet-level aggregate metrics.
//!
//! Everything here derives `PartialEq` so the executor's determinism
//! contract — parallel ingestion is bit-identical to serial — can be
//! asserted directly on whole snapshots and aggregates in tests.

/// One monitor alarm raised during ingestion (drained or read via
/// [`AucFleet::alarms`](super::AucFleet::alarms)).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FleetAlarm {
    /// Stream that degraded.
    pub stream: u64,
    /// Stream-local event count at which the alarm fired (1-based).
    pub stream_event: u64,
    /// Windowed AUC estimate at the alarm.
    pub auc: f64,
    /// Monitor baseline at the alarm.
    pub baseline: f64,
}

/// Point-in-time state of one stream.
#[derive(Clone, Debug, PartialEq)]
pub struct StreamSnapshot {
    /// Stream id.
    pub stream: u64,
    /// Current windowed AUC estimate.
    pub auc: f64,
    /// Pairs currently in the window (≤ configured capacity).
    pub len: usize,
    /// Estimator footprint: compressed-list size `|C|` (sentinels
    /// included) for approximate streams, distinct-score tree nodes for
    /// exact-maintained streams, `2·bins` count cells (`k`-independent)
    /// for binned streams.
    pub compressed_len: usize,
    /// Stream-local events ingested so far.
    pub events: u64,
    /// Alarms raised over the stream's lifetime.
    pub alarms: u32,
    /// True while the stream's monitor is inside an alarmed excursion.
    pub alarmed: bool,
    /// Monitor baseline (`None` when monitoring is disabled).
    pub baseline: Option<f64>,
    /// Logical memory cost of the stream in bytes: estimator
    /// structures plus window FIFO while live, frozen buffers while
    /// hibernated. Counts live structure sizes (never allocation
    /// capacity), so it is identical across execution strategies.
    pub footprint_bytes: u64,
}

/// Point-in-time state of the whole fleet
/// ([`AucFleet::snapshot`](super::AucFleet::snapshot)).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FleetSnapshot {
    /// All streams, sorted by stream id.
    pub streams: Vec<StreamSnapshot>,
    /// Ids of streams currently inside an alarmed excursion (same order
    /// as [`FleetSnapshot::streams`]).
    pub alarmed_streams: Vec<u64>,
    /// Total events ingested across the fleet.
    pub total_events: u64,
}

impl FleetSnapshot {
    /// Streams sorted by ascending AUC (worst first) — the triage view.
    pub fn worst_streams(&self, n: usize) -> Vec<&StreamSnapshot> {
        let mut refs: Vec<&StreamSnapshot> = self.streams.iter().collect();
        refs.sort_by(|a, b| a.auc.total_cmp(&b.auc));
        refs.truncate(n);
        refs
    }

    /// Mean AUC across streams with a non-empty window (0.5 if none).
    pub fn mean_auc(&self) -> f64 {
        let live: Vec<f64> =
            self.streams.iter().filter(|s| s.len > 0).map(|s| s.auc).collect();
        if live.is_empty() {
            0.5
        } else {
            live.iter().sum::<f64>() / live.len() as f64
        }
    }
}

/// Fleet-level aggregate metrics
/// ([`AucFleet::aggregate`](super::AucFleet::aggregate)): distribution
/// of the per-stream windowed AUC estimates plus alarm counts. Streams
/// with an empty window carry no estimate and are excluded from the
/// distribution (`live_streams` counts the included ones); with no live
/// streams every distribution field falls back to the crate-wide `0.5`
/// "no information" convention.
#[derive(Clone, Debug, PartialEq)]
pub struct FleetAggregate {
    /// Live streams in the fleet (evicted streams excluded).
    pub streams: usize,
    /// Streams with at least one pair in the window.
    pub live_streams: usize,
    /// Streams currently inside an alarmed excursion.
    pub alarmed_streams: usize,
    /// Total events ingested across the fleet.
    pub total_events: u64,
    /// Smallest per-stream AUC.
    pub min_auc: f64,
    /// 10th-percentile per-stream AUC (nearest-rank).
    pub p10_auc: f64,
    /// Median per-stream AUC (nearest-rank).
    pub median_auc: f64,
    /// 90th-percentile per-stream AUC (nearest-rank).
    pub p90_auc: f64,
    /// Largest per-stream AUC.
    pub max_auc: f64,
    /// Mean per-stream AUC, computed from a 2⁵²-fixed-point sum of the
    /// estimates (≤ 2⁻⁵³ relative quantization per stream). The integer
    /// sum is what lets the shard sketches maintain the mean
    /// incrementally yet bit-identically to a from-scratch rescan.
    pub mean_auc: f64,
    /// Total logical footprint of the fleet in bytes — the sum of
    /// every stream's [`StreamSnapshot::footprint_bytes`], live or
    /// hibernated (maintained in the shard sketches, so the
    /// sketch-backed aggregate reads it without visiting streams).
    pub footprint_bytes: u64,
}

impl FleetAggregate {
    /// The all-0.5 convention aggregate of a fleet with no live stream.
    pub(super) fn no_live(
        streams: usize,
        alarmed_streams: usize,
        total_events: u64,
        footprint_bytes: u64,
    ) -> FleetAggregate {
        FleetAggregate {
            streams,
            live_streams: 0,
            alarmed_streams,
            total_events,
            min_auc: 0.5,
            p10_auc: 0.5,
            median_auc: 0.5,
            p90_auc: 0.5,
            max_auc: 0.5,
            mean_auc: 0.5,
            footprint_bytes,
        }
    }

    /// Nearest-rank indices of (min, p10, median, p90, max) over
    /// `live` sorted values — one formula shared by the sketch-backed
    /// path (`AucFleet::aggregate`) and the rescan reference, so the
    /// two select the identical order statistics. Total over every
    /// `live`, including 0 and 1: `live - 1` saturates instead of
    /// underflowing, so a caller that forgets the empty-fleet guard
    /// gets `[0; 5]` rather than a wrapped index — the endpoints of
    /// the serving layer made that path reachable from the network.
    pub(super) fn ranks(live: usize) -> [usize; 5] {
        let top = live.saturating_sub(1);
        let q = |frac: f64| (top as f64 * frac).round() as usize;
        [0, q(0.1), q(0.5), q(0.9), top]
    }

    /// Mean of `live` AUCs from their fixed-point sum. One shared
    /// formula (again: sketch path ≡ rescan reference bit-for-bit);
    /// integer summation makes the value independent of summation
    /// order and of the add/remove history that produced it. Total at
    /// `live == 0` (the crate-wide 0.5 "no information" convention
    /// instead of a NaN from `0 / 0`), for the same
    /// network-reachability reason as [`FleetAggregate::ranks`].
    pub(super) fn mean_of_quantized(qauc_sum: i128, live: usize) -> f64 {
        if live == 0 {
            return 0.5;
        }
        (qauc_sum as f64) / super::shard::AUC_QUANT / live as f64
    }

    /// Build the aggregate from the collected per-stream AUCs — the
    /// rescan reference implementation. Sorting and the fixed-point
    /// summation are order-independent beyond the multiset of values,
    /// a prerequisite for serial/parallel bit-identity; the mean uses
    /// the same quantized sum the shard sketches maintain, so
    /// `AucFleet::aggregate` ≡ `AucFleet::aggregate_rescan` exactly.
    pub(super) fn compute(
        mut aucs: Vec<f64>,
        streams: usize,
        alarmed_streams: usize,
        total_events: u64,
        footprint_bytes: u64,
    ) -> FleetAggregate {
        let live_streams = aucs.len();
        if live_streams == 0 {
            return FleetAggregate::no_live(streams, alarmed_streams, total_events, footprint_bytes);
        }
        aucs.sort_unstable_by(f64::total_cmp);
        let [r_min, r10, r50, r90, r_max] = FleetAggregate::ranks(live_streams);
        let qauc_sum: i128 =
            aucs.iter().map(|&a| i128::from(super::shard::quantize_auc(a))).sum();
        FleetAggregate {
            streams,
            live_streams,
            alarmed_streams,
            total_events,
            min_auc: aucs[r_min],
            p10_auc: aucs[r10],
            median_auc: aucs[r50],
            p90_auc: aucs[r90],
            max_auc: aucs[r_max],
            mean_auc: FleetAggregate::mean_of_quantized(qauc_sum, live_streams),
            footprint_bytes,
        }
    }
}

/// Public view of the fleet-wide merge of the shard-maintained AUC
/// sketches ([`AucFleet::sketch_state`](super::AucFleet::sketch_state))
/// — exactly the state a dashboard needs, and what the serving layer's
/// subscription stream pushes per drain as deltas (`crate::serve`).
///
/// `bins[i]` counts live streams whose windowed AUC falls in bin `i`
/// of the fixed 64-bin partition `⌊auc · 64⌋` (AUC 1.0 lands in the
/// last bin); `qauc_sum` is the 2⁵²-fixed-point sum of the live
/// estimates, so [`FleetSketch::mean_auc`] reproduces the aggregate's
/// mean bit-for-bit. All fields are exactly reversible integers:
/// applying a subscription delta on top of a baseline reconstructs the
/// server's state without drift.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct FleetSketch {
    /// Live-stream counts per AUC bin (fixed 64-bin partition).
    pub bins: Vec<u64>,
    /// Streams with a non-empty window.
    pub live: usize,
    /// Streams inside an alarmed excursion.
    pub alarmed: usize,
    /// All streams, live or not (slab totals).
    pub streams: usize,
    /// Fixed-point (2⁵²) sum of the live AUC estimates.
    pub qauc_sum: i128,
}

impl FleetSketch {
    /// Mean per-stream AUC — bit-identical to
    /// [`FleetAggregate::mean_auc`](FleetAggregate) (same fixed-point
    /// formula); 0.5 with no live stream.
    pub fn mean_auc(&self) -> f64 {
        FleetAggregate::mean_of_quantized(self.qauc_sum, self.live)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn snap(stream: u64, auc: f64, len: usize) -> StreamSnapshot {
        StreamSnapshot {
            stream,
            auc,
            len,
            compressed_len: 2,
            events: len as u64,
            alarms: 0,
            alarmed: false,
            baseline: None,
            footprint_bytes: 64,
        }
    }

    #[test]
    fn worst_streams_sorts_ascending() {
        let s = FleetSnapshot {
            streams: vec![snap(1, 0.9, 5), snap(2, 0.4, 5), snap(3, 0.7, 5)],
            alarmed_streams: Vec::new(),
            total_events: 15,
        };
        let worst: Vec<u64> = s.worst_streams(2).iter().map(|x| x.stream).collect();
        assert_eq!(worst, vec![2, 3]);
    }

    #[test]
    fn mean_auc_skips_empty_windows() {
        let s = FleetSnapshot {
            streams: vec![snap(1, 1.0, 4), snap(2, 0.5, 0)],
            alarmed_streams: Vec::new(),
            total_events: 4,
        };
        assert_eq!(s.mean_auc(), 1.0);
        assert_eq!(FleetSnapshot::default().mean_auc(), 0.5);
    }

    #[test]
    fn aggregate_quantiles_nearest_rank() {
        // 11 values 0.0, 0.1, …, 1.0: every quantile lands on a rank.
        let aucs: Vec<f64> = (0..11).map(|i| f64::from(i) / 10.0).collect();
        let agg = FleetAggregate::compute(aucs, 11, 2, 99, 4096);
        assert_eq!(agg.streams, 11);
        assert_eq!(agg.live_streams, 11);
        assert_eq!(agg.alarmed_streams, 2);
        assert_eq!(agg.total_events, 99);
        assert_eq!(agg.footprint_bytes, 4096);
        assert_eq!(agg.min_auc, 0.0);
        assert_eq!(agg.p10_auc, 0.1);
        assert_eq!(agg.median_auc, 0.5);
        assert_eq!(agg.p90_auc, 0.9);
        assert_eq!(agg.max_auc, 1.0);
        assert!((agg.mean_auc - 0.5).abs() < 1e-12);
    }

    #[test]
    fn aggregate_is_order_independent() {
        let a = FleetAggregate::compute(vec![0.9, 0.1, 0.5], 3, 0, 3, 7);
        let b = FleetAggregate::compute(vec![0.5, 0.9, 0.1], 3, 0, 3, 7);
        assert_eq!(a, b);
    }

    #[test]
    fn aggregate_empty_is_half() {
        let agg = FleetAggregate::compute(Vec::new(), 0, 0, 0, 0);
        assert_eq!(agg.live_streams, 0);
        assert_eq!(agg.min_auc, 0.5);
        assert_eq!(agg.median_auc, 0.5);
        assert_eq!(agg.max_auc, 0.5);
        assert_eq!(agg.mean_auc, 0.5);
    }

    #[test]
    fn ranks_are_total_at_zero_and_one() {
        // `live == 0` must not underflow (no caller should index with
        // the result, but the formula itself has to be total now that
        // the serving layer reaches these paths from the network)…
        assert_eq!(FleetAggregate::ranks(0), [0; 5]);
        // …and a single live stream maps every quantile to itself.
        assert_eq!(FleetAggregate::ranks(1), [0; 5]);
        assert_eq!(FleetAggregate::ranks(2), [0, 0, 1, 1, 1]);
    }

    #[test]
    fn mean_of_quantized_is_total_at_zero() {
        assert_eq!(FleetAggregate::mean_of_quantized(0, 0), 0.5);
        assert_eq!(FleetAggregate::mean_of_quantized(12345, 0), 0.5);
        let one = i128::from(super::super::shard::quantize_auc(1.0));
        assert_eq!(FleetAggregate::mean_of_quantized(one, 1), 1.0);
    }

    #[test]
    fn sketch_mean_matches_the_aggregate_formula() {
        let sk = FleetSketch {
            bins: vec![0; 64],
            live: 0,
            alarmed: 0,
            streams: 0,
            qauc_sum: 0,
        };
        assert_eq!(sk.mean_auc(), 0.5);
        let one = i128::from(super::super::shard::quantize_auc(1.0));
        let sk = FleetSketch { live: 2, qauc_sum: one, ..sk };
        assert_eq!(sk.mean_auc(), 0.5);
    }
}
