//! Sort-based from-scratch AUC oracle.
//!
//! The simplest correct implementation of Eq. 1: keep the raw multiset,
//! sort on every query, group duplicate scores and sum. `O(k log k)` per
//! query — used as ground truth in tests and as the “recompute from
//! scratch” point of comparison in the related-work discussion (§5).

use super::{auc_terms_doubled, finish_auc, AucEstimator};

/// From-scratch AUC oracle over a raw multiset of pairs.
#[derive(Clone, Debug, Default)]
pub struct NaiveAuc {
    entries: Vec<(f64, bool)>,
}

impl NaiveAuc {
    /// Empty oracle.
    pub fn new() -> Self {
        Self::default()
    }

    /// Compute AUC of an arbitrary slice without building an estimator.
    pub fn of(pairs: &[(f64, bool)]) -> f64 {
        let mut sorted: Vec<(f64, bool)> = pairs.to_vec();
        sorted.sort_by(|a, b| a.0.total_cmp(&b.0));
        let mut groups: Vec<(u64, u64)> = Vec::new();
        let mut i = 0;
        while i < sorted.len() {
            let score = sorted[i].0;
            let mut p = 0;
            let mut n = 0;
            while i < sorted.len() && sorted[i].0 == score {
                if sorted[i].1 {
                    p += 1;
                } else {
                    n += 1;
                }
                i += 1;
            }
            groups.push((p, n));
        }
        let (a2, pos, neg) = auc_terms_doubled(groups.into_iter());
        finish_auc(a2, pos, neg)
    }
}

impl AucEstimator for NaiveAuc {
    fn insert(&mut self, score: f64, pos: bool) {
        self.entries.push((score, pos));
    }

    fn remove(&mut self, score: f64, pos: bool) {
        let i = self
            .entries
            .iter()
            .position(|&(s, p)| s == score && p == pos)
            .expect("naive remove: pair not present");
        self.entries.swap_remove(i);
    }

    fn auc(&self) -> f64 {
        NaiveAuc::of(&self.entries)
    }

    fn len(&self) -> usize {
        self.entries.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_values() {
        // Convention: larger score ⇒ more negative, so positives-low is 1.
        assert_eq!(NaiveAuc::of(&[(0.1, true), (0.9, false)]), 1.0);
        assert_eq!(NaiveAuc::of(&[(0.9, true), (0.1, false)]), 0.0);
        assert_eq!(NaiveAuc::of(&[(0.5, true), (0.5, false)]), 0.5);
        assert_eq!(
            NaiveAuc::of(&[(0.1, true), (0.5, true), (0.3, false), (0.5, false)]),
            2.5 / 4.0
        );
    }

    #[test]
    fn empty_class_is_half() {
        assert_eq!(NaiveAuc::of(&[]), 0.5);
        assert_eq!(NaiveAuc::of(&[(0.3, true)]), 0.5);
        assert_eq!(NaiveAuc::of(&[(0.3, false)]), 0.5);
    }

    #[test]
    fn estimator_interface_roundtrip() {
        let mut e = NaiveAuc::new();
        e.insert(0.1, true);
        e.insert(0.9, false);
        e.insert(0.5, false);
        assert_eq!(e.len(), 3);
        assert_eq!(e.auc(), 1.0);
        e.remove(0.5, false);
        assert_eq!(e.auc(), 1.0);
        e.remove(0.1, true);
        assert_eq!(e.auc(), 0.5);
    }

    #[test]
    #[should_panic(expected = "not present")]
    fn remove_missing_panics() {
        let mut e = NaiveAuc::new();
        e.insert(0.1, true);
        e.remove(0.1, false);
    }

    /// AUC equals the pair-counting probability definition.
    #[test]
    fn matches_pair_counting() {
        use crate::testing::Pcg;
        let mut rng = Pcg::seed(11);
        for _ in 0..50 {
            let k = 2 + rng.below(40) as usize;
            let pairs: Vec<(f64, bool)> = (0..k)
                .map(|_| (rng.below(10) as f64 / 10.0, rng.chance(0.5)))
                .collect();
            let pos: Vec<f64> = pairs.iter().filter(|e| e.1).map(|e| e.0).collect();
            let neg: Vec<f64> = pairs.iter().filter(|e| !e.1).map(|e| e.0).collect();
            if pos.is_empty() || neg.is_empty() {
                continue;
            }
            let mut num = 0.0;
            for &sp in &pos {
                for &sn in &neg {
                    // Correct ordering under the paper's convention: the
                    // positive scores lower than the negative.
                    if sp < sn {
                        num += 1.0;
                    } else if sp == sn {
                        num += 0.5;
                    }
                }
            }
            let want = num / (pos.len() * neg.len()) as f64;
            let got = NaiveAuc::of(&pairs);
            assert!((got - want).abs() < 1e-12, "got {got}, want {want}");
        }
    }
}
