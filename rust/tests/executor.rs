//! Scheduling-adversarial executor suite: the persistent work-stealing
//! pool must be **bit-identical** to serial ingestion no matter how the
//! scheduler interleaves workers — and must survive everything a
//! production ingest loop throws at it (skewed traffic, pool reuse
//! across hundreds of batches, queries and eviction between batches,
//! panicking streams).
//!
//! The determinism argument under test (`rust/DESIGN.md` §Parallelism):
//! shards stamp precomputed fleet-wide ticks, shard state is disjoint,
//! and alarm logs merge in shard-index order — so *any* claim order the
//! stealing cursor produces must yield the same fleet. These tests try
//! to break that with pathologically skewed stream→shard distributions
//! (a few streams take most of the traffic, so one bucket dwarfs the
//! rest), worker counts ∈ {2, 4, 8, 16} (more workers than busy shards
//! included), one pool reused across 100+ batches, pipelining on and
//! off, and `aggregate()` / `snapshot_iter()` / `evict_idle()`
//! interleaved between batches. Every case is seeded through
//! `streamauc::testing::check`, so a failure prints a replayable seed.

use std::collections::HashSet;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

use streamauc::fleet::{
    AucFleet, AucHistogram, EstimatorKind, FleetAggregate, FleetAlarm, FleetConfig,
    FleetExecutor, MonitorConfig, StreamConfig, StreamSnapshot,
};
use streamauc::serve::{http_get, json, wire, BinClient, FleetServer};
use streamauc::stream::Pcg;

type Event = (u64, f64, bool);

// ---------------------------------------------------------------------
// Adversarial schedule machinery
// ---------------------------------------------------------------------

/// Clock units one batch advances the fleet timestamp by (batch `i` is
/// stamped `(i + 1) · BATCH_CLOCK`), so `EvictOlderThan` thresholds
/// below are in "batches × 37".
const BATCH_CLOCK: u64 = 37;

/// One step of an ingest-loop schedule, replayed identically against
/// the serial reference and every parallel fleet.
#[derive(Clone, Copy, Debug)]
enum Step {
    /// Push batch `i` of the pre-generated trace, stamped with the
    /// batch clock.
    Batch(usize),
    /// Fleet-wide aggregate between batches.
    Aggregate,
    /// Streaming snapshot between batches.
    SnapshotIter,
    /// Worst-k triage query between batches.
    TopK(usize),
    /// Threshold count query between batches.
    CountBelow(f64),
    /// AUC distribution query between batches.
    Histogram(usize),
    /// Tick-idleness eviction with the given threshold between batches.
    EvictIdle(u64),
    /// Timestamp-age eviction with the given threshold between batches.
    EvictOlderThan(u64),
    /// Cold-stream hibernation sweep with the given idle threshold
    /// between batches; subsequent batches rehydrate transparently.
    Hibernate(u64),
}

/// Everything observable about a schedule run. Two fleets are
/// interchangeable iff their digests are equal.
#[derive(Debug, PartialEq)]
struct Digest {
    aggregates: Vec<FleetAggregate>,
    iter_snapshots: Vec<Vec<StreamSnapshot>>,
    top_k: Vec<Vec<StreamSnapshot>>,
    below: Vec<usize>,
    histograms: Vec<AucHistogram>,
    evicted: Vec<usize>,
    evicted_by_age: Vec<usize>,
    hibernated: Vec<usize>,
    final_streams: Vec<StreamSnapshot>,
    final_alarmed: Vec<u64>,
    alarms: Vec<FleetAlarm>,
    total_events: u64,
    clock: u64,
}

fn run_schedule(fleet: &mut AucFleet, batches: &[Vec<Event>], steps: &[Step]) -> Digest {
    let mut aggregates = Vec::new();
    let mut iter_snapshots = Vec::new();
    let mut top_k = Vec::new();
    let mut below = Vec::new();
    let mut histograms = Vec::new();
    let mut evicted = Vec::new();
    let mut evicted_by_age = Vec::new();
    let mut hibernated = Vec::new();
    for &step in steps {
        match step {
            Step::Batch(i) => fleet.push_batch_at(&batches[i], (i as u64 + 1) * BATCH_CLOCK),
            Step::Aggregate => {
                // Sketch ≡ pre-sketch: the running shard sketches must
                // answer bit-identically to the retained per-stream
                // rescan at every step of every schedule.
                let agg = fleet.aggregate();
                assert_eq!(
                    agg,
                    fleet.aggregate_rescan(),
                    "sketch-backed aggregate drifted from the rescan reference"
                );
                aggregates.push(agg);
            }
            Step::SnapshotIter => iter_snapshots.push(fleet.snapshot_iter().collect()),
            Step::TopK(k) => {
                let worst = fleet.top_k_worst(k);
                // Pre-sketch reference: full sort of the live snapshot
                // on the same (auc, id) total order.
                let mut reference: Vec<StreamSnapshot> = fleet
                    .snapshot()
                    .streams
                    .into_iter()
                    .filter(|s| s.len > 0)
                    .collect();
                reference.sort_by(|a, b| a.auc.total_cmp(&b.auc).then(a.stream.cmp(&b.stream)));
                reference.truncate(k);
                assert_eq!(worst, reference, "bin-pruned top-k drifted from the full sort");
                top_k.push(worst);
            }
            Step::CountBelow(t) => {
                let n = fleet.count_below(t);
                let reference =
                    fleet.snapshot().streams.iter().filter(|s| s.len > 0 && s.auc < t).count();
                assert_eq!(n, reference, "sketch count_below({t}) drifted from rescan");
                below.push(n);
            }
            Step::Histogram(bins) => {
                let h = fleet.auc_histogram(bins);
                // Pre-sketch reference: direct rebin of the snapshot.
                let b = bins.max(1);
                let mut counts = vec![0usize; b];
                let mut live = 0usize;
                for s in fleet.snapshot().streams.iter().filter(|s| s.len > 0) {
                    counts[((s.auc * b as f64) as usize).min(b - 1)] += 1;
                    live += 1;
                }
                assert_eq!(
                    h,
                    AucHistogram { counts, live_streams: live },
                    "sketch histogram({bins}) drifted from rescan"
                );
                histograms.push(h);
            }
            Step::EvictIdle(max_idle) => evicted.push(fleet.evict_idle(max_idle)),
            Step::EvictOlderThan(max_age) => evicted_by_age.push(fleet.evict_older_than(max_age)),
            Step::Hibernate(max_idle) => hibernated.push(fleet.hibernate_idle(max_idle)),
        }
    }
    // Whatever the schedule did — drains, evictions, resets — every
    // shard's running sketch must still equal a from-scratch rebuild.
    fleet.verify_sketches();
    let snap = fleet.snapshot();
    Digest {
        aggregates,
        iter_snapshots,
        top_k,
        below,
        histograms,
        evicted,
        evicted_by_age,
        hibernated,
        final_streams: snap.streams,
        final_alarmed: snap.alarmed_streams,
        alarms: fleet.alarms().to_vec(),
        total_events: snap.total_events,
        clock: fleet.clock(),
    }
}

/// Pathologically skewed event soup: streams 0..3 take ~70% of all
/// traffic (one bucket dwarfs the rest — the regime that serialized
/// the old chunked executor), the cold tail goes completely silent for
/// the middle sixth of the run (guaranteeing `evict_idle` has victims)
/// and again for a late stretch (guaranteeing `evict_older_than` has
/// victims of its own after the tail was revived), and the hot
/// streams' labels decouple from their scores halfway through (feeding
/// the drift monitors real alarms).
fn skewed_batches(rng: &mut Pcg, n_streams: u64, n_batches: usize) -> Vec<Vec<Event>> {
    let broken = 2.min(n_streams);
    (0..n_batches)
        .map(|b| {
            let len = 128 + rng.below(385) as usize; // 128..=512
            let tail_silent = (b >= n_batches / 3 && b < n_batches / 2)
                || (b >= 2 * n_batches / 3 && b < 5 * n_batches / 6);
            (0..len)
                .map(|_| {
                    let id = if tail_silent || rng.chance(0.7) {
                        rng.below(4.min(n_streams))
                    } else {
                        rng.below(n_streams)
                    };
                    let degraded = id < broken && b >= n_batches / 2;
                    let pos = rng.chance(0.5);
                    let score = if degraded {
                        rng.uniform()
                    } else if pos {
                        rng.normal_with(0.3, 0.1)
                    } else {
                        rng.normal_with(0.7, 0.1)
                    };
                    (id, score, pos)
                })
                .collect()
        })
        .collect()
}

fn monitored_defaults() -> StreamConfig {
    StreamConfig {
        window: 100,
        estimator: EstimatorKind::Approx { epsilon: 0.1 },
        monitor: Some(MonitorConfig { lambda: 0.001, margin: 0.08, patience: 30, warmup: 150 }),
    }
}

fn fleet_with(workers: usize, pool: bool, pipeline: bool) -> AucFleet {
    fleet_with_adaptive(workers, pool, pipeline, false)
}

fn fleet_with_adaptive(workers: usize, pool: bool, pipeline: bool, adaptive: bool) -> AucFleet {
    AucFleet::new(FleetConfig {
        shards: 16,
        workers,
        pool,
        pipeline,
        adaptive,
        stream_defaults: monitored_defaults(),
    })
}

/// The tentpole property: one persistent pool per fleet, reused across
/// 100+ batches of pathologically skewed traffic with queries (all
/// four `fleet/query.rs` queries run as pooled jobs) and both eviction
/// flavours interleaved, must be bit-identical to serial for workers ∈
/// {2, 4, 8, 16}, pipelined or not, under the scoped fallback, and
/// under adaptive worker scaling.
#[test]
fn pooled_ingestion_is_bit_identical_to_serial_under_adversarial_schedules() {
    streamauc::testing::check(0xADE5_CED1, 2, |rng| {
        let n_streams = 8 + rng.below(56); // 8..=63
        // ≥ 100 reused-pool batches; capped at 119 so the tail's silent
        // stretch [n/3, n/2) has delivered ≥ 8 batches × ≥ 128 events
        // (> the max eviction threshold of 999) by the eviction step at
        // batch 46 — the `evicted > 0` assertion below is deterministic.
        let n_batches = 100 + rng.below(20) as usize;
        let batches = skewed_batches(rng, n_streams, n_batches);
        // Interleave queries and eviction between batches, identically
        // for every fleet: every 7th step an aggregate, every 11th a
        // streaming snapshot, every 13th/17th/19th one of the query
        // layer's reads, every 29th a tick-idleness eviction pass, and
        // one timestamp-age eviction pass placed inside the *second*
        // silent stretch [2n/3, 5n/6) — which the idle passes skip, so
        // the age pass deterministically finds its own victims (the
        // tail last ticked at batch < 2n/3, an age of ≥ (n/6 − 5)
        // batches ≥ 11 · 37 clock units > the 300..=399 threshold).
        let age_step = 5 * n_batches / 6 - 5;
        let mut steps = Vec::new();
        for i in 0..n_batches {
            steps.push(Step::Batch(i));
            if i % 7 == 3 {
                steps.push(Step::Aggregate);
            }
            if i % 11 == 5 {
                steps.push(Step::SnapshotIter);
            }
            if i % 13 == 6 {
                steps.push(Step::TopK(1 + rng.below(8) as usize));
            }
            if i % 17 == 9 {
                steps.push(Step::CountBelow(0.4 + rng.uniform() * 0.4));
            }
            if i % 19 == 7 {
                // Alternate the pure-sketch-merge fast path (divisors
                // of the 64-bin sketch) with the cached-stat rebin
                // fallback (arbitrary bin counts).
                let bins = if rng.chance(0.5) {
                    [1usize, 2, 4, 8, 16, 32, 64][rng.below(7) as usize]
                } else {
                    3 + rng.below(13) as usize
                };
                steps.push(Step::Histogram(bins));
            }
            if i % 23 == 11 {
                // Thresholds derived from `i` (no rng draw, so the
                // seeded schedule above is unperturbed): i = 80 yields
                // 0 — a freeze-everything sweep the very next batch
                // must transparently rehydrate out of.
                steps.push(Step::Hibernate((i as u64 % 5) * 150));
            }
            let in_age_window = i >= 2 * n_batches / 3 && i < 5 * n_batches / 6;
            if i % 29 == 17 && !in_age_window {
                // Small enough that the tail's silent stretch (≥ 14
                // batches of ≥ 128 events) guarantees victims at the
                // eviction step landing inside it.
                steps.push(Step::EvictIdle(500 + rng.below(500)));
            }
            if i == age_step {
                steps.push(Step::EvictOlderThan(300 + rng.below(100)));
            }
        }
        let mut serial = fleet_with(1, false, false);
        let reference = run_schedule(&mut serial, &batches, &steps);
        assert!(!reference.alarms.is_empty(), "adversarial scenario must produce alarms to compare");
        assert!(
            reference.evicted.iter().any(|&e| e > 0),
            "adversarial scenario must evict something to compare"
        );
        assert!(
            reference.evicted_by_age.iter().any(|&e| e > 0),
            "adversarial scenario must age-evict something to compare"
        );
        assert!(
            reference.top_k.iter().any(|k| !k.is_empty())
                && reference.histograms.iter().any(|h| h.live_streams > 0),
            "adversarial scenario must produce query results to compare"
        );
        assert!(
            reference.hibernated.iter().any(|&h| h > 0),
            "adversarial scenario must hibernate something to compare"
        );

        for workers in [2usize, 4, 8, 16] {
            for pipeline in [false, true] {
                let mut pooled = fleet_with(workers, true, pipeline);
                let digest = run_schedule(&mut pooled, &batches, &steps);
                assert_eq!(
                    reference, digest,
                    "pooled fleet diverged from serial \
                     (workers {workers}, pipeline {pipeline}, {n_streams} streams)"
                );
            }
        }
        // The scoped fallback obeys the same contract.
        let mut scoped = fleet_with(4, false, false);
        let digest = run_schedule(&mut scoped, &batches, &steps);
        assert_eq!(reference, digest, "scoped fleet diverged from serial");
        // So does adaptive worker scaling (batches of 128..=512 events
        // land on every side of its crossover), pipelined or not.
        for pipeline in [false, true] {
            let mut adaptive = fleet_with_adaptive(8, true, pipeline, true);
            let digest = run_schedule(&mut adaptive, &batches, &steps);
            assert_eq!(
                reference, digest,
                "adaptive fleet diverged from serial (pipeline {pipeline})"
            );
        }
    });
}

/// `EstimatorKind` threading through the engine: a fleet mixing
/// ε-approximate and exact-maintained streams — overrides registered
/// before ingestion, the *broken* hot stream 0 among the exact ones —
/// obeys the same determinism contract as a homogeneous fleet. Every
/// execution strategy must be digest-identical to serial with
/// aggregates, triage queries and streaming snapshots interleaved.
#[test]
fn mixed_estimator_fleet_is_bit_identical_to_serial() {
    streamauc::testing::check(0x313C_ED00, 2, |rng| {
        let n_streams = 8 + rng.below(24); // 8..=31
        let n_batches = 40;
        let batches = skewed_batches(rng, n_streams, n_batches);
        // Every third stream runs the exact-maintained estimator under
        // the same window and monitor; stream 0 (hot *and* broken
        // halfway through) is among them, so exact streams exercise the
        // alarm path too.
        let exact_ids: Vec<u64> = (0..n_streams).filter(|id| id % 3 == 0).collect();
        let configure = |fleet: &mut AucFleet| {
            for &id in &exact_ids {
                fleet.configure_stream(
                    id,
                    monitored_defaults().with_estimator(EstimatorKind::ExactMaintained),
                );
            }
        };
        let mut steps = Vec::new();
        for i in 0..n_batches {
            steps.push(Step::Batch(i));
            if i % 5 == 2 {
                steps.push(Step::Aggregate);
            }
            if i % 7 == 3 {
                steps.push(Step::TopK(5));
            }
            if i % 11 == 6 {
                steps.push(Step::SnapshotIter);
            }
        }
        let mut serial = fleet_with(1, false, false);
        configure(&mut serial);
        let reference = run_schedule(&mut serial, &batches, &steps);
        assert!(!reference.alarms.is_empty(), "mixed scenario must alarm to compare");
        for (workers, pool, pipeline, adaptive) in [
            (4, true, false, false),
            (8, true, true, false),
            (8, true, true, true),
            (4, false, false, false),
        ] {
            let mut fleet = fleet_with_adaptive(workers, pool, pipeline, adaptive);
            configure(&mut fleet);
            let digest = run_schedule(&mut fleet, &batches, &steps);
            assert_eq!(
                reference, digest,
                "mixed-estimator fleet diverged from serial (workers {workers}, \
                 pool {pool}, pipeline {pipeline}, adaptive {adaptive})"
            );
        }
    });
}

/// The full three-way mix: binned bounded-score, exact-maintained and
/// ε-approximate streams in one fleet, with hot *broken* streams among
/// the binned and exact ones so all three kinds drive the alarm path.
/// The binned streams declare `[-1, 2]` — the trace's normal margins
/// (mean 0.3/0.7, sd 0.1) cannot leave it — and every digest component
/// (aggregates vs rescan, AUC histograms vs snapshot rebin, triage,
/// streaming snapshots, count-below, sketch verification) must be
/// bit-identical to serial under pooled, pipelined and adaptive
/// execution. The raw score distribution query, which reads binned
/// streams straight off their count arrays, must agree across
/// strategies too.
#[test]
fn three_way_mixed_estimator_fleet_is_bit_identical_to_serial() {
    streamauc::testing::check(0x3B1_ED01, 2, |rng| {
        let n_streams = 8 + rng.below(24); // 8..=31
        let n_batches = 40;
        let batches = skewed_batches(rng, n_streams, n_batches);
        // id % 3 == 0 → exact-maintained (stream 0: hot and broken),
        // id % 3 == 1 → binned (stream 1: hot and broken),
        // id % 3 == 2 → the ε-approximate default.
        let configure = |fleet: &mut AucFleet| {
            for id in 0..n_streams {
                match id % 3 {
                    0 => fleet.configure_stream(
                        id,
                        monitored_defaults().with_estimator(EstimatorKind::ExactMaintained),
                    ),
                    1 => fleet.configure_stream(
                        id,
                        monitored_defaults().with_estimator(EstimatorKind::Binned {
                            bins: 96,
                            lo: -1.0,
                            hi: 2.0,
                        }),
                    ),
                    _ => {}
                }
            }
        };
        let mut steps = Vec::new();
        for i in 0..n_batches {
            steps.push(Step::Batch(i));
            if i % 5 == 2 {
                steps.push(Step::Aggregate);
            }
            if i % 7 == 3 {
                steps.push(Step::TopK(5));
            }
            if i % 9 == 4 {
                // Cross-checked against the snapshot-derived rebin
                // inside `run_schedule` — with binned streams present.
                steps.push(Step::Histogram(3 + rng.below(13) as usize));
            }
            if i % 11 == 6 {
                steps.push(Step::SnapshotIter);
            }
            if i % 13 == 7 {
                steps.push(Step::CountBelow(0.4 + rng.uniform() * 0.4));
            }
        }
        let mut serial = fleet_with(1, false, false);
        configure(&mut serial);
        let reference = run_schedule(&mut serial, &batches, &steps);
        assert!(!reference.alarms.is_empty(), "three-way scenario must alarm to compare");
        assert!(
            reference.histograms.iter().any(|h| h.live_streams > 0),
            "three-way scenario must produce histograms to compare"
        );
        let reference_scores = serial.score_histogram(8);
        assert!(reference_scores.entries > 0, "score distribution must be non-empty");
        for (workers, pool, pipeline, adaptive) in [
            (4, true, false, false),
            (8, true, true, false),
            (8, true, true, true),
            (4, false, false, false),
        ] {
            let mut fleet = fleet_with_adaptive(workers, pool, pipeline, adaptive);
            configure(&mut fleet);
            let digest = run_schedule(&mut fleet, &batches, &steps);
            assert_eq!(
                reference, digest,
                "three-way mixed fleet diverged from serial (workers {workers}, \
                 pool {pool}, pipeline {pipeline}, adaptive {adaptive})"
            );
            assert_eq!(
                fleet.score_histogram(8),
                reference_scores,
                "score distribution diverged from serial (workers {workers})"
            );
        }
    });
}

// ---------------------------------------------------------------------
// Loopback serving digest (wire ≡ in-process, rust/src/serve)
// ---------------------------------------------------------------------

/// The digest contract extended over the wire: the same adversarial
/// schedule replayed against a pooled, **pipelined** fleet behind a
/// loopback [`FleetServer`] — ingestion routed through the server so
/// every drain publishes, every query answered over *both* protocols —
/// must reproduce the serial in-process digest exactly. Each wire
/// answer is held to three standards: the JSON body re-encodes to the
/// identical bytes, the binary payload re-encodes to the identical
/// bytes, and the decoded values (collected into a [`Digest`]) equal
/// the serial reference bit-for-bit.
#[test]
fn served_wire_answers_reproduce_the_serial_digest() {
    let mut rng = Pcg::seed(0x5E2F_ED16);
    let n_streams = 24;
    let n_batches = 40;
    let batches = skewed_batches(&mut rng, n_streams, n_batches);
    let mut steps = Vec::new();
    for i in 0..n_batches {
        steps.push(Step::Batch(i));
        if i % 5 == 2 {
            steps.push(Step::Aggregate);
        }
        if i % 7 == 3 {
            steps.push(Step::TopK(1 + rng.below(6) as usize));
        }
        if i % 11 == 4 {
            steps.push(Step::CountBelow(0.4 + rng.uniform() * 0.4));
        }
        if i % 9 == 6 {
            steps.push(Step::Histogram(1 + rng.below(16) as usize));
        }
    }
    let mut serial = fleet_with(1, false, false);
    let reference = run_schedule(&mut serial, &batches, &steps);
    assert!(!reference.alarms.is_empty(), "serving scenario must alarm to compare");
    assert!(
        reference.top_k.iter().any(|k| !k.is_empty()),
        "serving scenario must produce triage results to compare"
    );

    let server =
        FleetServer::start(fleet_with(8, true, true), "127.0.0.1:0").expect("bind loopback");
    let addr = server.local_addr();
    let mut bin = BinClient::connect(addr).expect("binary session");
    let mut aggregates = Vec::new();
    let mut top_k = Vec::new();
    let mut below = Vec::new();
    let mut histograms = Vec::new();
    for &step in &steps {
        match step {
            Step::Batch(i) => server.ingest_batch_at(&batches[i], (i as u64 + 1) * BATCH_CLOCK),
            Step::Aggregate => {
                let (status, body) = http_get(addr, "/aggregate").expect("http aggregate");
                assert_eq!(status, 200);
                let agg = json::aggregate_from_json(&body).expect("decode aggregate body");
                assert_eq!(json::aggregate_to_json(&agg), body, "aggregate re-encode drifted");
                let (code, payload) =
                    bin.request(wire::OP_AGGREGATE, &[]).expect("binary aggregate");
                assert_eq!(code, wire::STATUS_OK);
                assert_eq!(wire::decode_aggregate(&payload).expect("decode payload"), agg);
                assert_eq!(wire::encode_aggregate(&agg), payload, "aggregate bytes drifted");
                aggregates.push(agg);
            }
            Step::TopK(k) => {
                let (status, body) =
                    http_get(addr, &format!("/top_k_worst?k={k}")).expect("http top-k");
                assert_eq!(status, 200);
                let worst = json::top_k_from_json(&body).expect("decode top-k body");
                assert_eq!(json::top_k_to_json(&worst), body, "top-k re-encode drifted");
                let (code, payload) = bin
                    .request(wire::OP_TOP_K, &(k as u32).to_le_bytes())
                    .expect("binary top-k");
                assert_eq!(code, wire::STATUS_OK);
                assert_eq!(wire::decode_top_k(&payload).expect("decode payload"), worst);
                assert_eq!(wire::encode_top_k(&worst), payload, "top-k bytes drifted");
                top_k.push(worst);
            }
            Step::CountBelow(t) => {
                let (status, body) =
                    http_get(addr, &format!("/count_below?t={t}")).expect("http count-below");
                assert_eq!(status, 200);
                let (echoed, n) = json::count_below_from_json(&body).expect("decode count body");
                assert_eq!(echoed.to_bits(), t.to_bits(), "threshold echo drifted");
                let (code, payload) = bin
                    .request(wire::OP_COUNT_BELOW, &t.to_bits().to_le_bytes())
                    .expect("binary count-below");
                assert_eq!(code, wire::STATUS_OK);
                assert_eq!(wire::decode_count_below(&payload).expect("decode payload"), (t, n));
                below.push(n);
            }
            Step::Histogram(bins) => {
                let (status, body) = http_get(addr, &format!("/auc_histogram?bins={bins}"))
                    .expect("http histogram");
                assert_eq!(status, 200);
                let h = json::auc_histogram_from_json(&body).expect("decode histogram body");
                assert_eq!(json::auc_histogram_to_json(&h), body, "histogram re-encode drifted");
                let (code, payload) = bin
                    .request(wire::OP_AUC_HISTOGRAM, &(bins as u32).to_le_bytes())
                    .expect("binary histogram");
                assert_eq!(code, wire::STATUS_OK);
                assert_eq!(wire::decode_auc_histogram(&payload).expect("decode payload"), h);
                histograms.push(h);
            }
            Step::SnapshotIter | Step::EvictIdle(_) | Step::EvictOlderThan(_) => {
                unreachable!("not part of the served schedule")
            }
        }
    }

    // The served fleet's running sketches survive the schedule, and the
    // final snapshot crosses the wire byte-identically too.
    server.with_fleet(|f| f.verify_sketches());
    let (status, body) = http_get(addr, "/snapshot").expect("http snapshot");
    assert_eq!(status, 200);
    let snap = json::snapshot_from_json(&body).expect("decode snapshot body");
    assert_eq!(json::snapshot_to_json(&snap), body, "snapshot re-encode drifted");
    let (code, payload) = bin.request(wire::OP_SNAPSHOT, &[]).expect("binary snapshot");
    assert_eq!(code, wire::STATUS_OK);
    assert_eq!(wire::decode_snapshot(&payload).expect("decode payload"), snap);
    assert_eq!(wire::encode_snapshot(&snap), payload, "snapshot bytes drifted");

    let digest = Digest {
        aggregates,
        iter_snapshots: Vec::new(),
        top_k,
        below,
        histograms,
        evicted: Vec::new(),
        evicted_by_age: Vec::new(),
        final_streams: snap.streams,
        final_alarmed: snap.alarmed_streams,
        alarms: server.with_fleet_mut(|f| f.alarms().to_vec()),
        total_events: snap.total_events,
        clock: server.with_fleet(|f| f.clock()),
    };
    assert_eq!(reference, digest, "wire-served digest diverged from the serial reference");

    // The raw score distribution rides the same contract.
    let ref_scores = serial.score_histogram(8);
    let (status, body) = http_get(addr, "/score_histogram?bins=8").expect("http scores");
    assert_eq!(status, 200);
    let scores = json::score_histogram_from_json(&body).expect("decode scores body");
    assert_eq!(json::score_histogram_to_json(&scores), body, "score re-encode drifted");
    assert_eq!(scores, ref_scores, "served score distribution diverged from serial");
    let (code, payload) =
        bin.request(wire::OP_SCORE_HISTOGRAM, &8u32.to_le_bytes()).expect("binary scores");
    assert_eq!(code, wire::STATUS_OK);
    assert_eq!(wire::decode_score_histogram(&payload).expect("decode payload"), ref_scores);
}

/// Reconfiguring workers mid-stream (respawning the pool) must splice
/// invisibly: a fleet that switches 1 → 8 → 2 workers across a schedule
/// matches one that stays serial throughout.
#[test]
fn worker_reconfiguration_mid_stream_is_invisible() {
    let mut rng = Pcg::seed(0x5EC0);
    let batches = skewed_batches(&mut rng, 24, 60);
    let mut serial = fleet_with(1, false, false);
    let mut shifty = fleet_with(1, true, false);
    for (i, batch) in batches.iter().enumerate() {
        if i == 20 {
            shifty.set_workers(8);
            shifty.set_pipeline(true);
        }
        if i == 40 {
            shifty.set_workers(2);
        }
        serial.push_batch(batch);
        shifty.push_batch(batch);
    }
    assert_eq!(serial.snapshot(), shifty.snapshot());
    assert_eq!(serial.alarms(), shifty.alarms());
    assert_eq!(serial.aggregate(), shifty.aggregate());
}

// ---------------------------------------------------------------------
// Worker-participation regression (the ceil-chunking bug)
// ---------------------------------------------------------------------

/// A latch with a timeout: lets `quorum` threads prove they are all
/// concurrently inside the dispatched closure. With the old ceil-sized
/// chunking (9 items / 4 workers → 3 chunks) only 3 threads ever
/// existed, so the quorum could never assemble; the timeout turns that
/// hang into a countable failure.
struct Gate {
    arrived: Mutex<usize>,
    cv: Condvar,
    quorum: usize,
}

impl Gate {
    fn new(quorum: usize) -> Gate {
        Gate { arrived: Mutex::new(0), cv: Condvar::new(), quorum }
    }

    fn arrive_and_wait(&self, timeout: Duration) {
        let deadline = Instant::now() + timeout;
        let mut arrived = self.arrived.lock().unwrap();
        *arrived += 1;
        self.cv.notify_all();
        while *arrived < self.quorum {
            let left = deadline.saturating_duration_since(Instant::now());
            if left.is_zero() {
                return; // let the assertion below report the shortfall
            }
            let (guard, _) = self.cv.wait_timeout(arrived, left).unwrap();
            arrived = guard;
        }
    }
}

/// 9 work items on 4 workers must engage all 4. Ceil-sized chunking
/// produced ceil(9/4) = 3 chunks of 3 and silently idled a worker; the
/// stealing cursor hands the 4 blocked-at-the-gate threads one item
/// each before any of them can claim a second.
#[test]
fn nine_items_on_four_workers_engage_all_four() {
    let executor = FleetExecutor::new(4, false);
    assert_eq!(executor.planned_workers(9), 4, "participation plan regressed");
    let gate = Gate::new(4);
    let participants = Mutex::new(HashSet::new());
    executor.for_each_index(9, |_| {
        participants.lock().unwrap().insert(std::thread::current().id());
        gate.arrive_and_wait(Duration::from_secs(20));
    });
    let distinct = participants.lock().unwrap().len();
    assert_eq!(distinct, 4, "only {distinct} of 4 workers participated");
}

/// Same arithmetic straight through the fleet: on a 16-shard fleet
/// with 4 workers, shard counts that ceil-chunking mishandled (9, 13)
/// still aggregate and snapshot every stream exactly once.
#[test]
fn fleet_wide_queries_survive_awkward_shard_counts() {
    let mut fleet = AucFleet::new(FleetConfig {
        shards: 16,
        workers: 4,
        pool: false,
        pipeline: false,
        stream_defaults: StreamConfig::new(10, 0.1).without_monitor(),
        ..FleetConfig::default()
    });
    for id in 0..200u64 {
        fleet.push(id, 0.5, true);
    }
    let busy = fleet.shard_sizes().iter().filter(|&&len| len > 0).count();
    assert!(busy > 4, "200 hashed streams should spread past 4 of 16 shards");
    let agg = fleet.aggregate();
    assert_eq!(agg.streams, 200, "aggregate lost streams to dispatch arithmetic");
    assert_eq!(fleet.snapshot().streams.len(), 200);
}

// ---------------------------------------------------------------------
// Eviction edge cases (driven through parallel fleets)
// ---------------------------------------------------------------------

#[test]
fn evicting_every_stream_then_reingesting_starts_fresh() {
    let mut fleet = fleet_with(4, true, false);
    let mut rng = Pcg::seed(0xE111);
    let batches = skewed_batches(&mut rng, 12, 10);
    for batch in &batches {
        fleet.push_batch(batch);
    }
    let live = fleet.stream_count();
    assert!(live > 0);
    let events_before = fleet
        .snapshot()
        .streams
        .iter()
        .map(|s| (s.stream, s.events))
        .collect::<Vec<_>>();
    assert!(events_before.iter().all(|&(_, e)| e > 0));
    // `max_idle_events = 0` evicts everything, even just-touched streams.
    assert_eq!(fleet.evict_idle(0), live);
    assert_eq!(fleet.stream_count(), 0);
    assert!(fleet.snapshot().streams.is_empty());
    assert_eq!(fleet.snapshot_iter().count(), 0);
    // Re-ingesting an evicted id builds *fresh* state: the lifetime
    // event counter restarts instead of resuming the stale slab entry.
    fleet.push_batch(&[(0, 0.4, true), (0, 0.6, false)]);
    assert_eq!(fleet.stream_count(), 1);
    let snap = fleet.snapshot();
    assert_eq!(snap.streams[0].events, 2, "evicted stream resumed stale state");
    assert_eq!(fleet.stream_len(0), Some(2));
}

#[test]
fn overrides_survive_slab_compaction_and_eviction() {
    let mut fleet = fleet_with(2, true, false);
    // Tight override on stream 40; neighbours share its shard slab.
    fleet.configure_stream(40, StreamConfig::new(5, 0.0).without_monitor());
    let mut batch = Vec::new();
    for round in 0..30 {
        for id in 0..60u64 {
            batch.push((id, 0.1 * f64::from(round % 10), round % 2 == 0));
        }
    }
    fleet.push_batch(&batch);
    assert_eq!(fleet.stream_len(40), Some(5), "override window ignored");
    // Keep a few streams warm, idle the rest, then compact the slabs.
    let mut warm = Vec::new();
    for round in 0..40 {
        for id in [40u64, 41, 42] {
            warm.push((id, 0.1 * f64::from(round % 10), round % 2 == 1));
        }
    }
    fleet.push_batch(&warm);
    let survivor_windows: Vec<_> = [40u64, 41, 42]
        .iter()
        .map(|&id| fleet.entries(id).unwrap())
        .collect();
    let evicted = fleet.evict_idle(100);
    assert_eq!(evicted, 57, "expected the idle 57 of 60 streams to drop");
    // Survivors rode out the swap-remove compaction untouched, override
    // window included.
    for (i, &id) in [40u64, 41, 42].iter().enumerate() {
        assert_eq!(fleet.entries(id).unwrap(), survivor_windows[i], "stream {id} disturbed");
    }
    assert_eq!(fleet.stream_len(40), Some(5));
    // Evict the override stream itself; on return it must be recreated
    // under its override, not the defaults.
    assert_eq!(fleet.evict_idle(0), 3);
    for i in 0..20 {
        fleet.push(40, 0.05 * f64::from(i), i % 2 == 0);
    }
    assert_eq!(fleet.stream_len(40), Some(5), "override lost across eviction");
    assert_eq!(fleet.stream_config(40).window, 5);
}

/// Eviction immediately followed by a parallel batch: the compacted
/// slabs and the repaired id index must route the very next batch
/// correctly — revived streams fresh, survivors appended. Checked
/// bit-identically against a serial twin running the same ops.
#[test]
fn eviction_immediately_followed_by_parallel_batch_is_consistent() {
    let mut rng = Pcg::seed(0xE51C7);
    let warm: Vec<Event> = (0..2_000u64)
        .map(|i| {
            let pos = rng.chance(0.5);
            let s = if pos { rng.normal_with(0.35, 0.1) } else { rng.normal_with(0.65, 0.1) };
            (i % 40, s, pos)
        })
        .collect();
    // Second wave: revived ids, survivors, plus never-seen ids.
    let wave: Vec<Event> = (0..2_000u64)
        .map(|i| {
            let pos = rng.chance(0.5);
            let s = if pos { rng.normal_with(0.35, 0.1) } else { rng.normal_with(0.65, 0.1) };
            (i % 60, s, pos)
        })
        .collect();
    let tail: Vec<Event> = (20..40u64).map(|id| (id, 0.5, true)).collect();

    let mut serial = fleet_with(1, false, false);
    let mut pooled = fleet_with(8, true, true);
    let mut evicted_counts = Vec::new();
    for fleet in [&mut serial, &mut pooled] {
        fleet.push_batch(&warm);
        fleet.push_batch(&tail); // streams 20..40 stay warm
        evicted_counts.push(fleet.evict_idle(30));
        fleet.push_batch(&wave); // straight back into a parallel drain
    }
    assert!(evicted_counts[0] > 0, "warm-up should leave idle streams to evict");
    assert_eq!(evicted_counts[0], evicted_counts[1], "eviction diverged");
    assert_eq!(serial.snapshot(), pooled.snapshot());
    assert_eq!(serial.aggregate(), pooled.aggregate());
    assert_eq!(serial.alarms(), pooled.alarms());
}

// ---------------------------------------------------------------------
// snapshot_iter ≡ snapshot, aggregate boundary cases
// ---------------------------------------------------------------------

#[test]
fn snapshot_iter_matches_snapshot_under_all_worker_counts() {
    let mut rng = Pcg::seed(0x517E);
    let batches = skewed_batches(&mut rng, 32, 20);
    let mut reference: Option<Vec<StreamSnapshot>> = None;
    for workers in [1usize, 2, 4, 8, 16] {
        let mut fleet = fleet_with(workers, true, workers % 4 == 0);
        for batch in &batches {
            fleet.push_batch(batch);
        }
        let snap = fleet.snapshot();
        let mut streamed: Vec<StreamSnapshot> = fleet.snapshot_iter().collect();
        assert_eq!(streamed.len(), snap.streams.len());
        streamed.sort_by_key(|s| s.stream);
        assert_eq!(streamed, snap.streams, "snapshot_iter ≠ snapshot at {workers} workers");
        match &reference {
            None => reference = Some(snap.streams),
            Some(r) => assert_eq!(r, &snap.streams, "snapshot diverged at {workers} workers"),
        }
    }
}

#[test]
fn aggregate_nearest_rank_boundaries_on_tiny_fleets() {
    // 0 streams: every distribution field falls back to the 0.5
    // convention, under a parallel executor.
    let empty = fleet_with(4, true, false);
    let agg = empty.aggregate();
    assert_eq!(agg.streams, 0);
    assert_eq!(agg.live_streams, 0);
    assert_eq!((agg.min_auc, agg.median_auc, agg.max_auc), (0.5, 0.5, 0.5));
    assert_eq!((agg.p10_auc, agg.p90_auc, agg.mean_auc), (0.5, 0.5, 0.5));

    // 1 stream: every quantile is that stream's AUC (rank 0 throughout).
    let mut one = AucFleet::new(FleetConfig {
        shards: 8,
        workers: 4,
        pool: true,
        pipeline: false,
        stream_defaults: StreamConfig::new(10, 0.0).without_monitor(),
        ..FleetConfig::default()
    });
    for _ in 0..5 {
        one.push(7, 0.2, true);
        one.push(7, 0.8, false);
    }
    let agg = one.aggregate();
    assert_eq!(agg.live_streams, 1);
    assert_eq!((agg.min_auc, agg.p10_auc, agg.median_auc), (1.0, 1.0, 1.0));
    assert_eq!((agg.p90_auc, agg.max_auc, agg.mean_auc), (1.0, 1.0, 1.0));

    // 2 streams (AUC 0 and 1): nearest-rank rounds index 0.5 → 1 and
    // 0.1 → 0, so the median lands on the *upper* of the two while p10
    // stays on the lower — the documented boundary convention.
    let mut two = AucFleet::new(FleetConfig {
        shards: 8,
        workers: 4,
        pool: true,
        pipeline: false,
        stream_defaults: StreamConfig::new(10, 0.0).without_monitor(),
        ..FleetConfig::default()
    });
    for _ in 0..5 {
        two.push(1, 0.2, true);
        two.push(1, 0.8, false); // stream 1: AUC 1.0
        two.push(2, 0.8, true);
        two.push(2, 0.2, false); // stream 2: AUC 0.0
    }
    let agg = two.aggregate();
    assert_eq!(agg.live_streams, 2);
    assert_eq!(agg.min_auc, 0.0);
    assert_eq!(agg.p10_auc, 0.0, "p10 of 2 streams is the lower rank");
    assert_eq!(agg.median_auc, 1.0, "median of 2 streams rounds to the upper rank");
    assert_eq!(agg.p90_auc, 1.0);
    assert_eq!(agg.max_auc, 1.0);
    assert_eq!(agg.mean_auc, 0.5);
}

// ---------------------------------------------------------------------
// Panic safety
// ---------------------------------------------------------------------

/// A stream whose score panics the window's comparator boundary
/// (non-finite) mid-batch must not poison the pool: the panic surfaces
/// as a clean error on the ingesting call, and the *same* fleet — same
/// parked workers — keeps ingesting afterwards. The NaN check runs
/// before any state mutation, so even the offending stream stays
/// usable.
#[test]
fn panicking_stream_does_not_poison_the_pool() {
    let modes = [(4, true, false), (4, true, true), (4, false, false), (1, false, false)];
    for (workers, pool, pipeline) in modes {
        let mut fleet = AucFleet::new(FleetConfig {
            shards: 8,
            workers,
            pool,
            pipeline,
            stream_defaults: StreamConfig::new(50, 0.1).without_monitor(),
            ..FleetConfig::default()
        });
        let healthy: Vec<Event> =
            (0..400u64).map(|i| (i % 20, 0.3 + 0.001 * (i % 7) as f64, i % 2 == 0)).collect();
        fleet.push_batch(&healthy);
        let before = fleet.stream_count();

        // NaN hides mid-batch in one stream's run of events.
        let mut poisoned = healthy.clone();
        poisoned[137] = (5, f64::NAN, true);
        let err = catch_unwind(AssertUnwindSafe(|| {
            fleet.push_batch(&poisoned);
            // A pipelined fleet defers the drain; force the sync so the
            // panic surfaces inside this catch.
            let _ = fleet.stream_count();
        }));
        assert!(err.is_err(), "non-finite score must raise (workers {workers})");

        // The pool is alive and parked — not deadlocked, not poisoned:
        // the same fleet ingests 20 more batches and answers queries.
        for _ in 0..20 {
            fleet.push_batch(&healthy);
        }
        assert_eq!(fleet.stream_count(), before);
        assert!(fleet.auc(5).is_some(), "offending stream still queryable");
        let snap = fleet.snapshot();
        assert!(snap.streams.iter().all(|s| s.auc.is_finite()), "NaN leaked into state");
        let _ = fleet.aggregate();
        // The offending stream accepts clean traffic again.
        fleet.push(5, 0.5, true);
        assert!(fleet.stream_len(5).unwrap() > 0);
    }
}

/// Dropping a fleet with a batch still in flight (pipelined) must not
/// hang: the drop waits the drain out and joins the parked workers.
#[test]
fn dropping_a_pipelined_fleet_mid_flight_joins_cleanly() {
    let mut rng = Pcg::seed(0xD20F);
    let batches = skewed_batches(&mut rng, 16, 8);
    let mut fleet = fleet_with(8, true, true);
    for batch in &batches {
        fleet.push_batch(batch);
    }
    drop(fleet); // last batch may still be draining right here
}

/// Dropping a *query* mid-stream on a pipelined fleet — an abandoned
/// `snapshot_iter` — then dropping the fleet with the next batch still
/// in flight must be panic-free: readers synchronize, iterators hold
/// no locks past their shard, and drop never re-raises.
#[test]
fn drop_mid_flight_query_is_panic_free() {
    let mut rng = Pcg::seed(0xD21A);
    let batches = skewed_batches(&mut rng, 24, 10);
    let mut fleet = fleet_with(8, true, true);
    for batch in &batches[..5] {
        fleet.push_batch(batch);
    }
    {
        let mut iter = fleet.snapshot_iter();
        let _first = iter.next();
        // Abandon the iterator mid-shard.
    }
    fleet.push_batch(&batches[5]); // pipelined: returns at submission
    let _ = fleet.top_k_worst(3); // query syncs with the in-flight drain
    fleet.push_batch(&batches[6]);
    drop(fleet); // batch 6 may still be draining right here
}

/// Explicit `sync()`: after it returns, a pipelined fleet's in-flight
/// work is published — `alarms()` order, recycled buckets, participant
/// counts — without needing to issue a read.
#[test]
fn explicit_sync_publishes_the_in_flight_batch() {
    let mut rng = Pcg::seed(0x51CC);
    let batches = skewed_batches(&mut rng, 20, 30);
    let mut piped = fleet_with(4, true, true);
    let mut serial = fleet_with(1, false, false);
    for batch in &batches {
        piped.push_batch(batch);
        serial.push_batch(batch);
    }
    piped.sync(); // waits the last drain out
    assert!(piped.last_batch_workers() >= 1);
    assert_eq!(serial.alarms(), piped.alarms());
    assert_eq!(serial.snapshot(), piped.snapshot());
    // sync() on a quiescent (or serial) fleet is a no-op.
    piped.sync();
    serial.sync();
    assert_eq!(serial.total_events(), piped.total_events());
}

/// Queries issued from a `Drop` while the thread is already unwinding
/// — with a *poisoned* batch still in flight — must not double-panic
/// (which would abort the process instead of failing the test). The
/// regression: `wait_inflight` used to re-raise the worker panic
/// unconditionally; a fleet owner running diagnostics in its `Drop`
/// during a panic would abort.
#[test]
fn queries_during_unwind_do_not_double_panic() {
    struct QueryOnDrop {
        fleet: AucFleet,
    }
    impl Drop for QueryOnDrop {
        fn drop(&mut self) {
            // Diagnostics a service would plausibly log on the way
            // down; each one syncs with the poisoned in-flight batch.
            let agg = self.fleet.aggregate();
            let _ = self.fleet.top_k_worst(3);
            let _ = self.fleet.snapshot();
            assert!(agg.streams > 0, "pre-poison streams must still be visible");
        }
    }

    let healthy: Vec<Event> =
        (0..600u64).map(|i| (i % 24, 0.3 + 0.001 * (i % 7) as f64, i % 2 == 0)).collect();
    let result = catch_unwind(AssertUnwindSafe(|| {
        let mut guard = QueryOnDrop { fleet: fleet_with(4, true, true) };
        guard.fleet.push_batch(&healthy);
        guard.fleet.sync();
        let mut poisoned = healthy.clone();
        poisoned[137] = (5, f64::NAN, true); // panics inside a worker
        guard.fleet.push_batch(&poisoned); // pipelined: returns at submission
        panic!("caller panics while the poisoned batch is in flight");
    }));
    assert!(result.is_err(), "the caller panic itself must surface");
}

/// Acceptance check for the typed-job engine: with `pool = true` the
/// query jobs run on the persistent pool's threads, not inline on the
/// caller; with a serial executor they run inline. Observed through a
/// `select_streams` predicate, which executes inside the per-shard
/// visit.
#[test]
fn query_jobs_run_on_pool_threads_when_pooled() {
    use std::collections::HashSet as Set;
    use std::sync::{Arc, Mutex as StdMutex};
    use std::thread::ThreadId;

    let spread: Vec<Event> = (0..400u64).map(|id| (id, 0.5, true)).collect();
    let main = std::thread::current().id();

    let mut pooled = fleet_with(4, true, false);
    pooled.push_batch(&spread);
    let seen: Arc<StdMutex<Set<ThreadId>>> = Arc::new(StdMutex::new(Set::new()));
    let probe = Arc::clone(&seen);
    let hits = pooled.select_streams(move |_| {
        probe.lock().unwrap().insert(std::thread::current().id());
        true
    });
    assert_eq!(hits.len(), 400);
    let seen = seen.lock().unwrap();
    assert!(!seen.is_empty());
    assert!(
        !seen.contains(&main),
        "pooled query visits must run on pool threads, not the caller"
    );

    let mut serial = fleet_with(1, true, false);
    serial.push_batch(&spread);
    let seen: Arc<StdMutex<Set<ThreadId>>> = Arc::new(StdMutex::new(Set::new()));
    let probe = Arc::clone(&seen);
    serial.select_streams(move |_| {
        probe.lock().unwrap().insert(std::thread::current().id());
        true
    });
    assert_eq!(
        *seen.lock().unwrap(),
        Set::from([main]),
        "serial query visits must run inline on the caller"
    );
}

// ---------------------------------------------------------------------
// Timestamp threading + adaptive scaling (through the executor)
// ---------------------------------------------------------------------

/// `evict_older_than` across strategies: timestamps ride the batch, so
/// age eviction is as strategy-independent as tick eviction — checked
/// against a serial twin running the identical timed schedule.
#[test]
fn age_eviction_is_bit_identical_across_strategies() {
    let mut rng = Pcg::seed(0xA6E0);
    let batches = skewed_batches(&mut rng, 32, 40);
    let mut serial = fleet_with(1, false, false);
    let mut pooled = fleet_with_adaptive(8, true, true, true);
    let mut ages = Vec::new();
    for fleet in [&mut serial, &mut pooled] {
        let mut evicted = Vec::new();
        for (i, batch) in batches.iter().enumerate() {
            fleet.push_batch_at(batch, (i as u64 + 1) * 100);
            // Steps 17 and 30 land inside the trace's silent stretches
            // ([13, 20) and [26, 33) of 40 batches), where the cold
            // tail is ≥ 4 batches = 400 clock units stale — so victims
            // are guaranteed, deterministically.
            if i % 13 == 4 && i > 4 {
                evicted.push(fleet.evict_older_than(250));
            }
        }
        ages.push(evicted);
    }
    assert_eq!(ages[0], ages[1], "age eviction counts diverged");
    assert!(ages[0].iter().any(|&e| e > 0), "scenario must age-evict something");
    assert_eq!(serial.snapshot(), pooled.snapshot());
    assert_eq!(serial.clock(), pooled.clock());
    assert_eq!(serial.alarms(), pooled.alarms());
}

/// `hibernate_idle` across strategies — and against a twin that never
/// hibernates at all. Freeze sweeps (cold-only and freeze-everything)
/// interleave with skewed batches that transparently rehydrate
/// whatever they touch; the serial and pooled/pipelined/adaptive
/// hibernating fleets must freeze identical counts and answer
/// identical sketch-vs-rescan aggregates, and once a final batch thaws
/// every survivor, all three fleets — including the never-hibernated
/// twin — must be indistinguishable snapshot-for-snapshot (footprints
/// included: live footprint is content-determined, so a rehydrated
/// stream weighs exactly what its never-frozen twin does).
#[test]
fn hibernation_is_bit_identical_across_strategies() {
    let mut rng = Pcg::seed(0xF0_C01D);
    let n_streams = 32u64;
    let batches = skewed_batches(&mut rng, n_streams, 40);
    let mut serial = fleet_with(1, false, false);
    let mut pooled = fleet_with_adaptive(8, true, true, true);
    let mut never = fleet_with(4, true, false);
    let mut frozen_counts = Vec::new();
    for (which, fleet) in [&mut serial, &mut pooled, &mut never].into_iter().enumerate() {
        let hibernating = which < 2;
        let mut frozen = Vec::new();
        for (i, batch) in batches.iter().enumerate() {
            fleet.push_batch_at(batch, (i as u64 + 1) * 100);
            if hibernating && i % 7 == 3 {
                // Alternate a freeze-everything sweep (threshold 0)
                // with a cold-only sweep; the silent stretches of the
                // skewed trace guarantee the latter finds victims too.
                frozen.push(fleet.hibernate_idle(if i % 14 == 3 { 0 } else { 400 }));
                assert_eq!(
                    fleet.aggregate(),
                    fleet.aggregate_rescan(),
                    "sketch aggregate drifted over frozen streams at batch {i}"
                );
            }
        }
        if hibernating {
            frozen_counts.push(frozen);
        }
    }
    assert_eq!(frozen_counts[0], frozen_counts[1], "hibernation counts diverged");
    assert!(frozen_counts[0].iter().any(|&h| h > 0), "scenario must hibernate something");
    // Thaw every survivor with one event per stream, identically on
    // all three fleets, then compare them whole.
    let tail: Vec<Event> = (0..n_streams).map(|id| (id, 0.5, id % 2 == 0)).collect();
    for fleet in [&mut serial, &mut pooled, &mut never] {
        fleet.push_batch_at(&tail, 41 * 100);
        assert_eq!(fleet.hibernated_count(), 0, "tail batch must rehydrate every stream");
        fleet.verify_sketches();
    }
    let reference = never.snapshot();
    assert_eq!(serial.snapshot(), reference, "serial hibernating fleet diverged");
    assert_eq!(pooled.snapshot(), reference, "pooled hibernating fleet diverged");
    assert_eq!(serial.alarms(), never.alarms());
    assert_eq!(pooled.alarms(), never.alarms());
    assert_eq!(serial.footprint_bytes(), never.footprint_bytes());
}
