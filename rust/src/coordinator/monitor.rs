//! AUC drift monitor — the paper's motivating application (§1).
//!
//! “It is vital to monitor such a system continuously to notice
//! breakdowns early. Possible causes may be changes in the underlying
//! distribution or a system failure.” The monitor watches the windowed
//! AUC estimate, smooths it with an EWMA baseline, and raises an alarm
//! when the estimate degrades below the baseline by a configurable
//! margin for a sustained number of updates (debouncing transient dips).
//!
//! Because the estimate carries the `ε/2` relative guarantee, a margin
//! `δ` on the estimate corresponds to a true degradation of at least
//! `δ − ε/2` — the monitor's sensitivity floor is explicit.
//!
//! Cost note: the monitor consumes one AUC reading per update. Since
//! the estimator maintains its estimate incrementally (`DESIGN.md`
//! §Incremental-reads), that reading is `O(1)` — monitoring no longer
//! adds an `O(|C|)` scan to every ingested event, so fleets enable it
//! by default without a throughput cliff (`benches/fleet.rs`
//! monitored-ingestion rows).

/// Monitor outcome for one observation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MonitorEvent {
    /// Baseline still warming up (fewer than `warmup` observations).
    Warmup,
    /// AUC within margin of the baseline.
    Ok,
    /// Below margin, but not yet for `patience` consecutive updates.
    Degrading,
    /// Alarm: sustained degradation. Fires once per excursion.
    Alarm,
}

/// EWMA-based drift monitor over an AUC series.
#[derive(Clone, Debug)]
pub struct AucMonitor {
    /// EWMA decay factor for the baseline (weight of the new sample).
    lambda: f64,
    /// Absolute AUC margin below baseline that counts as degradation.
    margin: f64,
    /// Consecutive degraded updates before the alarm fires.
    patience: u32,
    /// Observations before the baseline is trusted.
    warmup: u32,
    baseline: f64,
    seen: u32,
    below: u32,
    alarmed: bool,
}

impl AucMonitor {
    /// New monitor.
    ///
    /// Choosing `lambda`: a sliding window of length `k` turns an abrupt
    /// drift into a ramp of ≈ `Δ/k` per update. The EWMA tracks a ramp
    /// with steady-state lag `(Δ/k)/lambda`; degradation is only
    /// detected when that lag exceeds `margin`, so pick
    /// `lambda < Δ_min / (k · margin)` — i.e. a baseline time-constant
    /// much longer than the window. For `k = 500`, `margin = 0.08` and a
    /// minimum interesting drop of `0.2`, `lambda ≲ 0.005`; the tests
    /// use `0.001`.
    pub fn new(lambda: f64, margin: f64, patience: u32, warmup: u32) -> Self {
        assert!(lambda > 0.0 && lambda <= 1.0, "lambda in (0, 1]");
        assert!(margin >= 0.0, "margin must be non-negative");
        AucMonitor {
            lambda,
            margin,
            patience,
            warmup,
            baseline: 0.0,
            seen: 0,
            below: 0,
            alarmed: false,
        }
    }

    /// Current EWMA baseline.
    pub fn baseline(&self) -> f64 {
        self.baseline
    }

    /// Feed one AUC observation; returns the monitor state transition.
    pub fn observe(&mut self, auc: f64) -> MonitorEvent {
        self.seen += 1;
        if self.seen == 1 {
            self.baseline = auc;
            return MonitorEvent::Warmup;
        }
        let degraded = auc < self.baseline - self.margin;
        if self.seen <= self.warmup {
            // Same freeze as the post-warmup branch: a stream already
            // degrading during warmup must not drag the baseline down
            // with it, or the broken level becomes the reference and
            // the alarm can never fire.
            if !degraded {
                self.baseline += self.lambda * (auc - self.baseline);
            }
            return MonitorEvent::Warmup;
        }
        if degraded {
            // Freeze the baseline while degraded so the alarm threshold
            // does not chase the failure downward.
            self.below += 1;
            if self.below >= self.patience {
                if !self.alarmed {
                    self.alarmed = true;
                    return MonitorEvent::Alarm;
                }
                return MonitorEvent::Degrading;
            }
            MonitorEvent::Degrading
        } else {
            self.baseline += self.lambda * (auc - self.baseline);
            self.below = 0;
            self.alarmed = false;
            MonitorEvent::Ok
        }
    }

    /// True while inside an alarmed excursion.
    pub fn is_alarmed(&self) -> bool {
        self.alarmed
    }
}

// Monitors ride along with their stream state onto the fleet's scoped
// worker threads; plain-data state keeps that provable.
const _: () = {
    const fn assert_send<T: Send>() {}
    assert_send::<AucMonitor>();
};

#[cfg(test)]
mod tests {
    use super::*;

    fn feed(m: &mut AucMonitor, auc: f64, n: u32) -> Vec<MonitorEvent> {
        (0..n).map(|_| m.observe(auc)).collect()
    }

    #[test]
    fn stable_stream_never_alarms() {
        let mut m = AucMonitor::new(0.05, 0.05, 10, 20);
        let events = feed(&mut m, 0.9, 200);
        assert!(!events.contains(&MonitorEvent::Alarm));
        assert!((m.baseline() - 0.9).abs() < 1e-9);
    }

    #[test]
    fn abrupt_drop_alarms_after_patience() {
        let mut m = AucMonitor::new(0.05, 0.05, 10, 20);
        feed(&mut m, 0.9, 100);
        let events = feed(&mut m, 0.6, 30);
        let alarm_at = events.iter().position(|e| *e == MonitorEvent::Alarm);
        assert_eq!(alarm_at, Some(9), "alarm after exactly `patience` updates");
        assert!(m.is_alarmed());
        // Alarm fires once, then stays in Degrading.
        assert_eq!(events.iter().filter(|e| **e == MonitorEvent::Alarm).count(), 1);
    }

    #[test]
    fn transient_dip_is_debounced() {
        let mut m = AucMonitor::new(0.05, 0.05, 10, 20);
        feed(&mut m, 0.9, 100);
        let events = feed(&mut m, 0.6, 5); // shorter than patience
        assert!(events.iter().all(|e| *e == MonitorEvent::Degrading));
        let events = feed(&mut m, 0.9, 20);
        assert!(events.iter().all(|e| *e == MonitorEvent::Ok));
        assert!(!m.is_alarmed());
    }

    #[test]
    fn recovery_rearms_the_monitor() {
        let mut m = AucMonitor::new(0.05, 0.05, 5, 10);
        feed(&mut m, 0.9, 50);
        let first = feed(&mut m, 0.5, 10);
        assert!(first.contains(&MonitorEvent::Alarm));
        feed(&mut m, 0.9, 50); // recover
        assert!(!m.is_alarmed());
        let second = feed(&mut m, 0.5, 10);
        assert!(second.contains(&MonitorEvent::Alarm), "second excursion re-alarms");
    }

    #[test]
    fn baseline_frozen_while_degraded() {
        let mut m = AucMonitor::new(0.5, 0.05, 1000, 5);
        feed(&mut m, 0.9, 50);
        let before = m.baseline();
        feed(&mut m, 0.4, 100); // long degradation, patience never reached
        assert_eq!(m.baseline(), before, "baseline must not chase a failure");
    }

    #[test]
    fn degradation_during_warmup_still_alarms() {
        // Regression: a stream that breaks *during* warmup used to pull
        // the EWMA baseline down to the broken level, so the alarm
        // never fired. The baseline must freeze against degraded
        // readings in warmup exactly as it does after it.
        // Without the freeze, 90 broken readings at λ = 0.05 settle the
        // baseline at ≈ 0.504 — within margin of the broken level, so
        // the post-warmup stream would read as healthy forever.
        let mut m = AucMonitor::new(0.05, 0.05, 10, 100);
        feed(&mut m, 0.9, 10); // healthy start, then broken mid-warmup
        let warm = feed(&mut m, 0.5, 90);
        assert!(warm.iter().all(|e| *e == MonitorEvent::Warmup));
        assert!(
            m.baseline() > 0.85,
            "baseline chased the failure during warmup: {}",
            m.baseline()
        );
        let events = feed(&mut m, 0.5, 15);
        assert_eq!(
            events.iter().position(|e| *e == MonitorEvent::Alarm),
            Some(9),
            "born-broken stream must alarm right after warmup + patience"
        );
    }

    #[test]
    fn warmup_counts() {
        let mut m = AucMonitor::new(0.1, 0.05, 5, 10);
        let events = feed(&mut m, 0.8, 10);
        assert!(events.iter().all(|e| *e == MonitorEvent::Warmup));
        let ev = m.observe(0.8);
        assert_eq!(ev, MonitorEvent::Ok);
    }
}
