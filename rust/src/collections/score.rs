//! Totally ordered classifier scores with `±∞` sentinels.
//!
//! The paper (§3.1) adds two sentinel nodes with scores `−∞` and `+∞` to
//! the search tree and assumes real entries never take these values. We
//! encode scores as `f64` and order them with IEEE-754 `total_cmp`, which
//! gives a total order (NaN included, though the public API rejects NaN at
//! the window boundary).

use std::cmp::Ordering;

/// A classifier score: an `f64` with a total order.
///
/// Wraps the raw score so the tree code can use `Ord` directly. `−∞` and
/// `+∞` are reserved for the sentinel nodes of paper §3.1.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Score(pub f64);

impl Score {
    /// Sentinel score of the first node (`−∞`, paper §3.1).
    pub const NEG_SENTINEL: Score = Score(f64::NEG_INFINITY);
    /// Sentinel score of the last node (`+∞`, paper §3.1).
    pub const POS_SENTINEL: Score = Score(f64::INFINITY);

    /// True if this is one of the two reserved sentinel scores.
    #[inline]
    pub fn is_sentinel(self) -> bool {
        self.0.is_infinite()
    }

    /// True for scores a data point is allowed to carry (finite, not NaN).
    #[inline]
    pub fn is_valid_entry(self) -> bool {
        self.0.is_finite()
    }
}

impl Eq for Score {}

impl PartialOrd for Score {
    #[inline]
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Score {
    #[inline]
    fn cmp(&self, other: &Self) -> Ordering {
        self.0.total_cmp(&other.0)
    }
}

impl From<f64> for Score {
    #[inline]
    fn from(v: f64) -> Self {
        Score(v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sentinels_bound_everything() {
        for v in [-1e300, -1.0, 0.0, 1.0, 1e300] {
            assert!(Score::NEG_SENTINEL < Score(v));
            assert!(Score(v) < Score::POS_SENTINEL);
        }
        assert!(Score::NEG_SENTINEL < Score::POS_SENTINEL);
    }

    #[test]
    fn total_order_on_negative_zero() {
        // total_cmp orders -0.0 < 0.0; duplicates of the same bit pattern
        // are equal. The window treats them as distinct scores, which is
        // harmless for AUC (adjacent distinct nodes).
        assert!(Score(-0.0) < Score(0.0));
        assert_eq!(Score(1.5), Score(1.5));
    }

    #[test]
    fn sentinel_classification() {
        assert!(Score::NEG_SENTINEL.is_sentinel());
        assert!(Score::POS_SENTINEL.is_sentinel());
        assert!(!Score(0.0).is_sentinel());
        assert!(Score(0.0).is_valid_entry());
        assert!(!Score(f64::NAN).is_valid_entry());
        assert!(!Score::POS_SENTINEL.is_valid_entry());
    }
}
