//! Exact sliding-window AUC — the §5 baseline.
//!
//! Brzezinski & Stefanowski maintain the window in a red-black tree and
//! recompute AUC from scratch on every update, giving `O(log k)` updates
//! and `O(k)` queries. This estimator reproduces that baseline with the
//! same augmented tree as the approximate estimator (minus `TP`/`P`/`C`,
//! which the baseline does not need), so the Figure 3 speed-up comparison
//! measures the algorithmic difference, not incidental constant factors.

use super::support::{Acc, Counts};
use super::{auc_terms_doubled, finish_auc, AucEstimator};
use crate::collections::{RbTree, Score};

/// Exact estimator: `O(log k)` update, `O(k)` AUC query.
#[derive(Clone, Debug, Default)]
pub struct ExactAuc {
    t: RbTree<Counts, Acc>,
    total_pos: u64,
    total_neg: u64,
}

impl ExactAuc {
    /// Empty estimator.
    pub fn new() -> Self {
        Self::default()
    }

    /// Number of distinct scores currently held.
    pub fn distinct_scores(&self) -> usize {
        self.t.len()
    }

    fn update(&mut self, score: f64, pos: bool, delta: i64) {
        let s = Score(super::canon(score));
        assert!(s.is_valid_entry(), "scores must be finite");
        if delta > 0 {
            let init = if pos { Counts { p: 1, n: 0 } } else { Counts { p: 0, n: 1 } };
            let (v, fresh) = self.t.insert(s, || init);
            if !fresh {
                self.t.with_val_mut(v, |c| if pos { c.p += 1 } else { c.n += 1 });
            }
        } else {
            let v = self.t.find(s).expect("exact remove: score not present");
            let c = *self.t.val(v);
            if pos {
                assert!(c.p > 0, "exact remove: no positive at this score");
            } else {
                assert!(c.n > 0, "exact remove: no negative at this score");
            }
            self.t.with_val_mut(v, |c| if pos { c.p -= 1 } else { c.n -= 1 });
            let c = *self.t.val(v);
            if c.p == 0 && c.n == 0 {
                self.t.remove(v);
            }
        }
        let d = delta as i128;
        if pos {
            self.total_pos = (self.total_pos as i128 + d) as u64;
        } else {
            self.total_neg = (self.total_neg as i128 + d) as u64;
        }
    }
}

impl AucEstimator for ExactAuc {
    fn insert(&mut self, score: f64, pos: bool) {
        self.update(score, pos, 1);
    }

    fn remove(&mut self, score: f64, pos: bool) {
        self.update(score, pos, -1);
    }

    /// Full Eq. 1 enumeration over the tree: `O(k)`.
    fn auc(&self) -> f64 {
        let groups = self.t.iter().map(|id| {
            let c = self.t.val(id);
            (c.p, c.n)
        });
        let (a2, pos, neg) = auc_terms_doubled(groups);
        debug_assert_eq!(pos, self.total_pos);
        debug_assert_eq!(neg, self.total_neg);
        finish_auc(a2, pos, neg)
    }

    fn len(&self) -> usize {
        (self.total_pos + self.total_neg) as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::NaiveAuc;
    use crate::testing::{check, gen_ops, Op};

    #[test]
    fn agrees_with_naive_on_random_streams() {
        for grid in [Some(4), Some(32), None] {
            check(0xE4AC ^ grid.unwrap_or(7), 20, |rng| {
                let mut exact = ExactAuc::new();
                let mut naive = NaiveAuc::new();
                for op in gen_ops(rng, 300, 60, grid) {
                    match op {
                        Op::Insert { score, pos } => {
                            exact.insert(score, pos);
                            naive.insert(score, pos);
                        }
                        Op::Remove { score, pos } => {
                            exact.remove(score, pos);
                            naive.remove(score, pos);
                        }
                    }
                    assert_eq!(exact.len(), naive.len());
                    let (a, b) = (exact.auc(), naive.auc());
                    assert!((a - b).abs() < 1e-12, "exact {a} vs naive {b}");
                }
            });
        }
    }

    #[test]
    fn node_lifecycle() {
        let mut e = ExactAuc::new();
        e.insert(1.0, true);
        e.insert(1.0, false);
        assert_eq!(e.distinct_scores(), 1);
        e.remove(1.0, true);
        assert_eq!(e.distinct_scores(), 1);
        e.remove(1.0, false);
        assert_eq!(e.distinct_scores(), 0);
        assert!(e.is_empty());
        assert_eq!(e.auc(), 0.5);
    }

    #[test]
    #[should_panic(expected = "not present")]
    fn remove_unknown_score_panics() {
        let mut e = ExactAuc::new();
        e.remove(3.0, true);
    }
}
