//! Figure 1: actual relative error as a function of ε.
//!
//! Paper setup: window k = 1000, ε swept on a log grid; top row plots
//! the relative error `|ãuc − auc| / auc` *averaged* over all sliding
//! windows, bottom row the *maximum*. Proposition 1 caps both at ε/2;
//! the finding is that observed errors sit orders of magnitude below.
//!
//! One pass per (dataset, ε): the stream flows through the approximate
//! estimator while the exact value is read from the same support tree
//! (`O(k)` enumeration), so both see the identical window.

use super::report::{fmt_sci, Table};
use super::{ExpConfig, EPSILONS};
use crate::coordinator::metrics::RelErr;
use crate::coordinator::window::Window;
use crate::coordinator::ApproxAuc;
use crate::stream::synth::{paper_datasets, Dataset};

/// One sweep point.
#[derive(Clone, Debug)]
pub struct Point {
    /// Dataset name.
    pub dataset: &'static str,
    /// Approximation parameter.
    pub epsilon: f64,
    /// Average relative error over all full windows.
    pub avg_err: f64,
    /// Maximum relative error over all full windows.
    pub max_err: f64,
}

/// Run the sweep, returning raw points (used by tests and the bench).
pub fn sweep(cfg: ExpConfig, epsilons: &[f64]) -> Vec<Point> {
    let mut points = Vec::new();
    for spec in paper_datasets() {
        let name = spec.name;
        let mut data = Dataset::new(spec, cfg.seed);
        let stream = data.score_stream(cfg.events);
        for &eps in epsilons {
            let mut win = Window::with_estimator(cfg.window, ApproxAuc::new(eps));
            let mut err = RelErr::new();
            for &(s, l) in &stream {
                win.push(s, l);
                if win.is_full() {
                    err.record(win.auc(), win.estimator().exact_auc());
                }
            }
            points.push(Point { dataset: name, epsilon: eps, avg_err: err.avg(), max_err: err.max() });
        }
    }
    points
}

/// Build the Figure 1 table (both rows of the figure: avg + max).
pub fn run(cfg: ExpConfig) -> Table {
    let mut table = Table::new(
        format!(
            "fig1: relative error vs ε (k={}, {} events/dataset; guarantee ε/2)",
            cfg.window, cfg.events
        ),
        &["dataset", "epsilon", "avg_rel_err", "max_rel_err", "guarantee", "max/guarantee"],
    );
    for p in sweep(cfg, &EPSILONS) {
        let g = p.epsilon / 2.0;
        table.push(vec![
            p.dataset.to_string(),
            fmt_sci(p.epsilon),
            fmt_sci(p.avg_err),
            fmt_sci(p.max_err),
            fmt_sci(g),
            fmt_sci(p.max_err / g),
        ]);
    }
    table
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn errors_bounded_and_grow_with_epsilon() {
        let cfg = ExpConfig { events: 4000, window: 300, seed: 7 };
        let points = sweep(cfg, &[1e-3, 1e-1]);
        assert_eq!(points.len(), 6); // 3 datasets × 2 ε
        for p in &points {
            assert!(
                p.max_err <= p.epsilon / 2.0,
                "{} ε={}: max {} over guarantee",
                p.dataset,
                p.epsilon,
                p.max_err
            );
            assert!(p.avg_err <= p.max_err);
        }
        // Per dataset, the tighter ε must not err more (on average).
        for chunk in points.chunks(2) {
            assert!(
                chunk[0].avg_err <= chunk[1].avg_err + 1e-12,
                "{}: avg err not monotone in ε",
                chunk[0].dataset
            );
        }
    }

    #[test]
    fn observed_error_is_below_guarantee_with_margin() {
        // The paper's headline: average error well below ε/2. The margin
        // is dataset-dependent (a high-AUC stream like hepmass uses more
        // of the budget because the bound is relative to AUC); every
        // dataset must stay under half the guarantee, and at least one
        // far under.
        let cfg = ExpConfig { events: 4000, window: 300, seed: 9 };
        let points = sweep(cfg, &[0.1]);
        let mut best_ratio = f64::INFINITY;
        for p in &points {
            let ratio = p.avg_err / (p.epsilon / 2.0);
            assert!(ratio < 0.5, "{}: avg {} uses {ratio:.2} of guarantee", p.dataset, p.avg_err);
            best_ratio = best_ratio.min(ratio);
        }
        assert!(best_ratio < 0.15, "no dataset far below guarantee ({best_ratio:.2})");
    }
}
