//! `streamauc` — CLI for the sliding-window AUC system.
//!
//! ```text
//! streamauc experiment <table1|fig1|fig2|fig3|all> [--events N] [--window K] [--seed S] [--csv DIR]
//! streamauc stream [--dataset D] [--epsilon E] [--window K] [--events N] [--drift-at I --drift-rate R]
//! streamauc fleet  [--streams N] [--events N] [--shards S] [--workers W] [--window K]
//!                  [--estimator approx|exact|binned] [--epsilon E] [--bins N]
//!                  [--score-range LO,HI] [--batch B] [--drift-frac F]
//!                  [--skew X] [--seed S] [--evict-idle N] [--evict-age N]
//!                  [--hibernate-idle N] [--pool BOOL]
//!                  [--pipeline] [--adaptive] [--top K] [--count-below X] [--hist BINS]
//! streamauc fleet serve [--addr HOST:PORT] [--serve-workers W] [--max-conns N]
//!                  [--timeout-ms MS] [fleet flags as above]
//! streamauc train  [--dataset D] [--steps N] [--lr X] [--events N] [--artifacts DIR] [--out FILE]
//! streamauc help
//! ```
//!
//! `experiment` regenerates the paper's tables/figures; `stream` runs
//! the monitoring pipeline on a synthetic scored stream; `fleet` runs
//! the multi-stream engine over a bursty synthetic fleet with injected
//! per-stream drift (`--workers N` runs ingestion *and* every read
//! path — aggregates, queries, snapshots, eviction — work-stealing on
//! the persistent worker pool; `--pool false` falls back to a thread
//! scope per call, `--pipeline` overlaps batch generation with the
//! previous drain, `--adaptive` scales active workers to the batch
//! size — every combination is bit-identical to serial) and then
//! answers the monitoring queries (`--top`, `--count-below`, `--hist`).
//! `fleet serve` runs the same ingest while serving every query over
//! the wire — HTTP/1.1 JSON and a binary protocol on one `--addr`
//! port, plus a `/subscribe` stream of per-drain sketch deltas
//! (`rust/DESIGN.md` §Serving) — and keeps serving after the ingest
//! completes, until interrupted. Its front-end is bounded:
//! `--serve-workers` connection workers (distinct from the ingestion
//! pool's `--workers`), a `--max-conns` accept queue that sheds
//! overload with 503/`STATUS_BUSY`, and `--timeout-ms` socket
//! timeouts doubling as the per-request deadline budget.
//! `--estimator` selects the per-stream estimator: `approx` (default)
//! runs the paper's `ε`-compressed sketch, `exact` the tree-maintained
//! exact accumulator (no `ε`; `--epsilon` is ignored), `binned` the
//! bounded-score count-array fast path (`--bins` cells over the
//! declared `--score-range LO,HI`; scores outside the range are a
//! contract violation). Numeric flags
//! are validated up front — zero `--workers`/`--hist`, a non-finite
//! `--evict-age` and similar nonsense fail with a clear message before
//! any work starts rather than panicking mid-run;
//! `train` runs the full three-layer path (PJRT-compiled JAX/Pallas
//! classifier trained and scored from rust, stream fed into the
//! estimator).

use anyhow::{bail, Context, Result};

use streamauc::cli::Args;
use streamauc::config::{Config, Settings};
use streamauc::coordinator::window::Window;
use streamauc::coordinator::{ApproxAuc, AucMonitor, MonitorEvent, NaiveAuc};
use streamauc::experiments::{fig1, fig2, fig3, table1, ExpConfig, Table};
use streamauc::fleet::{AucFleet, EstimatorKind, FleetConfig, StreamConfig};
use streamauc::runtime::{Runtime, Scorer, Trainer};
use streamauc::serve::{FleetServer, ServeLimits};
use streamauc::stream::source::write_csv;
use streamauc::stream::synth::{paper_datasets, Dataset, DatasetSpec};
use streamauc::stream::{Drift, DriftSchedule, MultiStream, StreamProfile};

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    match args.command.as_str() {
        "experiment" => cmd_experiment(&args),
        "stream" => cmd_stream(&args),
        "fleet" => cmd_fleet(&args),
        "train" => cmd_train(&args),
        "help" | "" => {
            print!("{}", HELP);
            Ok(())
        }
        other => bail!("unknown command {other:?}; see `streamauc help`"),
    }
}

const HELP: &str = "\
streamauc — efficient estimation of AUC in a sliding window (Tatti, 2019)

USAGE:
  streamauc experiment <table1|fig1|fig2|fig3|all> [--events N] [--window K] [--seed S] [--csv DIR]
  streamauc stream [--dataset D] [--epsilon E] [--window K] [--events N]
                   [--drift-at I --drift-rate R] [--config FILE]
  streamauc fleet  [--streams N] [--events N] [--shards S] [--workers W] [--window K]
                   [--estimator approx|exact|binned] [--epsilon E] [--bins N]
                   [--score-range LO,HI] [--batch B] [--drift-frac F]
                   [--skew X] [--seed S] [--evict-idle N] [--evict-age N]
                   [--hibernate-idle N] [--pool BOOL]
                   [--pipeline] [--adaptive] [--top K] [--count-below X] [--hist BINS]
  streamauc fleet serve [--addr HOST:PORT] [--serve-workers W] [--max-conns N]
                   [--timeout-ms MS] [fleet flags as above]
  streamauc train  [--dataset D] [--steps N] [--lr X] [--events N]
                   [--artifacts DIR] [--out stream.csv]
  streamauc help
";

fn dataset_by_name(name: &str) -> Result<DatasetSpec> {
    paper_datasets()
        .into_iter()
        .find(|s| s.name == name)
        .with_context(|| format!("unknown dataset {name:?} (hepmass|miniboone|tvads)"))
}

fn settings(args: &Args) -> Result<Settings> {
    let mut cfg = match args.get("config") {
        Some(path) => Config::load(std::path::Path::new(path))?,
        None => Config::new(),
    };
    // CLI wins over the file; strip non-settings flags first.
    let mut overlay = args.clone();
    let _ = &mut overlay; // settings-relevant flags only
    for key in ["epsilon", "window", "dataset", "events", "seed", "artifacts"] {
        if let Some(v) = args.get(key) {
            cfg.set(key, v);
        }
    }
    Settings::from_config(&cfg)
}

fn cmd_experiment(args: &Args) -> Result<()> {
    args.validate_flags(&["events", "window", "seed", "csv"])?;
    let which = args.positional.first().map(String::as_str).unwrap_or("all");
    let cfg = ExpConfig {
        events: args.get_or("events", ExpConfig::default().events)?,
        window: args.get_or("window", ExpConfig::default().window)?,
        seed: args.get_or("seed", ExpConfig::default().seed)?,
    };
    let tables: Vec<Table> = match which {
        "table1" => vec![table1::run(cfg)],
        "fig1" => vec![fig1::run(cfg)],
        "fig2" => vec![fig2::run(cfg)],
        "fig3" => vec![fig3::run(cfg)],
        "all" => vec![table1::run(cfg), fig1::run(cfg), fig2::run(cfg), fig3::run(cfg)],
        other => bail!("unknown experiment {other:?} (table1|fig1|fig2|fig3|all)"),
    };
    for t in &tables {
        println!("{}", t.render());
        if let Some(dir) = args.get("csv") {
            let dir = std::path::Path::new(dir);
            std::fs::create_dir_all(dir)?;
            let name = t.title.split(':').next().unwrap_or("table").trim().to_string();
            let path = dir.join(format!("{name}.csv"));
            t.write_csv(&path)?;
            println!("wrote {}\n", path.display());
        }
    }
    Ok(())
}

fn cmd_stream(args: &Args) -> Result<()> {
    args.validate_flags(&[
        "dataset", "epsilon", "window", "events", "seed", "config", "drift-at", "drift-rate",
        "report-every",
    ])?;
    let s = settings(args)?;
    let spec = dataset_by_name(&s.dataset)?;
    let mut data = Dataset::new(spec, s.seed);
    let mut stream = data.score_stream(s.events);
    let drift_at: usize = args.get_or("drift-at", 0)?;
    if drift_at > 0 {
        let rate: f64 = args.get_or("drift-rate", 0.5)?;
        Drift::Abrupt { at: drift_at, rate }.apply(&mut stream, s.seed ^ 0xD21F7);
        println!("# injected abrupt drift at {drift_at} (flip rate {rate})");
    }
    let report_every: usize = args.get_or("report-every", (s.events / 20).max(1))?;

    let mut win = Window::with_estimator(s.window, ApproxAuc::new(s.epsilon));
    let mut monitor = AucMonitor::new(0.001, 0.08, (s.window / 5) as u32, s.window as u32);
    let started = std::time::Instant::now();
    let mut alarms = Vec::new();
    println!("# dataset={} k={} ε={} events={}", s.dataset, s.window, s.epsilon, s.events);
    println!("{:>10}  {:>8}  {:>8}  {:>6}", "event", "auc~", "baseline", "|C|");
    for (i, &(score, label)) in stream.iter().enumerate() {
        win.push(score, label);
        if win.is_full() {
            let auc = win.auc();
            if monitor.observe(auc) == MonitorEvent::Alarm {
                alarms.push(i);
                println!("{i:>10}  ALARM: AUC {auc:.4} fell below baseline {:.4}", monitor.baseline());
            }
        }
        if (i + 1) % report_every == 0 {
            println!(
                "{:>10}  {:>8.4}  {:>8.4}  {:>6}",
                i + 1,
                win.auc(),
                monitor.baseline(),
                win.estimator().compressed_len()
            );
        }
    }
    let elapsed = started.elapsed();
    println!(
        "# {} events in {:.2?} ({:.0} events/s); final AUC~ {:.4}; alarms: {:?}",
        s.events,
        elapsed,
        s.events as f64 / elapsed.as_secs_f64(),
        win.auc(),
        alarms
    );
    Ok(())
}

/// Numeric knobs of `streamauc fleet`, parsed **and validated** up
/// front: a zero `--workers`/`--hist`/`--window`, a non-finite
/// `--evict-age` or an out-of-range fraction fails here with a message
/// naming the flag, before any stream state is built — not as a panic
/// (or silent nonsense) minutes into an ingest run.
struct FleetFlags {
    streams: usize,
    events: usize,
    shards: usize,
    workers: usize,
    pool: bool,
    pipeline: bool,
    adaptive: bool,
    window: usize,
    estimator: EstimatorKind,
    batch: usize,
    drift_frac: f64,
    skew: f64,
    seed: u64,
    evict_idle: u64,
    evict_age: u64,
    hibernate_idle: u64,
    top: usize,
    hist_bins: usize,
    count_below: Option<f64>,
}

fn parse_fleet_flags(args: &Args, serve: bool) -> Result<FleetFlags> {
    let mut allowed = vec![
        "streams", "events", "shards", "workers", "window", "estimator", "epsilon", "bins",
        "score-range", "batch", "drift-frac", "skew", "seed", "evict-idle", "evict-age",
        "hibernate-idle", "pool", "pipeline", "adaptive", "top", "count-below", "hist",
    ];
    if serve {
        allowed.extend(["addr", "serve-workers", "max-conns", "timeout-ms"]);
    }
    args.validate_flags(&allowed)?;
    let streams: usize = args.get_or("streams", 1000)?;
    let events: usize = args.get_or("events", 500_000)?;
    let shards: usize = args.get_or("shards", 64)?;
    let workers: usize = args.get_or("workers", 1)?;
    let pool: bool = args.get_or("pool", true)?;
    let pipeline: bool = args.get_or("pipeline", false)?;
    let adaptive: bool = args.get_or("adaptive", false)?;
    let window: usize = args.get_or("window", 300)?;
    let epsilon: f64 = args.get_or("epsilon", 0.05)?;
    let batch: usize = args.get_or("batch", 2048)?;
    let drift_frac: f64 = args.get_or("drift-frac", 0.05)?;
    let skew: f64 = args.get_or("skew", 1.5)?;
    let seed: u64 = args.get_or("seed", 0xF1EE7)?;
    let evict_idle: u64 = args.get_or("evict-idle", 0)?;
    // Parsed as f64 so `--evict-age inf`/`nan` is *rejected* instead of
    // saturating into a silently-wrong u64 threshold.
    let evict_age_raw: f64 = args.get_or("evict-age", 0.0)?;
    let hibernate_idle: u64 = args.get_or("hibernate-idle", 0)?;
    let top: usize = args.get_or("top", 10)?;
    let hist_bins: usize = args.get_or("hist", 10)?;
    // `t ≤ 0` counts nothing, `t > 1` counts every live stream — both
    // finite edges are well-defined at the query layer. Non-finite
    // thresholds are rejected here: `inf`/`nan` is a typo, not a query.
    let count_below: Option<f64> = match args.get("count-below") {
        Some(raw) => {
            let threshold: f64 = raw
                .parse()
                .map_err(|e| anyhow::anyhow!("flag --count-below {raw:?}: {e}"))?;
            if !threshold.is_finite() {
                bail!("--count-below must be a finite AUC threshold, got {threshold}");
            }
            Some(threshold)
        }
        None => None,
    };
    if streams == 0 || events == 0 || batch == 0 {
        bail!("--streams, --events and --batch must be positive");
    }
    if workers == 0 {
        bail!("--workers must be ≥ 1 (1 = serial ingestion; >1 engages the pool)");
    }
    if window == 0 {
        bail!("--window must be ≥ 1 pair");
    }
    if hist_bins == 0 {
        bail!("--hist must be ≥ 1 bin");
    }
    if !epsilon.is_finite() || epsilon < 0.0 {
        bail!("--epsilon must be a finite value ≥ 0, got {epsilon}");
    }
    if !evict_age_raw.is_finite() || evict_age_raw < 0.0 {
        bail!("--evict-age must be a finite event count ≥ 0, got {evict_age_raw}");
    }
    if !(0.0..=1.0).contains(&drift_frac) {
        bail!("--drift-frac must be in [0, 1]");
    }
    if !skew.is_finite() || skew < 1.0 {
        bail!("--skew must be finite and ≥ 1 (1 = uniform stream popularity)");
    }
    // Bounded-score declarations are validated here, at the boundary,
    // mirroring `BinnedAuc::new`'s contract: the run must fail before
    // any stream state exists, not panic mid-ingest.
    let bins: usize = args.get_or("bins", 256)?;
    if bins == 0 {
        bail!("--bins must be ≥ 1 count cell");
    }
    let range_raw = args.get("score-range").unwrap_or("0,1");
    let (lo, hi) = match range_raw.split_once(',') {
        Some((a, b)) => {
            let lo: f64 = a
                .trim()
                .parse()
                .map_err(|e| anyhow::anyhow!("flag --score-range {range_raw:?}: {e}"))?;
            let hi: f64 = b
                .trim()
                .parse()
                .map_err(|e| anyhow::anyhow!("flag --score-range {range_raw:?}: {e}"))?;
            (lo, hi)
        }
        None => bail!("--score-range must be `LO,HI` (comma-separated), got {range_raw:?}"),
    };
    if !lo.is_finite() || !hi.is_finite() {
        bail!("--score-range bounds must be finite, got [{lo}, {hi}]");
    }
    if lo >= hi {
        bail!("--score-range must satisfy LO < HI, got [{lo}, {hi}]");
    }
    let estimator = match args.get("estimator").unwrap_or("approx") {
        "approx" => EstimatorKind::Approx { epsilon },
        "exact" => EstimatorKind::ExactMaintained,
        "binned" => EstimatorKind::Binned { bins, lo, hi },
        other => bail!("--estimator must be `approx`, `exact` or `binned`, got {other:?}"),
    };
    Ok(FleetFlags {
        streams,
        events,
        shards,
        workers,
        pool,
        pipeline,
        adaptive,
        window,
        estimator,
        batch,
        drift_frac,
        skew,
        seed,
        evict_idle,
        evict_age: evict_age_raw as u64,
        hibernate_idle,
        top,
        hist_bins,
        count_below,
    })
}

/// Serve-only knobs of `streamauc fleet serve`, validated at the
/// boundary like the fleet flags: a zero worker pool, connection
/// budget or timeout is a misconfiguration that must fail with a
/// message naming the flag, not bind a port that can never answer.
/// (`--serve-workers` is distinct from `--workers`, which sizes the
/// *ingestion* pool.)
fn parse_serve_limits(args: &Args) -> Result<ServeLimits> {
    let defaults = ServeLimits::default();
    let workers: usize = args.get_or("serve-workers", defaults.workers)?;
    let max_conns: usize = args.get_or("max-conns", defaults.max_conns)?;
    let timeout_ms: u64 = args.get_or("timeout-ms", defaults.timeout.as_millis() as u64)?;
    if workers == 0 {
        bail!("--serve-workers must be ≥ 1 connection worker");
    }
    if max_conns == 0 {
        bail!("--max-conns must be ≥ 1 queued connection");
    }
    if timeout_ms == 0 {
        bail!("--timeout-ms must be ≥ 1 (socket timeouts and the per-request deadline budget)");
    }
    Ok(ServeLimits {
        workers,
        max_conns,
        timeout: std::time::Duration::from_millis(timeout_ms),
    })
}

/// Deterministic generator + fleet shared by `fleet` and
/// `fleet serve`: drift hits the first `drift_frac` of streams halfway
/// through their expected per-stream traffic.
fn build_fleet(f: &FleetFlags) -> (MultiStream, AucFleet, u64) {
    let drifted = (f.streams as f64 * f.drift_frac).round() as u64;
    let per_stream = (f.events / f.streams).max(1) as u64;
    let profiles: Vec<StreamProfile> = (0..f.streams as u64)
        .map(|id| {
            let p = StreamProfile::healthy(id);
            if id < drifted {
                p.with_drift(DriftSchedule::Abrupt { at: per_stream / 2, rate: 0.6 })
            } else {
                p
            }
        })
        .collect();
    let gen = MultiStream::with_profiles(profiles, f.seed).with_skew(f.skew);
    let fleet = AucFleet::new(FleetConfig {
        shards: f.shards,
        workers: f.workers,
        pool: f.pool,
        pipeline: f.pipeline,
        adaptive: f.adaptive,
        stream_defaults: StreamConfig::new(f.window, 0.0).with_estimator(f.estimator),
    });
    (gen, fleet, drifted)
}

fn cmd_fleet(args: &Args) -> Result<()> {
    if args.positional.first().map(String::as_str) == Some("serve") {
        return cmd_fleet_serve(args);
    }
    let flags = parse_fleet_flags(args, false)?;
    let (mut gen, mut fleet, drifted) = build_fleet(&flags);
    let FleetFlags {
        streams,
        events,
        window,
        estimator,
        batch,
        evict_idle,
        evict_age,
        hibernate_idle,
        top,
        hist_bins,
        count_below,
        adaptive,
        ..
    } = flags;

    let estimator_desc = match estimator {
        EstimatorKind::Approx { epsilon } => format!("approx ε={epsilon}"),
        EstimatorKind::ExactMaintained => "exact-maintained".to_string(),
        EstimatorKind::Binned { bins, lo, hi } => format!("binned {bins}×[{lo}, {hi}]"),
    };
    println!(
        "# fleet: {streams} streams ({drifted} drifted), {events} events, \
         batch {batch}, {} shards, {} worker(s) [{}{}{}], k={window}, {estimator_desc}",
        fleet.shard_count(),
        fleet.workers(),
        if fleet.pooled() { "pooled" } else if fleet.workers() > 1 { "scoped" } else { "serial" },
        if fleet.pipelined() { ", pipelined" } else { "" },
        if adaptive { ", adaptive" } else { "" }
    );
    let started = std::time::Instant::now();
    let mut remaining = events;
    while remaining > 0 {
        let n = remaining.min(batch);
        let chunk = gen.next_batch(n);
        // Event-count clock: each batch is stamped with the number of
        // events ingested before it, so `--evict-age` thresholds are in
        // events, like `--evict-idle`, but flow through the timestamp
        // path.
        let at = (events - remaining) as u64;
        fleet.push_batch_at(&chunk, at);
        remaining -= n;
    }
    // `stream_count` synchronizes with a pipelined final batch, so the
    // clock includes the full drain.
    let live = fleet.stream_count();
    let elapsed = started.elapsed();

    println!(
        "# ingested {} events into {live} streams in {:.2?} ({:.0} events/s)",
        fleet.total_events(),
        elapsed,
        events as f64 / elapsed.as_secs_f64()
    );
    if evict_idle > 0 {
        let dropped = fleet.evict_idle(evict_idle);
        println!(
            "# evicted {dropped} stream(s) idle ≥ {evict_idle} events; {} remain",
            fleet.stream_count()
        );
    }
    if evict_age > 0 {
        let dropped = fleet.evict_older_than(evict_age);
        println!(
            "# evicted {dropped} stream(s) older than {evict_age} (clock {}); {} remain",
            fleet.clock(),
            fleet.stream_count()
        );
    }
    if hibernate_idle > 0 {
        let before = fleet.footprint_bytes();
        let frozen = fleet.hibernate_idle(hibernate_idle);
        println!(
            "# hibernated {frozen} stream(s) idle ≥ {hibernate_idle} events \
             ({} total frozen); footprint {before} → {} bytes",
            fleet.hibernated_count(),
            fleet.footprint_bytes()
        );
    }
    let agg = fleet.aggregate();
    println!(
        "# AUC across {} live streams: min {:.4}  p10 {:.4}  median {:.4}  p90 {:.4}  max {:.4}  \
         mean {:.4}",
        agg.live_streams, agg.min_auc, agg.p10_auc, agg.median_auc, agg.p90_auc, agg.max_auc,
        agg.mean_auc
    );
    let snap = fleet.snapshot();
    println!("# fleet mean AUC {:.4}; {} streams alarmed", snap.mean_auc(), agg.alarmed_streams);

    // ---- shard-parallel queries (fleet/query.rs) --------------------
    if hist_bins > 0 {
        let hist = fleet.auc_histogram(hist_bins);
        println!("\n# AUC histogram over {} live streams:", hist.live_streams);
        let peak = hist.counts.iter().copied().max().unwrap_or(0).max(1);
        for (i, &count) in hist.counts.iter().enumerate() {
            let (lo, hi) = hist.bin_range(i);
            let bar = "#".repeat(count * 50 / peak);
            println!("#   [{lo:.2}, {hi:.2})  {count:>7}  {bar}");
        }
    }
    if let Some(threshold) = count_below {
        println!("# {} stream(s) below AUC {threshold}", fleet.count_below(threshold));
    }
    println!("\n{:>10}  {:>8}  {:>6}  {:>6}  {:>7}  alarmed", "stream", "auc~", "fill", "|C|", "alarms");
    for s in fleet.top_k_worst(top) {
        println!(
            "{:>10}  {:>8.4}  {:>6}  {:>6}  {:>7}  {}",
            s.stream, s.auc, s.len, s.compressed_len, s.alarms, s.alarmed
        );
    }
    let alarms = fleet.alarms();
    println!("\n# {} alarms total; first 5:", alarms.len());
    for a in alarms.iter().take(5) {
        println!(
            "#   stream {} at its event {}: auc {:.4} vs baseline {:.4}",
            a.stream, a.stream_event, a.auc, a.baseline
        );
    }
    Ok(())
}

/// `streamauc fleet serve`: same synthetic ingest as `fleet`, but the
/// fleet sits behind a [`FleetServer`] — queries are answered over the
/// wire *while* batches drain on the worker pool, and the server keeps
/// answering after the ingest completes, until the process is killed.
fn cmd_fleet_serve(args: &Args) -> Result<()> {
    let flags = parse_fleet_flags(args, true)?;
    let limits = parse_serve_limits(args)?;
    let addr = args.get("addr").unwrap_or("127.0.0.1:7878");
    let (mut gen, fleet, drifted) = build_fleet(&flags);
    let server =
        FleetServer::start_with(fleet, addr, limits).with_context(|| format!("binding {addr}"))?;
    // Flushed by the trailing newline — CI's smoke job waits for this
    // line before it starts hitting endpoints.
    println!("# serving fleet queries on http://{}", server.local_addr());
    println!(
        "#   GET /snapshot  /aggregate  /top_k_worst?k=K  /count_below?t=T  \
         /auc_histogram?bins=B  /score_histogram?bins=B  /subscribe"
    );
    println!(
        "#   limits: {} connection workers, {} max conns, {}ms socket/request timeout",
        limits.workers,
        limits.max_conns,
        limits.timeout.as_millis()
    );
    println!(
        "# ingesting {} events over {} streams ({} drifted), batch {}",
        flags.events, flags.streams, drifted, flags.batch
    );
    let started = std::time::Instant::now();
    let mut remaining = flags.events;
    while remaining > 0 {
        let n = remaining.min(flags.batch);
        let chunk = gen.next_batch(n);
        let at = (flags.events - remaining) as u64;
        server.ingest_batch_at(&chunk, at);
        remaining -= n;
    }
    let (seq, sketch) = server.last_published();
    println!(
        "# ingest complete in {:.2?}: {} events, {} live streams, {seq} sketch delta(s) \
         published; serving until interrupted",
        started.elapsed(),
        server.with_fleet(|f| f.total_events()),
        sketch.live
    );
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

fn cmd_train(args: &Args) -> Result<()> {
    args.validate_flags(&["dataset", "steps", "lr", "events", "seed", "artifacts", "out", "config"])?;
    let s = settings(args)?;
    let steps: usize = args.get_or("steps", 300)?;
    let lr: f32 = args.get_or("lr", 0.5)?;
    let spec = dataset_by_name(&s.dataset)?;
    println!("# loading PJRT runtime from {}/", s.artifacts);
    let rt = Runtime::new(&s.artifacts)?;
    println!("# platform: {}, contract: {:?}", rt.platform(), rt.meta());

    let mut data = Dataset::new(spec, s.seed);
    let train_n = s.events.min(data.spec().train_size);
    let train = data.examples(train_n);
    println!("# training on {train_n} examples, {steps} SGD steps, lr {lr}");
    let trainer = Trainer::new(&rt, lr)?;
    let t0 = std::time::Instant::now();
    let report = trainer.train(&train, steps)?;
    println!(
        "# trained in {:.2?}: loss {:.4} -> {:.4}",
        t0.elapsed(),
        report.early_loss(10),
        report.late_loss(10)
    );

    let test_n = s.events.min(data.spec().test_size);
    let test = data.examples(test_n);
    let scorer = Scorer::new(&rt, report.params)?;
    let rows: Vec<Vec<f32>> = test.iter().map(|e| e.features.clone()).collect();
    let t1 = std::time::Instant::now();
    let scores = scorer.score(&rows)?;
    println!(
        "# scored {test_n} examples in {:.2?} ({:.0}/s)",
        t1.elapsed(),
        test_n as f64 / t1.elapsed().as_secs_f64()
    );
    let pairs: Vec<(f64, bool)> = scores.iter().zip(&test).map(|(&sc, e)| (sc, e.label)).collect();
    println!("# held-out AUC (exact): {:.4}", NaiveAuc::of(&pairs));

    let mut win = Window::with_estimator(s.window, ApproxAuc::new(s.epsilon));
    for &(sc, l) in &pairs {
        win.push(sc, l);
    }
    println!(
        "# windowed (k={} ε={}): approx {:.4} vs exact {:.4}, |C| = {}",
        s.window,
        s.epsilon,
        win.auc(),
        win.estimator().exact_auc(),
        win.estimator().compressed_len()
    );
    if let Some(out) = args.get("out") {
        write_csv(std::path::Path::new(out), &pairs)?;
        println!("# wrote scored stream to {out}");
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fleet_args(extra: &str) -> Args {
        let raw = format!("fleet {extra}");
        Args::parse(raw.split_whitespace().map(String::from)).unwrap()
    }

    fn reject(extra: &str, needle: &str) {
        let err = parse_fleet_flags(&fleet_args(extra), false)
            .err()
            .unwrap_or_else(|| panic!("`fleet {extra}` must be rejected"))
            .to_string();
        assert!(err.contains(needle), "`fleet {extra}` → {err:?} (wanted {needle:?})");
    }

    #[test]
    fn fleet_defaults_parse_clean() {
        let f = parse_fleet_flags(&fleet_args(""), false).unwrap();
        assert_eq!(f.streams, 1000);
        assert_eq!(f.workers, 1);
        assert_eq!(f.hist_bins, 10);
        assert_eq!(f.evict_age, 0);
        assert_eq!(f.hibernate_idle, 0);
        assert_eq!(f.count_below, None);
        assert_eq!(f.estimator, EstimatorKind::Approx { epsilon: 0.05 });
    }

    #[test]
    fn fleet_rejects_zero_and_nonsense_numerics_up_front() {
        reject("--workers 0", "--workers");
        reject("--hist 0", "--hist");
        reject("--window 0", "--window");
        reject("--streams 0", "positive");
        reject("--events 0", "positive");
        reject("--batch 0", "positive");
        reject("--evict-age inf", "--evict-age");
        reject("--evict-age NaN", "--evict-age");
        reject("--evict-age -3", "--evict-age");
        reject("--epsilon -0.1", "--epsilon");
        reject("--epsilon inf", "--epsilon");
        reject("--drift-frac 1.5", "--drift-frac");
        reject("--skew 0.5", "--skew");
        reject("--skew nan", "--skew");
    }

    #[test]
    fn fleet_count_below_accepts_finite_edges_and_rejects_non_finite() {
        // Finite thresholds — including out-of-range ones with defined
        // semantics (t ≤ 0 counts nothing, t > 1 counts all live) —
        // parse clean.
        let f = parse_fleet_flags(&fleet_args("--count-below -1"), false).unwrap();
        assert_eq!(f.count_below, Some(-1.0));
        let f = parse_fleet_flags(&fleet_args("--count-below 1.5"), false).unwrap();
        assert_eq!(f.count_below, Some(1.5));
        // `inf`/`nan` is a typo, not a query.
        reject("--count-below inf", "--count-below");
        reject("--count-below -inf", "--count-below");
        reject("--count-below nan", "--count-below");
        reject("--count-below high", "--count-below");
    }

    #[test]
    fn fleet_serve_gates_the_addr_flag() {
        reject("--addr 127.0.0.1:0", "addr");
        let ok = parse_fleet_flags(&fleet_args("--addr 127.0.0.1:0"), true);
        assert!(ok.is_ok(), "{:?}", ok.err().map(|e| e.to_string()));
    }

    #[test]
    fn fleet_serve_gates_and_validates_the_limit_flags() {
        // Serve-only flags are rejected by plain `fleet` …
        reject("--serve-workers 2", "serve-workers");
        reject("--max-conns 8", "max-conns");
        reject("--timeout-ms 100", "timeout-ms");
        // … accepted (and parsed into limits) under `fleet serve` …
        let args = fleet_args("--serve-workers 2 --max-conns 8 --timeout-ms 250");
        parse_fleet_flags(&args, true).expect("serve flags allowed");
        let limits = parse_serve_limits(&args).expect("limits parse");
        assert_eq!(limits.workers, 2);
        assert_eq!(limits.max_conns, 8);
        assert_eq!(limits.timeout, std::time::Duration::from_millis(250));
        // … with defaults matching the library's.
        let defaults = parse_serve_limits(&fleet_args("")).expect("defaults parse");
        assert_eq!(defaults.workers, ServeLimits::default().workers);
        assert_eq!(defaults.max_conns, ServeLimits::default().max_conns);
        assert_eq!(defaults.timeout, ServeLimits::default().timeout);
        // Zero limits are misconfigurations, named at the boundary.
        for (extra, needle) in [
            ("--serve-workers 0", "--serve-workers"),
            ("--max-conns 0", "--max-conns"),
            ("--timeout-ms 0", "--timeout-ms"),
        ] {
            let err = parse_serve_limits(&fleet_args(extra))
                .err()
                .unwrap_or_else(|| panic!("`fleet serve {extra}` must be rejected"))
                .to_string();
            assert!(err.contains(needle), "{err:?} (wanted {needle:?})");
        }
    }

    #[test]
    fn fleet_estimator_flag_selects_the_kind() {
        let f = parse_fleet_flags(&fleet_args("--estimator exact"), false).unwrap();
        assert_eq!(f.estimator, EstimatorKind::ExactMaintained);
        let f = parse_fleet_flags(&fleet_args("--estimator approx --epsilon 0.2"), false).unwrap();
        assert_eq!(f.estimator, EstimatorKind::Approx { epsilon: 0.2 });
        reject("--estimator fancy", "--estimator");
    }

    #[test]
    fn fleet_binned_flags_select_and_validate_the_declaration() {
        // Defaults: 256 cells over the unit interval.
        let f = parse_fleet_flags(&fleet_args("--estimator binned"), false).unwrap();
        assert_eq!(f.estimator, EstimatorKind::Binned { bins: 256, lo: 0.0, hi: 1.0 });
        // Explicit declaration, negative lower bound included.
        let f = parse_fleet_flags(
            &fleet_args("--estimator binned --bins 64 --score-range -1.5,2"),
            false,
        )
        .unwrap();
        assert_eq!(f.estimator, EstimatorKind::Binned { bins: 64, lo: -1.5, hi: 2.0 });
        // Invalid declarations fail at the boundary, naming the flag —
        // even when the estimator is not binned (consistent with how
        // `--epsilon` is vetted under `--estimator exact`).
        reject("--bins 0", "--bins");
        reject("--estimator binned --bins 0", "--bins");
        reject("--score-range 1,0", "LO < HI");
        reject("--score-range 1,1", "LO < HI");
        reject("--score-range inf,1", "finite");
        reject("--score-range 0,nan", "finite");
        reject("--score-range 0:1", "comma-separated");
        reject("--score-range zero,one", "--score-range");
    }

    #[test]
    fn fleet_age_threshold_truncates_to_events() {
        let f = parse_fleet_flags(&fleet_args("--evict-age 1500"), false).unwrap();
        assert_eq!(f.evict_age, 1500);
    }

    #[test]
    fn fleet_hibernate_idle_parses_as_events() {
        let f = parse_fleet_flags(&fleet_args("--hibernate-idle 250"), false).unwrap();
        assert_eq!(f.hibernate_idle, 250);
        reject("--hibernate-idle -1", "--hibernate-idle");
    }
}
