//! Fleet ingestion throughput: updates/sec versus stream count,
//! batched (`push_batch`) against the naive one-at-a-time loop, and
//! the three execution strategies against each other — serial inline,
//! scoped threads spawned per batch (the PR-2 baseline), and the
//! persistent work-stealing pool (with and without cross-batch
//! pipelining).
//!
//! `cargo bench --bench fleet [-- --events N] [-- --workers W]`
//!
//! Each row streams the same pre-generated bursty event soup into a
//! fresh fleet seven ways:
//!
//! * `one-at-a-time` — `push` per event: full dispatch (stream-id hash
//!   + shard index probe) on every update;
//! * `batched` — `push_batch` in chunks: per-shard bucketing with the
//!   stream lookup amortized over same-stream runs, serial drain;
//! * `scoped ∥` — ditto, shards drained by `--workers` scoped threads
//!   spawned (and joined) on every batch;
//! * `pooled ∥` — ditto, drained by the persistent pool: workers spawn
//!   once, park between batches, and steal shards largest-bucket-first;
//! * `piped ∥` — pooled plus cross-batch pipelining: the next batch is
//!   bucketed while the previous one drains;
//! * `monitor` / `mon ∥` — batched serial / pooled with the per-stream
//!   drift monitor on (adds one `O(|C|)` AUC read per update — the full
//!   service configuration, and the regime where parallelism pays most).
//!
//! Besides the human-readable table, the run writes machine-readable
//! `BENCH_fleet.json` at the repository root (events/sec per scenario
//! per stream count, plus parallel speedups) so the perf trajectory is
//! tracked across PRs.
//!
//! Expected shape: batched ≥ one-at-a-time everywhere; pooled ≥ scoped
//! at small batches (no spawn/join per batch) and under skew (stealing
//! instead of fixed chunks); piped ≥ pooled when generation is a
//! visible fraction of the loop; every parallel mode ≈ serial at 1
//! stream (one shard is hot). Each parallel fleet is asserted
//! bit-identical to its serial twin before timings are reported — the
//! bench doubles as a determinism smoke test.

use std::fmt::Write as _;
use std::time::Instant;

use streamauc::fleet::{AucFleet, FleetConfig, StreamConfig};
use streamauc::stream::MultiStream;

const WINDOW: usize = 100;
const EPSILON: f64 = 0.1;
const BATCH: usize = 8192;
const SHARDS: usize = 64;

struct Row {
    streams: usize,
    one_at_a_time: f64,
    batched_serial: f64,
    batched_scoped: f64,
    batched_pooled: f64,
    pipelined: f64,
    monitor_serial: f64,
    monitor_pooled: f64,
    live: usize,
}

fn fresh_fleet(monitor: bool, workers: usize, pool: bool, pipeline: bool) -> AucFleet {
    let stream_defaults = if monitor {
        StreamConfig::new(WINDOW, EPSILON)
    } else {
        StreamConfig::new(WINDOW, EPSILON).without_monitor()
    };
    AucFleet::new(FleetConfig { shards: SHARDS, workers, pool, pipeline, stream_defaults })
}

fn throughput(events: &[(u64, f64, bool)], mut ingest: impl FnMut(&[(u64, f64, bool)])) -> f64 {
    let start = Instant::now();
    ingest(events);
    events.len() as f64 / start.elapsed().as_secs_f64()
}

fn batched(fleet: &mut AucFleet, soup: &[(u64, f64, bool)]) -> f64 {
    throughput(soup, |evs| {
        for chunk in evs.chunks(BATCH) {
            fleet.push_batch(chunk);
        }
        // A pipelined fleet may still be draining its last batch; fold
        // the wait into the timed region so strategies stay comparable.
        let _ = fleet.stream_count();
    })
}

fn flag(args: &[String], name: &str, default: usize) -> usize {
    match args.iter().position(|a| a == name) {
        Some(i) => args
            .get(i + 1)
            .unwrap_or_else(|| panic!("{name} N"))
            .parse()
            .unwrap_or_else(|_| panic!("{name} N")),
        None => default,
    }
}

fn json_report(events_per_row: usize, workers: usize, rows: &[Row]) -> String {
    let mut s = String::new();
    s.push_str("{\n");
    let _ = writeln!(s, "  \"bench\": \"fleet\",");
    let _ = writeln!(s, "  \"unit\": \"events_per_sec\",");
    let _ = writeln!(s, "  \"events_per_row\": {events_per_row},");
    let _ = writeln!(s, "  \"window\": {WINDOW},");
    let _ = writeln!(s, "  \"epsilon\": {EPSILON},");
    let _ = writeln!(s, "  \"batch\": {BATCH},");
    let _ = writeln!(s, "  \"shards\": {SHARDS},");
    let _ = writeln!(s, "  \"workers\": {workers},");
    s.push_str("  \"rows\": [\n");
    for (i, r) in rows.iter().enumerate() {
        let _ = write!(
            s,
            "    {{\"streams\": {}, \"live_streams\": {}, \"one_at_a_time\": {:.1}, \
             \"batched_serial\": {:.1}, \"batched_scoped\": {:.1}, \"batched_pooled\": {:.1}, \
             \"pipelined\": {:.1}, \"monitor_serial\": {:.1}, \"monitor_pooled\": {:.1}, \
             \"speedup_scoped\": {:.3}, \"speedup_pooled\": {:.3}, \"speedup_pipelined\": {:.3}, \
             \"speedup_monitor\": {:.3}}}",
            r.streams,
            r.live,
            r.one_at_a_time,
            r.batched_serial,
            r.batched_scoped,
            r.batched_pooled,
            r.pipelined,
            r.monitor_serial,
            r.monitor_pooled,
            r.batched_scoped / r.batched_serial,
            r.batched_pooled / r.batched_serial,
            r.pipelined / r.batched_serial,
            r.monitor_pooled / r.monitor_serial,
        );
        s.push_str(if i + 1 < rows.len() { ",\n" } else { "\n" });
    }
    s.push_str("  ]\n}\n");
    s
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let events_per_row = flag(&args, "--events", 400_000);
    let workers = flag(&args, "--workers", 4);

    println!("== fleet: ingestion throughput — batching and execution strategies ==");
    println!(
        "   (k={WINDOW}, ε={EPSILON}, batch={BATCH}, {SHARDS} shards, {workers} workers, \
         {events_per_row} events/row)\n"
    );
    println!(
        "{:>8}  {:>13}  {:>12}  {:>12}  {:>12}  {:>12}  {:>6}  {:>12}  {:>12}  {:>6}  {:>7}",
        "streams",
        "one-at-a-time",
        "batched",
        "scoped ∥",
        "pooled ∥",
        "piped ∥",
        "gain",
        "monitor",
        "mon ∥",
        "gain",
        "live"
    );

    let mut rows = Vec::new();
    for &n_streams in &[1usize, 100, 10_000] {
        // Pre-generate outside the timed region; bursty + mildly skewed
        // traffic (the regime push_batch's run-grouping and the
        // size-aware claim queue both exploit).
        let mut gen = MultiStream::new(n_streams, 0xBE7C).with_mean_burst(8.0);
        let soup = gen.next_batch(events_per_row);

        let mut fleet = fresh_fleet(false, 1, false, false);
        let one = throughput(&soup, |evs| {
            for &(id, s, l) in evs {
                fleet.push(id, s, l);
            }
        });
        let live = fleet.stream_count();

        let mut serial = fresh_fleet(false, 1, false, false);
        let batched_serial = batched(&mut serial, &soup);
        let mut scoped = fresh_fleet(false, workers, false, false);
        let batched_scoped = batched(&mut scoped, &soup);
        let mut pooled = fresh_fleet(false, workers, true, false);
        let batched_pooled = batched(&mut pooled, &soup);
        let mut piped = fresh_fleet(false, workers, true, true);
        let pipelined = batched(&mut piped, &soup);
        assert_eq!(serial.snapshot(), scoped.snapshot(), "scoped ingest diverged");
        assert_eq!(serial.snapshot(), pooled.snapshot(), "pooled ingest diverged");
        assert_eq!(serial.snapshot(), piped.snapshot(), "pipelined ingest diverged");
        assert_eq!(serial.aggregate(), pooled.aggregate(), "pooled aggregate diverged");

        let mut mon_serial = fresh_fleet(true, 1, false, false);
        let monitor_serial = batched(&mut mon_serial, &soup);
        let mut mon_pooled = fresh_fleet(true, workers, true, false);
        let monitor_pooled = batched(&mut mon_pooled, &soup);
        assert_eq!(mon_serial.alarms(), mon_pooled.alarms(), "pooled alarms diverged");
        assert_eq!(mon_serial.snapshot(), mon_pooled.snapshot(), "pooled monitor ingest diverged");

        println!(
            "{n_streams:>8}  {one:>11.0}/s  {batched_serial:>10.0}/s  {batched_scoped:>10.0}/s  \
             {batched_pooled:>10.0}/s  {pipelined:>10.0}/s  {:>5.2}x  {monitor_serial:>10.0}/s  \
             {monitor_pooled:>10.0}/s  {:>5.2}x  {live:>7}",
            batched_pooled / batched_serial,
            monitor_pooled / monitor_serial,
        );
        rows.push(Row {
            streams: n_streams,
            one_at_a_time: one,
            batched_serial,
            batched_scoped,
            batched_pooled,
            pipelined,
            monitor_serial,
            monitor_pooled,
            live,
        });
    }
    println!(
        "\n(gain = pooled / serial at {workers} workers; live = distinct streams touched)"
    );

    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("BENCH_fleet.json");
    let report = json_report(events_per_row, workers, &rows);
    match std::fs::write(&path, &report) {
        Ok(()) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("could not write {}: {e}", path.display()),
    }
}
