//! FIFO sliding-window driver.
//!
//! The estimators operate on a multiset; this wrapper adds the *sliding*
//! semantics of the paper's streaming setting: push the newest pair,
//! evict the oldest once the window exceeds `k`. Any [`AucEstimator`]
//! plugs in; [`SlidingAuc`] is the convenience alias over [`ApproxAuc`]
//! that downstream code (examples, CLI, runtime) uses.

use std::collections::VecDeque;

use super::{ApproxAuc, AucEstimator};

/// Sliding window of capacity `k` over any estimator.
#[derive(Clone, Debug)]
pub struct Window<E> {
    est: E,
    fifo: VecDeque<(f64, bool)>,
    capacity: usize,
}

impl<E: AucEstimator> Window<E> {
    /// Wrap an estimator with FIFO eviction at `capacity` entries.
    pub fn with_estimator(capacity: usize, est: E) -> Self {
        assert!(capacity > 0, "window capacity must be positive");
        Window { est, fifo: VecDeque::with_capacity(capacity + 1), capacity }
    }

    /// Push a pair; evicts and returns the oldest pair when the window
    /// is full.
    ///
    /// # Panics
    ///
    /// On a non-finite score: `NaN` has no place in the score order and
    /// `±∞` are reserved for the §3.1 sentinel nodes
    /// (`collections/score.rs`). The check runs **before** any state is
    /// touched, so a caught panic leaves the window exactly as it was —
    /// the property the fleet's worker-pool panic recovery relies on
    /// (`rust/tests/executor.rs`).
    pub fn push(&mut self, score: f64, pos: bool) -> Option<(f64, bool)> {
        assert!(score.is_finite(), "window scores must be finite, got {score}");
        self.est.insert(score, pos);
        self.fifo.push_back((score, pos));
        if self.fifo.len() > self.capacity {
            let (s, p) = self.fifo.pop_front().expect("non-empty");
            self.est.remove(s, p);
            Some((s, p))
        } else {
            None
        }
    }

    /// Current AUC of the windowed estimator. For [`ApproxAuc`] this is
    /// `O(1)`: the estimator maintains its doubled-area accumulator
    /// incrementally, so reading never rescans the compressed list
    /// (`DESIGN.md` §Incremental-reads) — which is what lets the fleet
    /// feed per-event drift monitors and shard sketches from this value
    /// at no asymptotic cost.
    pub fn auc(&self) -> f64 {
        self.est.auc()
    }

    /// Number of pairs currently in the window.
    pub fn len(&self) -> usize {
        self.fifo.len()
    }

    /// True until the first push.
    pub fn is_empty(&self) -> bool {
        self.fifo.is_empty()
    }

    /// True once the window reached capacity (estimates before this point
    /// cover a partial window).
    pub fn is_full(&self) -> bool {
        self.fifo.len() == self.capacity
    }

    /// Window capacity `k`.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// The wrapped estimator.
    pub fn estimator(&self) -> &E {
        &self.est
    }

    /// Window contents, oldest first (test / experiment helper).
    pub fn entries(&self) -> impl Iterator<Item = (f64, bool)> + '_ {
        self.fifo.iter().copied()
    }
}

/// The paper's configuration: approximate estimator in a sliding window.
pub type SlidingApprox = Window<ApproxAuc>;

/// Approximate sliding-window AUC — the crate's main entry point.
///
/// ```
/// use streamauc::coordinator::SlidingAuc;
/// let mut w = SlidingAuc::new(100, 0.05);
/// for i in 0..500 {
///     let pos = i % 2 == 0;
///     w.push(if pos { 0.2 } else { 0.8 }, pos);
/// }
/// assert_eq!(w.auc(), 1.0);
/// ```
#[derive(Clone, Debug)]
pub struct SlidingAuc {
    inner: SlidingApprox,
}

impl SlidingAuc {
    /// Window of capacity `k` with approximation parameter `ε`.
    pub fn new(k: usize, epsilon: f64) -> Self {
        SlidingAuc { inner: Window::with_estimator(k, ApproxAuc::new(epsilon)) }
    }

    /// Push a pair, evicting the oldest beyond capacity.
    pub fn push(&mut self, score: f64, pos: bool) -> Option<(f64, bool)> {
        self.inner.push(score, pos)
    }

    /// Current approximate AUC (`|ãuc − auc| ≤ ε·auc/2`). `O(1)` — the
    /// estimate is maintained incrementally, not recomputed per read.
    pub fn auc(&self) -> f64 {
        self.inner.auc()
    }

    /// Exact AUC over the same window (`O(k)`, for monitoring error).
    pub fn exact_auc(&self) -> f64 {
        self.inner.estimator().exact_auc()
    }

    /// Current `|C|` (compressed-list size).
    pub fn compressed_len(&self) -> usize {
        self.inner.estimator().compressed_len()
    }

    /// Pairs currently in the window.
    pub fn len(&self) -> usize {
        self.inner.len()
    }

    /// True before the first push.
    pub fn is_empty(&self) -> bool {
        self.inner.is_empty()
    }

    /// True once `len() == k`.
    pub fn is_full(&self) -> bool {
        self.inner.is_full()
    }

    /// Window capacity `k`.
    pub fn capacity(&self) -> usize {
        self.inner.capacity()
    }
}

// One stream's full per-stream state (estimator + FIFO) is `Send`:
// this is the window the fleet layer moves onto scoped worker threads.
const _: () = {
    const fn assert_send<T: Send>() {}
    assert_send::<SlidingAuc>();
    assert_send::<Window<ApproxAuc>>();
};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{ExactAuc, NaiveAuc};
    use crate::testing::Pcg;

    #[test]
    fn eviction_is_fifo() {
        let mut w = Window::with_estimator(3, NaiveAuc::new());
        assert_eq!(w.push(0.1, true), None);
        assert_eq!(w.push(0.2, false), None);
        assert_eq!(w.push(0.3, true), None);
        assert!(w.is_full());
        assert_eq!(w.push(0.4, false), Some((0.1, true)));
        assert_eq!(w.push(0.5, true), Some((0.2, false)));
        assert_eq!(w.len(), 3);
    }

    #[test]
    fn windowed_approx_tracks_windowed_exact() {
        let mut approx = SlidingAuc::new(150, 0.05);
        let mut exact = Window::with_estimator(150, ExactAuc::new());
        let mut rng = Pcg::seed(0x77);
        for i in 0..2000 {
            let pos = rng.chance(0.5);
            // Shift the distribution midway to exercise churn.
            let base = if i < 1000 { 0.0 } else { 0.3 };
            let s = base + if pos { rng.normal_with(0.4, 0.1) } else { rng.normal_with(0.6, 0.1) };
            approx.push(s, pos);
            exact.push(s, pos);
            let (a, b) = (approx.auc(), exact.auc());
            assert!((a - b).abs() <= 0.05 * b / 2.0 + 1e-12, "step {i}: {a} vs {b}");
        }
        assert_eq!(approx.len(), 150);
    }

    #[test]
    fn capacity_one() {
        let mut w = SlidingAuc::new(1, 0.1);
        w.push(0.5, true);
        assert_eq!(w.push(0.6, false), Some((0.5, true)));
        assert_eq!(w.len(), 1);
        assert_eq!(w.auc(), 0.5); // single class
    }

    #[test]
    #[should_panic(expected = "positive")]
    fn zero_capacity_rejected() {
        SlidingAuc::new(0, 0.1);
    }

    #[test]
    #[should_panic(expected = "finite")]
    fn nan_score_rejected_at_the_boundary() {
        let mut w = SlidingAuc::new(10, 0.1);
        w.push(f64::NAN, true);
    }

    #[test]
    fn rejected_push_leaves_window_untouched() {
        let mut w = Window::with_estimator(10, ApproxAuc::new(0.1));
        w.push(0.3, true);
        w.push(0.7, false);
        let before: Vec<(f64, bool)> = w.entries().collect();
        let auc_before = w.auc();
        let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            w.push(f64::INFINITY, false);
        }));
        assert!(err.is_err(), "sentinel scores must be rejected");
        assert_eq!(w.entries().collect::<Vec<_>>(), before);
        assert_eq!(w.auc(), auc_before);
        assert_eq!(w.len(), 2);
        w.push(0.5, true); // still fully usable
        assert_eq!(w.len(), 3);
    }

    #[test]
    fn doc_example() {
        let mut w = SlidingAuc::new(100, 0.05);
        for i in 0..500 {
            let pos = i % 2 == 0;
            w.push(if pos { 0.2 } else { 0.8 }, pos);
        }
        assert_eq!(w.auc(), 1.0);
    }
}
