//! Supporting data structures for estimating AUC (paper §3).
//!
//! * [`arena`] — typed slab arenas with free lists. Every tree node and
//!   list cell lives in one; standalone estimators bundle private
//!   arenas, the fleet pools them per shard (`rust/DESIGN.md` §Memory).
//! * [`rbtree`] — arena-based augmented red-black tree. Instantiated twice
//!   by the coordinator: as the score tree `T` (per-node label counters
//!   `p`, `n` plus subtree sums `accpos`, `accneg` maintained through
//!   rotations) and as the positive-node index `TP`.
//! * [`weighted_list`] — the weighted linked list with gap counters
//!   `gp`/`gn` used for the positive list `P` and the `(1+ε)`-compressed
//!   list `C`.
//! * [`score`] — total ordering for `f64` classifier scores, including the
//!   `±∞` sentinels of paper §3.1.

pub mod arena;
pub mod rbtree;
pub mod score;
pub mod weighted_list;

pub use arena::Arena;
pub use rbtree::{Augment, NodeId, RbTree};
pub use score::Score;
pub use weighted_list::{CellId, WeightedList};
