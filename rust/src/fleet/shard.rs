//! Shard-owned fleet state: the unit of parallelism.
//!
//! A [`Shard`] owns everything needed to ingest its slice of the fleet's
//! traffic without touching any other shard: the dense stream slab, the
//! stream-id → slot index, and a shard-local alarm log. Because the
//! state is fully shard-owned (no `Rc`, no interior mutability — see
//! the compile-time `Send` assertion at the bottom), each shard sits
//! behind its own mutex in the fleet core and is claimed by exactly one
//! worker of the work-stealing drain (`fleet/pool.rs`), so the locks
//! never contend. Batch buckets live fleet-side (`AucFleet` stages
//! them while the previous batch drains — the pipelining overlap) and
//! arrive here as plain slices; their *sizes* drive both the
//! precomputed tick stamps and the size-aware claim queue.
//!
//! Determinism contract: a shard's observable state after
//! [`Shard::drain_events`] depends only on the events it is given, the
//! `start_tick` and the batch timestamp — never on which thread ran it
//! or when. Alarms accumulate in the shard-local log and are merged
//! into the fleet-wide log in shard-index order, which is exactly the
//! order the serial path produces, so parallel and serial ingestion
//! are bit-identical (`rust/DESIGN.md` §Parallelism).
//!
//! **Memory.** The shard also owns the [`EstimatorArenas`] every one of
//! its streams allocates tree nodes and list cells from: streams hold
//! arena-backed cores ([`PooledEstimator`] — roots, counters,
//! accumulators) rather than per-stream `Vec`s, so a million estimators
//! share a handful of large slabs per shard instead of millions of
//! small allocations (`rust/DESIGN.md` §Memory). Eviction and
//! hibernation return every slot a stream held to the arena free lists
//! ([`PooledEstimator::free_in`]); when no live-form stream remains the
//! arenas reset and release their slabs, and trailing freed capacity is
//! trimmed after every eviction/hibernation pass so free lists never
//! ratchet. Idle streams can further be **hibernated** into the compact
//! frozen form ([`FrozenStream`]): window contents as contiguous
//! buffers, live structures freed, transparently rehydrated —
//! bit-identically — on the stream's next event.
//!
//! Besides ingestion, the shard exposes the **read-only visitor
//! methods** the typed job layer (`fleet/pool.rs` `ShardWork`) runs
//! shard-parallel: per-shard snapshots, aggregate partials and the
//! query primitives behind `fleet/query.rs`. Each returns plain owned
//! data so per-shard results can be reassembled in shard-index order
//! without further locking (`rust/DESIGN.md` §Jobs).
//!
//! **Running sketch.** Each shard additionally maintains a
//! [`ShardSketch`] — per-bin live-stream counts over a fixed
//! [`SKETCH_BINS`]-bin AUC histogram, the live/alarmed stream counts,
//! and a fixed-point sum of the live AUCs — updated at drain time as
//! each stream's estimate moves (old contribution retracted, new one
//! recorded; both `O(1)` because the per-stream AUC read is the
//! estimator's cached accumulator). Fleet-wide `aggregate()`,
//! `count_below()` and `auc_histogram()` then answer from
//! `O(shards·bins)` sketch merges with no per-stream rescan, and
//! `top_k_worst` / quantile refinement scan only candidate bins — see
//! `rust/DESIGN.md` §Incremental-reads for the invalidation rules
//! (refresh on every ingested event; retract on evict and reset).
//! Hibernated streams keep their sketch contribution — their estimate
//! is pinned by the frozen form — so sketch-backed reads never need to
//! rehydrate anything.

use std::collections::{HashMap, VecDeque};

use crate::coordinator::support::EstimatorArenas;
use crate::coordinator::{AucMonitor, MonitorEvent};

use super::config::{EstimatorKind, PooledEstimator, StreamConfig};
use super::frozen::FrozenStream;
use super::snapshot::{FleetAlarm, StreamSnapshot};

/// Bins of the shard-maintained AUC sketch. Exactly 64 so a set of
/// candidate bins is a `u64` mask, and a power of two so `auc · 64` is
/// an *exact* f64 product — which is what makes the bin partition
/// provably consistent with the `total_cmp` value order (every
/// refinement argument in `fleet/query.rs` leans on this).
pub(super) const SKETCH_BINS: usize = 64;

/// Fixed-point scale (2⁵²) for the sketch's running AUC sum. Integer
/// add/sub is exactly reversible, so the running mean survives any
/// interleaving of inserts, evictions and resets bit-identically to a
/// from-scratch rebuild — an incrementally maintained `f64` sum would
/// drift. Quantization error per stream is ≤ 2⁻⁵³ relative.
pub(super) const AUC_QUANT: f64 = (1u64 << 52) as f64;

/// Quantize one AUC estimate onto the fixed-point grid.
#[inline]
pub(super) fn quantize_auc(auc: f64) -> i64 {
    (auc * AUC_QUANT).round() as i64
}

/// Sketch bin of one AUC estimate: `⌊auc · 64⌋`, clamped so 1.0 lands
/// in the last bin. Monotone in `auc` (the product is exact — see
/// [`SKETCH_BINS`]).
#[inline]
pub(super) fn sketch_bin(auc: f64) -> u8 {
    ((auc * SKETCH_BINS as f64) as usize).min(SKETCH_BINS - 1) as u8
}

/// Sketch bin containing a `count_below`-style threshold. Defined next
/// to [`sketch_bin`] because the refinement argument in
/// `fleet/query.rs` needs the *same* partition for values and
/// thresholds: a value `v < t` can never sit in a bin above
/// `threshold_bin(t)`, nor `v ≥ t` below it. Meaningful only for
/// `0 < t ≤ 1` — the query surface handles everything outside that
/// range explicitly before binning (a bare `as usize` cast would
/// silently truncate negative or NaN thresholds to bin 0).
#[inline]
pub(super) fn threshold_bin(threshold: f64) -> usize {
    debug_assert!(
        threshold > 0.0 && threshold <= 1.0,
        "threshold {threshold} outside (0, 1] must be resolved before binning"
    );
    ((threshold * SKETCH_BINS as f64) as usize).min(SKETCH_BINS - 1)
}

/// The "worst stream first" total order on `(windowed AUC, stream id)`
/// keys: ascending AUC, ties broken by id. Shared by
/// [`Shard::top_k_worst`], the global merge in `fleet/query.rs`, and
/// the serving layer's published-view ranking (`serve/publish.rs`) —
/// the per-shard truncation argument ("any global top-k member is in
/// its own shard's top-k") and the wire-answer bit-identity proof are
/// sound **only** while every sort uses this exact order, so no site
/// may diverge from it.
pub(crate) fn worst_first(a: (f64, u64), b: (f64, u64)) -> std::cmp::Ordering {
    a.0.total_cmp(&b.0).then(a.1.cmp(&b.1))
}

/// One stream's contribution as currently recorded in the owning
/// shard's [`ShardSketch`]. Kept on the stream so the drain can
/// retract exactly what it recorded (`Shard::refresh_stat`); also the
/// cache the candidate-bin refinement scans read (`bin`, `auc`) —
/// `auc` is bit-equal to the stream's estimate by construction.
#[derive(Clone, Copy, Debug, Default)]
pub(super) struct StreamStat {
    /// Window non-empty: only live streams enter the distribution.
    pub(super) live: bool,
    /// Monitor currently inside an alarmed excursion.
    pub(super) alarmed: bool,
    /// [`sketch_bin`] of `auc` (meaningful only when `live`).
    pub(super) bin: u8,
    /// [`quantize_auc`] of `auc` (meaningful only when `live`).
    pub(super) qauc: i64,
    /// The windowed AUC estimate itself.
    pub(super) auc: f64,
    /// [`StreamState::footprint_bytes`] as last recorded — counted for
    /// *every* stream (an empty window still holds sentinel slots), so
    /// the sketch-backed fleet footprint needs no stream rescan.
    pub(super) footprint: u64,
}

impl StreamStat {
    /// The stat of a stream in its current state. `O(1)` — the AUC
    /// read is the estimator's cached accumulator (or the frozen
    /// form's pinned estimate, bit-equal by the rehydration contract).
    fn of(st: &StreamState) -> StreamStat {
        let auc = st.auc();
        StreamStat {
            live: !st.is_window_empty(),
            alarmed: st.monitor.as_ref().map_or(false, AucMonitor::is_alarmed),
            bin: sketch_bin(auc),
            qauc: quantize_auc(auc),
            auc,
            footprint: st.footprint_bytes() as u64,
        }
    }
}

/// Running sufficient statistics over one shard's streams: per-bin
/// live counts, live/alarmed totals and the fixed-point AUC sum.
/// Maintained by [`Shard::refresh_stat`] (record/retract pairs), read
/// by the fleet's sketch-backed aggregate and query paths. All fields
/// are exactly reversible integers, so the running value equals a
/// from-scratch rebuild bit-for-bit ([`Shard::verify_sketch`]).
#[derive(Clone, Debug, PartialEq)]
pub(super) struct ShardSketch {
    /// Live streams per [`sketch_bin`].
    pub(super) bins: [u32; SKETCH_BINS],
    /// Streams with a non-empty window.
    pub(super) live: usize,
    /// Streams inside an alarmed excursion.
    pub(super) alarmed: usize,
    /// Σ [`quantize_auc`] over live streams (`i128`: fleet-scale sums
    /// of 2⁵²-scaled values overflow `i64`).
    pub(super) qauc_sum: i128,
    /// Σ [`StreamStat::footprint`] over *all* streams — the shard's
    /// logical memory footprint, maintained incrementally so
    /// fleet-wide footprint reads are `O(shards)`, not `O(streams)`.
    pub(super) footprint: u64,
}

impl Default for ShardSketch {
    fn default() -> Self {
        ShardSketch { bins: [0; SKETCH_BINS], live: 0, alarmed: 0, qauc_sum: 0, footprint: 0 }
    }
}

impl ShardSketch {
    /// Add one stream's contribution.
    fn record(&mut self, s: StreamStat) {
        if s.live {
            self.bins[s.bin as usize] += 1;
            self.live += 1;
            self.qauc_sum += i128::from(s.qauc);
        }
        if s.alarmed {
            self.alarmed += 1;
        }
        self.footprint += s.footprint;
    }

    /// Remove a previously recorded contribution (exact inverse).
    fn retract(&mut self, s: StreamStat) {
        if s.live {
            self.bins[s.bin as usize] -= 1;
            self.live -= 1;
            self.qauc_sum -= i128::from(s.qauc);
        }
        if s.alarmed {
            self.alarmed -= 1;
        }
        self.footprint -= s.footprint;
    }
}

/// Sliding window over an arena-backed [`PooledEstimator`]: the pooled
/// counterpart of [`Window`](crate::coordinator::window::Window), with
/// every storage-touching operation taking the owning shard's arenas
/// explicitly. Semantics (FIFO eviction, finite-score rejection
/// *before* mutation) are identical — the executor's panic-recovery
/// contract relies on the latter.
#[derive(Clone, Debug)]
pub(super) struct PooledWindow {
    /// The arena-backed estimator core.
    pub(super) est: PooledEstimator,
    fifo: VecDeque<(f64, bool)>,
    capacity: usize,
}

impl PooledWindow {
    pub(super) fn new(capacity: usize, est: PooledEstimator) -> Self {
        assert!(capacity > 0, "window capacity must be positive");
        PooledWindow { est, fifo: VecDeque::with_capacity(capacity + 1), capacity }
    }

    /// Reassemble a window from rehydrated parts (`fleet/frozen.rs`).
    pub(super) fn from_parts(
        est: PooledEstimator,
        fifo: VecDeque<(f64, bool)>,
        capacity: usize,
    ) -> Self {
        assert!(capacity > 0, "window capacity must be positive");
        debug_assert!(fifo.len() <= capacity, "rehydrated window overfull");
        PooledWindow { est, fifo, capacity }
    }

    /// Push a pair; evicts and returns the oldest pair when the window
    /// is full. Panics on a non-finite score **before** any state is
    /// touched (same contract as `Window::push`).
    pub(super) fn push(
        &mut self,
        ars: &mut EstimatorArenas,
        score: f64,
        pos: bool,
    ) -> Option<(f64, bool)> {
        assert!(score.is_finite(), "window scores must be finite, got {score}");
        self.est.insert_in(ars, score, pos);
        self.fifo.push_back((score, pos));
        if self.fifo.len() > self.capacity {
            let (s, p) = self.fifo.pop_front().expect("non-empty");
            self.est.remove_in(ars, s, p);
            Some((s, p))
        } else {
            None
        }
    }

    /// Current AUC — `O(1)`, the estimator's cached accumulator.
    pub(super) fn auc(&self) -> f64 {
        self.est.auc()
    }

    pub(super) fn len(&self) -> usize {
        self.fifo.len()
    }

    pub(super) fn is_empty(&self) -> bool {
        self.fifo.is_empty()
    }

    pub(super) fn is_full(&self) -> bool {
        self.fifo.len() == self.capacity
    }

    /// Window contents, oldest first.
    pub(super) fn entries(&self) -> impl Iterator<Item = (f64, bool)> + '_ {
        self.fifo.iter().copied()
    }

    /// Logical bytes: the estimator's arena slots plus the FIFO pairs.
    pub(super) fn footprint_bytes(&self) -> usize {
        self.est.footprint_bytes() + self.fifo.len() * std::mem::size_of::<(f64, bool)>()
    }
}

/// The two forms a stream's window state takes: the live arena-backed
/// window, or the compact frozen buffer an idle stream is hibernated
/// into ([`FrozenStream`] — `rust/DESIGN.md` §Memory). Everything
/// observable (estimate, length, entries, snapshot) is identical
/// across the two forms; only cost differs.
#[derive(Clone, Debug)]
pub(super) enum StreamRepr {
    /// Live arena-backed window (hot path).
    Live(PooledWindow),
    /// Hibernated: contiguous buffers, no arena slots held. Boxed so
    /// the slab's per-stream stride stays one pointer wide for this
    /// variant's payload.
    Frozen(Box<FrozenStream>),
}

/// One stream's state: sliding estimator window (live or frozen) plus
/// optional drift monitor. Factored out of the shard so future
/// per-stream features (decay, flipped estimators) have one place to
/// live. The monitor and lifetime counters stay resident across
/// hibernation — they are a few machine words, and keeping them live
/// means rehydration rebuilds *only* the estimator, whose state is
/// content-determined (the bit-identity contract).
#[derive(Clone, Debug)]
pub(super) struct StreamState {
    /// Stream id (also the key in the owning shard's index).
    pub(super) id: u64,
    /// The stream's configuration; retained so hibernation can rebuild
    /// the estimator on rehydrate and resets don't re-resolve overrides.
    pub(super) cfg: StreamConfig,
    /// The window state — live arena-backed or hibernated.
    pub(super) repr: StreamRepr,
    /// Drift monitor; `None` when monitoring is disabled for the stream.
    pub(super) monitor: Option<AucMonitor>,
    /// Stream-local events ingested over the stream's lifetime.
    pub(super) events: u64,
    /// Alarms raised over the stream's lifetime.
    pub(super) alarms: u32,
    /// Fleet-wide tick (total fleet event count) at this stream's most
    /// recent event; drives [`Shard::evict_idle`].
    pub(super) last_seen: u64,
    /// Caller-supplied timestamp (wall clock, epoch seconds, … — any
    /// monotone unit) at this stream's most recent event; drives
    /// [`Shard::evict_older_than`]. `0` until the fleet is ever fed a
    /// timestamp, in which case only tick-based eviction is meaningful.
    pub(super) last_seen_at: u64,
    /// Contribution currently recorded in the owning shard's sketch.
    /// A fresh stream's default stat is inert (`live = false`,
    /// `alarmed = false`), i.e. "nothing recorded".
    pub(super) stat: StreamStat,
}

impl StreamState {
    pub(super) fn new_in(id: u64, cfg: &StreamConfig, ars: &mut EstimatorArenas) -> StreamState {
        StreamState {
            id,
            cfg: *cfg,
            repr: StreamRepr::Live(PooledWindow::new(cfg.window, cfg.estimator.build_in(ars))),
            monitor: cfg.monitor.map(|m| m.build()),
            events: 0,
            alarms: 0,
            last_seen: 0,
            last_seen_at: 0,
            stat: StreamStat::default(),
        }
    }

    /// The stream's current estimate: the live accumulator, or the
    /// frozen form's pinned value (bit-equal by the rehydration
    /// contract). `O(1)` either way.
    pub(super) fn auc(&self) -> f64 {
        match &self.repr {
            StreamRepr::Live(w) => w.auc(),
            StreamRepr::Frozen(f) => f.auc(),
        }
    }

    /// Pairs currently in the window.
    pub(super) fn window_len(&self) -> usize {
        match &self.repr {
            StreamRepr::Live(w) => w.len(),
            StreamRepr::Frozen(f) => f.len(),
        }
    }

    /// True before the stream's first event.
    pub(super) fn is_window_empty(&self) -> bool {
        self.window_len() == 0
    }

    /// True while hibernated (frozen form).
    pub(super) fn is_hibernated(&self) -> bool {
        matches!(self.repr, StreamRepr::Frozen(_))
    }

    /// Window contents, oldest first, identical across both forms.
    pub(super) fn window_entries(&self) -> Vec<(f64, bool)> {
        match &self.repr {
            StreamRepr::Live(w) => w.entries().collect(),
            StreamRepr::Frozen(f) => f.entries().collect(),
        }
    }

    /// Estimator structure size in cells/nodes (see
    /// [`PooledEstimator::footprint`]); frozen streams report the size
    /// the structure had when frozen (= will have again on rehydrate).
    pub(super) fn footprint_cells(&self) -> usize {
        match &self.repr {
            StreamRepr::Live(w) => w.est.footprint(),
            StreamRepr::Frozen(f) => f.footprint_cells(),
        }
    }

    /// Logical bytes of backing storage this stream currently holds:
    /// arena slots + FIFO pairs when live, the contiguous buffers when
    /// frozen. Content-determined in both forms — never allocation
    /// capacity — so the figure is identical across execution
    /// strategies and serves deterministically.
    pub(super) fn footprint_bytes(&self) -> usize {
        match &self.repr {
            StreamRepr::Live(w) => w.footprint_bytes(),
            StreamRepr::Frozen(f) => f.footprint_bytes(),
        }
    }

    /// Return held arena slots to the free lists (evict / reset).
    fn free_storage(&mut self, ars: &mut EstimatorArenas) {
        if let StreamRepr::Live(w) = &mut self.repr {
            w.est.free_in(ars);
        }
    }

    /// Point-in-time snapshot of this stream.
    pub(super) fn snapshot(&self) -> StreamSnapshot {
        StreamSnapshot {
            stream: self.id,
            auc: self.auc(),
            len: self.window_len(),
            compressed_len: self.footprint_cells(),
            footprint_bytes: self.footprint_bytes() as u64,
            events: self.events,
            alarms: self.alarms,
            alarmed: self.monitor.as_ref().map_or(false, AucMonitor::is_alarmed),
            baseline: self.monitor.as_ref().map(AucMonitor::baseline),
        }
    }
}

/// One shard: dense stream slab, id index, local alarm log and the
/// arenas every stream's estimator allocates from. See the module docs
/// for the ownership/determinism/memory rules.
#[derive(Clone, Debug, Default)]
pub(super) struct Shard {
    /// Dense slab of stream states (hot streams stay contiguous).
    streams: Vec<StreamState>,
    /// Stream id → slot in `streams`.
    index: HashMap<u64, u32>,
    /// Shard-local alarm log, merged into the fleet log in shard order.
    alarms: Vec<FleetAlarm>,
    /// Running sufficient stats over the slab (see module docs).
    sketch: ShardSketch,
    /// Pooled node/cell storage shared by every stream in this shard.
    ars: EstimatorArenas,
}

impl Shard {
    /// Number of live streams in this shard.
    pub(super) fn len(&self) -> usize {
        self.streams.len()
    }

    /// The stream slab (slot order: insertion order, perturbed only by
    /// [`Shard::evict_idle`] compaction).
    pub(super) fn streams(&self) -> &[StreamState] {
        &self.streams
    }

    /// Look up a stream by id.
    pub(super) fn get(&self, id: u64) -> Option<&StreamState> {
        self.index.get(&id).map(|&slot| &self.streams[slot as usize])
    }

    /// Slot of `id`, creating the stream on first contact with the
    /// override config if one is registered, the defaults otherwise.
    pub(super) fn ensure_slot(
        &mut self,
        id: u64,
        defaults: &StreamConfig,
        overrides: &HashMap<u64, StreamConfig>,
    ) -> usize {
        if let Some(&slot) = self.index.get(&id) {
            return slot as usize;
        }
        let cfg = overrides.get(&id).copied().unwrap_or(*defaults);
        let slot = self.streams.len();
        self.streams.push(StreamState::new_in(id, &cfg, &mut self.ars));
        self.index.insert(id, slot as u32);
        // Record the fresh stream's stat right away: the live-gated
        // fields are inert (empty window, no alarm), but the sentinel
        // slots it just allocated must enter the sketch's footprint sum
        // so the sketch stays bit-equal to the rescan reference.
        self.refresh_stat(slot);
        slot
    }

    /// Reset a live stream under a new configuration (window contents,
    /// monitor state and counters start fresh). Returns false when the
    /// stream is not live. `now` is the current fleet tick and `at` the
    /// current fleet timestamp, recorded as the reset stream's
    /// `last_seen`/`last_seen_at` so a reconfigure does not make it
    /// instantly eligible for either eviction flavour.
    pub(super) fn reset_stream(&mut self, id: u64, cfg: &StreamConfig, now: u64, at: u64) -> bool {
        match self.index.get(&id) {
            Some(&slot) => {
                let slot = slot as usize;
                // Sketch invalidation: the old state's contribution
                // goes; the fresh state's default stat is inert (empty
                // window, no alarm), so nothing is recorded until the
                // stream's next event refreshes it. The old storage
                // returns to the arena free lists before the new state
                // allocates its own.
                self.sketch.retract(self.streams[slot].stat);
                self.streams[slot].free_storage(&mut self.ars);
                let mut st = StreamState::new_in(id, cfg, &mut self.ars);
                st.last_seen = now;
                st.last_seen_at = at;
                self.streams[slot] = st;
                // Re-record immediately (live-gated fields stay inert;
                // the new sentinels' footprint must not go missing).
                self.refresh_stat(slot);
                true
            }
            None => false,
        }
    }

    /// Re-point one stream's sketch contribution at its current state:
    /// retract what was recorded, record the fresh stat. `O(1)`.
    fn refresh_stat(&mut self, slot: usize) {
        let st = &mut self.streams[slot];
        let fresh = StreamStat::of(st);
        let old = std::mem::replace(&mut st.stat, fresh);
        self.sketch.retract(old);
        self.sketch.record(fresh);
    }

    /// Ingest one event into a resolved slot: window update plus monitor
    /// observation (only on full windows, so partially filled streams
    /// never alarm on warm-up noise). `tick` is the fleet-wide event
    /// number of this event (1-based); `at` is the caller's timestamp
    /// for the batch the event arrived in. A hibernated stream is
    /// transparently rehydrated first.
    pub(super) fn push_slot(&mut self, slot: usize, score: f64, label: bool, tick: u64, at: u64) {
        // Bounded-score declarations are enforced here, naming the
        // stream — before any state mutates (like the finite-score
        // check in `PooledWindow::push`), so a caught panic leaves
        // stream, sketch, FIFO *and hibernation state* exactly as they
        // were. The range comes from the stored config, so a frozen
        // stream rejects without rehydrating. NaN fails the comparison
        // and is rejected by the same message.
        if let EstimatorKind::Binned { lo, hi, .. } = self.streams[slot].cfg.estimator {
            assert!(
                score >= lo && score <= hi,
                "stream {}: score {score} outside declared range [{lo}, {hi}]",
                self.streams[slot].id
            );
        }
        assert!(
            score.is_finite(),
            "stream {}: window scores must be finite, got {score}",
            self.streams[slot].id
        );
        self.thaw_slot(slot);
        let st = &mut self.streams[slot];
        let StreamRepr::Live(win) = &mut st.repr else { unreachable!("thawed above") };
        win.push(&mut self.ars, score, label);
        st.events += 1;
        st.last_seen = tick;
        st.last_seen_at = at;
        if win.is_full() {
            if let Some(m) = st.monitor.as_mut() {
                // O(1): the window's cached accumulator — monitoring no
                // longer pays a compressed-list scan per event.
                let auc = win.auc();
                if m.observe(auc) == MonitorEvent::Alarm {
                    st.alarms += 1;
                    self.alarms.push(FleetAlarm {
                        stream: st.id,
                        stream_event: st.events,
                        auc,
                        baseline: m.baseline(),
                    });
                }
            }
        }
        // Per event, not per batch: `PooledWindow::push` panics before
        // mutating, so even a mid-bucket panic leaves the sketch
        // coherent with exactly the events that landed.
        self.refresh_stat(slot);
    }

    /// Ingest one batch bucket in arrival order, resolving the
    /// stream-id → slot lookup once per run of same-stream events.
    /// Events are stamped with fleet ticks `start_tick + 1, + 2, …` —
    /// the exact ticks the serial shard-by-shard drain would assign,
    /// which is what makes out-of-order parallel draining deterministic
    /// — and with the batch-constant timestamp `at`, which is equally
    /// scheduling-independent.
    pub(super) fn drain_events(
        &mut self,
        events: &[(u64, f64, bool)],
        defaults: &StreamConfig,
        overrides: &HashMap<u64, StreamConfig>,
        start_tick: u64,
        at: u64,
    ) {
        let mut tick = start_tick;
        let mut i = 0;
        while i < events.len() {
            let id = events[i].0;
            let mut j = i + 1;
            while j < events.len() && events[j].0 == id {
                j += 1;
            }
            let slot = self.ensure_slot(id, defaults, overrides);
            for &(_, score, label) in &events[i..j] {
                tick += 1;
                self.push_slot(slot, score, label, tick, at);
            }
            i = j;
        }
    }

    /// Append this shard's pending alarms to `out` (emptying the local
    /// log). Called in shard-index order by the fleet after every
    /// ingestion step, which fixes the fleet-wide alarm order.
    pub(super) fn take_alarms_into(&mut self, out: &mut Vec<FleetAlarm>) {
        out.append(&mut self.alarms);
    }

    /// Drop every stream matching `dead`, compacting the slab via
    /// swap-remove and repairing the index. Returns the number of
    /// evicted streams. Shared engine behind both eviction flavours.
    /// Every arena slot an evicted stream held returns to the free
    /// lists, and storage is reclaimed afterwards
    /// ([`Shard::reclaim_storage`]).
    fn evict_where(&mut self, dead: impl Fn(&StreamState) -> bool) -> usize {
        let mut evicted = 0;
        let mut slot = 0;
        while slot < self.streams.len() {
            if dead(&self.streams[slot]) {
                let mut gone = self.streams.swap_remove(slot);
                gone.free_storage(&mut self.ars);
                self.sketch.retract(gone.stat);
                self.index.remove(&gone.id);
                if let Some(moved) = self.streams.get(slot) {
                    self.index.insert(moved.id, slot as u32);
                }
                evicted += 1;
            } else {
                slot += 1;
            }
        }
        if evicted > 0 {
            self.reclaim_storage();
        }
        evicted
    }

    /// Release arena memory that no live stream can be holding: when no
    /// stream is in live form, every slot has been freed and the arenas
    /// reset (slabs fully released); in any case trailing freed
    /// capacity is trimmed, so eviction/hibernation churn can never
    /// ratchet the free lists (the capacity-regression tests in
    /// `tests/structures.rs` pin this).
    fn reclaim_storage(&mut self) {
        if self.streams.iter().all(|st| !matches!(st.repr, StreamRepr::Live(_))) {
            self.ars.reset();
        }
        self.ars.shrink_to_fit();
    }

    /// Drop streams idle for at least `max_idle` fleet ticks (`now` is
    /// the current fleet tick). Returns the number of evicted streams.
    pub(super) fn evict_idle(&mut self, now: u64, max_idle: u64) -> usize {
        self.evict_where(|st| now.saturating_sub(st.last_seen) >= max_idle)
    }

    /// Drop streams whose last event's timestamp is at least `max_age`
    /// behind `now` (both in the caller's clock units — see
    /// [`StreamState::last_seen_at`]). Returns the number of evicted
    /// streams.
    pub(super) fn evict_older_than(&mut self, now: u64, max_age: u64) -> usize {
        self.evict_where(|st| now.saturating_sub(st.last_seen_at) >= max_age)
    }

    /// Hibernate live-form streams idle for at least `max_idle` fleet
    /// ticks into the compact frozen form — the middle tier between
    /// staying hot and being evicted (`rust/DESIGN.md` §Memory). The
    /// stream stays fully addressable (snapshots, queries, sketch) and
    /// rehydrates bit-identically on its next event. Returns the
    /// number of streams frozen by this call.
    pub(super) fn hibernate_idle(&mut self, now: u64, max_idle: u64) -> usize {
        let mut frozen = 0;
        for slot in 0..self.streams.len() {
            let st = &self.streams[slot];
            if matches!(st.repr, StreamRepr::Live(_))
                && now.saturating_sub(st.last_seen) >= max_idle
            {
                self.freeze_slot(slot);
                frozen += 1;
            }
        }
        if frozen > 0 {
            self.reclaim_storage();
        }
        frozen
    }

    /// Freeze one live-form stream: capture the frozen buffers, free
    /// every arena slot the estimator held, swap the representation.
    /// Observable state (estimate, length, entries, counters, monitor)
    /// is unchanged, so the sketch contribution stays valid as-is.
    fn freeze_slot(&mut self, slot: usize) {
        let st = &mut self.streams[slot];
        let StreamRepr::Live(win) = &mut st.repr else { return };
        let frozen = FrozenStream::freeze(win, &st.cfg, &self.ars);
        win.est.free_in(&mut self.ars);
        st.repr = StreamRepr::Frozen(Box::new(frozen));
        // The estimate is unchanged but the footprint shrank — re-point
        // the sketch contribution at the frozen cost.
        self.refresh_stat(slot);
    }

    /// Rehydrate one hibernated stream back to live form. Asserts the
    /// bit-identity contract: the rebuilt estimator must reproduce the
    /// frozen estimate exactly (`fleet/frozen.rs` explains why it
    /// always does).
    fn thaw_slot(&mut self, slot: usize) {
        let st = &mut self.streams[slot];
        let win = match &st.repr {
            StreamRepr::Frozen(f) => f.thaw(&mut self.ars),
            StreamRepr::Live(_) => return,
        };
        assert_eq!(
            win.auc().to_bits(),
            st.auc().to_bits(),
            "stream {}: rehydration changed the estimate",
            st.id
        );
        st.repr = StreamRepr::Live(win);
        // Back to live-form cost in the sketch's footprint sum.
        self.refresh_stat(slot);
    }

    /// Streams currently hibernated in this shard.
    pub(super) fn hibernated(&self) -> usize {
        self.streams.iter().filter(|st| st.is_hibernated()).count()
    }

    // ---- read-only visitor methods (run shard-parallel by the typed
    // job layer; each returns owned data merged in shard-index order) --

    /// Snapshot every stream in slab order.
    pub(super) fn snapshots(&self) -> Vec<StreamSnapshot> {
        self.streams.iter().map(StreamState::snapshot).collect()
    }

    /// Aggregate partial: the windowed AUC of every live (non-empty)
    /// stream in slab order, the currently-alarmed count, the total
    /// stream count, and the summed logical footprint in bytes. This
    /// is the **rescan reference** behind `AucFleet::aggregate_rescan`
    /// — it deliberately reads each stream's state directly (not the
    /// cached stats), so tests comparing it against the sketch-backed
    /// path prove the running sketch never drifts.
    pub(super) fn aggregate_partial(&self) -> (Vec<f64>, usize, usize, u64) {
        let mut aucs = Vec::with_capacity(self.streams.len());
        let mut alarmed = 0usize;
        let mut footprint = 0u64;
        for st in &self.streams {
            if !st.is_window_empty() {
                aucs.push(st.auc());
            }
            if st.monitor.as_ref().map_or(false, AucMonitor::is_alarmed) {
                alarmed += 1;
            }
            footprint += st.footprint_bytes() as u64;
        }
        (aucs, alarmed, self.streams.len(), footprint)
    }

    /// Summed logical footprint of this shard's streams in bytes
    /// (arena slots + FIFOs for live form, contiguous buffers for
    /// frozen form). Logical — live counts × slot sizes, not arena
    /// capacity — so it is execution-strategy-independent; the memory
    /// benchmark (`benches/fleet.rs` `mem`) compares it against
    /// process RSS.
    pub(super) fn footprint_bytes(&self) -> u64 {
        self.streams.iter().map(|st| st.footprint_bytes() as u64).sum()
    }

    /// The running sufficient stats over this shard's streams.
    pub(super) fn sketch(&self) -> &ShardSketch {
        &self.sketch
    }

    /// This shard's `k` worst live streams by [`worst_first`] order,
    /// snapshotted — considering only streams whose sketch bin is in
    /// `mask` (the fleet computes the smallest bin prefix holding ≥ k
    /// live streams from the merged sketches, so everything outside
    /// the mask is provably not in the global top-k; pass `!0` to rank
    /// the whole shard). Ranks lightweight `(auc, id, slot)` triples
    /// off the cached stats and snapshots only the `k` winners.
    pub(super) fn top_k_worst(&self, k: usize, mask: u64) -> Vec<StreamSnapshot> {
        let mut ranked: Vec<(f64, u64, usize)> = self
            .streams
            .iter()
            .enumerate()
            .filter(|(_, st)| st.stat.live && mask & (1u64 << st.stat.bin) != 0)
            .map(|(slot, st)| (st.stat.auc, st.id, slot))
            .collect();
        ranked.sort_by(|a, b| worst_first((a.0, a.1), (b.0, b.1)));
        ranked.truncate(k);
        ranked.into_iter().map(|(_, _, slot)| self.streams[slot].snapshot()).collect()
    }

    /// Live streams in sketch bin `bin` with AUC strictly below `t` —
    /// the boundary-bin refinement of the sketch-backed `count_below`
    /// (bins fully below the threshold are counted from the sketch
    /// alone; only the bin containing the threshold needs values).
    pub(super) fn count_below_in_bin(&self, bin: u8, t: f64) -> usize {
        self.streams
            .iter()
            .filter(|st| st.stat.live && st.stat.bin == bin && st.stat.auc < t)
            .count()
    }

    /// The live streams whose sketch bin is in `mask`, as
    /// `(bin, auc)` pairs in slab order — the quantile/min/max
    /// refinement partial behind the sketch-backed `aggregate()`.
    pub(super) fn bin_values(&self, mask: u64) -> Vec<(u8, f64)> {
        self.streams
            .iter()
            .filter(|st| st.stat.live && mask & (1u64 << st.stat.bin) != 0)
            .map(|st| (st.stat.bin, st.stat.auc))
            .collect()
    }

    /// Histogram partial over `[0, 1]` split into `bins` equal-width
    /// buckets (AUC 1.0 lands in the last). Returns the per-bin counts
    /// and the number of live streams counted. This is the fallback
    /// for bin counts that do not divide [`SKETCH_BINS`] (divisor
    /// counts are answered from the sketch with no stream scan); it
    /// reads the cached per-stream stats, so it is `O(streams)` with
    /// no estimator work.
    pub(super) fn histogram(&self, bins: usize) -> (Vec<usize>, usize) {
        let mut counts = vec![0usize; bins];
        let mut live = 0usize;
        for st in &self.streams {
            if !st.stat.live {
                continue;
            }
            let bin = ((st.stat.auc * bins as f64) as usize).min(bins - 1);
            counts[bin] += 1;
            live += 1;
        }
        (counts, live)
    }

    /// Score-distribution partial over `[0, 1]` split into `bins`
    /// equal-width cells (out-of-range scores clamp into the edge
    /// cells): per-cell window-entry counts plus the number of entries
    /// counted, summed over every stream in the shard.
    ///
    /// Binned streams declared exactly over `[0, 1]` with a cell count
    /// divisible by `bins` contribute **directly from their count
    /// arrays** — an `O(stream_bins)` group-sum with no window rescan:
    /// the stream's finer cell index refines the query's
    /// (`⌊⌊t·gb⌋/g⌋ = ⌊t·b⌋`), so grouping reports exactly where the
    /// estimator itself holds each score. With power-of-two cell
    /// counts the float products are exact and this is bit-identical
    /// to the FIFO rescan (the cross-check in `fleet/query.rs` tests);
    /// in general it is the estimator's own quantized view. A
    /// *hibernated* binned stream has no count arrays, so its stored
    /// scores go through the **same stream-cell map** before grouping —
    /// reproducing the live fast path's answer exactly, which keeps
    /// hibernation invisible to query results. Everything else falls
    /// back to one pass over the window entries.
    pub(super) fn score_histogram(&self, bins: usize) -> (Vec<u64>, u64) {
        let mut counts = vec![0u64; bins];
        let mut entries = 0u64;
        for st in &self.streams {
            match &st.repr {
                StreamRepr::Live(w) => match &w.est {
                    PooledEstimator::Binned(e)
                        if e.range() == (0.0, 1.0) && e.bins() % bins == 0 =>
                    {
                        let group = e.bins() / bins;
                        for (i, (p, n)) in e.cells().enumerate() {
                            let c = u64::from(p) + u64::from(n);
                            counts[i / group] += c;
                            entries += c;
                        }
                    }
                    _ => {
                        for (score, _) in w.entries() {
                            // `as usize` saturates: negative scores land
                            // in cell 0, the `.min` clamps `score ≥ 1`.
                            let cell = ((score * bins as f64) as usize).min(bins - 1);
                            counts[cell] += 1;
                            entries += 1;
                        }
                    }
                },
                StreamRepr::Frozen(f) => match st.cfg.estimator {
                    EstimatorKind::Binned { bins: sb, lo, hi }
                        if lo == 0.0 && hi == 1.0 && sb % bins == 0 =>
                    {
                        // Per-score stream cell grouped down to the
                        // query's bins — the same map as
                        // `BinnedAuc::bin_of` over [0, 1], so the
                        // answer is exactly what the live fast path
                        // reports for the same contents.
                        let group = sb / bins;
                        for (score, _) in f.entries() {
                            let cell = ((score * sb as f64) as usize).min(sb - 1);
                            counts[cell / group] += 1;
                            entries += 1;
                        }
                    }
                    _ => {
                        for (score, _) in f.entries() {
                            let cell = ((score * bins as f64) as usize).min(bins - 1);
                            counts[cell] += 1;
                            entries += 1;
                        }
                    }
                },
            }
        }
        (counts, entries)
    }

    /// Test support: rebuild the sketch from scratch and assert the
    /// running one matches bit-for-bit, and that every cached stat
    /// matches its stream's actual state. `O(streams)`.
    pub(super) fn verify_sketch(&self) {
        let mut rebuilt = ShardSketch::default();
        for st in &self.streams {
            let fresh = StreamStat::of(st);
            assert_eq!(st.stat.live, fresh.live, "stale live flag on stream {}", st.id);
            assert_eq!(st.stat.alarmed, fresh.alarmed, "stale alarm flag on stream {}", st.id);
            assert_eq!(st.stat.footprint, fresh.footprint, "stale footprint on stream {}", st.id);
            if st.stat.live {
                assert_eq!(
                    st.stat.auc.to_bits(),
                    fresh.auc.to_bits(),
                    "stale cached AUC on stream {}",
                    st.id
                );
                assert_eq!(st.stat.bin, fresh.bin, "stale bin on stream {}", st.id);
                assert_eq!(st.stat.qauc, fresh.qauc, "stale qauc on stream {}", st.id);
            }
            rebuilt.record(fresh);
        }
        assert_eq!(self.sketch, rebuilt, "running shard sketch drifted from rebuild");
    }
}

// Shards cross thread boundaries (pool workers lock and drain them);
// this compiles only while every constituent (arenas, estimator cores,
// frozen buffers, window FIFO, monitor) stays free of `Rc`/interior
// mutability.
const _: () = {
    const fn assert_send<T: Send>() {}
    assert_send::<StreamState>();
    assert_send::<Shard>();
};
