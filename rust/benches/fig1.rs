//! Bench target regenerating Figure 1: average and maximum relative
//! error as a function of ε (window k = 1000, 3 datasets).
//!
//! `cargo bench --bench fig1 [-- --events N --window K]`
//!
//! Expected shape (paper §6): every max ≤ ε/2; averages typically far
//! below the guarantee; both grow with ε.

use streamauc::experiments::{fig1, ExpConfig};

fn main() {
    let mut cfg = ExpConfig { events: 30_000, ..Default::default() };
    let args: Vec<String> = std::env::args().collect();
    if let Some(i) = args.iter().position(|a| a == "--events") {
        cfg.events = args[i + 1].parse().expect("--events N");
    }
    if let Some(i) = args.iter().position(|a| a == "--window") {
        cfg.window = args[i + 1].parse().expect("--window K");
    }
    println!("{}", fig1::run(cfg).render());
}
