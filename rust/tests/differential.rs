//! Differential property suite: every estimator against the sort-based
//! naive oracle over random operation sequences.
//!
//! Driven by the in-repo harness (`testing::check` / `testing::gen_ops`):
//! each property runs ≥100 deterministic seeded cases; on violation the
//! harness panics with the failing case's replay seed
//! (`property failed (case N, replay seed 0x…)`), so any failure here is
//! reproducible with `Pcg::seed(<printed seed>)`.
//!
//! Covered contracts:
//! * Proposition 1: `|ApproxAuc − NaiveAuc| ≤ ε·auc/2` (and therefore
//!   `≤ ε/2`) after **every** operation, for ε ∈ {0.5, 0.1, 0.01}, in
//!   both the duplicate-score grid regime (the paper pseudo-code's
//!   subtlest case) and the continuum regime;
//! * `ExactAuc == NaiveAuc` exactly (identical doubled-integer
//!   arithmetic ⇒ bit-equal results);
//! * `MaintainedExactAuc == ExactAuc == NaiveAuc` **bit-wise** after
//!   every operation: the delta-maintained doubled-area accumulator is
//!   indistinguishable from both the Eq. 1 tree scan and the sort-based
//!   oracle, in the duplicate-score grid regime and the continuum
//!   regime alike;
//! * `FlippedAuc` mirror guarantee `|est − auc| ≤ (1 − auc)·ε/2`;
//! * `BinnedAuc == NaiveAuc` **bit-wise** after every operation when
//!   scores live on a power-of-two grid the bin count is aligned with
//!   (quantization is injective there), with the running doubled-area
//!   accumulator bit-equal to its own from-scratch scan;
//! * off the aligned grid, `|BinnedAuc − NaiveAuc| ≤ error_bound()` —
//!   half the same-bin positive–negative pair fraction — after every
//!   operation, and the fleet auto-selection rule `bins = ⌈2/ε⌉`
//!   ([`StreamConfig::auto`]) lands both the bound and the realized
//!   error under `ε/2` on dense uniform windows, for every paper ε;
//! * hibernate/rehydrate bit-identity: a stream frozen into the compact
//!   cold form and thawed by its next push reads the same `auc()` bits
//!   after every event as one that never hibernated, for every
//!   estimator kind in both regimes (`fleet/frozen.rs`).

use streamauc::coordinator::{
    ApproxAuc, AucEstimator, BinnedAuc, ExactAuc, FlippedAuc, MaintainedExactAuc, NaiveAuc,
};
use streamauc::fleet::{AucFleet, EstimatorKind, FleetConfig, StreamConfig};
use streamauc::testing::{check, gen_ops, Op};

const CASES: u64 = 100;
const EPSILONS: [f64; 3] = [0.5, 0.1, 0.01];

fn apply(est: &mut impl AucEstimator, op: Op) {
    match op {
        Op::Insert { score, pos } => est.insert(score, pos),
        Op::Remove { score, pos } => est.remove(score, pos),
    }
}

/// Drive `approx` and the naive oracle through one random op sequence,
/// asserting the Proposition 1 bound after every operation.
fn assert_tracks_naive(eps: f64, ops: &[Op]) {
    let mut approx = ApproxAuc::new(eps);
    let mut naive = NaiveAuc::new();
    for (i, &op) in ops.iter().enumerate() {
        apply(&mut approx, op);
        apply(&mut naive, op);
        let truth = naive.auc();
        let est = approx.auc();
        // The relative form of Proposition 1…
        assert!(
            (est - truth).abs() <= eps * truth / 2.0 + 1e-12,
            "op {i}: |{est} − {truth}| > ε·auc/2 (ε = {eps})"
        );
        // …which implies the absolute ε/2 cap (auc ≤ 1).
        assert!(
            (est - truth).abs() <= eps / 2.0 + 1e-12,
            "op {i}: |{est} − {truth}| > ε/2 (ε = {eps})"
        );
    }
    assert_eq!(approx.len(), naive.len());
}

#[test]
fn approx_tracks_naive_duplicate_score_grid() {
    for (k, &eps) in EPSILONS.iter().enumerate() {
        check(0xD1FF_0000 ^ k as u64, CASES, |rng| {
            // Coarse grids force many same-score tree nodes.
            let grid = 3 + rng.below(29);
            let ops = gen_ops(rng, 250, 60, Some(grid));
            assert_tracks_naive(eps, &ops);
        });
    }
}

#[test]
fn approx_tracks_naive_continuum_scores() {
    for (k, &eps) in EPSILONS.iter().enumerate() {
        check(0xC047_0000 ^ k as u64, CASES, |rng| {
            let ops = gen_ops(rng, 250, 60, None);
            assert_tracks_naive(eps, &ops);
        });
    }
}

#[test]
fn approx_epsilon_zero_is_bit_exact() {
    check(0xE0AC, CASES, |rng| {
        let grid = if rng.chance(0.5) { Some(2 + rng.below(14)) } else { None };
        let ops = gen_ops(rng, 200, 50, grid);
        let mut approx = ApproxAuc::new(0.0);
        let mut naive = NaiveAuc::new();
        for &op in &ops {
            apply(&mut approx, op);
            apply(&mut naive, op);
            let (a, b) = (approx.auc(), naive.auc());
            assert!((a - b).abs() < 1e-12, "ε = 0 drifted: {a} vs {b}");
        }
    });
}

/// The O(1)-read contract: the running doubled-area accumulator equals
/// the retained from-scratch Algorithm 4 scan — **integer
/// bit-equality**, not closeness — after *every* operation, across
/// seeded insert/remove traces in both the duplicate-score grid regime
/// (merge/regroup-heavy: every `AddNext`/`Compress` shape fires) and
/// the continuum regime, for every paper ε. This is what makes the
/// incremental `auc()` indistinguishable from the paper's scan to all
/// downstream consumers (fleet digests included).
#[test]
fn incremental_a2_is_bit_exact_after_every_op() {
    for (k, &eps) in EPSILONS.iter().enumerate() {
        check(0xA2A2_0000 ^ k as u64, CASES, |rng| {
            let grid = if rng.chance(0.5) { Some(3 + rng.below(29)) } else { None };
            let ops = gen_ops(rng, 250, 60, grid);
            let mut approx = ApproxAuc::new(eps);
            for (i, &op) in ops.iter().enumerate() {
                apply(&mut approx, op);
                assert_eq!(
                    approx.doubled_area(),
                    approx.doubled_area_scan(),
                    "running a2 drifted from the scan at op {i} (ε = {eps})"
                );
                let (cached, scanned) = (approx.auc(), approx.auc_full_scan());
                assert_eq!(
                    cached.to_bits(),
                    scanned.to_bits(),
                    "cached read {cached} != scan read {scanned} at op {i}"
                );
            }
        });
    }
}

#[test]
fn exact_equals_naive_exactly() {
    check(0xE4C7, CASES, |rng| {
        // Alternate between the duplicate-heavy and continuum regimes.
        let grid = if rng.chance(0.5) { Some(2 + rng.below(30)) } else { None };
        let ops = gen_ops(rng, 250, 60, grid);
        let mut exact = ExactAuc::new();
        let mut naive = NaiveAuc::new();
        for (i, &op) in ops.iter().enumerate() {
            apply(&mut exact, op);
            apply(&mut naive, op);
            // Same grouping, same doubled-integer terms, same final
            // division ⇒ the values must be *identical*, not just close.
            let (a, b) = (exact.auc(), naive.auc());
            assert!(
                a == b,
                "op {i}: exact {a} != naive {b} (bits {:#x} vs {:#x})",
                a.to_bits(),
                b.to_bits()
            );
        }
        assert_eq!(exact.len(), naive.len());
    });
}

/// Drive the three exact implementations through one op sequence,
/// asserting three-way bit-equality after every operation.
fn assert_maintained_is_bit_exact(ops: &[Op]) {
    let mut maintained = MaintainedExactAuc::new();
    let mut exact = ExactAuc::new();
    let mut naive = NaiveAuc::new();
    for (i, &op) in ops.iter().enumerate() {
        apply(&mut maintained, op);
        apply(&mut exact, op);
        apply(&mut naive, op);
        // The O(1)-read contract: the delta-maintained accumulator
        // equals the retained Eq. 1 scan in *integer* arithmetic…
        assert_eq!(
            maintained.doubled_area(),
            maintained.doubled_area_scan(),
            "maintained a2 drifted from its own scan at op {i}"
        );
        // …so all three reads must be identical to the bit, not close.
        let (m, e, n) = (maintained.auc(), exact.auc(), naive.auc());
        assert_eq!(
            m.to_bits(),
            e.to_bits(),
            "op {i}: maintained {m} != exact scan {e}"
        );
        assert_eq!(e.to_bits(), n.to_bits(), "op {i}: exact {e} != naive {n}");
        assert_eq!(maintained.len(), naive.len());
    }
}

#[test]
fn maintained_exact_is_bit_exact_duplicate_score_grid() {
    check(0x3E4A_C7D0, CASES, |rng| {
        // Coarse grids force heavy same-score grouping: the `at_s`
        // terms of every delta shape fire constantly.
        let grid = 2 + rng.below(30);
        let ops = gen_ops(rng, 250, 60, Some(grid));
        assert_maintained_is_bit_exact(&ops);
    });
}

#[test]
fn maintained_exact_is_bit_exact_continuum_scores() {
    check(0x3E4A_C7D1, CASES, |rng| {
        let ops = gen_ops(rng, 250, 60, None);
        assert_maintained_is_bit_exact(&ops);
    });
}

/// On a power-of-two score grid whose point count divides the bin
/// count, quantization is injective: every grid point owns its own
/// bin, the binned group structure equals the exact group structure,
/// and the trapezoidal read runs the same doubled-integer arithmetic
/// as the oracle — so the values must be *identical*, not just close.
/// (Power-of-two is what makes `score · bins` exact in f64; see the
/// `coordinator::binned` module docs.)
#[test]
fn binned_is_bit_exact_on_aligned_power_of_two_grids() {
    check(0xB1_4E4D, CASES, |rng| {
        let grid = 1u64 << (2 + rng.below(4)); // 4, 8, 16 or 32 levels
        let bins = (grid as usize) << rng.below(3); // ×1, ×2 or ×4 cells
        let ops = gen_ops(rng, 250, 60, Some(grid));
        let mut binned = BinnedAuc::new(bins, 0.0, 1.0);
        let mut naive = NaiveAuc::new();
        for (i, &op) in ops.iter().enumerate() {
            apply(&mut binned, op);
            apply(&mut naive, op);
            assert_eq!(
                binned.doubled_area(),
                binned.doubled_area_scan(),
                "binned a2 drifted from its own scan at op {i}"
            );
            let (b, n) = (binned.auc(), naive.auc());
            assert_eq!(
                b.to_bits(),
                n.to_bits(),
                "op {i}: binned {b} != naive {n} (grid {grid}, {bins} bins)"
            );
        }
        assert_eq!(binned.len(), naive.len());
    });
}

/// Off the aligned grid the binned estimate may differ from the truth,
/// but never by more than `error_bound()`: pairs split across bins keep
/// their order under the monotone quantization, so only same-bin
/// positive–negative pairs (each off by ≤ ½) can contribute. The bound
/// must hold after **every** operation, in the duplicate-score grid
/// regime and the continuum regime alike, for arbitrary bin counts.
#[test]
fn binned_error_stays_within_the_same_bin_collision_bound() {
    check(0xB1_B0D4, CASES, |rng| {
        // Coarse non-aligned grids and the continuum both exercise
        // bins that hold several distinct scores.
        let grid = if rng.chance(0.5) { Some(3 + rng.below(29)) } else { None };
        let bins = 8 + rng.below(120) as usize;
        let ops = gen_ops(rng, 250, 60, grid);
        let mut binned = BinnedAuc::new(bins, 0.0, 1.0);
        let mut naive = NaiveAuc::new();
        for (i, &op) in ops.iter().enumerate() {
            apply(&mut binned, op);
            apply(&mut naive, op);
            assert_eq!(
                binned.doubled_area(),
                binned.doubled_area_scan(),
                "binned a2 drifted from its own scan at op {i}"
            );
            let (b, n) = (binned.auc(), naive.auc());
            let bound = binned.error_bound();
            assert!(
                (b - n).abs() <= bound + 1e-12,
                "op {i}: |{b} − {n}| > same-bin bound {bound} ({bins} bins, grid {grid:?})"
            );
        }
    });
}

/// The fleet auto-selection rule (`bins = ⌈2/ε⌉` when a bounded range
/// is declared) must actually deliver the `ε/2` target it is derived
/// from: on dense uniform windows the same-bin pair fraction
/// concentrates near `1/bins`, so `error_bound()` lands near `ε/4` —
/// comfortably under the `ε/2` the approx sketch would spend `O(k)`
/// memory to guarantee — and the realized error sits under the bound.
/// The bin count is read back from [`StreamConfig::auto`] itself, so
/// this test pins the shipped rule, not a re-derivation.
#[test]
fn auto_selected_bins_meet_the_half_epsilon_target() {
    for (k, &eps) in EPSILONS.iter().enumerate() {
        let cfg = StreamConfig::auto(2000, eps, Some((0.0, 1.0)));
        let EstimatorKind::Binned { bins, lo, hi } = cfg.estimator else {
            panic!("auto must pick the binned kind for ε = {eps} with a declared range");
        };
        assert_eq!(bins, (2.0 / eps).ceil() as usize, "auto bin rule changed");
        check(0xB1_E45 ^ k as u64, CASES, |rng| {
            let mut binned = BinnedAuc::new(bins, lo, hi);
            let mut naive = NaiveAuc::new();
            for _ in 0..2000 {
                let (score, pos) = (rng.uniform(), rng.chance(0.5));
                binned.insert(score, pos);
                naive.insert(score, pos);
            }
            let (b, n) = (binned.auc(), naive.auc());
            let bound = binned.error_bound();
            assert!(
                (b - n).abs() <= bound + 1e-12,
                "|{b} − {n}| > same-bin bound {bound} (ε = {eps}, {bins} bins)"
            );
            assert!(
                bound <= eps / 2.0 + 1e-12,
                "derived bound {bound} > ε/2 on a dense uniform window (ε = {eps}, {bins} bins)"
            );
        });
    }
}

#[test]
fn flipped_guarantee_against_naive() {
    for (k, &eps) in EPSILONS.iter().enumerate() {
        check(0xF11_0000 ^ k as u64, CASES, |rng| {
            let ops = gen_ops(rng, 200, 50, None);
            let mut flipped = FlippedAuc::new(eps);
            let mut naive = NaiveAuc::new();
            for &op in &ops {
                apply(&mut flipped, op);
                apply(&mut naive, op);
            }
            let truth = naive.auc();
            let est = flipped.auc();
            let tol = (1.0 - truth) * eps / 2.0 + 1e-12;
            assert!(
                (est - truth).abs() <= tol,
                "flipped: |{est} − {truth}| > (1 − auc)·ε/2 (ε = {eps})"
            );
        });
    }
}

/// Hibernate/rehydrate bit-identity (`fleet/frozen.rs`): for every
/// estimator kind and both score regimes, a single-stream fleet that
/// freezes at random points along a windowed trace — thawed
/// transparently by the next push — reads the same `auc()` bits after
/// every event as a twin that never hibernates. `Shard::thaw_slot`
/// additionally asserts the rebuilt estimator reproduces the frozen
/// estimate's bits, so every `hibernate_idle(0)` here also arms that
/// internal check for the very next push.
#[test]
fn hibernation_is_bit_identical_for_every_estimator_kind() {
    let kinds = [
        EstimatorKind::Approx { epsilon: 0.1 },
        EstimatorKind::Approx { epsilon: 0.01 },
        EstimatorKind::ExactMaintained,
        EstimatorKind::Binned { bins: 64, lo: 0.0, hi: 1.0 },
    ];
    for (j, kind) in kinds.into_iter().enumerate() {
        check(0xF07E_0000 ^ j as u64, 25, |rng| {
            // Duplicate-grid and continuum regimes alike; grids are
            // power-of-two so exact score arithmetic is preserved.
            let grid = if rng.chance(0.5) { Some(1u64 << (2 + rng.below(4))) } else { None };
            let window = 40 + rng.below(60) as usize;
            let defaults = StreamConfig { window, estimator: kind, monitor: None };
            let mk = || {
                AucFleet::new(FleetConfig {
                    shards: 4,
                    workers: 1,
                    pool: false,
                    pipeline: false,
                    adaptive: false,
                    stream_defaults: defaults,
                })
            };
            let (mut hib, mut twin) = (mk(), mk());
            for i in 0..3 * window {
                let score = match grid {
                    Some(g) => rng.below(g) as f64 / g as f64,
                    None => rng.uniform(),
                };
                let pos = rng.chance(0.5);
                hib.push(7, score, pos);
                twin.push(7, score, pos);
                if rng.chance(0.08) {
                    assert_eq!(hib.hibernate_idle(0), 1, "the lone stream must freeze");
                    assert!(hib.is_hibernated(7));
                }
                // Reads agree to the bit after every event, whether the
                // stream is live, frozen (pinned estimate), or was just
                // rehydrated by this push.
                assert_eq!(
                    hib.auc(7).map(f64::to_bits),
                    twin.auc(7).map(f64::to_bits),
                    "estimate bits diverged at event {i} ({kind:?})"
                );
                assert_eq!(hib.stream_len(7), twin.stream_len(7));
            }
            // One final push thaws a still-frozen survivor; the fleets
            // must then be indistinguishable wholesale — live logical
            // footprints included, because they are content-determined.
            hib.push(7, 0.5, true);
            twin.push(7, 0.5, true);
            assert!(!hib.is_hibernated(7));
            hib.verify_sketches();
            assert_eq!(hib.snapshot(), twin.snapshot());
        });
    }
}

/// The harness itself must report a replayable seed — the contract the
/// suite's debuggability rests on.
#[test]
fn violations_report_a_replay_seed() {
    let result = std::panic::catch_unwind(|| {
        check(0xBAD, CASES, |rng| {
            let ops = gen_ops(rng, 50, 20, Some(4));
            // Impossible "guarantee": est must equal naive with ε = 0.5.
            let mut approx = ApproxAuc::new(0.5);
            let mut naive = NaiveAuc::new();
            for &op in &ops {
                apply(&mut approx, op);
                apply(&mut naive, op);
            }
            assert!((approx.auc() - naive.auc()).abs() < 1e-15, "intentional");
        });
    });
    let err = result.expect_err("the intentional violation must fire");
    let msg = err
        .downcast_ref::<String>()
        .cloned()
        .unwrap_or_else(|| "<non-string>".to_string());
    assert!(msg.contains("replay seed"), "no replay seed in: {msg}");
}
