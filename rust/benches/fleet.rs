//! Fleet throughput: ingestion versus stream count across execution
//! strategies, plus — since the typed-job engine — the **read paths**
//! (aggregate, queries, snapshot) serial versus pooled, and the
//! adaptive small-batch crossover.
//!
//! `cargo bench --bench fleet [-- --events N] [-- --workers W]`
//!
//! Ingestion rows stream the same pre-generated bursty event soup into
//! a fresh fleet seven ways:
//!
//! * `one-at-a-time` — `push` per event: full dispatch (stream-id hash
//!   + shard index probe) on every update;
//! * `batched` — `push_batch` in chunks: per-shard bucketing with the
//!   stream lookup amortized over same-stream runs, serial drain;
//! * `scoped ∥` — ditto, shards drained by `--workers` scoped threads
//!   spawned (and joined) on every batch;
//! * `pooled ∥` — ditto, drained by the persistent pool: workers spawn
//!   once, park between batches, and steal shards largest-bucket-first;
//! * `piped ∥` — pooled plus cross-batch pipelining: the next batch is
//!   bucketed while the previous one drains;
//! * `monitor` / `mon ∥` — batched serial / pooled with the per-stream
//!   drift monitor on (one AUC read per update — the full service
//!   configuration; since the incremental-`a2` work that read is
//!   `O(1)`, so monitoring is nearly free).
//!
//! Two **incremental-read speedup** experiments ride along
//! (`DESIGN.md` §Incremental-reads):
//!
//! * `monitored_cached` vs `monitored_scan` — the same per-stream
//!   window + monitor stack fed by the `O(1)` cached read versus the
//!   retained `O(|C|)` full-scan read (what every monitored event paid
//!   before the running accumulator); `speedup_monitor_read` is their
//!   ratio.
//! * `aggregate()` vs `aggregate_rescan()` — the sketch-backed
//!   aggregate (merge shard sufficient stats + candidate-bin
//!   refinement) versus the retained full per-stream rescan, asserted
//!   bit-identical first; `speedup_aggregate_sketch` is their ratio.
//!
//! A **mixed-estimator** ingest pair rides along: the same soup into a
//! fleet whose every 4th stream is overridden to the tree-maintained
//! exact estimator (`EstimatorKind::ExactMaintained`) while the rest
//! keep the ε-sketch — serial vs pooled, asserted bit-identical first
//! (`mixed_serial` / `mixed_pooled` in the JSON) — so the cost of
//! mixing exactness-critical streams into a fleet is tracked per PR.
//! A **three-way** pair (`binned_serial` / `binned_pooled`) does the
//! same with binned streams in the mix: every 4th stream
//! exact-maintained, the next offset on the binned bounded-score fast
//! path (`bins = ⌈2/ε⌉` over the sigmoid scores' declared `[0, 1]`),
//! the rest on the ε-sketch.
//!
//! Read rows then time, on the already-ingested serial and pooled
//! fleets, calls/sec of `aggregate()`, the query suite
//! (`top_k_worst(10)` + `count_below(0.5)` + `auc_histogram(16)`) and
//! `snapshot()` — all of which now execute as typed jobs on the
//! persistent pool when `pool = true`. The small-batch row ingests the
//! soup in 64-event batches with a fixed worker count versus
//! `FleetConfig::adaptive`, which drains trickle batches inline — the
//! crossover the adaptive satellite exists for.
//!
//! A **served-reads** measurement rides the same data over the wire
//! (`rust/src/serve`): a pre-ingested pooled fleet goes behind a
//! loopback `FleetServer` while a background thread keeps feeding
//! 64-event batches through it. `serve_qps` counts keep-alive HTTP
//! `/aggregate` round-trips per second — the snapshot-read path,
//! answered from the epoch-swapped `PublishedView` with zero
//! fleet-lock acquisitions — and `serve_qps_locked` counts
//! `/score_histogram?bins=10` round-trips, the one endpoint that must
//! take the fleet lock per request; their ratio (`speedup_serve_view`)
//! is what the publish layer buys under concurrent write load. The
//! 1-stream row skips the server and reports 0 — one stream is not a
//! serving scenario. A separate **fan-out** section attaches
//! [`FANOUT_SUBS`] binary subscribers to one server, publishes
//! [`FANOUT_ROUNDS`] sketch deltas through it, and reports delivered
//! push frames per second across all subscribers (lag resyncs — a
//! coalesced notice + fresh baseline — count as the frames actually
//! written); every subscriber is asserted to land on the final
//! publication seq.
//!
//! A **mem** section measures the million-stream memory story
//! (`rust/DESIGN.md` §Memory): for each stream count it fills a fleet
//! (window [`MEM_WINDOW`], ~[`MEM_FILL`] events/stream), reads the
//! logical footprint from the shard sketches and the process RSS from
//! `/proc/self/status` (`VmRSS`), hibernates every stream
//! (`hibernate_idle(0)` — arenas reset outright once a shard holds no
//! live-form stream), re-reads both, and times transparent
//! rehydration by pushing one event into a sample of frozen streams.
//! Per-stream byte budgets are **asserted**, not just reported: live ≤
//! [`LIVE_BUDGET_BYTES`], hibernated ≤ [`HIB_BUDGET_BYTES`], and the
//! hibernated form ≤ ⅓ of live. The default run tops out at 100k
//! streams so smoke stays fast; `-- --streams 1000000` produces the
//! million-stream row.
//!
//! Besides the human-readable tables, the run writes machine-readable
//! `BENCH_fleet.json` at the repository root (events/sec or calls/sec
//! per scenario per stream count, plus parallel speedups and the `mem`
//! rows) so the perf trajectory is tracked across PRs.
//!
//! Expected shape: batched ≥ one-at-a-time everywhere; pooled ≥ scoped
//! at small batches (no spawn/join per batch) and under skew (stealing
//! instead of fixed chunks); pooled reads ≥ serial reads at 10k
//! streams (shard-parallel collection) and ≈ serial at 1 stream;
//! adaptive ≥ fixed-worker ingestion at 64-event batches. Every
//! parallel fleet and every pooled read is asserted bit-identical to
//! its serial twin before timings are reported — the bench doubles as
//! a determinism smoke test.

use std::fmt::Write as _;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use streamauc::coordinator::window::Window;
use streamauc::coordinator::{ApproxAuc, AucMonitor};
use streamauc::fleet::{AucFleet, FleetConfig, StreamConfig};
use streamauc::serve::{BinClient, FleetServer, HttpClient, ServeLimits, SubEvent};
use streamauc::stream::MultiStream;

const WINDOW: usize = 100;
const EPSILON: f64 = 0.1;
const BATCH: usize = 8192;
const SMALL_BATCH: usize = 64;
const SHARDS: usize = 64;

/// Window capacity for the `mem` section — small enough that the
/// million-stream row fits a dev box, large enough that the live form
/// carries real tree/list structure per stream.
const MEM_WINDOW: usize = 32;
/// Events per stream the `mem` section ingests (windows ~half full).
const MEM_FILL: usize = 16;
/// Asserted ceiling on logical bytes per live stream at `MEM_WINDOW`.
const LIVE_BUDGET_BYTES: f64 = 6144.0;
/// Asserted ceiling on logical bytes per hibernated stream.
const HIB_BUDGET_BYTES: f64 = 768.0;

/// Binary subscribers attached in the fan-out section.
const FANOUT_SUBS: usize = 256;
/// Sketch publications driven through the fan-out server.
const FANOUT_ROUNDS: usize = 200;

struct Row {
    streams: usize,
    one_at_a_time: f64,
    batched_serial: f64,
    batched_scoped: f64,
    batched_pooled: f64,
    pipelined: f64,
    monitor_serial: f64,
    monitor_pooled: f64,
    monitored_cached: f64,
    monitored_scan: f64,
    aggregate_serial: f64,
    aggregate_pooled: f64,
    aggregate_rescan: f64,
    query_serial: f64,
    query_pooled: f64,
    snapshot_serial: f64,
    snapshot_pooled: f64,
    small_batch_pooled: f64,
    small_batch_adaptive: f64,
    mixed_serial: f64,
    mixed_pooled: f64,
    binned_serial: f64,
    binned_pooled: f64,
    serve_qps: f64,
    serve_qps_locked: f64,
    live: usize,
}

/// The subscriber fan-out measurement: one server, [`FANOUT_SUBS`]
/// binary subscribers, [`FANOUT_ROUNDS`] publications.
struct FanoutRow {
    deliveries_per_sec: f64,
    lag_resyncs: usize,
}

fn fresh_fleet(monitor: bool, workers: usize, pool: bool, pipeline: bool, adaptive: bool) -> AucFleet {
    let stream_defaults = if monitor {
        StreamConfig::new(WINDOW, EPSILON)
    } else {
        StreamConfig::new(WINDOW, EPSILON).without_monitor()
    };
    AucFleet::new(FleetConfig { shards: SHARDS, workers, pool, pipeline, adaptive, stream_defaults })
}

fn throughput(events: &[(u64, f64, bool)], mut ingest: impl FnMut(&[(u64, f64, bool)])) -> f64 {
    let start = Instant::now();
    ingest(events);
    events.len() as f64 / start.elapsed().as_secs_f64()
}

fn batched_by(fleet: &mut AucFleet, soup: &[(u64, f64, bool)], chunk: usize) -> f64 {
    throughput(soup, |evs| {
        for batch in evs.chunks(chunk) {
            fleet.push_batch(batch);
        }
        // A pipelined fleet may still be draining its last batch; fold
        // the wait into the timed region so strategies stay comparable.
        fleet.sync();
    })
}

fn batched(fleet: &mut AucFleet, soup: &[(u64, f64, bool)]) -> f64 {
    batched_by(fleet, soup, BATCH)
}

/// The monitored per-stream stack without the fleet wrapper: one
/// window + drift monitor per stream, the monitor fed either by the
/// `O(1)` cached read or by the retained `O(|C|)` full-scan read —
/// isolating exactly the read cost that incremental `a2` removed from
/// every monitored event.
fn monitored_stack(soup: &[(u64, f64, bool)], full_scan: bool) -> f64 {
    use std::collections::HashMap;
    let mut streams: HashMap<u64, (Window<ApproxAuc>, AucMonitor)> = HashMap::new();
    throughput(soup, |evs| {
        for &(id, s, l) in evs {
            let (win, mon) = streams.entry(id).or_insert_with(|| {
                (
                    Window::with_estimator(WINDOW, ApproxAuc::new(EPSILON)),
                    AucMonitor::new(0.001, 0.08, 100, 500),
                )
            });
            win.push(s, l);
            if win.is_full() {
                let auc =
                    if full_scan { win.estimator().auc_full_scan() } else { win.auc() };
                mon.observe(auc);
            }
        }
    })
}

/// Calls/sec of a read op: repeat until the clock has something to
/// measure (CI numbers are noise anyway; the shape is what matters).
fn calls_per_sec(mut op: impl FnMut()) -> f64 {
    let start = Instant::now();
    let mut iters = 0u32;
    loop {
        op();
        iters += 1;
        if iters >= 200 || start.elapsed().as_millis() >= 150 {
            break;
        }
    }
    f64::from(iters) / start.elapsed().as_secs_f64()
}

struct MemRow {
    streams: usize,
    live: usize,
    live_bytes: u64,
    hib_bytes: u64,
    rss_live_kb: u64,
    rss_hib_kb: u64,
    rehydrate_ns: u64,
}

/// Resident set size in kB from `/proc/self/status` (0 where absent —
/// non-Linux hosts report logical footprint only).
fn vm_rss_kb() -> u64 {
    let Ok(status) = std::fs::read_to_string("/proc/self/status") else {
        return 0;
    };
    status
        .lines()
        .find_map(|l| l.strip_prefix("VmRSS:"))
        .and_then(|rest| rest.trim().strip_suffix("kB"))
        .and_then(|n| n.trim().parse().ok())
        .unwrap_or(0)
}

/// One `mem` row: fill a fleet, read logical + resident cost live,
/// hibernate everything, read both again, then time transparent
/// rehydration. The per-stream byte budgets are asserted here — the
/// bench run *fails* if a regression pushes a stream over budget.
fn mem_row(workers: usize, n_streams: usize) -> MemRow {
    let mut fleet = AucFleet::new(FleetConfig {
        shards: SHARDS,
        workers,
        pool: true,
        pipeline: false,
        adaptive: false,
        stream_defaults: StreamConfig::new(MEM_WINDOW, EPSILON).without_monitor(),
    });
    // Generate and ingest chunk by chunk so no event buffer survives
    // into the RSS readings.
    let mut gen = MultiStream::new(n_streams, 0x3E3).with_mean_burst(4.0);
    let mut remaining = n_streams * MEM_FILL;
    while remaining > 0 {
        let n = remaining.min(BATCH);
        fleet.push_batch(&gen.next_batch(n));
        remaining -= n;
    }
    let live = fleet.stream_count();
    let live_bytes = fleet.footprint_bytes();
    let rss_live_kb = vm_rss_kb();

    // The sketch-maintained footprint is part of the aggregate's
    // bit-identity contract — prove it before trusting the numbers.
    assert_eq!(
        fleet.aggregate(),
        fleet.aggregate_rescan(),
        "mem row: sketch aggregate diverged from rescan"
    );

    let frozen = fleet.hibernate_idle(0);
    assert_eq!(frozen, live, "every stream should hibernate");
    let hib_bytes = fleet.footprint_bytes();
    let rss_hib_kb = vm_rss_kb();

    let live_per = live_bytes as f64 / live as f64;
    let hib_per = hib_bytes as f64 / live as f64;
    assert!(
        live_per <= LIVE_BUDGET_BYTES,
        "live footprint {live_per:.0} B/stream exceeds the {LIVE_BUDGET_BYTES} budget"
    );
    assert!(
        hib_per <= HIB_BUDGET_BYTES,
        "hibernated footprint {hib_per:.0} B/stream exceeds the {HIB_BUDGET_BYTES} budget"
    );
    assert!(
        hib_bytes * 3 <= live_bytes,
        "hibernated form must cost ≤ ⅓ of live: {hib_bytes} vs {live_bytes}"
    );
    if live >= 100_000 && rss_live_kb > 0 && rss_hib_kb > 0 {
        assert!(
            rss_hib_kb <= rss_live_kb,
            "hibernation must not grow RSS: {rss_hib_kb} kB vs {rss_live_kb} kB"
        );
    }

    // Transparent rehydration: one event into each of a sample of
    // frozen streams (the shard asserts bit-identity on every thaw).
    let sample: Vec<u64> =
        (0..n_streams as u64).filter(|&id| fleet.is_hibernated(id)).take(1000).collect();
    let t = Instant::now();
    for &id in &sample {
        fleet.push(id, 0.5, true);
    }
    let rehydrate_ns = t.elapsed().as_nanos() as u64 / sample.len().max(1) as u64;
    assert_eq!(fleet.hibernated_count(), live - sample.len(), "sampled streams must rehydrate");

    MemRow { streams: n_streams, live, live_bytes, hib_bytes, rss_live_kb, rss_hib_kb, rehydrate_ns }
}

/// The fan-out measurement: attach [`FANOUT_SUBS`] binary subscribers
/// to one server, drive [`FANOUT_ROUNDS`] publications through it,
/// then drain every subscriber to the final publication seq. Seq
/// tracking rides the protocol contract — one delta per seq bump,
/// gapless until a lag notice, whose following baseline lands at the
/// notice's seq — so a subscriber that lagged and one that kept up
/// both converge on the same seq, asserted per subscriber. The rate is
/// push frames actually delivered (deltas + lag notices + baselines)
/// across all subscribers over the publish+drain wall clock — the
/// coalescing policy means a lagging subscriber costs *less* to catch
/// up, not more, and the number reflects that.
fn fanout_row(workers: usize) -> FanoutRow {
    let mut gen = MultiStream::new(1_000, 0xFA17).with_mean_burst(4.0);
    let mut fed = fresh_fleet(false, workers, true, false, false);
    fed.push_batch(&gen.next_batch(20_000));
    // max_conns caps attached subscribers too — leave headroom over
    // FANOUT_SUBS; the generous timeout keeps writers blocked on full
    // loopback buffers alive until the drain below reads them out.
    let server = FleetServer::start_with(
        fed,
        "127.0.0.1:0",
        ServeLimits { workers: 4, max_conns: 2 * FANOUT_SUBS, timeout: Duration::from_secs(30) },
    )
    .expect("bind loopback");
    let addr = server.local_addr();
    // (client, seq it has caught up to) — the subscribe response's seq
    // echo is the baseline's publication epoch.
    let mut subs: Vec<(BinClient, u64)> = (0..FANOUT_SUBS)
        .map(|_| {
            let mut c = BinClient::connect(addr).expect("connect subscriber");
            c.subscribe().expect("subscribe");
            let seq = c.last_seq().expect("baseline seq echo");
            (c, seq)
        })
        .collect();
    assert_eq!(server.subscriber_count(), FANOUT_SUBS, "every subscriber attached");

    let start = Instant::now();
    for _ in 0..FANOUT_ROUNDS {
        server.ingest_batch(&gen.next_batch(SMALL_BATCH));
    }
    let final_seq = server.last_published().0;

    let mut deliveries = 0usize;
    let mut lag_resyncs = 0usize;
    for (sub, seq) in &mut subs {
        while *seq < final_seq {
            deliveries += 1;
            match sub.next_event().expect("push frame") {
                SubEvent::Delta(_) => *seq += 1,
                SubEvent::Lagged(at) => {
                    lag_resyncs += 1;
                    match sub.next_event().expect("frame after lag") {
                        SubEvent::Baseline(_) => deliveries += 1,
                        _ => panic!("lag notice not followed by a baseline"),
                    }
                    *seq = at;
                }
                SubEvent::Baseline(_) => panic!("baseline without a lag notice"),
            }
        }
        assert_eq!(*seq, final_seq, "subscriber overshot the final publication");
    }
    let elapsed = start.elapsed().as_secs_f64();
    FanoutRow { deliveries_per_sec: deliveries as f64 / elapsed, lag_resyncs }
}

fn flag(args: &[String], name: &str, default: usize) -> usize {
    match args.iter().position(|a| a == name) {
        Some(i) => args
            .get(i + 1)
            .unwrap_or_else(|| panic!("{name} N"))
            .parse()
            .unwrap_or_else(|_| panic!("{name} N")),
        None => default,
    }
}

fn json_report(
    events_per_row: usize,
    workers: usize,
    rows: &[Row],
    fanout: &FanoutRow,
    mem: &[MemRow],
) -> String {
    let mut s = String::new();
    s.push_str("{\n");
    let _ = writeln!(s, "  \"bench\": \"fleet\",");
    let _ = writeln!(s, "  \"unit\": \"events_per_sec (ingest) / calls_per_sec (reads)\",");
    let _ = writeln!(s, "  \"events_per_row\": {events_per_row},");
    let _ = writeln!(s, "  \"window\": {WINDOW},");
    let _ = writeln!(s, "  \"epsilon\": {EPSILON},");
    let _ = writeln!(s, "  \"batch\": {BATCH},");
    let _ = writeln!(s, "  \"small_batch\": {SMALL_BATCH},");
    let _ = writeln!(s, "  \"shards\": {SHARDS},");
    let _ = writeln!(s, "  \"workers\": {workers},");
    s.push_str("  \"rows\": [\n");
    for (i, r) in rows.iter().enumerate() {
        let _ = write!(
            s,
            "    {{\"streams\": {}, \"live_streams\": {}, \"one_at_a_time\": {:.1}, \
             \"batched_serial\": {:.1}, \"batched_scoped\": {:.1}, \"batched_pooled\": {:.1}, \
             \"pipelined\": {:.1}, \"monitor_serial\": {:.1}, \"monitor_pooled\": {:.1}, \
             \"monitored_cached\": {:.1}, \"monitored_scan\": {:.1}, \
             \"aggregate_serial\": {:.1}, \"aggregate_pooled\": {:.1}, \
             \"aggregate_rescan\": {:.1}, \
             \"query_serial\": {:.1}, \"query_pooled\": {:.1}, \
             \"snapshot_serial\": {:.1}, \"snapshot_pooled\": {:.1}, \
             \"small_batch_pooled\": {:.1}, \"small_batch_adaptive\": {:.1}, \
             \"mixed_serial\": {:.1}, \"mixed_pooled\": {:.1}, \
             \"binned_serial\": {:.1}, \"binned_pooled\": {:.1}, \
             \"serve_qps\": {:.1}, \"serve_qps_locked\": {:.1}, \
             \"speedup_scoped\": {:.3}, \"speedup_pooled\": {:.3}, \"speedup_pipelined\": {:.3}, \
             \"speedup_monitor\": {:.3}, \"speedup_monitor_read\": {:.3}, \
             \"speedup_aggregate\": {:.3}, \"speedup_aggregate_sketch\": {:.3}, \
             \"speedup_query\": {:.3}, \
             \"speedup_snapshot\": {:.3}, \"speedup_small_batch\": {:.3}, \
             \"speedup_mixed\": {:.3}, \"speedup_binned\": {:.3}, \
             \"speedup_serve_view\": {:.3}}}",
            r.streams,
            r.live,
            r.one_at_a_time,
            r.batched_serial,
            r.batched_scoped,
            r.batched_pooled,
            r.pipelined,
            r.monitor_serial,
            r.monitor_pooled,
            r.monitored_cached,
            r.monitored_scan,
            r.aggregate_serial,
            r.aggregate_pooled,
            r.aggregate_rescan,
            r.query_serial,
            r.query_pooled,
            r.snapshot_serial,
            r.snapshot_pooled,
            r.small_batch_pooled,
            r.small_batch_adaptive,
            r.mixed_serial,
            r.mixed_pooled,
            r.binned_serial,
            r.binned_pooled,
            r.serve_qps,
            r.serve_qps_locked,
            r.batched_scoped / r.batched_serial,
            r.batched_pooled / r.batched_serial,
            r.pipelined / r.batched_serial,
            r.monitor_pooled / r.monitor_serial,
            r.monitored_cached / r.monitored_scan,
            r.aggregate_pooled / r.aggregate_serial,
            r.aggregate_serial / r.aggregate_rescan,
            r.query_pooled / r.query_serial,
            r.snapshot_pooled / r.snapshot_serial,
            r.small_batch_adaptive / r.small_batch_pooled,
            r.mixed_pooled / r.mixed_serial,
            r.binned_pooled / r.binned_serial,
            // 0 for the skipped 1-stream row — 0/0 would print NaN,
            // which is not JSON.
            if r.serve_qps_locked > 0.0 {
                r.serve_qps / r.serve_qps_locked
            } else {
                0.0
            },
        );
        s.push_str(if i + 1 < rows.len() { ",\n" } else { "\n" });
    }
    s.push_str("  ],\n");
    let fanout_rate = fanout.deliveries_per_sec;
    let _ = writeln!(s, "  \"serve_fanout_subscribers\": {FANOUT_SUBS},");
    let _ = writeln!(s, "  \"serve_fanout_rounds\": {FANOUT_ROUNDS},");
    let _ = writeln!(s, "  \"serve_fanout_deliveries_per_sec\": {fanout_rate:.1},");
    let _ = writeln!(s, "  \"serve_fanout_lag_resyncs\": {},", fanout.lag_resyncs);
    let _ = writeln!(s, "  \"mem_window\": {MEM_WINDOW},");
    let _ = writeln!(s, "  \"mem_fill\": {MEM_FILL},");
    let _ = writeln!(s, "  \"mem_live_budget_bytes\": {LIVE_BUDGET_BYTES},");
    let _ = writeln!(s, "  \"mem_hibernated_budget_bytes\": {HIB_BUDGET_BYTES},");
    s.push_str("  \"mem\": [\n");
    for (i, m) in mem.iter().enumerate() {
        let _ = write!(
            s,
            "    {{\"streams\": {}, \"live_streams\": {}, \
             \"live_bytes_per_stream\": {:.1}, \"hibernated_bytes_per_stream\": {:.1}, \
             \"hibernated_to_live_ratio\": {:.3}, \
             \"live_total_bytes\": {}, \"hibernated_total_bytes\": {}, \
             \"rss_live_kb\": {}, \"rss_hibernated_kb\": {}, \
             \"rehydrate_ns_per_stream\": {}}}",
            m.streams,
            m.live,
            m.live_bytes as f64 / m.live.max(1) as f64,
            m.hib_bytes as f64 / m.live.max(1) as f64,
            m.hib_bytes as f64 / m.live_bytes.max(1) as f64,
            m.live_bytes,
            m.hib_bytes,
            m.rss_live_kb,
            m.rss_hib_kb,
            m.rehydrate_ns,
        );
        s.push_str(if i + 1 < mem.len() { ",\n" } else { "\n" });
    }
    s.push_str("  ]\n}\n");
    s
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let events_per_row = flag(&args, "--events", 400_000);
    let workers = flag(&args, "--workers", 4);
    // Largest `mem`-section fleet; pass `--streams 1000000` for the
    // million-stream row (the default keeps smoke runs fast).
    let mem_streams = flag(&args, "--streams", 100_000);

    println!("== fleet: ingestion throughput — batching and execution strategies ==");
    println!(
        "   (k={WINDOW}, ε={EPSILON}, batch={BATCH}, {SHARDS} shards, {workers} workers, \
         {events_per_row} events/row)\n"
    );
    println!(
        "{:>8}  {:>13}  {:>12}  {:>12}  {:>12}  {:>12}  {:>6}  {:>12}  {:>12}  {:>6}  {:>7}",
        "streams",
        "one-at-a-time",
        "batched",
        "scoped ∥",
        "pooled ∥",
        "piped ∥",
        "gain",
        "monitor",
        "mon ∥",
        "gain",
        "live"
    );

    let mut rows = Vec::new();
    for &n_streams in &[1usize, 100, 10_000] {
        // Pre-generate outside the timed region; bursty + mildly skewed
        // traffic (the regime push_batch's run-grouping and the
        // size-aware claim queue both exploit).
        let mut gen = MultiStream::new(n_streams, 0xBE7C).with_mean_burst(8.0);
        let soup = gen.next_batch(events_per_row);

        let mut fleet = fresh_fleet(false, 1, false, false, false);
        let one = throughput(&soup, |evs| {
            for &(id, s, l) in evs {
                fleet.push(id, s, l);
            }
        });
        let live = fleet.stream_count();

        let mut serial = fresh_fleet(false, 1, false, false, false);
        let batched_serial = batched(&mut serial, &soup);
        let mut scoped = fresh_fleet(false, workers, false, false, false);
        let batched_scoped = batched(&mut scoped, &soup);
        let mut pooled = fresh_fleet(false, workers, true, false, false);
        let batched_pooled = batched(&mut pooled, &soup);
        let mut piped = fresh_fleet(false, workers, true, true, false);
        let pipelined = batched(&mut piped, &soup);
        assert_eq!(serial.snapshot(), scoped.snapshot(), "scoped ingest diverged");
        assert_eq!(serial.snapshot(), pooled.snapshot(), "pooled ingest diverged");
        assert_eq!(serial.snapshot(), piped.snapshot(), "pipelined ingest diverged");

        // ---- read paths on the already-ingested fleets: serial
        // executor vs the persistent pool, same data in both ----------
        assert_eq!(serial.aggregate(), pooled.aggregate(), "pooled aggregate diverged");
        assert_eq!(
            serial.top_k_worst(10),
            pooled.top_k_worst(10),
            "pooled top_k_worst diverged"
        );
        assert_eq!(
            serial.auc_histogram(16),
            pooled.auc_histogram(16),
            "pooled histogram diverged"
        );
        assert_eq!(
            serial.count_below(0.5),
            pooled.count_below(0.5),
            "pooled count_below diverged"
        );
        // Sketch-backed aggregate vs the retained per-stream rescan,
        // proven bit-identical before either is timed.
        assert_eq!(
            serial.aggregate(),
            serial.aggregate_rescan(),
            "sketch aggregate diverged from rescan"
        );
        let aggregate_serial = calls_per_sec(|| {
            let _ = serial.aggregate();
        });
        let aggregate_pooled = calls_per_sec(|| {
            let _ = pooled.aggregate();
        });
        let aggregate_rescan = calls_per_sec(|| {
            let _ = serial.aggregate_rescan();
        });
        let query_serial = calls_per_sec(|| {
            let _ = serial.top_k_worst(10);
            let _ = serial.count_below(0.5);
            let _ = serial.auc_histogram(16);
        });
        let query_pooled = calls_per_sec(|| {
            let _ = pooled.top_k_worst(10);
            let _ = pooled.count_below(0.5);
            let _ = pooled.auc_histogram(16);
        });
        let snapshot_serial = calls_per_sec(|| {
            let _ = serial.snapshot();
        });
        let snapshot_pooled = calls_per_sec(|| {
            let _ = pooled.snapshot();
        });

        // ---- adaptive crossover: trickle batches, fixed vs adaptive -
        let small_len = (events_per_row / 4).max(2_000).min(soup.len());
        let small_soup = &soup[..small_len];
        let mut small_fixed = fresh_fleet(false, workers, true, false, false);
        let small_batch_pooled = batched_by(&mut small_fixed, small_soup, SMALL_BATCH);
        let mut small_adaptive = fresh_fleet(false, workers, true, false, true);
        let small_batch_adaptive = batched_by(&mut small_adaptive, small_soup, SMALL_BATCH);
        assert_eq!(
            small_fixed.snapshot(),
            small_adaptive.snapshot(),
            "adaptive ingest diverged"
        );

        // ---- mixed-estimator fleet: every 4th stream overridden to
        // the exact-maintained estimator, the rest on the ε-sketch ----
        let mixed_fleet = |workers: usize, pool: bool| {
            let mut fleet = fresh_fleet(false, workers, pool, false, false);
            for id in (0..n_streams as u64).step_by(4) {
                fleet.configure_stream(id, StreamConfig::exact(WINDOW).without_monitor());
            }
            fleet
        };
        let mut mixed_s = mixed_fleet(1, false);
        let mixed_serial = batched(&mut mixed_s, &soup);
        let mut mixed_p = mixed_fleet(workers, true);
        let mixed_pooled = batched(&mut mixed_p, &soup);
        assert_eq!(mixed_s.snapshot(), mixed_p.snapshot(), "mixed-estimator ingest diverged");

        // ---- three-way mix: every 4th stream exact-maintained, the
        // next offset binned at the ⌈2/ε⌉ auto resolution over the
        // sigmoid scores' [0, 1], the rest on the ε-sketch ------------
        let auto_bins = (2.0 / EPSILON).ceil() as usize;
        let binned_fleet = |workers: usize, pool: bool| {
            let mut fleet = fresh_fleet(false, workers, pool, false, false);
            for id in (0..n_streams as u64).step_by(4) {
                fleet.configure_stream(id, StreamConfig::exact(WINDOW).without_monitor());
            }
            for id in (2..n_streams as u64).step_by(4) {
                fleet.configure_stream(
                    id,
                    StreamConfig::binned(WINDOW, auto_bins, 0.0, 1.0).without_monitor(),
                );
            }
            fleet
        };
        let mut binned_s = binned_fleet(1, false);
        let binned_serial = batched(&mut binned_s, &soup);
        let mut binned_p = binned_fleet(workers, true);
        let binned_pooled = batched(&mut binned_p, &soup);
        assert_eq!(binned_s.snapshot(), binned_p.snapshot(), "three-way mix ingest diverged");

        let mut mon_serial = fresh_fleet(true, 1, false, false, false);
        let monitor_serial = batched(&mut mon_serial, &soup);
        let mut mon_pooled = fresh_fleet(true, workers, true, false, false);
        let monitor_pooled = batched(&mut mon_pooled, &soup);
        assert_eq!(mon_serial.alarms(), mon_pooled.alarms(), "pooled alarms diverged");
        assert_eq!(mon_serial.snapshot(), mon_pooled.snapshot(), "pooled monitor ingest diverged");

        // Monitored ingestion with the O(1) cached read vs the retained
        // full-scan read, same per-stream stack either way.
        let monitored_cached = monitored_stack(&soup, false);
        let monitored_scan = monitored_stack(&soup, true);

        // ---- served reads: keep-alive HTTP round-trips answered
        // while a background thread keeps ingesting 64-event batches
        // through the same server. /aggregate answers from the
        // epoch-swapped published view (no fleet lock);
        // /score_histogram is the one endpoint that must lock the
        // fleet per request — the pair prices the snapshot-read
        // path against the fleet-lock path under write load. ---------
        let (serve_qps, serve_qps_locked) = if n_streams > 1 {
            let mut fed = fresh_fleet(false, workers, true, false, false);
            for batch in soup.chunks(BATCH) {
                fed.push_batch(batch);
            }
            let server = Arc::new(
                FleetServer::start_with(
                    fed,
                    "127.0.0.1:0",
                    ServeLimits { workers: 4, max_conns: 64, timeout: Duration::from_secs(10) },
                )
                .expect("bind loopback"),
            );
            let addr = server.local_addr();
            let stop = Arc::new(AtomicBool::new(false));
            let feeder = {
                let server = Arc::clone(&server);
                let stop = Arc::clone(&stop);
                let chunks: Vec<Vec<(u64, f64, bool)>> =
                    soup.chunks(SMALL_BATCH).map(<[_]>::to_vec).collect();
                std::thread::spawn(move || {
                    let mut i = 0usize;
                    while !stop.load(Ordering::Relaxed) {
                        server.ingest_batch(&chunks[i % chunks.len()]);
                        i += 1;
                    }
                })
            };
            let mut client = HttpClient::connect(addr).expect("connect loopback");
            let view_qps = calls_per_sec(|| {
                let (status, body) = client.get("/aggregate").expect("served aggregate");
                assert_eq!(status, 200, "served aggregate errored mid-bench");
                assert!(!body.is_empty());
            });
            let locked_qps = calls_per_sec(|| {
                let (status, body) =
                    client.get("/score_histogram?bins=10").expect("served histogram");
                assert_eq!(status, 200, "served score histogram errored mid-bench");
                assert!(!body.is_empty());
            });
            stop.store(true, Ordering::Relaxed);
            feeder.join().expect("feeder thread");
            (view_qps, locked_qps)
        } else {
            (0.0, 0.0)
        };

        println!(
            "{n_streams:>8}  {one:>11.0}/s  {batched_serial:>10.0}/s  {batched_scoped:>10.0}/s  \
             {batched_pooled:>10.0}/s  {pipelined:>10.0}/s  {:>5.2}x  {monitor_serial:>10.0}/s  \
             {monitor_pooled:>10.0}/s  {:>5.2}x  {live:>7}",
            batched_pooled / batched_serial,
            monitor_pooled / monitor_serial,
        );
        rows.push(Row {
            streams: n_streams,
            one_at_a_time: one,
            batched_serial,
            batched_scoped,
            batched_pooled,
            pipelined,
            monitor_serial,
            monitor_pooled,
            monitored_cached,
            monitored_scan,
            aggregate_serial,
            aggregate_pooled,
            aggregate_rescan,
            query_serial,
            query_pooled,
            snapshot_serial,
            snapshot_pooled,
            small_batch_pooled,
            small_batch_adaptive,
            mixed_serial,
            mixed_pooled,
            binned_serial,
            binned_pooled,
            serve_qps,
            serve_qps_locked,
            live,
        });
    }
    println!(
        "\n(gain = pooled / serial at {workers} workers; live = distinct streams touched)"
    );

    println!("\n== incremental reads: monitored ingest (cached vs scan) and sketch aggregate ==\n");
    println!(
        "{:>8}  {:>26}  {:>30}",
        "streams", "monitor cached/scan (gain)", "aggregate sketch/rescan (gain)"
    );
    for r in &rows {
        println!(
            "{:>8}  {:>9.0}/{:<9.0} {:>5.2}x  {:>10.0}/{:<10.0} {:>5.2}x",
            r.streams,
            r.monitored_cached,
            r.monitored_scan,
            r.monitored_cached / r.monitored_scan,
            r.aggregate_serial,
            r.aggregate_rescan,
            r.aggregate_serial / r.aggregate_rescan,
        );
    }

    println!(
        "\n== mixed-estimator ingestion (exact mix; three-way mix adds binned streams) ==\n"
    );
    println!(
        "{:>8}  {:>12}  {:>12}  {:>6}  {:>12}  {:>12}  {:>6}  {:>14}",
        "streams", "mixed", "mixed ∥", "gain", "3-way", "3-way ∥", "gain", "vs all-approx"
    );
    for r in &rows {
        println!(
            "{:>8}  {:>10.0}/s  {:>10.0}/s  {:>5.2}x  {:>10.0}/s  {:>10.0}/s  {:>5.2}x  \
             {:>13.2}x",
            r.streams,
            r.mixed_serial,
            r.mixed_pooled,
            r.mixed_pooled / r.mixed_serial,
            r.binned_serial,
            r.binned_pooled,
            r.binned_pooled / r.binned_serial,
            r.mixed_serial / r.batched_serial,
        );
    }

    println!("\n== read paths (calls/s, serial vs pooled) and adaptive small batches ==\n");
    println!(
        "{:>8}  {:>20}  {:>20}  {:>20}  {:>24}",
        "streams",
        "aggregate s/∥ (gain)",
        "query s/∥ (gain)",
        "snapshot s/∥ (gain)",
        "64-ev batch fix/adpt (gain)"
    );
    for r in &rows {
        println!(
            "{:>8}  {:>6.0}/{:<6.0} {:>5.2}x  {:>6.0}/{:<6.0} {:>5.2}x  {:>6.0}/{:<6.0} {:>5.2}x  \
             {:>8.0}/{:<8.0} {:>5.2}x",
            r.streams,
            r.aggregate_serial,
            r.aggregate_pooled,
            r.aggregate_pooled / r.aggregate_serial,
            r.query_serial,
            r.query_pooled,
            r.query_pooled / r.query_serial,
            r.snapshot_serial,
            r.snapshot_pooled,
            r.snapshot_pooled / r.snapshot_serial,
            r.small_batch_pooled,
            r.small_batch_adaptive,
            r.small_batch_adaptive / r.small_batch_pooled,
        );
    }

    println!(
        "\n== served reads: HTTP qps under concurrent ingestion \
         (view = /aggregate from the published view, locked = /score_histogram \
         through the fleet lock) ==\n"
    );
    println!("{:>8}  {:>12}  {:>12}  {:>6}", "streams", "view qps", "locked qps", "gain");
    for r in &rows {
        if r.serve_qps > 0.0 {
            println!(
                "{:>8}  {:>10.0}/s  {:>10.0}/s  {:>5.2}x",
                r.streams, r.serve_qps, r.serve_qps_locked, r.serve_qps / r.serve_qps_locked
            );
        } else {
            println!("{:>8}  {:>12}  {:>12}  {:>6}", r.streams, "(skipped)", "", "");
        }
    }

    println!(
        "\n== served fan-out: {FANOUT_SUBS} binary subscribers × {FANOUT_ROUNDS} \
         publications ==\n"
    );
    let fanout = fanout_row(workers);
    println!(
        "  {:>10.0} push frames/s delivered, {} lag resync(s) coalesced",
        fanout.deliveries_per_sec, fanout.lag_resyncs
    );

    println!(
        "\n== mem: bytes/stream live vs hibernated (k={MEM_WINDOW}, ~{MEM_FILL} events/stream; \
         budgets asserted: live ≤ {LIVE_BUDGET_BYTES:.0} B, hibernated ≤ {HIB_BUDGET_BYTES:.0} B, \
         ratio ≤ ⅓) ==\n"
    );
    println!(
        "{:>9}  {:>10}  {:>12}  {:>6}  {:>11}  {:>11}  {:>12}",
        "streams", "live B/st", "hib B/st", "ratio", "RSS live", "RSS hib", "rehydrate"
    );
    let mut mem_rows = Vec::new();
    for &n in &[10_000usize, mem_streams] {
        if mem_rows.iter().any(|m: &MemRow| m.streams == n) {
            continue;
        }
        let m = mem_row(workers, n);
        println!(
            "{:>9}  {:>10.0}  {:>12.0}  {:>5.2}x  {:>8} kB  {:>8} kB  {:>9} ns",
            m.streams,
            m.live_bytes as f64 / m.live.max(1) as f64,
            m.hib_bytes as f64 / m.live.max(1) as f64,
            m.hib_bytes as f64 / m.live_bytes.max(1) as f64,
            m.rss_live_kb,
            m.rss_hib_kb,
            m.rehydrate_ns,
        );
        mem_rows.push(m);
    }

    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("BENCH_fleet.json");
    let report = json_report(events_per_row, workers, &rows, &fanout, &mem_rows);
    match std::fs::write(&path, &report) {
        Ok(()) => println!("\nwrote {}", path.display()),
        Err(e) => eprintln!("could not write {}: {e}", path.display()),
    }
}
