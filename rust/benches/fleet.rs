//! Fleet ingestion throughput: updates/sec versus stream count,
//! batched (`push_batch`) against the naive one-at-a-time loop.
//!
//! `cargo bench --bench fleet [-- --events N]`
//!
//! Each row streams the same pre-generated bursty event soup into a
//! fresh fleet three ways:
//!
//! * `one-at-a-time` — `push` per event: full dispatch (stream-id hash
//!   + shard index probe) on every update;
//! * `batched` — `push_batch` in chunks of 4096: per-shard bucketing
//!   with the stream lookup amortized over same-stream runs;
//! * `batched+monitor` — ditto with the per-stream drift monitor on
//!   (adds one `O(|C|)` AUC read per update), the full service
//!   configuration.
//!
//! Expected shape: batched ≥ one-at-a-time everywhere, with the gap
//! widening as the stream count (and thus the dispatch share of the
//! per-event cost) grows; absolute throughput drops from 1 stream to
//! 10k streams as the working set leaves cache.

use std::time::Instant;

use streamauc::fleet::{AucFleet, FleetConfig, StreamConfig};
use streamauc::stream::MultiStream;

const WINDOW: usize = 100;
const EPSILON: f64 = 0.1;
const BATCH: usize = 4096;

fn fresh_fleet(monitor: bool) -> AucFleet {
    let stream_defaults = if monitor {
        StreamConfig::new(WINDOW, EPSILON)
    } else {
        StreamConfig::new(WINDOW, EPSILON).without_monitor()
    };
    AucFleet::new(FleetConfig { shards: 64, stream_defaults })
}

fn throughput(events: &[(u64, f64, bool)], mut ingest: impl FnMut(&[(u64, f64, bool)])) -> f64 {
    let start = Instant::now();
    ingest(events);
    events.len() as f64 / start.elapsed().as_secs_f64()
}

fn main() {
    let mut events_per_row = 400_000usize;
    let args: Vec<String> = std::env::args().collect();
    if let Some(i) = args.iter().position(|a| a == "--events") {
        events_per_row = args.get(i + 1).expect("--events N").parse().expect("--events N");
    }

    println!("== fleet: ingestion throughput, batched vs one-at-a-time ==");
    println!("   (k={WINDOW}, ε={EPSILON}, batch={BATCH}, {events_per_row} events/row)\n");
    println!(
        "{:>8}  {:>14}  {:>14}  {:>7}  {:>16}  {:>8}",
        "streams", "one-at-a-time", "batched", "gain", "batched+monitor", "live"
    );

    for &n_streams in &[1usize, 100, 10_000] {
        // Pre-generate outside the timed region; bursty + mildly skewed
        // traffic (the regime push_batch's run-grouping exploits).
        let mut gen = MultiStream::new(n_streams, 0xBE7C).with_mean_burst(8.0);
        let soup = gen.next_batch(events_per_row);

        let mut fleet = fresh_fleet(false);
        let one = throughput(&soup, |evs| {
            for &(id, s, l) in evs {
                fleet.push(id, s, l);
            }
        });
        let live = fleet.stream_count();

        let mut fleet = fresh_fleet(false);
        let batched = throughput(&soup, |evs| {
            for chunk in evs.chunks(BATCH) {
                fleet.push_batch(chunk);
            }
        });

        let mut fleet = fresh_fleet(true);
        let monitored = throughput(&soup, |evs| {
            for chunk in evs.chunks(BATCH) {
                fleet.push_batch(chunk);
            }
        });

        println!(
            "{n_streams:>8}  {one:>12.0}/s  {batched:>12.0}/s  {:>6.2}x  {monitored:>14.0}/s  {live:>8}",
            batched / one
        );
    }
    println!("\n(gain = batched / one-at-a-time; live = distinct streams touched)");
}
