//! Hand-rolled CLI argument parsing (clap is unavailable offline).
//!
//! Grammar: `streamauc <command> [--flag value]... [--switch]...`.
//! [`Args::parse`] splits a raw argv into the command and a flag map;
//! typed accessors mirror the config module so flags override config
//! files uniformly.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

/// Parsed command line: one subcommand plus `--key value` flags.
#[derive(Clone, Debug, Default)]
pub struct Args {
    /// The subcommand (first positional argument).
    pub command: String,
    /// Positional arguments after the command.
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
}

impl Args {
    /// Parse from raw args (excluding argv[0]).
    ///
    /// `--key value` and `--key=value` are both accepted; a trailing
    /// `--key` with no value is a boolean switch (stored as `"true"`).
    pub fn parse(raw: impl IntoIterator<Item = String>) -> Result<Args> {
        let mut it = raw.into_iter().peekable();
        let command = it.next().unwrap_or_default();
        let mut flags = BTreeMap::new();
        let mut positional = Vec::new();
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                if name.is_empty() {
                    bail!("bare `--` is not supported");
                }
                if let Some((k, v)) = name.split_once('=') {
                    flags.insert(k.to_string(), v.to_string());
                } else if it.peek().map_or(false, |n| !n.starts_with("--")) {
                    flags.insert(name.to_string(), it.next().unwrap());
                } else {
                    flags.insert(name.to_string(), "true".to_string());
                }
            } else {
                positional.push(tok);
            }
        }
        Ok(Args { command, positional, flags })
    }

    /// Raw flag lookup.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(String::as_str)
    }

    /// Typed flag with default.
    pub fn get_or<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        match self.flags.get(key) {
            None => Ok(default),
            Some(raw) => raw.parse().map_err(|e| anyhow!("flag --{key} {raw:?}: {e}")),
        }
    }

    /// Boolean switch presence.
    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    /// Error on flags outside the allowed set.
    pub fn validate_flags(&self, allowed: &[&str]) -> Result<()> {
        for k in self.flags.keys() {
            if !allowed.contains(&k.as_str()) {
                bail!("unknown flag --{k} (allowed: {allowed:?})");
            }
        }
        Ok(())
    }

    /// Fold flags into a config map (flags win).
    pub fn overlay_on(&self, cfg: &mut crate::config::Config) {
        for (k, v) in &self.flags {
            cfg.set(k, v.clone());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn command_flags_positional() {
        let a = parse("experiment fig1 --events 500 --csv=out --verbose");
        assert_eq!(a.command, "experiment");
        assert_eq!(a.positional, vec!["fig1"]);
        assert_eq!(a.get("events"), Some("500"));
        assert_eq!(a.get("csv"), Some("out"));
        assert!(a.has("verbose"));
        assert_eq!(a.get_or("events", 0usize).unwrap(), 500);
    }

    #[test]
    fn boolean_switch_before_flag() {
        let a = parse("run --fast --eps 0.1");
        assert_eq!(a.get("fast"), Some("true"));
        assert_eq!(a.get_or("eps", 0.0).unwrap(), 0.1);
    }

    #[test]
    fn empty_args() {
        let a = parse("");
        assert_eq!(a.command, "");
        assert!(a.positional.is_empty());
    }

    #[test]
    fn type_errors_name_the_flag() {
        let a = parse("x --n abc");
        let err = a.get_or("n", 0usize).unwrap_err().to_string();
        assert!(err.contains("--n"), "{err}");
    }

    #[test]
    fn unknown_flag_rejected() {
        let a = parse("x --bogus 1");
        assert!(a.validate_flags(&["events"]).is_err());
        assert!(a.validate_flags(&["bogus"]).is_ok());
    }

    #[test]
    fn overlay_overrides_config() {
        let mut cfg = crate::config::Config::parse("events = 10").unwrap();
        let a = parse("x --events 99");
        a.overlay_on(&mut cfg);
        assert_eq!(cfg.get("events"), Some("99"));
    }
}
