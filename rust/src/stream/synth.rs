//! Synthetic dataset generators standing in for the paper's UCI datasets.
//!
//! The paper evaluates on three UCI datasets scored by a scikit-learn
//! logistic regression (Table 1). Those datasets are not redistributable
//! inside this environment, so each is replaced by a parametric generator
//! that reproduces the *regime* the dataset exercises (DESIGN.md
//! §Substitutions):
//!
//! | paper       | stand-in           | regime preserved                    |
//! |-------------|--------------------|-------------------------------------|
//! | Hepmass     | [`hepmass_like`]   | large test stream, balanced classes, well-separated scores (high AUC) |
//! | Miniboone   | [`miniboone_like`] | class imbalance (28% positive), moderate overlap |
//! | Tvads       | [`tvads_like`]     | low separability **and quantized scores** — many duplicate-score nodes |
//!
//! Generators produce *feature vectors + labels*; the classifier layers
//! (L1/L2 via the PJRT runtime) turn features into scores on the real
//! pipeline. For algorithm-only experiments, [`Dataset::score_stream`]
//! shortcuts with the generator's analytic margin + noise, which follows
//! the same sigmoid-margin family a trained logistic regression emits.

use super::rng::Pcg;

/// One labelled example: dense features + binary label.
#[derive(Clone, Debug)]
pub struct Example {
    /// Dense feature vector (length = [`DatasetSpec::dims`]).
    pub features: Vec<f32>,
    /// True label (`ℓ = 1` is the positive / anomalous class).
    pub label: bool,
}

/// Parameters of a two-class Gaussian-mixture dataset with an analytic
/// margin, mimicking one of the paper's benchmark datasets.
#[derive(Clone, Debug)]
pub struct DatasetSpec {
    /// Dataset name used in reports (matches the paper's tables).
    pub name: &'static str,
    /// Feature dimensionality.
    pub dims: usize,
    /// Training-set size (Table 1).
    pub train_size: usize,
    /// Test-set (stream) size (Table 1).
    pub test_size: usize,
    /// P(label = 1).
    pub pos_rate: f64,
    /// Distance between class means along the discriminative direction;
    /// controls achievable AUC.
    pub separation: f64,
    /// Per-class feature noise.
    pub noise: f64,
    /// If set, scores are quantized to this many distinct levels —
    /// reproducing Tvads' duplicate-heavy score distribution.
    pub quantize: Option<u32>,
}

impl DatasetSpec {
    /// Scaled-down sizes for tests and quick runs (`scale` divides both
    /// train and test sizes, minimum 100).
    pub fn scaled(mut self, scale: usize) -> Self {
        self.train_size = (self.train_size / scale).max(100);
        self.test_size = (self.test_size / scale).max(100);
        self
    }
}

/// Hepmass-like: 28 features, 50/50 classes, strong separation. The
/// paper's largest stream (500k train / 3.5M test).
pub fn hepmass_like() -> DatasetSpec {
    DatasetSpec {
        name: "hepmass",
        dims: 28,
        train_size: 500_000,
        test_size: 3_500_000,
        pos_rate: 0.5,
        separation: 2.4,
        noise: 1.0,
        quantize: None,
    }
}

/// Miniboone-like: 50 features, 28% positives, moderate overlap
/// (30k train / 100k test).
pub fn miniboone_like() -> DatasetSpec {
    DatasetSpec {
        name: "miniboone",
        dims: 50,
        train_size: 30_064,
        test_size: 100_000,
        pos_rate: 0.28,
        separation: 1.6,
        noise: 1.0,
        quantize: None,
    }
}

/// Tvads-like: wide features, near-balanced, weak separation and
/// *quantized* scores (40k train / 89k test). The quantization forces
/// duplicate-score tree nodes, the structurally distinct regime.
pub fn tvads_like() -> DatasetSpec {
    DatasetSpec {
        name: "tvads",
        dims: 124,
        train_size: 40_265,
        test_size: 89_420,
        pos_rate: 0.45,
        separation: 1.0,
        noise: 1.3,
        quantize: Some(256),
    }
}

/// The paper's three benchmark datasets (Table 1 order).
pub fn paper_datasets() -> Vec<DatasetSpec> {
    vec![hepmass_like(), miniboone_like(), tvads_like()]
}

/// Instantiated generator: draws examples and analytic score streams.
#[derive(Clone, Debug)]
pub struct Dataset {
    spec: DatasetSpec,
    /// Unit discriminative direction (class mean offset).
    direction: Vec<f64>,
    rng: Pcg,
}

impl Dataset {
    /// Instantiate a spec with a seed (direction and draws deterministic).
    pub fn new(spec: DatasetSpec, seed: u64) -> Self {
        let mut rng = Pcg::seed_stream(seed, 0xD5);
        let mut direction: Vec<f64> = (0..spec.dims).map(|_| rng.normal()).collect();
        let norm = direction.iter().map(|x| x * x).sum::<f64>().sqrt().max(1e-12);
        for d in &mut direction {
            *d /= norm;
        }
        Dataset { spec, direction, rng }
    }

    /// The spec this dataset was built from.
    pub fn spec(&self) -> &DatasetSpec {
        &self.spec
    }

    /// Draw one labelled example. Positives are shifted by `−separation`
    /// along the discriminative direction (lower margin ⇒ lower score,
    /// matching the paper's convention: larger score ⇒ more negative).
    pub fn example(&mut self) -> Example {
        let label = self.rng.chance(self.spec.pos_rate);
        let shift = if label { -self.spec.separation } else { 0.0 };
        let features: Vec<f32> = self
            .direction
            .iter()
            .map(|&d| (d * shift + self.rng.normal() * self.spec.noise) as f32)
            .collect();
        Example { features, label }
    }

    /// Draw a batch of examples.
    pub fn examples(&mut self, n: usize) -> Vec<Example> {
        (0..n).map(|_| self.example()).collect()
    }

    /// Analytic score for an example: the logistic of its margin along
    /// the discriminative direction — the Bayes-optimal family the
    /// trained logistic regression converges to. Quantized per spec.
    pub fn analytic_score(&self, ex: &Example) -> f64 {
        let margin: f64 = ex
            .features
            .iter()
            .zip(&self.direction)
            .map(|(&f, &d)| f64::from(f) * d)
            .sum::<f64>()
            + 0.5 * self.spec.separation;
        let score = 1.0 / (1.0 + (-margin).exp());
        self.quantize(score)
    }

    /// Apply the spec's score quantization.
    pub fn quantize(&self, score: f64) -> f64 {
        match self.spec.quantize {
            Some(levels) => (score * f64::from(levels)).floor() / f64::from(levels),
            None => score,
        }
    }

    /// Draw `n` scored pairs `(score, label)` from the analytic-score
    /// shortcut (no classifier in the loop).
    pub fn score_stream(&mut self, n: usize) -> Vec<(f64, bool)> {
        (0..n)
            .map(|_| {
                let ex = self.example();
                (self.analytic_score(&ex), ex.label)
            })
            .collect()
    }
}

// ---------------------------------------------------------------------
// Multi-stream fleet generator
// ---------------------------------------------------------------------

/// Per-stream drift schedule for the fleet generator. Indices are
/// **stream-local** event counts (the `t`-th event emitted on that
/// stream), unlike [`crate::stream::Drift`] which rewrites a
/// materialized single-stream slice.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum DriftSchedule {
    /// No drift: the stream stays healthy.
    None,
    /// From stream-local event `at` onward, labels flip with
    /// probability `rate` (sudden regime change / upstream failure).
    Abrupt {
        /// Stream-local event index where the change happens.
        at: u64,
        /// Probability a post-change label flips.
        rate: f64,
    },
    /// Between `from` and `to`, flip probability ramps 0 → `rate`
    /// (slow distribution shift), staying at `rate` afterwards.
    Gradual {
        /// Ramp start (stream-local).
        from: u64,
        /// Ramp end (stream-local).
        to: u64,
        /// Final flip probability.
        rate: f64,
    },
}

impl DriftSchedule {
    /// Label-flip probability at stream-local event `t`.
    pub fn flip_rate(self, t: u64) -> f64 {
        match self {
            DriftSchedule::None => 0.0,
            DriftSchedule::Abrupt { at, rate } => {
                if t >= at {
                    rate
                } else {
                    0.0
                }
            }
            DriftSchedule::Gradual { from, to, rate } => {
                if t < from {
                    0.0
                } else if t >= to {
                    rate
                } else {
                    rate * (t - from) as f64 / (to - from).max(1) as f64
                }
            }
        }
    }
}

/// Profile of one synthetic stream in a [`MultiStream`] fleet: a 1-D
/// sigmoid-margin classifier stand-in (same family as [`Dataset`], but
/// per-stream and cheap enough to instantiate thousands of times).
#[derive(Clone, Debug)]
pub struct StreamProfile {
    /// Stream id (the key the fleet shards by).
    pub id: u64,
    /// P(label = 1).
    pub pos_rate: f64,
    /// Distance between class margin means; controls the clean AUC.
    pub separation: f64,
    /// Margin noise standard deviation.
    pub noise: f64,
    /// Quantize scores to this many levels (duplicate-score regime).
    pub quantize: Option<u32>,
    /// Drift schedule (stream-local event indexing).
    pub drift: DriftSchedule,
}

impl StreamProfile {
    /// A healthy, well-separated stream (clean AUC ≈ 0.94).
    pub fn healthy(id: u64) -> StreamProfile {
        StreamProfile {
            id,
            pos_rate: 0.4,
            separation: 2.2,
            noise: 1.0,
            quantize: None,
            drift: DriftSchedule::None,
        }
    }

    /// Attach a drift schedule.
    pub fn with_drift(mut self, drift: DriftSchedule) -> StreamProfile {
        self.drift = drift;
        self
    }

    /// Quantize scores to `levels` distinct values.
    pub fn quantized(mut self, levels: u32) -> StreamProfile {
        self.quantize = Some(levels);
        self
    }
}

/// Generator state for one stream.
#[derive(Clone, Debug)]
struct StreamGen {
    profile: StreamProfile,
    rng: Pcg,
    emitted: u64,
}

impl StreamGen {
    /// Emit one `(id, score, label)` event. Positives carry *lower*
    /// scores (paper §2 convention: larger score ⇒ more negative).
    fn emit(&mut self) -> (u64, f64, bool) {
        let p = &self.profile;
        let mut label = self.rng.chance(p.pos_rate);
        let half = 0.5 * p.separation;
        let margin = if label { -half } else { half } + self.rng.normal() * p.noise;
        let mut score = 1.0 / (1.0 + (-margin).exp());
        if let Some(levels) = p.quantize {
            score = (score * f64::from(levels)).floor() / f64::from(levels);
        }
        let rate = p.drift.flip_rate(self.emitted);
        if rate > 0.0 && self.rng.chance(rate) {
            label = !label;
        }
        self.emitted += 1;
        (p.id, score, label)
    }
}

/// Deterministic multi-stream event source: interleaves per-stream
/// generators with bursty, optionally skewed traffic — the workload
/// shape [`crate::fleet::AucFleet`] is built for.
///
/// * **Bursty**: the generator stays on one stream for a geometric
///   number of events (mean [`MultiStream::with_mean_burst`]) before
///   re-drawing, producing the same-stream runs real ingest pipelines
///   see.
/// * **Skewed**: stream selection draws `⌊n·u^skew⌋`; `skew = 1` is
///   uniform popularity, larger values concentrate traffic on
///   low-index streams (hot heads, long cold tail).
///
/// Every stream owns a forked [`Pcg`], so the emitted event sequence is
/// fully determined by the construction seed.
#[derive(Clone, Debug)]
pub struct MultiStream {
    gens: Vec<StreamGen>,
    pick: Pcg,
    current: usize,
    burst_left: u32,
    mean_burst: f64,
    skew: f64,
}

impl MultiStream {
    /// Fleet of `n_streams` healthy streams with ids `0..n_streams`.
    pub fn new(n_streams: usize, seed: u64) -> MultiStream {
        let profiles = (0..n_streams).map(|i| StreamProfile::healthy(i as u64)).collect();
        MultiStream::with_profiles(profiles, seed)
    }

    /// Fleet from explicit per-stream profiles.
    pub fn with_profiles(profiles: Vec<StreamProfile>, seed: u64) -> MultiStream {
        assert!(!profiles.is_empty(), "need at least one stream profile");
        let mut master = Pcg::seed_stream(seed, 0xF1EE7);
        let gens = profiles
            .into_iter()
            .map(|profile| StreamGen { profile, rng: master.fork(), emitted: 0 })
            .collect();
        MultiStream {
            gens,
            pick: master.fork(),
            current: 0,
            burst_left: 0,
            mean_burst: 8.0,
            skew: 1.0,
        }
    }

    /// Mean burst length (events on one stream before switching).
    pub fn with_mean_burst(mut self, mean: f64) -> MultiStream {
        assert!(mean >= 1.0, "mean burst must be at least 1");
        self.mean_burst = mean;
        self
    }

    /// Traffic skew exponent (`≥ 1`; 1 = uniform popularity).
    pub fn with_skew(mut self, skew: f64) -> MultiStream {
        assert!(skew >= 1.0, "skew exponent must be at least 1");
        self.skew = skew;
        self
    }

    /// Number of streams in the fleet.
    pub fn stream_count(&self) -> usize {
        self.gens.len()
    }

    /// Events emitted so far on a stream (by vector index).
    pub fn emitted(&self, idx: usize) -> u64 {
        self.gens[idx].emitted
    }

    /// Emit the next `(stream_id, score, label)` event.
    pub fn next_event(&mut self) -> (u64, f64, bool) {
        if self.burst_left == 0 {
            let u = self.pick.uniform();
            let idx = (u.powf(self.skew) * self.gens.len() as f64) as usize;
            self.current = idx.min(self.gens.len() - 1);
            // Geometric burst length with the configured mean, capped
            // so a pathological draw cannot starve the other streams.
            let continue_p = 1.0 - 1.0 / self.mean_burst;
            let cap = (64.0 * self.mean_burst) as u32;
            self.burst_left = 1;
            while self.burst_left < cap && self.pick.chance(continue_p) {
                self.burst_left += 1;
            }
        }
        self.burst_left -= 1;
        self.gens[self.current].emit()
    }

    /// Emit a batch of `n` events (the fleet-ingestion unit).
    pub fn next_batch(&mut self, n: usize) -> Vec<(u64, f64, bool)> {
        (0..n).map(|_| self.next_event()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::NaiveAuc;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Dataset::new(miniboone_like().scaled(100), 7);
        let mut b = Dataset::new(miniboone_like().scaled(100), 7);
        for _ in 0..50 {
            let (ea, eb) = (a.example(), b.example());
            assert_eq!(ea.features, eb.features);
            assert_eq!(ea.label, eb.label);
        }
    }

    #[test]
    fn pos_rate_respected() {
        for spec in paper_datasets() {
            let rate = spec.pos_rate;
            let mut d = Dataset::new(spec, 1);
            let n = 20_000;
            let pos = (0..n).filter(|_| d.example().label).count();
            let got = pos as f64 / n as f64;
            assert!((got - rate).abs() < 0.02, "{}: {got} vs {rate}", d.spec().name);
        }
    }

    #[test]
    fn analytic_scores_discriminate_as_specified() {
        // Separation ordering must translate into AUC ordering, with
        // hepmass clearly high and tvads clearly lower.
        let mut aucs = std::collections::HashMap::new();
        for spec in paper_datasets() {
            let name = spec.name;
            let mut d = Dataset::new(spec, 3);
            let pairs = d.score_stream(8000);
            aucs.insert(name, NaiveAuc::of(&pairs));
        }
        let (h, m, t) = (aucs["hepmass"], aucs["miniboone"], aucs["tvads"]);
        assert!(h > 0.90, "hepmass AUC {h}");
        assert!(m > 0.75 && m < h, "miniboone AUC {m}");
        assert!(t > 0.60 && t < m, "tvads AUC {t}");
    }

    #[test]
    fn quantization_produces_duplicates() {
        let mut d = Dataset::new(tvads_like().scaled(100), 5);
        let pairs = d.score_stream(2000);
        let mut scores: Vec<f64> = pairs.iter().map(|p| p.0).collect();
        scores.sort_by(f64::total_cmp);
        scores.dedup();
        assert!(
            scores.len() <= 256,
            "tvads must quantize to ≤256 levels, got {}",
            scores.len()
        );
        let mut d = Dataset::new(hepmass_like().scaled(1000), 5);
        let pairs = d.score_stream(2000);
        let mut scores: Vec<f64> = pairs.iter().map(|p| p.0).collect();
        scores.sort_by(f64::total_cmp);
        scores.dedup();
        assert!(scores.len() > 1900, "hepmass scores continuous");
    }

    #[test]
    fn scores_are_valid_probabilities() {
        for spec in paper_datasets() {
            let mut d = Dataset::new(spec.scaled(100), 9);
            for (s, _) in d.score_stream(1000) {
                assert!((0.0..=1.0).contains(&s), "score {s} out of range");
            }
        }
    }

    #[test]
    fn scaled_reduces_sizes() {
        let s = hepmass_like().scaled(1000);
        assert_eq!(s.train_size, 500);
        assert_eq!(s.test_size, 3500);
        let tiny = hepmass_like().scaled(usize::MAX);
        assert_eq!(tiny.train_size, 100);
    }

    #[test]
    fn table1_sizes_match_paper() {
        let specs = paper_datasets();
        assert_eq!(specs[0].train_size, 500_000);
        assert_eq!(specs[0].test_size, 3_500_000);
        assert_eq!(specs[1].train_size, 30_064);
        assert_eq!(specs[1].test_size, 100_000);
        assert_eq!(specs[2].train_size, 40_265);
        assert_eq!(specs[2].test_size, 89_420);
    }

    // ---- multi-stream fleet generator --------------------------------

    #[test]
    fn multi_stream_deterministic_and_in_range() {
        let mut a = MultiStream::new(20, 7);
        let mut b = MultiStream::new(20, 7);
        for _ in 0..500 {
            let (ea, eb) = (a.next_event(), b.next_event());
            assert_eq!(ea, eb);
            assert!(ea.0 < 20, "stream id out of range");
            assert!((0.0..=1.0).contains(&ea.1), "score {}", ea.1);
        }
        let mut c = MultiStream::new(20, 8);
        let same = (0..200).filter(|_| b.next_event() == c.next_event()).count();
        assert!(same < 20, "different seeds should diverge");
    }

    #[test]
    fn multi_stream_covers_all_streams() {
        let n = 50;
        let mut gen = MultiStream::new(n, 11).with_mean_burst(4.0);
        let batch = gen.next_batch(20_000);
        assert_eq!(batch.len(), 20_000);
        let mut seen = vec![0u32; n];
        for (id, _, _) in &batch {
            seen[*id as usize] += 1;
        }
        assert!(seen.iter().all(|&c| c > 0), "cold streams never emitted: {seen:?}");
    }

    #[test]
    fn bursts_produce_same_stream_runs() {
        let mut gen = MultiStream::new(100, 13).with_mean_burst(16.0);
        let batch = gen.next_batch(10_000);
        let switches = batch.windows(2).filter(|w| w[0].0 != w[1].0).count();
        // Mean burst 16 ⇒ roughly 10_000/16 switches; far below the
        // ~9_900 a memoryless uniform draw over 100 streams would give.
        assert!(switches < 2_000, "traffic not bursty: {switches} switches");
    }

    #[test]
    fn skew_concentrates_on_low_ids() {
        let n = 100;
        let mut gen = MultiStream::new(n, 17).with_skew(3.0).with_mean_burst(2.0);
        let batch = gen.next_batch(30_000);
        let head = batch.iter().filter(|e| e.0 < 10).count();
        // u^3 puts ~46% of draws below 0.1; uniform would put 10%.
        assert!(
            head > batch.len() / 4,
            "skew 3.0 should concentrate on the head, got {head}/30000"
        );
    }

    #[test]
    fn healthy_streams_have_high_auc() {
        let mut gen = MultiStream::new(4, 23);
        let batch = gen.next_batch(12_000);
        for id in 0..4u64 {
            let pairs: Vec<(f64, bool)> =
                batch.iter().filter(|e| e.0 == id).map(|e| (e.1, e.2)).collect();
            assert!(pairs.len() > 1000, "stream {id} underfed: {}", pairs.len());
            let auc = NaiveAuc::of(&pairs);
            assert!(auc > 0.85, "stream {id}: healthy AUC only {auc}");
        }
    }

    #[test]
    fn abrupt_drift_degrades_after_the_point() {
        let profile = StreamProfile::healthy(0)
            .with_drift(DriftSchedule::Abrupt { at: 3000, rate: 0.6 });
        let mut gen = MultiStream::with_profiles(vec![profile], 29);
        let batch = gen.next_batch(6000);
        let before: Vec<(f64, bool)> = batch[..3000].iter().map(|e| (e.1, e.2)).collect();
        let after: Vec<(f64, bool)> = batch[3000..].iter().map(|e| (e.1, e.2)).collect();
        let (clean, broken) = (NaiveAuc::of(&before), NaiveAuc::of(&after));
        assert!(clean > 0.85, "pre-drift AUC {clean}");
        assert!(broken < 0.65, "post-drift AUC {broken} should collapse");
    }

    #[test]
    fn gradual_drift_ramps() {
        let s = DriftSchedule::Gradual { from: 100, to: 300, rate: 0.5 };
        assert_eq!(s.flip_rate(0), 0.0);
        assert_eq!(s.flip_rate(100), 0.0);
        assert!((s.flip_rate(200) - 0.25).abs() < 1e-12);
        assert_eq!(s.flip_rate(300), 0.5);
        assert_eq!(s.flip_rate(10_000), 0.5);
        assert_eq!(DriftSchedule::None.flip_rate(9), 0.0);
        assert_eq!(DriftSchedule::Abrupt { at: 5, rate: 0.3 }.flip_rate(4), 0.0);
        assert_eq!(DriftSchedule::Abrupt { at: 5, rate: 0.3 }.flip_rate(5), 0.3);
    }

    #[test]
    fn quantized_profiles_duplicate_scores() {
        let profile = StreamProfile::healthy(0).quantized(16);
        let mut gen = MultiStream::with_profiles(vec![profile], 31);
        let batch = gen.next_batch(2000);
        let mut scores: Vec<f64> = batch.iter().map(|e| e.1).collect();
        scores.sort_by(f64::total_cmp);
        scores.dedup();
        assert!(scores.len() <= 16, "expected ≤16 levels, got {}", scores.len());
    }
}
