//! Serving layer: the fleet's query surface over the wire.
//!
//! [`FleetServer`] puts an [`crate::fleet::AucFleet`] behind a
//! `std::net::TcpListener` and exposes every incremental read —
//! snapshot, aggregate, worst-k, count-below, both histograms — plus
//! a subscription stream that pushes one sketch delta per ingestion
//! drain. Two protocols share the port, routed by the first byte:
//!
//! * **HTTP/1.1** (`GET`-only, keep-alive): `/snapshot`, `/aggregate`,
//!   `/top_k_worst?k=`, `/count_below?t=`, `/auc_histogram?bins=`,
//!   `/score_histogram?bins=`, `/subscribe` (streaming ndjson).
//! * **Binary** (magic `0xAB 'S' 'A' '1'`, then
//!   `[opcode][u32 len][payload]` frames): the same queries with
//!   fixed little-endian payloads.
//!
//! The front-end is bounded and deadline-driven: a fixed pool of
//! connection workers fed by a bounded accept queue (overflow is shed
//! with HTTP 503 / a `STATUS_BUSY` frame), read/write timeouts plus a
//! per-request deadline budget on every socket (`limits`), and
//! sketch-answerable reads served from an epoch-swapped
//! [`PublishedView`] with zero fleet-lock acquisitions (`publish`).
//! Every response echoes the publication `seq` it answers at, and
//! subscribers ride per-subscriber bounded queues with a lag-coalescing
//! resync policy — one stuck client can never stall ingestion or the
//! other readers. Tune with [`ServeLimits`] via
//! [`FleetServer::start_with`].
//!
//! Everything is hand-rolled on `std` — the build is offline, so there
//! is no HTTP or serialization dependency to reach for. The codecs are
//! lossless by construction (shortest-round-trip decimals in JSON, raw
//! `f64` bits in binary), which upgrades "the server answers queries"
//! to "a wire response decodes bit-identical to the in-process answer
//! at the echoed seq" — the property `rust/tests/serve.rs` and the
//! executor digest harness pin down. Protocol grammar and the
//! delta-subscription semantics are specified in `rust/DESIGN.md`
//! §Serving.

mod client;
pub mod json;
mod limits;
mod publish;
mod server;
pub mod wire;

pub use client::{http_get, http_subscribe, BinClient, HttpClient, SubEvent};
pub use limits::ServeLimits;
pub use publish::PublishedView;
pub use server::{FleetServer, MAX_HEAD_BYTES};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fleet::{AucFleet, FleetConfig};

    fn tiny_fleet() -> AucFleet {
        let mut fleet = AucFleet::new(FleetConfig::default());
        for round in 0..4u64 {
            let batch: Vec<(u64, f64, bool)> = (1..=6u64)
                .map(|id| {
                    let score = (id as f64) / 7.0;
                    (id, score, (id + round) % 2 == 0)
                })
                .collect();
            fleet.push_batch(&batch);
        }
        fleet
    }

    #[test]
    fn http_and_binary_share_one_port() {
        let server = FleetServer::start(tiny_fleet(), "127.0.0.1:0").expect("bind loopback");
        let addr = server.local_addr();

        let (status, body) = http_get(addr, "/aggregate").expect("http round-trip");
        assert_eq!(status, 200);
        let via_http = json::aggregate_from_json(&body).expect("decodable body");

        let mut bin = BinClient::connect(addr).expect("binary session");
        let (code, payload) = bin.request(wire::OP_AGGREGATE, &[]).expect("binary round-trip");
        assert_eq!(code, wire::STATUS_OK);
        let via_bin = wire::decode_aggregate(&payload).expect("decodable payload");

        let in_process = server.with_fleet(|f| f.aggregate());
        assert_eq!(via_http, in_process);
        assert_eq!(via_bin, in_process);
    }

    #[test]
    fn shutdown_is_idempotent_and_runs_on_drop() {
        let mut server = FleetServer::start(tiny_fleet(), "127.0.0.1:0").expect("bind loopback");
        server.shutdown();
        server.shutdown();
        drop(server); // shutdown again via Drop — must not hang
    }
}
