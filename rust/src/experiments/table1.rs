//! Table 1: basic characteristics of the benchmark datasets.
//!
//! Reprints the paper's Table 1 (train/test sizes) for the synthetic
//! stand-ins and appends measured properties that justify the
//! substitution: positive rate, full-stream AUC of the analytic scores,
//! and the number of distinct score levels (the duplicate regime).

use super::report::{fmt_sci, Table};
use super::ExpConfig;
use crate::coordinator::NaiveAuc;
use crate::stream::synth::{paper_datasets, Dataset};

/// Build the Table 1 reproduction.
pub fn run(cfg: ExpConfig) -> Table {
    let mut table = Table::new(
        "table1: dataset characteristics (paper sizes, measured stream stats)",
        &["dataset", "train", "test", "sampled", "pos_rate", "auc", "distinct_scores"],
    );
    for spec in paper_datasets() {
        let name = spec.name;
        let (train, test) = (spec.train_size, spec.test_size);
        let sample = cfg.events.min(test);
        let mut data = Dataset::new(spec, cfg.seed);
        let stream = data.score_stream(sample);
        let pos = stream.iter().filter(|p| p.1).count();
        let auc = NaiveAuc::of(&stream);
        let mut scores: Vec<f64> = stream.iter().map(|p| p.0).collect();
        scores.sort_by(f64::total_cmp);
        scores.dedup();
        table.push(vec![
            name.to_string(),
            train.to_string(),
            test.to_string(),
            sample.to_string(),
            fmt_sci(pos as f64 / sample as f64),
            fmt_sci(auc),
            scores.len().to_string(),
        ]);
    }
    table
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reproduces_paper_sizes_and_regimes() {
        let cfg = ExpConfig { events: 5000, ..Default::default() };
        let t = run(cfg);
        assert_eq!(t.rows.len(), 3);
        // Paper sizes present verbatim.
        assert_eq!(t.rows[0][1], "500000");
        assert_eq!(t.rows[0][2], "3500000");
        assert_eq!(t.rows[1][1], "30064");
        assert_eq!(t.rows[2][2], "89420");
        // Tvads row must show the quantized (duplicate-heavy) regime.
        let tvads_distinct: usize = t.rows[2][6].parse().unwrap();
        assert!(tvads_distinct <= 256);
        let hepmass_distinct: usize = t.rows[0][6].parse().unwrap();
        assert!(hepmass_distinct > 4000);
    }
}
