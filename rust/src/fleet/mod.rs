//! Multi-stream AUC fleet engine — the service layer over the paper's
//! estimator.
//!
//! The §4 machinery maintains *one* `ε/2`-approximate window in
//! `O((log k)/ε)` per update. A production monitoring system maintains
//! one such window **per user / model / segment** — thousands to
//! millions of concurrent streams under bursty traffic. [`AucFleet`]
//! owns that multiplexing:
//!
//! * **Shard-level storage** — streams live in `2^s` shards selected by
//!   a mixed hash of the stream id. Each shard packs its stream states
//!   into a dense slab (`Vec`) with a side index, so a hot stream's
//!   working set stays contiguous and cold shards stay untouched —
//!   hot streams don't evict cold ones from cache.
//! * **Batched ingestion** — [`AucFleet::push_batch`] buckets a batch
//!   by shard (reusing per-shard scratch buffers across calls), then
//!   drains shard by shard, resolving the stream-id → slot lookup once
//!   per *run* of same-stream events. Bursty traffic produces long
//!   runs, so the per-event dispatch cost (hash + map probe) amortizes
//!   away and consecutive updates hit a warm window. `benches/fleet.rs`
//!   measures the batched-vs-one-at-a-time gap at 1 / 100 / 10 000
//!   streams.
//! * **Per-stream configuration** — window size `k`, accuracy `ε` and
//!   drift-monitor parameters default from
//!   [`FleetConfig::stream_defaults`] and can be overridden per stream
//!   ([`AucFleet::configure_stream`]).
//! * **Fleet-wide observability** — every monitored stream feeds its
//!   windowed estimate into an [`AucMonitor`]; alarms accumulate in a
//!   fleet-level log ([`AucFleet::alarms`], [`AucFleet::take_alarms`])
//!   and [`AucFleet::snapshot`] returns the current AUC of every
//!   stream plus the set currently alarmed.
//!
//! ```
//! use streamauc::fleet::AucFleet;
//!
//! let mut fleet = AucFleet::with_defaults();
//! fleet.push_batch(&[(7, 0.2, true), (7, 0.8, false), (9, 0.4, true)]);
//! assert_eq!(fleet.stream_count(), 2);
//! assert_eq!(fleet.auc(7), Some(1.0)); // positives score low: perfect
//! assert_eq!(fleet.auc(9), Some(0.5)); // single class: undefined ⇒ ½
//! ```

mod config;
mod snapshot;

pub use config::{FleetConfig, MonitorConfig, StreamConfig};
pub use snapshot::{FleetAlarm, FleetSnapshot, StreamSnapshot};

use std::collections::HashMap;

use crate::coordinator::window::Window;
use crate::coordinator::{ApproxAuc, AucMonitor, MonitorEvent};

/// One stream's state: sliding estimator window plus optional monitor.
#[derive(Clone, Debug)]
struct StreamState {
    id: u64,
    win: Window<ApproxAuc>,
    monitor: Option<AucMonitor>,
    events: u64,
    alarms: u32,
}

impl StreamState {
    fn new(id: u64, cfg: &StreamConfig) -> StreamState {
        StreamState {
            id,
            win: Window::with_estimator(cfg.window, ApproxAuc::new(cfg.epsilon)),
            monitor: cfg.monitor.map(|m| m.build()),
            events: 0,
            alarms: 0,
        }
    }
}

/// One shard: dense stream slab + id index.
#[derive(Clone, Debug, Default)]
struct Shard {
    streams: Vec<StreamState>,
    index: HashMap<u64, u32>,
}

/// A fleet of independent sliding-window AUC estimators keyed by
/// stream id. See the module docs for the design.
#[derive(Clone, Debug)]
pub struct AucFleet {
    shards: Vec<Shard>,
    /// `shards.len() - 1`; shard count is a power of two.
    mask: u64,
    defaults: StreamConfig,
    overrides: HashMap<u64, StreamConfig>,
    /// Per-shard batch buckets, reused across `push_batch` calls.
    scratch: Vec<Vec<(u64, f64, bool)>>,
    total_events: u64,
    alarm_log: Vec<FleetAlarm>,
}

/// splitmix64 finalizer: decorrelates sequential / structured stream
/// ids before the power-of-two shard mask.
#[inline]
fn mix64(mut x: u64) -> u64 {
    x ^= x >> 30;
    x = x.wrapping_mul(0xbf58476d1ce4e5b9);
    x ^= x >> 27;
    x = x.wrapping_mul(0x94d049bb133111eb);
    x ^ (x >> 31)
}

impl AucFleet {
    /// New fleet from a configuration.
    pub fn new(cfg: FleetConfig) -> AucFleet {
        let shards = cfg.shards.max(1).next_power_of_two();
        AucFleet {
            shards: (0..shards).map(|_| Shard::default()).collect(),
            mask: shards as u64 - 1,
            defaults: cfg.stream_defaults,
            overrides: HashMap::new(),
            scratch: (0..shards).map(|_| Vec::new()).collect(),
            total_events: 0,
            alarm_log: Vec::new(),
        }
    }

    /// New fleet with [`FleetConfig::default`].
    pub fn with_defaults() -> AucFleet {
        AucFleet::new(FleetConfig::default())
    }

    #[inline]
    fn shard_of(&self, id: u64) -> usize {
        (mix64(id) & self.mask) as usize
    }

    /// Register a per-stream configuration override. If the stream is
    /// already live its state is **reset** under the new configuration
    /// (window contents, monitor state and alarm counters start fresh);
    /// otherwise the override applies on the stream's first event.
    pub fn configure_stream(&mut self, id: u64, cfg: StreamConfig) {
        let s = self.shard_of(id);
        let shard = &mut self.shards[s];
        if let Some(&slot) = shard.index.get(&id) {
            shard.streams[slot as usize] = StreamState::new(id, &cfg);
        }
        self.overrides.insert(id, cfg);
    }

    /// Effective configuration for a stream (override or defaults).
    pub fn stream_config(&self, id: u64) -> StreamConfig {
        self.overrides.get(&id).copied().unwrap_or(self.defaults)
    }

    /// Slot of `id` in shard `s`, creating the stream on first contact.
    fn ensure_slot(&mut self, s: usize, id: u64) -> usize {
        if let Some(&slot) = self.shards[s].index.get(&id) {
            return slot as usize;
        }
        let cfg = self.overrides.get(&id).copied().unwrap_or(self.defaults);
        let shard = &mut self.shards[s];
        let slot = shard.streams.len();
        shard.streams.push(StreamState::new(id, &cfg));
        shard.index.insert(id, slot as u32);
        slot
    }

    /// Ingest one event into a resolved stream slot: window update plus
    /// monitor observation (only on full windows, so partially filled
    /// streams never alarm on warm-up noise).
    fn push_at(&mut self, s: usize, slot: usize, score: f64, label: bool) {
        let st = &mut self.shards[s].streams[slot];
        st.win.push(score, label);
        st.events += 1;
        self.total_events += 1;
        if st.win.is_full() {
            if let Some(m) = st.monitor.as_mut() {
                let auc = st.win.auc();
                if m.observe(auc) == MonitorEvent::Alarm {
                    st.alarms += 1;
                    let alarm = FleetAlarm {
                        stream: st.id,
                        stream_event: st.events,
                        auc,
                        baseline: m.baseline(),
                    };
                    self.alarm_log.push(alarm);
                }
            }
        }
    }

    /// Ingest one `(stream, score, label)` event. The one-at-a-time
    /// path: full dispatch (hash + index probe) on every call. Prefer
    /// [`AucFleet::push_batch`] under load.
    pub fn push(&mut self, stream: u64, score: f64, label: bool) {
        let s = self.shard_of(stream);
        let slot = self.ensure_slot(s, stream);
        self.push_at(s, slot, score, label);
    }

    /// Ingest a batch of `(stream, score, label)` events.
    ///
    /// Events are bucketed per shard, then each shard is drained in
    /// arrival order with the stream lookup resolved once per run of
    /// same-stream events. Per-stream event order is preserved, so
    /// every *per-stream* outcome (window contents, AUC, monitor
    /// state, alarms) is identical to pushing one at a time; only the
    /// interleaving of the fleet-wide [`AucFleet::alarms`] log across
    /// *different* streams within one batch may differ from strict
    /// arrival order.
    pub fn push_batch(&mut self, batch: &[(u64, f64, bool)]) {
        for bucket in &mut self.scratch {
            bucket.clear();
        }
        for &(id, score, label) in batch {
            let s = self.shard_of(id);
            self.scratch[s].push((id, score, label));
        }
        for s in 0..self.shards.len() {
            if self.scratch[s].is_empty() {
                continue;
            }
            // Take the bucket out so `push_at(&mut self)` can run while
            // we iterate it; hand the allocation back afterwards.
            let bucket = std::mem::take(&mut self.scratch[s]);
            let mut i = 0;
            while i < bucket.len() {
                let id = bucket[i].0;
                let mut j = i + 1;
                while j < bucket.len() && bucket[j].0 == id {
                    j += 1;
                }
                let slot = self.ensure_slot(s, id);
                for &(_, score, label) in &bucket[i..j] {
                    self.push_at(s, slot, score, label);
                }
                i = j;
            }
            self.scratch[s] = bucket;
        }
    }

    fn find(&self, id: u64) -> Option<&StreamState> {
        let shard = &self.shards[self.shard_of(id)];
        shard.index.get(&id).map(|&slot| &shard.streams[slot as usize])
    }

    /// Current windowed AUC estimate of a stream (`None` if unseen).
    pub fn auc(&self, id: u64) -> Option<f64> {
        self.find(id).map(|st| st.win.auc())
    }

    /// Pairs currently in a stream's window (`None` if unseen).
    pub fn stream_len(&self, id: u64) -> Option<usize> {
        self.find(id).map(|st| st.win.len())
    }

    /// A stream's window contents, oldest first (`None` if unseen).
    /// Test / audit helper: lets callers recompute the exact AUC over
    /// the identical window.
    pub fn entries(&self, id: u64) -> Option<impl Iterator<Item = (f64, bool)> + '_> {
        self.find(id).map(|st| st.win.entries())
    }

    /// True while a stream's monitor is inside an alarmed excursion.
    pub fn is_alarmed(&self, id: u64) -> bool {
        self.find(id)
            .and_then(|st| st.monitor.as_ref())
            .map_or(false, AucMonitor::is_alarmed)
    }

    /// True once a stream has been seen.
    pub fn contains(&self, id: u64) -> bool {
        self.find(id).is_some()
    }

    /// Number of live streams across all shards.
    pub fn stream_count(&self) -> usize {
        self.shards.iter().map(|s| s.streams.len()).sum()
    }

    /// Total events ingested across the fleet.
    pub fn total_events(&self) -> u64 {
        self.total_events
    }

    /// Shard count (power of two).
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// Streams per shard (balance diagnostics).
    pub fn shard_sizes(&self) -> Vec<usize> {
        self.shards.iter().map(|s| s.streams.len()).collect()
    }

    /// Alarms accumulated since construction (or the last
    /// [`AucFleet::take_alarms`]), in firing order.
    pub fn alarms(&self) -> &[FleetAlarm] {
        &self.alarm_log
    }

    /// Drain the alarm log.
    pub fn take_alarms(&mut self) -> Vec<FleetAlarm> {
        std::mem::take(&mut self.alarm_log)
    }

    /// Point-in-time snapshot of every stream: AUC, window fill, `|C|`,
    /// alarm state. Streams are sorted by id. `O(total |C|)`.
    pub fn snapshot(&self) -> FleetSnapshot {
        let mut streams = Vec::with_capacity(self.stream_count());
        for shard in &self.shards {
            for st in &shard.streams {
                streams.push(StreamSnapshot {
                    stream: st.id,
                    auc: st.win.auc(),
                    len: st.win.len(),
                    compressed_len: st.win.estimator().compressed_len(),
                    events: st.events,
                    alarms: st.alarms,
                    alarmed: st.monitor.as_ref().map_or(false, AucMonitor::is_alarmed),
                    baseline: st.monitor.as_ref().map(AucMonitor::baseline),
                });
            }
        }
        streams.sort_by_key(|s| s.stream);
        let alarmed_streams = streams.iter().filter(|s| s.alarmed).map(|s| s.stream).collect();
        FleetSnapshot { streams, alarmed_streams, total_events: self.total_events }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::NaiveAuc;
    use crate::testing::Pcg;

    fn small_fleet(window: usize, epsilon: f64) -> AucFleet {
        AucFleet::new(FleetConfig {
            shards: 8,
            stream_defaults: StreamConfig::new(window, epsilon),
        })
    }

    /// Deterministic event soup over `n_streams` streams.
    fn soup(n_streams: u64, events: usize, seed: u64) -> Vec<(u64, f64, bool)> {
        let mut rng = Pcg::seed(seed);
        (0..events)
            .map(|_| {
                let id = rng.below(n_streams);
                let pos = rng.chance(0.5);
                // Separable per-stream scores so AUCs are interesting.
                let s = if pos { rng.normal_with(0.35, 0.15) } else { rng.normal_with(0.65, 0.15) };
                (id, s, pos)
            })
            .collect()
    }

    #[test]
    fn batched_equals_one_at_a_time() {
        let events = soup(17, 4000, 0xBA7C);
        let mut one = small_fleet(100, 0.1);
        let mut bat = small_fleet(100, 0.1);
        for &(id, s, l) in &events {
            one.push(id, s, l);
        }
        for chunk in events.chunks(257) {
            bat.push_batch(chunk);
        }
        assert_eq!(one.stream_count(), bat.stream_count());
        assert_eq!(one.total_events(), bat.total_events());
        // The fleet-wide log may interleave streams differently across
        // a batch; per-stream alarm sequences must match exactly.
        let by_stream = |alarms: &[FleetAlarm]| {
            let mut v = alarms.to_vec();
            v.sort_by_key(|a| (a.stream, a.stream_event));
            v
        };
        assert_eq!(by_stream(one.alarms()), by_stream(bat.alarms()));
        for id in 0..17 {
            assert_eq!(one.auc(id), bat.auc(id), "stream {id} AUC diverged");
            assert_eq!(one.stream_len(id), bat.stream_len(id));
            let a: Vec<_> = one.entries(id).unwrap().collect();
            let b: Vec<_> = bat.entries(id).unwrap().collect();
            assert_eq!(a, b, "stream {id} window contents diverged");
        }
    }

    #[test]
    fn streams_are_isolated() {
        let mut fleet = small_fleet(50, 0.05);
        // Stream 1: perfectly separated. Stream 2: adversarial noise.
        let mut rng = Pcg::seed(3);
        for _ in 0..200 {
            fleet.push(1, 0.2, true);
            fleet.push(1, 0.8, false);
            fleet.push(2, rng.uniform(), rng.chance(0.5));
        }
        assert_eq!(fleet.auc(1), Some(1.0), "noise in stream 2 leaked into stream 1");
        assert_eq!(fleet.stream_len(1), Some(50));
    }

    #[test]
    fn windows_evict_fifo_per_stream() {
        let mut fleet = small_fleet(3, 0.1);
        for (i, id) in [(1, 7u64), (2, 9), (3, 7), (4, 7), (5, 7)] {
            fleet.push(id, f64::from(i), true);
        }
        // Stream 7 saw scores 1, 3, 4, 5 with capacity 3 → {3, 4, 5}.
        let got: Vec<f64> = fleet.entries(7).unwrap().map(|(s, _)| s).collect();
        assert_eq!(got, vec![3.0, 4.0, 5.0]);
        assert_eq!(fleet.stream_len(9), Some(1));
    }

    #[test]
    fn per_stream_config_overrides_apply() {
        let mut fleet = small_fleet(100, 0.0);
        fleet.configure_stream(5, StreamConfig::new(10, 0.0).without_monitor());
        let events = soup(1, 300, 9); // all events on stream 0…
        for &(_, s, l) in &events {
            fleet.push(0, s, l); // …default config
            fleet.push(5, s, l); // …override
        }
        assert_eq!(fleet.stream_len(0), Some(100));
        assert_eq!(fleet.stream_len(5), Some(10), "override window ignored");
        assert_eq!(fleet.stream_config(5).window, 10);
        assert_eq!(fleet.stream_config(0).window, 100);
    }

    #[test]
    fn configure_resets_live_stream() {
        let mut fleet = small_fleet(50, 0.1);
        for i in 0..40 {
            fleet.push(3, f64::from(i) / 40.0, i % 2 == 0);
        }
        assert_eq!(fleet.stream_len(3), Some(40));
        fleet.configure_stream(3, StreamConfig::new(20, 0.1));
        assert_eq!(fleet.stream_len(3), Some(0), "reconfigure must reset the window");
        fleet.push(3, 0.5, true);
        assert_eq!(fleet.stream_len(3), Some(1));
    }

    #[test]
    fn estimates_track_naive_oracle_per_stream() {
        let eps = 0.1;
        let events = soup(11, 6000, 0x0A7E);
        let mut fleet = small_fleet(120, eps);
        for chunk in events.chunks(512) {
            fleet.push_batch(chunk);
        }
        for id in 0..11 {
            let window: Vec<(f64, bool)> = fleet.entries(id).unwrap().collect();
            let truth = NaiveAuc::of(&window);
            let est = fleet.auc(id).unwrap();
            assert!(
                (est - truth).abs() <= eps * truth / 2.0 + 1e-12,
                "stream {id}: est {est} vs naive {truth}"
            );
        }
    }

    #[test]
    fn monitor_alarms_surface_in_log_and_snapshot() {
        let mut fleet = AucFleet::new(FleetConfig {
            shards: 4,
            stream_defaults: StreamConfig {
                window: 100,
                epsilon: 0.1,
                monitor: Some(MonitorConfig {
                    lambda: 0.001,
                    margin: 0.08,
                    patience: 20,
                    warmup: 100,
                }),
            },
        });
        let mut rng = Pcg::seed(0xA1A);
        // Healthy phase on both streams.
        for _ in 0..1500 {
            for id in [1u64, 2] {
                let pos = rng.chance(0.5);
                let s = if pos { rng.normal_with(0.3, 0.1) } else { rng.normal_with(0.7, 0.1) };
                fleet.push(id, s, pos);
            }
        }
        assert!(fleet.alarms().is_empty(), "healthy phase must not alarm");
        // Stream 2 breaks: labels decouple from scores.
        for _ in 0..1500 {
            let pos = rng.chance(0.5);
            let s = if pos { rng.normal_with(0.3, 0.1) } else { rng.normal_with(0.7, 0.1) };
            fleet.push(1, s, pos);
            fleet.push(2, rng.uniform(), rng.chance(0.5));
        }
        let alarmed: Vec<u64> = fleet.alarms().iter().map(|a| a.stream).collect();
        assert!(alarmed.contains(&2), "broken stream must alarm");
        assert!(!alarmed.contains(&1), "healthy stream must stay quiet");
        assert!(fleet.is_alarmed(2));
        assert!(!fleet.is_alarmed(1));
        let snap = fleet.snapshot();
        assert_eq!(snap.alarmed_streams, vec![2]);
        let drained = fleet.take_alarms();
        assert!(!drained.is_empty());
        assert!(fleet.alarms().is_empty());
    }

    #[test]
    fn snapshot_is_sorted_and_complete() {
        let mut fleet = small_fleet(30, 0.2);
        let events = soup(23, 2000, 0x51AB);
        fleet.push_batch(&events);
        let snap = fleet.snapshot();
        assert_eq!(snap.streams.len(), fleet.stream_count());
        assert_eq!(snap.total_events, 2000);
        let ids: Vec<u64> = snap.streams.iter().map(|s| s.stream).collect();
        let mut sorted = ids.clone();
        sorted.sort_unstable();
        assert_eq!(ids, sorted, "snapshot must be id-sorted");
        for s in &snap.streams {
            assert!(s.len <= 30);
            assert!(s.compressed_len >= 2);
            assert!((0.0..=1.0).contains(&s.auc));
        }
        assert!(snap.mean_auc() > 0.5, "separable soup should score above chance");
    }

    #[test]
    fn sharding_spreads_streams() {
        let mut fleet = AucFleet::new(FleetConfig {
            shards: 16,
            stream_defaults: StreamConfig::new(10, 0.5).without_monitor(),
        });
        // Sequential ids — the adversarial pattern for naive modulo.
        for id in 0..1600u64 {
            fleet.push(id, 0.5, true);
        }
        assert_eq!(fleet.shard_count(), 16);
        assert_eq!(fleet.stream_count(), 1600);
        let sizes = fleet.shard_sizes();
        let (min, max) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
        assert!(*min > 50 && *max < 200, "unbalanced shards: {sizes:?}");
    }

    #[test]
    fn shard_count_rounds_to_power_of_two() {
        let fleet = AucFleet::new(FleetConfig { shards: 5, ..FleetConfig::default() });
        assert_eq!(fleet.shard_count(), 8);
        let fleet = AucFleet::new(FleetConfig { shards: 0, ..FleetConfig::default() });
        assert_eq!(fleet.shard_count(), 1);
    }

    #[test]
    fn empty_batch_and_unseen_queries() {
        let mut fleet = AucFleet::with_defaults();
        fleet.push_batch(&[]);
        assert_eq!(fleet.stream_count(), 0);
        assert_eq!(fleet.total_events(), 0);
        assert_eq!(fleet.auc(42), None);
        assert_eq!(fleet.stream_len(42), None);
        assert!(!fleet.contains(42));
        assert!(!fleet.is_alarmed(42));
        assert!(fleet.entries(42).is_none());
        assert!(fleet.snapshot().streams.is_empty());
    }
}
