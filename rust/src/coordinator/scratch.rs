//! Weighted data points: from-scratch `(1+ε)`-list construction (§7).
//!
//! The incremental machinery of §4 relies on updates changing counters by
//! exactly 1 (Lemma 1). With *weighted* points that fails, so the paper
//! sketches the alternative implemented here: keep the augmented tree,
//! and at query time build a `(1+ε)`-grouped list from scratch using a
//! new query — *the node `v` with the largest `hp(v) ≤ σ`* — issued with
//! exponentially increasing thresholds. Each query is `O(log k)` (same
//! descent trick as `HeadStats`), the list has `O(log_{1+ε} W)` nodes,
//! giving `O((log² k)/ε)` per AUC evaluation for integer-ish weights.
//!
//! Greedy construction: from the current node `u`, the next threshold is
//! `σ = α·(hp(u) + p(u))`; take the rightmost node with `hp ≤ σ`, or, if
//! that does not advance (the very next node already overshoots), take
//! the immediate successor — mirroring how Eq. 4 lets *pairs* of groups
//! overshoot. Every selected pair then satisfies Eq. 3, so the
//! Proposition 1 argument applies verbatim and the estimate is within
//! `ε·auc/2`.

use crate::collections::{Augment, NodeId, RbTree, Score};

/// Weighted per-score label mass.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct WCounts {
    /// Total positive weight at this score.
    pub wp: f64,
    /// Total negative weight at this score.
    pub wn: f64,
}

/// Weighted subtree sums.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct WAcc {
    /// Subtree positive weight.
    pub pos: f64,
    /// Subtree negative weight.
    pub neg: f64,
}

impl Augment<WCounts> for WAcc {
    #[inline]
    fn recompute(val: &WCounts, left: Option<&Self>, right: Option<&Self>) -> Self {
        WAcc {
            pos: val.wp + left.map_or(0.0, |a| a.pos) + right.map_or(0.0, |a| a.pos),
            neg: val.wn + left.map_or(0.0, |a| a.neg) + right.map_or(0.0, |a| a.neg),
        }
    }
}

/// Weighted-point AUC with from-scratch `(1+ε)`-grouped estimation (§7).
#[derive(Clone, Debug, Default)]
pub struct WeightedAuc {
    t: RbTree<WCounts, WAcc>,
    total_wp: f64,
    total_wn: f64,
    points: usize,
}

impl WeightedAuc {
    /// Empty structure.
    pub fn new() -> Self {
        Self::default()
    }

    /// Number of insert-minus-remove operations currently live.
    pub fn len(&self) -> usize {
        self.points
    }

    /// True when nothing is held.
    pub fn is_empty(&self) -> bool {
        self.points == 0
    }

    /// Total positive / negative weight.
    pub fn totals(&self) -> (f64, f64) {
        (self.total_wp, self.total_wn)
    }

    /// Insert a point with label `pos` and weight `w > 0`. `O(log k)`.
    pub fn insert(&mut self, score: f64, pos: bool, w: f64) {
        assert!(w > 0.0 && w.is_finite(), "weight must be positive and finite");
        let s = Score(score);
        assert!(s.is_valid_entry(), "scores must be finite");
        let init = if pos { WCounts { wp: w, wn: 0.0 } } else { WCounts { wp: 0.0, wn: w } };
        let (v, fresh) = self.t.insert(s, || init);
        if !fresh {
            self.t.with_val_mut(v, |c| if pos { c.wp += w } else { c.wn += w });
        }
        if pos {
            self.total_wp += w;
        } else {
            self.total_wn += w;
        }
        self.points += 1;
    }

    /// Remove weight `w` previously inserted at `(score, pos)`. `O(log k)`.
    pub fn remove(&mut self, score: f64, pos: bool, w: f64) {
        let v = self.t.find(Score(score)).expect("weighted remove: score not present");
        self.t.with_val_mut(v, |c| {
            let slot = if pos { &mut c.wp } else { &mut c.wn };
            assert!(*slot >= w - 1e-9, "weighted remove: more weight than present");
            *slot = (*slot - w).max(0.0);
        });
        let c = *self.t.val(v);
        if c.wp <= 0.0 && c.wn <= 0.0 {
            self.t.remove(v);
        }
        if pos {
            self.total_wp = (self.total_wp - w).max(0.0);
        } else {
            self.total_wn = (self.total_wn - w).max(0.0);
        }
        self.points -= 1;
    }

    /// Exact weighted AUC by full enumeration (Eq. 1 with weights),
    /// `O(k)`.
    pub fn exact_auc(&self) -> f64 {
        let area = self.total_wp * self.total_wn;
        if area <= 0.0 {
            return 0.5;
        }
        let mut hp = 0.0;
        let mut a = 0.0;
        for id in self.t.iter() {
            let c = self.t.val(id);
            a += (hp + 0.5 * c.wp) * c.wn;
            hp += c.wp;
        }
        a / area
    }

    /// §7 query: the node with the largest `hp(v) ≤ σ` (rightmost), via
    /// an `accpos`-guided descent. `O(log k)`.
    fn floor_by_hp(&self, sigma: f64) -> Option<NodeId> {
        let mut cur = self.t.root();
        let mut run = 0.0; // positive weight strictly left of the subtree
        let mut best = None;
        while let Some(v) = cur {
            let left_pos = self.t.left(v).map_or(0.0, |l| self.t.aug(l).pos);
            let hp_v = run + left_pos;
            if hp_v <= sigma {
                best = Some(v);
                run = hp_v + self.t.val(v).wp;
                cur = self.t.right(v);
            } else {
                cur = self.t.left(v);
            }
        }
        best
    }

    /// `hp`/`hn` below a node (weighted `HeadStats`). `O(log k)`.
    fn head_stats(&self, s: Score) -> (f64, f64) {
        let mut hp = 0.0;
        let mut hn = 0.0;
        let mut cur = self.t.root();
        while let Some(v) = cur {
            if self.t.key(v) < s {
                let c = self.t.val(v);
                hp += c.wp;
                hn += c.wn;
                if let Some(l) = self.t.left(v) {
                    let a = self.t.aug(l);
                    hp += a.pos;
                    hn += a.neg;
                }
                cur = self.t.right(v);
            } else {
                cur = self.t.left(v);
            }
        }
        (hp, hn)
    }

    /// Build the from-scratch `(1+ε)` node selection. Returns the chosen
    /// nodes in score order. `O((log k)·m)` where `m` is the list length.
    fn build_selection(&self, epsilon: f64) -> Vec<NodeId> {
        let alpha = 1.0 + epsilon;
        let mut sel = Vec::new();
        let Some(first) = self.t.first() else { return sel };
        sel.push(first);
        let mut u = first;
        let (mut hp_u, _) = (0.0, 0.0);
        loop {
            let pu = self.t.val(u).wp;
            // Smallest meaningful threshold: must at least admit hp(u)+p(u)
            // (the successor's lower bound); α-scale it per Eq. 3.
            let sigma = alpha * (hp_u + pu).max(f64::MIN_POSITIVE);
            let cand = self.floor_by_hp(sigma).unwrap_or(u);
            let next = if self.t.key(cand) > self.t.key(u) {
                cand
            } else {
                match self.t.successor(u) {
                    Some(nxt) => nxt,
                    None => break,
                }
            };
            sel.push(next);
            let (hp_next, _) = self.head_stats(self.t.key(next));
            hp_u = hp_next;
            u = next;
        }
        sel
    }

    /// Approximate weighted AUC within `ε·auc/2`, rebuilding the grouped
    /// list from scratch (§7). `O((log² k)/ε)` for weights bounded below.
    pub fn approx_auc(&self, epsilon: f64) -> f64 {
        assert!(epsilon >= 0.0, "epsilon must be non-negative");
        let area = self.total_wp * self.total_wn;
        if area <= 0.0 {
            return 0.5;
        }
        let sel = self.build_selection(epsilon);
        let mut a = 0.0;
        let mut hp = 0.0;
        for (i, &v) in sel.iter().enumerate() {
            let c = self.t.val(v);
            // Exact node term.
            a += (hp + 0.5 * c.wp) * c.wn;
            hp += c.wp;
            // Grouped gap to the next selected node.
            if let Some(&w) = sel.get(i + 1) {
                let (hp_v, hn_v) = self.head_stats(self.t.key(v));
                let (hp_w, hn_w) = self.head_stats(self.t.key(w));
                let gp = hp_w - hp_v - c.wp;
                let gn = hn_w - hn_v - c.wn;
                a += (hp + 0.5 * gp) * gn;
                hp += gp;
            }
        }
        a / area
    }

    /// Length of the from-scratch selection for a given `ε` (reported by
    /// the extension bench).
    pub fn selection_len(&self, epsilon: f64) -> usize {
        self.build_selection(epsilon).len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::NaiveAuc;
    use crate::testing::{check, Pcg};

    #[test]
    fn unit_weights_match_naive() {
        check(0x57C, 15, |rng| {
            let mut w = WeightedAuc::new();
            let mut naive = NaiveAuc::new();
            use crate::coordinator::AucEstimator;
            for _ in 0..150 {
                let s = rng.below(40) as f64 / 40.0;
                let pos = rng.chance(0.5);
                w.insert(s, pos, 1.0);
                naive.insert(s, pos);
            }
            let (a, b) = (w.exact_auc(), naive.auc());
            assert!((a - b).abs() < 1e-9, "weighted-exact {a} vs naive {b}");
        });
    }

    #[test]
    fn approx_guarantee_weighted() {
        for eps in [0.01, 0.1, 0.5] {
            check(0x3E1 ^ (eps * 100.0) as u64, 10, |rng| {
                let mut w = WeightedAuc::new();
                for _ in 0..300 {
                    let pos = rng.chance(0.4);
                    let s = if pos { rng.normal_with(0.4, 0.2) } else { rng.normal_with(0.6, 0.2) };
                    let weight = 0.5 + rng.uniform() * 4.0;
                    w.insert(s, pos, weight);
                }
                let truth = w.exact_auc();
                let est = w.approx_auc(eps);
                let tol = eps * truth / 2.0 + 1e-9;
                assert!(
                    (est - truth).abs() <= tol,
                    "ε={eps}: est {est}, truth {truth}, tol {tol}"
                );
            });
        }
    }

    #[test]
    fn epsilon_zero_is_exact() {
        let mut rng = Pcg::seed(0xE0E0);
        let mut w = WeightedAuc::new();
        for _ in 0..200 {
            w.insert(rng.uniform(), rng.chance(0.5), 1.0 + rng.uniform());
        }
        assert!((w.approx_auc(0.0) - w.exact_auc()).abs() < 1e-9);
    }

    #[test]
    fn selection_shrinks_with_epsilon() {
        let mut rng = Pcg::seed(0x5E1);
        let mut w = WeightedAuc::new();
        for _ in 0..5000 {
            w.insert(rng.uniform(), rng.chance(0.5), 1.0);
        }
        let small = w.selection_len(1.0);
        let large = w.selection_len(0.01);
        assert!(small < large, "selection must shrink: {small} vs {large}");
        assert!(small < 100, "ε=1 selection should be tiny, got {small}");
    }

    #[test]
    fn remove_weight_roundtrip() {
        let mut w = WeightedAuc::new();
        w.insert(0.3, true, 2.0);
        w.insert(0.7, false, 3.0);
        assert_eq!(w.exact_auc(), 1.0);
        w.remove(0.3, true, 2.0);
        assert_eq!(w.exact_auc(), 0.5);
        w.remove(0.7, false, 3.0);
        assert!(w.is_empty());
    }

    #[test]
    #[should_panic(expected = "positive and finite")]
    fn zero_weight_rejected() {
        WeightedAuc::new().insert(0.5, true, 0.0);
    }

    #[test]
    fn floor_by_hp_brute_force() {
        check(0xF100, 10, |rng| {
            let mut w = WeightedAuc::new();
            let mut pts: Vec<(f64, bool, f64)> = Vec::new();
            for _ in 0..80 {
                let s = rng.below(30) as f64 / 30.0;
                let pos = rng.chance(0.5);
                let weight = 1.0 + rng.below(5) as f64;
                w.insert(s, pos, weight);
                pts.push((s, pos, weight));
            }
            for _ in 0..20 {
                let sigma = rng.uniform() * w.totals().0 * 1.2;
                let got = w.floor_by_hp(sigma).map(|v| w.t.key(v).0);
                // Brute force: rightmost distinct score whose hp ≤ σ.
                let mut scores: Vec<f64> = pts.iter().map(|p| p.0).collect();
                scores.sort_by(f64::total_cmp);
                scores.dedup();
                let mut want = None;
                for &sc in &scores {
                    let hp: f64 = pts.iter().filter(|p| p.1 && p.0 < sc).map(|p| p.2).sum();
                    if hp <= sigma {
                        want = Some(sc);
                    }
                }
                assert_eq!(got, want, "σ={sigma}");
            }
        });
    }
}
