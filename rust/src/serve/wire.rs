//! Compact binary codec and framing for the serving layer.
//!
//! Layout is fixed little-endian with no self-description — both ends
//! are this crate, and the protocol is versioned by the magic
//! preamble. Floats travel as raw `f64::to_bits` words, so wire values
//! are bit-identical to the in-process answers by construction; the
//! 128-bit fixed-point AUC sum is 16 bytes LE; `usize` counters widen
//! to `u64`.
//!
//! A binary session opens with [`MAGIC`] (first byte `0xAB`, which can
//! never begin an HTTP method token — that is how the listener routes
//! the two protocols on one port) and then exchanges frames:
//! `[u8 opcode][u32 LE payload length][payload]`. Requests use the
//! `OP_*` opcodes; every response is a [`STATUS_OK`] frame holding the
//! 8-byte LE publication seq followed by the encoded answer, a
//! [`STATUS_ERR`] frame holding the seq followed by a UTF-8 message,
//! or a [`STATUS_BUSY`] frame when the server is shedding load.
//! Subscriptions additionally push [`OP_DELTA`] frames after the
//! baseline response — or, when the subscriber lags behind the
//! publisher, an [`OP_LAGGED`] notice followed by a fresh
//! [`OP_BASELINE`] coalescing everything missed. Push frames carry
//! their seq inside the payload, not as a prefix.

use crate::fleet::{
    AucHistogram, FleetAggregate, FleetSketch, FleetSnapshot, ScoreHistogram, StreamSnapshot,
};
use std::io::{self, Read, Write};

/// Binary-session preamble; `0xAB` disambiguates from HTTP.
pub const MAGIC: [u8; 4] = [0xAB, b'S', b'A', b'1'];

/// Request: full [`FleetSnapshot`]. Empty payload.
pub const OP_SNAPSHOT: u8 = 1;
/// Request: [`FleetAggregate`]. Empty payload.
pub const OP_AGGREGATE: u8 = 2;
/// Request: worst-k streams. Payload: `u32` k.
pub const OP_TOP_K: u8 = 3;
/// Request: streams with AUC below a threshold. Payload: `f64` bits.
pub const OP_COUNT_BELOW: u8 = 4;
/// Request: [`AucHistogram`]. Payload: `u32` bins (must be ≥ 1).
pub const OP_AUC_HISTOGRAM: u8 = 5;
/// Request: [`ScoreHistogram`]. Payload: `u32` bins (must be ≥ 1).
pub const OP_SCORE_HISTOGRAM: u8 = 6;
/// Request: subscribe to sketch deltas. Empty payload; the OK response
/// carries the baseline `(seq, sketch)`.
pub const OP_SUBSCRIBE: u8 = 7;
/// Server push: one `(seq, sketch-delta)` per ingestion drain.
pub const OP_DELTA: u8 = 8;
/// Server push: a fresh full baseline `(seq, sketch)` replacing
/// everything a lagged subscriber missed (follows an [`OP_LAGGED`]
/// notice; resume applying [`OP_DELTA`]s from its seq).
pub const OP_BASELINE: u8 = 9;
/// Server push: this subscriber lagged and its missed deltas were
/// coalesced. Payload: `u64` LE — the seq of the [`OP_BASELINE`] that
/// follows immediately.
pub const OP_LAGGED: u8 = 10;

/// Response opcode: payload is the 8-byte LE seq echo followed by the
/// encoded answer.
pub const STATUS_OK: u8 = 0;
/// Response opcode: payload is the 8-byte LE seq echo followed by a
/// UTF-8 error message.
pub const STATUS_ERR: u8 = 1;
/// Response opcode: the server is shedding load (connection or
/// subscriber limit reached). Payload like [`STATUS_ERR`]; the server
/// closes the connection after sending it.
pub const STATUS_BUSY: u8 = 2;

/// Upper bound on a frame payload; anything larger is a corrupt or
/// hostile length prefix, not a real answer.
const MAX_FRAME: usize = 1 << 30;

/// Upper bound on a *request* frame payload the server will accept.
/// Every request payload is a few bytes (a `u32` or an `f64`), so
/// anything beyond this is hostile or corrupt — the server answers
/// [`STATUS_ERR`] and closes without reading (or allocating) the
/// claimed length.
pub const MAX_REQUEST_FRAME: usize = 64 << 10;

// ---------------------------------------------------------------------
// Primitives
// ---------------------------------------------------------------------

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_usize(out: &mut Vec<u8>, v: usize) {
    put_u64(out, v as u64);
}

fn put_f64(out: &mut Vec<u8>, v: f64) {
    put_u64(out, v.to_bits());
}

fn put_i128(out: &mut Vec<u8>, v: i128) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_bool(out: &mut Vec<u8>, v: bool) {
    out.push(u8::from(v));
}

fn put_opt_f64(out: &mut Vec<u8>, v: Option<f64>) {
    match v {
        Some(x) => {
            out.push(1);
            put_f64(out, x);
        }
        None => out.push(0),
    }
}

/// Bounds-checked reader over one frame payload.
pub struct Cursor<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Cursor<'a> {
    /// Wrap a payload for decoding.
    pub fn new(b: &'a [u8]) -> Self {
        Cursor { b, i: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], String> {
        let end = self
            .i
            .checked_add(n)
            .filter(|&e| e <= self.b.len())
            .ok_or_else(|| format!("payload truncated at offset {}", self.i))?;
        let s = &self.b[self.i..end];
        self.i = end;
        Ok(s)
    }

    /// Read a `u8`.
    pub fn u8(&mut self) -> Result<u8, String> {
        Ok(self.take(1)?[0])
    }

    /// Read a LE `u32`.
    pub fn u32(&mut self) -> Result<u32, String> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().expect("4 bytes")))
    }

    /// Read a LE `u64`.
    pub fn u64(&mut self) -> Result<u64, String> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().expect("8 bytes")))
    }

    /// Read a `usize` carried as LE `u64`.
    pub fn usize(&mut self) -> Result<usize, String> {
        usize::try_from(self.u64()?).map_err(|_| "count exceeds usize".to_string())
    }

    /// Read an `f64` carried as raw bits.
    pub fn f64(&mut self) -> Result<f64, String> {
        Ok(f64::from_bits(self.u64()?))
    }

    /// Read a LE `i128`.
    pub fn i128(&mut self) -> Result<i128, String> {
        Ok(i128::from_le_bytes(self.take(16)?.try_into().expect("16 bytes")))
    }

    /// Read a `bool` byte (strictly 0 or 1).
    pub fn bool(&mut self) -> Result<bool, String> {
        match self.u8()? {
            0 => Ok(false),
            1 => Ok(true),
            other => Err(format!("invalid bool byte {other}")),
        }
    }

    /// Read a tagged optional `f64`.
    pub fn opt_f64(&mut self) -> Result<Option<f64>, String> {
        if self.bool()? {
            self.f64().map(Some)
        } else {
            Ok(None)
        }
    }

    /// Require that the whole payload was consumed.
    pub fn done(&self) -> Result<(), String> {
        if self.i == self.b.len() {
            Ok(())
        } else {
            Err(format!("{} trailing payload bytes", self.b.len() - self.i))
        }
    }
}

// ---------------------------------------------------------------------
// Framing
// ---------------------------------------------------------------------

/// Write one `[opcode][len][payload]` frame.
pub fn write_frame(w: &mut impl Write, opcode: u8, payload: &[u8]) -> io::Result<()> {
    debug_assert!(payload.len() <= MAX_FRAME);
    let mut head = [0u8; 5];
    head[0] = opcode;
    head[1..5].copy_from_slice(&(payload.len() as u32).to_le_bytes());
    w.write_all(&head)?;
    w.write_all(payload)
}

/// Read one frame; errors on EOF or an implausible length prefix.
pub fn read_frame(r: &mut impl Read) -> io::Result<(u8, Vec<u8>)> {
    let mut head = [0u8; 5];
    r.read_exact(&mut head)?;
    let len = u32::from_le_bytes(head[1..5].try_into().expect("4 bytes")) as usize;
    if len > MAX_FRAME {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            format!("frame length {len} exceeds the {MAX_FRAME}-byte cap"),
        ));
    }
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload)?;
    Ok((head[0], payload))
}

// ---------------------------------------------------------------------
// Value encoding
// ---------------------------------------------------------------------

fn put_stream_snapshot(out: &mut Vec<u8>, s: &StreamSnapshot) {
    put_u64(out, s.stream);
    put_f64(out, s.auc);
    put_usize(out, s.len);
    put_usize(out, s.compressed_len);
    put_u64(out, s.footprint_bytes);
    put_u64(out, s.events);
    put_u32(out, s.alarms);
    put_bool(out, s.alarmed);
    put_opt_f64(out, s.baseline);
}

fn stream_snapshot_from(c: &mut Cursor) -> Result<StreamSnapshot, String> {
    Ok(StreamSnapshot {
        stream: c.u64()?,
        auc: c.f64()?,
        len: c.usize()?,
        compressed_len: c.usize()?,
        footprint_bytes: c.u64()?,
        events: c.u64()?,
        alarms: c.u32()?,
        alarmed: c.bool()?,
        baseline: c.opt_f64()?,
    })
}

fn put_stream_list(out: &mut Vec<u8>, streams: &[StreamSnapshot]) {
    put_u32(out, streams.len() as u32);
    for s in streams {
        put_stream_snapshot(out, s);
    }
}

fn stream_list_from(c: &mut Cursor) -> Result<Vec<StreamSnapshot>, String> {
    let n = c.u32()? as usize;
    let mut streams = Vec::with_capacity(n.min(1 << 20));
    for _ in 0..n {
        streams.push(stream_snapshot_from(c)?);
    }
    Ok(streams)
}

/// Encode a [`FleetSnapshot`].
pub fn encode_snapshot(s: &FleetSnapshot) -> Vec<u8> {
    let mut out = Vec::with_capacity(16 + 52 * s.streams.len() + 8 * s.alarmed_streams.len());
    put_u64(&mut out, s.total_events);
    put_u32(&mut out, s.alarmed_streams.len() as u32);
    for &id in &s.alarmed_streams {
        put_u64(&mut out, id);
    }
    put_stream_list(&mut out, &s.streams);
    out
}

/// Decode a [`FleetSnapshot`].
pub fn decode_snapshot(payload: &[u8]) -> Result<FleetSnapshot, String> {
    let mut c = Cursor::new(payload);
    let total_events = c.u64()?;
    let n_alarmed = c.u32()? as usize;
    let mut alarmed_streams = Vec::with_capacity(n_alarmed.min(1 << 20));
    for _ in 0..n_alarmed {
        alarmed_streams.push(c.u64()?);
    }
    let streams = stream_list_from(&mut c)?;
    c.done()?;
    Ok(FleetSnapshot { streams, alarmed_streams, total_events })
}

/// Encode a [`FleetAggregate`].
pub fn encode_aggregate(a: &FleetAggregate) -> Vec<u8> {
    let mut out = Vec::with_capacity(80);
    put_usize(&mut out, a.streams);
    put_usize(&mut out, a.live_streams);
    put_usize(&mut out, a.alarmed_streams);
    put_u64(&mut out, a.total_events);
    put_u64(&mut out, a.footprint_bytes);
    for v in [a.min_auc, a.p10_auc, a.median_auc, a.p90_auc, a.max_auc, a.mean_auc] {
        put_f64(&mut out, v);
    }
    out
}

/// Decode a [`FleetAggregate`].
pub fn decode_aggregate(payload: &[u8]) -> Result<FleetAggregate, String> {
    let mut c = Cursor::new(payload);
    let a = FleetAggregate {
        streams: c.usize()?,
        live_streams: c.usize()?,
        alarmed_streams: c.usize()?,
        total_events: c.u64()?,
        footprint_bytes: c.u64()?,
        min_auc: c.f64()?,
        p10_auc: c.f64()?,
        median_auc: c.f64()?,
        p90_auc: c.f64()?,
        max_auc: c.f64()?,
        mean_auc: c.f64()?,
    };
    c.done()?;
    Ok(a)
}

/// Encode a worst-k answer.
pub fn encode_top_k(streams: &[StreamSnapshot]) -> Vec<u8> {
    let mut out = Vec::with_capacity(4 + 52 * streams.len());
    put_stream_list(&mut out, streams);
    out
}

/// Decode a worst-k answer.
pub fn decode_top_k(payload: &[u8]) -> Result<Vec<StreamSnapshot>, String> {
    let mut c = Cursor::new(payload);
    let streams = stream_list_from(&mut c)?;
    c.done()?;
    Ok(streams)
}

/// Encode a count-below answer as `(threshold, count)`.
pub fn encode_count_below(threshold: f64, count: usize) -> Vec<u8> {
    let mut out = Vec::with_capacity(16);
    put_f64(&mut out, threshold);
    put_usize(&mut out, count);
    out
}

/// Decode a count-below answer.
pub fn decode_count_below(payload: &[u8]) -> Result<(f64, usize), String> {
    let mut c = Cursor::new(payload);
    let pair = (c.f64()?, c.usize()?);
    c.done()?;
    Ok(pair)
}

/// Encode an [`AucHistogram`].
pub fn encode_auc_histogram(h: &AucHistogram) -> Vec<u8> {
    let mut out = Vec::with_capacity(12 + 8 * h.counts.len());
    put_u32(&mut out, h.counts.len() as u32);
    for &cnt in &h.counts {
        put_usize(&mut out, cnt);
    }
    put_usize(&mut out, h.live_streams);
    out
}

/// Decode an [`AucHistogram`].
pub fn decode_auc_histogram(payload: &[u8]) -> Result<AucHistogram, String> {
    let mut c = Cursor::new(payload);
    let n = c.u32()? as usize;
    let mut counts = Vec::with_capacity(n.min(1 << 20));
    for _ in 0..n {
        counts.push(c.usize()?);
    }
    let live_streams = c.usize()?;
    c.done()?;
    Ok(AucHistogram { counts, live_streams })
}

/// Encode a [`ScoreHistogram`].
pub fn encode_score_histogram(h: &ScoreHistogram) -> Vec<u8> {
    let mut out = Vec::with_capacity(12 + 8 * h.counts.len());
    put_u32(&mut out, h.counts.len() as u32);
    for &cnt in &h.counts {
        put_u64(&mut out, cnt);
    }
    put_u64(&mut out, h.entries);
    out
}

/// Decode a [`ScoreHistogram`].
pub fn decode_score_histogram(payload: &[u8]) -> Result<ScoreHistogram, String> {
    let mut c = Cursor::new(payload);
    let n = c.u32()? as usize;
    let mut counts = Vec::with_capacity(n.min(1 << 20));
    for _ in 0..n {
        counts.push(c.u64()?);
    }
    let entries = c.u64()?;
    c.done()?;
    Ok(ScoreHistogram { counts, entries })
}

/// Encode a subscription baseline `(seq, sketch)` — full bin array.
pub fn encode_sketch(seq: u64, sk: &FleetSketch) -> Vec<u8> {
    let mut out = Vec::with_capacity(64 + 8 * sk.bins.len());
    put_u64(&mut out, seq);
    put_usize(&mut out, sk.streams);
    put_usize(&mut out, sk.live);
    put_usize(&mut out, sk.alarmed);
    put_i128(&mut out, sk.qauc_sum);
    put_u32(&mut out, sk.bins.len() as u32);
    for &b in &sk.bins {
        put_u64(&mut out, b);
    }
    out
}

/// Decode a subscription baseline.
pub fn decode_sketch(payload: &[u8]) -> Result<(u64, FleetSketch), String> {
    let mut c = Cursor::new(payload);
    let seq = c.u64()?;
    let streams = c.usize()?;
    let live = c.usize()?;
    let alarmed = c.usize()?;
    let qauc_sum = c.i128()?;
    let n = c.u32()? as usize;
    let mut bins = Vec::with_capacity(n.min(1 << 20));
    for _ in 0..n {
        bins.push(c.u64()?);
    }
    c.done()?;
    Ok((seq, FleetSketch { bins, live, alarmed, streams, qauc_sum }))
}

/// Encode a subscription delta: absolute scalars plus the
/// `[bin, new_count]` pairs that differ between `prev` and `next`.
pub fn encode_delta(seq: u64, prev: &FleetSketch, next: &FleetSketch) -> Vec<u8> {
    let mut out = Vec::with_capacity(64);
    put_u64(&mut out, seq);
    put_usize(&mut out, next.streams);
    put_usize(&mut out, next.live);
    put_usize(&mut out, next.alarmed);
    put_i128(&mut out, next.qauc_sum);
    let changed: Vec<(u32, u64)> = prev
        .bins
        .iter()
        .zip(next.bins.iter())
        .enumerate()
        .filter(|(_, (p, n))| p != n)
        .map(|(b, (_, &n))| (b as u32, n))
        .collect();
    put_u32(&mut out, changed.len() as u32);
    for (b, n) in changed {
        put_u32(&mut out, b);
        put_u64(&mut out, n);
    }
    out
}

/// Apply one delta payload onto `onto`, returning its sequence number.
pub fn apply_delta(payload: &[u8], onto: &mut FleetSketch) -> Result<u64, String> {
    let mut c = Cursor::new(payload);
    let seq = c.u64()?;
    onto.streams = c.usize()?;
    onto.live = c.usize()?;
    onto.alarmed = c.usize()?;
    onto.qauc_sum = c.i128()?;
    let n = c.u32()? as usize;
    for _ in 0..n {
        let bin = c.u32()? as usize;
        let count = c.u64()?;
        let slot = onto
            .bins
            .get_mut(bin)
            .ok_or_else(|| format!("delta bin {bin} out of range"))?;
        *slot = count;
    }
    c.done()?;
    Ok(seq)
}

/// Decode an [`OP_LAGGED`] payload: the seq of the baseline that
/// follows.
pub fn decode_lagged(payload: &[u8]) -> Result<u64, String> {
    let mut c = Cursor::new(payload);
    let seq = c.u64()?;
    c.done()?;
    Ok(seq)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn snap(stream: u64, auc: f64, baseline: Option<f64>) -> StreamSnapshot {
        StreamSnapshot {
            stream,
            auc,
            len: 3,
            compressed_len: 3,
            events: 11,
            alarms: 1,
            alarmed: baseline.is_some(),
            baseline,
            footprint_bytes: 256,
        }
    }

    #[test]
    fn frames_round_trip_and_reject_hostile_lengths() {
        let mut buf = Vec::new();
        write_frame(&mut buf, OP_TOP_K, &7u32.to_le_bytes()).unwrap();
        write_frame(&mut buf, STATUS_OK, b"").unwrap();
        let mut r = &buf[..];
        assert_eq!(read_frame(&mut r).unwrap(), (OP_TOP_K, 7u32.to_le_bytes().to_vec()));
        assert_eq!(read_frame(&mut r).unwrap(), (STATUS_OK, Vec::new()));
        assert!(read_frame(&mut r).is_err(), "EOF must error");

        let mut hostile = vec![OP_SNAPSHOT];
        hostile.extend_from_slice(&u32::MAX.to_le_bytes());
        assert!(read_frame(&mut &hostile[..]).is_err());
    }

    #[test]
    fn every_value_round_trips_bitwise() {
        let snapshot = FleetSnapshot {
            streams: vec![snap(1, 0.1 + 0.2, None), snap(2, 1.0 / 3.0, Some(0.5))],
            alarmed_streams: vec![2],
            total_events: u64::MAX,
        };
        assert_eq!(decode_snapshot(&encode_snapshot(&snapshot)).unwrap(), snapshot);

        let agg = FleetAggregate {
            streams: 5,
            live_streams: 4,
            alarmed_streams: 1,
            total_events: 1 << 40,
            min_auc: 5e-324,
            p10_auc: 0.1,
            median_auc: 0.5,
            p90_auc: 0.9,
            max_auc: 1.0,
            mean_auc: 2.0 / 3.0,
            footprint_bytes: u64::MAX,
        };
        let back = decode_aggregate(&encode_aggregate(&agg)).unwrap();
        assert_eq!(back, agg);
        assert_eq!(back.mean_auc.to_bits(), agg.mean_auc.to_bits());

        let streams = vec![snap(9, 0.25, Some(0.9))];
        assert_eq!(decode_top_k(&encode_top_k(&streams)).unwrap(), streams);
        assert_eq!(decode_count_below(&encode_count_below(0.7, 3)).unwrap(), (0.7, 3));

        let h = AucHistogram { counts: vec![1, 0, 4], live_streams: 5 };
        assert_eq!(decode_auc_histogram(&encode_auc_histogram(&h)).unwrap(), h);
        let s = ScoreHistogram { counts: vec![u64::MAX, 2], entries: 9 };
        assert_eq!(decode_score_histogram(&encode_score_histogram(&s)).unwrap(), s);
    }

    #[test]
    fn truncated_and_padded_payloads_are_rejected() {
        let agg = decode_aggregate(&encode_aggregate(&FleetAggregate {
            streams: 1,
            live_streams: 1,
            alarmed_streams: 0,
            total_events: 1,
            min_auc: 0.5,
            p10_auc: 0.5,
            median_auc: 0.5,
            p90_auc: 0.5,
            max_auc: 0.5,
            mean_auc: 0.5,
            footprint_bytes: 640,
        }))
        .unwrap();
        let full = encode_aggregate(&agg);
        assert!(decode_aggregate(&full[..full.len() - 1]).is_err());
        let mut padded = full.clone();
        padded.push(0);
        assert!(decode_aggregate(&padded).is_err());
    }

    #[test]
    fn deltas_reconstruct_the_sketch() {
        let mut prev = FleetSketch {
            bins: vec![0; 64],
            live: 2,
            alarmed: 0,
            streams: 2,
            qauc_sum: 1 << 90,
        };
        prev.bins[0] = 1;
        prev.bins[32] = 1;
        let (seq, base) = decode_sketch(&encode_sketch(4, &prev)).unwrap();
        assert_eq!((seq, &base), (4, &prev));

        let mut next = prev.clone();
        next.bins[32] = 0;
        next.bins[33] = 2;
        next.live = 3;
        next.qauc_sum = -(1 << 70);
        let mut applied = prev.clone();
        assert_eq!(apply_delta(&encode_delta(5, &prev, &next), &mut applied).unwrap(), 5);
        assert_eq!(applied, next);
    }
}
