//! Bench target regenerating the paper's Table 1 (dataset
//! characteristics + measured stream statistics of the stand-ins).
//!
//! `cargo bench --bench table1 [-- --events N]`

use streamauc::experiments::{table1, ExpConfig};

fn main() {
    let mut cfg = ExpConfig::default();
    if let Some(n) = std::env::args().skip_while(|a| a != "--events").nth(1) {
        cfg.events = n.parse().expect("--events N");
    }
    println!("{}", table1::run(cfg).render());
}
