"""Pure-jnp oracles for the Pallas kernels (correctness ground truth).

Every kernel in :mod:`compile.kernels.logreg` is checked against these
references by ``python/tests/test_kernels.py`` (exact math, no tiling),
including hypothesis sweeps over shapes and dtypes.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def score_batch(w, b, x):
    """Reference ``sigmoid(x @ w + b)`` — shape (batch,)."""
    return jax.nn.sigmoid(x @ w + b)


def mean_logloss(w, b, x, y):
    """Mean binary cross-entropy of the logistic model (stable form)."""
    logits = x @ w + b
    # log(1 + e^z) computed stably.
    softplus = jnp.logaddexp(0.0, logits)
    return jnp.mean(softplus - y * logits)


def grad(w, b, x, y):
    """Analytic mean-loss gradient: ``((p − y)ᵀ x / B, mean(p − y))``."""
    g = jax.nn.sigmoid(x @ w + b) - y
    return g @ x / x.shape[0], jnp.mean(g)
