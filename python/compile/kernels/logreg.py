"""Pallas kernels for the logistic-regression classifier (Layer 1).

The paper scores its streams with scikit-learn logistic regression; here
the classifier is a JAX/Pallas model compiled ahead-of-time and executed
from the rust coordinator. Two kernels cover the compute hot-spots:

* :func:`score_batch` — fused ``sigmoid(x @ w + b)`` over batch tiles
  (the scoring path feeding the sliding-window estimator);
* :func:`grad_partials` — fused logistic-loss gradient partials per
  batch tile (the training path).

TPU shaping (DESIGN.md §Hardware-Adaptation): the batch dimension is
tiled into ``(block_b, d)`` VMEM blocks via ``BlockSpec``; the weight
vector rides along as a ``(d, 1)`` block mapped to the same index for
every grid step, so it stays VMEM-resident; matvec + bias + sigmoid are
fused so each tile costs one HBM read of ``x`` and one write of the
scores. ``interpret=True`` everywhere — real-TPU lowering emits Mosaic
custom-calls the CPU PJRT plugin cannot execute (see
/opt/xla-example/README.md).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default batch-tile height. 128 matches the MXU/VPU lane width and, at
# d = 128 features, puts a 64 KiB x-tile + 0.5 KiB weight block in VMEM —
# far under the ~16 MiB budget, leaving room for double buffering.
DEFAULT_BLOCK_B = 128


def _pick_block(batch: int, block_b: int | None) -> int:
    """Largest usable tile height: the provided/default block if it
    divides the batch, otherwise the whole batch as a single tile."""
    b = block_b or DEFAULT_BLOCK_B
    return b if batch % b == 0 else batch


def _score_kernel(x_ref, w_ref, b_ref, o_ref):
    """One tile of fused ``sigmoid(x @ w + b)``.

    x_ref: (block_b, d) VMEM tile; w_ref: (d, 1) resident block;
    b_ref: (1, 1); o_ref: (block_b, 1).
    """
    logits = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)
    o_ref[...] = jax.nn.sigmoid(logits + b_ref[0, 0]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_b",))
def score_batch(w, b, x, block_b: int | None = None):
    """Scores for a feature batch: ``sigmoid(x @ w + b)``.

    Args:
      w: (d,) weights. b: scalar bias. x: (batch, d) features.
      block_b: batch-tile height (static); defaults to 128 when it
        divides the batch, else one whole-batch tile.

    Returns: (batch,) scores in (0, 1).
    """
    batch, d = x.shape
    blk = _pick_block(batch, block_b)
    out = pl.pallas_call(
        _score_kernel,
        grid=(batch // blk,),
        in_specs=[
            pl.BlockSpec((blk, d), lambda i: (i, 0)),
            pl.BlockSpec((d, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((blk, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((batch, 1), x.dtype),
        interpret=True,
    )(x, w.reshape(-1, 1), b.reshape(1, 1))
    return out[:, 0]


def _grad_kernel(x_ref, y_ref, w_ref, b_ref, gw_ref, gb_ref):
    """Per-tile logistic-loss gradient partials.

    With p = sigmoid(x @ w + b) and residual g = p − y:
      gw_partial = gᵀ @ x   (1, d)
      gb_partial = Σ g      (1, 1)
    Forward and backward fuse in one VMEM pass over the tile.
    """
    logits = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)
    p = jax.nn.sigmoid(logits + b_ref[0, 0])
    g = p - y_ref[...]  # (block_b, 1)
    gw_ref[...] = jnp.dot(g.T, x_ref[...]).astype(gw_ref.dtype)
    gb_ref[...] = jnp.sum(g).reshape(1, 1).astype(gb_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_b",))
def grad_partials(w, b, x, y, block_b: int | None = None):
    """Per-tile partial gradients of the mean logistic loss.

    Args:
      w: (d,) weights. b: scalar bias. x: (batch, d). y: (batch,) in
        {0, 1}. block_b: static tile height, as in :func:`score_batch`.

    Returns: ``(gw_partials, gb_partials)`` of shapes (tiles, d) and
    (tiles, 1); summing over the tile axis and dividing by ``batch``
    yields the full mean-loss gradient (done in the L2 model so the sum
    lowers into the same HLO).
    """
    batch, d = x.shape
    blk = _pick_block(batch, block_b)
    tiles = batch // blk
    gw, gb = pl.pallas_call(
        _grad_kernel,
        grid=(tiles,),
        in_specs=[
            pl.BlockSpec((blk, d), lambda i: (i, 0)),
            pl.BlockSpec((blk, 1), lambda i: (i, 0)),
            pl.BlockSpec((d, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, d), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((tiles, d), x.dtype),
            jax.ShapeDtypeStruct((tiles, 1), x.dtype),
        ],
        interpret=True,
    )(x, y.reshape(-1, 1), w.reshape(-1, 1), b.reshape(1, 1))
    return gw, gb
