//! Error and latency accounting for the experiment drivers.
//!
//! The paper's evaluation (§6) reports the *relative* approximation error
//! `|ãuc − auc| / auc` averaged and maximised over all sliding windows,
//! plus per-update running time. These accumulators are shared by the
//! Figure 1–3 drivers and the examples.

use std::time::Duration;

/// Streaming summary of a scalar series: count / mean / max / min.
#[derive(Clone, Copy, Debug, Default)]
pub struct Summary {
    count: u64,
    sum: f64,
    max: f64,
    min: f64,
}

impl Summary {
    /// Empty summary.
    pub fn new() -> Self {
        Summary { count: 0, sum: 0.0, max: f64::NEG_INFINITY, min: f64::INFINITY }
    }

    /// Fold one observation.
    pub fn push(&mut self, x: f64) {
        self.count += 1;
        self.sum += x;
        self.max = self.max.max(x);
        self.min = self.min.min(x);
    }

    /// Number of observations.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Arithmetic mean (0 when empty).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    /// Maximum (0 when empty).
    pub fn max(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.max
        }
    }

    /// Minimum (0 when empty).
    pub fn min(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.min
        }
    }
}

/// Relative-error tracker: feeds Figure 1 (average and maximum relative
/// error over all sliding windows).
#[derive(Clone, Copy, Debug, Default)]
pub struct RelErr {
    summary: Summary,
    skipped: u64,
}

impl RelErr {
    /// Empty tracker.
    pub fn new() -> Self {
        RelErr { summary: Summary::new(), skipped: 0 }
    }

    /// Record one window: the estimate against the exact value. Windows
    /// with `auc = 0` are skipped (relative error undefined), counted in
    /// [`RelErr::skipped`].
    pub fn record(&mut self, estimate: f64, exact: f64) {
        if exact == 0.0 {
            self.skipped += 1;
            return;
        }
        self.summary.push((estimate - exact).abs() / exact);
    }

    /// Average relative error over recorded windows.
    pub fn avg(&self) -> f64 {
        self.summary.mean()
    }

    /// Maximum relative error over recorded windows.
    pub fn max(&self) -> f64 {
        self.summary.max()
    }

    /// Number of recorded windows.
    pub fn count(&self) -> u64 {
        self.summary.count()
    }

    /// Windows skipped because the exact AUC was zero.
    pub fn skipped(&self) -> u64 {
        self.skipped
    }
}

/// Latency tracker with mean and high percentiles, for per-update cost.
///
/// Keeps raw nanosecond samples (the experiment streams are bounded, and
/// exact percentiles beat a histogram's bucketing error at this scale).
#[derive(Clone, Debug, Default)]
pub struct Latency {
    nanos: Vec<u64>,
}

impl Latency {
    /// Empty tracker.
    pub fn new() -> Self {
        Self::default()
    }

    /// Pre-sized tracker.
    pub fn with_capacity(n: usize) -> Self {
        Latency { nanos: Vec::with_capacity(n) }
    }

    /// Record one duration.
    pub fn push(&mut self, d: Duration) {
        self.nanos.push(d.as_nanos() as u64);
    }

    /// Number of samples.
    pub fn count(&self) -> usize {
        self.nanos.len()
    }

    /// Total recorded time.
    pub fn total(&self) -> Duration {
        Duration::from_nanos(self.nanos.iter().sum())
    }

    /// Mean per-sample time.
    pub fn mean(&self) -> Duration {
        if self.nanos.is_empty() {
            return Duration::ZERO;
        }
        Duration::from_nanos(self.nanos.iter().sum::<u64>() / self.nanos.len() as u64)
    }

    /// Exact percentile (`q ∈ [0, 1]`) by nearest-rank.
    pub fn percentile(&self, q: f64) -> Duration {
        if self.nanos.is_empty() {
            return Duration::ZERO;
        }
        let mut sorted = self.nanos.clone();
        sorted.sort_unstable();
        let rank = ((q * sorted.len() as f64).ceil() as usize).clamp(1, sorted.len());
        Duration::from_nanos(sorted[rank - 1])
    }

    /// Median.
    pub fn median(&self) -> Duration {
        self.percentile(0.5)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_moments() {
        let mut s = Summary::new();
        for x in [1.0, 2.0, 3.0, 10.0] {
            s.push(x);
        }
        assert_eq!(s.count(), 4);
        assert_eq!(s.mean(), 4.0);
        assert_eq!(s.max(), 10.0);
        assert_eq!(s.min(), 1.0);
    }

    #[test]
    fn empty_summary_is_zero() {
        let s = Summary::new();
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.max(), 0.0);
        assert_eq!(s.min(), 0.0);
    }

    #[test]
    fn rel_err_tracks_avg_and_max() {
        let mut r = RelErr::new();
        r.record(0.99, 1.0); // 1%
        r.record(0.90, 1.0); // 10%
        r.record(0.5, 0.0); // skipped
        assert_eq!(r.count(), 2);
        assert_eq!(r.skipped(), 1);
        assert!((r.avg() - 0.055).abs() < 1e-12);
        assert!((r.max() - 0.10).abs() < 1e-12);
    }

    #[test]
    fn latency_percentiles() {
        let mut l = Latency::new();
        for i in 1..=100u64 {
            l.push(Duration::from_nanos(i));
        }
        assert_eq!(l.median(), Duration::from_nanos(50));
        assert_eq!(l.percentile(0.95), Duration::from_nanos(95));
        assert_eq!(l.percentile(1.0), Duration::from_nanos(100));
        assert_eq!(l.mean(), Duration::from_nanos(50));
        assert_eq!(l.count(), 100);
    }

    #[test]
    fn empty_latency_is_zero() {
        let l = Latency::new();
        assert_eq!(l.median(), Duration::ZERO);
        assert_eq!(l.mean(), Duration::ZERO);
        assert_eq!(l.total(), Duration::ZERO);
    }
}
