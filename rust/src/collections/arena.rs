//! Typed slab arenas: index-addressed storage decoupled from its owner.
//!
//! The paper's structures (the trees `T`/`TP` and the weighted lists
//! `P`/`C`, §3) are long-lived and churn-heavy: every window slide
//! allocates and frees a handful of nodes. With one `Vec` slab per
//! structure per stream, a million-stream fleet pays the global
//! allocator per stream *and* retains every stream's peak capacity
//! forever. An [`Arena`] extracts the slab: slots are addressed by
//! `u32` index, freed slots go on a free list for reuse, and — the
//! point — the arena can be owned by a *shard* and shared by every
//! stream in it. A stream's structures then shrink to a handful of
//! integers (root index, head/tail indices, lengths) while node churn
//! recycles shard-local slots without touching the allocator
//! (`rust/DESIGN.md` §Memory).
//!
//! Index stability: a slot index is stable for the lifetime of the
//! allocation; [`Arena::release`] invalidates it (the slot may be
//! recycled by any later [`Arena::alloc`] on the same arena).

/// A typed slab with a free list. Plain owned data (no `Rc`, no
/// interior mutability), so it is `Send` whenever `T` is — the fleet
/// moves whole shard-owned arenas across pool workers.
#[derive(Clone, Debug)]
pub struct Arena<T> {
    /// Backing slots; freed slots stay in place until recycled.
    pub(crate) slots: Vec<T>,
    /// Indices of freed slots, recycled LIFO.
    pub(crate) free: Vec<u32>,
}

impl<T> Default for Arena<T> {
    fn default() -> Self {
        Arena::new()
    }
}

impl<T> Arena<T> {
    /// Empty arena.
    pub fn new() -> Arena<T> {
        Arena { slots: Vec::new(), free: Vec::new() }
    }

    /// Empty arena with room for `cap` slots before reallocating.
    pub fn with_capacity(cap: usize) -> Arena<T> {
        Arena { slots: Vec::with_capacity(cap), free: Vec::new() }
    }

    /// Number of live (allocated, not freed) slots.
    #[inline]
    pub fn len(&self) -> usize {
        self.slots.len() - self.free.len()
    }

    /// True when no slot is live.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Total slots the arena has ever grown to (live + freed) — the
    /// retained-capacity measure the shrink hooks act on.
    #[inline]
    pub fn slot_count(&self) -> usize {
        self.slots.len()
    }

    /// Allocate a slot holding `value`, recycling a freed slot if one
    /// exists.
    #[inline]
    pub fn alloc(&mut self, value: T) -> u32 {
        match self.free.pop() {
            Some(i) => {
                self.slots[i as usize] = value;
                i
            }
            None => {
                let i = u32::try_from(self.slots.len()).expect("arena overflow (> u32::MAX slots)");
                self.slots.push(value);
                i
            }
        }
    }

    /// Free a slot for reuse. The index (and any copies) become
    /// invalid; the slot's old value stays in place until recycled.
    #[inline]
    pub fn release(&mut self, i: u32) {
        debug_assert!((i as usize) < self.slots.len(), "release of out-of-range slot");
        self.free.push(i);
    }

    /// Drop all storage. Callers must have released every slot first —
    /// this is the bulk-release hook for "no live owner left" moments
    /// (a shard whose streams are all frozen, a tree drained to empty),
    /// where retaining the peak-capacity slab would leak RSS forever.
    pub fn reset(&mut self) {
        assert_eq!(self.free.len(), self.slots.len(), "arena reset with live slots");
        self.slots = Vec::new();
        self.free = Vec::new();
    }

    /// Release retained capacity without moving any live slot: freed
    /// slots at the *tail* of the slab are truncated away (interior
    /// freed slots must stay — live indices are stable), then both
    /// vectors shrink to fit. Cheap relative to the churn that grew
    /// the arena; `O(slot_count)`.
    pub fn shrink_to_fit(&mut self) {
        if self.free.len() == self.slots.len() {
            self.slots.clear();
            self.free.clear();
        } else if !self.free.is_empty() {
            let mut is_free = vec![false; self.slots.len()];
            for &i in &self.free {
                is_free[i as usize] = true;
            }
            let mut keep = self.slots.len();
            while keep > 0 && is_free[keep - 1] {
                keep -= 1;
            }
            if keep < self.slots.len() {
                self.slots.truncate(keep);
                self.free.retain(|&i| (i as usize) < keep);
            }
        }
        self.slots.shrink_to_fit();
        self.free.shrink_to_fit();
    }

    /// Logical bytes held by live slots. Deliberately *logical* (live
    /// count × slot size, ignoring capacity slack and free-list
    /// backing): footprint numbers flow into snapshots and wire
    /// digests, so they must be a function of content, never of the
    /// allocation history that produced it.
    #[inline]
    pub fn live_bytes(&self) -> usize {
        self.len() * std::mem::size_of::<T>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_recycles_released_slots() {
        let mut ar: Arena<u64> = Arena::new();
        let a = ar.alloc(1);
        let b = ar.alloc(2);
        assert_eq!((a, b), (0, 1));
        assert_eq!(ar.len(), 2);
        ar.release(a);
        assert_eq!(ar.len(), 1);
        let c = ar.alloc(3);
        assert_eq!(c, a, "freed slot must be recycled");
        assert_eq!(ar.slots[c as usize], 3);
        assert_eq!(ar.slot_count(), 2);
    }

    #[test]
    fn reset_drops_everything() {
        let mut ar: Arena<u64> = Arena::with_capacity(8);
        let a = ar.alloc(1);
        let b = ar.alloc(2);
        ar.release(b);
        ar.release(a);
        ar.reset();
        assert_eq!(ar.slot_count(), 0);
        assert!(ar.is_empty());
        assert_eq!(ar.alloc(9), 0);
    }

    #[test]
    #[should_panic(expected = "arena reset with live slots")]
    fn reset_with_live_slots_panics() {
        let mut ar: Arena<u64> = Arena::new();
        ar.alloc(1);
        ar.reset();
    }

    #[test]
    fn shrink_truncates_freed_tail_only() {
        let mut ar: Arena<u64> = Arena::new();
        let ids: Vec<u32> = (0..8).map(|i| ar.alloc(i)).collect();
        // Free an interior slot and the whole tail.
        ar.release(ids[2]);
        for &i in &ids[5..] {
            ar.release(i);
        }
        ar.shrink_to_fit();
        // Tail slots 5..8 are gone; interior freed slot 2 survives.
        assert_eq!(ar.slot_count(), 5);
        assert_eq!(ar.len(), 4);
        assert_eq!(ar.free, vec![2]);
        // Live slots kept their indices and values.
        assert_eq!(ar.slots[4], 4);
        // Recycling still works.
        assert_eq!(ar.alloc(99), 2);
    }

    #[test]
    fn shrink_of_fully_freed_arena_clears() {
        let mut ar: Arena<u64> = Arena::new();
        let ids: Vec<u32> = (0..16).map(|i| ar.alloc(i)).collect();
        for &i in &ids {
            ar.release(i);
        }
        ar.shrink_to_fit();
        assert_eq!(ar.slot_count(), 0);
        assert_eq!(ar.live_bytes(), 0);
    }
}
