//! Integration suite for the serving layer (`src/serve/`).
//!
//! The load-bearing property is **wire ≡ in-process**: every endpoint
//! response, on both protocols, must decode to a value equal to the
//! in-process query — and *byte-derived* equal: re-encoding the
//! decoded value reproduces the exact response bytes, so nothing was
//! lost or reformatted in flight. The suite drives seeded
//! mixed-estimator fleets (approx + maintained-exact + binned in one
//! fleet), the empty- and one-stream edges that used to underflow
//! before the quantile-rank fix, the malformed requests that must be
//! rejected at the surface instead of panicking the fleet, and the
//! delta-subscription stream on both protocols.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};

use streamauc::fleet::{AucFleet, FleetConfig, StreamConfig};
use streamauc::serve::{http_get, http_subscribe, json, wire, BinClient, FleetServer, HttpClient};
use streamauc::stream::Pcg;

// ---------------------------------------------------------------------
// Fixtures
// ---------------------------------------------------------------------

fn fleet_with(workers: usize, pipeline: bool, defaults: StreamConfig) -> AucFleet {
    AucFleet::new(FleetConfig {
        shards: 8,
        workers,
        pool: true,
        pipeline,
        adaptive: false,
        stream_defaults: defaults,
    })
}

/// A seeded fleet mixing all three estimator kinds, fed enough traffic
/// to spread streams across sketch bins.
fn mixed_fleet(workers: usize, pipeline: bool) -> AucFleet {
    let mut fleet = fleet_with(workers, pipeline, StreamConfig::new(32, 0.1).without_monitor());
    fleet.configure_stream(3, StreamConfig::exact(32).without_monitor());
    fleet.configure_stream(5, StreamConfig::binned(32, 64, 0.0, 1.0).without_monitor());
    let mut rng = Pcg::seed(0x5EAF);
    let mut batch = Vec::new();
    for _ in 0..30 {
        batch.clear();
        for _ in 0..40 {
            let id = rng.below(24);
            let pos = rng.chance(0.5);
            let score = if pos { rng.range(0.05, 0.7) } else { rng.range(0.3, 0.95) };
            batch.push((id, score, pos));
        }
        fleet.push_batch(&batch);
    }
    fleet
}

/// One deterministic batch for post-subscription ingestion.
fn delta_batch(seed: u64) -> Vec<(u64, f64, bool)> {
    let mut rng = Pcg::seed(seed);
    (0..64)
        .map(|_| {
            let pos = rng.chance(0.5);
            let score = if pos { rng.range(0.05, 0.6) } else { rng.range(0.4, 0.95) };
            (rng.below(30), score, pos)
        })
        .collect()
}

/// Send a raw request (must carry `Connection: close`) and return
/// `(status, body)`.
fn raw_http(addr: SocketAddr, request: &str) -> (u16, String) {
    let mut s = TcpStream::connect(addr).expect("connect");
    s.write_all(request.as_bytes()).expect("send");
    let mut buf = String::new();
    s.read_to_string(&mut buf).expect("read");
    let status = buf
        .split_whitespace()
        .nth(1)
        .and_then(|v| v.parse().ok())
        .unwrap_or_else(|| panic!("no status in {buf:?}"));
    let body = buf.split_once("\r\n\r\n").map(|(_, b)| b.to_string()).unwrap_or_default();
    (status, body)
}

fn get_ok(addr: SocketAddr, target: &str) -> String {
    let (status, body) = http_get(addr, target).expect("http round-trip");
    assert_eq!(status, 200, "GET {target} → {body}");
    body
}

fn bad_request(addr: SocketAddr, target: &str) {
    let (status, body) = http_get(addr, target).expect("http round-trip");
    assert_eq!(status, 400, "GET {target} must be rejected, got {status}: {body}");
    let err = json::Json::parse(&body).expect("error body is JSON");
    let msg = err.get("error").expect("error key");
    assert!(matches!(msg, json::Json::Str(s) if !s.is_empty()), "{body}");
}

// ---------------------------------------------------------------------
// Wire ≡ in-process
// ---------------------------------------------------------------------

#[test]
fn http_endpoints_are_byte_derived_equal_to_in_process_queries() {
    for (workers, pipeline) in [(1, false), (4, true)] {
        let server =
            FleetServer::start(mixed_fleet(workers, pipeline), "127.0.0.1:0").expect("bind");
        let addr = server.local_addr();
        let label = format!("workers={workers} pipeline={pipeline}");

        let body = get_ok(addr, "/snapshot");
        let snap = json::snapshot_from_json(&body).expect("decode snapshot");
        assert_eq!(snap, server.with_fleet(|f| f.snapshot()), "{label}");
        assert_eq!(json::snapshot_to_json(&snap), body, "{label}");

        let body = get_ok(addr, "/aggregate");
        let agg = json::aggregate_from_json(&body).expect("decode aggregate");
        assert_eq!(agg, server.with_fleet(|f| f.aggregate()), "{label}");
        assert_eq!(json::aggregate_to_json(&agg), body, "{label}");

        let body = get_ok(addr, "/top_k_worst?k=5");
        let top = json::top_k_from_json(&body).expect("decode top-k");
        assert_eq!(top, server.with_fleet(|f| f.top_k_worst(5)), "{label}");
        assert_eq!(json::top_k_to_json(&top), body, "{label}");

        for t in ["0.5", "0.015625", "1", "-2", "3.5"] {
            let body = get_ok(addr, &format!("/count_below?t={t}"));
            let (threshold, count) = json::count_below_from_json(&body).expect("decode count");
            assert_eq!(threshold, t.parse::<f64>().unwrap(), "{label}");
            assert_eq!(count, server.with_fleet(|f| f.count_below(threshold)), "{label} t={t}");
            assert_eq!(json::count_below_to_json(threshold, count), body, "{label}");
        }

        let body = get_ok(addr, "/auc_histogram?bins=7");
        let hist = json::auc_histogram_from_json(&body).expect("decode histogram");
        assert_eq!(hist, server.with_fleet(|f| f.auc_histogram(7)), "{label}");
        assert_eq!(json::auc_histogram_to_json(&hist), body, "{label}");

        let body = get_ok(addr, "/score_histogram?bins=9");
        let hist = json::score_histogram_from_json(&body).expect("decode histogram");
        assert_eq!(hist, server.with_fleet(|f| f.score_histogram(9)), "{label}");
        assert_eq!(json::score_histogram_to_json(&hist), body, "{label}");
    }
}

#[test]
fn binary_endpoints_are_byte_derived_equal_to_in_process_queries() {
    let server = FleetServer::start(mixed_fleet(4, true), "127.0.0.1:0").expect("bind");
    let mut bin = BinClient::connect(server.local_addr()).expect("binary session");

    let mut ask = |op: u8, payload: &[u8]| -> Vec<u8> {
        let (status, body) = bin.request(op, payload).expect("binary round-trip");
        assert_eq!(status, wire::STATUS_OK, "{}", String::from_utf8_lossy(&body));
        body
    };

    let body = ask(wire::OP_SNAPSHOT, &[]);
    let snap = wire::decode_snapshot(&body).expect("decode snapshot");
    assert_eq!(snap, server.with_fleet(|f| f.snapshot()));
    assert_eq!(wire::encode_snapshot(&snap), body);

    let body = ask(wire::OP_AGGREGATE, &[]);
    let agg = wire::decode_aggregate(&body).expect("decode aggregate");
    assert_eq!(agg, server.with_fleet(|f| f.aggregate()));
    assert_eq!(wire::encode_aggregate(&agg), body);

    let body = ask(wire::OP_TOP_K, &4u32.to_le_bytes());
    let top = wire::decode_top_k(&body).expect("decode top-k");
    assert_eq!(top, server.with_fleet(|f| f.top_k_worst(4)));
    assert_eq!(wire::encode_top_k(&top), body);

    let body = ask(wire::OP_COUNT_BELOW, &0.62_f64.to_bits().to_le_bytes());
    let (threshold, count) = wire::decode_count_below(&body).expect("decode count");
    assert_eq!(threshold.to_bits(), 0.62_f64.to_bits());
    assert_eq!(count, server.with_fleet(|f| f.count_below(0.62)));
    assert_eq!(wire::encode_count_below(threshold, count), body);

    let body = ask(wire::OP_AUC_HISTOGRAM, &11u32.to_le_bytes());
    let hist = wire::decode_auc_histogram(&body).expect("decode histogram");
    assert_eq!(hist, server.with_fleet(|f| f.auc_histogram(11)));
    assert_eq!(wire::encode_auc_histogram(&hist), body);

    let body = ask(wire::OP_SCORE_HISTOGRAM, &6u32.to_le_bytes());
    let hist = wire::decode_score_histogram(&body).expect("decode histogram");
    assert_eq!(hist, server.with_fleet(|f| f.score_histogram(6)));
    assert_eq!(wire::encode_score_histogram(&hist), body);
}

#[test]
fn http_and_binary_answers_decode_to_the_same_value() {
    let server = FleetServer::start(mixed_fleet(2, false), "127.0.0.1:0").expect("bind");
    let addr = server.local_addr();
    let via_http = json::aggregate_from_json(&get_ok(addr, "/aggregate")).expect("decode http");
    let mut bin = BinClient::connect(addr).expect("binary session");
    let (status, payload) = bin.request(wire::OP_AGGREGATE, &[]).expect("binary round-trip");
    assert_eq!(status, wire::STATUS_OK);
    let via_bin = wire::decode_aggregate(&payload).expect("decode binary");
    assert_eq!(via_http, via_bin);
    for (a, b) in [
        (via_http.min_auc, via_bin.min_auc),
        (via_http.median_auc, via_bin.median_auc),
        (via_http.mean_auc, via_bin.mean_auc),
    ] {
        assert_eq!(a.to_bits(), b.to_bits());
    }
}

// ---------------------------------------------------------------------
// Memory accounting over the wire
// ---------------------------------------------------------------------

/// `footprint_bytes` — per stream and in the aggregate — must survive
/// both protocols byte-derived, sum to the fleet-wide total, and track
/// hibernation: freezing every stream shrinks each served figure to
/// the compact form's cost while AUC bits and lengths stay pinned.
#[test]
fn footprint_bytes_track_hibernation_on_both_protocols() {
    let server = FleetServer::start(mixed_fleet(2, false), "127.0.0.1:0").expect("bind");
    let addr = server.local_addr();

    let live_total = server.with_fleet(|f| f.footprint_bytes());
    assert!(live_total > 0);
    let live = json::snapshot_from_json(&get_ok(addr, "/snapshot")).expect("decode");
    assert!(live.streams.iter().all(|s| s.footprint_bytes > 0));
    assert_eq!(live.streams.iter().map(|s| s.footprint_bytes).sum::<u64>(), live_total);
    let agg = json::aggregate_from_json(&get_ok(addr, "/aggregate")).expect("decode");
    assert_eq!(agg.footprint_bytes, live_total);

    let frozen = server.with_fleet_mut(|f| f.hibernate_idle(0));
    assert_eq!(frozen, live.streams.len(), "every stream must freeze");

    // HTTP: byte-derived, shrunk per stream, estimates pinned.
    let body = get_ok(addr, "/snapshot");
    let hib = json::snapshot_from_json(&body).expect("decode");
    assert_eq!(json::snapshot_to_json(&hib), body);
    let hib_total = server.with_fleet(|f| f.footprint_bytes());
    assert!(
        hib_total * 3 <= live_total,
        "hibernated total {hib_total} not ≤ ⅓ of live {live_total}"
    );
    assert_eq!(hib.streams.iter().map(|s| s.footprint_bytes).sum::<u64>(), hib_total);
    for (l, h) in live.streams.iter().zip(&hib.streams) {
        assert_eq!(l.stream, h.stream);
        assert_eq!(l.auc.to_bits(), h.auc.to_bits(), "frozen estimate must stay pinned");
        assert_eq!(l.len, h.len);
        assert!(h.footprint_bytes < l.footprint_bytes, "stream {} did not shrink", l.stream);
    }

    // The binary protocol serves the same figures, byte-derived.
    let mut bin = BinClient::connect(addr).expect("binary session");
    let (status, payload) = bin.request(wire::OP_SNAPSHOT, &[]).expect("binary round-trip");
    assert_eq!(status, wire::STATUS_OK);
    let via_bin = wire::decode_snapshot(&payload).expect("decode snapshot");
    assert_eq!(via_bin, hib);
    assert_eq!(wire::encode_snapshot(&via_bin), payload);
    let (status, payload) = bin.request(wire::OP_AGGREGATE, &[]).expect("binary round-trip");
    assert_eq!(status, wire::STATUS_OK);
    let agg = wire::decode_aggregate(&payload).expect("decode aggregate");
    assert_eq!(agg.footprint_bytes, hib_total);
    assert_eq!(wire::encode_aggregate(&agg), payload);
}

// ---------------------------------------------------------------------
// Empty-fleet and one-stream edges (network-reachable since the
// quantile-rank underflow fix)
// ---------------------------------------------------------------------

#[test]
fn empty_fleet_endpoints_answer_totally() {
    let empty = fleet_with(2, false, StreamConfig::new(16, 0.0).without_monitor());
    let server = FleetServer::start(empty, "127.0.0.1:0").expect("bind");
    let addr = server.local_addr();

    let agg = json::aggregate_from_json(&get_ok(addr, "/aggregate")).expect("decode");
    assert_eq!(agg, server.with_fleet(|f| f.aggregate()));
    assert_eq!(agg.live_streams, 0);

    let snap = json::snapshot_from_json(&get_ok(addr, "/snapshot")).expect("decode");
    assert!(snap.streams.is_empty());

    let top = json::top_k_from_json(&get_ok(addr, "/top_k_worst?k=3")).expect("decode");
    assert!(top.is_empty());

    let (_, count) =
        json::count_below_from_json(&get_ok(addr, "/count_below?t=0.5")).expect("decode");
    assert_eq!(count, 0);

    let hist = json::auc_histogram_from_json(&get_ok(addr, "/auc_histogram?bins=4")).expect("ok");
    assert_eq!(hist.counts, vec![0; 4]);
    let hist =
        json::score_histogram_from_json(&get_ok(addr, "/score_histogram?bins=4")).expect("ok");
    assert_eq!(hist.counts, vec![0; 4]);
}

#[test]
fn one_stream_fleet_serves_degenerate_quantiles() {
    let mut fleet = fleet_with(2, false, StreamConfig::new(16, 0.0).without_monitor());
    fleet.push_batch(&[(42, 0.2, true), (42, 0.8, false), (42, 0.5, true)]);
    let server = FleetServer::start(fleet, "127.0.0.1:0").expect("bind");
    let addr = server.local_addr();

    let agg = json::aggregate_from_json(&get_ok(addr, "/aggregate")).expect("decode");
    assert_eq!(agg, server.with_fleet(|f| f.aggregate()));
    assert_eq!(agg.live_streams, 1);
    // Every quantile of a one-stream fleet is that stream's AUC.
    for q in [agg.min_auc, agg.p10_auc, agg.median_auc, agg.p90_auc, agg.max_auc] {
        assert_eq!(q.to_bits(), agg.mean_auc.to_bits());
    }
    let top = json::top_k_from_json(&get_ok(addr, "/top_k_worst?k=8")).expect("decode");
    assert_eq!(top.len(), 1);
    assert_eq!(top[0].stream, 42);
}

// ---------------------------------------------------------------------
// Malformed requests error cleanly on both protocols
// ---------------------------------------------------------------------

#[test]
fn malformed_http_requests_get_client_errors_not_panics() {
    let server = FleetServer::start(mixed_fleet(2, false), "127.0.0.1:0").expect("bind");
    let addr = server.local_addr();

    // Zero-bin histograms: the in-process methods assert, the wire
    // surface must reject instead.
    bad_request(addr, "/auc_histogram?bins=0");
    bad_request(addr, "/score_histogram?bins=0");
    // Non-finite and unparseable thresholds.
    bad_request(addr, "/count_below?t=nan");
    bad_request(addr, "/count_below?t=inf");
    bad_request(addr, "/count_below?t=half");
    // Missing parameters.
    bad_request(addr, "/top_k_worst");
    bad_request(addr, "/count_below");
    bad_request(addr, "/auc_histogram");
    bad_request(addr, "/auc_histogram?bins=-1");

    let (status, body) = http_get(addr, "/nope").expect("http round-trip");
    assert_eq!(status, 404, "{body}");
    json::Json::parse(&body).expect("404 body is JSON");

    let (status, _) =
        raw_http(addr, "POST /aggregate HTTP/1.1\r\nConnection: close\r\n\r\n");
    assert_eq!(status, 400, "non-GET must be rejected");

    // The server survives all of the above.
    let agg = json::aggregate_from_json(&get_ok(addr, "/aggregate")).expect("decode");
    assert_eq!(agg, server.with_fleet(|f| f.aggregate()));
}

#[test]
fn malformed_binary_requests_get_error_frames() {
    let server = FleetServer::start(mixed_fleet(2, false), "127.0.0.1:0").expect("bind");
    let mut bin = BinClient::connect(server.local_addr()).expect("binary session");

    let mut expect_err = |op: u8, payload: &[u8]| {
        let (status, body) = bin.request(op, payload).expect("binary round-trip");
        assert_eq!(status, wire::STATUS_ERR, "opcode {op} must error");
        assert!(!body.is_empty(), "error frame carries a message");
        String::from_utf8(body).expect("error message is UTF-8");
    };

    expect_err(99, &[]); // unknown opcode
    expect_err(wire::OP_AUC_HISTOGRAM, &0u32.to_le_bytes());
    expect_err(wire::OP_SCORE_HISTOGRAM, &0u32.to_le_bytes());
    expect_err(wire::OP_COUNT_BELOW, &f64::NAN.to_bits().to_le_bytes());
    expect_err(wire::OP_COUNT_BELOW, &f64::INFINITY.to_bits().to_le_bytes());
    expect_err(wire::OP_TOP_K, &[1, 2]); // truncated k
    expect_err(wire::OP_SNAPSHOT, &[0]); // trailing payload

    // The session keeps working after rejected requests.
    let (status, payload) = bin.request(wire::OP_TOP_K, &2u32.to_le_bytes()).expect("ok");
    assert_eq!(status, wire::STATUS_OK);
    let top = wire::decode_top_k(&payload).expect("decode");
    assert_eq!(top, server.with_fleet(|f| f.top_k_worst(2)));
}

// ---------------------------------------------------------------------
// Keep-alive and concurrency
// ---------------------------------------------------------------------

#[test]
fn http_keep_alive_serves_many_requests_on_one_connection() {
    let server = FleetServer::start(mixed_fleet(2, false), "127.0.0.1:0").expect("bind");
    let mut client = HttpClient::connect(server.local_addr()).expect("connect");
    let reference = server.with_fleet(|f| f.aggregate());
    for _ in 0..25 {
        let (status, body) = client.get("/aggregate").expect("keep-alive get");
        assert_eq!(status, 200);
        assert_eq!(json::aggregate_from_json(&body).expect("decode"), reference);
    }
}

#[test]
fn queries_stay_well_formed_under_concurrent_pooled_ingestion() {
    let fleet = fleet_with(4, true, StreamConfig::new(32, 0.1).without_monitor());
    let server = std::sync::Arc::new(FleetServer::start(fleet, "127.0.0.1:0").expect("bind"));
    let addr = server.local_addr();

    let ingest = {
        let server = std::sync::Arc::clone(&server);
        std::thread::spawn(move || {
            for round in 0..40u64 {
                server.ingest_batch(&delta_batch(0xFEED ^ round));
            }
        })
    };
    let mut client = HttpClient::connect(addr).expect("connect");
    for i in 0..60 {
        let target = match i % 4 {
            0 => "/aggregate",
            1 => "/snapshot",
            2 => "/top_k_worst?k=3",
            _ => "/auc_histogram?bins=5",
        };
        let (status, body) = client.get(target).expect("get under ingestion");
        assert_eq!(status, 200);
        // Under live mutation the *value* changes between requests,
        // but every response must still be a complete, decodable
        // document.
        match i % 4 {
            0 => {
                json::aggregate_from_json(&body).expect("decode");
            }
            1 => {
                json::snapshot_from_json(&body).expect("decode");
            }
            2 => {
                json::top_k_from_json(&body).expect("decode");
            }
            _ => {
                json::auc_histogram_from_json(&body).expect("decode");
            }
        }
    }
    ingest.join().expect("ingest thread");
    // Quiesced: wire and in-process agree again, byte-derived.
    let body = get_ok(addr, "/aggregate");
    let agg = json::aggregate_from_json(&body).expect("decode");
    assert_eq!(agg, server.with_fleet(|f| f.aggregate()));
    assert_eq!(json::aggregate_to_json(&agg), body);
}

// ---------------------------------------------------------------------
// Subscriptions
// ---------------------------------------------------------------------

#[test]
fn http_subscription_baseline_plus_deltas_reconstruct_the_sketch() {
    let server = FleetServer::start(mixed_fleet(2, false), "127.0.0.1:0").expect("bind");
    let mut lines = http_subscribe(server.local_addr()).expect("subscribe");

    let baseline_line = lines.next().expect("baseline line").expect("read");
    let (base_seq, mut sketch) = json::sketch_from_json(&baseline_line).expect("decode baseline");
    assert_eq!(sketch, server.with_fleet(|f| f.sketch_state()));

    for round in 0..3u64 {
        server.ingest_batch(&delta_batch(0xD17A ^ round));
        let delta_line = lines.next().expect("delta line").expect("read");
        let seq = json::apply_subscription_json(&delta_line, &mut sketch).expect("apply");
        // Gapless: one delta per publishing drain, in order.
        assert_eq!(seq, base_seq + round + 1);
        let (want_seq, want) = server.last_published();
        assert_eq!((seq, &sketch), (want_seq, &want));
    }
    assert_eq!(sketch, server.with_fleet(|f| f.sketch_state()));
}

#[test]
fn binary_subscription_baseline_plus_deltas_reconstruct_the_sketch() {
    let server = FleetServer::start(mixed_fleet(4, true), "127.0.0.1:0").expect("bind");
    let mut bin = BinClient::connect(server.local_addr()).expect("binary session");

    let baseline = bin.subscribe().expect("subscribe");
    let (base_seq, mut sketch) = wire::decode_sketch(&baseline).expect("decode baseline");
    assert_eq!(sketch, server.with_fleet(|f| f.sketch_state()));
    assert_eq!(server.subscriber_count(), 1);

    // A quiet drain publishes nothing.
    server.ingest_batch(&[]);
    assert_eq!(server.last_published().0, base_seq);

    for round in 0..3u64 {
        server.ingest_batch(&delta_batch(0xB1A5 ^ round));
        let payload = bin.next_delta().expect("delta frame");
        let seq = wire::apply_delta(&payload, &mut sketch).expect("apply");
        assert_eq!(seq, base_seq + round + 1);
        let (want_seq, want) = server.last_published();
        assert_eq!((seq, &sketch), (want_seq, &want));
    }
    assert_eq!(sketch, server.with_fleet(|f| f.sketch_state()));
}

#[test]
fn dropped_subscribers_are_pruned_on_the_next_publish() {
    let server = FleetServer::start(mixed_fleet(2, false), "127.0.0.1:0").expect("bind");
    {
        let mut bin = BinClient::connect(server.local_addr()).expect("binary session");
        bin.subscribe().expect("subscribe");
        assert_eq!(server.subscriber_count(), 1);
    } // client dropped — socket closed
    // Publishing notices the dead socket and prunes it. Early writes
    // can still land in the closed socket's buffer until the kernel
    // processes the reset, so publish until the prune shows up.
    for round in 0..50u64 {
        server.ingest_batch(&delta_batch(0xDEAD ^ round));
        if server.subscriber_count() == 0 {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(10));
    }
    assert_eq!(server.subscriber_count(), 0);
}
