//! Persistent worker pool and the shared drain-job state it executes.
//!
//! PR-2's executor paid a `std::thread::scope` spawn+join on **every
//! batch** and split shards into fixed contiguous chunks, so a skewed
//! batch (one hot shard) serialized the whole drain while the other
//! workers idled. This module replaces both mechanisms:
//!
//! * [`WorkerPool`] — threads spawned **once** per fleet (lazily, when
//!   the executor is built with pooling and ≥ 2 workers) and parked on
//!   their job channels between batches. Submitting a batch costs one
//!   boxed closure per worker instead of a thread spawn.
//! * [`DrainJob`] — everything one batch drain needs, shared behind an
//!   `Arc`: the per-shard event buckets, the size-aware claim queue, the
//!   precomputed fleet ticks, and a completion latch. Workers *steal*
//!   shards from the queue through an atomic cursor — largest pending
//!   bucket first — so a hot shard occupies one worker while the rest
//!   drain the tail, and no worker idles while work remains.
//!
//! Determinism: claiming order affects only wall-clock. Each shard's
//! observable state depends solely on its own bucket and its
//! precomputed `start_tick`, and the batch's alarms are merged into the
//! fleet-wide pending log in shard-index order by whichever worker
//! finishes last — the exact order the serial drain produces. See
//! `rust/DESIGN.md` §Parallelism.
//!
//! Panic safety: a panic inside one shard's drain (e.g. a non-finite
//! score hitting the window's comparator boundary) is caught per shard,
//! recorded on the job, and re-raised as a clean panic at the fleet's
//! next synchronization point. The pool threads never unwind, so the
//! same `AucFleet` keeps ingesting afterwards — no poisoned, parked or
//! deadlocked workers (property-tested in `rust/tests/executor.rs`).

use std::collections::HashMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex, MutexGuard, PoisonError};
use std::thread;

use super::config::StreamConfig;
use super::shard::Shard;
use super::snapshot::FleetAlarm;

/// One ingestion event: `(stream id, score, label)`.
pub(super) type Event = (u64, f64, bool);

/// A unit of work shipped to a pool thread.
pub(super) type Task = Box<dyn FnOnce() + Send + 'static>;

/// Lock a mutex, ignoring poisoning: fleet invariants are maintained at
/// a coarser level (a drain panic marks the whole job poisoned and the
/// fleet re-raises it at the next sync), so an unwound worker must not
/// brick every later lock of the same shard.
pub(super) fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// The shard state shared between the fleet handle and the pool
/// workers. Everything a drain job mutates lives here, behind one
/// mutex per shard (always uncontended: the claim cursor hands each
/// shard to exactly one worker, and the fleet only locks after the
/// job's completion latch).
#[derive(Debug)]
pub(super) struct FleetCore {
    /// One mutex per shard; the shard is the unit of parallelism.
    pub(super) shards: Vec<Mutex<Shard>>,
    /// Alarms of the in-flight (or just-finished) batch, merged here in
    /// shard-index order by the job's last worker; the fleet moves them
    /// into its public log at the next sync.
    pub(super) pending_alarms: Mutex<Vec<FleetAlarm>>,
    /// Drained bucket allocations handed back for reuse by later
    /// batches (capacity recycling across the pipeline).
    pub(super) spare_buckets: Mutex<Vec<Vec<Event>>>,
}

impl FleetCore {
    pub(super) fn new(shards: usize) -> FleetCore {
        FleetCore {
            shards: (0..shards).map(|_| Mutex::new(Shard::default())).collect(),
            pending_alarms: Mutex::new(Vec::new()),
            spare_buckets: Mutex::new(Vec::new()),
        }
    }

    /// Shard count (power of two).
    pub(super) fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// Lock one shard (unpoisoning — see [`lock`]).
    pub(super) fn lock_shard(&self, s: usize) -> MutexGuard<'_, Shard> {
        lock(&self.shards[s])
    }
}

/// One batch drain, shared by every worker participating in it.
///
/// The fleet constructs the job with the batch's buckets, the
/// size-aware claim queue and the precomputed per-shard start ticks,
/// then hands an `Arc` of it to the executor. Workers call
/// [`DrainJob::run_worker`]; the fleet calls [`DrainJob::wait`] at its
/// next synchronization point (immediately unless pipelining).
#[derive(Debug)]
pub(super) struct DrainJob {
    core: Arc<FleetCore>,
    /// Per-shard event buckets (full shard indexing; untouched shards
    /// hold empty vectors). Mutexed so any worker can take one.
    buckets: Vec<Mutex<Vec<Event>>>,
    /// Claim queue: indices of non-empty shards, largest bucket first
    /// (ties broken by shard index — the queue is deterministic even
    /// though claiming is not, and neither affects results).
    order: Vec<usize>,
    /// Fleet tick immediately before each shard's first event — the
    /// exact ticks the serial shard-by-shard drain would assign.
    start_ticks: Vec<u64>,
    defaults: StreamConfig,
    /// Shared with the fleet (copy-on-write there), so a job costs one
    /// `Arc` bump instead of a map clone per batch.
    overrides: Arc<HashMap<u64, StreamConfig>>,
    /// Next claim-queue position to steal.
    cursor: AtomicUsize,
    /// Workers that have not yet finished their claim loop.
    remaining: AtomicUsize,
    /// Workers that drained at least one shard (scheduling diagnostics).
    pub(super) participants: AtomicUsize,
    /// Set when any shard's drain panicked; the fleet re-raises once at
    /// the next sync.
    pub(super) poisoned: AtomicBool,
    /// Completion latch: flipped by the last worker *after* the
    /// shard-order alarm merge, so waiters always observe merged state.
    done: Mutex<bool>,
    cv: Condvar,
}

impl DrainJob {
    pub(super) fn new(
        core: Arc<FleetCore>,
        buckets: Vec<Mutex<Vec<Event>>>,
        order: Vec<usize>,
        start_ticks: Vec<u64>,
        defaults: StreamConfig,
        overrides: Arc<HashMap<u64, StreamConfig>>,
        workers: usize,
    ) -> DrainJob {
        DrainJob {
            core,
            buckets,
            order,
            start_ticks,
            defaults,
            overrides,
            cursor: AtomicUsize::new(0),
            remaining: AtomicUsize::new(workers.max(1)),
            participants: AtomicUsize::new(0),
            poisoned: AtomicBool::new(false),
            done: Mutex::new(false),
            cv: Condvar::new(),
        }
    }

    /// Worker entry point: steal shards off the claim queue until it is
    /// empty, then arrive at the latch. Called exactly `workers` times
    /// per job (inline for the serial path).
    pub(super) fn run_worker(&self) {
        let mut claimed = false;
        loop {
            let i = self.cursor.fetch_add(1, Ordering::Relaxed);
            let Some(&s) = self.order.get(i) else { break };
            claimed = true;
            // Catch per shard: one poisoned stream must not stop this
            // worker from draining the shards it would steal next, and
            // must never unwind into the pool's run loop.
            if catch_unwind(AssertUnwindSafe(|| self.drain_shard(s))).is_err() {
                self.poisoned.store(true, Ordering::Relaxed);
            }
        }
        if claimed {
            self.participants.fetch_add(1, Ordering::Relaxed);
        }
        self.finish();
    }

    /// Drain one claimed shard, then recycle its bucket allocation.
    fn drain_shard(&self, s: usize) {
        let mut bucket = std::mem::take(&mut *lock(&self.buckets[s]));
        {
            let mut shard = self.core.lock_shard(s);
            shard.drain_events(&bucket, &self.defaults, &self.overrides, self.start_ticks[s]);
        }
        bucket.clear();
        lock(&self.core.spare_buckets).push(bucket);
    }

    /// Arrive at the latch; the last worker merges the batch's alarms in
    /// shard-index order (the serial order) before releasing waiters.
    fn finish(&self) {
        if self.remaining.fetch_sub(1, Ordering::AcqRel) == 1 {
            {
                let mut out = lock(&self.core.pending_alarms);
                for shard in &self.core.shards {
                    lock(shard).take_alarms_into(&mut out);
                }
            }
            *lock(&self.done) = true;
            self.cv.notify_all();
        }
    }

    /// Block until every worker has finished and the alarm merge is
    /// visible. Cheap (one uncontended lock) once the job is done.
    pub(super) fn wait(&self) {
        let mut done = lock(&self.done);
        while !*done {
            done = self.cv.wait(done).unwrap_or_else(PoisonError::into_inner);
        }
    }
}

/// Persistent ingestion threads, spawned once per fleet and parked on
/// their job channels between batches.
#[derive(Debug)]
pub(super) struct WorkerPool {
    senders: Vec<mpsc::Sender<Task>>,
    handles: Vec<thread::JoinHandle<()>>,
}

impl WorkerPool {
    /// Spawn `workers` named threads, each parked on its own channel.
    pub(super) fn spawn(workers: usize) -> WorkerPool {
        let mut senders = Vec::with_capacity(workers);
        let mut handles = Vec::with_capacity(workers);
        for w in 0..workers {
            let (tx, rx) = mpsc::channel::<Task>();
            let handle = thread::Builder::new()
                .name(format!("fleet-worker-{w}"))
                .spawn(move || {
                    // Parked in `recv` between batches; exits when the
                    // pool drops its sender. Tasks are already
                    // panic-proofed by `DrainJob::run_worker`; the
                    // catch here is defense in depth so no panic can
                    // ever take a pool thread down.
                    while let Ok(task) = rx.recv() {
                        let _ = catch_unwind(AssertUnwindSafe(task));
                    }
                })
                .expect("failed to spawn fleet worker thread");
            senders.push(tx);
            handles.push(handle);
        }
        WorkerPool { senders, handles }
    }

    /// Number of pool threads.
    pub(super) fn size(&self) -> usize {
        self.senders.len()
    }

    /// Hand a task to worker `w`. If that thread is somehow gone the
    /// task runs inline so the job's completion latch still resolves.
    pub(super) fn submit(&self, w: usize, task: Task) {
        if let Err(mpsc::SendError(task)) = self.senders[w].send(task) {
            task();
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        // Disconnect the channels; each worker finishes its in-flight
        // task (if any) and exits its recv loop, then we join.
        self.senders.clear();
        for handle in self.handles.drain(..) {
            let _ = handle.join();
        }
    }
}

// The job is shared across worker threads behind an `Arc`, and the pool
// (inside the executor, inside the fleet) must move with the fleet.
const _: () = {
    const fn assert_send_sync<T: Send + Sync>() {}
    const fn assert_send<T: Send>() {}
    assert_send_sync::<DrainJob>();
    assert_send_sync::<FleetCore>();
    assert_send::<WorkerPool>();
};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pool_runs_tasks_and_survives_panics() {
        let pool = WorkerPool::spawn(2);
        assert_eq!(pool.size(), 2);
        let hits = Arc::new(AtomicUsize::new(0));
        // A panicking task must not kill the worker...
        pool.submit(0, Box::new(|| panic!("boom")));
        for w in 0..2 {
            let hits = Arc::clone(&hits);
            pool.submit(
                w,
                Box::new(move || {
                    hits.fetch_add(1, Ordering::SeqCst);
                }),
            );
        }
        // ...so both workers still drain their queues before the drop
        // below joins them.
        drop(pool);
        assert_eq!(hits.load(Ordering::SeqCst), 2);
    }

    #[test]
    fn latch_waits_for_all_workers_and_merge() {
        let core = Arc::new(FleetCore::new(4));
        let buckets: Vec<Mutex<Vec<Event>>> =
            (0..4).map(|_| Mutex::new(Vec::new())).collect();
        let job = Arc::new(DrainJob::new(
            Arc::clone(&core),
            buckets,
            Vec::new(), // nothing to claim: workers arrive immediately
            vec![0; 4],
            StreamConfig::default(),
            Arc::new(HashMap::new()),
            3,
        ));
        let pool = WorkerPool::spawn(3);
        for w in 0..3 {
            let j = Arc::clone(&job);
            pool.submit(w, Box::new(move || j.run_worker()));
        }
        job.wait();
        assert!(!job.poisoned.load(Ordering::Relaxed));
        assert_eq!(job.participants.load(Ordering::Relaxed), 0);
    }
}
