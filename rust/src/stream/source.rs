//! Stream sources and sinks.
//!
//! The coordinator consumes an iterator of `(score, label)` pairs; this
//! module provides the ways to produce one — synthetic generators, CSV
//! files (`score,label` per line), and pre-materialized vectors — plus
//! the CSV writer used by experiment drivers to persist streams.

use std::fs::File;
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

/// Read a scored stream from a CSV file with `score,label` lines
/// (`label ∈ {0, 1}`; `#`-prefixed lines and a `score,label` header are
/// skipped).
pub fn read_csv(path: &Path) -> Result<Vec<(f64, bool)>> {
    let file = File::open(path).with_context(|| format!("open {}", path.display()))?;
    let mut out = Vec::new();
    for (lineno, line) in BufReader::new(file).lines().enumerate() {
        let line = line?;
        let trimmed = line.trim();
        if trimmed.is_empty() || trimmed.starts_with('#') || trimmed == "score,label" {
            continue;
        }
        let mut parts = trimmed.split(',');
        let (Some(score), Some(label)) = (parts.next(), parts.next()) else {
            bail!("{}:{}: expected `score,label`", path.display(), lineno + 1);
        };
        let score: f64 = score
            .trim()
            .parse()
            .with_context(|| format!("{}:{}: bad score", path.display(), lineno + 1))?;
        if !score.is_finite() {
            bail!("{}:{}: non-finite score", path.display(), lineno + 1);
        }
        let label = match label.trim() {
            "1" | "true" => true,
            "0" | "false" => false,
            other => bail!("{}:{}: bad label {other:?}", path.display(), lineno + 1),
        };
        out.push((score, label));
    }
    Ok(out)
}

/// Write a scored stream as CSV (with header), the inverse of
/// [`read_csv`].
pub fn write_csv(path: &Path, stream: &[(f64, bool)]) -> Result<()> {
    let file = File::create(path).with_context(|| format!("create {}", path.display()))?;
    let mut w = BufWriter::new(file);
    writeln!(w, "score,label")?;
    for (score, label) in stream {
        writeln!(w, "{score},{}", u8::from(*label))?;
    }
    w.flush()?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("streamauc-tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn roundtrip() {
        let path = tmp("roundtrip.csv");
        let stream = vec![(0.25, true), (0.5, false), (1e-9, true)];
        write_csv(&path, &stream).unwrap();
        assert_eq!(read_csv(&path).unwrap(), stream);
    }

    #[test]
    fn skips_comments_and_header() {
        let path = tmp("comments.csv");
        std::fs::write(&path, "# comment\nscore,label\n0.5,1\n\n0.25,0\n").unwrap();
        assert_eq!(read_csv(&path).unwrap(), vec![(0.5, true), (0.25, false)]);
    }

    #[test]
    fn rejects_bad_label() {
        let path = tmp("badlabel.csv");
        std::fs::write(&path, "0.5,2\n").unwrap();
        assert!(read_csv(&path).is_err());
    }

    #[test]
    fn rejects_nan_score() {
        let path = tmp("nan.csv");
        std::fs::write(&path, "NaN,1\n").unwrap();
        assert!(read_csv(&path).is_err());
    }

    #[test]
    fn rejects_missing_field() {
        let path = tmp("short.csv");
        std::fs::write(&path, "0.5\n").unwrap();
        assert!(read_csv(&path).is_err());
    }
}
