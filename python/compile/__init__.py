"""Build-time compile package (never imported at runtime)."""
