//! Deterministic random numbers for data generation and property tests.
//!
//! PCG-XSH-RR 64/32 (O'Neill 2014) with splitmix64 seeding. No external
//! crates; every experiment in the repo is reproducible from a seed.

/// PCG-XSH-RR 64/32 generator.
#[derive(Clone, Debug)]
pub struct Pcg {
    state: u64,
    inc: u64,
}

const MULT: u64 = 6364136223846793005;

impl Pcg {
    /// Seed deterministically (stream id fixed).
    pub fn seed(seed: u64) -> Self {
        Self::seed_stream(seed, 0xda3e39cb94b95bdb)
    }

    /// Seed with an explicit stream id, so parallel workers can draw
    /// independent sequences from one master seed.
    pub fn seed_stream(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(splitmix64(seed));
        rng.next_u32();
        rng
    }

    /// Next raw 32-bit output.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Next 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        (u64::from(self.next_u32()) << 32) | u64::from(self.next_u32())
    }

    /// Uniform integer in `[0, bound)` (Lemire-style rejection; unbiased).
    pub fn below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "below(0)");
        if bound == 1 {
            return 0;
        }
        let threshold = bound.wrapping_neg() % bound;
        loop {
            let r = self.next_u64();
            if r >= threshold {
                return r % bound;
            }
        }
    }

    /// Uniform `f64` in `[0, 1)` with 53 random bits.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform `f64` in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Bernoulli draw.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.uniform() < p
    }

    /// Standard normal via Box–Muller (one value per call; the twin is
    /// discarded for simplicity — generation is not the hot path).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.uniform();
            if u1 > 1e-300 {
                let u2 = self.uniform();
                return (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos();
            }
        }
    }

    /// Normal with the given mean and standard deviation.
    #[inline]
    pub fn normal_with(&mut self, mean: f64, sd: f64) -> f64 {
        mean + sd * self.normal()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Fork an independent generator (for sub-streams).
    pub fn fork(&mut self) -> Pcg {
        let s = self.next_u64();
        let st = self.next_u64();
        Pcg::seed_stream(s, st)
    }
}

fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9e3779b97f4a7c15);
    x = (x ^ (x >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94d049bb133111eb);
    x ^ (x >> 31)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Pcg::seed(7);
        let mut b = Pcg::seed(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Pcg::seed(1);
        let mut b = Pcg::seed(2);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn below_is_in_range_and_hits_all() {
        let mut rng = Pcg::seed(3);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let v = rng.below(7);
            assert!(v < 7);
            seen[v as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn uniform_mean_is_half() {
        let mut rng = Pcg::seed(4);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| rng.uniform()).sum::<f64>() / f64::from(n);
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg::seed(5);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / xs.len() as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_permutes() {
        let mut rng = Pcg::seed(6);
        let mut xs: Vec<u32> = (0..50).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(xs, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn forked_streams_independent() {
        let mut rng = Pcg::seed(8);
        let mut f1 = rng.fork();
        let mut f2 = rng.fork();
        let same = (0..64).filter(|_| f1.next_u32() == f2.next_u32()).count();
        assert!(same < 4);
    }
}
