//! Estimators and sliding-window coordination (paper §4).
//!
//! * [`support`] — the §3 supporting structure: score tree `T`, positive
//!   index `TP`, positive weighted list `P`, `HeadStats`, `MaxPos`, and
//!   the four tree update procedures.
//! * [`approx`] — the paper's contribution: the `(1+ε)`-compressed list
//!   `C` and `ApproxAUC` with the `ε/2` relative-error guarantee.
//! * [`exact`] — the Brzezinski & Stefanowski-style baseline: same
//!   balanced tree, exact `O(k)` recomputation per query.
//! * [`maintained`] — the Tatti (2021) follow-up: exact AUC maintained
//!   delta-wise on the augmented tree, `O(log k)` update / `O(1)` read,
//!   plus the exact H-measure.
//! * [`binned`] — bounded-score fast path: fixed cells over a declared
//!   `[lo, hi]` range, two flat count arrays, the maintained doubled
//!   area for an `O(1)` read, and a derived discretization bound.
//! * [`naive`] — sort-based from-scratch oracle used by tests.
//! * [`flipped`] — §4.1 remark: label-flipped estimator with a
//!   `(1−auc)·ε/2` guarantee, preferable when AUC ≈ 1.
//! * [`scratch`] — §7 extension: weighted data points, `(1+ε)`-list
//!   construction from scratch via threshold queries.
//! * [`decay`] — §5 future-work line: AUC under exponential decay,
//!   built on the weighted machinery via weight-scale invariance.
//! * [`window`] — FIFO sliding-window driver over any estimator.
//! * [`monitor`] — drift monitor raising alarms on AUC degradation (the
//!   intro's motivating application).
//! * [`metrics`] — error/latency accounting shared by the experiment
//!   drivers.

pub mod approx;
pub mod binned;
pub mod decay;
pub mod exact;
pub mod flipped;
pub mod maintained;
pub mod metrics;
pub mod monitor;
pub mod naive;
pub mod scratch;
pub mod support;
pub mod window;

pub use approx::ApproxAuc;
pub use binned::BinnedAuc;
pub use decay::DecayedAuc;
pub use exact::ExactAuc;
pub use flipped::FlippedAuc;
pub use maintained::MaintainedExactAuc;
pub use monitor::{AucMonitor, MonitorEvent};
pub use naive::NaiveAuc;
pub use scratch::WeightedAuc;
pub use window::SlidingAuc;

/// A sliding-window AUC estimator: multiset of `(score, label)` pairs
/// under insertion and removal, queried for the area under the ROC curve.
///
/// Score convention follows the paper (§2 footnote): *larger scores mean
/// the negative label (0) is more likely*; AUC is the probability that a
/// uniformly random positive/negative pair is ordered correctly under
/// this convention, with ties counting one half.
pub trait AucEstimator {
    /// Insert one `(score, label)` pair. `pos` is the true label
    /// (`ℓ = 1`).
    fn insert(&mut self, score: f64, pos: bool);

    /// Remove one previously inserted pair.
    fn remove(&mut self, score: f64, pos: bool);

    /// Current AUC. Returns 0.5 when one of the classes is empty (AUC is
    /// undefined there; 0.5 = “no discriminative information”, the same
    /// convention across all estimators in this crate).
    fn auc(&self) -> f64;

    /// Number of pairs currently held.
    fn len(&self) -> usize;

    /// True when no pairs are held.
    fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Canonicalize a score at the estimator boundary: maps `−0.0` to
/// `+0.0` so the tree order (`total_cmp`, which distinguishes the two
/// zeros) and the cached-`f64` comparisons on the hot path agree.
#[inline]
pub(crate) fn canon(score: f64) -> f64 {
    score + 0.0
}

/// Exact AUC from label-count pairs `(p, n)` listed in ascending score
/// order, one entry per distinct score (Eq. 1). Doubled-integer
/// arithmetic: returns `(2·Σ (hp + p/2)·n, pos_total, neg_total)`.
pub(crate) fn auc_terms_doubled(groups: impl Iterator<Item = (u64, u64)>) -> (u128, u64, u64) {
    let mut hp: u64 = 0;
    let mut a2: u128 = 0;
    let mut neg: u64 = 0;
    for (p, n) in groups {
        a2 += u128::from(2 * hp + p) * u128::from(n);
        hp += p;
        neg += n;
    }
    (a2, hp, neg)
}

/// Turn doubled AUC terms into the final ratio with the empty-class
/// convention.
pub(crate) fn finish_auc(a2: u128, pos: u64, neg: u64) -> f64 {
    let area = u128::from(pos) * u128::from(neg);
    if area == 0 {
        return 0.5;
    }
    (a2 as f64) / (2.0 * area as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn auc_terms_perfect_separation() {
        // positives at low scores, negatives at high scores → AUC = 1
        // (paper convention: larger score ⇒ more negative).
        let groups = [(2u64, 0u64), (3, 0), (0, 4)];
        let (a2, p, n) = auc_terms_doubled(groups.into_iter());
        assert_eq!((p, n), (5, 4));
        assert_eq!(finish_auc(a2, p, n), 1.0);
    }

    #[test]
    fn auc_terms_reversed() {
        let groups = [(0u64, 4u64), (5, 0)];
        let (a2, p, n) = auc_terms_doubled(groups.into_iter());
        assert_eq!(finish_auc(a2, p, n), 0.0);
    }

    #[test]
    fn auc_terms_all_tied_is_half() {
        let groups = [(3u64, 7u64)];
        let (a2, p, n) = auc_terms_doubled(groups.into_iter());
        assert_eq!(finish_auc(a2, p, n), 0.5);
    }

    #[test]
    fn empty_class_convention() {
        assert_eq!(finish_auc(0, 0, 5), 0.5);
        assert_eq!(finish_auc(0, 5, 0), 0.5);
        assert_eq!(finish_auc(0, 0, 0), 0.5);
    }
}
