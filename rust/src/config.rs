//! Key = value configuration files.
//!
//! A deliberately small format (serde/toml are unavailable offline):
//! one `key = value` pair per line, `#` comments, string values
//! unquoted. CLI flags override file values; [`Settings`] is the merged
//! view consumed by `main.rs` and the examples.
//!
//! ```text
//! # streamauc.conf
//! epsilon = 0.05
//! window  = 1000
//! dataset = miniboone
//! events  = 50000
//! ```

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

/// Parsed key→value map with typed accessors.
#[derive(Clone, Debug, Default)]
pub struct Config {
    values: BTreeMap<String, String>,
}

impl Config {
    /// Empty config.
    pub fn new() -> Self {
        Self::default()
    }

    /// Parse from text.
    pub fn parse(text: &str) -> Result<Config> {
        let mut values = BTreeMap::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| anyhow!("line {}: expected `key = value`", lineno + 1))?;
            let key = k.trim();
            if key.is_empty() {
                bail!("line {}: empty key", lineno + 1);
            }
            values.insert(key.to_string(), v.trim().to_string());
        }
        Ok(Config { values })
    }

    /// Load from a file.
    pub fn load(path: &Path) -> Result<Config> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("read config {}", path.display()))?;
        Self::parse(&text).with_context(|| format!("parse config {}", path.display()))
    }

    /// Set (or override) a key.
    pub fn set(&mut self, key: &str, value: impl Into<String>) {
        self.values.insert(key.to_string(), value.into());
    }

    /// Raw string lookup.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(String::as_str)
    }

    /// Typed lookup with default.
    pub fn get_or<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        match self.values.get(key) {
            None => Ok(default),
            Some(raw) => raw
                .parse()
                .map_err(|e| anyhow!("config key {key} = {raw:?}: {e}")),
        }
    }

    /// Keys present (for unknown-key validation).
    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.values.keys().map(String::as_str)
    }

    /// Error on keys outside the allowed set (catches typos early).
    pub fn validate_keys(&self, allowed: &[&str]) -> Result<()> {
        for k in self.keys() {
            if !allowed.contains(&k) {
                bail!("unknown config key {k:?} (allowed: {allowed:?})");
            }
        }
        Ok(())
    }
}

/// Merged runtime settings for the CLI and examples.
#[derive(Clone, Debug)]
pub struct Settings {
    /// Approximation parameter ε.
    pub epsilon: f64,
    /// Sliding-window size k.
    pub window: usize,
    /// Dataset name (`hepmass` / `miniboone` / `tvads`).
    pub dataset: String,
    /// Events to stream.
    pub events: usize,
    /// Master seed.
    pub seed: u64,
    /// Artifact directory for the PJRT runtime.
    pub artifacts: String,
}

impl Default for Settings {
    fn default() -> Self {
        Settings {
            epsilon: 0.05,
            window: 1000,
            dataset: "miniboone".into(),
            events: 50_000,
            seed: 0xA0C_2019,
            artifacts: "artifacts".into(),
        }
    }
}

/// Keys [`Settings::from_config`] understands.
pub const SETTINGS_KEYS: [&str; 6] =
    ["epsilon", "window", "dataset", "events", "seed", "artifacts"];

impl Settings {
    /// Build from a config map, defaulting missing keys.
    pub fn from_config(cfg: &Config) -> Result<Settings> {
        cfg.validate_keys(&SETTINGS_KEYS)?;
        let d = Settings::default();
        let s = Settings {
            epsilon: cfg.get_or("epsilon", d.epsilon)?,
            window: cfg.get_or("window", d.window)?,
            dataset: cfg.get("dataset").unwrap_or(&d.dataset).to_string(),
            events: cfg.get_or("events", d.events)?,
            seed: cfg.get_or("seed", d.seed)?,
            artifacts: cfg.get("artifacts").unwrap_or(&d.artifacts).to_string(),
        };
        if s.epsilon < 0.0 || !s.epsilon.is_finite() {
            bail!("epsilon must be finite and ≥ 0, got {}", s.epsilon);
        }
        if s.window == 0 {
            bail!("window must be positive");
        }
        Ok(s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_pairs_comments_blanks() {
        let c = Config::parse("a = 1\n# comment\n\nb= x y # trailing\n").unwrap();
        assert_eq!(c.get("a"), Some("1"));
        assert_eq!(c.get("b"), Some("x y"));
        assert_eq!(c.get("missing"), None);
    }

    #[test]
    fn typed_accessors() {
        let c = Config::parse("n = 42\nf = 0.5\nflag = true").unwrap();
        assert_eq!(c.get_or("n", 0usize).unwrap(), 42);
        assert_eq!(c.get_or("f", 0.0f64).unwrap(), 0.5);
        assert!(c.get_or("flag", false).unwrap());
        assert_eq!(c.get_or("absent", 7u32).unwrap(), 7);
        assert!(c.get_or("f", 0usize).is_err());
    }

    #[test]
    fn rejects_malformed_lines() {
        assert!(Config::parse("just a line").is_err());
        assert!(Config::parse("= novalue").is_err());
    }

    #[test]
    fn settings_defaults_and_overrides() {
        let mut c = Config::parse("epsilon = 0.1\nwindow = 200").unwrap();
        let s = Settings::from_config(&c).unwrap();
        assert_eq!(s.epsilon, 0.1);
        assert_eq!(s.window, 200);
        assert_eq!(s.dataset, "miniboone");
        c.set("dataset", "tvads");
        assert_eq!(Settings::from_config(&c).unwrap().dataset, "tvads");
    }

    #[test]
    fn settings_reject_bad_values() {
        let c = Config::parse("epsilon = -1").unwrap();
        assert!(Settings::from_config(&c).is_err());
        let c = Config::parse("window = 0").unwrap();
        assert!(Settings::from_config(&c).is_err());
        let c = Config::parse("unknown_key = 1").unwrap();
        assert!(Settings::from_config(&c).is_err());
    }
}
