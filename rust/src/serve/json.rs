//! Human-readable JSON codec for the serving layer.
//!
//! Hand-rolled: the offline image forbids crates.io, so there is no
//! serde here — just the handful of fixed document shapes the server
//! emits (`crate::serve::FleetServer`) and a minimal recursive-descent
//! parser for the clients and round-trip tests.
//!
//! **Wire ≡ in-process bit-identity.** Floats are written with Rust's
//! `{}` formatting, which emits the shortest decimal that parses back
//! to the identical bits for every finite `f64`; the decoder keeps the
//! raw digits and re-parses them with `str::parse::<f64>`, so
//! `decode(encode(x))` reproduces `x` bit-for-bit (`rust/DESIGN.md`
//! §Serving). The 128-bit fixed-point AUC sum travels as a decimal
//! *string* (`"qauc_sum":"…"`) because JSON numbers beyond 2⁵³ are not
//! faithfully representable in consumers that funnel numbers through
//! f64. Every float the fleet serves is finite by construction;
//! encoding a non-finite one is a contract violation (debug-asserted).

use std::fmt::Write as _;

use crate::fleet::{
    AucHistogram, FleetAggregate, FleetSketch, FleetSnapshot, ScoreHistogram, StreamSnapshot,
};

// ---------------------------------------------------------------------
// Encoding
// ---------------------------------------------------------------------

/// Append one finite float in shortest-round-trip form.
fn num(out: &mut String, v: f64) {
    debug_assert!(v.is_finite(), "JSON codec requires finite floats, got {v}");
    let _ = write!(out, "{v}");
}

fn stream_snapshot(out: &mut String, s: &StreamSnapshot) {
    let _ = write!(out, "{{\"stream\":{},\"auc\":", s.stream);
    num(out, s.auc);
    let _ = write!(
        out,
        ",\"len\":{},\"compressed_len\":{},\"footprint_bytes\":{},\"events\":{},\"alarms\":{},\"alarmed\":{}",
        s.len, s.compressed_len, s.footprint_bytes, s.events, s.alarms, s.alarmed
    );
    out.push_str(",\"baseline\":");
    match s.baseline {
        Some(b) => num(out, b),
        None => out.push_str("null"),
    }
    out.push('}');
}

/// `/snapshot` document.
pub fn snapshot_to_json(s: &FleetSnapshot) -> String {
    let mut out = String::with_capacity(64 + 112 * s.streams.len());
    let _ = write!(out, "{{\"total_events\":{},\"alarmed_streams\":[", s.total_events);
    for (i, id) in s.alarmed_streams.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        let _ = write!(out, "{id}");
    }
    out.push_str("],\"streams\":[");
    for (i, st) in s.streams.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        stream_snapshot(&mut out, st);
    }
    out.push_str("]}");
    out
}

/// `/aggregate` document.
pub fn aggregate_to_json(a: &FleetAggregate) -> String {
    let mut out = String::with_capacity(256);
    let _ = write!(
        out,
        "{{\"streams\":{},\"live_streams\":{},\"alarmed_streams\":{},\"total_events\":{},\"footprint_bytes\":{}",
        a.streams, a.live_streams, a.alarmed_streams, a.total_events, a.footprint_bytes
    );
    for (key, v) in [
        ("min_auc", a.min_auc),
        ("p10_auc", a.p10_auc),
        ("median_auc", a.median_auc),
        ("p90_auc", a.p90_auc),
        ("max_auc", a.max_auc),
        ("mean_auc", a.mean_auc),
    ] {
        let _ = write!(out, ",\"{key}\":");
        num(&mut out, v);
    }
    out.push('}');
    out
}

/// `/top_k_worst` document.
pub fn top_k_to_json(streams: &[StreamSnapshot]) -> String {
    let mut out = String::with_capacity(16 + 112 * streams.len());
    out.push_str("{\"streams\":[");
    for (i, st) in streams.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        stream_snapshot(&mut out, st);
    }
    out.push_str("]}");
    out
}

/// `/count_below` document.
pub fn count_below_to_json(threshold: f64, count: usize) -> String {
    let mut out = String::from("{\"threshold\":");
    num(&mut out, threshold);
    let _ = write!(out, ",\"count\":{count}}}");
    out
}

/// `/auc_histogram` document.
pub fn auc_histogram_to_json(h: &AucHistogram) -> String {
    let mut out = String::with_capacity(32 + 8 * h.counts.len());
    out.push_str("{\"counts\":[");
    for (i, c) in h.counts.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        let _ = write!(out, "{c}");
    }
    let _ = write!(out, "],\"live_streams\":{}}}", h.live_streams);
    out
}

/// `/score_histogram` document.
pub fn score_histogram_to_json(h: &ScoreHistogram) -> String {
    let mut out = String::with_capacity(32 + 8 * h.counts.len());
    out.push_str("{\"counts\":[");
    for (i, c) in h.counts.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        let _ = write!(out, "{c}");
    }
    let _ = write!(out, "],\"entries\":{}}}", h.entries);
    out
}

fn sketch_scalars(out: &mut String, seq: u64, sk: &FleetSketch) {
    let _ = write!(
        out,
        "{{\"seq\":{seq},\"streams\":{},\"live\":{},\"alarmed\":{},\"qauc_sum\":\"{}\"",
        sk.streams, sk.live, sk.alarmed, sk.qauc_sum
    );
}

/// A subscription **baseline** line: scalars plus the full bin array.
/// Sent once when a subscriber attaches, so later deltas have a state
/// to apply onto.
pub fn sketch_to_json(seq: u64, sk: &FleetSketch) -> String {
    let mut out = String::with_capacity(64 + 8 * sk.bins.len());
    sketch_scalars(&mut out, seq, sk);
    out.push_str(",\"bins\":[");
    for (i, c) in sk.bins.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        let _ = write!(out, "{c}");
    }
    out.push_str("]}");
    out
}

/// A subscription **delta** line: scalars are absolute (self-healing),
/// bins are compressed to the `[bin, new_count]` pairs that changed
/// since `prev`.
pub fn delta_to_json(seq: u64, prev: &FleetSketch, next: &FleetSketch) -> String {
    let mut out = String::with_capacity(128);
    sketch_scalars(&mut out, seq, next);
    out.push_str(",\"changed\":[");
    let mut first = true;
    for (b, (&p, &n)) in prev.bins.iter().zip(next.bins.iter()).enumerate() {
        if p != n {
            if !first {
                out.push(',');
            }
            first = false;
            let _ = write!(out, "[{b},{n}]");
        }
    }
    out.push_str("]}");
    out
}

/// A subscription **lagged** notice line: this subscriber fell behind
/// and its missed deltas were coalesced; the very next line is a fresh
/// baseline at `seq` to resume from.
pub fn lagged_to_json(seq: u64) -> String {
    format!("{{\"lagged\":true,\"seq\":{seq}}}")
}

/// Recognize a lagged notice line, returning the baseline seq it
/// announces. `None` for any other line (baseline or delta) — callers
/// check this before [`apply_subscription_json`].
pub fn parse_lagged_notice(text: &str) -> Option<u64> {
    let v = Json::parse(text).ok()?;
    if v.get("lagged").ok()?.bool().ok()? {
        v.get("seq").ok()?.u64().ok()
    } else {
        None
    }
}

// ---------------------------------------------------------------------
// Parsing
// ---------------------------------------------------------------------

/// A parsed JSON value. Numbers keep their raw text until a typed
/// accessor parses them — nothing is funneled through an intermediate
/// f64, which is what preserves bit-identity and 128-bit integers.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// A number, as its raw text.
    Num(String),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object, fields in document order.
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Parse one complete JSON document.
    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(format!("trailing bytes at offset {}", p.i));
        }
        Ok(v)
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Result<&Json, String> {
        match self {
            Json::Obj(fields) => fields
                .iter()
                .find(|(k, _)| k == key)
                .map(|(_, v)| v)
                .ok_or_else(|| format!("missing key {key:?}")),
            _ => Err(format!("expected an object holding {key:?}")),
        }
    }

    /// The value as a finite `f64` (exact reparse of the raw digits).
    pub fn f64(&self) -> Result<f64, String> {
        match self {
            Json::Num(raw) => raw.parse().map_err(|e| format!("number {raw:?}: {e}")),
            _ => Err("expected a number".to_string()),
        }
    }

    /// The value as a `u64`.
    pub fn u64(&self) -> Result<u64, String> {
        match self {
            Json::Num(raw) => raw.parse().map_err(|e| format!("number {raw:?}: {e}")),
            _ => Err("expected a number".to_string()),
        }
    }

    /// The value as a `u32`.
    pub fn u32(&self) -> Result<u32, String> {
        match self {
            Json::Num(raw) => raw.parse().map_err(|e| format!("number {raw:?}: {e}")),
            _ => Err("expected a number".to_string()),
        }
    }

    /// The value as a `usize`.
    pub fn usize(&self) -> Result<usize, String> {
        match self {
            Json::Num(raw) => raw.parse().map_err(|e| format!("number {raw:?}: {e}")),
            _ => Err("expected a number".to_string()),
        }
    }

    /// The value as an `i128` carried in a JSON *string* (the
    /// `qauc_sum` convention).
    pub fn i128_str(&self) -> Result<i128, String> {
        match self {
            Json::Str(raw) => raw.parse().map_err(|e| format!("i128 {raw:?}: {e}")),
            _ => Err("expected a decimal string".to_string()),
        }
    }

    /// The value as a `bool`.
    pub fn bool(&self) -> Result<bool, String> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => Err("expected a boolean".to_string()),
        }
    }

    /// The value as `null`-or-finite-f64 (the `baseline` convention).
    pub fn opt_f64(&self) -> Result<Option<f64>, String> {
        match self {
            Json::Null => Ok(None),
            other => other.f64().map(Some),
        }
    }

    /// The value as an array slice.
    pub fn arr(&self) -> Result<&[Json], String> {
        match self {
            Json::Arr(items) => Ok(items),
            _ => Err("expected an array".to_string()),
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl Parser<'_> {
    fn ws(&mut self) {
        while matches!(self.b.get(self.i), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.b.get(self.i) {
            None => Err("unexpected end of input".to_string()),
            Some(b'{') => self.obj(),
            Some(b'[') => self.array(),
            Some(b'"') => self.string().map(Json::Str),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(_) => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("invalid literal at offset {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        while matches!(
            self.b.get(self.i),
            Some(b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        ) {
            self.i += 1;
        }
        if self.i == start {
            return Err(format!("expected a value at offset {start}"));
        }
        // The slice is ASCII by construction of the loop above.
        let raw = std::str::from_utf8(&self.b[start..self.i])
            .map_err(|_| "non-UTF8 number".to_string())?;
        Ok(Json::Num(raw.to_string()))
    }

    fn string(&mut self) -> Result<String, String> {
        self.i += 1; // opening quote
        let mut out = String::new();
        loop {
            match self.b.get(self.i) {
                None => return Err("unterminated string".to_string()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let esc = *self
                        .b
                        .get(self.i)
                        .ok_or_else(|| "unterminated escape".to_string())?;
                    out.push(match esc {
                        b'"' => '"',
                        b'\\' => '\\',
                        b'/' => '/',
                        b'n' => '\n',
                        b'r' => '\r',
                        b't' => '\t',
                        other => {
                            return Err(format!("unsupported escape \\{}", other as char));
                        }
                    });
                    self.i += 1;
                }
                Some(&c) if c < 0x80 => {
                    out.push(c as char);
                    self.i += 1;
                }
                Some(_) => {
                    // Multibyte UTF-8 scalar: copy it whole.
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| "non-UTF8 string".to_string())?;
                    let ch = rest.chars().next().expect("non-empty by construction");
                    out.push(ch);
                    self.i += ch.len_utf8();
                }
            }
        }
    }

    fn obj(&mut self) -> Result<Json, String> {
        self.i += 1; // '{'
        let mut fields = Vec::new();
        self.ws();
        if self.b.get(self.i) == Some(&b'}') {
            self.i += 1;
            return Ok(Json::Obj(fields));
        }
        loop {
            self.ws();
            if self.b.get(self.i) != Some(&b'"') {
                return Err(format!("expected a key at offset {}", self.i));
            }
            let key = self.string()?;
            self.ws();
            if self.b.get(self.i) != Some(&b':') {
                return Err(format!("expected ':' at offset {}", self.i));
            }
            self.i += 1;
            self.ws();
            let v = self.value()?;
            fields.push((key, v));
            self.ws();
            match self.b.get(self.i) {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(fields));
                }
                _ => return Err(format!("expected ',' or '}}' at offset {}", self.i)),
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.i += 1; // '['
        let mut items = Vec::new();
        self.ws();
        if self.b.get(self.i) == Some(&b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.ws();
            items.push(self.value()?);
            self.ws();
            match self.b.get(self.i) {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(format!("expected ',' or ']' at offset {}", self.i)),
            }
        }
    }
}

// ---------------------------------------------------------------------
// Typed decoding
// ---------------------------------------------------------------------

fn stream_snapshot_from(v: &Json) -> Result<StreamSnapshot, String> {
    Ok(StreamSnapshot {
        stream: v.get("stream")?.u64()?,
        auc: v.get("auc")?.f64()?,
        len: v.get("len")?.usize()?,
        compressed_len: v.get("compressed_len")?.usize()?,
        events: v.get("events")?.u64()?,
        alarms: v.get("alarms")?.u32()?,
        alarmed: v.get("alarmed")?.bool()?,
        baseline: v.get("baseline")?.opt_f64()?,
        footprint_bytes: v.get("footprint_bytes")?.u64()?,
    })
}

/// Decode a `/snapshot` document.
pub fn snapshot_from_json(text: &str) -> Result<FleetSnapshot, String> {
    let v = Json::parse(text)?;
    let streams = v
        .get("streams")?
        .arr()?
        .iter()
        .map(stream_snapshot_from)
        .collect::<Result<Vec<_>, _>>()?;
    let alarmed_streams = v
        .get("alarmed_streams")?
        .arr()?
        .iter()
        .map(Json::u64)
        .collect::<Result<Vec<_>, _>>()?;
    Ok(FleetSnapshot { streams, alarmed_streams, total_events: v.get("total_events")?.u64()? })
}

/// Decode an `/aggregate` document.
pub fn aggregate_from_json(text: &str) -> Result<FleetAggregate, String> {
    let v = Json::parse(text)?;
    Ok(FleetAggregate {
        streams: v.get("streams")?.usize()?,
        live_streams: v.get("live_streams")?.usize()?,
        alarmed_streams: v.get("alarmed_streams")?.usize()?,
        total_events: v.get("total_events")?.u64()?,
        min_auc: v.get("min_auc")?.f64()?,
        p10_auc: v.get("p10_auc")?.f64()?,
        median_auc: v.get("median_auc")?.f64()?,
        p90_auc: v.get("p90_auc")?.f64()?,
        max_auc: v.get("max_auc")?.f64()?,
        mean_auc: v.get("mean_auc")?.f64()?,
        footprint_bytes: v.get("footprint_bytes")?.u64()?,
    })
}

/// Decode a `/top_k_worst` document.
pub fn top_k_from_json(text: &str) -> Result<Vec<StreamSnapshot>, String> {
    let v = Json::parse(text)?;
    v.get("streams")?.arr()?.iter().map(stream_snapshot_from).collect()
}

/// Decode a `/count_below` document into `(threshold, count)`.
pub fn count_below_from_json(text: &str) -> Result<(f64, usize), String> {
    let v = Json::parse(text)?;
    Ok((v.get("threshold")?.f64()?, v.get("count")?.usize()?))
}

/// Decode an `/auc_histogram` document.
pub fn auc_histogram_from_json(text: &str) -> Result<AucHistogram, String> {
    let v = Json::parse(text)?;
    let counts =
        v.get("counts")?.arr()?.iter().map(Json::usize).collect::<Result<Vec<_>, _>>()?;
    Ok(AucHistogram { counts, live_streams: v.get("live_streams")?.usize()? })
}

/// Decode a `/score_histogram` document.
pub fn score_histogram_from_json(text: &str) -> Result<ScoreHistogram, String> {
    let v = Json::parse(text)?;
    let counts = v.get("counts")?.arr()?.iter().map(Json::u64).collect::<Result<Vec<_>, _>>()?;
    Ok(ScoreHistogram { counts, entries: v.get("entries")?.u64()? })
}

fn sketch_scalars_from(v: &Json, bins: Vec<u64>) -> Result<(u64, FleetSketch), String> {
    Ok((
        v.get("seq")?.u64()?,
        FleetSketch {
            bins,
            live: v.get("live")?.usize()?,
            alarmed: v.get("alarmed")?.usize()?,
            streams: v.get("streams")?.usize()?,
            qauc_sum: v.get("qauc_sum")?.i128_str()?,
        },
    ))
}

/// Decode a subscription **baseline** line into `(seq, sketch)`.
pub fn sketch_from_json(text: &str) -> Result<(u64, FleetSketch), String> {
    let v = Json::parse(text)?;
    let bins = v.get("bins")?.arr()?.iter().map(Json::u64).collect::<Result<Vec<_>, _>>()?;
    sketch_scalars_from(&v, bins)
}

/// Apply one subscription line — baseline (`"bins"`) or delta
/// (`"changed"`) — onto `onto`, returning the line's sequence number.
/// Scalars are absolute in every line; only the bin array is
/// delta-compressed.
pub fn apply_subscription_json(text: &str, onto: &mut FleetSketch) -> Result<u64, String> {
    let v = Json::parse(text)?;
    if let Ok(bins) = v.get("bins") {
        let bins = bins.arr()?.iter().map(Json::u64).collect::<Result<Vec<_>, _>>()?;
        let (seq, sk) = sketch_scalars_from(&v, bins)?;
        *onto = sk;
        return Ok(seq);
    }
    for pair in v.get("changed")?.arr()? {
        let pair = pair.arr()?;
        if pair.len() != 2 {
            return Err("delta pair must be [bin, count]".to_string());
        }
        let bin = pair[0].usize()?;
        let count = pair[1].u64()?;
        let slot = onto
            .bins
            .get_mut(bin)
            .ok_or_else(|| format!("delta bin {bin} out of range"))?;
        *slot = count;
    }
    let (seq, scalars) = sketch_scalars_from(&v, Vec::new())?;
    onto.live = scalars.live;
    onto.alarmed = scalars.alarmed;
    onto.streams = scalars.streams;
    onto.qauc_sum = scalars.qauc_sum;
    Ok(seq)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn snap(stream: u64, auc: f64, baseline: Option<f64>) -> StreamSnapshot {
        StreamSnapshot {
            stream,
            auc,
            len: 7,
            compressed_len: 5,
            events: 90,
            alarms: 2,
            alarmed: baseline.is_some(),
            baseline,
            footprint_bytes: 1234,
        }
    }

    #[test]
    fn parser_handles_the_basics() {
        let v = Json::parse(r#" {"a": [1, -2.5e3, "x\n"], "b": null, "c": true} "#).unwrap();
        assert_eq!(v.get("a").unwrap().arr().unwrap().len(), 3);
        assert_eq!(v.get("a").unwrap().arr().unwrap()[0].u64().unwrap(), 1);
        assert_eq!(v.get("a").unwrap().arr().unwrap()[1].f64().unwrap(), -2.5e3);
        assert_eq!(v.get("a").unwrap().arr().unwrap()[2], Json::Str("x\n".to_string()));
        assert_eq!(v.get("b").unwrap().opt_f64().unwrap(), None);
        assert!(v.get("c").unwrap().bool().unwrap());
        assert!(v.get("missing").is_err());
        assert!(Json::parse("{\"a\":1} trailing").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{").is_err());
    }

    #[test]
    fn snapshot_round_trips_awkward_floats_bitwise() {
        // Shortest-round-trip Display must reproduce these exactly.
        let awkward = [
            0.1 + 0.2,
            1.0 / 3.0,
            f64::MIN_POSITIVE,
            5e-324, // subnormal
            1.0 - f64::EPSILON,
            0.999_999_999_999_999_9,
        ];
        let streams: Vec<StreamSnapshot> = awkward
            .iter()
            .enumerate()
            .map(|(i, &a)| snap(i as u64, a, if i % 2 == 0 { Some(a / 2.0) } else { None }))
            .collect();
        let original = FleetSnapshot {
            streams,
            alarmed_streams: vec![0, 2, 4],
            total_events: u64::MAX,
        };
        let text = snapshot_to_json(&original);
        let back = snapshot_from_json(&text).unwrap();
        assert_eq!(back, original);
        for (a, b) in original.streams.iter().zip(&back.streams) {
            assert_eq!(a.auc.to_bits(), b.auc.to_bits());
        }
        // Byte-derived equality: re-encoding the decoded value is the
        // identical document.
        assert_eq!(snapshot_to_json(&back), text);
    }

    #[test]
    fn aggregate_and_histograms_round_trip() {
        let agg = FleetAggregate {
            streams: 11,
            live_streams: 9,
            alarmed_streams: 3,
            total_events: 1 << 60,
            min_auc: 0.0,
            p10_auc: 0.1 + 0.2,
            median_auc: 0.5,
            p90_auc: 2.0 / 3.0,
            max_auc: 1.0,
            mean_auc: 0.123_456_789_012_345_67,
            footprint_bytes: u64::MAX,
        };
        let back = aggregate_from_json(&aggregate_to_json(&agg)).unwrap();
        assert_eq!(back, agg);
        assert_eq!(back.p10_auc.to_bits(), agg.p10_auc.to_bits());

        let h = AucHistogram { counts: vec![0, 3, 1, usize::MAX], live_streams: 4 };
        assert_eq!(auc_histogram_from_json(&auc_histogram_to_json(&h)).unwrap(), h);
        let s = ScoreHistogram { counts: vec![u64::MAX, 0, 7], entries: 42 };
        assert_eq!(score_histogram_from_json(&score_histogram_to_json(&s)).unwrap(), s);
    }

    #[test]
    fn top_k_and_count_below_round_trip() {
        let streams = vec![snap(3, 0.25, None), snap(9, 0.75, Some(0.8))];
        assert_eq!(top_k_from_json(&top_k_to_json(&streams)).unwrap(), streams);
        assert_eq!(top_k_from_json(&top_k_to_json(&[])).unwrap(), Vec::new());
        let (t, c) = count_below_from_json(&count_below_to_json(0.8, 17)).unwrap();
        assert_eq!((t, c), (0.8, 17));
    }

    #[test]
    fn lagged_notices_round_trip_and_reject_other_lines() {
        assert_eq!(parse_lagged_notice(&lagged_to_json(42)), Some(42));
        let sk = FleetSketch { bins: vec![0; 64], live: 0, alarmed: 0, streams: 0, qauc_sum: 0 };
        assert_eq!(parse_lagged_notice(&sketch_to_json(7, &sk)), None);
        assert_eq!(parse_lagged_notice(&delta_to_json(8, &sk, &sk)), None);
        assert_eq!(parse_lagged_notice("not json"), None);
    }

    #[test]
    fn subscription_deltas_reconstruct_the_sketch() {
        let mut prev = FleetSketch {
            bins: vec![0; 64],
            live: 3,
            alarmed: 1,
            streams: 4,
            qauc_sum: -(1_i128 << 100),
        };
        prev.bins[10] = 2;
        prev.bins[63] = 1;
        // Baseline line restores the whole state.
        let (seq, back) = sketch_from_json(&sketch_to_json(7, &prev)).unwrap();
        assert_eq!(seq, 7);
        assert_eq!(back, prev);

        // A delta line carries only the changed bins.
        let mut next = prev.clone();
        next.bins[10] = 0;
        next.bins[11] = 3;
        next.live = 4;
        next.qauc_sum = 1 << 90;
        let line = delta_to_json(8, &prev, &next);
        assert!(line.contains("\"changed\":[[10,0],[11,3]]"), "{line}");
        let mut applied = prev.clone();
        assert_eq!(apply_subscription_json(&line, &mut applied).unwrap(), 8);
        assert_eq!(applied, next);
        // Applying a baseline line through the same entry point works.
        let mut fresh = FleetSketch {
            bins: vec![0; 64],
            live: 0,
            alarmed: 0,
            streams: 0,
            qauc_sum: 0,
        };
        assert_eq!(
            apply_subscription_json(&sketch_to_json(9, &next), &mut fresh).unwrap(),
            9
        );
        assert_eq!(fresh, next);
    }
}
