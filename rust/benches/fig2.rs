//! Bench target regenerating Figure 2: running time (top) and
//! compressed-list size |C| (bottom) versus the achieved average error.
//!
//! `cargo bench --bench fig2 [-- --events N --window K]`
//!
//! Expected shape (paper §6): time falls as ε (and the error) grows,
//! then plateaus on the ε-independent tree maintenance; |C| ~ (log k)/ε.

use streamauc::experiments::{fig2, ExpConfig};

fn main() {
    let mut cfg = ExpConfig { events: 30_000, ..Default::default() };
    let args: Vec<String> = std::env::args().collect();
    if let Some(i) = args.iter().position(|a| a == "--events") {
        cfg.events = args[i + 1].parse().expect("--events N");
    }
    if let Some(i) = args.iter().position(|a| a == "--window") {
        cfg.window = args[i + 1].parse().expect("--window K");
    }
    println!("{}", fig2::run(cfg).render());
}
