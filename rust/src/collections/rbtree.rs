//! Arena-based augmented red-black tree (paper §3.1).
//!
//! The paper stores the sliding window in a red-black tree `T` sorted by
//! score, augmented with subtree label sums `accpos`/`accneg` that are
//! maintained through rotations “without additional costs”, and keeps a
//! second tree `TP` over the positive nodes for the `MaxPos` query (§3.2).
//!
//! Both trees are instances of the same machinery: nodes live in a typed
//! [`Arena`] slab, are addressed by [`NodeId`], and carry a user value `V`
//! plus an augmentation `A` recomputed locally from a node's value and its
//! children's augmentations. Rotations and the insert/delete fix-ups keep
//! the augmentation consistent, so subtree-sum queries such as
//! `HeadStats` (Algorithm 1) remain `O(log k)`.
//!
//! The tree comes in two forms sharing one implementation:
//!
//! * [`RbTreeCore`] — the storage-free form: a root index and a length.
//!   Every method takes the backing `Arena<Node<V, A>>` explicitly, so
//!   many cores (one per stream) can share one shard-owned arena — the
//!   million-stream memory layout (`rust/DESIGN.md` §Memory).
//! * [`RbTree`] — the self-contained form bundling a core with its own
//!   private arena; the ergonomic owner for standalone estimators,
//!   tests and benches.
//!
//! Augmentation-maintenance order (important for correctness):
//! 1. structural change (BST insert / transplant-delete);
//! 2. [`RbTreeCore::update_upward`] from the deepest structurally changed
//!    node — after this the whole path to the root is consistent;
//! 3. rebalancing fix-up — each rotation recomputes exactly the two
//!    rotated nodes from their (already consistent) children, and
//!    recolourings never touch the augmentation.

use super::arena::Arena;
use super::score::Score;

/// Handle to a tree node. Stable for the node's lifetime; slots are
/// recycled after removal, so holders must not use a handle past `remove`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct NodeId(pub u32);

const NIL: u32 = u32::MAX;

impl NodeId {
    #[inline]
    fn idx(self) -> usize {
        self.0 as usize
    }
}

/// Subtree augmentation: recomputed locally from the node value and the
/// children's augmentations whenever the subtree under a node changes.
pub trait Augment<V>: Clone {
    /// Value of the augmentation for a node with value `val` whose children
    /// carry `left` / `right` (absent child ⇒ `None`).
    fn recompute(val: &V, left: Option<&Self>, right: Option<&Self>) -> Self;
}

/// No augmentation (used by the positive-index tree `TP`).
impl<V> Augment<V> for () {
    #[inline]
    fn recompute(_: &V, _: Option<&Self>, _: Option<&Self>) -> Self {}
}

/// One tree node as stored in the arena slab.
#[derive(Clone, Debug)]
pub(crate) struct Node<V, A> {
    key: Score,
    val: V,
    aug: A,
    left: u32,
    right: u32,
    parent: u32,
    red: bool,
}

#[inline]
fn min_of<V, A>(ar: &Arena<Node<V, A>>, mut i: u32) -> u32 {
    while ar.slots[i as usize].left != NIL {
        i = ar.slots[i as usize].left;
    }
    i
}

#[inline]
fn max_of<V, A>(ar: &Arena<Node<V, A>>, mut i: u32) -> u32 {
    while ar.slots[i as usize].right != NIL {
        i = ar.slots[i as usize].right;
    }
    i
}

/// In-order successor by link-walking (independent of the root).
fn succ<V, A>(ar: &Arena<Node<V, A>>, id: u32) -> u32 {
    let mut i = id;
    if ar.slots[i as usize].right != NIL {
        return min_of(ar, ar.slots[i as usize].right);
    }
    let mut p = ar.slots[i as usize].parent;
    while p != NIL && ar.slots[p as usize].right == i {
        i = p;
        p = ar.slots[p as usize].parent;
    }
    p
}

/// In-order predecessor by link-walking.
fn pred<V, A>(ar: &Arena<Node<V, A>>, id: u32) -> u32 {
    let mut i = id;
    if ar.slots[i as usize].left != NIL {
        return max_of(ar, ar.slots[i as usize].left);
    }
    let mut p = ar.slots[i as usize].parent;
    while p != NIL && ar.slots[p as usize].left == i {
        i = p;
        p = ar.slots[p as usize].parent;
    }
    p
}

fn recompute_aug<V, A: Augment<V>>(ar: &mut Arena<Node<V, A>>, i: u32) {
    let (l, r) = {
        let n = &ar.slots[i as usize];
        (n.left, n.right)
    };
    let la = if l == NIL { None } else { Some(&ar.slots[l as usize].aug) };
    let ra = if r == NIL { None } else { Some(&ar.slots[r as usize].aug) };
    let aug = A::recompute(&ar.slots[i as usize].val, la, ra);
    ar.slots[i as usize].aug = aug;
}

/// Storage-free augmented red-black tree: root index + length, with the
/// backing arena passed into every operation. Copyable — a stream's
/// whole tree handle is twelve bytes.
///
/// Duplicate keys are rejected by [`RbTreeCore::insert`] (it returns the
/// existing node), matching the paper where one tree node aggregates
/// every window entry sharing a score.
///
/// Correct use requires passing the *same* arena the core's nodes were
/// allocated from to every call; the shard layer guarantees this by
/// owning arenas and cores together.
#[derive(Clone, Copy, Debug)]
pub(crate) struct RbTreeCore {
    root: u32,
    len: usize,
}

impl Default for RbTreeCore {
    fn default() -> Self {
        Self::new()
    }
}

impl RbTreeCore {
    /// Empty tree.
    pub(crate) fn new() -> RbTreeCore {
        RbTreeCore { root: NIL, len: 0 }
    }

    /// Number of live nodes.
    #[inline]
    pub(crate) fn len(&self) -> usize {
        self.len
    }

    /// True when the tree holds no nodes.
    #[inline]
    pub(crate) fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Root node, if any.
    #[inline]
    pub(crate) fn root(&self) -> Option<NodeId> {
        wrap(self.root)
    }

    /// Key (score) of a node.
    #[inline]
    pub(crate) fn key<V, A>(&self, ar: &Arena<Node<V, A>>, id: NodeId) -> Score {
        ar.slots[id.idx()].key
    }

    /// Value of a node.
    #[inline]
    pub(crate) fn val<'a, V, A>(&self, ar: &'a Arena<Node<V, A>>, id: NodeId) -> &'a V {
        &ar.slots[id.idx()].val
    }

    /// Augmentation of a node (the subtree summary).
    #[inline]
    pub(crate) fn aug<'a, V, A>(&self, ar: &'a Arena<Node<V, A>>, id: NodeId) -> &'a A {
        &ar.slots[id.idx()].aug
    }

    /// Left child.
    #[inline]
    pub(crate) fn left<V, A>(&self, ar: &Arena<Node<V, A>>, id: NodeId) -> Option<NodeId> {
        wrap(ar.slots[id.idx()].left)
    }

    /// Right child.
    #[inline]
    pub(crate) fn right<V, A>(&self, ar: &Arena<Node<V, A>>, id: NodeId) -> Option<NodeId> {
        wrap(ar.slots[id.idx()].right)
    }

    /// Parent node.
    #[inline]
    pub(crate) fn parent<V, A>(&self, ar: &Arena<Node<V, A>>, id: NodeId) -> Option<NodeId> {
        wrap(ar.slots[id.idx()].parent)
    }

    /// Mutate a node's value, then restore the augmentation along the path
    /// to the root (`O(log k)`, paper §3.3 “update the accpos counters …
    /// only for the ancestors”).
    pub(crate) fn with_val_mut<V, A: Augment<V>, R>(
        &mut self,
        ar: &mut Arena<Node<V, A>>,
        id: NodeId,
        f: impl FnOnce(&mut V) -> R,
    ) -> R {
        let r = f(&mut ar.slots[id.idx()].val);
        self.update_upward(ar, id);
        r
    }

    /// Recompute augmentations from `id` up to the root.
    pub(crate) fn update_upward<V, A: Augment<V>>(&self, ar: &mut Arena<Node<V, A>>, id: NodeId) {
        let mut cur = id.0;
        while cur != NIL {
            recompute_aug(ar, cur);
            cur = ar.slots[cur as usize].parent;
        }
    }

    /// Find the node with exactly this key.
    pub(crate) fn find<V, A>(&self, ar: &Arena<Node<V, A>>, key: Score) -> Option<NodeId> {
        let mut cur = self.root;
        while cur != NIL {
            let n = &ar.slots[cur as usize];
            cur = match key.cmp(&n.key) {
                std::cmp::Ordering::Less => n.left,
                std::cmp::Ordering::Greater => n.right,
                std::cmp::Ordering::Equal => return Some(NodeId(cur)),
            };
        }
        None
    }

    /// Largest node with key `≤ key` (the shape of `MaxPos`, paper §3.2).
    pub(crate) fn floor<V, A>(&self, ar: &Arena<Node<V, A>>, key: Score) -> Option<NodeId> {
        let mut cur = self.root;
        let mut best = NIL;
        while cur != NIL {
            let n = &ar.slots[cur as usize];
            if n.key <= key {
                best = cur;
                cur = n.right;
            } else {
                cur = n.left;
            }
        }
        wrap(best)
    }

    /// Smallest node with key `≥ key`.
    pub(crate) fn ceil<V, A>(&self, ar: &Arena<Node<V, A>>, key: Score) -> Option<NodeId> {
        let mut cur = self.root;
        let mut best = NIL;
        while cur != NIL {
            let n = &ar.slots[cur as usize];
            if n.key >= key {
                best = cur;
                cur = n.left;
            } else {
                cur = n.right;
            }
        }
        wrap(best)
    }

    /// Node with the smallest key.
    pub(crate) fn first<V, A>(&self, ar: &Arena<Node<V, A>>) -> Option<NodeId> {
        if self.root == NIL {
            return None;
        }
        Some(NodeId(min_of(ar, self.root)))
    }

    /// Node with the largest key.
    pub(crate) fn last<V, A>(&self, ar: &Arena<Node<V, A>>) -> Option<NodeId> {
        if self.root == NIL {
            return None;
        }
        Some(NodeId(max_of(ar, self.root)))
    }

    /// In-order successor.
    pub(crate) fn successor<V, A>(&self, ar: &Arena<Node<V, A>>, id: NodeId) -> Option<NodeId> {
        wrap(succ(ar, id.0))
    }

    /// In-order predecessor.
    pub(crate) fn predecessor<V, A>(&self, ar: &Arena<Node<V, A>>, id: NodeId) -> Option<NodeId> {
        wrap(pred(ar, id.0))
    }

    /// In-order iteration over node ids (ascending key).
    pub(crate) fn iter_in<'a, V, A>(&self, ar: &'a Arena<Node<V, A>>) -> InOrder<'a, V, A> {
        InOrder { ar, next: self.first(ar) }
    }

    /// Insert `key`, creating the node with `make()` if absent.
    ///
    /// Returns the node and whether it was newly created. On creation the
    /// augmentation path to the root is restored.
    pub(crate) fn insert<V, A: Augment<V>>(
        &mut self,
        ar: &mut Arena<Node<V, A>>,
        key: Score,
        make: impl FnOnce() -> V,
    ) -> (NodeId, bool) {
        let mut parent = NIL;
        let mut cur = self.root;
        let mut went_left = false;
        while cur != NIL {
            parent = cur;
            let n = &ar.slots[cur as usize];
            match key.cmp(&n.key) {
                std::cmp::Ordering::Less => {
                    cur = n.left;
                    went_left = true;
                }
                std::cmp::Ordering::Greater => {
                    cur = n.right;
                    went_left = false;
                }
                std::cmp::Ordering::Equal => return (NodeId(cur), false),
            }
        }
        let val = make();
        let aug = A::recompute(&val, None, None);
        let node = Node { key, val, aug, left: NIL, right: NIL, parent, red: true };
        let id = ar.alloc(node);
        if parent == NIL {
            self.root = id;
        } else if went_left {
            ar.slots[parent as usize].left = id;
        } else {
            ar.slots[parent as usize].right = id;
        }
        self.len += 1;
        if parent != NIL {
            self.update_upward(ar, NodeId(parent));
        }
        self.insert_fixup(ar, id);
        (NodeId(id), true)
    }

    /// Remove a node, returning its slot to the arena's free list. The
    /// handle (and any copies) become invalid; the slot may be recycled
    /// by a later insert into *any* structure sharing the arena.
    pub(crate) fn remove<V, A: Augment<V>>(&mut self, ar: &mut Arena<Node<V, A>>, id: NodeId) {
        let z = id.0;
        debug_assert!(self.is_live(ar, id), "remove of dead node");
        let (zl, zr) = (ar.slots[z as usize].left, ar.slots[z as usize].right);
        // y: node physically unlinked or moved; x: subtree replacing y's
        // old position (possibly NIL); xp: x's parent after the transplant.
        let y_red;
        let x;
        let xp;
        if zl == NIL {
            y_red = ar.slots[z as usize].red;
            x = zr;
            xp = ar.slots[z as usize].parent;
            self.transplant(ar, z, zr);
        } else if zr == NIL {
            y_red = ar.slots[z as usize].red;
            x = zl;
            xp = ar.slots[z as usize].parent;
            self.transplant(ar, z, zl);
        } else {
            let y = min_of(ar, zr);
            y_red = ar.slots[y as usize].red;
            x = ar.slots[y as usize].right;
            if ar.slots[y as usize].parent == z {
                xp = y;
            } else {
                xp = ar.slots[y as usize].parent;
                self.transplant(ar, y, x);
                let zr_now = ar.slots[z as usize].right;
                ar.slots[y as usize].right = zr_now;
                ar.slots[zr_now as usize].parent = y;
            }
            self.transplant(ar, z, y);
            let zl_now = ar.slots[z as usize].left;
            ar.slots[y as usize].left = zl_now;
            ar.slots[zl_now as usize].parent = y;
            ar.slots[y as usize].red = ar.slots[z as usize].red;
        }
        // Restore augmentation along the whole changed path before any
        // rebalancing rotations (they recompute locally from children).
        if xp != NIL {
            self.update_upward(ar, NodeId(xp));
        }
        if !y_red {
            self.delete_fixup(ar, x, xp);
        }
        // Retire the slot.
        ar.release(z);
        self.len -= 1;
        // Poison links in debug builds to catch stale handles.
        if cfg!(debug_assertions) {
            let n = &mut ar.slots[z as usize];
            n.left = NIL;
            n.right = NIL;
            n.parent = NIL;
        }
    }

    /// True if `id` currently addresses a live node (test/debug helper; it
    /// is linear in the free list, and meaningful only for single-owner
    /// arenas — on a shared arena a freed slot may belong to a sibling).
    pub(crate) fn is_live<V, A>(&self, ar: &Arena<Node<V, A>>, id: NodeId) -> bool {
        id.idx() < ar.slots.len() && !ar.free.contains(&id.0)
    }

    /// Release every node back to the arena in one `O(len)` pass —
    /// no rebalancing, no per-node `remove`. The bulk-free hook for
    /// dropping a pooled stream (freeze / evict): afterwards the core
    /// is empty and all its slots are on the arena's free list.
    /// (Successor walks only read links, and released slots keep
    /// theirs intact until recycled — nothing allocates mid-walk.)
    pub(crate) fn drain<V, A>(&mut self, ar: &mut Arena<Node<V, A>>) {
        let mut cur = if self.root == NIL { NIL } else { min_of(ar, self.root) };
        while cur != NIL {
            let nxt = succ(ar, cur);
            ar.release(cur);
            cur = nxt;
        }
        self.root = NIL;
        self.len = 0;
    }

    fn transplant<V, A>(&mut self, ar: &mut Arena<Node<V, A>>, u: u32, v: u32) {
        let p = ar.slots[u as usize].parent;
        if p == NIL {
            self.root = v;
        } else if ar.slots[p as usize].left == u {
            ar.slots[p as usize].left = v;
        } else {
            ar.slots[p as usize].right = v;
        }
        if v != NIL {
            ar.slots[v as usize].parent = p;
        }
    }

    /// Left rotation around `x`; recomputes the augmentation of exactly the
    /// two rotated nodes (paper §3.3: counters are maintainable during
    /// rotations without additional cost).
    fn rotate_left<V, A: Augment<V>>(&mut self, ar: &mut Arena<Node<V, A>>, x: u32) {
        let y = ar.slots[x as usize].right;
        debug_assert_ne!(y, NIL);
        let yl = ar.slots[y as usize].left;
        ar.slots[x as usize].right = yl;
        if yl != NIL {
            ar.slots[yl as usize].parent = x;
        }
        let xp = ar.slots[x as usize].parent;
        ar.slots[y as usize].parent = xp;
        if xp == NIL {
            self.root = y;
        } else if ar.slots[xp as usize].left == x {
            ar.slots[xp as usize].left = y;
        } else {
            ar.slots[xp as usize].right = y;
        }
        ar.slots[y as usize].left = x;
        ar.slots[x as usize].parent = y;
        recompute_aug(ar, x);
        recompute_aug(ar, y);
    }

    fn rotate_right<V, A: Augment<V>>(&mut self, ar: &mut Arena<Node<V, A>>, x: u32) {
        let y = ar.slots[x as usize].left;
        debug_assert_ne!(y, NIL);
        let yr = ar.slots[y as usize].right;
        ar.slots[x as usize].left = yr;
        if yr != NIL {
            ar.slots[yr as usize].parent = x;
        }
        let xp = ar.slots[x as usize].parent;
        ar.slots[y as usize].parent = xp;
        if xp == NIL {
            self.root = y;
        } else if ar.slots[xp as usize].left == x {
            ar.slots[xp as usize].left = y;
        } else {
            ar.slots[xp as usize].right = y;
        }
        ar.slots[y as usize].right = x;
        ar.slots[x as usize].parent = y;
        recompute_aug(ar, x);
        recompute_aug(ar, y);
    }

    fn insert_fixup<V, A: Augment<V>>(&mut self, ar: &mut Arena<Node<V, A>>, mut z: u32) {
        while {
            let p = ar.slots[z as usize].parent;
            p != NIL && ar.slots[p as usize].red
        } {
            let p = ar.slots[z as usize].parent;
            let g = ar.slots[p as usize].parent;
            debug_assert_ne!(g, NIL, "red root");
            if ar.slots[g as usize].left == p {
                let u = ar.slots[g as usize].right;
                if u != NIL && ar.slots[u as usize].red {
                    ar.slots[p as usize].red = false;
                    ar.slots[u as usize].red = false;
                    ar.slots[g as usize].red = true;
                    z = g;
                } else {
                    if ar.slots[p as usize].right == z {
                        z = p;
                        self.rotate_left(ar, z);
                    }
                    let p = ar.slots[z as usize].parent;
                    let g = ar.slots[p as usize].parent;
                    ar.slots[p as usize].red = false;
                    ar.slots[g as usize].red = true;
                    self.rotate_right(ar, g);
                }
            } else {
                let u = ar.slots[g as usize].left;
                if u != NIL && ar.slots[u as usize].red {
                    ar.slots[p as usize].red = false;
                    ar.slots[u as usize].red = false;
                    ar.slots[g as usize].red = true;
                    z = g;
                } else {
                    if ar.slots[p as usize].left == z {
                        z = p;
                        self.rotate_right(ar, z);
                    }
                    let p = ar.slots[z as usize].parent;
                    let g = ar.slots[p as usize].parent;
                    ar.slots[p as usize].red = false;
                    ar.slots[g as usize].red = true;
                    self.rotate_left(ar, g);
                }
            }
        }
        let r = self.root;
        ar.slots[r as usize].red = false;
    }

    /// CLRS delete-fixup adapted to arena form: `x` may be NIL, so its
    /// parent is tracked explicitly in `xp`.
    fn delete_fixup<V, A: Augment<V>>(
        &mut self,
        ar: &mut Arena<Node<V, A>>,
        mut x: u32,
        mut xp: u32,
    ) {
        while x != self.root && (x == NIL || !ar.slots[x as usize].red) {
            if xp == NIL {
                break; // tree became empty
            }
            if ar.slots[xp as usize].left == x {
                let mut w = ar.slots[xp as usize].right;
                if w != NIL && ar.slots[w as usize].red {
                    ar.slots[w as usize].red = false;
                    ar.slots[xp as usize].red = true;
                    self.rotate_left(ar, xp);
                    w = ar.slots[xp as usize].right;
                }
                if w == NIL {
                    x = xp;
                    xp = ar.slots[x as usize].parent;
                    continue;
                }
                let wl = ar.slots[w as usize].left;
                let wr = ar.slots[w as usize].right;
                let wl_red = wl != NIL && ar.slots[wl as usize].red;
                let wr_red = wr != NIL && ar.slots[wr as usize].red;
                if !wl_red && !wr_red {
                    ar.slots[w as usize].red = true;
                    x = xp;
                    xp = ar.slots[x as usize].parent;
                } else {
                    if !wr_red {
                        if wl != NIL {
                            ar.slots[wl as usize].red = false;
                        }
                        ar.slots[w as usize].red = true;
                        self.rotate_right(ar, w);
                        w = ar.slots[xp as usize].right;
                    }
                    ar.slots[w as usize].red = ar.slots[xp as usize].red;
                    ar.slots[xp as usize].red = false;
                    let wr = ar.slots[w as usize].right;
                    if wr != NIL {
                        ar.slots[wr as usize].red = false;
                    }
                    self.rotate_left(ar, xp);
                    x = self.root;
                    xp = NIL;
                }
            } else {
                let mut w = ar.slots[xp as usize].left;
                if w != NIL && ar.slots[w as usize].red {
                    ar.slots[w as usize].red = false;
                    ar.slots[xp as usize].red = true;
                    self.rotate_right(ar, xp);
                    w = ar.slots[xp as usize].left;
                }
                if w == NIL {
                    x = xp;
                    xp = ar.slots[x as usize].parent;
                    continue;
                }
                let wl = ar.slots[w as usize].left;
                let wr = ar.slots[w as usize].right;
                let wl_red = wl != NIL && ar.slots[wl as usize].red;
                let wr_red = wr != NIL && ar.slots[wr as usize].red;
                if !wl_red && !wr_red {
                    ar.slots[w as usize].red = true;
                    x = xp;
                    xp = ar.slots[x as usize].parent;
                } else {
                    if !wl_red {
                        if wr != NIL {
                            ar.slots[wr as usize].red = false;
                        }
                        ar.slots[w as usize].red = true;
                        self.rotate_left(ar, w);
                        w = ar.slots[xp as usize].left;
                    }
                    ar.slots[w as usize].red = ar.slots[xp as usize].red;
                    ar.slots[xp as usize].red = false;
                    let wl = ar.slots[w as usize].left;
                    if wl != NIL {
                        ar.slots[wl as usize].red = false;
                    }
                    self.rotate_right(ar, xp);
                    x = self.root;
                    xp = NIL;
                }
            }
        }
        if x != NIL {
            ar.slots[x as usize].red = false;
        }
    }

    /// Validate every red-black + BST + augmentation invariant. Test and
    /// property-test helper; panics with a description on violation.
    pub(crate) fn check_invariants<V, A>(&self, ar: &Arena<Node<V, A>>)
    where
        A: Augment<V> + PartialEq + std::fmt::Debug,
    {
        if self.root == NIL {
            assert_eq!(self.len, 0, "len ≠ 0 for empty tree");
            return;
        }
        assert!(!ar.slots[self.root as usize].red, "red root");
        assert_eq!(ar.slots[self.root as usize].parent, NIL, "root has parent");
        let (count, _) = self.check_node(ar, self.root);
        assert_eq!(count, self.len, "len mismatch");
        // Keys strictly increasing in order.
        let mut prev: Option<Score> = None;
        for id in self.iter_in(ar) {
            if let Some(p) = prev {
                assert!(p < self.key(ar, id), "in-order keys not strictly increasing");
            }
            prev = Some(self.key(ar, id));
        }
    }

    /// Returns (node count, black height) of subtree `i`, checking
    /// red-black, parent-pointer and augmentation invariants.
    fn check_node<V, A>(&self, ar: &Arena<Node<V, A>>, i: u32) -> (usize, usize)
    where
        A: Augment<V> + PartialEq + std::fmt::Debug,
    {
        let n = &ar.slots[i as usize];
        for c in [n.left, n.right] {
            if c != NIL {
                assert_eq!(ar.slots[c as usize].parent, i, "broken parent pointer");
                if n.red {
                    assert!(!ar.slots[c as usize].red, "red node with red child");
                }
            }
        }
        let (lc, lb) = if n.left != NIL { self.check_node(ar, n.left) } else { (0, 1) };
        let (rc, rb) = if n.right != NIL { self.check_node(ar, n.right) } else { (0, 1) };
        assert_eq!(lb, rb, "black height mismatch");
        let la = if n.left == NIL { None } else { Some(&ar.slots[n.left as usize].aug) };
        let ra = if n.right == NIL { None } else { Some(&ar.slots[n.right as usize].aug) };
        let expect = A::recompute(&n.val, la, ra);
        assert_eq!(n.aug, expect, "stale augmentation at node {i}");
        (lc + rc + 1, lb + usize::from(!n.red))
    }
}

/// Augmented red-black tree bundling its own node arena — the
/// self-contained form for standalone estimators, tests and benches.
/// Delegates everything to an [`RbTreeCore`] over a private [`Arena`];
/// the shard layer uses the core directly against shared arenas.
#[derive(Clone, Debug)]
pub struct RbTree<V, A> {
    ar: Arena<Node<V, A>>,
    core: RbTreeCore,
}

impl<V, A: Augment<V>> Default for RbTree<V, A> {
    fn default() -> Self {
        Self::new()
    }
}

impl<V, A: Augment<V>> RbTree<V, A> {
    /// Empty tree.
    pub fn new() -> Self {
        RbTree { ar: Arena::new(), core: RbTreeCore::new() }
    }

    /// Empty tree with room for `cap` nodes before reallocating.
    pub fn with_capacity(cap: usize) -> Self {
        RbTree { ar: Arena::with_capacity(cap), core: RbTreeCore::new() }
    }

    /// Number of live nodes.
    #[inline]
    pub fn len(&self) -> usize {
        self.core.len()
    }

    /// True when the tree holds no nodes.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.core.is_empty()
    }

    /// Root node, if any.
    #[inline]
    pub fn root(&self) -> Option<NodeId> {
        self.core.root()
    }

    /// Key (score) of a node.
    #[inline]
    pub fn key(&self, id: NodeId) -> Score {
        self.core.key(&self.ar, id)
    }

    /// Value of a node.
    #[inline]
    pub fn val(&self, id: NodeId) -> &V {
        self.core.val(&self.ar, id)
    }

    /// Augmentation of a node (the subtree summary).
    #[inline]
    pub fn aug(&self, id: NodeId) -> &A {
        self.core.aug(&self.ar, id)
    }

    /// Left child.
    #[inline]
    pub fn left(&self, id: NodeId) -> Option<NodeId> {
        self.core.left(&self.ar, id)
    }

    /// Right child.
    #[inline]
    pub fn right(&self, id: NodeId) -> Option<NodeId> {
        self.core.right(&self.ar, id)
    }

    /// Parent node.
    #[inline]
    pub fn parent(&self, id: NodeId) -> Option<NodeId> {
        self.core.parent(&self.ar, id)
    }

    /// Mutate a node's value, then restore the augmentation along the
    /// path to the root.
    pub fn with_val_mut<R>(&mut self, id: NodeId, f: impl FnOnce(&mut V) -> R) -> R {
        self.core.with_val_mut(&mut self.ar, id, f)
    }

    /// Recompute augmentations from `id` up to the root.
    pub fn update_upward(&mut self, id: NodeId) {
        self.core.update_upward(&mut self.ar, id);
    }

    /// Find the node with exactly this key.
    pub fn find(&self, key: Score) -> Option<NodeId> {
        self.core.find(&self.ar, key)
    }

    /// Largest node with key `≤ key` (the shape of `MaxPos`, paper §3.2).
    pub fn floor(&self, key: Score) -> Option<NodeId> {
        self.core.floor(&self.ar, key)
    }

    /// Smallest node with key `≥ key`.
    pub fn ceil(&self, key: Score) -> Option<NodeId> {
        self.core.ceil(&self.ar, key)
    }

    /// Node with the smallest key.
    pub fn first(&self) -> Option<NodeId> {
        self.core.first(&self.ar)
    }

    /// Node with the largest key.
    pub fn last(&self) -> Option<NodeId> {
        self.core.last(&self.ar)
    }

    /// In-order successor.
    pub fn successor(&self, id: NodeId) -> Option<NodeId> {
        self.core.successor(&self.ar, id)
    }

    /// In-order predecessor.
    pub fn predecessor(&self, id: NodeId) -> Option<NodeId> {
        self.core.predecessor(&self.ar, id)
    }

    /// In-order iteration over node ids (ascending key).
    pub fn iter(&self) -> InOrder<'_, V, A> {
        self.core.iter_in(&self.ar)
    }

    /// Insert `key`, creating the node with `make()` if absent. Returns
    /// the node and whether it was newly created.
    pub fn insert(&mut self, key: Score, make: impl FnOnce() -> V) -> (NodeId, bool) {
        self.core.insert(&mut self.ar, key, make)
    }

    /// Remove a node. The handle (and any copies) become invalid; the
    /// slot may be recycled by a later insert. Removing the last node
    /// resets the arena outright — a drained tree releases its peak
    /// capacity instead of retaining it forever (the churn-shrink hook).
    pub fn remove(&mut self, id: NodeId) {
        self.core.remove(&mut self.ar, id);
        if self.core.is_empty() {
            self.ar.reset();
        }
    }

    /// True if `id` currently addresses a live node (test/debug helper;
    /// linear in the free list).
    pub fn is_live(&self, id: NodeId) -> bool {
        self.core.is_live(&self.ar, id)
    }

    /// Release retained slab capacity (freed tail slots + vector slack)
    /// without disturbing live nodes. See [`Arena::shrink_to_fit`].
    pub fn shrink_to_fit(&mut self) {
        self.ar.shrink_to_fit();
    }

    /// Slots the backing arena currently retains (live + freed) — the
    /// measure the capacity-regression tests bound after churn.
    pub fn capacity(&self) -> usize {
        self.ar.slot_count()
    }

    /// Validate every red-black + BST + augmentation invariant. Panics
    /// with a description on violation.
    pub fn check_invariants(&self)
    where
        A: PartialEq + std::fmt::Debug,
    {
        self.core.check_invariants(&self.ar);
    }
}

// The arena is plain owned data (a `Vec` of nodes addressed by index —
// no `Rc`, no interior mutability), so a tree is `Send` whenever its
// value and augmentation types are. The fleet's pool executor relies on
// this to move whole per-stream estimators across workers; keep it
// provable at compile time.
const _: () = {
    const fn assert_send<T: Send>() {}
    assert_send::<RbTree<u64, ()>>();
};

#[inline]
fn wrap(i: u32) -> Option<NodeId> {
    if i == NIL {
        None
    } else {
        Some(NodeId(i))
    }
}

/// Ascending in-order iterator over node ids.
pub struct InOrder<'a, V, A> {
    ar: &'a Arena<Node<V, A>>,
    next: Option<NodeId>,
}

impl<V, A> Iterator for InOrder<'_, V, A> {
    type Item = NodeId;

    fn next(&mut self) -> Option<NodeId> {
        let cur = self.next?;
        self.next = wrap(succ(self.ar, cur.0));
        Some(cur)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::Pcg;

    /// Subtree size augmentation for tests (counts nodes).
    #[derive(Clone, Copy, Debug, PartialEq)]
    struct Size(usize);

    impl Augment<u64> for Size {
        fn recompute(_v: &u64, l: Option<&Self>, r: Option<&Self>) -> Self {
            Size(1 + l.map_or(0, |s| s.0) + r.map_or(0, |s| s.0))
        }
    }

    /// Sum-of-values augmentation (models accpos).
    #[derive(Clone, Copy, Debug, PartialEq)]
    struct Sum(u64);

    impl Augment<u64> for Sum {
        fn recompute(v: &u64, l: Option<&Self>, r: Option<&Self>) -> Self {
            Sum(v + l.map_or(0, |s| s.0) + r.map_or(0, |s| s.0))
        }
    }

    fn tree_from(keys: &[f64]) -> RbTree<u64, Size> {
        let mut t = RbTree::new();
        for &k in keys {
            t.insert(Score(k), || 0);
        }
        t
    }

    #[test]
    fn empty_tree() {
        let t: RbTree<u64, Size> = RbTree::new();
        assert!(t.is_empty());
        assert_eq!(t.first(), None);
        assert_eq!(t.last(), None);
        assert_eq!(t.find(Score(1.0)), None);
        assert_eq!(t.floor(Score(1.0)), None);
        assert_eq!(t.ceil(Score(1.0)), None);
        t.check_invariants();
    }

    #[test]
    fn insert_ascending_descending() {
        for order in [true, false] {
            let mut keys: Vec<f64> = (0..200).map(f64::from).collect();
            if !order {
                keys.reverse();
            }
            let t = tree_from(&keys);
            assert_eq!(t.len(), 200);
            t.check_invariants();
            let got: Vec<f64> = t.iter().map(|id| t.key(id).0).collect();
            let mut want = keys.clone();
            want.sort_by(f64::total_cmp);
            assert_eq!(got, want);
        }
    }

    #[test]
    fn insert_duplicate_returns_existing() {
        let mut t: RbTree<u64, Size> = RbTree::new();
        let (a, fresh_a) = t.insert(Score(5.0), || 7);
        let (b, fresh_b) = t.insert(Score(5.0), || panic!("must not be called"));
        assert!(fresh_a && !fresh_b);
        assert_eq!(a, b);
        assert_eq!(*t.val(a), 7);
        assert_eq!(t.len(), 1);
    }

    #[test]
    fn floor_ceil_find() {
        let t = tree_from(&[1.0, 3.0, 5.0, 7.0]);
        let key = |id: Option<NodeId>| id.map(|i| t.key(i).0);
        assert_eq!(key(t.floor(Score(0.0))), None);
        assert_eq!(key(t.floor(Score(1.0))), Some(1.0));
        assert_eq!(key(t.floor(Score(4.0))), Some(3.0));
        assert_eq!(key(t.floor(Score(9.0))), Some(7.0));
        assert_eq!(key(t.ceil(Score(0.0))), Some(1.0));
        assert_eq!(key(t.ceil(Score(5.5))), Some(7.0));
        assert_eq!(key(t.ceil(Score(8.0))), None);
        assert_eq!(key(t.find(Score(3.0))), Some(3.0));
        assert_eq!(t.find(Score(4.0)), None);
    }

    #[test]
    fn successor_predecessor_chain() {
        let t = tree_from(&[2.0, 4.0, 6.0, 8.0, 10.0]);
        let mut cur = t.first();
        let mut seen = Vec::new();
        while let Some(id) = cur {
            seen.push(t.key(id).0);
            cur = t.successor(id);
        }
        assert_eq!(seen, vec![2.0, 4.0, 6.0, 8.0, 10.0]);
        let mut cur = t.last();
        seen.clear();
        while let Some(id) = cur {
            seen.push(t.key(id).0);
            cur = t.predecessor(id);
        }
        assert_eq!(seen, vec![10.0, 8.0, 6.0, 4.0, 2.0]);
    }

    #[test]
    fn remove_all_orders() {
        // Remove in insertion, reverse, and middle-out orders.
        let keys: Vec<f64> = (0..64).map(f64::from).collect();
        for variant in 0..3 {
            let mut t = tree_from(&keys);
            let mut order: Vec<f64> = keys.clone();
            match variant {
                0 => {}
                1 => order.reverse(),
                _ => order.sort_by(|a, b| {
                    (a - 32.0).abs().partial_cmp(&(b - 32.0).abs()).unwrap()
                }),
            }
            for (i, k) in order.iter().enumerate() {
                let id = t.find(Score(*k)).expect("present");
                t.remove(id);
                t.check_invariants();
                assert_eq!(t.len(), keys.len() - i - 1);
            }
            assert!(t.is_empty());
        }
    }

    #[test]
    fn value_mutation_restores_augmentation() {
        let mut t: RbTree<u64, Sum> = RbTree::new();
        let mut ids = Vec::new();
        for k in 0..100 {
            let (id, _) = t.insert(Score(f64::from(k)), || 1);
            ids.push(id);
        }
        t.with_val_mut(ids[42], |v| *v = 100);
        let root = t.root().unwrap();
        assert_eq!(t.aug(root).0, 100 + 99);
        t.check_invariants();
    }

    #[test]
    fn slot_recycling() {
        let mut t = tree_from(&[1.0, 2.0, 3.0]);
        let id = t.find(Score(2.0)).unwrap();
        t.remove(id);
        let (nid, fresh) = t.insert(Score(4.0), || 0);
        assert!(fresh);
        // Slot of the removed node is reused.
        assert_eq!(nid.0, id.0);
        t.check_invariants();
    }

    #[test]
    fn drain_to_empty_releases_capacity() {
        let mut t = tree_from(&(0..512).map(f64::from).collect::<Vec<_>>());
        assert!(t.capacity() >= 512);
        let keys: Vec<f64> = t.iter().map(|id| t.key(id).0).collect();
        for k in keys {
            let id = t.find(Score(k)).unwrap();
            t.remove(id);
        }
        // The drained tree must not retain its peak slab.
        assert_eq!(t.capacity(), 0);
        // …and must keep working afterwards.
        t.insert(Score(1.0), || 0);
        t.check_invariants();
        assert_eq!(t.len(), 1);
    }

    #[test]
    fn shrink_to_fit_trims_churn_slack() {
        let mut t = tree_from(&(0..256).map(f64::from).collect::<Vec<_>>());
        // Evict the upper half (tail slots in insertion order).
        for k in 128..256 {
            let id = t.find(Score(f64::from(k))).unwrap();
            t.remove(id);
        }
        let before = t.capacity();
        t.shrink_to_fit();
        assert!(t.capacity() < before, "shrink must drop freed tail slots");
        t.check_invariants();
        assert_eq!(t.len(), 128);
    }

    /// Randomized stress: mirror a `BTreeMap`, checking invariants and
    /// queries after every operation.
    #[test]
    fn stress_against_btreemap() {
        use std::collections::BTreeMap;
        let mut rng = Pcg::seed(0xA0C_2019);
        let mut t: RbTree<u64, Sum> = RbTree::new();
        let mut model: BTreeMap<i64, u64> = BTreeMap::new();
        for step in 0..4000 {
            let key = i64::from(rng.below(64) as u32) - 32;
            let ks = Score(key as f64);
            match rng.below(4) {
                0 | 1 => {
                    let v = rng.below(10);
                    let (id, fresh) = t.insert(ks, || v);
                    if !fresh {
                        t.with_val_mut(id, |old| *old = v);
                    }
                    model.insert(key, v);
                }
                2 => {
                    if let Some(id) = t.find(ks) {
                        t.remove(id);
                        model.remove(&key);
                    }
                }
                _ => {
                    // floor query must agree with the model
                    let got = t.floor(ks).map(|id| t.key(id).0 as i64);
                    let want = model.range(..=key).next_back().map(|(k, _)| *k);
                    assert_eq!(got, want, "floor({key}) disagrees at step {step}");
                }
            }
            if step % 64 == 0 {
                t.check_invariants();
                assert_eq!(t.len(), model.len());
                let total: u64 = model.values().sum();
                let got = t.root().map_or(0, |r| t.aug(r).0);
                assert_eq!(got, total, "sum augmentation diverged at step {step}");
            }
        }
        // Drain fully.
        let keys: Vec<i64> = model.keys().copied().collect();
        for k in keys {
            let id = t.find(Score(k as f64)).unwrap();
            t.remove(id);
        }
        assert!(t.is_empty());
        t.check_invariants();
    }
}
