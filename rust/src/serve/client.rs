//! Minimal clients for both serving protocols — shared by the
//! integration tests, the load-generating bench, and the example.
//! They are deliberately thin: connect, frame, and hand bytes back;
//! decoding belongs to `super::json` / `super::wire`.
//!
//! Both clients capture the server's seq echo (`X-Fleet-Seq` header /
//! the 8-byte payload prefix) as [`HttpClient::last_seq`] and
//! [`BinClient::last_seq`] — the publication epoch a response is
//! bit-identical to.

use std::io::{self, BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpStream};

use super::wire;

/// A keep-alive HTTP/1.1 client issuing `GET`s over one connection.
pub struct HttpClient {
    stream: TcpStream,
    reader: BufReader<TcpStream>,
    last_seq: Option<u64>,
}

impl HttpClient {
    /// Connect to a server.
    pub fn connect(addr: SocketAddr) -> io::Result<HttpClient> {
        let stream = TcpStream::connect(addr)?;
        let reader = BufReader::new(stream.try_clone()?);
        Ok(HttpClient { stream, reader, last_seq: None })
    }

    /// Issue `GET target` and return `(status, body)`.
    pub fn get(&mut self, target: &str) -> io::Result<(u16, String)> {
        let head = format!("GET {target} HTTP/1.1\r\nHost: fleet\r\n\r\n");
        self.stream.write_all(head.as_bytes())?;
        self.read_response()
    }

    /// The `X-Fleet-Seq` echo of the last response — the publication
    /// epoch its body answers at. `None` before the first response.
    pub fn last_seq(&self) -> Option<u64> {
        self.last_seq
    }

    fn read_response(&mut self) -> io::Result<(u16, String)> {
        let mut status_line = String::new();
        if self.reader.read_line(&mut status_line)? == 0 {
            return Err(io::Error::new(io::ErrorKind::UnexpectedEof, "server closed"));
        }
        let status: u16 = status_line
            .split_whitespace()
            .nth(1)
            .and_then(|s| s.parse().ok())
            .ok_or_else(|| {
                io::Error::new(io::ErrorKind::InvalidData, format!("bad status line {status_line:?}"))
            })?;
        let mut content_length: Option<usize> = None;
        loop {
            let mut header = String::new();
            if self.reader.read_line(&mut header)? == 0 {
                return Err(io::Error::new(io::ErrorKind::UnexpectedEof, "truncated head"));
            }
            let header = header.trim_end();
            if header.is_empty() {
                break;
            }
            if let Some((name, value)) = header.split_once(':') {
                if name.eq_ignore_ascii_case("content-length") {
                    content_length = value.trim().parse().ok();
                } else if name.eq_ignore_ascii_case("x-fleet-seq") {
                    self.last_seq = value.trim().parse().ok();
                }
            }
        }
        let len = content_length.ok_or_else(|| {
            io::Error::new(io::ErrorKind::InvalidData, "response without Content-Length")
        })?;
        let mut body = vec![0u8; len];
        self.reader.read_exact(&mut body)?;
        String::from_utf8(body)
            .map(|b| (status, b))
            .map_err(|_| io::Error::new(io::ErrorKind::InvalidData, "non-UTF8 body"))
    }
}

/// One-shot `GET` on a fresh connection; returns `(status, body)`.
pub fn http_get(addr: SocketAddr, target: &str) -> io::Result<(u16, String)> {
    HttpClient::connect(addr)?.get(target)
}

/// Open `/subscribe` over HTTP and return a line iterator positioned
/// at the baseline line (streaming ndjson body — read lines as the
/// server drains batches). A line may also be a lagged notice
/// (`super::json::parse_lagged_notice`) followed by a fresh baseline,
/// when the subscriber fell behind the publisher.
pub fn http_subscribe(addr: SocketAddr) -> io::Result<impl Iterator<Item = io::Result<String>>> {
    let mut stream = TcpStream::connect(addr)?;
    stream.write_all(b"GET /subscribe HTTP/1.1\r\nHost: fleet\r\n\r\n")?;
    let mut reader = BufReader::new(stream);
    let mut status_line = String::new();
    reader.read_line(&mut status_line)?;
    if !status_line.contains("200") {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            format!("subscribe refused: {status_line:?}"),
        ));
    }
    loop {
        let mut header = String::new();
        if reader.read_line(&mut header)? == 0 {
            return Err(io::Error::new(io::ErrorKind::UnexpectedEof, "truncated head"));
        }
        if header.trim_end().is_empty() {
            break;
        }
    }
    Ok(reader.lines())
}

/// One pushed subscription frame, decoded to its kind.
pub enum SubEvent {
    /// A sketch delta payload (apply with [`wire::apply_delta`]).
    Delta(Vec<u8>),
    /// The subscriber lagged; a [`SubEvent::Baseline`] at this seq
    /// follows immediately.
    Lagged(u64),
    /// A fresh full baseline payload (decode with
    /// [`wire::decode_sketch`]), replacing everything missed.
    Baseline(Vec<u8>),
}

/// A binary-protocol client over one framed connection.
pub struct BinClient {
    stream: TcpStream,
    last_seq: Option<u64>,
}

impl BinClient {
    /// Connect and send the protocol magic.
    pub fn connect(addr: SocketAddr) -> io::Result<BinClient> {
        let mut stream = TcpStream::connect(addr)?;
        stream.write_all(&wire::MAGIC)?;
        Ok(BinClient { stream, last_seq: None })
    }

    /// Issue one request frame and return `(status, payload)` with the
    /// server's 8-byte seq echo already stripped from the payload (it
    /// is captured as [`BinClient::last_seq`]).
    pub fn request(&mut self, opcode: u8, payload: &[u8]) -> io::Result<(u8, Vec<u8>)> {
        wire::write_frame(&mut self.stream, opcode, payload)?;
        let (status, full) = wire::read_frame(&mut self.stream)?;
        if full.len() < 8 {
            return Err(io::Error::new(
                io::ErrorKind::InvalidData,
                "response payload shorter than its seq echo",
            ));
        }
        self.last_seq = Some(u64::from_le_bytes(full[..8].try_into().expect("8 bytes")));
        Ok((status, full[8..].to_vec()))
    }

    /// The seq echo of the last response — the publication epoch its
    /// payload answers at. `None` before the first response.
    pub fn last_seq(&self) -> Option<u64> {
        self.last_seq
    }

    /// Subscribe; returns the baseline payload (decode with
    /// [`wire::decode_sketch`]), after which [`BinClient::next_delta`]
    /// or [`BinClient::next_event`] yields pushed frames.
    pub fn subscribe(&mut self) -> io::Result<Vec<u8>> {
        let (status, payload) = self.request(wire::OP_SUBSCRIBE, &[])?;
        if status != wire::STATUS_OK {
            return Err(io::Error::new(
                io::ErrorKind::InvalidData,
                String::from_utf8_lossy(&payload).into_owned(),
            ));
        }
        Ok(payload)
    }

    /// Block for the next pushed delta frame payload (apply with
    /// [`wire::apply_delta`]). Errors on a lag resync — use
    /// [`BinClient::next_event`] when the subscriber may fall behind.
    pub fn next_delta(&mut self) -> io::Result<Vec<u8>> {
        match self.next_event()? {
            SubEvent::Delta(payload) => Ok(payload),
            SubEvent::Lagged(seq) => Err(io::Error::new(
                io::ErrorKind::InvalidData,
                format!("expected a delta frame, got a lag resync to seq {seq}"),
            )),
            SubEvent::Baseline(_) => Err(io::Error::new(
                io::ErrorKind::InvalidData,
                "expected a delta frame, got a baseline",
            )),
        }
    }

    /// Block for the next pushed subscription frame of any kind.
    pub fn next_event(&mut self) -> io::Result<SubEvent> {
        let (op, payload) = wire::read_frame(&mut self.stream)?;
        match op {
            wire::OP_DELTA => Ok(SubEvent::Delta(payload)),
            wire::OP_BASELINE => Ok(SubEvent::Baseline(payload)),
            wire::OP_LAGGED => wire::decode_lagged(&payload)
                .map(SubEvent::Lagged)
                .map_err(|e| io::Error::new(io::ErrorKind::InvalidData, e)),
            other => Err(io::Error::new(
                io::ErrorKind::InvalidData,
                format!("unexpected push frame opcode {other}"),
            )),
        }
    }
}
