//! Fleet integration: 200 streams × ~5k events each with per-stream
//! drift, spot-checked against freshly built naive oracles over the
//! identical window contents, with alarm coverage assertions; plus the
//! executor determinism property (parallel ≡ serial, bit-identical)
//! and idle-stream eviction.
//!
//! The event soup comes from the bursty [`MultiStream`] generator;
//! streams 0..20 break abruptly halfway through their traffic. The
//! fleet maintains one ε/2-approximate window + drift monitor per
//! stream, with a handful of streams running on per-stream config
//! overrides (tighter ε, smaller window).

use std::collections::HashSet;

use streamauc::coordinator::NaiveAuc;
use streamauc::fleet::{AucFleet, EstimatorKind, FleetConfig, MonitorConfig, StreamConfig};
use streamauc::stream::{DriftSchedule, MultiStream, Pcg, StreamProfile};

const STREAMS: u64 = 200;
const DRIFTED: u64 = 20;
const EVENTS: usize = 1_000_000; // ≈ 5k events per stream
const BATCH: usize = 4_096;
const DEFAULT_EPS: f64 = 0.2;
const OVERRIDE_EPS: f64 = 0.05;
/// Streams 190..200 run with the tighter override config.
const OVERRIDE_FROM: u64 = 190;

fn build_fleet() -> AucFleet {
    // Parallel drain on purpose: the main integration scenario also
    // exercises the pooled work-stealing executor (with cross-batch
    // pipelining) against the naive oracle.
    let mut fleet = AucFleet::new(FleetConfig {
        shards: 32,
        workers: 4,
        pool: true,
        pipeline: true,
        stream_defaults: StreamConfig {
            window: 200,
            estimator: EstimatorKind::Approx { epsilon: DEFAULT_EPS },
            monitor: Some(MonitorConfig {
                lambda: 0.001,
                margin: 0.08,
                patience: 50,
                warmup: 250,
            }),
        },
        ..FleetConfig::default()
    });
    for id in OVERRIDE_FROM..STREAMS {
        fleet.configure_stream(id, StreamConfig::new(120, OVERRIDE_EPS));
    }
    fleet
}

fn build_generator() -> MultiStream {
    let per_stream = EVENTS as u64 / STREAMS; // ≈ 5000
    let profiles: Vec<StreamProfile> = (0..STREAMS)
        .map(|id| {
            let p = StreamProfile::healthy(id);
            if id < DRIFTED {
                p.with_drift(DriftSchedule::Abrupt { at: per_stream / 2, rate: 0.6 })
            } else {
                p
            }
        })
        .collect();
    MultiStream::with_profiles(profiles, 0x200_5000).with_mean_burst(8.0)
}

#[test]
fn fleet_200_streams_drift_and_differential_spot_checks() {
    let mut fleet = build_fleet();
    let mut gen = build_generator();

    let mut pushed = 0;
    while pushed < EVENTS {
        let n = BATCH.min(EVENTS - pushed);
        fleet.push_batch(&gen.next_batch(n));
        pushed += n;
    }
    assert_eq!(fleet.total_events(), EVENTS as u64);
    assert_eq!(fleet.stream_count(), STREAMS as usize, "every stream must be live");

    // ---- differential spot-checks: ≥20 random streams against a
    // freshly built naive oracle over the same window contents -------
    let mut rng = Pcg::seed(0x5707);
    let mut checked = HashSet::new();
    while checked.len() < 20 {
        checked.insert(rng.below(STREAMS));
    }
    // Always include override streams so both configs are exercised.
    checked.insert(OVERRIDE_FROM);
    checked.insert(STREAMS - 1);
    for &id in &checked {
        let window = fleet.entries(id).expect("live stream");
        let cfg = fleet.stream_config(id);
        assert!(!window.is_empty() && window.len() <= cfg.window, "stream {id} window size");
        let truth = NaiveAuc::of(&window);
        let est = fleet.auc(id).expect("live stream");
        assert!(
            (est - truth).abs() <= cfg.epsilon * truth / 2.0 + 1e-12,
            "stream {id} (ε = {}): est {est} vs naive {truth}",
            cfg.epsilon
        );
    }

    // ---- alarms fire on the drifted streams, and only there --------
    let alarmed: HashSet<u64> = fleet.alarms().iter().map(|a| a.stream).collect();
    for id in 0..DRIFTED {
        assert!(alarmed.contains(&id), "drifted stream {id} never alarmed");
    }
    for &id in &alarmed {
        assert!(id < DRIFTED, "healthy stream {id} raised a false alarm");
    }
    // Drifted streams are still degraded at end-of-stream, so the
    // snapshot must report them as currently alarmed.
    let snap = fleet.snapshot();
    let snap_alarmed: HashSet<u64> = snap.alarmed_streams.iter().copied().collect();
    for id in 0..DRIFTED {
        assert!(snap_alarmed.contains(&id), "stream {id} not alarmed in snapshot");
    }

    // ---- snapshot-level health separation --------------------------
    let (mut drifted_auc, mut healthy_auc) = (0.0, 0.0);
    for s in &snap.streams {
        if s.stream < DRIFTED {
            drifted_auc += s.auc;
        } else {
            healthy_auc += s.auc;
        }
    }
    drifted_auc /= DRIFTED as f64;
    healthy_auc /= (STREAMS - DRIFTED) as f64;
    assert!(healthy_auc > 0.85, "healthy fleet mean AUC {healthy_auc}");
    assert!(drifted_auc < 0.6, "drifted fleet mean AUC {drifted_auc} should collapse");
    assert!(
        snap.streams.iter().all(|s| s.events > 3_000),
        "bursty scheduling starved a stream"
    );

    // Alarm records carry consistent metadata.
    for a in fleet.alarms() {
        assert!(a.auc < a.baseline - 0.08 + 1e-9, "alarm without margin violation");
        assert!(a.stream_event > 200, "alarm before the window ever filled");
    }
}

/// Executor determinism: ingesting the same `MultiStream` trace with
/// `workers ∈ {2, 4, 8}` must yield **bit-identical** snapshots,
/// aggregate metrics and alarm logs to the serial path. Each property
/// case draws its own fleet shape, traffic mix and batch size.
#[test]
fn parallel_ingestion_is_bit_identical_to_serial() {
    streamauc::testing::check(0x9A11E1, 2, |rng| {
        let n_streams = 50 + rng.below(50);
        let drifted = n_streams / 10;
        let per_stream = 1_500u64;
        let events = (n_streams * per_stream) as usize;
        let chunk = 256 + rng.below(3_841) as usize; // 256..=4096
        let profiles: Vec<StreamProfile> = (0..n_streams)
            .map(|id| {
                let p = StreamProfile::healthy(id);
                if id < drifted {
                    p.with_drift(DriftSchedule::Abrupt { at: per_stream / 2, rate: 0.6 })
                } else {
                    p
                }
            })
            .collect();
        let trace = MultiStream::with_profiles(profiles, 0xD17E ^ n_streams)
            .with_mean_burst(6.0)
            .next_batch(events);

        let config = |workers: usize| FleetConfig {
            shards: 16,
            workers,
            pool: true,
            pipeline: false,
            stream_defaults: StreamConfig {
                window: 200,
                estimator: EstimatorKind::Approx { epsilon: 0.1 },
                monitor: Some(MonitorConfig {
                    lambda: 0.001,
                    margin: 0.08,
                    patience: 50,
                    warmup: 250,
                }),
            },
            ..FleetConfig::default()
        };
        let mut serial = AucFleet::new(config(1));
        for batch in trace.chunks(chunk) {
            serial.push_batch(batch);
        }
        // The drift injection makes alarms part of what must match.
        assert!(!serial.alarms().is_empty(), "scenario produced no alarms to compare");

        for workers in [2usize, 4, 8] {
            let mut parallel = AucFleet::new(config(workers));
            for batch in trace.chunks(chunk) {
                parallel.push_batch(batch);
            }
            assert_eq!(
                serial.snapshot(),
                parallel.snapshot(),
                "snapshot diverged at {workers} workers (chunk {chunk}, {n_streams} streams)"
            );
            assert_eq!(
                serial.aggregate(),
                parallel.aggregate(),
                "aggregate diverged at {workers} workers"
            );
            assert_eq!(
                serial.alarms(),
                parallel.alarms(),
                "alarm log diverged at {workers} workers"
            );
            assert_eq!(serial.total_events(), parallel.total_events());
        }
    });
}

/// Idle-stream eviction: dead streams are dropped fleet-wide, surviving
/// streams keep their exact window state through slab compaction, and
/// revived streams start fresh.
#[test]
fn evict_idle_drops_dead_streams_and_preserves_the_rest() {
    let mut fleet = AucFleet::new(FleetConfig {
        shards: 8,
        workers: 2,
        stream_defaults: StreamConfig::new(50, 0.1).without_monitor(),
        ..FleetConfig::default()
    });
    let mut rng = Pcg::seed(0xE71C);
    let event = |rng: &mut Pcg| {
        let pos = rng.chance(0.5);
        let s = if pos { rng.normal_with(0.35, 0.15) } else { rng.normal_with(0.65, 0.15) };
        (s, pos)
    };
    // Phase 1: streams 0..20 all take traffic (2 000 events).
    let mut batch = Vec::new();
    for _ in 0..100 {
        for id in 0..20u64 {
            let (s, l) = event(&mut rng);
            batch.push((id, s, l));
        }
    }
    fleet.push_batch(&batch);
    // Phase 2: only streams 10..20 stay active (3 000 events).
    batch.clear();
    for _ in 0..300 {
        for id in 10..20u64 {
            let (s, l) = event(&mut rng);
            batch.push((id, s, l));
        }
    }
    fleet.push_batch(&batch);
    assert_eq!(fleet.total_events(), 5_000);
    assert_eq!(fleet.stream_count(), 20);

    let survivors: Vec<Vec<(f64, bool)>> =
        (10..20u64).map(|id| fleet.entries(id).unwrap()).collect();
    // Streams 0..10 have been idle ≥ 3 000 ticks; survivors < 20.
    let evicted = fleet.evict_idle(3_000);
    assert_eq!(evicted, 10);
    assert_eq!(fleet.stream_count(), 10);
    for id in 0..10u64 {
        assert!(!fleet.contains(id), "stream {id} should have been evicted");
        assert_eq!(fleet.auc(id), None);
    }
    for (i, id) in (10..20u64).enumerate() {
        let after = fleet.entries(id).unwrap();
        assert_eq!(after, survivors[i], "stream {id} window disturbed by compaction");
        assert_eq!(after.len(), 50, "stream {id} window should have stayed full");
    }
    // The snapshot and aggregate reflect the smaller fleet.
    let snap = fleet.snapshot();
    assert_eq!(snap.streams.len(), 10);
    assert!(snap.streams.iter().all(|s| s.stream >= 10));
    assert_eq!(fleet.aggregate().streams, 10);
    // A revived stream starts from an empty window.
    fleet.push(3, 0.5, true);
    assert_eq!(fleet.stream_len(3), Some(1));
}

/// Time-based eviction: streams age against the caller-supplied batch
/// timestamps (not the fleet tick), survivors ride out the slab
/// compaction untouched, and per-stream overrides survive both the
/// compaction and their own eviction.
#[test]
fn timed_eviction_ages_by_timestamp_and_overrides_survive() {
    let mut fleet = AucFleet::new(FleetConfig {
        shards: 8,
        workers: 2,
        stream_defaults: StreamConfig::new(50, 0.1).without_monitor(),
        ..FleetConfig::default()
    });
    assert_eq!(fleet.clock(), 0);
    fleet.configure_stream(7, StreamConfig::new(5, 0.0).without_monitor());

    // t = 100: everyone takes traffic. t = 200..600: only 5..10.
    let all: Vec<(u64, f64, bool)> =
        (0..200u64).map(|i| (i % 10, 0.3 + 0.01 * (i % 40) as f64, i % 2 == 0)).collect();
    fleet.push_batch_at(&all, 100);
    for t in [200u64, 300, 400, 500, 600] {
        let warm: Vec<(u64, f64, bool)> =
            (0..100u64).map(|i| (5 + i % 5, 0.3 + 0.01 * (i % 40) as f64, i % 2 == 1)).collect();
        fleet.push_batch_at(&warm, t);
    }
    assert_eq!(fleet.clock(), 600);
    assert_eq!(fleet.stream_len(7), Some(5), "override window ignored");

    let survivors: Vec<Vec<(f64, bool)>> =
        (5..10u64).map(|id| fleet.entries(id).unwrap()).collect();
    // Streams 0..5 were last seen at t = 100 (age 500); 5..10 at 600.
    // A few events is plenty of *ticks*, so tick-idleness would not
    // fire here — age does.
    assert_eq!(fleet.evict_older_than(400), 5);
    assert_eq!(fleet.stream_count(), 5);
    for id in 0..5u64 {
        assert!(!fleet.contains(id), "stream {id} should be age-evicted");
    }
    for (i, id) in (5..10u64).enumerate() {
        assert_eq!(fleet.entries(id).unwrap(), survivors[i], "stream {id} disturbed");
    }
    assert_eq!(fleet.stream_len(7), Some(5), "override lost through compaction");

    // An empty timed batch advances the clock; everything ages out.
    fleet.push_batch_at(&[], 2_000);
    assert_eq!(fleet.clock(), 2_000);
    assert_eq!(fleet.evict_older_than(1_000), 5);
    assert_eq!(fleet.stream_count(), 0);
    // The override survives its stream's eviction: re-ingest recreates
    // stream 7 under the 5-pair window.
    for i in 0..20 {
        fleet.push_at(7, 0.05 * f64::from(i), i % 2 == 0, 2_100);
    }
    assert_eq!(fleet.stream_len(7), Some(5), "override lost across age eviction");
    assert_eq!(fleet.stream_config(7).window, 5);
    // The clock never runs backwards: a stale timestamp is clamped.
    fleet.push_batch_at(&[(1, 0.5, true)], 50);
    assert_eq!(fleet.clock(), 2_100);
}

/// Adaptive worker scaling: trickle batches drain inline (one
/// participant, no pool dispatch), large batches engage the pool, and
/// the mixture is bit-identical to a serial twin.
#[test]
fn adaptive_scaling_is_invisible_and_skips_the_pool_for_trickles() {
    use streamauc::fleet::{adaptive_workers, ADAPTIVE_EVENTS_PER_WORKER};

    // The crossover arithmetic the satellite exists for.
    assert_eq!(adaptive_workers(2, 8), 1, "a 2-event batch must stay serial");
    assert_eq!(adaptive_workers(ADAPTIVE_EVENTS_PER_WORKER - 1, 8), 1);
    assert_eq!(adaptive_workers(2 * ADAPTIVE_EVENTS_PER_WORKER, 8), 2);
    assert_eq!(adaptive_workers(100 * ADAPTIVE_EVENTS_PER_WORKER, 8), 8, "capped at workers");
    assert_eq!(adaptive_workers(0, 0), 1);

    let config = |workers: usize, adaptive: bool| FleetConfig {
        shards: 16,
        workers,
        adaptive,
        stream_defaults: StreamConfig::new(80, 0.1),
        ..FleetConfig::default()
    };
    let mut serial = AucFleet::new(config(1, false));
    let mut adaptive = AucFleet::new(config(4, true));
    assert!(adaptive.pooled());

    let mut rng = Pcg::seed(0xADA7);
    let soup: Vec<(u64, f64, bool)> = (0..30_000)
        .map(|_| {
            let id = rng.below(40);
            let pos = rng.chance(0.5);
            let s = if pos { rng.normal_with(0.35, 0.15) } else { rng.normal_with(0.65, 0.15) };
            (id, s, pos)
        })
        .collect();
    // Mixed batch sizes straddling the crossover either way.
    let mut offset = 0;
    let mut step = 0u64;
    while offset < soup.len() {
        let size = match step % 4 {
            0 => 2,    // trickle: must drain inline
            1 => 4096, // engages the pool
            2 => 600,
            _ => 64,
        };
        let end = (offset + size).min(soup.len());
        serial.push_batch(&soup[offset..end]);
        adaptive.push_batch(&soup[offset..end]);
        if size <= 64 {
            assert_eq!(
                adaptive.last_batch_workers(),
                1,
                "a {size}-event batch must not engage the pool"
            );
        }
        offset = end;
        step += 1;
    }
    assert_eq!(serial.snapshot(), adaptive.snapshot());
    assert_eq!(serial.aggregate(), adaptive.aggregate());
    assert_eq!(serial.alarms(), adaptive.alarms());
    assert_eq!(serial.top_k_worst(5), adaptive.top_k_worst(5));
    assert_eq!(serial.auc_histogram(8), adaptive.auc_histogram(8));
}

/// Shard-sketch lifecycle: the running sufficient stats behind
/// `aggregate()` / `count_below()` / `auc_histogram()` must survive
/// every state transition a fleet performs — ingestion, tick- and
/// age-based eviction with slab compaction, live-stream reconfigure
/// (reset), evict-all and re-ingest — staying bit-identical to a
/// from-scratch rebuild and to the retained rescan reference.
#[test]
fn shard_sketches_survive_eviction_reset_and_reingest() {
    let mut fleet = AucFleet::new(FleetConfig {
        shards: 8,
        workers: 2,
        stream_defaults: StreamConfig::new(50, 0.1),
        ..FleetConfig::default()
    });
    let mut rng = Pcg::seed(0x5CE7);
    let soup: Vec<(u64, f64, bool)> = (0..20_000)
        .map(|_| {
            let id = rng.below(60);
            let pos = rng.chance(0.5);
            let s = if pos { rng.normal_with(0.35, 0.15) } else { rng.normal_with(0.65, 0.15) };
            (id, s, pos)
        })
        .collect();

    let check = |fleet: &mut AucFleet, phase: &str| {
        fleet.verify_sketches();
        assert_eq!(
            fleet.aggregate(),
            fleet.aggregate_rescan(),
            "sketch aggregate drifted from rescan after {phase}"
        );
        let snap = fleet.snapshot();
        for t in [0.0, 0.25, 0.5, 0.75, 1.0, 1.5] {
            let reference = snap.streams.iter().filter(|s| s.len > 0 && s.auc < t).count();
            assert_eq!(
                fleet.count_below(t),
                reference,
                "count_below({t}) drifted after {phase}"
            );
        }
        for bins in [1usize, 7, 16, 64] {
            let h = fleet.auc_histogram(bins);
            let mut counts = vec![0usize; bins];
            for s in snap.streams.iter().filter(|s| s.len > 0) {
                counts[((s.auc * bins as f64) as usize).min(bins - 1)] += 1;
            }
            assert_eq!(h.counts, counts, "histogram({bins}) drifted after {phase}");
        }
    };

    for chunk in soup.chunks(1_500) {
        fleet.push_batch_at(chunk, fleet.clock() + 10);
    }
    check(&mut fleet, "ingest");

    // Idle a tail of streams, evict by tick, compact the slabs.
    let warm: Vec<(u64, f64, bool)> = (0..4_000u64).map(|i| (i % 12, 0.4, i % 2 == 0)).collect();
    fleet.push_batch(&warm);
    assert!(fleet.evict_idle(3_000) > 0, "scenario must evict something");
    check(&mut fleet, "evict_idle");

    // Reconfigure a live stream: reset must retract its contribution.
    fleet.configure_stream(3, StreamConfig::new(10, 0.0).without_monitor());
    check(&mut fleet, "configure_stream reset");
    fleet.push(3, 0.2, true);
    check(&mut fleet, "post-reset re-ingest");

    // Age-based eviction path: advance the clock while touching only a
    // few streams, so the untouched live ones go stale and age out.
    let bump: Vec<(u64, f64, bool)> = (0..4u64).map(|id| (id, 0.5, true)).collect();
    fleet.push_batch_at(&bump, fleet.clock() + 500);
    assert!(fleet.evict_older_than(400) > 0, "scenario must age-evict something");
    check(&mut fleet, "evict_older_than");

    // Evict everything, then start fresh on the same fleet.
    fleet.evict_idle(0);
    check(&mut fleet, "evict-all");
    assert_eq!(fleet.aggregate().live_streams, 0);
    fleet.push_batch(&soup[..2_000]);
    check(&mut fleet, "re-ingest after evict-all");
}
