//! Micro-benchmarks and ablations over the §3/§4 data structures.
//!
//! `cargo bench --bench ops`
//!
//! Reported per operation (median of timed batches after warmup):
//!
//! * support-tree updates (`add/remove × pos/neg`) at several window
//!   sizes — the `O(log k)` claims;
//! * `HeadStats` and `MaxPos` queries, including the **TP-vs-accpos
//!   ablation** (what the dedicated positive tree buys over descending
//!   the main tree with subtree counters);
//! * full estimator updates (`ApproxAuc` push+query vs `ExactAuc`
//!   push+query) — the headline per-event costs;
//! * `ApproxAUC` evaluation alone at several ε (the `O(|C|)` read);
//! * **Compress ablation**: update cost with the paper's incremental
//!   `AddNext`+`Compress` versus rebuilding C from scratch each event.

use std::time::{Duration, Instant};

use streamauc::coordinator::support::SupportTree;
use streamauc::coordinator::{ApproxAuc, AucEstimator, ExactAuc};
use streamauc::collections::Score;
use streamauc::stream::Pcg;

/// Median-of-batches timer: runs `op` in `batches` batches of
/// `per_batch` calls, reports the median per-call latency.
fn bench(name: &str, batches: usize, per_batch: usize, mut op: impl FnMut()) {
    // Warmup.
    for _ in 0..per_batch / 2 {
        op();
    }
    let mut samples: Vec<Duration> = (0..batches)
        .map(|_| {
            let t = Instant::now();
            for _ in 0..per_batch {
                op();
            }
            t.elapsed() / per_batch as u32
        })
        .collect();
    samples.sort();
    let median = samples[samples.len() / 2];
    println!("{name:<58} {:>10.0} ns/op", median.as_nanos() as f64);
}

fn filled_support(k: usize, rng: &mut Pcg) -> SupportTree {
    let mut t = SupportTree::new();
    for _ in 0..k {
        let s = Score(rng.uniform());
        if rng.chance(0.5) {
            t.add_pos(s);
        } else {
            t.add_neg(s);
        }
    }
    t
}

fn main() {
    let mut rng = Pcg::seed(0x0B5);
    println!("== ops: §3/§4 micro-benchmarks (median ns/op) ==\n");

    // ---- support tree updates at several k ---------------------------
    for &k in &[1_000usize, 10_000, 100_000] {
        let mut t = filled_support(k, &mut rng);
        let mut r = rng.fork();
        bench(
            &format!("support: add_pos+remove_pos churn (k={k})"),
            30,
            2_000,
            || {
                let s = Score(r.uniform());
                t.add_pos(s);
                t.remove_pos(s);
            },
        );
        let mut r = rng.fork();
        bench(
            &format!("support: add_neg+remove_neg churn (k={k})"),
            30,
            2_000,
            || {
                let s = Score(r.uniform());
                t.add_neg(s);
                t.remove_neg(s);
            },
        );
    }
    println!();

    // ---- queries: HeadStats, MaxPos (TP vs accpos descent) -----------
    for &k in &[1_000usize, 100_000] {
        let t = filled_support(k, &mut rng);
        let mut r = rng.fork();
        let mut sink = 0u64;
        bench(&format!("query: HeadStats (k={k})"), 30, 5_000, || {
            let (hp, hn) = t.head_stats(Score(r.uniform()));
            sink = sink.wrapping_add(hp + hn);
        });
        let mut r = rng.fork();
        bench(&format!("query: MaxPos via TP (k={k})"), 30, 5_000, || {
            let (v, _) = t.max_pos(Score(r.uniform()));
            sink = sink.wrapping_add(u64::from(v.0));
        });
        let mut r = rng.fork();
        bench(
            &format!("query: MaxPos via accpos descent [ablation] (k={k})"),
            30,
            5_000,
            || {
                let v = t.max_pos_via_t(Score(r.uniform()));
                sink = sink.wrapping_add(u64::from(v.0));
            },
        );
        std::hint::black_box(sink);
    }
    println!();

    // ---- full estimator updates (push + query per event) -------------
    for &k in &[1_000usize, 10_000] {
        for &eps in &[0.01, 0.1] {
            let mut est = ApproxAuc::new(eps);
            let mut fifo = std::collections::VecDeque::new();
            let mut r = rng.fork();
            let mut sink = 0.0;
            bench(
                &format!("estimator: approx push+query (k={k}, ε={eps})"),
                20,
                2_000,
                || {
                    let s = r.uniform();
                    let l = r.chance(0.5);
                    est.insert(s, l);
                    fifo.push_back((s, l));
                    if fifo.len() > k {
                        let (os, ol) = fifo.pop_front().unwrap();
                        est.remove(os, ol);
                    }
                    sink += est.auc();
                },
            );
            std::hint::black_box(sink);
        }
        let mut est = ExactAuc::new();
        let mut fifo = std::collections::VecDeque::new();
        let mut r = rng.fork();
        let mut sink = 0.0;
        bench(
            &format!("estimator: exact push+query [baseline] (k={k})"),
            10,
            500,
            || {
                let s = r.uniform();
                let l = r.chance(0.5);
                est.insert(s, l);
                fifo.push_back((s, l));
                if fifo.len() > k {
                    let (os, ol) = fifo.pop_front().unwrap();
                    est.remove(os, ol);
                }
                sink += est.auc();
            },
        );
        std::hint::black_box(sink);
    }
    println!();

    // ---- ApproxAUC evaluation alone (the O(|C|) read) -----------------
    for &eps in &[0.001, 0.01, 0.1, 1.0] {
        let mut est = ApproxAuc::new(eps);
        let mut r = rng.fork();
        for _ in 0..10_000 {
            est.insert(r.uniform(), r.chance(0.5));
        }
        let mut sink = 0.0;
        bench(
            &format!(
                "query: ApproxAUC eval only (k=10000, ε={eps}, |C|={})",
                est.compressed_len()
            ),
            30,
            5_000,
            || sink += est.auc(),
        );
        std::hint::black_box(sink);
    }
    println!();

    // ---- ablation: incremental C vs from-scratch rebuild --------------
    // The paper's design maintains C incrementally (AddNext + Compress).
    // The alternative — rebuild C from P at every event — costs O(|P|).
    {
        let k = 10_000;
        let mut est = ApproxAuc::new(0.1);
        let mut fifo = std::collections::VecDeque::new();
        let mut r = rng.fork();
        bench(
            "ablation: incremental C maintenance (paper) (k=10000, ε=0.1)",
            20,
            2_000,
            || {
                let s = r.uniform();
                let l = r.chance(0.5);
                est.insert(s, l);
                fifo.push_back((s, l));
                if fifo.len() > k {
                    let (os, ol) = fifo.pop_front().unwrap();
                    est.remove(os, ol);
                }
            },
        );
        // From-scratch comparator: the §7 construction run per event.
        use streamauc::coordinator::WeightedAuc;
        let mut w = WeightedAuc::new();
        let mut r = rng.fork();
        let mut fifo = std::collections::VecDeque::new();
        for _ in 0..k {
            let s = r.uniform();
            let l = r.chance(0.5);
            w.insert(s, l, 1.0);
            fifo.push_back((s, l));
        }
        let mut sink = 0.0;
        bench(
            "ablation: from-scratch (1+ε)-list per event (k=10000, ε=0.1)",
            10,
            200,
            || {
                let s = r.uniform();
                let l = r.chance(0.5);
                w.insert(s, l, 1.0);
                fifo.push_back((s, l));
                let (os, ol) = fifo.pop_front().unwrap();
                w.remove(os, ol, 1.0);
                sink += w.approx_auc(0.1);
            },
        );
        std::hint::black_box(sink);
    }
}
