//! Profiling workload used by the §Perf pass (EXPERIMENTS.md):
//! 2M-event FIFO churn at k = 10⁴, ε = 0.01, with an ApproxAUC query
//! per event. Run under `perf record -g` on a release build.
//!
//! ```sh
//! cargo build --release --example prof
//! perf record -g ./target/release/examples/prof && perf report
//! ```

use streamauc::coordinator::{ApproxAuc, AucEstimator};
use streamauc::stream::Pcg;

fn main() {
    let mut rng = Pcg::seed(1);
    let mut est = ApproxAuc::new(0.01);
    let mut fifo = std::collections::VecDeque::new();
    let mut sink = 0.0;
    for _ in 0..2_000_000u64 {
        let s = rng.uniform();
        let l = rng.chance(0.5);
        est.insert(s, l);
        fifo.push_back((s, l));
        if fifo.len() > 10_000 {
            let (os, ol) = fifo.pop_front().unwrap();
            est.remove(os, ol);
        }
        sink += est.auc();
    }
    std::hint::black_box(sink);
    println!("prof done");
}
