//! The paper's contribution: `ε/2`-approximate sliding-window AUC (§4).
//!
//! On top of the §3 support structure, the estimator maintains a weighted
//! linked list `C` that is `(1+ε)`-**compressed**: for consecutive
//! `v, w ∈ C`
//!
//! ```text
//! hp(w) ≤ α·(hp(v) + p(v))                    (Eq. 3, accuracy)
//! hp(next(w)) > α·(hp(v) + p(v)) if it exists (Eq. 4, size)
//! ```
//!
//! with `α = 1 + ε` and `hp(x)` the number of positive labels *below*
//! `s(x)`. Eq. 3 drives Proposition 1 (`|ãuc − auc| ≤ ε·auc/2`), Eq. 4
//! drives Proposition 2 (`|C| ∈ O((log k)/ε)`); `ApproxAUC` (Algorithm 4)
//! reads the estimate from `C`'s gap counters in `O(|C|)`.
//!
//! The update procedures follow §4.2: negatives only touch one gap
//! counter; positives additionally repair Eq. 3 via `AddNext`
//! (Algorithm 5 / Lemma 1) and re-establish Eq. 4 via `Compress`
//! (Algorithm 6).
//!
//! **Incremental read (this crate, beyond the paper).** Algorithm 4
//! reads the estimate by scanning all of `C` — `O(|C|) = O((log k)/ε)`
//! per read, which dominates monitored ingestion (one read per update).
//! We instead maintain the doubled-area accumulator `a2` as a running
//! `u128` updated delta-wise by every list mutation, so
//! [`ApproxAuc::auc`] is `O(1)`. All deltas are integer arithmetic over
//! exactly the terms the scan sums, so the running value is **bit-equal**
//! to the from-scratch scan (retained as
//! [`ApproxAuc::doubled_area_scan`]) after every operation — derivation
//! in `DESIGN.md` §Incremental-reads, property-tested per op in
//! `rust/tests/differential.rs` and in [`ApproxAuc::check_invariants`].
//!
//! Like the layers underneath, the estimator comes in two forms: the
//! storage-free [`ApproxCore`] allocating from a caller-supplied
//! [`EstimatorArenas`] (the fleet pools one bundle per shard) and the
//! self-contained [`ApproxAuc`] wrapper with private arenas. The core
//! additionally supports **rehydration** ([`ApproxCore::rebuild_in`]):
//! a hibernated stream stores only its window content plus the finite
//! keys of `C`; replaying the content through the support structure and
//! rebuilding `C`'s cells from those keys (gap counters are a pure
//! function of the key set and the window) reproduces the frozen
//! estimator bit-for-bit — `C`'s shape depends on the full insertion
//! history, so it must be restored, not re-derived (`rust/DESIGN.md`
//! §Memory).
//!
//! Deviations from the paper's pseudo-code (all behaviour-preserving;
//! rationale in DESIGN.md §Pseudo-code-fixes):
//!
//! * Algorithm 7 line 5 checks `α·(c + p(v))` with `v` the freshly
//!   inserted tree node; Eq. 3 for the pair `(u, next(u; C))` requires
//!   `p(u)` — we use `p(u)` (identical when `s(v)` coincides with `s(u)`,
//!   which is the only case where the written form is meaningful).
//! * Algorithm 8's scan omits the running-total update `c ← c + x`
//!   between iterations; we restore it (otherwise `c` would stay 0 and
//!   the scan would spuriously add nodes).
//! * `ε = 0` is allowed and degenerates to the exact estimator over the
//!   positive list `P` (paper §5: “essentially equivalent … if we set
//!   ε = 0”).

use super::support::{EstimatorArenas, SupportCore};
use super::{finish_auc, AucEstimator};
use crate::collections::weighted_list::ListCore;
use crate::collections::{CellId, Score};

/// Storage-free form of the approximate estimator: a [`SupportCore`],
/// the compressed list's head/tail, and two scalars. All nodes and
/// cells live in the [`EstimatorArenas`] passed into every call.
#[derive(Clone, Copy, Debug)]
pub(crate) struct ApproxCore {
    pub(crate) sup: SupportCore,
    /// The `(1+ε)`-compressed list `C` (cells in the bundle's `c` arena).
    c: ListCore,
    /// `α = 1 + ε`.
    alpha: f64,
    /// Running doubled-area accumulator: at every op boundary equal —
    /// bit-for-bit — to what the Algorithm 4 scan over `C` would sum
    /// ([`ApproxCore::doubled_area_scan`]). Maintained by integer deltas
    /// at each list mutation; makes the `auc` read `O(1)`.
    a2: u128,
}

impl ApproxCore {
    /// New estimator with approximation parameter `ε ≥ 0`, allocating
    /// its sentinels from `ars`.
    pub(crate) fn new_in(ars: &mut EstimatorArenas, epsilon: f64) -> Self {
        assert!(
            epsilon >= 0.0 && epsilon.is_finite(),
            "epsilon must be finite and non-negative"
        );
        let sup = SupportCore::new_in(ars);
        let mut c = ListCore::new();
        c.push_back(&mut ars.c, sup.neg_sentinel(), f64::NEG_INFINITY, 0, 0);
        c.push_back(&mut ars.c, sup.pos_sentinel(), f64::INFINITY, 0, 0);
        ApproxCore { sup, c, alpha: 1.0 + epsilon, a2: 0 }
    }

    /// Release every node and cell back to the arenas (`O(k)`). The core
    /// must not be used afterwards.
    pub(crate) fn free_in(&mut self, ars: &mut EstimatorArenas) {
        self.sup.free_in(ars);
        self.c.drain(&mut ars.c);
        self.a2 = 0;
    }

    /// The `ε` this estimator was built with.
    #[inline]
    pub(crate) fn epsilon(&self) -> f64 {
        self.alpha - 1.0
    }

    /// Current size of the compressed list `C`, sentinels included.
    #[inline]
    pub(crate) fn compressed_len(&self) -> usize {
        self.c.len()
    }

    /// Logical bytes of arena storage this estimator's structures
    /// occupy: the support bundle plus the `C` cells. Content-determined
    /// (live counts × slot sizes), never arena capacity.
    pub(crate) fn live_bytes(&self) -> usize {
        self.sup.live_bytes()
            + self.c.len() * std::mem::size_of::<crate::collections::weighted_list::Cell>()
    }

    /// Positive / negative totals.
    #[inline]
    pub(crate) fn class_totals(&self) -> (u64, u64) {
        (self.sup.total_pos(), self.sup.total_neg())
    }

    /// Window size (all entries).
    #[inline]
    pub(crate) fn len(&self) -> usize {
        self.sup.len()
    }

    /// Exact AUC via `O(k)` enumeration of the support tree.
    pub(crate) fn exact_auc(&self, ars: &EstimatorArenas) -> f64 {
        self.sup.exact_auc(ars)
    }

    /// The running doubled-area accumulator behind the `O(1)` read.
    #[inline]
    pub(crate) fn doubled_area(&self) -> u128 {
        self.a2
    }

    /// `ApproxAUC(C)` (Algorithm 4) in `O(1)` from the running
    /// accumulator.
    #[inline]
    pub(crate) fn auc(&self) -> f64 {
        finish_auc(self.a2, self.sup.total_pos(), self.sup.total_neg())
    }

    /// The doubled-area accumulator recomputed from scratch by the
    /// Algorithm 4 scan over `C` — `O(|C|)`. This is the reference the
    /// running accumulator must equal bit-for-bit after every operation.
    pub(crate) fn doubled_area_scan(&self, ars: &EstimatorArenas) -> u128 {
        let mut hp: u64 = 0;
        let mut a2: u128 = 0;
        // Cell-local read: cached (p, n), one slab lookup per cell
        // (§Perf) — no tree dereferences at all.
        for cell in self.c.views_in(&ars.c) {
            // The C node itself, exact.
            a2 += u128::from(2 * hp + cell.p) * u128::from(cell.n);
            hp += cell.p;
            // The grouped gap behind it, as one pseudo-node.
            let gp = cell.gp - cell.p;
            let gn = cell.gn - cell.n;
            a2 += u128::from(2 * hp + gp) * u128::from(gn);
            hp += gp;
        }
        a2
    }

    /// The estimate read via the full `O(|C|)` scan instead of the
    /// cached accumulator.
    pub(crate) fn auc_full_scan(&self, ars: &EstimatorArenas) -> f64 {
        finish_auc(self.doubled_area_scan(ars), self.sup.total_pos(), self.sup.total_neg())
    }

    /// The finite keys of `C` in ascending order (sentinels excluded) —
    /// exactly what hibernation must store to restore `C`'s shape
    /// ([`ApproxCore::rebuild_in`]).
    pub(crate) fn compressed_keys(&self, ars: &EstimatorArenas) -> Vec<f64> {
        self.c
            .iter_in(&ars.c)
            .filter_map(|cell| {
                let k = self.c.key(&ars.c, cell);
                k.is_finite().then_some(k)
            })
            .collect()
    }

    /// Rebuild `C` from a frozen key set (rehydration). `self.sup` must
    /// already hold the full window content and `C` must be pristine
    /// (sentinels only, zero gaps — the state [`ApproxCore::new_in`]
    /// leaves). The gap counters of the rebuilt cells are pure
    /// functions of the key set and the window, so the result is
    /// bit-identical to the estimator that was frozen; `a2` is
    /// re-derived by the reference scan, which the running value always
    /// equals.
    pub(crate) fn rebuild_in(&mut self, ars: &mut EstimatorArenas, keys: &[f64]) {
        debug_assert_eq!(self.c.len(), 2, "rebuild over a non-pristine C");
        let head = self.c.head().expect("C sentinels present");
        // Seed the −∞ sentinel's gap with the whole window, then split
        // off each stored cell left to right.
        let tp = i64::try_from(self.sup.total_pos()).expect("window too large");
        let tn = i64::try_from(self.sup.total_neg()).expect("window too large");
        self.c.add_gp(&mut ars.c, head, tp);
        self.c.add_gn(&mut ars.c, head, tn);
        let mut prev = head;
        let (mut hp_prev, mut hn_prev) = (0u64, 0u64);
        for &key in keys {
            let s = Score(key);
            let node = self.sup.t.find(&ars.t, s).expect("frozen C key missing from T");
            let cnt = *self.sup.t.val(&ars.t, node);
            let (hp, hn) = self.sup.head_stats(ars, s);
            prev = self
                .c
                .insert_after(&mut ars.c, prev, node, key, cnt.p, cnt.n, hp - hp_prev, hn - hn_prev);
            hp_prev = hp;
            hn_prev = hn;
        }
        self.a2 = self.doubled_area_scan(ars);
    }

    // ------------------------------------------------------------------
    // C-list helpers
    // ------------------------------------------------------------------

    /// Largest `u ∈ C` with `s(u) ≤ s`, plus the prefix sums `hp(u)` /
    /// `hn(u)` accumulated from the gap counters of the cells before
    /// `u`. Linear in `|C|`, which is the budgeted `O((log k)/ε)`
    /// (§4.2).
    fn c_floor(&self, ars: &EstimatorArenas, s: Score) -> (CellId, u64, u64) {
        // Hot loop: cached keys + single slab lookup per hop (§Perf).
        self.c.floor_scan(&ars.c, s.0)
    }

    /// One cell's contribution to the doubled-area accumulator, given
    /// `h` positives in the cells before it: the C node itself exactly,
    /// then the grouped gap behind it as one pseudo-node — the two
    /// terms the Algorithm 4 scan adds per cell.
    #[inline]
    fn cell_a2(&self, ars: &EstimatorArenas, cell: CellId, h: u64) -> u128 {
        let v = self.c.view(&ars.c, cell);
        let node = u128::from(2 * h + v.p) * u128::from(v.n);
        let gp = v.gp - v.p;
        let gn = v.gn - v.n;
        let gap = u128::from(2 * (h + v.p) + gp) * u128::from(gn);
        node + gap
    }

    /// `AddNext(v, C, P)` (Algorithm 5): splice the `P`-successor of
    /// `node(v_cell)` into `C` right after `v_cell`, with gap counters
    /// taken from `P` in `O(1)`. No-op if the successor is already in
    /// `C`. `h` is `hp(v; C)` — the positives before `v_cell` — needed
    /// to recompute the two touched cells' `a2` contributions (the gap
    /// split moves no positives across later cells, so the delta is
    /// purely local).
    fn add_next(&mut self, ars: &mut EstimatorArenas, v_cell: CellId, h: u64) {
        let v_node = self.c.node(&ars.c, v_cell);
        let p = self.sup.p;
        let v_in_p = p.cell_of(&ars.p, v_node).expect("C nodes are always in P");
        let Some(w_in_p) = p.next(&ars.p, v_in_p) else {
            return; // v is the +∞ sentinel; nothing follows
        };
        let w_node = p.node(&ars.p, w_in_p);
        if self.c.contains(&ars.c, w_node) {
            return;
        }
        let (gp, gn) = (p.gp(&ars.p, v_in_p), p.gn(&ars.p, v_in_p));
        let (key, wp, wn) = (p.key(&ars.p, w_in_p), p.cp(&ars.p, w_in_p), p.cn(&ars.p, w_in_p));
        let old = self.cell_a2(ars, v_cell, h);
        let w_cell = self.c.insert_after(&mut ars.c, v_cell, w_node, key, wp, wn, gp, gn);
        self.a2 = self.a2 - old
            + self.cell_a2(ars, v_cell, h)
            + self.cell_a2(ars, w_cell, h + self.c.gp(&ars.c, v_cell));
    }

    /// `Compress(C, α)` alone (Algorithm 6): merge-only pass for
    /// `AddPos`, where Eq. 3 can only break at the floor cell and is
    /// repaired before this runs — a full repair scan would double the
    /// per-cell work for nothing (§Perf). A merge folds `w` into `v`
    /// without moving positives across later cells, so each one is a
    /// local `a2` recompute of the pair → merged cell.
    fn compress(&mut self, ars: &mut EstimatorArenas) {
        let Some(mut v) = self.c.head() else { return };
        let mut c_hp = 0u64;
        loop {
            let Some(w) = self.c.next(&ars.c, v) else { break };
            if self.c.next(&ars.c, w).is_none() {
                break; // w is the last cell (+∞ sentinel): keep it
            }
            let merged = c_hp + self.c.gp(&ars.c, v) + self.c.gp(&ars.c, w);
            let bound = self.alpha * (c_hp + self.c.cp(&ars.c, v)) as f64;
            if (merged as f64) <= bound {
                let old = self.cell_a2(ars, v, c_hp)
                    + self.cell_a2(ars, w, c_hp + self.c.gp(&ars.c, v));
                self.c.remove(&mut ars.c, w);
                self.a2 = self.a2 - old + self.cell_a2(ars, v, c_hp);
            } else {
                c_hp += self.c.gp(&ars.c, v);
                v = w;
            }
        }
    }

    /// Eq. 3 check for the pair starting at cell `v` given `c = hp(v)`.
    #[inline]
    fn eq3_violated(&self, ars: &EstimatorArenas, v: CellId, c_hp: u64) -> bool {
        let hp_next = c_hp + self.c.gp(&ars.c, v);
        (hp_next as f64) > self.alpha * (c_hp + self.c.cp(&ars.c, v)) as f64
    }

    /// `AddPos` (Algorithm 7).
    fn add_pos(&mut self, ars: &mut EstimatorArenas, s: Score) {
        let _v = self.sup.add_pos(ars, s);
        let (u_cell, c_hp, c_hn) = self.c_floor(ars, s);
        // The new positive becomes one more predecessor of every
        // negative in the cells after u: their scan terms grow by
        // 2·gn each, one suffix adjustment totalling 2·suffix_gn. The
        // gn prefix rides the floor scan, so this is O(1) extra.
        let suffix_gn = self.sup.total_neg() - c_hn - self.c.gn(&ars.c, u_cell);
        let old = self.cell_a2(ars, u_cell, c_hp);
        self.c.add_gp(&mut ars.c, u_cell, 1);
        if self.c.key(&ars.c, u_cell) == s.0 {
            self.c.add_cp(&mut ars.c, u_cell, 1);
        }
        self.a2 = self.a2 - old + self.cell_a2(ars, u_cell, c_hp) + 2 * u128::from(suffix_gn);
        // At most one Eq. 3 violation, at u (Lemma 1 discussion, §4.2).
        if self.eq3_violated(ars, u_cell, c_hp) {
            self.add_next(ars, u_cell, c_hp);
        }
        self.compress(ars);
    }

    /// `RemovePos` (Algorithm 8).
    ///
    /// Note the ordering fix versus the paper's pseudo-code: Algorithm 8
    /// decrements `gp(u; C)` *before* `AddNext`, but `AddNext` splits the
    /// gap using `gp(u; P) = p(u)` — when `u` is the only positive in its
    /// own C-gap (`gp(u; C) = p(u) = 1`), the literal order drives the
    /// new cell's counter to `−1`. Splitting first, then decrementing,
    /// performs the identical net transfer without the underflow.
    fn remove_pos(&mut self, ars: &mut EstimatorArenas, s: Score) {
        let (u_cell, c_hp, c_hn) = self.c_floor(ars, s);
        if self.c.key(&ars.c, u_cell) == s.0 && self.c.cp(&ars.c, u_cell) == 1 {
            // u is about to stop being positive: pull in its P-successor
            // so the coverage of C is preserved, account the departing
            // label inside [u, w), then drop u from C.
            self.add_next(ars, u_cell, c_hp);
            // Fused a2 step for {gp(u) −= 1; remove u}: retract prev's
            // and u's contributions while both are coherent, apply both
            // mutations, re-add the merged predecessor, and charge the
            // departed positive against the negatives after u.
            let suffix_gn = self.sup.total_neg() - c_hn - self.c.gn(&ars.c, u_cell);
            let prev =
                self.c.prev(&ars.c, u_cell).expect("floor of a finite score is never the head");
            let h_prev = c_hp - self.c.gp(&ars.c, prev);
            let old = self.cell_a2(ars, prev, h_prev) + self.cell_a2(ars, u_cell, c_hp);
            self.c.add_gp(&mut ars.c, u_cell, -1);
            self.c.remove(&mut ars.c, u_cell);
            self.a2 = self.a2 - old + self.cell_a2(ars, prev, h_prev) - 2 * u128::from(suffix_gn);
        } else {
            let suffix_gn = self.sup.total_neg() - c_hn - self.c.gn(&ars.c, u_cell);
            let old = self.cell_a2(ars, u_cell, c_hp);
            self.c.add_gp(&mut ars.c, u_cell, -1);
            if self.c.key(&ars.c, u_cell) == s.0 {
                self.c.add_cp(&mut ars.c, u_cell, -1);
            }
            self.a2 = self.a2 - old + self.cell_a2(ars, u_cell, c_hp) - 2 * u128::from(suffix_gn);
        }
        self.sup.remove_pos(ars, s);
        // Re-establish Eq. 3 along the whole list (two violation shapes
        // are possible after a removal; Lemma 1 repairs each by one
        // AddNext), then Eq. 4. Measured §Perf note: fusing these two
        // passes into one was tried and reverted — the branchier fused
        // loop ran ~10% slower than two tight passes.
        let Some(mut v) = self.c.head() else { return };
        let mut c_hp = 0u64;
        while let Some(w) = self.c.next(&ars.c, v) {
            let x = self.c.gp(&ars.c, v);
            if self.eq3_violated(ars, v, c_hp) {
                self.add_next(ars, v, c_hp);
            }
            c_hp += x;
            v = w;
        }
        self.compress(ars);
    }

    /// Add-negative update (§4.2): one gap counter in `C`. Negatives
    /// never shift the positive prefix of later cells, so the `a2`
    /// delta is purely local to the floor cell.
    fn add_neg(&mut self, ars: &mut EstimatorArenas, s: Score) {
        self.sup.add_neg(ars, s);
        let (u_cell, c_hp, _) = self.c_floor(ars, s);
        let old = self.cell_a2(ars, u_cell, c_hp);
        self.c.add_gn(&mut ars.c, u_cell, 1);
        if self.c.key(&ars.c, u_cell) == s.0 {
            self.c.add_cn(&mut ars.c, u_cell, 1);
        }
        self.a2 = self.a2 - old + self.cell_a2(ars, u_cell, c_hp);
    }

    /// Remove-negative update (§4.2).
    fn remove_neg(&mut self, ars: &mut EstimatorArenas, s: Score) {
        self.sup.remove_neg(ars, s);
        let (u_cell, c_hp, _) = self.c_floor(ars, s);
        let old = self.cell_a2(ars, u_cell, c_hp);
        self.c.add_gn(&mut ars.c, u_cell, -1);
        if self.c.key(&ars.c, u_cell) == s.0 {
            self.c.add_cn(&mut ars.c, u_cell, -1);
        }
        self.a2 = self.a2 - old + self.cell_a2(ars, u_cell, c_hp);
    }

    /// Insert one labelled entry ([`AucEstimator::insert`] semantics).
    pub(crate) fn insert_in(&mut self, ars: &mut EstimatorArenas, score: f64, pos: bool) {
        let s = Score(super::canon(score));
        assert!(s.is_valid_entry(), "scores must be finite");
        if pos {
            self.add_pos(ars, s);
        } else {
            self.add_neg(ars, s);
        }
    }

    /// Remove one labelled entry ([`AucEstimator::remove`] semantics).
    pub(crate) fn remove_in(&mut self, ars: &mut EstimatorArenas, score: f64, pos: bool) {
        let s = Score(super::canon(score));
        if pos {
            self.remove_pos(ars, s);
        } else {
            self.remove_neg(ars, s);
        }
    }

    /// Validate the §4 invariants on `C` (tests / property harness):
    /// coverage, ordering, Eq. 3, Eq. 4, and gap counters against brute
    /// force. Panics on violation.
    pub(crate) fn check_invariants(&self, ars: &EstimatorArenas) {
        self.sup.check_invariants(ars);
        let cells: Vec<CellId> = self.c.iter_in(&ars.c).collect();
        assert!(cells.len() >= 2, "C lost its sentinels");
        assert_eq!(self.c.node(&ars.c, cells[0]), self.sup.neg_sentinel(), "C head sentinel");
        assert_eq!(
            self.c.node(&ars.c, *cells.last().unwrap()),
            self.sup.pos_sentinel(),
            "C tail sentinel"
        );
        // Every C node is in P (sentinels included), scores ascend, and
        // the gap counters match brute-force head-stat differences.
        for w in cells.windows(2) {
            let (a, b) = (w[0], w[1]);
            let (na, nb) = (self.c.node(&ars.c, a), self.c.node(&ars.c, b));
            assert!(self.sup.p.contains(&ars.p, na), "C node not in P");
            let (sa, sb) = (self.sup.score(ars, na), self.sup.score(ars, nb));
            assert!(sa < sb, "C not score-ascending");
            let (hp_a, hn_a) = self.sup.head_stats(ars, sa);
            let (hp_b, hn_b) = self.sup.head_stats(ars, sb);
            assert_eq!(self.c.gp(&ars.c, a), hp_b - hp_a, "gp(·;C) brute mismatch");
            assert_eq!(self.c.gn(&ars.c, a), hn_b - hn_a, "gn(·;C) brute mismatch");
        }
        assert_eq!(self.c.total_gp(&ars.c), self.sup.total_pos(), "C misses positives");
        assert_eq!(self.c.total_gn(&ars.c), self.sup.total_neg(), "C misses negatives");
        // Cell caches (key, p, n) coherent with the tree.
        for &cell in &cells {
            let node = self.c.node(&ars.c, cell);
            assert_eq!(self.c.key(&ars.c, cell), self.sup.score(ars, node).0, "C cache: stale key");
            let cnt = self.sup.counts(ars, node);
            assert_eq!(self.c.cp(&ars.c, cell), cnt.p, "C cache: stale p");
            assert_eq!(self.c.cn(&ars.c, cell), cnt.n, "C cache: stale n");
        }
        // The running doubled-area accumulator never drifts from the
        // from-scratch Algorithm 4 scan — integer bit-equality.
        assert_eq!(
            self.a2,
            self.doubled_area_scan(ars),
            "incremental a2 drifted from the full scan"
        );
        // Eq. 3 for all consecutive pairs; Eq. 4 for all triples.
        let mut hp = 0u64;
        for (i, &v) in cells.iter().enumerate() {
            let p_v = self.sup.counts(ars, self.c.node(&ars.c, v)).p;
            let bound = self.alpha * (hp + p_v) as f64;
            if i + 1 < cells.len() {
                let hp_w = hp + self.c.gp(&ars.c, v);
                assert!(
                    hp_w as f64 <= bound,
                    "Eq. 3 violated at cell {i}: hp(w)={hp_w} > {bound}"
                );
                if i + 2 < cells.len() {
                    let hp_u = hp_w + self.c.gp(&ars.c, cells[i + 1]);
                    assert!(
                        hp_u as f64 > bound,
                        "Eq. 4 violated at cell {i}: hp(u)={hp_u} ≤ {bound}"
                    );
                }
            }
            hp += self.c.gp(&ars.c, v);
        }
    }
}

/// Approximate sliding-window AUC estimator (`|ãuc − auc| ≤ ε·auc/2`)
/// with private arenas — the self-contained form for standalone use.
/// Delegates to an [`ApproxCore`]; the fleet uses cores against
/// shard-owned arenas.
#[derive(Clone, Debug)]
pub struct ApproxAuc {
    ars: EstimatorArenas,
    core: ApproxCore,
}

impl ApproxAuc {
    /// New estimator with approximation parameter `ε ≥ 0`.
    ///
    /// `ε = 0` yields the exact AUC with `|C| = |P|` (every positive node
    /// enumerated); larger `ε` trades accuracy for a smaller `C`.
    pub fn new(epsilon: f64) -> Self {
        let mut ars = EstimatorArenas::default();
        let core = ApproxCore::new_in(&mut ars, epsilon);
        ApproxAuc { ars, core }
    }

    /// The `ε` this estimator was built with.
    #[inline]
    pub fn epsilon(&self) -> f64 {
        self.core.epsilon()
    }

    /// Current size of the compressed list `C`, sentinels included (the
    /// quantity plotted in Figure 2 bottom).
    #[inline]
    pub fn compressed_len(&self) -> usize {
        self.core.compressed_len()
    }

    /// Positive / negative totals (exposed for experiment drivers).
    pub fn class_totals(&self) -> (u64, u64) {
        self.core.class_totals()
    }

    /// Exact AUC via `O(k)` enumeration of the support tree. Used by the
    /// error-measurement experiments so approx and exact share one window.
    pub fn exact_auc(&self) -> f64 {
        self.core.exact_auc(&self.ars)
    }

    /// The running doubled-area accumulator behind the `O(1)`
    /// [`ApproxAuc::auc`] read. Exposed for the bit-equality property
    /// tests and the bench's cached-vs-scan comparison.
    #[inline]
    pub fn doubled_area(&self) -> u128 {
        self.core.doubled_area()
    }

    /// The doubled-area accumulator recomputed from scratch by the
    /// Algorithm 4 scan over `C` — `O(|C|)`. This is the reference the
    /// running accumulator must equal bit-for-bit after every
    /// operation (`rust/tests/differential.rs`,
    /// [`ApproxAuc::check_invariants`]); it is also the read path every
    /// call to [`ApproxAuc::auc`] used before the accumulator existed,
    /// retained for the `benches/core.rs` speedup measurement.
    pub fn doubled_area_scan(&self) -> u128 {
        self.core.doubled_area_scan(&self.ars)
    }

    /// The estimate read via the full `O(|C|)` scan instead of the
    /// cached accumulator. Bit-identical to [`ApproxAuc::auc`]; kept as
    /// the reference/benchmark read path.
    pub fn auc_full_scan(&self) -> f64 {
        self.core.auc_full_scan(&self.ars)
    }

    /// Release retained arena capacity (freed slots at the slab tails)
    /// without touching live state. Called automatically when the
    /// window drains to empty; exposed for explicit trimming after a
    /// churn spike.
    pub fn shrink_to_fit(&mut self) {
        self.ars.shrink_to_fit();
    }

    /// Total slots retained across the four backing arenas (live +
    /// reusable) — the capacity measure the shrink hooks act on.
    pub fn capacity(&self) -> usize {
        self.ars.t.slot_count()
            + self.ars.tp.slot_count()
            + self.ars.p.cells.slot_count()
            + self.ars.c.cells.slot_count()
    }

    /// Validate the §4 invariants on `C` (tests / property harness):
    /// coverage, ordering, Eq. 3, Eq. 4, and gap counters against brute
    /// force. Panics on violation.
    pub fn check_invariants(&self) {
        self.core.check_invariants(&self.ars);
    }
}

impl AucEstimator for ApproxAuc {
    fn insert(&mut self, score: f64, pos: bool) {
        self.core.insert_in(&mut self.ars, score, pos);
    }

    fn remove(&mut self, score: f64, pos: bool) {
        self.core.remove_in(&mut self.ars, score, pos);
        if self.core.len() == 0 {
            // Drained windows shed their churn slack so idle standalone
            // estimators never pin peak capacity (`DESIGN.md` §Memory).
            self.ars.shrink_to_fit();
        }
    }

    /// `ApproxAUC(C)` (Algorithm 4), read in `O(1)` from the running
    /// doubled-area accumulator instead of the paper's `O(|C|)` scan
    /// (bit-identical — see [`ApproxAuc::doubled_area_scan`]). No cell
    /// iteration happens on this path.
    fn auc(&self) -> f64 {
        self.core.auc()
    }

    fn len(&self) -> usize {
        self.core.len()
    }
}

// The estimator owns its support structure and compressed list outright
// (`Send`-clean from the rbtree up), so whole per-stream windows can be
// drained on the fleet executor's scoped worker threads.
const _: () = {
    const fn assert_send<T: Send>() {}
    assert_send::<ApproxAuc>();
};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::NaiveAuc;
    use crate::testing::{check, gen_ops, Op, Pcg};

    fn run_ops(eps: f64, ops: &[Op], check_every: usize) -> (ApproxAuc, NaiveAuc) {
        let mut approx = ApproxAuc::new(eps);
        let mut naive = NaiveAuc::new();
        for (i, op) in ops.iter().enumerate() {
            match *op {
                Op::Insert { score, pos } => {
                    approx.insert(score, pos);
                    naive.insert(score, pos);
                }
                Op::Remove { score, pos } => {
                    approx.remove(score, pos);
                    naive.remove(score, pos);
                }
            }
            if check_every > 0 && i % check_every == 0 {
                approx.check_invariants();
            }
            // Proposition 1 after every op: |ãuc − auc| ≤ ε·auc/2.
            let truth = naive.auc();
            let est = approx.auc();
            let tol = eps * truth / 2.0 + 1e-12;
            assert!(
                (est - truth).abs() <= tol,
                "guarantee violated at op {i}: est {est}, truth {truth}, ε {eps}"
            );
        }
        (approx, naive)
    }

    #[test]
    fn empty_and_single_class() {
        let e = ApproxAuc::new(0.1);
        assert_eq!(e.auc(), 0.5);
        assert_eq!(e.compressed_len(), 2);
        let mut e = ApproxAuc::new(0.1);
        for i in 0..20 {
            e.insert(f64::from(i), true);
        }
        assert_eq!(e.auc(), 0.5); // no negatives
        e.check_invariants();
        let mut e = ApproxAuc::new(0.1);
        for i in 0..20 {
            e.insert(f64::from(i), false);
        }
        assert_eq!(e.auc(), 0.5);
        e.check_invariants();
    }

    #[test]
    fn perfect_separation_within_guarantee() {
        // Grouping ties trailing negatives with grouped positives, so the
        // estimate is not exactly 1 — but must obey ε·auc/2 (here 0.25),
        // and tighten as ε shrinks.
        let mut prev_err = f64::INFINITY;
        for eps in [0.5, 0.1, 0.01, 0.0] {
            let mut e = ApproxAuc::new(eps);
            for i in 0..50 {
                e.insert(f64::from(i), true);
                e.insert(f64::from(i) + 1000.0, false);
            }
            e.check_invariants();
            let err = (e.auc() - 1.0).abs();
            assert!(err <= eps / 2.0 + 1e-12, "ε={eps}: err {err}");
            assert!(err <= prev_err + 1e-12, "error should tighten with ε");
            prev_err = err;
        }
        assert_eq!(prev_err, 0.0, "ε=0 must be exact");
    }

    #[test]
    fn epsilon_zero_matches_naive_exactly() {
        check(0xE0, 15, |rng| {
            let ops = gen_ops(rng, 250, 50, Some(16));
            let (approx, naive) = run_ops(0.0, &ops, 25);
            let (a, b) = (approx.auc(), naive.auc());
            assert!((a - b).abs() < 1e-12, "ε=0 mismatch: {a} vs {b}");
        });
    }

    #[test]
    fn guarantee_holds_for_all_epsilons_unique_scores() {
        for eps in [0.001, 0.01, 0.1, 0.5, 1.0] {
            check((eps * 1e4) as u64, 8, |rng| {
                let ops = gen_ops(rng, 250, 60, None);
                run_ops(eps, &ops, 25);
            });
        }
    }

    #[test]
    fn guarantee_holds_with_heavy_duplicates() {
        for eps in [0.01, 0.1, 0.5] {
            check(0xD0 ^ (eps * 1e3) as u64, 8, |rng| {
                let grid = 4 + rng.below(12);
                let ops = gen_ops(rng, 250, 60, Some(grid));
                run_ops(eps, &ops, 20);
            });
        }
    }

    #[test]
    fn fifo_window_churn_with_invariants() {
        for eps in [0.05, 0.25] {
            let mut approx = ApproxAuc::new(eps);
            let mut naive = NaiveAuc::new();
            let mut window: std::collections::VecDeque<(f64, bool)> = Default::default();
            let mut rng = Pcg::seed(0xF1F0);
            for i in 0..1500 {
                // Drifting score distribution.
                let drift = f64::from(i / 300) * 0.1;
                let pos = rng.chance(0.4);
                let mean = if pos { 0.35 + drift } else { 0.65 };
                let score = (rng.normal_with(mean, 0.15)).clamp(0.0, 1.0);
                approx.insert(score, pos);
                naive.insert(score, pos);
                window.push_back((score, pos));
                if window.len() > 200 {
                    let (s, p) = window.pop_front().unwrap();
                    approx.remove(s, p);
                    naive.remove(s, p);
                }
                if i % 100 == 0 {
                    approx.check_invariants();
                }
                let truth = naive.auc();
                let est = approx.auc();
                assert!(
                    (est - truth).abs() <= eps * truth / 2.0 + 1e-12,
                    "op {i}: est {est} truth {truth}"
                );
            }
            approx.check_invariants();
        }
    }

    #[test]
    fn running_a2_matches_scan_after_every_op() {
        // The O(1) read contract at unit scale: the running accumulator
        // is bit-equal to the retained Algorithm 4 scan after *every*
        // op, across grids (merge/regroup-heavy) and the continuum.
        // The integration-scale version lives in tests/differential.rs.
        for eps in [0.0, 0.01, 0.1, 0.5] {
            check(0xA2 ^ (eps * 1e3) as u64, 6, |rng| {
                let grid = if rng.chance(0.5) { Some(4 + rng.below(12)) } else { None };
                let ops = gen_ops(rng, 300, 60, grid);
                let mut e = ApproxAuc::new(eps);
                for (i, op) in ops.iter().enumerate() {
                    match *op {
                        Op::Insert { score, pos } => e.insert(score, pos),
                        Op::Remove { score, pos } => e.remove(score, pos),
                    }
                    assert_eq!(
                        e.doubled_area(),
                        e.doubled_area_scan(),
                        "a2 drift at op {i} (ε = {eps})"
                    );
                    assert_eq!(e.auc().to_bits(), e.auc_full_scan().to_bits());
                }
            });
        }
    }

    #[test]
    fn compressed_list_is_logarithmic() {
        // Proposition 2: |C| ∈ O(log k / ε). Fill a large window and
        // check |C| stays far below the number of distinct positives.
        let mut e = ApproxAuc::new(0.1);
        let mut rng = Pcg::seed(0x517E);
        let k = 20_000;
        for _ in 0..k {
            e.insert(rng.uniform(), rng.chance(0.5));
        }
        let bound = ((k as f64).log2() / 0.1) as usize;
        assert!(
            e.compressed_len() < bound,
            "|C| = {} exceeds O(log k/ε) ballpark {bound}",
            e.compressed_len()
        );
        // And is much smaller than the positive count.
        assert!(e.compressed_len() < 1000);
    }

    #[test]
    fn monotone_epsilon_shrinks_c() {
        let mut sizes = Vec::new();
        for eps in [0.0, 0.01, 0.1, 1.0] {
            let mut e = ApproxAuc::new(eps);
            let mut rng = Pcg::seed(42);
            for _ in 0..4000 {
                e.insert(rng.uniform(), rng.chance(0.5));
            }
            sizes.push(e.compressed_len());
        }
        assert!(
            sizes.windows(2).all(|w| w[0] >= w[1]),
            "|C| not monotone in ε: {sizes:?}"
        );
        assert!(sizes[0] > 10 * sizes[3], "compression should be drastic: {sizes:?}");
    }

    #[test]
    fn all_same_score_stream() {
        let mut e = ApproxAuc::new(0.1);
        for _ in 0..100 {
            e.insert(0.5, true);
            e.insert(0.5, false);
        }
        e.check_invariants();
        assert_eq!(e.auc(), 0.5);
        for _ in 0..100 {
            e.remove(0.5, true);
            e.remove(0.5, false);
        }
        assert!(e.is_empty());
        e.check_invariants();
    }

    #[test]
    fn drain_to_empty_and_refill() {
        let mut rng = Pcg::seed(0xABCD);
        let mut e = ApproxAuc::new(0.2);
        let mut live: Vec<(f64, bool)> = Vec::new();
        for round in 0..3 {
            for _ in 0..200 {
                let pair = (rng.below(20) as f64, rng.chance(0.5));
                e.insert(pair.0, pair.1);
                live.push(pair);
            }
            e.check_invariants();
            rng.shuffle(&mut live);
            while let Some((s, p)) = live.pop() {
                e.remove(s, p);
            }
            assert!(e.is_empty(), "round {round}");
            e.check_invariants();
            assert_eq!(e.compressed_len(), 2);
        }
    }

    #[test]
    fn drained_estimator_sheds_capacity() {
        let mut e = ApproxAuc::new(0.1);
        let mut rng = Pcg::seed(0x5123);
        let mut live: Vec<(f64, bool)> = Vec::new();
        for _ in 0..2000 {
            let pair = (rng.uniform(), rng.chance(0.5));
            e.insert(pair.0, pair.1);
            live.push(pair);
        }
        let peak = e.capacity();
        assert!(peak > 1000, "peak capacity should reflect the fill: {peak}");
        rng.shuffle(&mut live);
        for (s, p) in live {
            e.remove(s, p);
        }
        // The empty-window hook trims the slack down to the sentinels.
        assert!(
            e.capacity() <= 8,
            "drained estimator retains {} slots (peak {peak})",
            e.capacity()
        );
        e.check_invariants();
        // And the estimator is fully usable afterwards.
        e.insert(0.25, true);
        e.insert(0.75, false);
        assert_eq!(e.auc(), 1.0);
        e.check_invariants();
    }

    #[test]
    fn rebuild_reproduces_frozen_state_bit_for_bit() {
        // The hibernation contract at unit scale: replay the window
        // content through a fresh support core, rebuild C from the
        // stored finite keys, and every observable — auc bits, a2,
        // |C|, invariants — matches the live twin. Integration-scale
        // version (through the fleet API) lives in tests/differential.rs.
        for eps in [0.0, 0.05, 0.3] {
            check(0xF207 ^ (eps * 1e3) as u64, 6, |rng| {
                let grid = if rng.chance(0.5) { Some(4 + rng.below(10)) } else { None };
                let ops = gen_ops(rng, 300, 60, grid);
                let mut live = ApproxAuc::new(eps);
                let mut window: Vec<(f64, bool)> = Vec::new();
                for op in &ops {
                    match *op {
                        Op::Insert { score, pos } => {
                            live.insert(score, pos);
                            window.push((score, pos));
                        }
                        Op::Remove { score, pos } => {
                            live.remove(score, pos);
                            let at = window
                                .iter()
                                .position(|&(s, p)| s == score && p == pos)
                                .expect("removal of live entry");
                            window.remove(at);
                        }
                    }
                }
                // Freeze: the compact representation of the live core.
                let keys = live.core.compressed_keys(&live.ars);
                // Thaw into a fresh bundle: replay content, rebuild C.
                let mut ars = EstimatorArenas::default();
                let mut thawed = ApproxCore::new_in(&mut ars, eps);
                for &(score, pos) in &window {
                    let s = Score(crate::coordinator::canon(score));
                    if pos {
                        thawed.sup.add_pos(&mut ars, s);
                    } else {
                        thawed.sup.add_neg(&mut ars, s);
                    }
                }
                thawed.rebuild_in(&mut ars, &keys);
                thawed.check_invariants(&ars);
                assert_eq!(thawed.auc().to_bits(), live.auc().to_bits(), "auc bits");
                assert_eq!(thawed.doubled_area(), live.doubled_area(), "a2");
                assert_eq!(thawed.compressed_len(), live.compressed_len(), "|C|");
            });
        }
    }

    #[test]
    #[should_panic(expected = "finite")]
    fn rejects_nan_scores() {
        let mut e = ApproxAuc::new(0.1);
        e.insert(f64::NAN, true);
    }

    #[test]
    #[should_panic(expected = "non-negative")]
    fn rejects_negative_epsilon() {
        ApproxAuc::new(-0.5);
    }
}
