"""AOT lowering: JAX model → HLO *text* artifacts for the rust runtime.

Interchange format is HLO text, NOT a serialized ``HloModuleProto``:
jax ≥ 0.5 emits protos with 64-bit instruction ids that the pinned
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Usage::

    cd python && python -m compile.aot --out ../artifacts

writes ``score_batch.hlo.txt``, ``train_step.hlo.txt`` and ``meta.json``
(the shape contract the rust side validates against).
"""

from __future__ import annotations

import argparse
import json
import os

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (return_tuple so the rust
    side always unwraps a tuple)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_all():
    """Lower both entry points; returns {name: hlo_text}."""
    score_spec, train_spec = model.lowering_specs()
    return {
        "score_batch": to_hlo_text(jax.jit(model.score_batch).lower(*score_spec)),
        "train_step": to_hlo_text(jax.jit(model.train_step).lower(*train_spec)),
    }


def metadata() -> dict:
    """The shape contract shared with rust/src/runtime."""
    return {
        "dims": model.DIMS,
        "score_batch": {"batch": model.SCORE_BATCH, "inputs": ["w", "b", "x"], "outputs": ["scores"]},
        "train_step": {
            "batch": model.TRAIN_BATCH,
            "inputs": ["w", "b", "x", "y", "lr"],
            "outputs": ["w", "b", "loss"],
        },
        "score_convention": "larger score => more likely negative (paper §2)",
        "dtype": "f32",
    }


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out", default="../artifacts", help="output directory")
    args = parser.parse_args()
    os.makedirs(args.out, exist_ok=True)
    for name, text in lower_all().items():
        path = os.path.join(args.out, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path} ({len(text)} chars)")
    meta_path = os.path.join(args.out, "meta.json")
    with open(meta_path, "w") as f:
        json.dump(metadata(), f, indent=2)
    print(f"wrote {meta_path}")


if __name__ == "__main__":
    main()
