//! Serving quickstart: a fleet behind the wire, queried over both
//! protocols while it keeps ingesting.
//!
//! ```sh
//! cargo run --release --example serve
//! ```
//!
//! Starts a [`FleetServer`] on an ephemeral loopback port (the same
//! thing `streamauc fleet serve` does for a long-running process),
//! ingests bursty multi-stream traffic *through* the server, and hits
//! every endpoint both ways — HTTP/1.1 + JSON and the length-prefixed
//! binary protocol, sharing one port — checking each wire answer
//! against the in-process query it must be bit-identical to. A
//! subscriber rides along: it takes the full fleet-sketch baseline
//! once, then reconstructs the server's published state from the
//! per-drain deltas alone, verifying sequence numbers stay gapless.
//! Protocol details live in `rust/DESIGN.md` §Serving.

use streamauc::fleet::{AucFleet, EstimatorKind, FleetConfig, StreamConfig};
use streamauc::serve::{http_get, http_subscribe, json, wire, BinClient, FleetServer};
use streamauc::stream::MultiStream;

const STREAMS: u64 = 500;
const BATCH: usize = 2_048;
const ROUNDS: usize = 40;

fn main() {
    let defaults = StreamConfig {
        window: 200,
        estimator: EstimatorKind::Approx { epsilon: 0.1 },
        monitor: None,
    };
    let fleet = AucFleet::new(FleetConfig {
        shards: 32,
        workers: 4,
        pool: true,
        pipeline: false,
        adaptive: false,
        stream_defaults: defaults,
    });

    // Ephemeral port: the OS picks, `local_addr` reports.
    let server = FleetServer::start(fleet, "127.0.0.1:0").expect("bind loopback");
    let addr = server.local_addr();
    println!("serving fleet queries on http://{addr} (binary protocol on the same port)\n");

    // A subscriber connected *before* traffic sees the empty baseline
    // and then one delta per ingestion drain.
    let mut deltas = http_subscribe(addr).expect("subscribe");
    let baseline = deltas.next().expect("baseline line").expect("read baseline");
    let (mut seq, mut mirror) = json::sketch_from_json(&baseline).expect("decode baseline");

    // Ingest through the server so every drain publishes to the
    // subscriber while the query surface stays live.
    let mut gen = MultiStream::new(STREAMS as usize, 0x5E1F).with_mean_burst(8.0);
    for _ in 0..ROUNDS {
        server.ingest_batch(&gen.next_batch(BATCH));
        let line = deltas.next().expect("delta line").expect("read delta");
        let next = json::apply_subscription_json(&line, &mut mirror).expect("apply delta");
        assert_eq!(next, seq + 1, "subscription skipped a sequence number");
        seq = next;
    }
    let (published_seq, published) = server.last_published();
    assert_eq!(seq, published_seq, "mirror fell behind the server");
    assert_eq!(mirror, published, "delta-reconstructed sketch diverged");
    println!(
        "subscriber reconstructed the fleet sketch from {ROUNDS} deltas: \
         {} live streams, mean AUC {:.4} (seq {seq})\n",
        mirror.live,
        mirror.mean_auc()
    );

    // Every endpoint, over HTTP/JSON — decoded and checked against the
    // in-process answer.
    let (status, body) = http_get(addr, "/aggregate").expect("GET /aggregate");
    assert_eq!(status, 200);
    let agg = json::aggregate_from_json(&body).expect("decode aggregate");
    assert_eq!(agg, server.with_fleet(|f| f.aggregate()), "wire aggregate diverged");
    println!(
        "GET /aggregate        → {} streams, mean AUC {:.4}, median {:.4}",
        agg.streams, agg.mean_auc, agg.median_auc
    );

    let (_, body) = http_get(addr, "/snapshot").expect("GET /snapshot");
    let snap = json::snapshot_from_json(&body).expect("decode snapshot");
    println!(
        "GET /snapshot         → {} streams, {} total events",
        snap.streams.len(),
        snap.total_events
    );

    let (_, body) = http_get(addr, "/top_k_worst?k=3").expect("GET /top_k_worst");
    let worst = json::top_k_from_json(&body).expect("decode top-k");
    let ids: Vec<u64> = worst.iter().map(|s| s.stream).collect();
    println!("GET /top_k_worst?k=3  → worst streams {ids:?}");

    let (_, body) = http_get(addr, "/count_below?t=0.7").expect("GET /count_below");
    let (threshold, count) = json::count_below_from_json(&body).expect("decode count");
    println!("GET /count_below      → {count} streams below AUC {threshold}");

    let (_, body) = http_get(addr, "/auc_histogram?bins=10").expect("GET /auc_histogram");
    let hist = json::auc_histogram_from_json(&body).expect("decode histogram");
    println!("GET /auc_histogram    → {:?} ({} live)", hist.counts, hist.live_streams);

    let (_, body) = http_get(addr, "/score_histogram?bins=10").expect("GET /score_histogram");
    let scores = json::score_histogram_from_json(&body).expect("decode scores");
    println!("GET /score_histogram  → {:?} ({} entries)", scores.counts, scores.entries);

    // Malformed queries come back as errors, not panics.
    let (status, _) = http_get(addr, "/auc_histogram?bins=0").expect("GET bins=0");
    assert_eq!(status, 400, "zero bins must be a client error");
    let (status, _) = http_get(addr, "/count_below?t=nan").expect("GET t=nan");
    assert_eq!(status, 400, "a NaN threshold must be a client error");

    // The same queries over the binary protocol, bit-identical to HTTP.
    let mut bin = BinClient::connect(addr).expect("binary session");
    let (code, payload) = bin.request(wire::OP_AGGREGATE, &[]).expect("binary aggregate");
    assert_eq!(code, wire::STATUS_OK);
    assert_eq!(wire::decode_aggregate(&payload).expect("decode"), agg, "binary ≠ HTTP");
    let (code, payload) =
        bin.request(wire::OP_COUNT_BELOW, &0.7f64.to_bits().to_le_bytes()).expect("binary count");
    assert_eq!(code, wire::STATUS_OK);
    assert_eq!(wire::decode_count_below(&payload).expect("decode"), (0.7, count));
    println!("\nbinary protocol answers decode bit-identical to the HTTP/JSON ones.");
    println!("serving quickstart complete.");
}
