//! Admission control for the serving front-end: the resource limits a
//! [`FleetServer`](super::FleetServer) enforces, the bounded queue the
//! acceptor feeds and the connection workers drain (the park/claim
//! idiom of `fleet/pool.rs`, with a capacity so overload is *shed* at
//! the door instead of queueing unboundedly), the live-connection
//! tracker `shutdown` uses to unwedge blocked socket reads, and the
//! per-request deadline arithmetic.
//!
//! Nothing here knows about HTTP or the binary protocol — this module
//! decides *whether* and *for how long* a connection may hold a
//! worker; `super::server` decides what to say on it.

use std::collections::VecDeque;
use std::io;
use std::net::{Shutdown, TcpStream};
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// Resource limits of one [`FleetServer`](super::FleetServer).
///
/// Every socket the server touches gets `timeout` as its read *and*
/// write timeout, and every request gets `timeout` as its total
/// deadline budget once its first byte has arrived — so a half-open
/// connect, a slow-loris head, and a stuck subscriber each cost at
/// most one timeout before their worker (or writer) is released.
#[derive(Clone, Copy, Debug)]
pub struct ServeLimits {
    /// Connection workers — the maximum number of in-flight requests.
    pub workers: usize,
    /// Accepted-but-unclaimed connections the server will hold (and
    /// also the maximum number of attached subscribers). Beyond this
    /// the acceptor sheds with HTTP 503 / a `STATUS_BUSY` frame.
    pub max_conns: usize,
    /// Socket read/write timeout and per-request deadline budget.
    pub timeout: Duration,
}

impl Default for ServeLimits {
    fn default() -> ServeLimits {
        ServeLimits {
            workers: 4,
            max_conns: 64,
            timeout: Duration::from_millis(5000),
        }
    }
}

/// The bounded hand-off between the acceptor and the connection
/// workers. `offer` never blocks (the acceptor must keep accepting so
/// it can shed); `take` parks the calling worker on the condvar until
/// a connection or shutdown arrives.
pub(super) struct AcceptQueue {
    state: Mutex<QueueState>,
    ready: Condvar,
    cap: usize,
}

struct QueueState {
    conns: VecDeque<TcpStream>,
    open: bool,
}

impl AcceptQueue {
    pub(super) fn new(cap: usize) -> AcceptQueue {
        AcceptQueue {
            state: Mutex::new(QueueState { conns: VecDeque::new(), open: true }),
            ready: Condvar::new(),
            cap: cap.max(1),
        }
    }

    /// Enqueue an accepted connection, or hand it back when the queue
    /// is at capacity (the caller sheds it) or the server is stopping.
    pub(super) fn offer(&self, conn: TcpStream) -> Result<(), TcpStream> {
        let mut st = lock(&self.state);
        if !st.open || st.conns.len() >= self.cap {
            return Err(conn);
        }
        st.conns.push_back(conn);
        drop(st);
        self.ready.notify_one();
        Ok(())
    }

    /// Claim the next connection; parks until one arrives. `None`
    /// means the queue was closed — the worker should exit. Closing
    /// wins over queued connections (they are drained and dropped by
    /// [`AcceptQueue::close`], not half-served during shutdown).
    pub(super) fn take(&self) -> Option<TcpStream> {
        let mut st = lock(&self.state);
        loop {
            if !st.open {
                return None;
            }
            if let Some(conn) = st.conns.pop_front() {
                return Some(conn);
            }
            st = self.ready.wait(st).unwrap_or_else(std::sync::PoisonError::into_inner);
        }
    }

    /// Close the queue, wake every parked worker, and return whatever
    /// was still queued so the caller can drop (reset) it.
    pub(super) fn close(&self) -> VecDeque<TcpStream> {
        let mut st = lock(&self.state);
        st.open = false;
        let queued = std::mem::take(&mut st.conns);
        drop(st);
        self.ready.notify_all();
        queued
    }
}

/// Live-connection registry: every socket a worker or subscriber
/// writer is currently serving, as `try_clone`d control handles.
/// `shutdown_all` half-closes them, which makes any blocked
/// `read`/`write` on the real socket return immediately — that is what
/// bounds `FleetServer::shutdown`'s drain to "already in flight plus
/// one syscall" instead of one full socket timeout per connection.
#[derive(Default)]
pub(super) struct ConnTracker {
    slots: Mutex<Vec<Option<TcpStream>>>,
}

impl ConnTracker {
    /// Register a connection; returns the token for `deregister`.
    pub(super) fn register(&self, conn: &TcpStream) -> Option<usize> {
        let clone = conn.try_clone().ok()?;
        let mut slots = lock(&self.slots);
        if let Some(i) = slots.iter().position(Option::is_none) {
            slots[i] = Some(clone);
            return Some(i);
        }
        slots.push(Some(clone));
        Some(slots.len() - 1)
    }

    pub(super) fn deregister(&self, token: Option<usize>) {
        if let Some(i) = token {
            lock(&self.slots)[i] = None;
        }
    }

    /// Half-close every live connection (both directions); their
    /// owners' blocked socket ops error out and the owners exit.
    pub(super) fn shutdown_all(&self) {
        for conn in lock(&self.slots).iter().flatten() {
            let _ = conn.shutdown(Shutdown::Both);
        }
    }
}

/// A per-request deadline: started when the request's first byte
/// arrives, consulted before every subsequent socket read so a client
/// trickling one byte per timeout cannot extend a request forever.
pub(super) struct Deadline {
    end: Instant,
}

impl Deadline {
    pub(super) fn after(budget: Duration) -> Deadline {
        Deadline { end: Instant::now() + budget }
    }

    /// Time left, `None` once expired. Never returns `Some(0)` — a
    /// zero `set_read_timeout` means "no timeout" to the OS, the
    /// opposite of what an expired deadline wants.
    pub(super) fn remaining(&self) -> Option<Duration> {
        let rem = self.end.saturating_duration_since(Instant::now());
        if rem.is_zero() {
            None
        } else {
            Some(rem)
        }
    }
}

/// Did this I/O error come from a socket timeout? (`WouldBlock` on
/// unix, `TimedOut` on windows — std documents either for expired
/// read/write timeouts.)
pub(super) fn is_timeout(e: &io::Error) -> bool {
    matches!(e.kind(), io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut)
}

/// Is this the peer going away (or our own shutdown half-closing the
/// socket) rather than a programming error? Such connections are
/// closed quietly.
pub(super) fn is_disconnect(e: &io::Error) -> bool {
    matches!(
        e.kind(),
        io::ErrorKind::UnexpectedEof
            | io::ErrorKind::ConnectionReset
            | io::ErrorKind::ConnectionAborted
            | io::ErrorKind::BrokenPipe
            | io::ErrorKind::NotConnected
    )
}

/// Lock a mutex, ignoring poisoning: queue and tracker state are
/// plain data, safe to read after a panicking thread released them
/// (same policy as `fleet/pool.rs`).
fn lock<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::{TcpListener, TcpStream};

    fn pair() -> (TcpStream, TcpStream) {
        let l = TcpListener::bind("127.0.0.1:0").expect("bind");
        let a = TcpStream::connect(l.local_addr().expect("addr")).expect("connect");
        let (b, _) = l.accept().expect("accept");
        (a, b)
    }

    #[test]
    fn queue_sheds_at_capacity_and_drains_on_close() {
        let q = AcceptQueue::new(2);
        let (c1, _k1) = pair();
        let (c2, _k2) = pair();
        let (c3, _k3) = pair();
        assert!(q.offer(c1).is_ok());
        assert!(q.offer(c2).is_ok());
        assert!(q.offer(c3).is_err(), "third connection must be shed");
        let queued = q.close();
        assert_eq!(queued.len(), 2);
        assert!(q.take().is_none(), "closed queue releases workers");
        let (c4, _k4) = pair();
        assert!(q.offer(c4).is_err(), "closed queue refuses new connections");
    }

    #[test]
    fn tracker_reuses_slots_and_survives_deregister() {
        let t = ConnTracker::default();
        let (a, _ka) = pair();
        let (b, _kb) = pair();
        let ta = t.register(&a);
        t.deregister(ta);
        let tb = t.register(&b);
        assert_eq!(ta, tb, "freed slot is reused");
        t.deregister(None); // no-op
        t.shutdown_all();
    }

    #[test]
    fn deadline_expires_and_never_reports_zero() {
        let d = Deadline::after(Duration::from_millis(20));
        assert!(d.remaining().is_some());
        std::thread::sleep(Duration::from_millis(30));
        assert!(d.remaining().is_none());
    }
}
