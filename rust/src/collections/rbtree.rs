//! Arena-based augmented red-black tree (paper §3.1).
//!
//! The paper stores the sliding window in a red-black tree `T` sorted by
//! score, augmented with subtree label sums `accpos`/`accneg` that are
//! maintained through rotations “without additional costs”, and keeps a
//! second tree `TP` over the positive nodes for the `MaxPos` query (§3.2).
//!
//! Both trees are instances of [`RbTree`]: nodes live in a slab (`Vec` with
//! a free list), are addressed by [`NodeId`], and carry a user value `V`
//! plus an augmentation `A` recomputed locally from a node's value and its
//! children's augmentations. Rotations and the insert/delete fix-ups keep
//! the augmentation consistent, so subtree-sum queries such as
//! `HeadStats` (Algorithm 1) remain `O(log k)`.
//!
//! Augmentation-maintenance order (important for correctness):
//! 1. structural change (BST insert / transplant-delete);
//! 2. [`RbTree::update_upward`] from the deepest structurally changed node
//!    — after this the whole path to the root is consistent;
//! 3. rebalancing fix-up — each rotation recomputes exactly the two
//!    rotated nodes from their (already consistent) children, and
//!    recolourings never touch the augmentation.

use super::score::Score;

/// Handle to a tree node. Stable for the node's lifetime; slots are
/// recycled after removal, so holders must not use a handle past `remove`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct NodeId(pub u32);

const NIL: u32 = u32::MAX;

impl NodeId {
    #[inline]
    fn idx(self) -> usize {
        self.0 as usize
    }
}

/// Subtree augmentation: recomputed locally from the node value and the
/// children's augmentations whenever the subtree under a node changes.
pub trait Augment<V>: Clone {
    /// Value of the augmentation for a node with value `val` whose children
    /// carry `left` / `right` (absent child ⇒ `None`).
    fn recompute(val: &V, left: Option<&Self>, right: Option<&Self>) -> Self;
}

/// No augmentation (used by the positive-index tree `TP`).
impl<V> Augment<V> for () {
    #[inline]
    fn recompute(_: &V, _: Option<&Self>, _: Option<&Self>) -> Self {}
}

#[derive(Clone, Debug)]
struct Node<V, A> {
    key: Score,
    val: V,
    aug: A,
    left: u32,
    right: u32,
    parent: u32,
    red: bool,
}

/// Augmented red-black tree keyed by [`Score`].
///
/// Duplicate keys are rejected by [`RbTree::insert`] (it returns the
/// existing node), matching the paper where one tree node aggregates every
/// window entry sharing a score.
#[derive(Clone, Debug)]
pub struct RbTree<V, A> {
    nodes: Vec<Node<V, A>>,
    free: Vec<u32>,
    root: u32,
    len: usize,
}

impl<V, A: Augment<V>> Default for RbTree<V, A> {
    fn default() -> Self {
        Self::new()
    }
}

impl<V, A: Augment<V>> RbTree<V, A> {
    /// Empty tree.
    pub fn new() -> Self {
        RbTree { nodes: Vec::new(), free: Vec::new(), root: NIL, len: 0 }
    }

    /// Empty tree with room for `cap` nodes before reallocating.
    pub fn with_capacity(cap: usize) -> Self {
        RbTree { nodes: Vec::with_capacity(cap), free: Vec::new(), root: NIL, len: 0 }
    }

    /// Number of live nodes.
    #[inline]
    pub fn len(&self) -> usize {
        self.len
    }

    /// True when the tree holds no nodes.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Root node, if any.
    #[inline]
    pub fn root(&self) -> Option<NodeId> {
        wrap(self.root)
    }

    #[inline]
    fn node(&self, id: NodeId) -> &Node<V, A> {
        &self.nodes[id.idx()]
    }

    #[inline]
    fn node_mut(&mut self, id: NodeId) -> &mut Node<V, A> {
        &mut self.nodes[id.idx()]
    }

    /// Key (score) of a node.
    #[inline]
    pub fn key(&self, id: NodeId) -> Score {
        self.node(id).key
    }

    /// Value of a node.
    #[inline]
    pub fn val(&self, id: NodeId) -> &V {
        &self.node(id).val
    }

    /// Augmentation of a node (the subtree summary).
    #[inline]
    pub fn aug(&self, id: NodeId) -> &A {
        &self.node(id).aug
    }

    /// Left child.
    #[inline]
    pub fn left(&self, id: NodeId) -> Option<NodeId> {
        wrap(self.node(id).left)
    }

    /// Right child.
    #[inline]
    pub fn right(&self, id: NodeId) -> Option<NodeId> {
        wrap(self.node(id).right)
    }

    /// Parent node.
    #[inline]
    pub fn parent(&self, id: NodeId) -> Option<NodeId> {
        wrap(self.node(id).parent)
    }

    /// Mutate a node's value, then restore the augmentation along the path
    /// to the root (`O(log k)`, paper §3.3 “update the accpos counters …
    /// only for the ancestors”).
    pub fn with_val_mut<R>(&mut self, id: NodeId, f: impl FnOnce(&mut V) -> R) -> R {
        let r = f(&mut self.node_mut(id.into()).val);
        self.update_upward(id);
        r
    }

    /// Recompute augmentations from `id` up to the root.
    pub fn update_upward(&mut self, id: NodeId) {
        let mut cur = id.0;
        while cur != NIL {
            self.recompute_aug(cur);
            cur = self.nodes[cur as usize].parent;
        }
    }

    fn recompute_aug(&mut self, i: u32) {
        let (l, r) = {
            let n = &self.nodes[i as usize];
            (n.left, n.right)
        };
        let la = if l == NIL { None } else { Some(&self.nodes[l as usize].aug) };
        let ra = if r == NIL { None } else { Some(&self.nodes[r as usize].aug) };
        let aug = A::recompute(&self.nodes[i as usize].val, la, ra);
        self.nodes[i as usize].aug = aug;
    }

    /// Find the node with exactly this key.
    pub fn find(&self, key: Score) -> Option<NodeId> {
        let mut cur = self.root;
        while cur != NIL {
            let n = &self.nodes[cur as usize];
            cur = match key.cmp(&n.key) {
                std::cmp::Ordering::Less => n.left,
                std::cmp::Ordering::Greater => n.right,
                std::cmp::Ordering::Equal => return Some(NodeId(cur)),
            };
        }
        None
    }

    /// Largest node with key `≤ key` (the shape of `MaxPos`, paper §3.2).
    pub fn floor(&self, key: Score) -> Option<NodeId> {
        let mut cur = self.root;
        let mut best = NIL;
        while cur != NIL {
            let n = &self.nodes[cur as usize];
            if n.key <= key {
                best = cur;
                cur = n.right;
            } else {
                cur = n.left;
            }
        }
        wrap(best)
    }

    /// Smallest node with key `≥ key`.
    pub fn ceil(&self, key: Score) -> Option<NodeId> {
        let mut cur = self.root;
        let mut best = NIL;
        while cur != NIL {
            let n = &self.nodes[cur as usize];
            if n.key >= key {
                best = cur;
                cur = n.left;
            } else {
                cur = n.right;
            }
        }
        wrap(best)
    }

    /// Node with the smallest key.
    pub fn first(&self) -> Option<NodeId> {
        if self.root == NIL {
            return None;
        }
        Some(NodeId(self.min_of(self.root)))
    }

    /// Node with the largest key.
    pub fn last(&self) -> Option<NodeId> {
        if self.root == NIL {
            return None;
        }
        Some(NodeId(self.max_of(self.root)))
    }

    fn min_of(&self, mut i: u32) -> u32 {
        while self.nodes[i as usize].left != NIL {
            i = self.nodes[i as usize].left;
        }
        i
    }

    fn max_of(&self, mut i: u32) -> u32 {
        while self.nodes[i as usize].right != NIL {
            i = self.nodes[i as usize].right;
        }
        i
    }

    /// In-order successor.
    pub fn successor(&self, id: NodeId) -> Option<NodeId> {
        let mut i = id.0;
        if self.nodes[i as usize].right != NIL {
            return Some(NodeId(self.min_of(self.nodes[i as usize].right)));
        }
        let mut p = self.nodes[i as usize].parent;
        while p != NIL && self.nodes[p as usize].right == i {
            i = p;
            p = self.nodes[p as usize].parent;
        }
        wrap(p)
    }

    /// In-order predecessor.
    pub fn predecessor(&self, id: NodeId) -> Option<NodeId> {
        let mut i = id.0;
        if self.nodes[i as usize].left != NIL {
            return Some(NodeId(self.max_of(self.nodes[i as usize].left)));
        }
        let mut p = self.nodes[i as usize].parent;
        while p != NIL && self.nodes[p as usize].left == i {
            i = p;
            p = self.nodes[p as usize].parent;
        }
        wrap(p)
    }

    /// In-order iteration over node ids (ascending key).
    pub fn iter(&self) -> InOrder<'_, V, A> {
        InOrder { tree: self, next: self.first() }
    }

    /// Insert `key`, creating the node with `make()` if absent.
    ///
    /// Returns the node and whether it was newly created. On creation the
    /// augmentation path to the root is restored.
    pub fn insert(&mut self, key: Score, make: impl FnOnce() -> V) -> (NodeId, bool) {
        let mut parent = NIL;
        let mut cur = self.root;
        let mut went_left = false;
        while cur != NIL {
            parent = cur;
            let n = &self.nodes[cur as usize];
            match key.cmp(&n.key) {
                std::cmp::Ordering::Less => {
                    cur = n.left;
                    went_left = true;
                }
                std::cmp::Ordering::Greater => {
                    cur = n.right;
                    went_left = false;
                }
                std::cmp::Ordering::Equal => return (NodeId(cur), false),
            }
        }
        let val = make();
        let aug = A::recompute(&val, None, None);
        let node = Node { key, val, aug, left: NIL, right: NIL, parent, red: true };
        let id = match self.free.pop() {
            Some(slot) => {
                self.nodes[slot as usize] = node;
                slot
            }
            None => {
                self.nodes.push(node);
                (self.nodes.len() - 1) as u32
            }
        };
        if parent == NIL {
            self.root = id;
        } else if went_left {
            self.nodes[parent as usize].left = id;
        } else {
            self.nodes[parent as usize].right = id;
        }
        self.len += 1;
        if parent != NIL {
            self.update_upward(NodeId(parent));
        }
        self.insert_fixup(id);
        (NodeId(id), true)
    }

    /// Remove a node. The handle (and any copies) become invalid; the slot
    /// may be recycled by a later insert.
    pub fn remove(&mut self, id: NodeId) {
        let z = id.0;
        debug_assert!(self.is_live(id), "remove of dead node");
        let (zl, zr) = (self.nodes[z as usize].left, self.nodes[z as usize].right);
        // y: node physically unlinked or moved; x: subtree replacing y's
        // old position (possibly NIL); xp: x's parent after the transplant.
        let y_red;
        let x;
        let xp;
        if zl == NIL {
            y_red = self.nodes[z as usize].red;
            x = zr;
            xp = self.nodes[z as usize].parent;
            self.transplant(z, zr);
        } else if zr == NIL {
            y_red = self.nodes[z as usize].red;
            x = zl;
            xp = self.nodes[z as usize].parent;
            self.transplant(z, zl);
        } else {
            let y = self.min_of(zr);
            y_red = self.nodes[y as usize].red;
            x = self.nodes[y as usize].right;
            if self.nodes[y as usize].parent == z {
                xp = y;
            } else {
                xp = self.nodes[y as usize].parent;
                self.transplant(y, x);
                let zr_now = self.nodes[z as usize].right;
                self.nodes[y as usize].right = zr_now;
                self.nodes[zr_now as usize].parent = y;
            }
            self.transplant(z, y);
            let zl_now = self.nodes[z as usize].left;
            self.nodes[y as usize].left = zl_now;
            self.nodes[zl_now as usize].parent = y;
            self.nodes[y as usize].red = self.nodes[z as usize].red;
        }
        // Restore augmentation along the whole changed path before any
        // rebalancing rotations (they recompute locally from children).
        if xp != NIL {
            self.update_upward(NodeId(xp));
        }
        if !y_red {
            self.delete_fixup(x, xp);
        }
        // Retire the slot.
        self.free.push(z);
        self.len -= 1;
        // Poison links in debug builds to catch stale handles.
        if cfg!(debug_assertions) {
            let n = &mut self.nodes[z as usize];
            n.left = NIL;
            n.right = NIL;
            n.parent = NIL;
        }
    }

    /// True if `id` currently addresses a live node (test/debug helper; it
    /// is linear in the free list).
    pub fn is_live(&self, id: NodeId) -> bool {
        id.idx() < self.nodes.len() && !self.free.contains(&id.0)
    }

    fn transplant(&mut self, u: u32, v: u32) {
        let p = self.nodes[u as usize].parent;
        if p == NIL {
            self.root = v;
        } else if self.nodes[p as usize].left == u {
            self.nodes[p as usize].left = v;
        } else {
            self.nodes[p as usize].right = v;
        }
        if v != NIL {
            self.nodes[v as usize].parent = p;
        }
    }

    /// Left rotation around `x`; recomputes the augmentation of exactly the
    /// two rotated nodes (paper §3.3: counters are maintainable during
    /// rotations without additional cost).
    fn rotate_left(&mut self, x: u32) {
        let y = self.nodes[x as usize].right;
        debug_assert_ne!(y, NIL);
        let yl = self.nodes[y as usize].left;
        self.nodes[x as usize].right = yl;
        if yl != NIL {
            self.nodes[yl as usize].parent = x;
        }
        let xp = self.nodes[x as usize].parent;
        self.nodes[y as usize].parent = xp;
        if xp == NIL {
            self.root = y;
        } else if self.nodes[xp as usize].left == x {
            self.nodes[xp as usize].left = y;
        } else {
            self.nodes[xp as usize].right = y;
        }
        self.nodes[y as usize].left = x;
        self.nodes[x as usize].parent = y;
        self.recompute_aug(x);
        self.recompute_aug(y);
    }

    fn rotate_right(&mut self, x: u32) {
        let y = self.nodes[x as usize].left;
        debug_assert_ne!(y, NIL);
        let yr = self.nodes[y as usize].right;
        self.nodes[x as usize].left = yr;
        if yr != NIL {
            self.nodes[yr as usize].parent = x;
        }
        let xp = self.nodes[x as usize].parent;
        self.nodes[y as usize].parent = xp;
        if xp == NIL {
            self.root = y;
        } else if self.nodes[xp as usize].left == x {
            self.nodes[xp as usize].left = y;
        } else {
            self.nodes[xp as usize].right = y;
        }
        self.nodes[y as usize].right = x;
        self.nodes[x as usize].parent = y;
        self.recompute_aug(x);
        self.recompute_aug(y);
    }

    fn insert_fixup(&mut self, mut z: u32) {
        while {
            let p = self.nodes[z as usize].parent;
            p != NIL && self.nodes[p as usize].red
        } {
            let p = self.nodes[z as usize].parent;
            let g = self.nodes[p as usize].parent;
            debug_assert_ne!(g, NIL, "red root");
            if self.nodes[g as usize].left == p {
                let u = self.nodes[g as usize].right;
                if u != NIL && self.nodes[u as usize].red {
                    self.nodes[p as usize].red = false;
                    self.nodes[u as usize].red = false;
                    self.nodes[g as usize].red = true;
                    z = g;
                } else {
                    if self.nodes[p as usize].right == z {
                        z = p;
                        self.rotate_left(z);
                    }
                    let p = self.nodes[z as usize].parent;
                    let g = self.nodes[p as usize].parent;
                    self.nodes[p as usize].red = false;
                    self.nodes[g as usize].red = true;
                    self.rotate_right(g);
                }
            } else {
                let u = self.nodes[g as usize].left;
                if u != NIL && self.nodes[u as usize].red {
                    self.nodes[p as usize].red = false;
                    self.nodes[u as usize].red = false;
                    self.nodes[g as usize].red = true;
                    z = g;
                } else {
                    if self.nodes[p as usize].left == z {
                        z = p;
                        self.rotate_right(z);
                    }
                    let p = self.nodes[z as usize].parent;
                    let g = self.nodes[p as usize].parent;
                    self.nodes[p as usize].red = false;
                    self.nodes[g as usize].red = true;
                    self.rotate_left(g);
                }
            }
        }
        let r = self.root;
        self.nodes[r as usize].red = false;
    }

    /// CLRS delete-fixup adapted to arena form: `x` may be NIL, so its
    /// parent is tracked explicitly in `xp`.
    fn delete_fixup(&mut self, mut x: u32, mut xp: u32) {
        while x != self.root && (x == NIL || !self.nodes[x as usize].red) {
            if xp == NIL {
                break; // tree became empty
            }
            if self.nodes[xp as usize].left == x {
                let mut w = self.nodes[xp as usize].right;
                if w != NIL && self.nodes[w as usize].red {
                    self.nodes[w as usize].red = false;
                    self.nodes[xp as usize].red = true;
                    self.rotate_left(xp);
                    w = self.nodes[xp as usize].right;
                }
                if w == NIL {
                    x = xp;
                    xp = self.nodes[x as usize].parent;
                    continue;
                }
                let wl = self.nodes[w as usize].left;
                let wr = self.nodes[w as usize].right;
                let wl_red = wl != NIL && self.nodes[wl as usize].red;
                let wr_red = wr != NIL && self.nodes[wr as usize].red;
                if !wl_red && !wr_red {
                    self.nodes[w as usize].red = true;
                    x = xp;
                    xp = self.nodes[x as usize].parent;
                } else {
                    if !wr_red {
                        if wl != NIL {
                            self.nodes[wl as usize].red = false;
                        }
                        self.nodes[w as usize].red = true;
                        self.rotate_right(w);
                        w = self.nodes[xp as usize].right;
                    }
                    self.nodes[w as usize].red = self.nodes[xp as usize].red;
                    self.nodes[xp as usize].red = false;
                    let wr = self.nodes[w as usize].right;
                    if wr != NIL {
                        self.nodes[wr as usize].red = false;
                    }
                    self.rotate_left(xp);
                    x = self.root;
                    xp = NIL;
                }
            } else {
                let mut w = self.nodes[xp as usize].left;
                if w != NIL && self.nodes[w as usize].red {
                    self.nodes[w as usize].red = false;
                    self.nodes[xp as usize].red = true;
                    self.rotate_right(xp);
                    w = self.nodes[xp as usize].left;
                }
                if w == NIL {
                    x = xp;
                    xp = self.nodes[x as usize].parent;
                    continue;
                }
                let wl = self.nodes[w as usize].left;
                let wr = self.nodes[w as usize].right;
                let wl_red = wl != NIL && self.nodes[wl as usize].red;
                let wr_red = wr != NIL && self.nodes[wr as usize].red;
                if !wl_red && !wr_red {
                    self.nodes[w as usize].red = true;
                    x = xp;
                    xp = self.nodes[x as usize].parent;
                } else {
                    if !wl_red {
                        if wr != NIL {
                            self.nodes[wr as usize].red = false;
                        }
                        self.nodes[w as usize].red = true;
                        self.rotate_left(w);
                        w = self.nodes[xp as usize].left;
                    }
                    self.nodes[w as usize].red = self.nodes[xp as usize].red;
                    self.nodes[xp as usize].red = false;
                    let wl = self.nodes[w as usize].left;
                    if wl != NIL {
                        self.nodes[wl as usize].red = false;
                    }
                    self.rotate_right(xp);
                    x = self.root;
                    xp = NIL;
                }
            }
        }
        if x != NIL {
            self.nodes[x as usize].red = false;
        }
    }

    /// Validate every red-black + BST + augmentation invariant. Test and
    /// property-test helper; panics with a description on violation.
    pub fn check_invariants(&self)
    where
        A: PartialEq + std::fmt::Debug,
    {
        if self.root == NIL {
            assert_eq!(self.len, 0, "len ≠ 0 for empty tree");
            return;
        }
        assert!(!self.nodes[self.root as usize].red, "red root");
        assert_eq!(self.nodes[self.root as usize].parent, NIL, "root has parent");
        let (count, _) = self.check_node(self.root);
        assert_eq!(count, self.len, "len mismatch");
        // Keys strictly increasing in order.
        let mut prev: Option<Score> = None;
        for id in self.iter() {
            if let Some(p) = prev {
                assert!(p < self.key(id), "in-order keys not strictly increasing");
            }
            prev = Some(self.key(id));
        }
    }

    /// Returns (node count, black height) of subtree `i`, checking
    /// red-black, parent-pointer and augmentation invariants.
    fn check_node(&self, i: u32) -> (usize, usize)
    where
        A: PartialEq + std::fmt::Debug,
    {
        let n = &self.nodes[i as usize];
        for c in [n.left, n.right] {
            if c != NIL {
                assert_eq!(self.nodes[c as usize].parent, i, "broken parent pointer");
                if n.red {
                    assert!(!self.nodes[c as usize].red, "red node with red child");
                }
            }
        }
        let (lc, lb) = if n.left != NIL { self.check_node(n.left) } else { (0, 1) };
        let (rc, rb) = if n.right != NIL { self.check_node(n.right) } else { (0, 1) };
        assert_eq!(lb, rb, "black height mismatch");
        let la = if n.left == NIL { None } else { Some(&self.nodes[n.left as usize].aug) };
        let ra = if n.right == NIL { None } else { Some(&self.nodes[n.right as usize].aug) };
        let expect = A::recompute(&n.val, la, ra);
        assert_eq!(n.aug, expect, "stale augmentation at node {i}");
        (lc + rc + 1, lb + usize::from(!n.red))
    }
}

// The arena is plain owned data (a `Vec` of nodes addressed by index —
// no `Rc`, no interior mutability), so a tree is `Send` whenever its
// value and augmentation types are. The fleet's scoped-thread executor
// relies on this to move whole per-stream estimators across workers;
// keep it provable at compile time.
const _: () = {
    const fn assert_send<T: Send>() {}
    assert_send::<RbTree<u64, ()>>();
};

#[inline]
fn wrap(i: u32) -> Option<NodeId> {
    if i == NIL {
        None
    } else {
        Some(NodeId(i))
    }
}

/// Ascending in-order iterator over node ids.
pub struct InOrder<'a, V, A> {
    tree: &'a RbTree<V, A>,
    next: Option<NodeId>,
}

impl<V, A: Augment<V>> Iterator for InOrder<'_, V, A> {
    type Item = NodeId;

    fn next(&mut self) -> Option<NodeId> {
        let cur = self.next?;
        self.next = self.tree.successor(cur);
        Some(cur)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::Pcg;

    /// Subtree size augmentation for tests (counts nodes).
    #[derive(Clone, Copy, Debug, PartialEq)]
    struct Size(usize);

    impl Augment<u64> for Size {
        fn recompute(_v: &u64, l: Option<&Self>, r: Option<&Self>) -> Self {
            Size(1 + l.map_or(0, |s| s.0) + r.map_or(0, |s| s.0))
        }
    }

    /// Sum-of-values augmentation (models accpos).
    #[derive(Clone, Copy, Debug, PartialEq)]
    struct Sum(u64);

    impl Augment<u64> for Sum {
        fn recompute(v: &u64, l: Option<&Self>, r: Option<&Self>) -> Self {
            Sum(v + l.map_or(0, |s| s.0) + r.map_or(0, |s| s.0))
        }
    }

    fn tree_from(keys: &[f64]) -> RbTree<u64, Size> {
        let mut t = RbTree::new();
        for &k in keys {
            t.insert(Score(k), || 0);
        }
        t
    }

    #[test]
    fn empty_tree() {
        let t: RbTree<u64, Size> = RbTree::new();
        assert!(t.is_empty());
        assert_eq!(t.first(), None);
        assert_eq!(t.last(), None);
        assert_eq!(t.find(Score(1.0)), None);
        assert_eq!(t.floor(Score(1.0)), None);
        assert_eq!(t.ceil(Score(1.0)), None);
        t.check_invariants();
    }

    #[test]
    fn insert_ascending_descending() {
        for order in [true, false] {
            let mut keys: Vec<f64> = (0..200).map(f64::from).collect();
            if !order {
                keys.reverse();
            }
            let t = tree_from(&keys);
            assert_eq!(t.len(), 200);
            t.check_invariants();
            let got: Vec<f64> = t.iter().map(|id| t.key(id).0).collect();
            let mut want = keys.clone();
            want.sort_by(f64::total_cmp);
            assert_eq!(got, want);
        }
    }

    #[test]
    fn insert_duplicate_returns_existing() {
        let mut t: RbTree<u64, Size> = RbTree::new();
        let (a, fresh_a) = t.insert(Score(5.0), || 7);
        let (b, fresh_b) = t.insert(Score(5.0), || panic!("must not be called"));
        assert!(fresh_a && !fresh_b);
        assert_eq!(a, b);
        assert_eq!(*t.val(a), 7);
        assert_eq!(t.len(), 1);
    }

    #[test]
    fn floor_ceil_find() {
        let t = tree_from(&[1.0, 3.0, 5.0, 7.0]);
        let key = |id: Option<NodeId>| id.map(|i| t.key(i).0);
        assert_eq!(key(t.floor(Score(0.0))), None);
        assert_eq!(key(t.floor(Score(1.0))), Some(1.0));
        assert_eq!(key(t.floor(Score(4.0))), Some(3.0));
        assert_eq!(key(t.floor(Score(9.0))), Some(7.0));
        assert_eq!(key(t.ceil(Score(0.0))), Some(1.0));
        assert_eq!(key(t.ceil(Score(5.5))), Some(7.0));
        assert_eq!(key(t.ceil(Score(8.0))), None);
        assert_eq!(key(t.find(Score(3.0))), Some(3.0));
        assert_eq!(t.find(Score(4.0)), None);
    }

    #[test]
    fn successor_predecessor_chain() {
        let t = tree_from(&[2.0, 4.0, 6.0, 8.0, 10.0]);
        let mut cur = t.first();
        let mut seen = Vec::new();
        while let Some(id) = cur {
            seen.push(t.key(id).0);
            cur = t.successor(id);
        }
        assert_eq!(seen, vec![2.0, 4.0, 6.0, 8.0, 10.0]);
        let mut cur = t.last();
        seen.clear();
        while let Some(id) = cur {
            seen.push(t.key(id).0);
            cur = t.predecessor(id);
        }
        assert_eq!(seen, vec![10.0, 8.0, 6.0, 4.0, 2.0]);
    }

    #[test]
    fn remove_all_orders() {
        // Remove in insertion, reverse, and middle-out orders.
        let keys: Vec<f64> = (0..64).map(f64::from).collect();
        for variant in 0..3 {
            let mut t = tree_from(&keys);
            let mut order: Vec<f64> = keys.clone();
            match variant {
                0 => {}
                1 => order.reverse(),
                _ => order.sort_by(|a, b| {
                    (a - 32.0).abs().partial_cmp(&(b - 32.0).abs()).unwrap()
                }),
            }
            for (i, k) in order.iter().enumerate() {
                let id = t.find(Score(*k)).expect("present");
                t.remove(id);
                t.check_invariants();
                assert_eq!(t.len(), keys.len() - i - 1);
            }
            assert!(t.is_empty());
        }
    }

    #[test]
    fn value_mutation_restores_augmentation() {
        let mut t: RbTree<u64, Sum> = RbTree::new();
        let mut ids = Vec::new();
        for k in 0..100 {
            let (id, _) = t.insert(Score(f64::from(k)), || 1);
            ids.push(id);
        }
        t.with_val_mut(ids[42], |v| *v = 100);
        let root = t.root().unwrap();
        assert_eq!(t.aug(root).0, 100 + 99);
        t.check_invariants();
    }

    #[test]
    fn slot_recycling() {
        let mut t = tree_from(&[1.0, 2.0, 3.0]);
        let id = t.find(Score(2.0)).unwrap();
        t.remove(id);
        let (nid, fresh) = t.insert(Score(4.0), || 0);
        assert!(fresh);
        // Slot of the removed node is reused.
        assert_eq!(nid.0, id.0);
        t.check_invariants();
    }

    /// Randomized stress: mirror a `BTreeMap`, checking invariants and
    /// queries after every operation.
    #[test]
    fn stress_against_btreemap() {
        use std::collections::BTreeMap;
        let mut rng = Pcg::seed(0xA0C_2019);
        let mut t: RbTree<u64, Sum> = RbTree::new();
        let mut model: BTreeMap<i64, u64> = BTreeMap::new();
        for step in 0..4000 {
            let key = i64::from(rng.below(64) as u32) - 32;
            let ks = Score(key as f64);
            match rng.below(4) {
                0 | 1 => {
                    let v = rng.below(10);
                    let (id, fresh) = t.insert(ks, || v);
                    if !fresh {
                        t.with_val_mut(id, |old| *old = v);
                    }
                    model.insert(key, v);
                }
                2 => {
                    if let Some(id) = t.find(ks) {
                        t.remove(id);
                        model.remove(&key);
                    }
                }
                _ => {
                    // floor query must agree with the model
                    let got = t.floor(ks).map(|id| t.key(id).0 as i64);
                    let want = model.range(..=key).next_back().map(|(k, _)| *k);
                    assert_eq!(got, want, "floor({key}) disagrees at step {step}");
                }
            }
            if step % 64 == 0 {
                t.check_invariants();
                assert_eq!(t.len(), model.len());
                let total: u64 = model.values().sum();
                let got = t.root().map_or(0, |r| t.aug(r).0);
                assert_eq!(got, total, "sum augmentation diverged at step {step}");
            }
        }
        // Drain fully.
        let keys: Vec<i64> = model.keys().copied().collect();
        for k in keys {
            let id = t.find(Score(k as f64)).unwrap();
            t.remove(id);
        }
        assert!(t.is_empty());
        t.check_invariants();
    }
}
