//! The reader/writer split of the serving layer: an epoch-swapped
//! [`PublishedView`] that sketch-answerable read endpoints serve from
//! with zero fleet-lock acquisitions, and the subscriber fan-out that
//! cannot stall the publisher.
//!
//! **The epoch invariant.** Every fleet mutation the server performs
//! (`ingest_batch`, `ingest_batch_at`, `with_fleet_mut`) calls
//! [`Fanout::republish`] *while still holding the fleet lock*, and the
//! republish swaps in a fresh view before the lock is released. So
//! whoever holds the fleet lock knows the current view's epoch is
//! exactly the fleet's state — which is what makes first-reader
//! materialization sound: the first reader of an epoch takes the fleet
//! lock once, re-checks the (necessarily same-epoch) current view, and
//! swaps in a filled twin — same seq, `snapshot`/`aggregate` read
//! under that lock. Every later reader of the epoch is lock-free. A
//! quiet epoch costs nothing.
//!
//! **The sequence number.** `seq` counts sketch *publications*: it
//! bumps exactly when the merged [`FleetSketch`] changes, and each
//! bump broadcasts exactly one delta — the gapless-subscription
//! contract. A mutation that leaves the sketch unchanged but may have
//! moved snapshot-level state (hibernation changing footprints, a
//! batch that left every estimate in place) swaps a fresh
//! *unmaterialized* view at the same seq, so stale derived state is
//! never served.
//!
//! **Fan-out.** Each subscriber owns a bounded queue drained by a
//! dedicated writer thread; the publisher only ever `try_send`s. A
//! full queue marks the subscriber *lagged* and drops the delta; its
//! writer then discards the stale queue and resyncs with a `lagged`
//! notice plus a fresh baseline — coalescing however many deltas were
//! missed into one line. A vanished subscriber is pruned at the next
//! publish. Either way `ingest_batch` never blocks on a socket.

use std::io::{self, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{Receiver, RecvTimeoutError, SyncSender, TrySendError};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::{self, JoinHandle};
use std::time::Duration;

use super::limits::ConnTracker;
use super::{json, wire};
use crate::fleet::{
    worst_first, AucFleet, AucHistogram, FleetAggregate, FleetSketch, FleetSnapshot,
    StreamSnapshot,
};

/// Outbound messages a subscriber writer may hold, queued per
/// subscriber. Capacity is small on purpose: a subscriber that cannot
/// keep up with ~a handful of drains is better resynced with one fresh
/// baseline than fed an ever-growing backlog.
const SUB_QUEUE: usize = 32;

/// How often an idle writer wakes to check the stop flag and the lag
/// mark.
const WRITER_TICK: Duration = Duration::from_millis(100);

// ---------------------------------------------------------------------
// Published views
// ---------------------------------------------------------------------

/// One publication epoch of the fleet: the merged sketch at that
/// epoch, its sequence number, and — once the epoch has its first
/// reader — the materialized query-answerable state
/// ([`FleetSnapshot`] + [`FleetAggregate`]). Views are immutable;
/// materialization swaps the *current* view for a filled twin at the
/// same epoch (see [`Fanout::materialized_view`]), so no lazy cell or
/// interior mutability is needed and a quiet epoch costs nothing.
///
/// The query methods answer **bit-identically** to the corresponding
/// `AucFleet` calls at the same epoch:
/// * `snapshot`/`aggregate` *are* the fleet's answers, captured under
///   the fleet lock at materialization;
/// * `top_k_worst` ranks the snapshot's live streams by the same
///   [`worst_first`] total order the fleet's candidate-bin merge uses
///   (a total order, so ranking all live streams or only the
///   candidate bins yields the same first `k`);
/// * `count_below` is the retained rescan the fleet's sketch-backed
///   count is proven equal to (`fleet/query.rs`'s differential test);
/// * `auc_histogram` bins the snapshot's live estimates with the exact
///   product `⌊auc · bins⌋` — the shard fallback's formula, and for
///   divisor bin counts also bit-identical to the sketch group-sum
///   (both partitions use exact f64 products).
///
/// `rust/tests/serve.rs` asserts all four against the fleet directly.
pub struct PublishedView {
    seq: u64,
    sketch: FleetSketch,
    derived: Option<Derived>,
}

struct Derived {
    snapshot: FleetSnapshot,
    aggregate: FleetAggregate,
}

impl PublishedView {
    fn new(seq: u64, sketch: FleetSketch) -> PublishedView {
        PublishedView { seq, sketch, derived: None }
    }

    /// The publication sequence number echoed in every wire response.
    pub fn seq(&self) -> u64 {
        self.seq
    }

    /// The merged fleet sketch at this epoch.
    pub fn sketch(&self) -> &FleetSketch {
        &self.sketch
    }

    fn is_materialized(&self) -> bool {
        self.derived.is_some()
    }

    /// A filled twin of this view at the same epoch, with derived
    /// state read from `fleet`. Sound only under the epoch invariant:
    /// the caller holds the fleet lock and `self` is the current view,
    /// so `fleet`'s state *is* this epoch.
    fn materialized(&self, fleet: &AucFleet) -> PublishedView {
        PublishedView {
            seq: self.seq,
            sketch: self.sketch.clone(),
            derived: Some(Derived { snapshot: fleet.snapshot(), aggregate: fleet.aggregate() }),
        }
    }

    fn derived(&self) -> &Derived {
        self.derived.as_ref().expect("published view read before materialization")
    }

    /// The fleet snapshot at this epoch.
    ///
    /// # Panics
    ///
    /// Panics if the view has not been materialized — views handed out
    /// by the server ([`FleetServer::published_view`]
    /// (super::FleetServer::published_view) and the read endpoints)
    /// always are.
    pub fn snapshot(&self) -> &FleetSnapshot {
        &self.derived().snapshot
    }

    /// The fleet aggregate at this epoch. Panics like
    /// [`PublishedView::snapshot`] on an unmaterialized view.
    pub fn aggregate(&self) -> &FleetAggregate {
        &self.derived().aggregate
    }

    /// The `k` worst live streams, [`worst_first`]-ordered — equal to
    /// `AucFleet::top_k_worst(k)` at this epoch.
    pub fn top_k_worst(&self, k: usize) -> Vec<StreamSnapshot> {
        let mut live: Vec<&StreamSnapshot> =
            self.snapshot().streams.iter().filter(|s| s.len > 0).collect();
        live.sort_by(|a, b| worst_first((a.auc, a.stream), (b.auc, b.stream)));
        live.truncate(k);
        live.into_iter().cloned().collect()
    }

    /// Live streams with AUC strictly below `t` — equal to
    /// `AucFleet::count_below(t)` at this epoch (same explicit edge
    /// semantics: NaN and `t ≤ 0` count nothing, `t > 1` counts every
    /// live stream).
    pub fn count_below(&self, t: f64) -> usize {
        if t.is_nan() || t <= 0.0 {
            return 0;
        }
        let live = self.snapshot().streams.iter().filter(|s| s.len > 0);
        if t > 1.0 {
            live.count()
        } else {
            live.filter(|s| s.auc < t).count()
        }
    }

    /// Histogram of live-stream AUCs over `bins` equal-width buckets —
    /// equal to `AucFleet::auc_histogram(bins)` at this epoch.
    ///
    /// # Panics
    ///
    /// Panics if `bins == 0`, matching the fleet method (the serving
    /// surface validates first and answers 400 instead).
    pub fn auc_histogram(&self, bins: usize) -> AucHistogram {
        assert!(bins >= 1, "auc_histogram: bins must be >= 1");
        let mut counts = vec![0usize; bins];
        let mut live_streams = 0usize;
        for s in &self.snapshot().streams {
            if s.len == 0 {
                continue;
            }
            counts[((s.auc * bins as f64) as usize).min(bins - 1)] += 1;
            live_streams += 1;
        }
        AucHistogram { counts, live_streams }
    }
}

// ---------------------------------------------------------------------
// Publisher + subscriber fan-out
// ---------------------------------------------------------------------

/// Which wire dialect a subscriber speaks.
#[derive(Clone, Copy)]
pub(super) enum SubProto {
    Http,
    Binary,
}

enum OutMsg {
    /// One pre-encoded delta, shared across every subscriber's queue.
    Delta { json: Arc<str>, bin: Arc<[u8]> },
    /// Verbatim bytes (the subscription preamble + baseline).
    Raw(Vec<u8>),
    /// Liveness probe from the registration path; writers ignore it.
    Ping,
}

/// The publisher-side handle of one subscriber.
struct SubHandle {
    tx: SyncSender<OutMsg>,
    lagged: Arc<AtomicBool>,
}

impl SubHandle {
    /// Offer one delta; `false` means the writer is gone (prune).
    /// A full queue marks the subscriber lagged and *keeps* it — its
    /// writer resyncs from the current view instead.
    fn offer(&self, json: &Arc<str>, bin: &Arc<[u8]>) -> bool {
        match self.tx.try_send(OutMsg::Delta { json: Arc::clone(json), bin: Arc::clone(bin) }) {
            Ok(()) => true,
            Err(TrySendError::Full(_)) => {
                self.lagged.store(true, Ordering::Release);
                true
            }
            Err(TrySendError::Disconnected(_)) => false,
        }
    }

    /// Is the writer still attached? (Used to prune before the
    /// subscriber-cap check; a `Full` answer still proves liveness.)
    fn alive(&self) -> bool {
        !matches!(self.tx.try_send(OutMsg::Ping), Err(TrySendError::Disconnected(_)))
    }
}

struct PubSub {
    view: Arc<PublishedView>,
    subs: Vec<SubHandle>,
}

/// The publisher state + subscriber fan-out of one server.
pub(super) struct Fanout {
    pubsub: Mutex<PubSub>,
    writers: Mutex<Vec<JoinHandle<()>>>,
    stop: Arc<AtomicBool>,
    max_subs: usize,
}

impl Fanout {
    pub(super) fn new(baseline: FleetSketch, stop: Arc<AtomicBool>, max_subs: usize) -> Fanout {
        Fanout {
            pubsub: Mutex::new(PubSub {
                view: Arc::new(PublishedView::new(0, baseline)),
                subs: Vec::new(),
            }),
            writers: Mutex::new(Vec::new()),
            stop,
            max_subs,
        }
    }

    /// The current view, possibly unmaterialized — for seq echoes and
    /// `last_published`.
    pub(super) fn view(&self) -> Arc<PublishedView> {
        Arc::clone(&lock(&self.pubsub).view)
    }

    /// The current view, materialized — what the read endpoints serve
    /// from. Fast path: one brief `pubsub` lock. First read of an
    /// epoch: one fleet-lock acquisition, then the current view is
    /// swapped for a filled twin at the same seq (see the module docs
    /// for why re-reading the view under the fleet lock is what makes
    /// this sound). Views are immutable, so readers holding the
    /// unfilled `Arc` are unaffected; lock order is fleet → pubsub,
    /// matching [`Fanout::republish`].
    pub(super) fn materialized_view(&self, fleet: &Mutex<AucFleet>) -> Arc<PublishedView> {
        let view = self.view();
        if view.is_materialized() {
            return view;
        }
        let guard = fleet.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        let mut ps = lock(&self.pubsub);
        if !ps.view.is_materialized() {
            ps.view = Arc::new(ps.view.materialized(&guard));
        }
        Arc::clone(&ps.view)
    }

    /// Publish the fleet's current state. **Must be called with the
    /// fleet lock held** (the epoch invariant). Swaps the view; if the
    /// sketch changed, bumps `seq` and enqueues one delta per
    /// subscriber — `try_send` only, never a socket write.
    pub(super) fn republish(&self, fleet: &AucFleet) {
        let next = fleet.sketch_state();
        let mut ps = lock(&self.pubsub);
        if *ps.view.sketch() == next {
            // Quiet epoch: subscribers owe nothing, but snapshot-level
            // state may still have moved (e.g. hibernation changing
            // footprints) — refresh a materialized view in place.
            if ps.view.is_materialized() {
                ps.view = Arc::new(PublishedView::new(ps.view.seq(), next));
            }
            return;
        }
        let seq = ps.view.seq() + 1;
        let json_line: Arc<str> = json::delta_to_json(seq, ps.view.sketch(), &next).into();
        let bin: Arc<[u8]> = wire::encode_delta(seq, ps.view.sketch(), &next).into();
        ps.view = Arc::new(PublishedView::new(seq, next));
        ps.subs.retain(|sub| sub.offer(&json_line, &bin));
    }

    /// Attached subscribers (writers still running).
    pub(super) fn subscriber_count(&self) -> usize {
        let mut ps = lock(&self.pubsub);
        ps.subs.retain(SubHandle::alive);
        ps.subs.len()
    }

    /// Attach a subscriber: enqueue its preamble + baseline atomically
    /// with joining the broadcast list (so the first delta it sees is
    /// `baseline_seq + 1` — gapless), then hand the socket to a
    /// dedicated writer thread. `Err(stream)` means the subscriber cap
    /// (`max_conns`) is reached and the caller should shed.
    pub(super) fn subscribe(
        self: &Arc<Fanout>,
        stream: TcpStream,
        proto: SubProto,
        tracker: &Arc<ConnTracker>,
    ) -> Result<(), TcpStream> {
        let (tx, rx) = mpsc::sync_channel(SUB_QUEUE);
        let lagged = Arc::new(AtomicBool::new(false));
        {
            let mut ps = lock(&self.pubsub);
            ps.subs.retain(SubHandle::alive);
            if ps.subs.len() >= self.max_subs {
                return Err(stream);
            }
            let preamble = match proto {
                SubProto::Http => {
                    let mut bytes = b"HTTP/1.1 200 OK\r\nContent-Type: application/x-ndjson\r\nConnection: close\r\n\r\n".to_vec();
                    bytes.extend_from_slice(
                        json::sketch_to_json(ps.view.seq(), ps.view.sketch()).as_bytes(),
                    );
                    bytes.push(b'\n');
                    bytes
                }
                SubProto::Binary => {
                    let mut frame = Vec::new();
                    let payload = seq_prefixed(
                        ps.view.seq(),
                        &wire::encode_sketch(ps.view.seq(), ps.view.sketch()),
                    );
                    wire::write_frame(&mut frame, wire::STATUS_OK, &payload)
                        .expect("vec write is infallible");
                    frame
                }
            };
            tx.try_send(OutMsg::Raw(preamble)).expect("fresh queue has room for the baseline");
            ps.subs.push(SubHandle { tx, lagged: Arc::clone(&lagged) });
        }
        let token = tracker.register(&stream);
        let fanout = Arc::clone(self);
        let tracker_for_writer = Arc::clone(tracker);
        let writer = thread::Builder::new().name("fleet-serve-sub".to_string()).spawn(move || {
            run_writer(stream, proto, rx, lagged, &fanout);
            tracker_for_writer.deregister(token);
        });
        match writer {
            Ok(handle) => {
                let mut writers = lock(&self.writers);
                writers.retain(|w| !w.is_finished());
                writers.push(handle);
            }
            // Spawn failure (process out of threads) closes the
            // stream — it was moved into the dropped closure — and
            // the dead handle is pruned at the next publish. Degrade,
            // don't panic.
            Err(_) => tracker.deregister(token),
        }
        Ok(())
    }

    /// Drop every subscriber handle (disconnecting their queues) and
    /// join the writer threads. Called by `FleetServer::shutdown`
    /// after the connection tracker has half-closed the sockets, so
    /// writers blocked mid-`write` return immediately.
    pub(super) fn shutdown(&self) {
        lock(&self.pubsub).subs.clear();
        let writers = std::mem::take(&mut *lock(&self.writers));
        for w in writers {
            let _ = w.join();
        }
    }
}

/// Prefix a response body with the 8-byte LE sequence number — the
/// binary protocol's seq echo (HTTP echoes `X-Fleet-Seq` instead).
pub(super) fn seq_prefixed(seq: u64, body: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(8 + body.len());
    out.extend_from_slice(&seq.to_le_bytes());
    out.extend_from_slice(body);
    out
}

/// One subscriber's writer loop: drain the queue onto the socket;
/// on lag, discard the stale queue and resync (notice + baseline);
/// on any write failure or disconnect, exit — the publisher prunes
/// the handle at its next publish.
fn run_writer(
    mut stream: TcpStream,
    proto: SubProto,
    rx: Receiver<OutMsg>,
    lagged: Arc<AtomicBool>,
    fanout: &Fanout,
) {
    loop {
        if fanout.stop.load(Ordering::Acquire) {
            return;
        }
        // Lag wins over whatever is queued: everything in the queue
        // predates the mark, and the resync replaces it wholesale.
        if lagged.load(Ordering::Acquire) {
            match resync(&mut stream, proto, &rx, &lagged, fanout) {
                Ok(()) => continue,
                Err(_) => return,
            }
        }
        match rx.recv_timeout(WRITER_TICK) {
            Ok(msg) => {
                if write_msg(&mut stream, proto, &msg).is_err() {
                    return;
                }
            }
            Err(RecvTimeoutError::Timeout) => {}
            Err(RecvTimeoutError::Disconnected) => return,
        }
    }
}

/// Coalesce a lagged subscriber back to the current epoch: under the
/// `pubsub` lock (so the publisher cannot enqueue concurrently) drain
/// and discard the stale queue, clear the mark, and encode a `lagged`
/// notice plus a fresh baseline from the current view. The next delta
/// the publisher enqueues is `baseline_seq + 1` — gapless again.
fn resync(
    stream: &mut TcpStream,
    proto: SubProto,
    rx: &Receiver<OutMsg>,
    lagged: &AtomicBool,
    fanout: &Fanout,
) -> io::Result<()> {
    let bytes = {
        let ps = lock(&fanout.pubsub);
        while rx.try_recv().is_ok() {}
        lagged.store(false, Ordering::Release);
        let (seq, sketch) = (ps.view.seq(), ps.view.sketch());
        match proto {
            SubProto::Http => {
                let mut out = json::lagged_to_json(seq).into_bytes();
                out.push(b'\n');
                out.extend_from_slice(json::sketch_to_json(seq, sketch).as_bytes());
                out.push(b'\n');
                out
            }
            SubProto::Binary => {
                let mut out = Vec::new();
                wire::write_frame(&mut out, wire::OP_LAGGED, &seq.to_le_bytes())
                    .expect("vec write is infallible");
                wire::write_frame(&mut out, wire::OP_BASELINE, &wire::encode_sketch(seq, sketch))
                    .expect("vec write is infallible");
                out
            }
        }
    };
    stream.write_all(&bytes)
}

fn write_msg(stream: &mut TcpStream, proto: SubProto, msg: &OutMsg) -> io::Result<()> {
    match msg {
        OutMsg::Delta { json, bin } => match proto {
            SubProto::Http => {
                stream.write_all(json.as_bytes())?;
                stream.write_all(b"\n")
            }
            SubProto::Binary => wire::write_frame(stream, wire::OP_DELTA, bin),
        },
        OutMsg::Raw(bytes) => stream.write_all(bytes),
        OutMsg::Ping => Ok(()),
    }
}

/// Same poison-ignoring lock policy as `serve/limits.rs`.
fn lock<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}
