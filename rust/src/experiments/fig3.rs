//! Figure 3: computational cost versus window size.
//!
//! Paper setup: Miniboone, exact (`O(k)`/update) against the estimator
//! at ε ∈ {0.01, 0.1} (`O((log k)/ε)`/update), window sizes swept on a
//! log grid. The paper reports the estimate being **17× faster at
//! k = 10 000 with ε = 0.1**, with the speed-up growing in k.
//!
//! Protocol: for each k, stream the same scored events through (a) the
//! exact baseline — tree maintenance + full `O(k)` recompute per event,
//! exactly the §5 Brzezinski & Stefanowski loop — and (b) the
//! approximate estimator with its `O(|C|)` query per event.

use std::time::{Duration, Instant};

use super::report::{fmt_duration, Table};
use super::ExpConfig;
use crate::coordinator::{ApproxAuc, AucEstimator, ExactAuc};
use crate::stream::synth::{miniboone_like, Dataset};

/// Window sizes swept by default (paper: up to 10⁴).
pub const WINDOWS: [usize; 5] = [100, 316, 1000, 3162, 10_000];

/// ε values compared against exact (paper's figure legend).
pub const FIG3_EPSILONS: [f64; 2] = [0.01, 0.1];

/// One sweep point.
#[derive(Clone, Debug)]
pub struct Point {
    /// Window size `k`.
    pub window: usize,
    /// Exact per-event time.
    pub exact: Duration,
    /// Approx per-event time per ε (same order as
    /// [`FIG3_EPSILONS`]).
    pub approx: Vec<Duration>,
}

impl Point {
    /// Speed-up of the `i`-th ε over exact.
    pub fn speedup(&self, i: usize) -> f64 {
        self.exact.as_secs_f64() / self.approx[i].as_secs_f64().max(1e-12)
    }
}

fn timed_pass<E: AucEstimator>(stream: &[(f64, bool)], window: usize, mut est: E) -> Duration {
    let mut fifo = std::collections::VecDeque::with_capacity(window + 1);
    let start = Instant::now();
    let mut sink = 0.0;
    for &(s, l) in stream {
        est.insert(s, l);
        fifo.push_back((s, l));
        if fifo.len() > window {
            let (os, ol) = fifo.pop_front().unwrap();
            est.remove(os, ol);
        }
        sink += est.auc();
    }
    let total = start.elapsed();
    std::hint::black_box(sink);
    total / stream.len().max(1) as u32
}

/// Run the sweep. `events` is clamped below `4·k` so every window size
/// sees several full turnovers.
pub fn sweep(cfg: ExpConfig, windows: &[usize]) -> Vec<Point> {
    let mut data = Dataset::new(miniboone_like(), cfg.seed);
    let mut points = Vec::new();
    for &k in windows {
        let n = cfg.events.max(4 * k);
        let stream = data.score_stream(n);
        let exact = timed_pass(&stream, k, ExactAuc::new());
        let approx = FIG3_EPSILONS
            .iter()
            .map(|&eps| timed_pass(&stream, k, ApproxAuc::new(eps)))
            .collect();
        points.push(Point { window: k, exact, approx });
    }
    points
}

/// Build the Figure 3 table.
pub fn run(cfg: ExpConfig) -> Table {
    let mut table = Table::new(
        "fig3: per-event cost vs window size (miniboone, ≥4k events per k)",
        &[
            "window_k",
            "exact/event",
            "eps=0.01/event",
            "eps=0.1/event",
            "speedup@0.01",
            "speedup@0.1",
        ],
    );
    for p in sweep(cfg, &WINDOWS) {
        table.push(vec![
            p.window.to_string(),
            fmt_duration(p.exact),
            fmt_duration(p.approx[0]),
            fmt_duration(p.approx[1]),
            format!("{:.1}x", p.speedup(0)),
            format!("{:.1}x", p.speedup(1)),
        ]);
    }
    table
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn speedup_grows_with_window_size() {
        let cfg = ExpConfig { events: 2000, window: 0, seed: 5 };
        let points = sweep(cfg, &[100, 2000]);
        let small = points[0].speedup(1);
        let large = points[1].speedup(1);
        assert!(
            large > small,
            "speed-up must grow with k: {small:.2} → {large:.2}"
        );
        // At k = 2000 the estimate must already be clearly faster.
        assert!(large > 2.0, "k=2000 ε=0.1 speed-up only {large:.2}x");
    }

    #[test]
    fn looser_epsilon_is_not_slower() {
        let cfg = ExpConfig { events: 2000, window: 0, seed: 6 };
        let points = sweep(cfg, &[3000]);
        let p = &points[0];
        assert!(
            p.approx[1] <= p.approx[0].mul_f64(1.3),
            "ε=0.1 should not be slower than ε=0.01: {:?} vs {:?}",
            p.approx[1],
            p.approx[0]
        );
    }
}
