//! §7 extension: weighted data points with from-scratch `(1+ε)`-lists.
//!
//! ```sh
//! cargo run --release --example weighted_scratch
//! ```
//!
//! Demonstrates the weighted estimator on an importance-weighted stream
//! (e.g. events carrying sampling weights): exact vs approximate AUC
//! across ε, the selection size, and the query-time trade-off the paper
//! sketches (`O((log² k)/ε)` per evaluation instead of incremental
//! maintenance).

use std::time::Instant;

use streamauc::coordinator::WeightedAuc;
use streamauc::stream::Pcg;

fn main() {
    let mut rng = Pcg::seed(0x57);
    let mut w = WeightedAuc::new();
    // Importance-weighted stream: weights follow a heavy-ish tail.
    let n = 200_000;
    for _ in 0..n {
        let pos = rng.chance(0.35);
        let score = if pos { rng.normal_with(0.42, 0.18) } else { rng.normal_with(0.58, 0.18) };
        let weight = (-rng.uniform().ln()).max(0.05); // Exp(1) weights
        w.insert(score, pos, weight);
    }
    let t = Instant::now();
    let exact = w.exact_auc();
    let exact_time = t.elapsed();
    println!("{n} weighted points; exact AUC {exact:.5} in {exact_time:.2?}\n");
    println!(
        "{:>8}  {:>9}  {:>9}  {:>10}  {:>9}",
        "epsilon", "approx", "rel_err", "selection", "query"
    );
    for eps in [1.0, 0.3, 0.1, 0.03, 0.01] {
        let t = Instant::now();
        let approx = w.approx_auc(eps);
        let q = t.elapsed();
        let rel = (approx - exact).abs() / exact;
        println!(
            "{eps:>8}  {approx:>9.5}  {rel:>9.2e}  {:>10}  {q:>9.2?}",
            w.selection_len(eps)
        );
        assert!(rel <= eps / 2.0 + 1e-9, "guarantee violated at ε={eps}");
    }
    println!("\nweighted §7 extension OK: guarantee holds for every ε.");
}
