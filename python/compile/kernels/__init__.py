"""Pallas kernels (L1)."""
from . import logreg, ref  # noqa: F401
