//! The paper's motivating scenario (§1): continuously monitor a
//! classifier in production and alarm on breakdowns.
//!
//! ```sh
//! cargo run --release --example monitoring
//! ```
//!
//! A Hepmass-like event stream is scored by an (analytic) classifier.
//! Three failure modes are injected one after another:
//!
//! 1. a gradual concept drift (labels decouple from scores over time),
//! 2. recovery (e.g. the model was retrained),
//! 3. an abrupt system failure (score pipeline degrades with noise).
//!
//! The windowed approximate AUC (ε = 0.05) feeds an EWMA drift monitor;
//! the example prints the timeline and the alarms it raises.

use streamauc::coordinator::window::Window;
use streamauc::coordinator::{ApproxAuc, AucMonitor, MonitorEvent};
use streamauc::stream::synth::{hepmass_like, Dataset};
use streamauc::stream::Drift;

const WINDOW: usize = 2000;
const EVENTS: usize = 120_000;

fn main() {
    let mut data = Dataset::new(hepmass_like(), 7);
    let mut stream = data.score_stream(EVENTS);
    // Failure 1: gradual label drift between 30k and 50k.
    Drift::Gradual { from: 30_000, to: 50_000, rate: 0.35 }.apply(&mut stream, 1);
    // Recovery: the clean generator resumes after 50k — re-draw the tail.
    let tail = data.score_stream(EVENTS - 50_000);
    stream.splice(50_000.., tail);
    // Failure 2: abrupt score-noise failure at 90k.
    Drift::NoiseRamp { from: 90_000, to: 92_000, sd: 0.35 }.apply(&mut stream, 2);

    let mut window = Window::with_estimator(WINDOW, ApproxAuc::new(0.05));
    let mut monitor = AucMonitor::new(0.0001, 0.06, 400, WINDOW as u32);
    let mut alarms: Vec<usize> = Vec::new();

    println!("injected: gradual drift @30k–50k, recovery @50k, noise failure @90k\n");
    println!("{:>8}  {:>8}  {:>9}  state", "event", "auc~", "baseline");
    for (i, &(score, label)) in stream.iter().enumerate() {
        window.push(score, label);
        if !window.is_full() {
            continue;
        }
        let event = monitor.observe(window.auc());
        if event == MonitorEvent::Alarm {
            alarms.push(i);
            println!(
                "{i:>8}  {:>8.4}  {:>9.4}  *** ALARM ***",
                window.auc(),
                monitor.baseline()
            );
        } else if i % 10_000 == 0 {
            println!(
                "{i:>8}  {:>8.4}  {:>9.4}  {:?}",
                window.auc(),
                monitor.baseline(),
                event
            );
        }
    }

    println!("\nalarms at events: {alarms:?}");
    assert_eq!(alarms.len(), 2, "expected exactly two alarms (one per failure)");
    assert!(
        (30_000..55_000).contains(&alarms[0]),
        "first alarm should land inside the gradual-drift span"
    );
    assert!(alarms[1] > 90_000, "second alarm should follow the noise failure");
    println!("monitoring scenario reproduced: both failures caught, recovery quiet.");
}
