//! Concept-drift injectors.
//!
//! The paper motivates windowed AUC monitoring with “changes in the
//! underlying distribution or a system failure” (§1). These injectors
//! transform a scored stream to reproduce the failure modes the monitor
//! (coordinator::monitor) must catch:
//!
//! * [`Drift::Abrupt`] — at a point in the stream, a fraction of labels
//!   flips (sudden regime change / upstream failure);
//! * [`Drift::Gradual`] — the score-label association decays linearly
//!   over a span (slow distribution shift);
//! * [`Drift::NoiseRamp`] — score noise grows over a span (sensor or
//!   feature-pipeline degradation).

use super::rng::Pcg;

/// A drift to inject into a scored stream.
#[derive(Clone, Copy, Debug)]
pub enum Drift {
    /// From `at` onward, each label flips with probability `rate`.
    Abrupt {
        /// Stream index where the change happens.
        at: usize,
        /// Probability a post-change label flips.
        rate: f64,
    },
    /// Between `from` and `to`, flip probability ramps 0 → `rate`.
    Gradual {
        /// Ramp start index.
        from: usize,
        /// Ramp end index (flip probability `rate` from here on).
        to: usize,
        /// Final flip probability.
        rate: f64,
    },
    /// Between `from` and `to`, zero-mean score noise ramps 0 → `sd`;
    /// scores stay clamped to [0, 1].
    NoiseRamp {
        /// Ramp start index.
        from: usize,
        /// Ramp end index.
        to: usize,
        /// Final noise standard deviation.
        sd: f64,
    },
}

impl Drift {
    /// Apply the drift to a scored stream in place, deterministically.
    pub fn apply(self, stream: &mut [(f64, bool)], seed: u64) {
        let mut rng = Pcg::seed_stream(seed, 0xD21F7);
        match self {
            Drift::Abrupt { at, rate } => {
                for pair in stream.iter_mut().skip(at) {
                    if rng.chance(rate) {
                        pair.1 = !pair.1;
                    }
                }
            }
            Drift::Gradual { from, to, rate } => {
                assert!(to > from, "empty ramp");
                for (i, pair) in stream.iter_mut().enumerate().skip(from) {
                    let t = ((i - from) as f64 / (to - from) as f64).min(1.0);
                    if rng.chance(rate * t) {
                        pair.1 = !pair.1;
                    }
                }
            }
            Drift::NoiseRamp { from, to, sd } => {
                assert!(to > from, "empty ramp");
                for (i, pair) in stream.iter_mut().enumerate().skip(from) {
                    let t = ((i - from) as f64 / (to - from) as f64).min(1.0);
                    pair.0 = (pair.0 + rng.normal() * sd * t).clamp(0.0, 1.0);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::NaiveAuc;
    use crate::stream::synth::{hepmass_like, Dataset};

    fn clean_stream(n: usize) -> Vec<(f64, bool)> {
        Dataset::new(hepmass_like().scaled(1000), 11).score_stream(n)
    }

    #[test]
    fn abrupt_degrades_only_after_the_point() {
        let mut s = clean_stream(4000);
        let before_auc = NaiveAuc::of(&s[..2000]);
        Drift::Abrupt { at: 2000, rate: 0.5 }.apply(&mut s, 1);
        assert_eq!(NaiveAuc::of(&s[..2000]), before_auc, "prefix untouched");
        let after = NaiveAuc::of(&s[2000..]);
        assert!(after < 0.65, "full flip noise should kill AUC, got {after}");
    }

    #[test]
    fn gradual_is_monotone_decay() {
        let mut s = clean_stream(6000);
        Drift::Gradual { from: 2000, to: 5000, rate: 0.5 }.apply(&mut s, 2);
        let early = NaiveAuc::of(&s[2000..3000]);
        let late = NaiveAuc::of(&s[4500..5500]);
        assert!(early > late + 0.05, "decay not monotone: {early} vs {late}");
    }

    #[test]
    fn noise_ramp_degrades_scores_not_labels() {
        let mut s = clean_stream(4000);
        let labels_before: Vec<bool> = s.iter().map(|p| p.1).collect();
        Drift::NoiseRamp { from: 1000, to: 3000, sd: 0.4 }.apply(&mut s, 3);
        let labels_after: Vec<bool> = s.iter().map(|p| p.1).collect();
        assert_eq!(labels_before, labels_after);
        let clean = NaiveAuc::of(&s[..1000]);
        let noisy = NaiveAuc::of(&s[3000..]);
        assert!(noisy < clean - 0.05, "noise must reduce AUC: {noisy} vs {clean}");
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = clean_stream(1000);
        let mut b = a.clone();
        Drift::Abrupt { at: 100, rate: 0.3 }.apply(&mut a, 42);
        Drift::Abrupt { at: 100, rate: 0.3 }.apply(&mut b, 42);
        assert_eq!(a, b);
    }
}
